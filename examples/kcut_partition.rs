//! Balanced k-cut on tabular data: ABA vs the METIS-like multilevel
//! partitioner (the paper's §5.5 application).
//!
//! ```bash
//! cargo run --release --example kcut_partition
//! ```
//!
//! On a complete squared-Euclidean graph, minimizing the balanced-cut
//! cost is equivalent to maximizing the within-group pairwise sum W(C),
//! so ABA — which never materializes the graph — competes directly with
//! a graph partitioner that needs an explicit sparse adjacency input.

use aba::algo::ClusterStats;
use aba::data::synth::{load, Scale};
use aba::graph::builder::random_neighbor_graph;
use aba::graph::metis_like::{min_max_ratio, partition, PartitionConfig};
use aba::util::timer::Timer;
use aba::{Aba, Anticlusterer};

fn main() -> anyhow::Result<()> {
    let ds = load("electric", Scale::Small)?;
    let k = 20;
    println!("balanced {k}-cut on {} (n={}, d={})\n", ds.name, ds.n, ds.d);

    // --- ABA: straight from the feature matrix -------------------------
    let aba_part = Aba::builder().build()?.partition(&ds, k)?;
    // Algorithm-only time, so the comparison with the METIS timer below
    // (which also excludes stats computation) is apples to apples.
    let aba_secs = aba_part.timings.algo_secs();
    let aba_labels = &aba_part.labels;
    let aba_stats = &aba_part.stats;

    // --- METIS-like: needs the sparse graph input first ----------------
    let t = Timer::start();
    let graph = random_neighbor_graph(&ds, 30, 17);
    let input_secs = t.secs();
    let t = Timer::start();
    let metis_labels = partition(&graph, &PartitionConfig::new(k));
    let metis_secs = t.secs();
    let metis_stats = ClusterStats::compute(&ds, &metis_labels, k);

    println!("                         ABA        METIS-like");
    println!(
        "W(C) (higher=better)     {:>12.0}  {:>12.0}",
        aba_stats.pairwise_total(),
        metis_stats.pairwise_total()
    );
    println!(
        "cut cost on p=30 graph   {:>12}  {:>12}",
        graph.cut_cost(&aba_labels),
        graph.cut_cost(&metis_labels)
    );
    println!("partition time [s]       {aba_secs:>12.3}  {metis_secs:>12.3}");
    println!("input-construction [s]   {:>12}  {input_secs:>12.3}", "0");
    println!(
        "min/max size ratio [%]   {:>12.2}  {:>12.2}",
        aba_stats.min_max_ratio_pct(),
        min_max_ratio(&metis_labels, k)
    );
    let dev = 100.0 * (metis_stats.pairwise_total() - aba_stats.pairwise_total())
        / aba_stats.pairwise_total();
    println!("\nMETIS-like W(C) deviation from ABA: {dev:.3}% (negative = ABA wins)");
    Ok(())
}
