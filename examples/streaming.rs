//! Streaming / serving scenario: one live `OnlinePartition` under churn.
//!
//! ```bash
//! cargo run --release --example streaming
//! ```
//!
//! A serving process keeps K = 16 representative anticlusters over a
//! population of 8,000 rows while users arrive and expire: each round
//! inserts 200 new rows (a small max-gain rectangular assignment),
//! expires the 200 oldest (with balance repair), and runs a bounded
//! refine pass scoped to the touched clusters. The objective is read
//! from delta-maintained state (no O(n·d) recompute), compared at the
//! end against a from-scratch re-solve of the final contents, and the
//! handle is persisted + reloaded to demonstrate the warm-restart path.

use aba::data::synth::{generate, SynthKind};
use aba::{Aba, Anticlusterer, OnlinePartition};
use std::collections::VecDeque;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let (n, k, d, rounds, churn) = (8_000usize, 16usize, 16usize, 10usize, 200usize);
    let ds = generate(
        SynthKind::GaussianMixture { components: 6, spread: 4.0 },
        n,
        d,
        21,
        "stream-seed",
    );
    let mut session = Aba::builder().auto_hier(false).build()?;

    let t = Instant::now();
    let mut live = session.partition_online(&ds.view(), k)?;
    println!(
        "initial partition: n={n}, k={k}, d={d} in {:.3}s — objective {:.1}",
        t.elapsed().as_secs_f64(),
        live.objective()
    );

    // The arrival stream (cycled) and the expiry queue (oldest first).
    let arrivals = generate(
        SynthKind::GaussianMixture { components: 6, spread: 4.0 },
        4_000,
        d,
        22,
        "arrivals",
    );
    let mut next_arrival = 0usize;
    let mut oldest: VecDeque<u64> = (0..n as u64).collect();

    let t = Instant::now();
    for round in 0..rounds {
        let idx: Vec<usize> = (0..churn)
            .map(|j| (next_arrival + j) % arrivals.n)
            .collect();
        next_arrival += churn;
        let batch = arrivals.view().select(&idx);
        let ids = live.insert_batch(&batch)?;
        let expire: Vec<u64> = oldest.drain(..churn).collect();
        live.remove(&expire)?;
        oldest.extend(ids);
        let r = live.refine(20_000);
        println!(
            "round {round:>2}: +{churn}/-{churn} rows, {:>3} refine swaps, objective {:.1}",
            r.swapped,
            live.objective()
        );
    }
    let churn_secs = t.elapsed().as_secs_f64();
    let updates = 2 * rounds * churn;
    println!(
        "{updates} row updates in {churn_secs:.3}s ({:.0} updates/s)",
        updates as f64 / churn_secs.max(1e-9)
    );

    // How much objective does delta maintenance give up vs re-solving
    // the final population from scratch?
    let current = live.to_dataset("current")?;
    let t = Instant::now();
    let fresh = session.partition(&current, k)?;
    let delta_obj = live.objective();
    println!(
        "delta-maintained {delta_obj:.1} vs from-scratch {:.1} ({:+.3}%, re-solve took {:.3}s)",
        fresh.objective,
        100.0 * (delta_obj - fresh.objective) / fresh.objective,
        t.elapsed().as_secs_f64()
    );

    // Warm restart: persist, reload under the same session config.
    let path = std::env::temp_dir().join("aba_streaming_example.json");
    live.save(&path)?;
    let bytes = std::fs::metadata(&path)?.len();
    let mut back = OnlinePartition::load(&path, session.config())?;
    assert_eq!(back.objective(), live.objective());
    assert_eq!(back.sizes(), live.sizes());
    println!("warm restart OK: {bytes} snapshot bytes round-tripped bit-identically");
    std::fs::remove_file(&path).ok();
    Ok(())
}
