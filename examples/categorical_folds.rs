//! Stratified K-fold cross-validation via categorical anticlustering
//! (the paper's §5.4 variant applied to its §1 cross-validation use case).
//!
//! ```bash
//! cargo run --release --example categorical_folds
//! ```
//!
//! Each fold must (a) mirror the overall class distribution exactly
//! (constraint (5)) and (b) be *representative* — have nearly the same
//! feature distribution as the full dataset. ABA with categories gives
//! both; plain stratified random folds only give (a).

use aba::algo::ClusterStats;
use aba::baselines::random_part::random_partition_categorical;
use aba::data::kmeans::kmeans;
use aba::data::synth::{generate, SynthKind};
use aba::{Aba, Anticlusterer};

fn main() -> anyhow::Result<()> {
    // A classification-like dataset: 12,000 points, 12 features, with a
    // "class" feature derived from the latent structure (5 classes).
    let base = generate(
        SynthKind::GaussianMixture { components: 5, spread: 5.0 },
        12_000,
        12,
        21,
        "folds",
    );
    let classes = kmeans(&base, 5, 50, 3).labels;
    let ds = base.with_categories(classes.clone())?;
    let folds = 10;

    println!("stratified {folds}-fold construction on n={}, 5 classes\n", ds.n);

    let aba_folds = Aba::builder().build()?.partition(&ds, folds)?.labels;
    for (name, labels) in [
        ("ABA folds ", aba_folds),
        ("Rand folds", random_partition_categorical(&classes, folds, 9)),
    ] {
        let stats = ClusterStats::compute(&ds, &labels, folds);
        // Class balance: max deviation of any class count across folds.
        let mut worst_spread = 0usize;
        for class in 0..5u32 {
            let per_fold: Vec<usize> = (0..folds as u32)
                .map(|f| {
                    (0..ds.n)
                        .filter(|&i| labels[i] == f && classes[i] == class)
                        .count()
                })
                .collect();
            worst_spread = worst_spread
                .max(per_fold.iter().max().unwrap() - per_fold.iter().min().unwrap());
        }
        println!("[{name}]");
        println!("  class-count spread across folds (max): {worst_spread} (<= 1 required)");
        println!(
            "  fold representativeness — diversity sd: {:.4}, range: {:.4}",
            stats.diversity_sd(),
            stats.diversity_range()
        );
        println!(
            "  objective (ssd to fold centroids): {:.1}\n",
            stats.ssd_total()
        );
    }
    println!("Both satisfy the stratification constraint; ABA folds additionally have");
    println!("near-identical internal diversity (sd orders of magnitude lower), i.e.");
    println!("every fold is a faithful miniature of the dataset.");
    Ok(())
}
