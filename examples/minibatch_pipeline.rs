//! End-to-end driver: the full three-layer system on a real small
//! workload (this is the repo's E2E validation run — see EXPERIMENTS.md).
//!
//! ```bash
//! cargo run --release --example minibatch_pipeline            # native backend
//! cargo run --release --example minibatch_pipeline -- --xla   # AOT/PJRT backend
//! ```
//!
//! Workload: the paper's machine-learning motivation — mini-batch
//! construction for SGD. A 30,000 x 32 mixture dataset with noisy linear
//! labels is partitioned into K = 200 anticlusters per epoch by the L3
//! streaming pipeline (ABA with LAPJV on the per-batch cost matrices —
//! which, with `--xla`, are computed by the AOT-compiled Pallas/JAX
//! artifact through PJRT). A logistic-regression consumer trains on the
//! streamed batches; the same budget is repeated with random shuffling.
//!
//! Reported: pipeline throughput, loss trajectory, and the within-epoch
//! batch-loss variance — the measurable benefit of representative batches.

use aba::algo::AbaConfig;
use aba::data::synth::{generate, SynthKind};
use aba::metrics::Summary;
use aba::pipeline::sgd::{synth_labels, LogReg};
use aba::pipeline::{run_pipeline, BatchStrategy, PipelineConfig};
use aba::runtime::BackendKind;

fn main() -> anyhow::Result<()> {
    let use_xla = std::env::args().any(|a| a == "--xla");
    let ds = generate(
        SynthKind::GaussianMixture { components: 10, spread: 3.0 },
        30_000,
        32,
        7,
        "minibatch-e2e",
    );
    let y = synth_labels(&ds, 0.05, 11);
    let (k, epochs) = (200, 5);
    println!(
        "E2E mini-batch pipeline: n={}, d={}, K={k} batches/epoch, {epochs} epochs, backend={}",
        ds.n,
        ds.d,
        if use_xla { "xla (AOT Pallas artifact via PJRT)" } else { "native" }
    );

    let mut report = Vec::new();
    for strategy_name in ["ABA", "Random"] {
        let strategy = match strategy_name {
            "ABA" => BatchStrategy::Aba {
                cfg: AbaConfig {
                    backend: if use_xla { BackendKind::Xla } else { BackendKind::Native },
                    ..AbaConfig::default()
                },
                shuffle_seed: 3,
            },
            _ => BatchStrategy::Random { seed: 3 },
        };
        let cfg = PipelineConfig { k, epochs, queue_depth: 8, strategy };
        let mut model = LogReg::new(ds.d, 0.3);
        let mut epoch_losses: Vec<Vec<f64>> = vec![Vec::new(); epochs];
        let mut last_epoch_batches: Vec<Vec<usize>> = Vec::new();
        let stats = run_pipeline(&ds, &cfg, |batch| {
            let loss = model.train_batch(&ds, &y, &batch.indices);
            epoch_losses[batch.epoch].push(loss);
            if batch.epoch == epochs - 1 {
                last_epoch_batches.push(batch.indices.clone());
            }
        })?;
        println!("\n[{strategy_name}]");
        println!(
            "  {} batches in {:.2}s total ({:.1} batches/s; partitioning {:.2}s, backpressure {:.3}s)",
            stats.batches_consumed,
            stats.total_secs,
            stats.batches_consumed as f64 / stats.total_secs,
            stats.produce_secs,
            stats.blocked_secs
        );
        println!("  loss curve (per-epoch mean ± sd of batch losses):");
        for (e, losses) in epoch_losses.iter().enumerate() {
            let s = Summary::of(losses);
            println!("    epoch {e}: {:.4} ± {:.4}", s.mean, s.sd);
        }
        // Batch representativeness, isolated from model drift: per-batch
        // loss of the *frozen* final model. Representative batches all
        // look like the full dataset, so their losses coincide.
        let frozen: Vec<f64> = last_epoch_batches
            .iter()
            .map(|b| model.loss(&ds, &y, b))
            .collect();
        let frozen_stats = Summary::of(&frozen);
        let final_stats = Summary::of(&epoch_losses[epochs - 1]);
        let acc = model.accuracy(&ds, &y);
        println!("  final accuracy: {acc:.4}");
        println!(
            "  frozen-model per-batch loss: mean {:.4}, sd {:.5} (batch representativeness)",
            frozen_stats.mean, frozen_stats.sd
        );
        report.push((strategy_name, final_stats.mean, frozen_stats.sd, acc));
    }

    println!("\n=== headline ===");
    let (aba, rand) = (&report[0], &report[1]);
    println!(
        "frozen-model batch-loss sd: ABA {:.5} vs Random {:.5} ({:.1}x lower gradient noise)",
        aba.2,
        rand.2,
        rand.2 / aba.2.max(1e-12)
    );
    println!(
        "final loss: ABA {:.4} vs Random {:.4}; accuracy: {:.4} vs {:.4}",
        aba.1, rand.1, aba.3, rand.3
    );
    Ok(())
}
