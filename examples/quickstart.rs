//! Quickstart: partition a dataset into representative anticlusters.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a tabular dataset, builds a reusable `Aba` session with the
//! builder API (LAPJV solver, native cost backend, automatic hierarchical
//! decomposition), and compares the rich `Partition` result against the
//! `RandomPartition` baseline through the same `Anticlusterer` trait.

use aba::baselines::RandomPartition;
use aba::data::synth::{generate, SynthKind};
use aba::{Aba, Anticlusterer};

fn main() -> anyhow::Result<()> {
    // 20,000 objects with latent cluster structure, 16 features.
    let ds = generate(
        SynthKind::GaussianMixture { components: 8, spread: 4.0 },
        20_000,
        16,
        42,
        "quickstart",
    );
    let k = 50;
    println!("dataset: n={}, d={}, k={k}", ds.n, ds.d);

    // Both algorithms behind one trait: swap freely.
    let mut solvers: Vec<Box<dyn Anticlusterer>> = vec![
        Box::new(Aba::builder().build()?),
        Box::new(RandomPartition::new(1)),
    ];
    let mut objectives = Vec::new();
    let mut sds = Vec::new();
    for solver in solvers.iter_mut() {
        let part = solver.partition(&ds, k)?;
        println!("\n{:<18} ({:.3} s)", solver.name(), part.timings.total_secs);
        println!("  objective (ssd to centroids): {:.2}", part.objective);
        println!(
            "  diversity sd / range:         {:.4} / {:.4}",
            part.stats.diversity_sd(),
            part.stats.diversity_range()
        );
        println!(
            "  anticluster sizes:            {}..{}",
            part.sizes().iter().min().unwrap(),
            part.sizes().iter().max().unwrap()
        );
        objectives.push(part.objective);
        sds.push(part.stats.diversity_sd());
    }

    let gain = 100.0 * (objectives[0] - objectives[1]) / objectives[1];
    let balance = sds[1] / sds[0].max(1e-12);
    println!("\nABA vs random: objective +{gain:.3}%, diversity balance {balance:.0}x tighter");

    // Sessions amortize: reuse the same ABA session for repeated calls
    // (K-fold CV sweeps, per-epoch batching, serving).
    let mut session = Aba::builder().build()?;
    print!("\nreused session across K sweeps:");
    for k in [10, 25, 50, 100] {
        let part = session.partition(&ds, k)?;
        print!("  K={k}: {:.3}s", part.timings.total_secs);
    }
    println!();
    Ok(())
}
