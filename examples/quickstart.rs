//! Quickstart: partition a dataset into representative anticlusters.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a tabular dataset, runs ABA with default settings (LAPJV
//! solver, native cost backend, automatic hierarchical decomposition),
//! and compares the result against random partitioning on the objective
//! and diversity-balance metrics the paper reports.

use aba::algo::{run_aba, AbaConfig, ClusterStats};
use aba::baselines::random_part::random_partition;
use aba::data::synth::{generate, SynthKind};
use aba::util::timer::timed;

fn main() -> anyhow::Result<()> {
    // 20,000 objects with latent cluster structure, 16 features.
    let ds = generate(
        SynthKind::GaussianMixture { components: 8, spread: 4.0 },
        20_000,
        16,
        42,
        "quickstart",
    );
    let k = 50;
    println!("dataset: n={}, d={}, k={k}", ds.n, ds.d);

    // --- ABA -----------------------------------------------------------
    let (labels, secs) = timed(|| run_aba(&ds, k, &AbaConfig::default()));
    let labels = labels?;
    let stats = ClusterStats::compute(&ds, &labels, k);
    println!("\nABA                ({secs:.3} s)");
    println!("  objective (ssd to centroids): {:.2}", stats.ssd_total());
    println!("  diversity sd / range:         {:.4} / {:.4}", stats.diversity_sd(), stats.diversity_range());
    println!(
        "  anticluster sizes:            {}..{}",
        stats.sizes.iter().min().unwrap(),
        stats.sizes.iter().max().unwrap()
    );

    // --- Random baseline -------------------------------------------------
    let (rand_labels, rsecs) = timed(|| random_partition(ds.n, k, 1));
    let rstats = ClusterStats::compute(&ds, &rand_labels, k);
    println!("\nRandom             ({rsecs:.3} s)");
    println!("  objective (ssd to centroids): {:.2}", rstats.ssd_total());
    println!("  diversity sd / range:         {:.4} / {:.4}", rstats.diversity_sd(), rstats.diversity_range());

    let gain = 100.0 * (stats.ssd_total() - rstats.ssd_total()) / rstats.ssd_total();
    let balance = rstats.diversity_sd() / stats.diversity_sd().max(1e-12);
    println!("\nABA vs random: objective +{gain:.3}%, diversity balance {balance:.0}x tighter");
    Ok(())
}
