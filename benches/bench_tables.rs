//! Table/figure regeneration bench: runs every paper table and figure at
//! quick scale and reports wall time per experiment. `cargo bench`
//! therefore exercises the entire harness end to end; full-scale runs
//! are `aba table <id>` / `aba fig <id>` (see EXPERIMENTS.md).

use aba::experiments::{common::ExpOptions, figs, t11, t4, t8, t9};
use aba::util::timer::Timer;

fn main() {
    let opts = ExpOptions {
        quick: true,
        time_limit_secs: 30.0,
        out_dir: std::path::PathBuf::from("results/quick"),
        ..ExpOptions::default()
    };
    println!("# bench_tables — full harness at quick scale (CSV under results/quick/)");
    let experiments: Vec<(&str, Box<dyn Fn() -> anyhow::Result<()>>)> = vec![
        ("table t4", Box::new(|| t4::table4(&opts_clone()).map(|_| ()))),
        ("table t6", Box::new(|| t4::table6(&opts_clone()).map(|_| ()))),
        ("table t8", Box::new(|| t8::table8(&opts_clone()).map(|_| ()))),
        ("table t9", Box::new(|| t9::table9(&opts_clone()).map(|_| ()))),
        ("table t10", Box::new(|| t9::table10(&opts_clone()).map(|_| ()))),
        ("table t11", Box::new(|| t11::table11(&opts_clone()).map(|_| ()))),
        ("fig f5", Box::new(|| figs::fig5(&opts_clone()).map(|_| ()))),
        ("fig f6", Box::new(|| figs::fig6(&opts_clone()).map(|_| ()))),
        ("fig f7", Box::new(|| figs::fig7(&opts_clone()).map(|_| ()))),
    ];
    let _ = &opts;
    let mut total = 0.0;
    for (name, run) in experiments {
        let t = Timer::start();
        run().unwrap_or_else(|e| panic!("{name} failed: {e:#}"));
        let secs = t.secs();
        total += secs;
        println!(">>> {name}: {secs:.2}s");
    }
    println!(">>> all experiments: {total:.2}s");
}

fn opts_clone() -> ExpOptions {
    ExpOptions {
        quick: true,
        time_limit_secs: 30.0,
        out_dir: std::path::PathBuf::from("results/quick"),
        ..ExpOptions::default()
    }
}
