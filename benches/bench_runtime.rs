//! Cost-backend benchmark: native Rust loops vs the AOT Pallas/JAX
//! artifact through PJRT, across the shipped shape buckets.
//!
//! This quantifies the three-layer integration overhead on CPU (literal
//! construction + PJRT dispatch + copy-out vs a plain loop). On a real
//! TPU the same artifact dispatch amortizes onto the MXU; see
//! EXPERIMENTS.md §Perf for the footprint estimates. The XLA columns
//! need both the `xla` feature and built artifacts (`make artifacts`);
//! otherwise the bench prints the native side only.

use aba::rng::Pcg32;
#[cfg(feature = "xla")]
use aba::runtime::XlaBackend;
use aba::runtime::{CostBackend, NativeBackend};
use aba::util::timer::bench;

#[cfg(feature = "xla")]
type XlaState = Option<XlaBackend>;
#[cfg(not(feature = "xla"))]
type XlaState = ();

#[cfg(feature = "xla")]
fn init_xla() -> XlaState {
    match XlaBackend::from_default_dir() {
        Ok(b) => Some(b),
        Err(e) => {
            println!("(xla backend unavailable: {e:#}; run `make artifacts`)");
            None
        }
    }
}

#[cfg(not(feature = "xla"))]
fn init_xla() -> XlaState {
    println!("(built without the `xla` feature; native only — rerun with --features xla)");
}

#[cfg(feature = "xla")]
fn xla_cost_mean(xla: &mut XlaState, x: &[f32], m: usize, d: usize, c: &[f32], k: usize) -> Option<f64> {
    xla.as_mut().map(|b| {
        let mut out = Vec::new();
        bench(2, 20, || b.batch_costs(x, m, d, c, k, &mut out)).mean
    })
}

#[cfg(not(feature = "xla"))]
fn xla_cost_mean(_: &mut XlaState, _: &[f32], _: usize, _: usize, _: &[f32], _: usize) -> Option<f64> {
    None
}

#[cfg(feature = "xla")]
fn xla_centroid_report(xla: &mut XlaState, x: &[f32], n: usize, d: usize, mu: &[f32], nat_mean: f64) {
    if let Some(b) = xla.as_mut() {
        let mut out = Vec::new();
        let xs = bench(2, 20, || b.centroid_distances(x, n, d, mu, &mut out));
        println!("  xla:    {:.1} µs ({:.2}x native)", xs.mean * 1e6, xs.mean / nat_mean);
        println!(
            "  xla telemetry: {} artifact calls, {} native fallbacks",
            b.xla_calls, b.native_fallbacks
        );
    }
}

#[cfg(not(feature = "xla"))]
fn xla_centroid_report(_: &mut XlaState, _: &[f32], _: usize, _: usize, _: &[f32], _: f64) {}

fn main() {
    println!("# bench_runtime — cost-matrix backends");
    let mut native = NativeBackend::default();
    let mut xla = init_xla();

    println!(
        "{:>16} {:>14} {:>14} {:>10}",
        "shape (m,k,d)", "native [µs]", "xla [µs]", "xla/nat"
    );
    for &(m, k, d) in &[
        (64usize, 64usize, 16usize),
        (128, 128, 32),
        (128, 128, 64),
        (256, 256, 64),
        (256, 256, 128),
        (100, 100, 20), // padded (exercises pad/crop)
    ] {
        let mut rng = Pcg32::new((m * k + d) as u64);
        let x: Vec<f32> = (0..m * d).map(|_| rng.f32()).collect();
        let c: Vec<f32> = (0..k * d).map(|_| rng.f32()).collect();
        let mut out = Vec::new();
        let nat = bench(2, 20, || native.batch_costs(&x, m, d, &c, k, &mut out));
        match xla_cost_mean(&mut xla, &x, m, d, &c, k) {
            Some(xm) => println!(
                "{:>16} {:>14.1} {:>14.1} {:>10.2}",
                format!("({m},{k},{d})"),
                nat.mean * 1e6,
                xm * 1e6,
                xm / nat.mean
            ),
            None => println!(
                "{:>16} {:>14.1} {:>14} {:>10}",
                format!("({m},{k},{d})"),
                nat.mean * 1e6,
                "—",
                "—"
            ),
        }
    }

    println!("\n# centroid-distance path (n=4096 chunked)");
    let (n, d) = (4_096usize, 64usize);
    let mut rng = Pcg32::new(9);
    let x: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
    let mu: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
    let mut out = Vec::new();
    let nat = bench(2, 20, || native.centroid_distances(&x, n, d, &mu, &mut out));
    println!("  native: {:.1} µs", nat.mean * 1e6);
    xla_centroid_report(&mut xla, &x, n, d, &mu, nat.mean);
}
