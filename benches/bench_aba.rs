//! End-to-end ABA benchmarks: runtime scaling in N, K, D; variant and
//! hierarchical-decomposition ablations; solver ablation.
//!
//! Regenerates the *performance* claims of the paper at reduced scale:
//! ABA is O(N(D + log N + K^2)) flat and O(N L K^(2/L)) decomposed
//! (§4.5); decomposition buys ~2 orders of magnitude at large K for
//! <0.1% objective loss (Figure 7's message).

use aba::algo::{run_aba, run_hierarchical, AbaConfig, ClusterStats, Variant};
use aba::assignment::SolverKind;
use aba::data::synth::{generate, SynthKind};
use aba::util::timer::timed;

fn mk(n: usize, d: usize, seed: u64) -> aba::data::Dataset {
    generate(SynthKind::GaussianMixture { components: 8, spread: 3.0 }, n, d, seed, "bench")
}

fn main() {
    println!("# bench_aba — end-to-end runtime scaling");
    println!("\n## N scaling (D=16, K=50, flat)");
    for &n in &[10_000usize, 20_000, 40_000, 80_000] {
        let ds = mk(n, 16, 1);
        let cfg = AbaConfig { auto_hier: false, ..AbaConfig::default() };
        let (labels, secs) = timed(|| run_aba(&ds, 50, &cfg).unwrap());
        let ofv = ClusterStats::compute(&ds, &labels, 50).ssd_total();
        println!("  n={n:>7}: {secs:>7.3}s  ofv={ofv:.1}");
    }

    println!("\n## K scaling (N=20000, D=16): flat vs auto-hierarchical");
    for &k in &[50usize, 100, 200, 400, 800] {
        let ds = mk(20_000, 16, 2);
        let flat_cfg = AbaConfig { auto_hier: false, ..AbaConfig::default() };
        let (flat_labels, flat_secs) = timed(|| run_aba(&ds, k, &flat_cfg).unwrap());
        let auto_cfg = AbaConfig::default();
        let (auto_labels, auto_secs) = timed(|| run_aba(&ds, k, &auto_cfg).unwrap());
        let fo = ClusterStats::compute(&ds, &flat_labels, k).ssd_total();
        let ao = ClusterStats::compute(&ds, &auto_labels, k).ssd_total();
        println!(
            "  k={k:>4}: flat {flat_secs:>7.3}s | auto {auto_secs:>7.3}s ({:>5.1}x) | ofv loss {:>7.4}%",
            flat_secs / auto_secs.max(1e-9),
            100.0 * (ao - fo) / fo
        );
    }

    println!("\n## variant ablation (small anticlusters, N=8192, K=2048, i.e. size 4)");
    {
        let ds = mk(8_192, 16, 3);
        for (name, variant) in [("base", Variant::Base), ("small", Variant::Small)] {
            let cfg = AbaConfig { variant, hier: Some(vec![32, 64]), ..AbaConfig::default() };
            let (labels, secs) = timed(|| run_aba(&ds, 2_048, &cfg).unwrap());
            let ofv = ClusterStats::compute(&ds, &labels, 2_048).ssd_total();
            println!("  {name:>6}: {secs:>7.3}s  ofv={ofv:.1}");
        }
    }

    println!("\n## solver ablation (N=10000, D=16, K=100, flat)");
    {
        let ds = mk(10_000, 16, 4);
        for (name, solver) in [
            ("lapjv", SolverKind::Lapjv),
            ("auction", SolverKind::Auction),
            ("greedy", SolverKind::Greedy),
        ] {
            let cfg = AbaConfig { solver, auto_hier: false, ..AbaConfig::default() };
            let (labels, secs) = timed(|| run_aba(&ds, 100, &cfg).unwrap());
            let ofv = ClusterStats::compute(&ds, &labels, 100).ssd_total();
            println!("  {name:>8}: {secs:>7.3}s  ofv={ofv:.1}");
        }
    }

    println!("\n## 3-level decomposition (N=65536, D=32, K=4096, size 16)");
    {
        let ds = mk(65_536, 32, 5);
        let cfg = AbaConfig { auto_hier: false, ..AbaConfig::default() };
        for spec in [vec![64, 64], vec![16, 16, 16], vec![4, 32, 32]] {
            let label = spec.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("x");
            let (labels, secs) = timed(|| run_hierarchical(&ds, &spec, &cfg).unwrap());
            let ofv = ClusterStats::compute(&ds, &labels, 4_096).ssd_total();
            println!("  {label:>10}: {secs:>7.3}s  ofv={ofv:.1}");
        }
    }
}
