//! End-to-end ABA benchmarks: runtime scaling in N, K, D; variant and
//! hierarchical-decomposition ablations; solver ablation; the
//! session-reuse amortization of the `Anticlusterer` API; and the
//! parallel runtime (serial vs threaded, with a bit-identity check).
//!
//! Regenerates the *performance* claims of the paper at reduced scale:
//! ABA is O(N(D + log N + K^2)) flat and O(N L K^(2/L)) decomposed
//! (§4.5); decomposition buys ~2 orders of magnitude at large K for
//! <0.1% objective loss (Figure 7's message). The session-reuse section
//! quantifies what a reused `Aba` session saves over cold per-call
//! construction (scratch/backend/pool reuse — the serving / pipeline /
//! repeated-partitioning hot path).
//!
//! Besides the human-readable report, every measurement is appended to
//! `BENCH_aba.json` (section, label, n, k, d, threads, algorithm
//! seconds, wall seconds, objective, gathered bytes, cost-buffer bytes)
//! so the perf trajectory is tracked across PRs by machines, not
//! eyeballs. The `deep_hier_bytes` section runs a 3-level decomposition
//! with the zero-copy view path and records the bytes actually gathered
//! next to what the old per-level `Dataset::subset` copy would have
//! cost. The `large_k_sparse` section runs the candidate-pruned
//! assignment path at a scale whose dense `k x k` cost buffer would
//! exceed 256 MiB, next to a one-batch dense LAPJV reference at the
//! same `k` (a *full* dense run at this scale is `O(k^3)` per batch x
//! 20 batches — not worth anyone's wall clock). The `online_churn`
//! section drives a live `OnlinePartition` through remove+insert+refine
//! rounds and records sustained updates/sec, the refine cost, and the
//! delta-maintained vs from-scratch objective gap. The
//! `serve_throughput` section stands up an in-process `serve::Server`
//! with fewer resident-handle slots than partitions and records req/s,
//! p50/p99 request latency, and forced eviction count. The `certify`
//! section measures the quality-certificate machinery at n = 200k:
//! the `gap_pct` row stores the solve's certified optimality gap *in
//! percent* in the `objective` column, and the `cert_serial` /
//! `cert_threads` rows store the standalone certification wall time in
//! `algo_secs`/`total_secs` (their `objective` column carries the
//! certificate's upper bound).
//!
//! The `pareto` section runs the bicriterion multi-restart engine and
//! records front size, hypervolume vs the single-ABA solution's
//! (diversity, dispersion) reference point, and restarts/sec serial vs
//! pooled — with a serial-vs-pooled front bit-identity assert.
//!
//! The `kernel` section microbenchmarks the runtime-dispatched SIMD
//! distance kernels themselves: `cost_block`, the cache-blocked
//! `cost_panel`, and `row_norms` GFLOP/s at d ∈ {8, 32, 128} for each
//! table the host can select (scalar always; the vector and FMA tables
//! where the ISA exists; the relaxed-determinism fast-math table where
//! it beats scalar), so the vector-vs-scalar speedup is a recorded
//! number rather than an assumption. The `kernel_e2e` section runs the
//! same two instances end to end under `--kernels scalar`, the Auto
//! dispatch, and `--kernels fast-math` — the flat n = 200k dense solve
//! and a large-K sparse solve. Scalar vs Auto asserts label
//! bit-identity; the fast-math arm is *never* identity-gated (its
//! contract is relaxed) — instead its objective gap vs scalar is
//! recorded in ppm in the `{label}_fastmath_gap_ppm` row's `objective`
//! column, which is what CI and cross-PR diffs gate on. Every run also
//! opens with one `env` record carrying `kernel_isa=<isa>` plus the
//! capture host's CPU model so cross-host comparisons of BENCH_aba.json
//! know what the numbers ran on.
//!
//! Set `ABA_BENCH_ONLY=section[,section...]` to run a subset of the
//! sections (e.g. `ABA_BENCH_ONLY=large_k_sparse`). Filtered runs
//! write `BENCH_aba.partial.json` so they never truncate the canonical
//! cross-PR record in `BENCH_aba.json` (which only full runs rewrite).

use aba::algo::{AbaConfig, Variant};
use aba::assignment::{CandidateMode, SolverKind};
use aba::data::synth::{generate, SynthKind};
use aba::runtime::{KernelMode, Kernels, Parallelism};
use aba::util::timer::timed;
use aba::{Aba, Anticlusterer, Partition};

/// Whether a section filter is active (`ABA_BENCH_ONLY=a,b`).
fn section_filter() -> Option<String> {
    match std::env::var("ABA_BENCH_ONLY") {
        Ok(v) if !v.trim().is_empty() => Some(v),
        _ => None,
    }
}

/// Section filter: `ABA_BENCH_ONLY=a,b` runs only those sections.
fn section_enabled(name: &str) -> bool {
    match section_filter() {
        Some(v) => v.split(',').any(|s| s.trim() == name),
        None => true,
    }
}

fn mk(n: usize, d: usize, seed: u64) -> aba::data::Dataset {
    generate(SynthKind::GaussianMixture { components: 8, spread: 3.0 }, n, d, seed, "bench")
}

/// Capture-host CPU model for the `env` record (so BENCH_aba.json rows
/// are attributable to hardware). Best-effort: /proc/cpuinfo on Linux,
/// "unknown" elsewhere — never a reason to fail a bench run.
fn host_model() -> String {
    std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("model name"))
                .and_then(|l| l.split(':').nth(1))
                .map(|m| m.trim().replace('"', ""))
        })
        .unwrap_or_else(|| "unknown".to_string())
}

/// One machine-readable measurement for `BENCH_aba.json`.
struct Rec {
    section: &'static str,
    label: String,
    n: usize,
    k: usize,
    d: usize,
    threads: usize,
    /// Ordering + assignment only (the paper's runtime convention).
    algo_secs: f64,
    /// Wall clock including session construction and the stats pass.
    total_secs: f64,
    objective: f64,
    /// Feature bytes actually gathered (copied) during the run, from the
    /// `data::view` meter. 0 where the section does not measure it.
    gathered_bytes: u64,
    /// Peak bytes of the per-batch cost structure (dense `m*k` f32s or
    /// the sparse CSR). 0 where the section does not measure it.
    cost_buffer_bytes: u64,
}

fn record(
    recs: &mut Vec<Rec>,
    section: &'static str,
    label: impl Into<String>,
    ds: &aba::data::Dataset,
    k: usize,
    threads: usize,
    part: &Partition,
    wall_secs: f64,
) {
    recs.push(Rec {
        section,
        label: label.into(),
        n: ds.n,
        k,
        d: ds.d,
        threads,
        algo_secs: part.timings.algo_secs(),
        total_secs: wall_secs,
        objective: part.objective,
        gathered_bytes: 0,
        cost_buffer_bytes: 0,
    });
}

fn write_json(path: &str, recs: &[Rec]) {
    let mut s = String::from("[\n");
    for (i, r) in recs.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"section\": \"{}\", \"label\": \"{}\", \"n\": {}, \"k\": {}, \"d\": {}, \
             \"threads\": {}, \"algo_secs\": {:.6}, \"total_secs\": {:.6}, \
             \"objective\": {:.3}, \"gathered_bytes\": {}, \"cost_buffer_bytes\": {}}}{}\n",
            r.section,
            r.label,
            r.n,
            r.k,
            r.d,
            r.threads,
            r.algo_secs,
            r.total_secs,
            r.objective,
            r.gathered_bytes,
            r.cost_buffer_bytes,
            if i + 1 < recs.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    match std::fs::write(path, &s) {
        Ok(()) => println!("\nwrote {} records to {path}", recs.len()),
        Err(e) => eprintln!("\nWARN: could not write {path}: {e}"),
    }
}

/// One cold call: build a fresh session (as the deprecated free
/// functions did on every invocation), partition once, drop it. Returns
/// the partition and the wall time including construction.
fn cold_partition(ds: &aba::data::Dataset, k: usize, cfg: &AbaConfig) -> (Partition, f64) {
    timed(|| {
        Aba::from_config(cfg.clone())
            .unwrap()
            .partition(ds, k)
            .unwrap()
    })
}

/// Measure one kernel call repeated until the sample is long enough to
/// time, returning (seconds per call). `flops_per_call` sizes the rep
/// count so every measurement spends roughly the same work.
fn time_kernel(flops_per_call: f64, mut call: impl FnMut()) -> f64 {
    let reps = ((2.0e8 / flops_per_call) as usize).max(1);
    call(); // warm-up: page in the buffers, settle the dispatch
    let t = std::time::Instant::now();
    for _ in 0..reps {
        call();
    }
    t.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let mut recs: Vec<Rec> = Vec::new();
    let host_isa = Kernels::get().isa();
    // The env record: one row describing what the whole run dispatched
    // to, so cross-host BENCH_aba.json diffs are interpretable.
    recs.push(Rec {
        section: "env",
        label: format!("kernel_isa={host_isa}; host={}", host_model()),
        n: 0,
        k: 0,
        d: 0,
        threads: Parallelism::Auto.effective_threads(),
        algo_secs: 0.0,
        total_secs: 0.0,
        objective: 0.0,
        gathered_bytes: 0,
        cost_buffer_bytes: 0,
    });
    println!("# bench_aba — end-to-end runtime scaling (kernels: {host_isa})");

    if section_enabled("kernel") {
        // The SIMD microkernels in isolation: GFLOP/s of the tiled
        // cost_block (2mkd flops) and row_norms (2md flops) per
        // selectable table, against the scalar baseline. CI runs this
        // section alone (`ABA_BENCH_ONLY=kernel`) — keep it seconds.
        let (m, kc) = (1024usize, 256usize);
        println!("\n## kernel microbench (m={m} rows x k={kc} centers, GFLOP/s)");
        let mut rng = aba::rng::Pcg32::new(99);
        for &d in &[8usize, 32, 128] {
            let x: Vec<f32> = (0..m * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let c: Vec<f32> = (0..kc * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut tables = vec![Kernels::select(KernelMode::Scalar)];
            let auto = Kernels::select(KernelMode::Auto);
            if auto.isa() != "scalar" {
                tables.push(auto);
            }
            let fma = Kernels::select(KernelMode::Fma);
            if fma.isa().contains("fma") {
                tables.push(fma);
            }
            let mut scalar_cost_gflops = 0.0;
            for kern in tables {
                let mut xn = Vec::new();
                let mut cn = Vec::new();
                kern.row_norms(&c, kc, d, &mut cn);
                let norm_flops = (2 * m * d) as f64;
                let norm_secs = time_kernel(norm_flops, || {
                    kern.row_norms(&x, m, d, &mut xn);
                    std::hint::black_box(&mut xn);
                });
                let mut out = vec![0f32; m * kc];
                let cost_flops = (2 * m * kc * d) as f64;
                let cost_secs = time_kernel(cost_flops, || {
                    kern.cost_block(&x, &xn, 0, m, d, &c, &cn, kc, &mut out);
                    std::hint::black_box(&mut out);
                });
                // The cache-blocked panel kernel over the same tile: in
                // the deterministic tiers it is the same per-entry math
                // (only the streaming order differs), so the delta here
                // is pure blocking; in fast-math it is register-blocked.
                let panel_secs = time_kernel(cost_flops, || {
                    kern.cost_panel(&x, &xn, 0, m, d, &c, &cn, kc, &mut out);
                    std::hint::black_box(&mut out);
                });
                let cost_gflops = cost_flops / cost_secs / 1e9;
                let panel_gflops = cost_flops / panel_secs / 1e9;
                let norm_gflops = norm_flops / norm_secs / 1e9;
                let speedup = if kern.isa() == "scalar" {
                    scalar_cost_gflops = cost_gflops;
                    String::new()
                } else {
                    format!("  ({:.2}x scalar)", cost_gflops / scalar_cost_gflops.max(1e-9))
                };
                println!(
                    "  d={d:>3} {:>8}: cost_block {cost_gflops:>6.2} | cost_panel {panel_gflops:>6.2} | row_norms {norm_gflops:>6.2}{speedup}",
                    kern.isa()
                );
                let mut push = |op: &str, secs: f64, gflops: f64| {
                    recs.push(Rec {
                        section: "kernel",
                        label: format!("{op}_d{d}_{}", kern.isa()),
                        n: m,
                        k: kc,
                        d,
                        threads: 1,
                        algo_secs: secs,
                        total_secs: secs,
                        // GFLOP/s in the objective column — the one
                        // free numeric slot in the record shape.
                        objective: gflops,
                        gathered_bytes: 0,
                        cost_buffer_bytes: 0,
                    });
                };
                push("cost_block", cost_secs, cost_gflops);
                push("cost_panel", panel_secs, panel_gflops);
                push("row_norms", norm_secs, norm_gflops);
            }
            // The relaxed-determinism fast-math table, where it exists
            // (AVX-512F, else AVX2+FMA; on scalar-only hosts the tier
            // degrades to the rows already recorded above). Labelled
            // `fastmath_<isa>` because its AVX2 fallback shares the
            // hardware ISA string with the deterministic FMA table.
            let fast = Kernels::select(KernelMode::FastMath);
            if fast.isa() != "scalar" {
                let mut xn = Vec::new();
                let mut cn = Vec::new();
                fast.row_norms(&x, m, d, &mut xn);
                fast.row_norms(&c, kc, d, &mut cn);
                let mut out = vec![0f32; m * kc];
                let cost_flops = (2 * m * kc * d) as f64;
                let fast_secs = time_kernel(cost_flops, || {
                    fast.cost_panel(&x, &xn, 0, m, d, &c, &cn, kc, &mut out);
                    std::hint::black_box(&mut out);
                });
                let fast_gflops = cost_flops / fast_secs / 1e9;
                println!(
                    "  d={d:>3} fast-math({}): cost_panel {fast_gflops:>6.2}  ({:.2}x scalar)",
                    fast.isa(),
                    fast_gflops / scalar_cost_gflops.max(1e-9)
                );
                recs.push(Rec {
                    section: "kernel",
                    label: format!("cost_panel_d{d}_fastmath_{}", fast.isa()),
                    n: m,
                    k: kc,
                    d,
                    threads: 1,
                    algo_secs: fast_secs,
                    total_secs: fast_secs,
                    objective: fast_gflops,
                    gathered_bytes: 0,
                    cost_buffer_bytes: 0,
                });
            }
        }
    }
    // The flat baseline stays on the dense (exact) solve even where K
    // crosses the sparse Auto threshold — these sections measure the
    // dense machinery; `large_k_sparse` below measures the sparse path.
    let flat = AbaConfig {
        auto_hier: false,
        candidates: CandidateMode::Dense,
        ..AbaConfig::default()
    };
    if section_enabled("n_scaling") {
        println!("\n## N scaling (D=16, K=50, flat)");
        for &n in &[10_000usize, 20_000, 40_000, 80_000] {
            let ds = mk(n, 16, 1);
            let (part, secs) = cold_partition(&ds, 50, &flat);
            println!("  n={n:>7}: {secs:>7.3}s  ofv={:.1}", part.objective);
            record(&mut recs, "n_scaling", format!("n{n}"), &ds, 50, 1, &part, secs);
        }
    }

    if section_enabled("k_scaling") {
        println!("\n## K scaling (N=20000, D=16): flat vs auto-hierarchical");
        for &k in &[50usize, 100, 200, 400, 800] {
            let ds = mk(20_000, 16, 2);
            let (fp, flat_secs) = cold_partition(&ds, k, &flat);
            let (ap, auto_secs) = cold_partition(&ds, k, &AbaConfig::default());
            println!(
                "  k={k:>4}: flat {flat_secs:>7.3}s | auto {auto_secs:>7.3}s ({:>5.1}x) | ofv loss {:>7.4}%",
                flat_secs / auto_secs.max(1e-9),
                100.0 * (ap.objective - fp.objective) / fp.objective
            );
            record(&mut recs, "k_scaling_flat", format!("k{k}"), &ds, k, 1, &fp, flat_secs);
            record(&mut recs, "k_scaling_auto", format!("k{k}"), &ds, k, 1, &ap, auto_secs);
        }
    }

    if section_enabled("session_reuse") {
        println!("\n## session reuse (N=40000, D=16, K=50): cold per-call vs one warm session");
        let ds = mk(40_000, 16, 6);
        // Two cold calls, each paying session construction + scratch
        // warm-up (the behaviour of the deprecated one-shot functions).
        let (c1, cold1) = cold_partition(&ds, 50, &flat);
        let (c2, cold2) = cold_partition(&ds, 50, &flat);
        // One session, two calls: the second reuses the backend and the
        // assignment loop's scratch buffers.
        let mut session = Aba::from_config(flat.clone()).unwrap();
        let (w1, warm1) = timed(|| session.partition(&ds, 50).unwrap());
        let (w2, warm2) = timed(|| session.partition(&ds, 50).unwrap());
        let cold_mean = 0.5 * (cold1 + cold2);
        println!("  cold calls:   {cold1:>7.3}s, {cold2:>7.3}s (mean {cold_mean:.3}s)");
        println!(
            "  warm session: {warm1:>7.3}s, {warm2:>7.3}s (2nd call {:+.1}% vs cold mean)",
            100.0 * (warm2 - cold_mean) / cold_mean
        );
        if warm2 > cold_mean {
            // Scratch/backend reuse should never lose; flag it but keep
            // reporting (wall-clock noise on a loaded box is possible).
            println!("  WARN: warm call slower than cold mean — rerun on an idle machine");
        }
        record(&mut recs, "session_reuse", "cold1", &ds, 50, 1, &c1, cold1);
        record(&mut recs, "session_reuse", "cold2", &ds, 50, 1, &c2, cold2);
        record(&mut recs, "session_reuse", "warm1", &ds, 50, 1, &w1, warm1);
        record(&mut recs, "session_reuse", "warm2", &ds, 50, 1, &w2, warm2);
    }

    let auto_threads = Parallelism::Auto.effective_threads();
    if section_enabled("parallel_flat") {
        println!("\n## parallel cost path (N=20000, D=16, K=2000 flat): serial vs {auto_threads} threads");
        let ds = mk(20_000, 16, 7);
        let run = |par: Parallelism| {
            let cfg = AbaConfig {
                auto_hier: false,
                parallelism: par,
                // This section measures the chunk-parallel *dense* cost
                // kernel, so keep candidate pruning off.
                candidates: CandidateMode::Dense,
                ..AbaConfig::default()
            };
            cold_partition(&ds, 2_000, &cfg)
        };
        let (sp, serial_secs) = run(Parallelism::Serial);
        let (tp, par_secs) = run(Parallelism::Threads(auto_threads));
        assert_eq!(sp.labels, tp.labels, "parallel flat run must be bit-identical");
        println!(
            "  serial {serial_secs:>7.3}s | threads({auto_threads}) {par_secs:>7.3}s ({:>5.2}x) | labels bit-identical: yes",
            serial_secs / par_secs.max(1e-9)
        );
        record(&mut recs, "parallel_flat", "serial", &ds, 2_000, 1, &sp, serial_secs);
        record(&mut recs, "parallel_flat", "threads", &ds, 2_000, auto_threads, &tp, par_secs);
    }

    if section_enabled("parallel_hier") {
        println!("\n## parallel fan-out (N=65536, D=16, K=4096 via 64x64): serial vs {auto_threads} threads");
        let ds = mk(65_536, 16, 8);
        let run = |par: Parallelism| {
            let cfg = AbaConfig {
                auto_hier: false,
                hier: Some(vec![64, 64]),
                parallelism: par,
                ..AbaConfig::default()
            };
            cold_partition(&ds, 4_096, &cfg)
        };
        let (sp, serial_secs) = run(Parallelism::Serial);
        let (tp, par_secs) = run(Parallelism::Threads(auto_threads));
        assert_eq!(sp.labels, tp.labels, "parallel hierarchical run must be bit-identical");
        println!(
            "  serial {serial_secs:>7.3}s | threads({auto_threads}) {par_secs:>7.3}s ({:>5.2}x) | labels bit-identical: yes",
            serial_secs / par_secs.max(1e-9)
        );
        record(&mut recs, "parallel_hier", "serial", &ds, 4_096, 1, &sp, serial_secs);
        record(&mut recs, "parallel_hier", "threads", &ds, 4_096, auto_threads, &tp, par_secs);
    }

    if section_enabled("variant") {
        println!("\n## variant ablation (small anticlusters, N=8192, K=2048, i.e. size 4)");
        let ds = mk(8_192, 16, 3);
        for (name, variant) in [("base", Variant::Base), ("small", Variant::Small)] {
            let cfg = AbaConfig { variant, hier: Some(vec![32, 64]), ..AbaConfig::default() };
            let (part, secs) = cold_partition(&ds, 2_048, &cfg);
            println!("  {name:>6}: {secs:>7.3}s  ofv={:.1}", part.objective);
            record(&mut recs, "variant", name, &ds, 2_048, 1, &part, secs);
        }
    }

    if section_enabled("solver") {
        println!("\n## solver ablation (N=10000, D=16, K=100, flat)");
        let ds = mk(10_000, 16, 4);
        for (name, solver) in [
            ("lapjv", SolverKind::Lapjv),
            ("auction", SolverKind::Auction),
            ("greedy", SolverKind::Greedy),
        ] {
            let cfg = AbaConfig { solver, auto_hier: false, ..AbaConfig::default() };
            let (part, secs) = cold_partition(&ds, 100, &cfg);
            println!("  {name:>8}: {secs:>7.3}s  ofv={:.1}", part.objective);
            record(&mut recs, "solver", name, &ds, 100, 1, &part, secs);
        }
    }

    if section_enabled("decomposition") {
        println!("\n## 3-level decomposition (N=65536, D=32, K=4096, size 16)");
        let ds = mk(65_536, 32, 5);
        for spec in [vec![64, 64], vec![16, 16, 16], vec![4, 32, 32]] {
            let label = spec.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("x");
            let cfg = AbaConfig { auto_hier: false, hier: Some(spec), ..AbaConfig::default() };
            let (part, secs) = cold_partition(&ds, 4_096, &cfg);
            println!("  {label:>10}: {secs:>7.3}s  ofv={:.1}", part.objective);
            record(&mut recs, "decomposition", label, &ds, 4_096, 1, &part, secs);
        }
    }

    if section_enabled("deep_hier_bytes") {
        println!("\n## deep hierarchy, zero-copy views (N=100000, D=16, K=5000 via 25x20x10)");
        // Levels descend as index views: the only feature copies are the
        // bounded per-batch stagings, metered by data::view. The old
        // per-level `Dataset::subset` path would have gathered the full
        // n x d matrix once per level on top of that staging — reported
        // side by side so BENCH_aba.json carries the delta.
        let ds = mk(100_000, 16, 9);
        let spec = vec![25usize, 20, 10];
        let levels = spec.len() as u64;
        let cfg = AbaConfig { auto_hier: false, hier: Some(spec), ..AbaConfig::default() };
        aba::data::view::reset_gathered_bytes();
        let (part, secs) = cold_partition(&ds, 5_000, &cfg);
        let gathered = aba::data::view::gathered_bytes();
        let per_level_copy = (ds.n * ds.d * std::mem::size_of::<f32>()) as u64 * levels;
        let mib = |b: u64| b as f64 / (1 << 20) as f64;
        println!(
            "  25x20x10: {secs:>7.3}s  ofv={:.1}  staged {:.1} MiB \
             (per-level copy path would add {:.1} MiB; delta {:.1} MiB)",
            part.objective,
            mib(gathered),
            mib(per_level_copy),
            mib(per_level_copy)
        );
        let mut deep = |label: &str, bytes: u64| {
            record(&mut recs, "deep_hier_bytes", label, &ds, 5_000, 1, &part, secs);
            recs.last_mut().unwrap().gathered_bytes = bytes;
        };
        deep("view_path", gathered);
        deep("per_level_copy_equivalent", gathered + per_level_copy);
    }

    if section_enabled("large_k_sparse") {
        // The headline large-K claim: an instance whose dense k x k cost
        // buffer (10_000^2 f32 = 400 MiB > 256 MiB) the dense path cannot
        // reasonably serve. The sparse path runs the full instance; the
        // dense reference solves exactly ONE batch at the same k (an
        // n = 2k dense run seeds batch 1 and dense-solves batch 2), since
        // a full dense run is O(k^3) per batch x 20 batches. Expect the
        // dense reference to take minutes — that asymmetry is the point.
        let (n, k, d) = (200_000usize, 10_000usize, 16usize);
        println!("\n## large-K sparse candidate path (N={n}, D={d}, K={k} flat)");
        let ds = mk(n, d, 10);
        let sparse_cfg = AbaConfig {
            auto_hier: false,
            candidates: CandidateMode::Auto, // k >= 512 -> C = 32
            ..AbaConfig::default()
        };
        let mut session = Aba::from_config(sparse_cfg).unwrap();
        let (sp, sparse_secs) = timed(|| session.partition(&ds, k).unwrap());
        let stats = session.sparse_stats();
        let solved_batches = (stats.sparse_batches + stats.dense_batches).max(1);
        let sparse_per_batch = sp.timings.assign_secs / solved_batches as f64;
        let dense_bytes = (k * k * 4) as u64;
        let mib = |b: u64| b as f64 / (1 << 20) as f64;
        println!(
            "  sparse (C=32): {sparse_secs:>8.3}s total, {sparse_per_batch:>7.3}s/batch \
             over {solved_batches} batches, ofv={:.1}",
            sp.objective
        );
        println!(
            "  cost buffers:  sparse peak {:.1} MiB vs dense k x k {:.1} MiB \
             ({} sparse / {} dense batches, {} escalations, {} fallbacks)",
            mib(stats.peak_cost_bytes as u64),
            mib(dense_bytes),
            stats.sparse_batches,
            stats.dense_batches,
            stats.escalations,
            stats.fallback_batches
        );

        println!("  dense LAPJV reference (one k x k batch; this takes a while)...");
        let dense_ds = mk(2 * k, d, 10);
        let dense_cfg = AbaConfig {
            auto_hier: false,
            candidates: CandidateMode::Dense,
            ..AbaConfig::default()
        };
        let (dp, _dense_secs) = cold_partition(&dense_ds, k, &dense_cfg);
        let dense_per_batch = dp.timings.assign_secs; // exactly one solved batch
        println!(
            "  dense: {dense_per_batch:>8.3}s/batch at k={k} -> sparse is {:.1}x faster per batch",
            dense_per_batch / sparse_per_batch.max(1e-9)
        );

        record(&mut recs, "large_k_sparse", "sparse_full", &ds, k, 1, &sp, sparse_secs);
        recs.last_mut().unwrap().cost_buffer_bytes = stats.peak_cost_bytes as u64;
        record(&mut recs, "large_k_sparse", "sparse_per_batch", &ds, k, 1, &sp, sparse_secs);
        {
            let r = recs.last_mut().unwrap();
            r.algo_secs = sparse_per_batch;
            r.total_secs = sparse_per_batch;
            r.cost_buffer_bytes = stats.peak_cost_bytes as u64;
        }
        record(
            &mut recs,
            "large_k_sparse",
            "dense_per_batch",
            &dense_ds,
            k,
            1,
            &dp,
            dense_per_batch,
        );
        {
            let r = recs.last_mut().unwrap();
            r.algo_secs = dense_per_batch;
            r.total_secs = dense_per_batch;
            r.cost_buffer_bytes = dense_bytes;
        }
    }

    if section_enabled("kernel_e2e") {
        // What the SIMD dispatch buys end to end: the flat dense solve
        // at n = 200k and a large-K sparse solve, each run under the
        // forced scalar fallback ("before"), the Auto selection
        // ("after"), and the relaxed-determinism fast-math tier. Auto
        // preserves scalar reduction order, so its labels must not move
        // a bit while the wall clock does. Fast-math's labels MAY move
        // (that is its contract) — so it is never identity-asserted;
        // instead its objective gap vs scalar is recorded in ppm in the
        // `{label}_fastmath_gap_ppm` row, the number the contract gates.
        println!("\n## kernel end-to-end: scalar fallback vs auto vs fast-math ({host_isa})");
        let mut compare = |recs: &mut Vec<Rec>,
                           label: &str,
                           ds: &aba::data::Dataset,
                           k: usize,
                           cfg: &AbaConfig| {
            let scalar_cfg = AbaConfig { kernels: Some(KernelMode::Scalar), ..cfg.clone() };
            let auto_cfg = AbaConfig { kernels: Some(KernelMode::Auto), ..cfg.clone() };
            let fast_cfg = AbaConfig { kernels: Some(KernelMode::FastMath), ..cfg.clone() };
            let (sp, scalar_secs) = cold_partition(ds, k, &scalar_cfg);
            let (ap, auto_secs) = cold_partition(ds, k, &auto_cfg);
            let (fp, fast_secs) = cold_partition(ds, k, &fast_cfg);
            assert_eq!(sp.labels, ap.labels, "{label}: kernel modes diverged");
            let gap_ppm =
                1e6 * (fp.objective - sp.objective).abs() / sp.objective.abs().max(1e-9);
            println!(
                "  {label:>14}: scalar {scalar_secs:>8.3}s | {host_isa} {auto_secs:>8.3}s \
                 ({:.2}x) | labels bit-identical: yes",
                scalar_secs / auto_secs.max(1e-9)
            );
            println!(
                "  {label:>14}: fast-math ({}) {fast_secs:>8.3}s ({:.2}x scalar, \
                 {:.2}x auto) | objective gap {gap_ppm:.2} ppm",
                fp.timings.kernel_isa,
                scalar_secs / fast_secs.max(1e-9),
                auto_secs / fast_secs.max(1e-9)
            );
            record(recs, "kernel_e2e", format!("{label}_scalar"), ds, k, 1, &sp, scalar_secs);
            record(recs, "kernel_e2e", format!("{label}_auto"), ds, k, 1, &ap, auto_secs);
            record(recs, "kernel_e2e", format!("{label}_fastmath"), ds, k, 1, &fp, fast_secs);
            record(
                recs,
                "kernel_e2e",
                format!("{label}_fastmath_gap_ppm"),
                ds,
                k,
                1,
                &fp,
                fast_secs,
            );
            recs.last_mut().unwrap().objective = gap_ppm;
        };
        let flat_ds = mk(200_000, 16, 14);
        compare(&mut recs, "flat_n200k", &flat_ds, 100, &flat);
        let sparse_ds = mk(100_000, 16, 15);
        let sparse_cfg = AbaConfig {
            auto_hier: false,
            candidates: CandidateMode::Auto, // k >= 512 -> sparse path
            ..AbaConfig::default()
        };
        compare(&mut recs, "sparse_k2000", &sparse_ds, 2_000, &sparse_cfg);
    }

    if section_enabled("online_churn") {
        // The serving path: one live OnlinePartition under churn vs
        // re-solving from scratch. Reported: sustained row updates/sec
        // (insert+remove with repair), the refine cost, and the
        // delta-vs-scratch objective gap after all rounds.
        let (n, k, d, rounds, churn) = (20_000usize, 100usize, 16usize, 20usize, 250usize);
        println!("\n## online churn (N={n}, D={d}, K={k}): {rounds} rounds of +{churn}/-{churn}");
        let ds = mk(n, d, 11);
        let arrivals = mk(4 * churn, d, 12);
        let mut session = Aba::from_config(flat.clone()).unwrap();
        let (mut live, init_secs) = timed(|| session.partition_online(&ds.view(), k).unwrap());
        let mut oldest: std::collections::VecDeque<u64> = (0..n as u64).collect();
        let mut next = 0usize;
        let mut refine_secs = 0f64;
        let mut refine_swaps = 0usize;
        let t = std::time::Instant::now();
        for _ in 0..rounds {
            let idx: Vec<usize> = (0..churn).map(|j| (next + j) % arrivals.n).collect();
            next += churn;
            let ids = live.insert_batch(&arrivals.view().select(&idx)).unwrap();
            let expire: Vec<u64> = oldest.drain(..churn).collect();
            live.remove(&expire).unwrap();
            oldest.extend(ids);
            let tr = std::time::Instant::now();
            refine_swaps += live.refine(50_000).swapped;
            refine_secs += tr.elapsed().as_secs_f64();
        }
        let total_secs = t.elapsed().as_secs_f64();
        let churn_secs = total_secs - refine_secs;
        let updates = 2 * rounds * churn;
        let delta_obj = live.objective();
        let current = live.to_dataset("current").unwrap();
        let (fresh, scratch_secs) =
            timed(|| Aba::from_config(flat.clone()).unwrap().partition(&current, k).unwrap());
        let gap_pct = 100.0 * (delta_obj - fresh.objective) / fresh.objective;
        println!(
            "  init {init_secs:>7.3}s | {updates} updates in {churn_secs:.3}s \
             ({:.0} updates/s) | refine {refine_secs:.3}s ({refine_swaps} swaps)",
            updates as f64 / churn_secs.max(1e-9)
        );
        println!(
            "  delta-maintained ofv {delta_obj:.1} vs from-scratch {:.1} ({gap_pct:+.4}%; \
             re-solve costs {scratch_secs:.3}s per refresh)",
            fresh.objective
        );
        let mut push = |label: &str, algo_secs: f64, total: f64, objective: f64| {
            recs.push(Rec {
                section: "online_churn",
                label: label.into(),
                n,
                k,
                d,
                threads: 1,
                algo_secs,
                total_secs: total,
                objective,
                gathered_bytes: 0,
                cost_buffer_bytes: 0,
            });
        };
        push("churn_updates", churn_secs, total_secs, delta_obj);
        push("refine", refine_secs, refine_secs, delta_obj);
        push("scratch_resolve", fresh.timings.algo_secs(), scratch_secs, fresh.objective);
    }

    if section_enabled("certify") {
        // Quality certificates at production scale: how tight the TSS
        // upper bound is on a real solve, and what a standalone
        // certification pass costs serial vs pooled (the pass is one
        // O(nd) sweep, so it should be noise next to the solve).
        let (n, k, d) = (200_000usize, 100usize, 16usize);
        println!("\n## quality certificates (N={n}, D={d}, K={k} flat)");
        let ds = mk(n, d, 13);
        let cert_cfg = AbaConfig { certify: true, ..flat.clone() };
        let mut session = Aba::from_config(cert_cfg).unwrap();
        let (part, solve_secs) = timed(|| session.partition(&ds, k).unwrap());
        let attached = session.last_certificate().expect("certify knob was on").clone();
        let gap_pct = 100.0 * part.gap();
        println!(
            "  solve {solve_secs:>7.3}s  ofv={:.1}  bound={:.1}  certified gap {gap_pct:.4}%",
            part.objective,
            part.upper_bound()
        );
        let (cert_serial, serial_secs) =
            timed(|| aba::cert::bounds::certify(&ds.view(), k).unwrap());
        let pool = aba::runtime::WorkerPool::new(auto_threads);
        let (cert_par, par_secs) = timed(|| {
            aba::cert::bounds::certify_with_pool(&ds.view(), k, Some(&pool)).unwrap()
        });
        assert_eq!(
            cert_serial.upper_bound.to_bits(),
            cert_par.upper_bound.to_bits(),
            "pooled certification must be bit-identical"
        );
        println!(
            "  certification: serial {serial_secs:>7.3}s | threads({auto_threads}) \
             {par_secs:>7.3}s ({:>5.2}x) | bit-identical: yes | attached-cert pass {:.3}s",
            serial_secs / par_secs.max(1e-9),
            attached.secs
        );
        record(&mut recs, "certify", "solve_with_cert", &ds, k, 1, &part, solve_secs);
        record(&mut recs, "certify", "gap_pct", &ds, k, 1, &part, solve_secs);
        {
            let r = recs.last_mut().unwrap();
            r.objective = gap_pct;
            r.algo_secs = attached.secs;
            r.total_secs = attached.secs;
        }
        record(&mut recs, "certify", "cert_serial", &ds, k, 1, &part, serial_secs);
        {
            let r = recs.last_mut().unwrap();
            r.objective = cert_serial.upper_bound;
            r.algo_secs = serial_secs;
        }
        record(&mut recs, "certify", "cert_threads", &ds, k, auto_threads, &part, par_secs);
        {
            let r = recs.last_mut().unwrap();
            r.objective = cert_par.upper_bound;
            r.algo_secs = par_secs;
        }
    }

    if section_enabled("serve_throughput") {
        // The HTTP serving path end to end: an in-process `serve::Server`
        // with more partitions than resident-handle slots, hammered with
        // round-robin reads so requests constantly re-load evicted
        // handles from snapshots — the steady-state cost of serving many
        // partitions from bounded memory. Reported: sustained req/s,
        // p50/p99 request latency from the server's own ring, and how
        // many evictions the run forced.
        let (parts, n, k, d, reads) = (8usize, 2_000usize, 10usize, 8usize, 200usize);
        println!(
            "\n## serve throughput ({parts} partitions of N={n}, K={k}, D={d}; \
             4 resident handles; {reads} round-robin reads)"
        );
        let dir = std::env::temp_dir().join(format!("aba_bench_serve_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let server = aba::serve::Server::start(aba::serve::ServeConfig {
            workers: 4,
            queue: 256,
            max_handles: 4,
            snapshot_dir: dir.clone(),
            cfg: flat.clone(),
            ..aba::serve::ServeConfig::default()
        })
        .unwrap();
        let addr = server.addr();
        let get = |path: &str, body: &str| -> u16 {
            use std::io::{Read, Write};
            let mut s = std::net::TcpStream::connect(addr).unwrap();
            let method = if body.is_empty() { "GET" } else { "POST" };
            s.write_all(
                format!(
                    "{method} {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
                    body.len()
                )
                .as_bytes(),
            )
            .unwrap();
            let mut text = String::new();
            s.read_to_string(&mut text).unwrap();
            text.split_whitespace().nth(1).unwrap().parse().unwrap()
        };
        for p in 0..parts {
            let ds = mk(n, d, 20 + p as u64);
            let mut csv: String =
                (0..d).map(|j| format!("f{j}")).collect::<Vec<_>>().join(",");
            csv.push('\n');
            for i in 0..n {
                let cells: Vec<String> = ds.row(i).iter().map(|v| format!("{v}")).collect();
                csv.push_str(&cells.join(","));
                csv.push('\n');
            }
            let mut body = std::collections::BTreeMap::new();
            body.insert("id".to_string(), aba::util::json::Json::Str(format!("bench{p}")));
            body.insert("k".to_string(), aba::util::json::Json::Num(k as f64));
            body.insert("csv".to_string(), aba::util::json::Json::Str(csv));
            let status = get(
                "/v1/partitions",
                &aba::util::json::to_string(&aba::util::json::Json::Obj(body)),
            );
            assert_eq!(status, 201, "bench partition create failed");
        }
        let t = std::time::Instant::now();
        for r in 0..reads {
            let status = get(&format!("/v1/partitions/bench{}", r % parts), "");
            assert_eq!(status, 200);
        }
        let wall = t.elapsed().as_secs_f64();
        let rps = reads as f64 / wall.max(1e-9);
        let metrics = server.metrics();
        let (p50_us, p99_us) = metrics.latency_percentiles_us();
        let evictions =
            metrics.evictions.load(std::sync::atomic::Ordering::Relaxed) as usize;
        println!(
            "  {reads} reads in {wall:.3}s -> {rps:.0} req/s | p50 {:.2} ms, p99 {:.2} ms | \
             {evictions} evictions (handle cache 4/{parts})",
            p50_us as f64 / 1e3,
            p99_us as f64 / 1e3
        );
        server.drain().unwrap();
        std::fs::remove_dir_all(&dir).ok();
        let mut push = |label: &str, algo_secs: f64, total: f64, objective: f64| {
            recs.push(Rec {
                section: "serve_throughput",
                label: label.into(),
                n,
                k,
                d,
                threads: 4,
                algo_secs,
                total_secs: total,
                objective,
                gathered_bytes: 0,
                cost_buffer_bytes: 0,
            });
        };
        push("throughput_rps", wall, wall, rps);
        push("p50_latency", p50_us as f64 / 1e6, p50_us as f64 / 1e6, rps);
        push("p99_latency", p99_us as f64 / 1e6, p99_us as f64 / 1e6, rps);
        push("evictions", 0.0, wall, evictions as f64);
    }

    if section_enabled("pareto") {
        // The bicriterion Pareto engine: multi-restart interchange
        // search producing a diversity/dispersion front. Serial vs
        // pooled runs must be bit-identical (the engine's determinism
        // contract), so the threaded row is pure wall clock. The
        // hypervolume is measured against the single-ABA solution's own
        // (diversity, dispersion) point nudged epsilon inward — any
        // positive value is front area *beyond* the one-objective
        // solver. CI runs this section (`ABA_BENCH_ONLY=..,pareto`) —
        // keep it seconds.
        let (n, k, d) = (2_000usize, 10usize, 8usize);
        let pcfg = aba::pareto::ParetoConfig {
            restarts: 8,
            passes: 2,
            partners: 6,
            ..Default::default()
        };
        let restarts = pcfg.restarts;
        println!("\n## bicriterion pareto front (N={n}, D={d}, K={k}; {restarts} restarts)");
        let ds = mk(n, d, 16);
        let view = ds.view();
        let aba_part = Aba::from_config(flat.clone()).unwrap().partition(&ds, k).unwrap();
        let aba_disp = aba::algo::objective::dispersion(&view, &aba_part.labels, k);
        let (serial, serial_secs) = timed(|| {
            aba::pareto::pareto_front(&view, k, &pcfg, Some(&aba_part.labels), None).unwrap()
        });
        let pool = aba::runtime::WorkerPool::new(auto_threads);
        let (pooled, pooled_secs) = timed(|| {
            aba::pareto::pareto_front(&view, k, &pcfg, Some(&aba_part.labels), Some(&pool))
                .unwrap()
        });
        assert_eq!(serial, pooled, "pooled pareto front must be bit-identical to serial");
        let ref_point = (aba_part.objective * (1.0 - 1e-9), aba_disp * (1.0 - 1e-9));
        let hv = serial.hypervolume(ref_point);
        println!(
            "  front: {} point(s) | hypervolume vs single-ABA point {hv:.3} | \
             diversity {:.1}..{:.1}, dispersion {:.4}..{:.4}",
            serial.points.len(),
            serial.best_dispersion().map_or(0.0, |p| p.diversity),
            serial.best_diversity().map_or(0.0, |p| p.diversity),
            serial.best_diversity().map_or(0.0, |p| p.dispersion),
            serial.best_dispersion().map_or(0.0, |p| p.dispersion),
        );
        println!(
            "  restarts: serial {serial_secs:>7.3}s ({:.2}/s) | threads({auto_threads}) \
             {pooled_secs:>7.3}s ({:.2}/s, {:.2}x) | fronts bit-identical: yes",
            restarts as f64 / serial_secs.max(1e-9),
            restarts as f64 / pooled_secs.max(1e-9),
            serial_secs / pooled_secs.max(1e-9)
        );
        let mut push = |label: &str, threads: usize, secs: f64, objective: f64| {
            recs.push(Rec {
                section: "pareto",
                label: label.into(),
                n,
                k,
                d,
                threads,
                algo_secs: secs,
                total_secs: secs,
                objective,
                gathered_bytes: 0,
                cost_buffer_bytes: 0,
            });
        };
        push("front_size", 1, serial_secs, serial.points.len() as f64);
        push("hypervolume_vs_aba", 1, serial_secs, hv);
        push("restarts_per_sec_serial", 1, serial_secs, restarts as f64 / serial_secs.max(1e-9));
        push(
            "restarts_per_sec_threads",
            auto_threads,
            pooled_secs,
            restarts as f64 / pooled_secs.max(1e-9),
        );
    }

    // A filtered run must not truncate the canonical cross-PR record,
    // which carries every section: divert it to a scratch file.
    if section_filter().is_some() {
        write_json("BENCH_aba.partial.json", &recs);
    } else {
        write_json("BENCH_aba.json", &recs);
    }
}
