//! End-to-end ABA benchmarks: runtime scaling in N, K, D; variant and
//! hierarchical-decomposition ablations; solver ablation; and the
//! session-reuse amortization of the `Anticlusterer` API.
//!
//! Regenerates the *performance* claims of the paper at reduced scale:
//! ABA is O(N(D + log N + K^2)) flat and O(N L K^(2/L)) decomposed
//! (§4.5); decomposition buys ~2 orders of magnitude at large K for
//! <0.1% objective loss (Figure 7's message). The session-reuse section
//! quantifies what a reused `Aba` session saves over cold per-call
//! construction (scratch/backend reuse — the serving / pipeline /
//! repeated-partitioning hot path).

use aba::algo::{AbaConfig, Variant};
use aba::assignment::SolverKind;
use aba::data::synth::{generate, SynthKind};
use aba::util::timer::timed;
use aba::{Aba, Anticlusterer};

fn mk(n: usize, d: usize, seed: u64) -> aba::data::Dataset {
    generate(SynthKind::GaussianMixture { components: 8, spread: 3.0 }, n, d, seed, "bench")
}

/// One cold call: build a fresh session (as `run_aba` used to on every
/// invocation), partition once, drop it.
fn cold_partition(ds: &aba::data::Dataset, k: usize, cfg: &AbaConfig) -> (f64, f64) {
    let (part, secs) = timed(|| {
        Aba::from_config(cfg.clone())
            .unwrap()
            .partition(ds, k)
            .unwrap()
    });
    (part.objective, secs)
}

fn main() {
    println!("# bench_aba — end-to-end runtime scaling");
    println!("\n## N scaling (D=16, K=50, flat)");
    let flat = AbaConfig { auto_hier: false, ..AbaConfig::default() };
    for &n in &[10_000usize, 20_000, 40_000, 80_000] {
        let ds = mk(n, 16, 1);
        let (ofv, secs) = cold_partition(&ds, 50, &flat);
        println!("  n={n:>7}: {secs:>7.3}s  ofv={ofv:.1}");
    }

    println!("\n## K scaling (N=20000, D=16): flat vs auto-hierarchical");
    for &k in &[50usize, 100, 200, 400, 800] {
        let ds = mk(20_000, 16, 2);
        let (fo, flat_secs) = cold_partition(&ds, k, &flat);
        let (ao, auto_secs) = cold_partition(&ds, k, &AbaConfig::default());
        println!(
            "  k={k:>4}: flat {flat_secs:>7.3}s | auto {auto_secs:>7.3}s ({:>5.1}x) | ofv loss {:>7.4}%",
            flat_secs / auto_secs.max(1e-9),
            100.0 * (ao - fo) / fo
        );
    }

    println!("\n## session reuse (N=40000, D=16, K=50): cold per-call vs one warm session");
    {
        let ds = mk(40_000, 16, 6);
        // Two cold calls, each paying session construction + scratch
        // warm-up (the old `run_aba` free-function behaviour).
        let (_, cold1) = cold_partition(&ds, 50, &flat);
        let (_, cold2) = cold_partition(&ds, 50, &flat);
        // One session, two calls: the second reuses the backend and the
        // assignment loop's scratch buffers.
        let mut session = Aba::from_config(flat.clone()).unwrap();
        let (_, warm1) = timed(|| session.partition(&ds, 50).unwrap());
        let (_, warm2) = timed(|| session.partition(&ds, 50).unwrap());
        let cold_mean = 0.5 * (cold1 + cold2);
        println!("  cold calls:   {cold1:>7.3}s, {cold2:>7.3}s (mean {cold_mean:.3}s)");
        println!(
            "  warm session: {warm1:>7.3}s, {warm2:>7.3}s (2nd call {:+.1}% vs cold mean)",
            100.0 * (warm2 - cold_mean) / cold_mean
        );
        if warm2 > cold_mean {
            // Scratch/backend reuse should never lose; flag it but keep
            // reporting (wall-clock noise on a loaded box is possible).
            println!("  WARN: warm call slower than cold mean — rerun on an idle machine");
        }
    }

    println!("\n## variant ablation (small anticlusters, N=8192, K=2048, i.e. size 4)");
    {
        let ds = mk(8_192, 16, 3);
        for (name, variant) in [("base", Variant::Base), ("small", Variant::Small)] {
            let cfg = AbaConfig { variant, hier: Some(vec![32, 64]), ..AbaConfig::default() };
            let (ofv, secs) = cold_partition(&ds, 2_048, &cfg);
            println!("  {name:>6}: {secs:>7.3}s  ofv={ofv:.1}");
        }
    }

    println!("\n## solver ablation (N=10000, D=16, K=100, flat)");
    {
        let ds = mk(10_000, 16, 4);
        for (name, solver) in [
            ("lapjv", SolverKind::Lapjv),
            ("auction", SolverKind::Auction),
            ("greedy", SolverKind::Greedy),
        ] {
            let cfg = AbaConfig { solver, auto_hier: false, ..AbaConfig::default() };
            let (ofv, secs) = cold_partition(&ds, 100, &cfg);
            println!("  {name:>8}: {secs:>7.3}s  ofv={ofv:.1}");
        }
    }

    println!("\n## 3-level decomposition (N=65536, D=32, K=4096, size 16)");
    {
        let ds = mk(65_536, 32, 5);
        for spec in [vec![64, 64], vec![16, 16, 16], vec![4, 32, 32]] {
            let label = spec.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("x");
            let cfg = AbaConfig { auto_hier: false, hier: Some(spec), ..AbaConfig::default() };
            let (ofv, secs) = cold_partition(&ds, 4_096, &cfg);
            println!("  {label:>10}: {secs:>7.3}s  ofv={ofv:.1}");
        }
    }
}
