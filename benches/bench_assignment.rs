//! Assignment-solver microbenchmarks (custom harness; the offline vendor
//! set has no criterion).
//!
//! Covers the paper's §4.5 claim that LAPJV dominates ABA's runtime at
//! O(K^3) per batch, and the §6 future-work ablation (auction solver):
//! time per solve and quality ratio vs exact, across K.

use aba::assignment::{assignment_cost, auction, greedy, Lapjv};
use aba::rng::Pcg32;
use aba::util::timer::bench;

fn main() {
    println!("# bench_assignment — max-cost K x K solves (cost ~ squared distances)");
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>14} {:>12} {:>12}",
        "K", "lapjv [ms]", "lapjv-cold", "auction [ms]", "greedy [ms]", "auc/opt", "grd/opt"
    );
    for &k in &[16usize, 32, 64, 128, 256, 512] {
        let mut rng = Pcg32::new(k as u64);
        let cost: Vec<f32> = (0..k * k).map(|_| rng.f32() * 100.0).collect();
        let iters = if k >= 256 { 3 } else { 10 };

        let mut solver = Lapjv::new();
        let lapjv_stats = bench(1, iters, || solver.solve(&cost, k, k, true));
        let mut cold = Lapjv::new();
        cold.warm_start = false;
        let cold_stats = bench(1, iters, || cold.solve(&cost, k, k, true));
        let lapjv_assign = Lapjv::new().solve(&cost, k, k, true);
        let opt = assignment_cost(&cost, k, &lapjv_assign);

        let auction_stats = bench(1, iters, || auction::solve_max(&cost, k, k));
        let auction_assign = auction::solve_max(&cost, k, k);
        let auc_ratio = assignment_cost(&cost, k, &auction_assign) / opt;

        let greedy_stats = bench(1, iters, || greedy::solve_max(&cost, k, k));
        let greedy_assign = greedy::solve_max(&cost, k, k);
        let grd_ratio = assignment_cost(&cost, k, &greedy_assign) / opt;

        println!(
            "{:>6} {:>14.3} {:>14.3} {:>14.3} {:>14.3} {:>12.6} {:>12.6}",
            k,
            lapjv_stats.mean * 1e3,
            cold_stats.mean * 1e3,
            auction_stats.mean * 1e3,
            greedy_stats.mean * 1e3,
            auc_ratio,
            grd_ratio
        );
        assert!(auc_ratio > 0.999, "auction must stay near-optimal");
        assert!(grd_ratio > 0.5, "greedy sanity");
    }
    println!("\n# rectangular (last ABA batch): nr = K/3 rows");
    for &k in &[64usize, 256] {
        let nr = k / 3;
        let mut rng = Pcg32::new(k as u64 + 1);
        let cost: Vec<f32> = (0..nr * k).map(|_| rng.f32() * 100.0).collect();
        let mut solver = Lapjv::new();
        let stats = bench(1, 10, || solver.solve(&cost, nr, k, true));
        println!("  {nr}x{k}: lapjv {:.3} ms", stats.mean * 1e3);
    }
}
