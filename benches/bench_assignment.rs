//! Assignment-solver microbenchmarks (custom harness; the offline vendor
//! set has no criterion).
//!
//! Covers the paper's §4.5 claim that LAPJV dominates ABA's runtime at
//! O(K^3) per batch, and the §6 future-work ablation (auction solver):
//! time per solve and quality ratio vs exact, across K.

use aba::assignment::{assignment_cost, auction, greedy, Lapjv};
use aba::rng::Pcg32;
use aba::util::timer::bench;

fn main() {
    println!("# bench_assignment — max-cost K x K solves (cost ~ squared distances)");
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>14} {:>12} {:>12}",
        "K", "lapjv [ms]", "lapjv-cold", "auction [ms]", "greedy [ms]", "auc/opt", "grd/opt"
    );
    for &k in &[16usize, 32, 64, 128, 256, 512] {
        let mut rng = Pcg32::new(k as u64);
        let cost: Vec<f32> = (0..k * k).map(|_| rng.f32() * 100.0).collect();
        let iters = if k >= 256 { 3 } else { 10 };

        let mut solver = Lapjv::new();
        let lapjv_stats = bench(1, iters, || solver.solve(&cost, k, k, true));
        let mut cold = Lapjv::new();
        cold.warm_start = false;
        let cold_stats = bench(1, iters, || cold.solve(&cost, k, k, true));
        let lapjv_assign = Lapjv::new().solve(&cost, k, k, true);
        let opt = assignment_cost(&cost, k, &lapjv_assign);

        let auction_stats = bench(1, iters, || auction::solve_max(&cost, k, k));
        let auction_assign = auction::solve_max(&cost, k, k);
        let auc_ratio = assignment_cost(&cost, k, &auction_assign) / opt;

        let greedy_stats = bench(1, iters, || greedy::solve_max(&cost, k, k));
        let greedy_assign = greedy::solve_max(&cost, k, k);
        let grd_ratio = assignment_cost(&cost, k, &greedy_assign) / opt;

        println!(
            "{:>6} {:>14.3} {:>14.3} {:>14.3} {:>14.3} {:>12.6} {:>12.6}",
            k,
            lapjv_stats.mean * 1e3,
            cold_stats.mean * 1e3,
            auction_stats.mean * 1e3,
            greedy_stats.mean * 1e3,
            auc_ratio,
            grd_ratio
        );
        assert!(auc_ratio > 0.999, "auction must stay near-optimal");
        assert!(grd_ratio > 0.5, "greedy sanity");
    }
    println!("\n# rectangular (last ABA batch): nr = K/3 rows");
    for &k in &[64usize, 256] {
        let nr = k / 3;
        let mut rng = Pcg32::new(k as u64 + 1);
        let cost: Vec<f32> = (0..nr * k).map(|_| rng.f32() * 100.0).collect();
        let mut solver = Lapjv::new();
        let stats = bench(1, 10, || solver.solve(&cost, nr, k, true));
        println!("  {nr}x{k}: lapjv {:.3} ms", stats.mean * 1e3);
    }

    // The sparse large-K path: K x K instances restricted to C
    // candidates per row (feasible by construction — row i always
    // carries column i). At K where a dense solve is still practical,
    // the dense time is printed next to it for the contrast.
    println!("\n# sparse candidate-pruned solves (CSR LAPJV), C candidates/row");
    for &(k, c) in &[(256usize, 16usize), (1024, 32), (4096, 32), (10_000, 32)] {
        let mut rng = Pcg32::new(k as u64 + 7);
        let mut row_ptr = Vec::with_capacity(k + 1);
        let mut cols: Vec<u32> = Vec::with_capacity(k * c);
        let mut vals: Vec<f32> = Vec::with_capacity(k * c);
        row_ptr.push(0usize);
        let mut seen = vec![usize::MAX; k];
        for i in 0..k {
            seen[i] = i; // guarantee a perfect matching exists
            cols.push(i as u32);
            vals.push(rng.f32() * 100.0);
            let mut added = 1;
            while added < c {
                let j = rng.gen_index(k);
                if seen[j] != i {
                    seen[j] = i;
                    cols.push(j as u32);
                    vals.push(rng.f32() * 100.0);
                    added += 1;
                }
            }
            row_ptr.push(cols.len());
            // Reset the dedupe marks touched by this row.
            for &jc in &cols[row_ptr[i]..row_ptr[i + 1]] {
                seen[jc as usize] = usize::MAX;
            }
        }
        let csr = aba::assignment::sparse::CsrCost {
            row_ptr: &row_ptr,
            cols: &cols,
            vals: &vals,
            nc: k,
        };
        let mut sparse = aba::assignment::sparse::SparseLapjv::new();
        let iters = if k >= 4096 { 3 } else { 10 };
        let sparse_stats = bench(1, iters, || sparse.solve_max(&csr).unwrap());
        if k <= 1024 {
            // Dense equivalent (missing entries = 0, never optimal to
            // pick): timing-only contrast at matched k.
            let mut dense_cost = vec![0f32; k * k];
            for i in 0..k {
                for t in row_ptr[i]..row_ptr[i + 1] {
                    dense_cost[i * k + cols[t] as usize] = vals[t];
                }
            }
            let mut dense = Lapjv::new();
            let dense_stats = bench(1, 3, || dense.solve(&dense_cost, k, k, true));
            println!(
                "  K={k:>6} C={c:>3}: sparse {:>9.3} ms | dense {:>10.3} ms ({:>6.1}x)",
                sparse_stats.mean * 1e3,
                dense_stats.mean * 1e3,
                dense_stats.mean / sparse_stats.mean.max(1e-12)
            );
        } else {
            println!(
                "  K={k:>6} C={c:>3}: sparse {:>9.3} ms | dense (skipped: O(K^3))",
                sparse_stats.mean * 1e3
            );
        }
    }
}
