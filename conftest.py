"""Root conftest: make the build-time python package importable when
pytest is invoked from the repository root (`pytest python/tests/`)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
