//! AOT round-trip integration: every artifact the Python compile path
//! emitted must load, compile, and execute through PJRT from Rust with
//! numerics matching the native implementation. This is the end-to-end
//! proof that L1 (Pallas) → L2 (JAX) → HLO text → L3 (Rust/PJRT)
//! composes.
//!
//! All tests no-op (with a notice) when `make artifacts` has not run,
//! and the whole suite compiles only with the `xla` feature.

#![cfg(feature = "xla")]

use aba::runtime::artifacts::{ArtifactKind, Manifest};
use aba::runtime::backend::cost_matrix_native;
use aba::runtime::{CostBackend, NativeBackend, XlaBackend, XlaRuntime};
use aba::rng::Pcg32;

fn manifest() -> Option<Manifest> {
    let dir = aba::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        Some(Manifest::load(dir).unwrap())
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn every_cost_artifact_matches_native_numerics() {
    let Some(man) = manifest() else { return };
    let entries: Vec<_> = man
        .entries
        .iter()
        .filter(|e| e.kind == ArtifactKind::Cost)
        .cloned()
        .collect();
    assert!(entries.len() >= 5, "expected all shipped cost buckets");
    let mut rt = XlaRuntime::new(man).unwrap();
    for e in entries {
        let (m, k, d) = (e.m, e.k, e.d);
        let mut rng = Pcg32::new(m as u64 * 31 + d as u64);
        let x: Vec<f32> = (0..m * d).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let c: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let got = rt.run_f32(&e, &[(&x, &[m, d]), (&c, &[k, d])]).unwrap();
        let mut want = vec![0f32; m * k];
        cost_matrix_native(&x, m, d, &c, k, &mut want);
        let max_err = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-2, "{}: max_err={max_err}", e.name);
    }
}

#[test]
fn dist_and_csum_artifacts_execute() {
    let Some(man) = manifest() else { return };
    let dist = man
        .entries
        .iter()
        .find(|e| e.kind == ArtifactKind::Dist && e.d == 32)
        .unwrap()
        .clone();
    let csum = man
        .entries
        .iter()
        .find(|e| e.kind == ArtifactKind::Csum && e.d == 32)
        .unwrap()
        .clone();
    let mut rt = XlaRuntime::new(man).unwrap();
    let (n, d) = (dist.m, dist.d);
    let mut rng = Pcg32::new(5);
    let x: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
    let mu: Vec<f32> = (0..d).map(|_| rng.f32()).collect();

    let dists = rt.run_f32(&dist, &[(&x, &[n, d]), (&mu, &[1, d])]).unwrap();
    assert_eq!(dists.len(), n);
    // Spot check a few entries.
    for i in (0..n).step_by(257) {
        let want: f32 = (0..d)
            .map(|t| {
                let diff = x[i * d + t] - mu[t];
                diff * diff
            })
            .sum();
        assert!((dists[i] - want).abs() < 1e-2, "{i}: {} vs {want}", dists[i]);
    }

    let sums = rt.run_f32(&csum, &[(&x, &[n, d])]).unwrap();
    assert_eq!(sums.len(), d);
    let want0: f32 = (0..n).map(|i| x[i * d]).sum();
    assert!((sums[0] - want0).abs() < 0.3, "{} vs {want0}", sums[0]);
}

#[test]
fn xla_backend_full_partition_path() {
    if manifest().is_none() {
        return;
    }
    // Drive the whole ABA pipeline through the XLA backend and verify
    // the result is a sane partition identical in quality to native.
    use aba::algo::{run_aba_with_backend, AbaConfig, ClusterStats};
    use aba::data::synth::{generate, SynthKind};
    let ds = generate(SynthKind::Uniform, 500, 12, 6, "rt");
    let k = 50;
    let cfg = AbaConfig { auto_hier: false, ..AbaConfig::default() };
    let mut xla = XlaBackend::from_default_dir().unwrap();
    let labels_xla = run_aba_with_backend(&ds, k, &cfg, &mut xla).unwrap();
    assert!(xla.xla_calls > 0, "XLA path must actually be exercised");
    let mut native = NativeBackend::default();
    let labels_nat = run_aba_with_backend(&ds, k, &cfg, &mut native).unwrap();
    let ox = ClusterStats::compute(&ds, &labels_xla, k).ssd_total();
    let on = ClusterStats::compute(&ds, &labels_nat, k).ssd_total();
    assert!((ox - on).abs() < 1e-3 * on, "xla {ox} vs native {on}");
}

#[test]
fn backend_trait_objects_are_interchangeable() {
    let Some(_) = manifest() else { return };
    let mut backends: Vec<Box<dyn CostBackend>> = vec![
        Box::new(NativeBackend::default()),
        Box::new(XlaBackend::from_default_dir().unwrap()),
    ];
    let mut rng = Pcg32::new(8);
    let (m, k, d) = (20usize, 10usize, 6usize);
    let x: Vec<f32> = (0..m * d).map(|_| rng.f32()).collect();
    let c: Vec<f32> = (0..k * d).map(|_| rng.f32()).collect();
    let mut outs = Vec::new();
    for b in backends.iter_mut() {
        let mut out = Vec::new();
        b.batch_costs(&x, m, d, &c, k, &mut out);
        outs.push(out);
    }
    for (a, b) in outs[0].iter().zip(&outs[1]) {
        assert!((a - b).abs() < 1e-3);
    }
}
