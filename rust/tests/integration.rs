//! Cross-module integration tests: the full system exercised through its
//! public API, plus the quick-scale experiment harness end to end.

use aba::algo::{run_hierarchical, AbaConfig, ClusterStats, Variant};
use aba::assignment::SolverKind;
use aba::baselines::exchange::{fast_anticlustering, ExchangeConfig};
use aba::baselines::random_part::random_partition;
use aba::data::kmeans::kmeans;
use aba::data::synth::{generate, load, Scale, SynthKind};
use aba::data::Dataset;
use aba::experiments::common::ExpOptions;
use aba::pipeline::sgd::{synth_labels, LogReg};
use aba::pipeline::{run_pipeline, BatchStrategy, PipelineConfig};
use aba::runtime::BackendKind;
use aba::{Aba, AbaError, Anticlusterer};

/// One-shot session helper used where a test only needs labels.
fn aba_labels(ds: &Dataset, k: usize, cfg: &AbaConfig) -> Vec<u32> {
    Aba::from_config(cfg.clone())
        .unwrap()
        .partition(ds, k)
        .unwrap()
        .labels
}

fn results_dir() -> std::path::PathBuf {
    std::env::temp_dir().join("aba_integration_results")
}

fn quick_opts() -> ExpOptions {
    ExpOptions {
        quick: true,
        time_limit_secs: 30.0,
        out_dir: results_dir(),
        ..ExpOptions::default()
    }
}

// ---------------------------------------------------------------------------
// Headline behaviour: ABA vs baselines on quality, runtime, balance.
// ---------------------------------------------------------------------------

#[test]
fn aba_beats_random_and_matches_exchange_on_mixture_data() {
    let ds = generate(
        SynthKind::GaussianMixture { components: 6, spread: 5.0 },
        2_000,
        8,
        1,
        "itest",
    );
    let k = 20;
    let aba = aba_labels(&ds, k, &AbaConfig::default());
    let aba_ofv = ClusterStats::compute(&ds, &aba, k).ssd_total();

    let rand = random_partition(ds.n, k, 3);
    let rand_ofv = ClusterStats::compute(&ds, &rand, k).ssd_total();
    assert!(aba_ofv > rand_ofv, "ABA {aba_ofv} must beat random {rand_ofv}");

    let exch = fast_anticlustering(&ds, k, &ExchangeConfig::random(50, 5));
    let exch_ofv = ClusterStats::compute(&ds, &exch.labels, k).ssd_total();
    // Table 4 shape: comparable quality (within a fraction of a percent).
    let rel = (aba_ofv - exch_ofv).abs() / exch_ofv;
    assert!(rel < 0.01, "ABA {aba_ofv} vs exchange {exch_ofv} rel={rel}");
}

#[test]
fn aba_diversity_balance_dominates_baselines() {
    // Table 6 shape: ABA's per-anticluster diversity spread is far
    // smaller than both random's and the exchange heuristic's.
    let ds = load("travel", Scale::Tiny).unwrap();
    let k = 10;
    let aba = aba_labels(&ds, k, &AbaConfig::default());
    let aba_sd = ClusterStats::compute(&ds, &aba, k).diversity_sd();

    let rand = random_partition(ds.n, k, 1);
    let rand_sd = ClusterStats::compute(&ds, &rand, k).diversity_sd();
    let exch = fast_anticlustering(&ds, k, &ExchangeConfig::random(20, 2));
    let exch_sd = ClusterStats::compute(&ds, &exch.labels, k).diversity_sd();

    assert!(aba_sd < rand_sd, "aba {aba_sd} rand {rand_sd}");
    assert!(aba_sd < exch_sd, "aba {aba_sd} exch {exch_sd}");
}

#[test]
fn advantage_over_random_grows_with_k() {
    // Table 8 shape: the random-partition deficit widens as K grows.
    let ds = generate(SynthKind::ImageLike { classes: 10 }, 4_096, 16, 2, "t8i");
    // One reused session across the whole sweep — the serving pattern.
    let mut session = Aba::new().unwrap();
    let mut devs = Vec::new();
    for &k in &[32usize, 256, 2_048] {
        let part = session.partition(&ds, k).unwrap();
        let aba_ofv = part.objective;
        let rand = random_partition(ds.n, k, 1);
        let rand_ofv = ClusterStats::compute(&ds, &rand, k).ssd_total();
        devs.push(100.0 * (rand_ofv - aba_ofv) / aba_ofv);
    }
    assert!(devs[0] <= 0.5, "{devs:?}");
    assert!(devs[2] < devs[0], "deficit should grow: {devs:?}");
    assert!(devs[2] < -2.0, "large-K deficit should be substantial: {devs:?}");
}

// ---------------------------------------------------------------------------
// Variants compose: categorical + hierarchical + small.
// ---------------------------------------------------------------------------

#[test]
fn categorical_hierarchical_composition_respects_all_constraints() {
    let base = generate(SynthKind::Uniform, 1_200, 6, 3, "cat");
    let cats = kmeans(&base, 3, 30, 1).labels;
    let ds = base.with_categories(cats.clone()).unwrap();
    let spec = [3usize, 4];
    let k = 12;
    let labels = run_hierarchical(&ds, &spec, &AbaConfig::default()).unwrap();
    let stats = ClusterStats::compute(&ds, &labels, k);
    // Proposition 1: global sizes within one.
    let (min, max) = (
        *stats.sizes.iter().min().unwrap(),
        *stats.sizes.iter().max().unwrap(),
    );
    assert!(max - min <= 1, "{:?}", stats.sizes);
    // Per-category balance holds approximately through the hierarchy
    // (exact bounds hold per level; composition can add one per level).
    for g in 0..3u32 {
        let total = cats.iter().filter(|&&c| c == g).count();
        let ideal = total as f64 / k as f64;
        for cl in 0..k as u32 {
            let cnt = (0..ds.n)
                .filter(|&i| labels[i] == cl && cats[i] == g)
                .count() as f64;
            assert!(
                (cnt - ideal).abs() <= 2.0,
                "cat {g} cluster {cl}: {cnt} vs ideal {ideal}"
            );
        }
    }
}

#[test]
fn small_variant_improves_tiny_anticlusters() {
    // §4.2: for anticlusters of size 2 (matching), the interleaved order
    // should not be worse than the base order.
    let ds = generate(SynthKind::Uniform, 512, 4, 4, "sm");
    let k = 256;
    let run = |variant| {
        let cfg = AbaConfig { variant, auto_hier: false, ..AbaConfig::default() };
        Aba::from_config(cfg).unwrap().partition(&ds, k).unwrap().objective
    };
    let base = run(Variant::Base);
    let small = run(Variant::Small);
    assert!(
        small >= base * 0.95,
        "small variant should be competitive: base={base} small={small}"
    );
}

// ---------------------------------------------------------------------------
// Backends agree end to end.
// ---------------------------------------------------------------------------

#[cfg(feature = "xla")]
#[test]
fn xla_backend_produces_same_partition_as_native() {
    if !aba::runtime::default_artifact_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ds = generate(SynthKind::Uniform, 600, 10, 5, "xla");
    let k = 60; // fits the (64,64,16) bucket after padding
    let native_cfg = AbaConfig { auto_hier: false, ..AbaConfig::default() };
    let xla_cfg = AbaConfig {
        backend: BackendKind::Xla,
        auto_hier: false,
        ..AbaConfig::default()
    };
    let a = aba_labels(&ds, k, &native_cfg);
    let b = aba_labels(&ds, k, &xla_cfg);
    // Tiny float differences may flip ties; objectives must agree closely.
    let oa = ClusterStats::compute(&ds, &a, k).ssd_total();
    let ob = ClusterStats::compute(&ds, &b, k).ssd_total();
    assert!(
        (oa - ob).abs() < 1e-3 * oa,
        "native {oa} vs xla {ob}"
    );
}

// ---------------------------------------------------------------------------
// Pipeline end to end with a real consumer.
// ---------------------------------------------------------------------------

#[test]
fn pipeline_with_sgd_consumer_reduces_batch_loss_variance() {
    let ds = generate(
        SynthKind::GaussianMixture { components: 5, spread: 3.0 },
        3_000,
        12,
        6,
        "pipe",
    );
    let y = synth_labels(&ds, 0.05, 7);
    let k = 30;
    let epochs = 3;
    let sd_of = |strategy: BatchStrategy| {
        let cfg = PipelineConfig { k, epochs, queue_depth: 4, strategy };
        let mut model = LogReg::new(ds.d, 0.3);
        let mut final_epoch = Vec::new();
        run_pipeline(&ds, &cfg, |b| {
            let loss = model.train_batch(&ds, &y, &b.indices);
            if b.epoch == epochs - 1 {
                final_epoch.push(loss);
            }
        })
        .unwrap();
        aba::metrics::Summary::of(&final_epoch).sd
    };
    let aba_sd = sd_of(BatchStrategy::Aba { cfg: AbaConfig::default(), shuffle_seed: 1 });
    let rand_sd = sd_of(BatchStrategy::Random { seed: 1 });
    assert!(
        aba_sd < rand_sd,
        "representative batches must lower loss variance: aba {aba_sd} rand {rand_sd}"
    );
}

// ---------------------------------------------------------------------------
// The experiment harness runs end to end at quick scale.
// ---------------------------------------------------------------------------

#[test]
fn all_tables_and_figures_run_quick() {
    let opts = quick_opts();
    aba::experiments::t4::table4(&opts).unwrap();
    aba::experiments::t8::table8(&opts).unwrap();
    let t9_opts = ExpOptions {
        datasets: Some(vec!["abalone".into()]),
        ..quick_opts()
    };
    aba::experiments::t9::table9(&t9_opts).unwrap();
    aba::experiments::t11::table11(&ExpOptions {
        datasets: Some(vec!["abalone".into()]),
        ..quick_opts()
    })
    .unwrap();
    aba::experiments::figs::fig7(&opts).unwrap();
    // CSVs landed.
    for f in ["t4_k5.csv", "t8.csv", "t9.csv", "t11.csv", "f7.csv"] {
        assert!(results_dir().join(f).exists(), "{f} missing");
    }
}

// ---------------------------------------------------------------------------
// Failure injection.
// ---------------------------------------------------------------------------

#[test]
fn oversized_k_and_bad_specs_fail_cleanly() {
    let ds = generate(SynthKind::Uniform, 50, 3, 8, "fi");
    let mut session = Aba::new().unwrap();
    assert!(matches!(
        session.partition(&ds, 51),
        Err(AbaError::InvalidK { k: 51, n: 50, .. })
    ));
    assert!(matches!(
        session.partition(&ds, 0),
        Err(AbaError::InvalidK { k: 0, .. })
    ));
    // Hier spec whose product exceeds n.
    assert!(matches!(
        run_hierarchical(&ds, &[8, 8], &AbaConfig::default()),
        Err(AbaError::BadHierSpec(_))
    ));
    // Empty spec errors.
    assert!(matches!(
        run_hierarchical(&ds, &[], &AbaConfig::default()),
        Err(AbaError::BadHierSpec(_))
    ));
    // A session with an explicit spec whose product != k errors too.
    let mut hier = Aba::builder().hier(vec![4, 5]).build().unwrap();
    assert!(matches!(
        hier.partition(&ds, 21),
        Err(AbaError::BadHierSpec(_))
    ));
}

#[cfg(feature = "xla")]
#[test]
fn missing_artifacts_dir_yields_helpful_error() {
    std::env::set_var("ABA_ARTIFACTS", "/nonexistent/aba_artifacts");
    let err = match aba::runtime::XlaBackend::from_default_dir() {
        Ok(_) => panic!("expected missing-artifacts error"),
        Err(e) => e,
    };
    std::env::remove_var("ABA_ARTIFACTS");
    assert!(format!("{err:#}").contains("make artifacts"));
}

#[cfg(not(feature = "xla"))]
#[test]
fn xla_backend_unavailable_without_feature_is_typed() {
    // Requesting the XLA backend from a build without the `xla` feature
    // must fail with the typed BackendUnavailable error at session
    // construction, not at partition time.
    let err = Aba::builder().backend(BackendKind::Xla).build().unwrap_err();
    assert!(matches!(err, AbaError::BackendUnavailable(_)), "{err}");
    assert!(err.to_string().contains("xla"), "{err}");
}

#[test]
fn solver_choice_is_pluggable_end_to_end() {
    let ds = generate(SynthKind::Uniform, 300, 4, 9, "sv");
    for solver in [SolverKind::Lapjv, SolverKind::Auction, SolverKind::Greedy] {
        let mut session = Aba::builder().solver(solver).build().unwrap();
        let part = session.partition(&ds, 10).unwrap();
        assert_eq!(part.sizes().iter().sum::<usize>(), 300);
    }
}

#[test]
fn baselines_are_interchangeable_behind_the_trait() {
    let ds = generate(SynthKind::Uniform, 120, 4, 10, "tr");
    let mut solvers: Vec<Box<dyn Anticlusterer>> = vec![
        Box::new(Aba::new().unwrap()),
        Box::new(aba::baselines::RandomPartition::new(3)),
        Box::new(aba::baselines::FastAnticlustering::random(10, 3)),
        Box::new(aba::baselines::ExactSolver::new(Some(
            std::time::Duration::from_millis(50),
        ))),
    ];
    for solver in solvers.iter_mut() {
        let part = solver.partition(&ds, 6).unwrap();
        assert_eq!(part.labels.len(), 120, "{}", solver.name());
        assert_eq!(part.sizes().iter().sum::<usize>(), 120, "{}", solver.name());
        assert!(part.objective > 0.0, "{}", solver.name());
    }
}

// ---------------------------------------------------------------------------
// Sparse candidate-pruned path.
// ---------------------------------------------------------------------------

#[test]
fn sparse_candidates_end_to_end_matches_dense_quality_closely() {
    // Moderate scale so it stays fast in debug: the pruned path must be
    // a valid balanced partition within a fraction of a percent of the
    // dense objective.
    use aba::assignment::CandidateMode;
    let ds = generate(
        SynthKind::GaussianMixture { components: 8, spread: 4.0 },
        2_000,
        8,
        42,
        "sp",
    );
    let k = 40;
    let cfg_for = |cand: CandidateMode| AbaConfig {
        auto_hier: false,
        candidates: cand,
        ..AbaConfig::default()
    };
    let dense = aba_labels(&ds, k, &cfg_for(CandidateMode::Dense));
    let mut session = Aba::from_config(cfg_for(CandidateMode::Fixed(8))).unwrap();
    let part = session.partition(&ds, k).unwrap();
    assert!(part.sizes().iter().all(|&s| s == 50), "{:?}", part.sizes());
    let stats = session.sparse_stats();
    assert!(stats.sparse_batches > 0, "sparse path never engaged: {stats:?}");
    let dense_ofv = ClusterStats::compute(&ds, &dense, k).ssd_total();
    assert!(
        part.objective > 0.99 * dense_ofv,
        "sparse {} vs dense {} lost more than 1%",
        part.objective,
        dense_ofv
    );
}

/// Release-profile large-K smoke: CI runs this with
/// `cargo test --release -q --test integration -- --ignored large_k_sparse_smoke`.
/// The dense path at this scale would build a 25 MiB cost matrix per
/// batch and spend `O(k^3)` per solve; the sparse path must finish the
/// whole instance quickly and stay far below that buffer size.
#[test]
#[ignore = "release-profile large-K smoke; run explicitly (CI does)"]
fn large_k_sparse_smoke() {
    use aba::assignment::CandidateMode;
    use aba::runtime::Parallelism;
    let ds = generate(
        SynthKind::GaussianMixture { components: 16, spread: 3.0 },
        50_000,
        8,
        44,
        "smoke",
    );
    let k = 2_500;
    let mut session = Aba::builder()
        .auto_hier(false)
        .candidates(CandidateMode::Fixed(32))
        .parallelism(Parallelism::Auto)
        .build()
        .unwrap();
    let part = session.partition(&ds, k).unwrap();
    assert_eq!(part.labels.len(), 50_000);
    assert!(part.sizes().iter().all(|&s| s == 20));
    let stats = session.sparse_stats();
    assert!(stats.sparse_batches > 0, "sparse path must engage: {stats:?}");
    if stats.fallback_batches == 0 {
        // Without fallbacks the peak cost structure is the CSR, which
        // must be far below the dense k x k buffer.
        assert!(
            stats.peak_cost_bytes < k * k * 4 / 10,
            "cost structure unexpectedly large: {stats:?}"
        );
    }
}
