//! Integration tests for the quality-certificate subsystem
//! ([`aba::cert`]): the cross-solver bound property, permutation
//! invariance of standalone certificates, the exact K=2 dispersion
//! coloring against the exhaustive oracle, and fuzzed robustness of
//! snapshot JSON parsing.

use aba::algo::{objective, Criterion};
use aba::assignment::CandidateMode;
use aba::baselines::exchange::ExchangeConfig;
use aba::baselines::{FastAnticlustering, RandomPartition};
use aba::cert;
use aba::data::synth::{generate, SynthKind};
use aba::data::Dataset;
use aba::prop_assert;
use aba::rng::Pcg32;
use aba::runtime::Parallelism;
use aba::testing::{oracle, PropRunner};
use aba::util::json;
use aba::{Aba, Anticlusterer, OnlinePartition, Partition};

fn rand_dataset(rng: &mut Pcg32, max_n: usize, max_d: usize) -> Dataset {
    let n = 8 + rng.gen_index(max_n - 8);
    let d = 1 + rng.gen_index(max_d);
    let kind = match rng.gen_index(3) {
        0 => SynthKind::Uniform,
        1 => SynthKind::GaussianMixture { components: 1 + rng.gen_index(5), spread: 3.0 },
        _ => SynthKind::HeavyTail,
    };
    generate(kind, n, d, rng.next_u64(), "cert-prop")
}

/// The partition-attached bound invariants every solve must satisfy:
/// `upper_bound() >= objective` exactly (the bound adds the
/// non-negative BGSS term to the objective) and a gap in `[0, 1]`.
fn check_bound(part: &Partition, who: &str) -> Result<(), String> {
    prop_assert!(
        part.upper_bound() >= part.objective,
        "{who}: upper bound {} < objective {}",
        part.upper_bound(),
        part.objective
    );
    let g = part.gap();
    prop_assert!((0.0..=1.0).contains(&g), "{who}: gap {g} outside [0, 1]");
    Ok(())
}

/// Satellite 1a: `upper_bound() >= diversity objective` for every
/// solver in the crate — ABA flat, hierarchical, sparse-candidates,
/// and online-bootstrap, plus the exchange and random baselines —
/// under both serial and threaded execution. The solver-independent
/// certificate from [`cert::bounds::certify`] must dominate all of
/// them too.
#[test]
fn prop_upper_bound_dominates_every_solver() {
    PropRunner::new(10).run("upper bound dominates all solvers", |rng| {
        let ds = rand_dataset(rng, 120, 5);
        let k = 2 + rng.gen_index(ds.n / 2 - 1);
        let standalone = cert::bounds::certify(&ds.view(), k).map_err(|e| e.to_string())?;
        let dominated = |part: &Partition, who: &str| -> Result<(), String> {
            check_bound(part, who)?;
            let slack = 1e-9 * standalone.upper_bound.abs() + 1e-9;
            prop_assert!(
                part.objective <= standalone.upper_bound + slack,
                "{who}: objective {} exceeds standalone certificate {}",
                part.objective,
                standalone.upper_bound
            );
            Ok(())
        };

        for par in [Parallelism::Serial, Parallelism::Threads(3)] {
            let flat = Aba::builder()
                .auto_hier(false)
                .parallelism(par)
                .build()
                .map_err(|e| e.to_string())?
                .partition(&ds, k)
                .map_err(|e| e.to_string())?;
            dominated(&flat, "aba flat")?;

            let sparse = Aba::builder()
                .candidates(CandidateMode::Fixed(2))
                .parallelism(par)
                .build()
                .map_err(|e| e.to_string())?
                .partition(&ds, k)
                .map_err(|e| e.to_string())?;
            dominated(&sparse, "aba sparse")?;

            // Hierarchical needs prod(spec) == k, so it runs at its own
            // fixed k = 4 (every case has n >= 8).
            let hier = Aba::builder()
                .hier(vec![2, 2])
                .parallelism(par)
                .build()
                .map_err(|e| e.to_string())?
                .partition(&ds, 4)
                .map_err(|e| e.to_string())?;
            check_bound(&hier, "aba hierarchical")?;

            let mut session = Aba::builder()
                .parallelism(par)
                .build()
                .map_err(|e| e.to_string())?;
            let mut handle = session
                .partition_online(&ds.view(), k)
                .map_err(|e| e.to_string())?;
            let live_obj = handle.objective();
            let live_ub = handle.upper_bound();
            prop_assert!(
                live_ub >= live_obj,
                "online handle: bound {live_ub} < objective {live_obj}"
            );
            let live_gap = handle.gap();
            prop_assert!(
                (0.0..=1.0).contains(&live_gap),
                "online handle: gap {live_gap} outside [0, 1]"
            );
            dominated(&handle.into_partition(), "online bootstrap")?;
        }

        let fast = FastAnticlustering::new(ExchangeConfig::nearest(3, rng.next_u64()))
            .partition(&ds, k)
            .map_err(|e| e.to_string())?;
        dominated(&fast, "fast_anticlustering")?;

        let random = RandomPartition::new(rng.next_u64())
            .partition(&ds, k)
            .map_err(|e| e.to_string())?;
        dominated(&random, "random baseline")?;
        Ok(())
    });
}

/// Satellite 1b: the standalone certificate is a function of the point
/// *set*, so shuffling the row order must not move the bound (beyond
/// f64 summation reordering).
#[test]
fn prop_certificate_bound_is_permutation_invariant() {
    PropRunner::new(15).run("certificate permutation invariance", |rng| {
        let ds = rand_dataset(rng, 150, 5);
        let k = 2 + rng.gen_index(5);
        let base = cert::bounds::certify(&ds.view(), k).map_err(|e| e.to_string())?;

        let mut order: Vec<usize> = (0..ds.n).collect();
        for i in (1..order.len()).rev() {
            order.swap(i, rng.gen_index(i + 1));
        }
        let view = ds.view();
        let rows: Vec<Vec<f32>> = order.iter().map(|&i| view.row(i).to_vec()).collect();
        let shuffled = Dataset::from_rows("shuffled", &rows).map_err(|e| e.to_string())?;
        let perm = cert::bounds::certify(&shuffled.view(), k).map_err(|e| e.to_string())?;

        let scale = base.total_ss.abs().max(1.0);
        prop_assert!(
            (base.total_ss - perm.total_ss).abs() <= 1e-9 * scale,
            "TSS moved under permutation: {} vs {}",
            base.total_ss,
            perm.total_ss
        );
        prop_assert!(
            (base.upper_bound - perm.upper_bound).abs() <= 1e-9 * scale,
            "bound moved under permutation: {} vs {}",
            base.upper_bound,
            perm.upper_bound
        );
        prop_assert!(
            (base.pairwise_upper_bound - perm.pairwise_upper_bound).abs()
                <= 1e-9 * base.pairwise_upper_bound.abs().max(1.0),
            "pairwise bound moved under permutation: {} vs {}",
            base.pairwise_upper_bound,
            perm.pairwise_upper_bound
        );
        Ok(())
    });
}

/// Satellite 2a: the polynomial K=2 coloring construction finds the
/// exhaustively-verified dispersion optimum for every cardinality
/// split on instances small enough to enumerate.
#[test]
fn prop_two_coloring_matches_exhaustive_oracle() {
    PropRunner::new(30).run("k=2 coloring vs exhaustive oracle", |rng| {
        let n = 4 + rng.gen_index(9); // 4..=12: oracle enumerates C(n, m0) splits
        let d = 1 + rng.gen_index(3);
        let kind = if rng.gen_index(2) == 0 {
            SynthKind::Uniform
        } else {
            SynthKind::GaussianMixture { components: 2, spread: 2.0 }
        };
        let ds = generate(kind, n, d, rng.next_u64(), "oracle");
        let m0 = 1 + rng.gen_index(n - 1); // 1..=n-1

        let fast = cert::two_color::solve_with_sizes(&ds.view(), m0).map_err(|e| e.to_string())?;
        let (opt, _) = oracle::dispersion_k2_exhaustive(&ds.view(), m0);
        prop_assert!(
            fast.dispersion == opt,
            "n={n} m0={m0}: coloring found {} but oracle says {opt}",
            fast.dispersion
        );
        prop_assert!(
            fast.labels.iter().filter(|&&l| l == 0).count() == m0,
            "n={n} m0={m0}: side-0 cardinality violated"
        );

        let balanced = cert::two_color::solve_balanced(&ds.view()).map_err(|e| e.to_string())?;
        let (bal_opt, _) = oracle::dispersion_k2_exhaustive(&ds.view(), n.div_ceil(2));
        prop_assert!(
            balanced.dispersion == bal_opt,
            "n={n} balanced: coloring found {} but oracle says {bal_opt}",
            balanced.dispersion
        );
        Ok(())
    });
}

/// Satellite 2b: an `Aba` session under the dispersion criterion routes
/// K=2 through the exact coloring solver, so its dispersion gap against
/// the oracle is pinned (tolerance covers floating point only); the
/// default diversity criterion optimizes a different objective and may
/// fall short, but can never *beat* the oracle.
#[test]
fn aba_k2_dispersion_gap_vs_oracle_is_pinned() {
    const TOL: f64 = 1e-9;
    for seed in [7u64, 21, 99] {
        let ds = generate(
            SynthKind::GaussianMixture { components: 3, spread: 2.0 },
            12,
            3,
            seed,
            "k2-oracle",
        );
        let (opt, _) = oracle::dispersion_k2_exhaustive(&ds.view(), 6);
        let tol = TOL * opt.abs().max(1.0);

        let exact = Aba::builder()
            .criterion(Criterion::Dispersion)
            .build()
            .unwrap()
            .partition(&ds, 2)
            .unwrap();
        let achieved = objective::dispersion(&ds, &exact.labels, 2);
        assert!(
            (achieved - opt).abs() <= tol,
            "seed {seed}: exact path achieved {achieved}, oracle optimum {opt}"
        );
        assert_eq!(exact.sizes(), &[6, 6], "seed {seed}: balanced cardinalities");

        let diversity = Aba::builder().build().unwrap().partition(&ds, 2).unwrap();
        let div_disp = objective::dispersion(&ds, &diversity.labels, 2);
        assert!(
            div_disp <= opt + tol,
            "seed {seed}: diversity solve dispersion {div_disp} beats the oracle {opt}"
        );
    }
}

/// Satellite 2c: at K=2 the bicriterion Pareto front's dispersion
/// extreme is pinned to the exact coloring optimum
/// ([`aba::cert::two_color`]). Seeding the engine with the coloring's
/// labels puts the optimum in the archive, so the front must hold it —
/// and since the coloring is exact, no balanced 2-partition the search
/// visits can beat it.
#[test]
fn pareto_front_dispersion_extreme_matches_two_color_oracle() {
    use aba::pareto::{pareto_front, ParetoConfig};
    for seed in [3u64, 11, 42] {
        let ds = generate(
            SynthKind::GaussianMixture { components: 3, spread: 2.5 },
            16,
            3,
            seed,
            "k2-front",
        );
        let view = ds.view();
        let exact = cert::two_color::solve_balanced(&view).unwrap();
        let opt = objective::dispersion(&view, &exact.labels, 2);
        let cfg = ParetoConfig { restarts: 5, seed, ..Default::default() };
        let front = pareto_front(&view, 2, &cfg, Some(&exact.labels), None).unwrap();
        let best = front.best_dispersion().unwrap();
        assert!(
            best.dispersion <= opt,
            "seed {seed}: front dispersion {} beats the exact optimum {opt}",
            best.dispersion
        );
        assert_eq!(
            best.dispersion.to_bits(),
            opt.to_bits(),
            "seed {seed}: front dropped the seeded dispersion optimum {opt}"
        );
    }
}

/// Satellite 3: fuzzed snapshot parsing. Truncations and byte-level
/// mutations of a valid snapshot document must never panic: the JSON
/// layer reports a typed error with an in-range byte offset and a
/// caret-context excerpt, and both snapshot entry points surface typed
/// [`aba::AbaError`] values.
///
/// The mutation alphabet deliberately excludes digits: substituting
/// digits can inflate header counts (`k`, `d`) into absurd-but-valid
/// allocations, which is a capacity-validation concern, not the parse
/// robustness under test here.
#[test]
fn prop_snapshot_json_fuzz_never_panics() {
    let ds = generate(SynthKind::Uniform, 24, 3, 5, "fuzz-seed");
    let mut session = Aba::builder().build().unwrap();
    let handle = session.partition_online(&ds.view(), 4).unwrap();
    let snapshot = handle.snapshot_string();
    let cfg = session.config().clone();

    // The pristine document round-trips through every entry point.
    assert!(OnlinePartition::from_snapshot_str(&snapshot, &cfg).is_ok());
    assert!(aba::online::inspect_snapshot_str(&snapshot).is_ok());

    const ALPHABET: &[u8] = b"az!~\"{}[]:,x ";
    PropRunner::new(300).run("snapshot fuzz", |rng| {
        let mut bytes = snapshot.clone().into_bytes();
        match rng.gen_index(3) {
            0 => bytes.truncate(rng.gen_index(bytes.len())),
            1 => {
                let i = rng.gen_index(bytes.len());
                bytes[i] = ALPHABET[rng.gen_index(ALPHABET.len())];
            }
            _ => {
                let i = rng.gen_index(bytes.len() + 1);
                bytes.insert(i, ALPHABET[rng.gen_index(ALPHABET.len())]);
            }
        }
        // Snapshot documents are ASCII and so is the mutation alphabet.
        let mutant = String::from_utf8(bytes).map_err(|e| e.to_string())?;

        if let Err(e) = json::parse(&mutant) {
            prop_assert!(
                e.offset <= mutant.len(),
                "offset {} past end of {}-byte input",
                e.offset,
                mutant.len()
            );
            let shown = e.to_string();
            prop_assert!(shown.contains("byte"), "display lacks byte offset: {shown}");
            prop_assert!(
                mutant.is_empty() || !e.context.is_empty(),
                "no caret context on non-empty input: {shown}"
            );
        }
        // Typed error or clean success — never a panic.
        if let Err(e) = OnlinePartition::from_snapshot_str(&mutant, &cfg) {
            prop_assert!(!e.to_string().is_empty(), "empty error display");
        }
        if let Err(e) = aba::online::inspect_snapshot_str(&mutant) {
            prop_assert!(!e.to_string().is_empty(), "empty error display");
        }
        Ok(())
    });
}
