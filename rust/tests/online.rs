//! Property tests for the online subsystem — the acceptance contract of
//! the live-handle API:
//!
//! * for any churn sequence (inserts / removes / refines) over flat and
//!   categorical data, under serial and threaded initial solves, the
//!   maintained `objective()` and `sizes()` exactly match a from-scratch
//!   recompute on the final membership;
//! * balance invariants (max - min size <= 1, §4.3 category caps) hold
//!   after every operation;
//! * `save` -> `load` round-trips bit-identically;
//! * `insert_batch` of a whole dataset into an empty handle reproduces
//!   the batch solver's partition.

use aba::algo::AbaConfig;
use aba::data::synth::{generate, SynthKind};
use aba::data::Dataset;
use aba::prop_assert;
use aba::rng::Pcg32;
use aba::runtime::Parallelism;
use aba::testing::PropRunner;
use aba::{Aba, AbaError, Anticlusterer, OnlinePartition};

/// Balance + §4.3 invariants, checked after every operation.
fn check_invariants(p: &OnlinePartition, ctx: &str) -> Result<(), String> {
    let sizes = p.sizes();
    let (min, max) = (
        *sizes.iter().min().unwrap(),
        *sizes.iter().max().unwrap(),
    );
    prop_assert!(max - min <= 1, "{ctx}: unbalanced sizes {sizes:?}");
    prop_assert!(
        sizes.iter().sum::<usize>() == p.len(),
        "{ctx}: sizes {sizes:?} do not cover n={}",
        p.len()
    );
    if p.n_categories() > 0 {
        // Recount categories from the authoritative entries.
        let g = p.n_categories();
        let entries = p.entries();
        let ds = p.to_dataset("check").map_err(|e| e.to_string())?;
        let cats = ds.categories.as_ref().expect("categorical handle");
        let mut totals = vec![0usize; g];
        let mut counts = vec![0usize; g * p.k()];
        for (i, &(_, label)) in entries.iter().enumerate() {
            let cat = cats[i] as usize;
            totals[cat] += 1;
            counts[cat * p.k() + label as usize] += 1;
        }
        for cat in 0..g {
            let cap = totals[cat].div_ceil(p.k());
            for c in 0..p.k() {
                prop_assert!(
                    counts[cat * p.k() + c] <= cap,
                    "{ctx}: cat {cat} cluster {c}: {} > cap {cap}",
                    counts[cat * p.k() + c]
                );
            }
        }
    }
    Ok(())
}

/// Maintained reads must equal the from-scratch oracle bit for bit.
fn check_exact_reads(p: &mut OnlinePartition, ctx: &str) -> Result<(), String> {
    let maintained = p.objective();
    let scratch = p.recompute_objective();
    prop_assert!(
        maintained == scratch,
        "{ctx}: maintained {maintained} != scratch {scratch}"
    );
    Ok(())
}

fn churn_source(rng: &mut Pcg32, b: usize, d: usize, g: usize) -> Dataset {
    let ds = generate(SynthKind::Uniform, b, d, rng.next_u64(), "churn");
    if g > 0 {
        ds.with_categories((0..b).map(|_| rng.gen_below(g as u32)).collect())
            .unwrap()
    } else {
        ds
    }
}

#[test]
fn prop_online_churn_keeps_exact_reads_and_invariants() {
    PropRunner::new(12).run("online churn consistency", |rng| {
        let d = 1 + rng.gen_index(4);
        let n = 40 + rng.gen_index(120);
        let k = 2 + rng.gen_index(6);
        // Mode: flat or categorical; initial solve serial or threaded.
        let g = if rng.gen_index(2) == 0 { 0 } else { 2 + rng.gen_index(3) };
        let par = if rng.gen_index(2) == 0 {
            Parallelism::Serial
        } else {
            Parallelism::Threads(3)
        };
        let mut base = generate(SynthKind::Uniform, n, d, rng.next_u64(), "base");
        if g > 0 {
            base = base
                .with_categories((0..n).map(|_| rng.gen_below(g as u32)).collect())
                .map_err(|e| e.to_string())?;
        }
        let mut session = Aba::builder()
            .auto_hier(false)
            .parallelism(par)
            .build()
            .map_err(|e| e.to_string())?;
        let mut p = session
            .partition_online(&base.view(), k)
            .map_err(|e| e.to_string())?;
        check_invariants(&p, "initial")?;
        check_exact_reads(&mut p, "initial")?;

        // A random churn sequence; invariants and exact reads are
        // checked after every single operation.
        for step in 0..6 {
            let ctx = format!("step {step} (n={}, k={k}, g={g}, par={par:?})", p.len());
            match rng.gen_index(3) {
                0 => {
                    let b = 1 + rng.gen_index(9);
                    let batch = churn_source(rng, b, d, g);
                    let ids = p.insert_batch(&batch.view()).map_err(|e| e.to_string())?;
                    prop_assert!(ids.len() == b, "{ctx}: {} ids for {b} rows", ids.len());
                }
                1 => {
                    let live: Vec<u64> = p.entries().iter().map(|&(id, _)| id).collect();
                    if live.len() > k {
                        let m = 1 + rng.gen_index((live.len() - k).min(10));
                        let mut pick = live;
                        rng.shuffle(&mut pick);
                        pick.truncate(m);
                        p.remove(&pick).map_err(|e| e.to_string())?;
                    }
                }
                _ => {
                    p.refine(rng.gen_index(3_000));
                }
            }
            check_invariants(&p, &ctx)?;
            check_exact_reads(&mut p, &ctx)?;
        }

        // Persistence: byte-identical round trip, and resuming under an
        // incompatible config is a typed error.
        let snapshot = p.snapshot_string();
        let mut back = OnlinePartition::from_snapshot_str(&snapshot, session.config())
            .map_err(|e| e.to_string())?;
        prop_assert!(back.snapshot_string() == snapshot, "snapshot round trip drifted");
        prop_assert!(back.entries() == p.entries(), "membership drifted through save/load");
        prop_assert!(
            back.objective() == p.objective(),
            "objective drifted through save/load"
        );
        let other = AbaConfig {
            solver: aba::assignment::SolverKind::Greedy,
            ..session.config().clone()
        };
        prop_assert!(
            matches!(
                OnlinePartition::from_snapshot_str(&snapshot, &other),
                Err(AbaError::SnapshotMismatch { .. })
            ),
            "incompatible fingerprint must be SnapshotMismatch"
        );
        Ok(())
    });
}

#[test]
fn prop_empty_handle_insert_reproduces_the_batch_solver() {
    PropRunner::new(12).run("empty-handle bootstrap parity", |rng| {
        let d = 1 + rng.gen_index(4);
        let n = 24 + rng.gen_index(120);
        let k = 2 + rng.gen_index(8.min(n / 2));
        let g = if rng.gen_index(2) == 0 { 0 } else { 2 + rng.gen_index(3) };
        let mut ds = generate(SynthKind::Uniform, n, d, rng.next_u64(), "boot");
        if g > 0 {
            ds = ds
                .with_categories((0..n).map(|_| rng.gen_below(g as u32)).collect())
                .map_err(|e| e.to_string())?;
        }
        let cfg = AbaConfig { auto_hier: false, ..AbaConfig::default() };
        let mut empty = OnlinePartition::empty(k, d, &cfg).map_err(|e| e.to_string())?;
        let ids = empty.insert_batch(&ds.view()).map_err(|e| e.to_string())?;
        let mut session = Aba::from_config(cfg).map_err(|e| e.to_string())?;
        let part = session.partition(&ds, k).map_err(|e| e.to_string())?;
        let entries = empty.entries();
        prop_assert!(entries.len() == n, "entry count");
        for (i, &(id, label)) in entries.iter().enumerate() {
            prop_assert!(id == ids[i], "id order drifted at {i}");
            prop_assert!(
                label == part.labels[i],
                "label diverges at row {i}: online {label} vs batch {} (n={n} k={k} g={g})",
                part.labels[i]
            );
        }
        Ok(())
    });
}

#[test]
fn online_partition_freeze_equals_partition_view() {
    // The frozen path is literally partition_online + into_partition —
    // pin that equivalence through the public API.
    let ds = generate(SynthKind::Uniform, 150, 5, 77, "freeze");
    let mut a = Aba::new().unwrap();
    let mut b = Aba::new().unwrap();
    let frozen = a.partition_online(&ds.view(), 10).unwrap().into_partition();
    let direct = b.partition(&ds, 10).unwrap();
    assert_eq!(frozen.labels, direct.labels);
    assert_eq!(frozen.objective, direct.objective);
    assert_eq!(frozen.pairwise, direct.pairwise);
    assert_eq!(frozen.sizes(), direct.sizes());
}

#[test]
fn evolving_handle_outlives_heavy_churn() {
    // A longer single-scenario soak: 10 rounds of churn on a larger
    // handle, exact reads and invariants at the end, then a from-scratch
    // re-solve for a sanity band on quality (the maintained partition
    // must stay within 25% of a full re-solve on this easy data).
    let ds = generate(
        SynthKind::GaussianMixture { components: 5, spread: 4.0 },
        1_200,
        6,
        91,
        "soak",
    );
    let mut session = Aba::builder().auto_hier(false).build().unwrap();
    let mut p = session.partition_online(&ds.view(), 12).unwrap();
    let arrivals = generate(
        SynthKind::GaussianMixture { components: 5, spread: 4.0 },
        600,
        6,
        92,
        "soak-arrivals",
    );
    let mut next = 0usize;
    for round in 0..10 {
        let idx: Vec<usize> = (0..60).map(|j| (next + j) % arrivals.n).collect();
        next += 60;
        let ids = p.insert_batch(&arrivals.view().select(&idx)).unwrap();
        // Expire 60 arbitrary live rows (deterministic pick).
        let live: Vec<u64> = p.entries().iter().map(|&(id, _)| id).collect();
        let expire: Vec<u64> = live.iter().copied().step_by(live.len() / 60).take(60).collect();
        p.remove(&expire).unwrap();
        p.refine(30_000);
        assert_eq!(p.len(), 1_200, "round {round}");
        assert!(!ids.is_empty());
    }
    assert_eq!(p.objective(), p.recompute_objective());
    let sizes = p.sizes();
    assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    let current = p.to_dataset("soak-current").unwrap();
    let fresh = session.partition(&current, 12).unwrap();
    let maintained = p.objective();
    assert!(
        maintained >= 0.75 * fresh.objective,
        "maintained {maintained} collapsed vs fresh {}",
        fresh.objective
    );
}
