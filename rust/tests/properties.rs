//! Property-based tests over the system's core invariants, driven by the
//! in-repo deterministic PropRunner (no proptest in the offline vendor
//! set; failures print a replayable seed).

use aba::algo::objective::pairwise_within_brute;
use aba::algo::{run_hierarchical, AbaConfig, ClusterStats};
use aba::assignment::{assignment_cost, brute, is_valid_assignment, solve_max, Lapjv, SolverKind};
use aba::data::synth::{generate, SynthKind};
use aba::prop_assert;
use aba::rng::Pcg32;
use aba::testing::PropRunner;
use aba::{Aba, Anticlusterer};

/// One-shot session helper for properties that only need labels.
fn aba_labels(ds: &aba::data::Dataset, k: usize) -> Result<Vec<u32>, String> {
    Ok(Aba::new()
        .map_err(|e| e.to_string())?
        .partition(ds, k)
        .map_err(|e| e.to_string())?
        .labels)
}

fn rand_dataset(rng: &mut Pcg32, max_n: usize, max_d: usize) -> aba::data::Dataset {
    let n = 4 + rng.gen_index(max_n - 4);
    let d = 1 + rng.gen_index(max_d);
    let kind = match rng.gen_index(4) {
        0 => SynthKind::Uniform,
        1 => SynthKind::GaussianMixture { components: 1 + rng.gen_index(6), spread: 4.0 },
        2 => SynthKind::Binary { p: 0.3 },
        _ => SynthKind::HeavyTail,
    };
    generate(kind, n, d, rng.next_u64(), "prop")
}

#[test]
fn prop_aba_partition_is_valid_and_balanced() {
    PropRunner::new(40).run("aba balanced partition", |rng| {
        let ds = rand_dataset(rng, 300, 8);
        let k = 1 + rng.gen_index(ds.n.min(40));
        let labels = aba_labels(&ds, k)?;
        prop_assert!(labels.len() == ds.n, "label length");
        prop_assert!(labels.iter().all(|&l| (l as usize) < k), "label range");
        let stats = ClusterStats::compute(&ds, &labels, k);
        let (min, max) = (
            *stats.sizes.iter().min().unwrap(),
            *stats.sizes.iter().max().unwrap(),
        );
        prop_assert!(max - min <= 1, "sizes n={} k={k}: {:?}", ds.n, stats.sizes);
        Ok(())
    });
}

#[test]
fn prop_fact1_holds_for_aba_partitions() {
    PropRunner::new(20).run("fact 1 equivalence", |rng| {
        let ds = rand_dataset(rng, 80, 5);
        let k = 2 + rng.gen_index(5.min(ds.n - 2));
        let labels = aba_labels(&ds, k)?;
        let stats = ClusterStats::compute(&ds, &labels, k);
        let pairwise = pairwise_within_brute(&ds, &labels, k);
        let fact1 = stats.pairwise_total();
        let rel = (pairwise - fact1).abs() / pairwise.max(1.0);
        prop_assert!(rel < 1e-6, "pairwise {pairwise} vs fact1 {fact1}");
        Ok(())
    });
}

#[test]
fn prop_lapjv_optimal_vs_brute() {
    PropRunner::new(60).run("lapjv optimality", |rng| {
        let nr = 1 + rng.gen_index(7);
        let nc = nr + rng.gen_index(4);
        // Mix of scales, negatives, and ties.
        let scale = [0.001f32, 1.0, 1000.0][rng.gen_index(3)];
        let cost: Vec<f32> = (0..nr * nc)
            .map(|_| (rng.f32() - 0.3) * scale)
            .collect();
        let got = Lapjv::new().solve(&cost, nr, nc, true);
        prop_assert!(is_valid_assignment(&got, nc), "validity");
        let want = brute::solve_max(&cost, nr, nc);
        let (gc, wc) = (
            assignment_cost(&cost, nc, &got),
            assignment_cost(&cost, nc, &want),
        );
        prop_assert!(
            (gc - wc).abs() <= 1e-4 * wc.abs().max(1.0),
            "lapjv {gc} vs brute {wc} ({nr}x{nc})"
        );
        Ok(())
    });
}

#[test]
fn prop_lapjv_and_auction_match_brute_oracle() {
    // Solver-parity property: on random max-cost instances up to 7x9,
    // both exact solvers must reach the brute-force oracle's assignment
    // cost (auction is epsilon-scaled, hence the small tolerance).
    PropRunner::new(60).run("lapjv+auction vs brute", |rng| {
        let nr = 1 + rng.gen_index(7); // <= 7 rows
        let nc = nr + rng.gen_index(10 - nr); // <= 9 columns
        let scale = [0.01f32, 1.0, 100.0][rng.gen_index(3)];
        let cost: Vec<f32> = (0..nr * nc).map(|_| (rng.f32() - 0.4) * scale).collect();
        let want = brute::solve_max(&cost, nr, nc);
        let wc = assignment_cost(&cost, nc, &want);
        for kind in [SolverKind::Lapjv, SolverKind::Auction] {
            let got = solve_max(kind, &cost, nr, nc);
            prop_assert!(is_valid_assignment(&got, nc), "{kind:?} validity ({nr}x{nc})");
            let gc = assignment_cost(&cost, nc, &got);
            prop_assert!(
                (gc - wc).abs() <= 1e-3 * wc.abs().max(1.0),
                "{kind:?} {gc} vs brute {wc} ({nr}x{nc})"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_partition_objective_matches_recomputed_stats() {
    // The rich Partition must be self-consistent: objective, pairwise,
    // and sizes all equal a fresh ClusterStats recomputation from its
    // own labels.
    PropRunner::new(25).run("partition objective consistency", |rng| {
        let ds = rand_dataset(rng, 200, 6);
        let k = 1 + rng.gen_index(12.min(ds.n));
        let part = Aba::new()
            .map_err(|e| e.to_string())?
            .partition(&ds, k)
            .map_err(|e| e.to_string())?;
        let stats = ClusterStats::compute(&ds, &part.labels, k);
        let tol = 1e-9 * part.objective.abs().max(1.0);
        prop_assert!(
            (part.objective - stats.ssd_total()).abs() <= tol,
            "objective {} vs recomputed {}",
            part.objective,
            stats.ssd_total()
        );
        prop_assert!(
            (part.pairwise - stats.pairwise_total()).abs()
                <= 1e-9 * part.pairwise.abs().max(1.0),
            "pairwise {} vs recomputed {}",
            part.pairwise,
            stats.pairwise_total()
        );
        prop_assert!(part.sizes() == &stats.sizes[..], "sizes mismatch");
        Ok(())
    });
}

#[test]
fn prop_parallel_sessions_bit_identical_to_serial() {
    // The `Parallelism` knob must be invisible in the results: labels
    // and both objectives agree exactly between a serial session and a
    // 4-thread session, across the flat, explicit-hierarchical, and
    // categorical (§4.3) dispatch paths.
    use aba::runtime::Parallelism;
    PropRunner::new(12).run("serial == threads(4)", |rng| {
        let mut ds = rand_dataset(rng, 260, 6);
        let mode = rng.gen_index(3);
        let mut hier: Option<Vec<usize>> = None;
        match mode {
            1 => {
                let (k1, k2) = (2 + rng.gen_index(2), 2 + rng.gen_index(2));
                if k1 * k2 <= ds.n {
                    hier = Some(vec![k1, k2]);
                }
            }
            2 => {
                let g = 2 + rng.gen_index(3);
                let cats: Vec<u32> = (0..ds.n).map(|_| rng.gen_below(g as u32)).collect();
                ds = ds.with_categories(cats).map_err(|e| e.to_string())?;
            }
            _ => {}
        }
        let k: usize = match &hier {
            Some(spec) => spec.iter().product(),
            None => 1 + rng.gen_index(ds.n.min(24)),
        };
        let build = |par: Parallelism| -> Result<aba::Aba, String> {
            let mut b = Aba::builder().parallelism(par);
            if let Some(spec) = &hier {
                b = b.hier(spec.clone());
            }
            b.build().map_err(|e| e.to_string())
        };
        let a = build(Parallelism::Serial)?
            .partition(&ds, k)
            .map_err(|e| e.to_string())?;
        let b = build(Parallelism::Threads(4))?
            .partition(&ds, k)
            .map_err(|e| e.to_string())?;
        prop_assert!(
            a.labels == b.labels,
            "labels diverge (n={} k={k} mode={mode})",
            ds.n
        );
        prop_assert!(
            a.objective == b.objective,
            "objective {} vs {} (n={} k={k} mode={mode})",
            a.objective,
            b.objective,
            ds.n
        );
        prop_assert!(a.pairwise == b.pairwise, "pairwise diverges");
        Ok(())
    });
}

#[test]
fn parallel_constrained_partition_matches_serial() {
    // The must-link / cannot-link loop rides on the backend pool; it
    // must be exactly as deterministic as the serial path.
    use aba::algo::Constraints;
    use aba::runtime::Parallelism;
    let ds = generate(SynthKind::Uniform, 120, 4, 91, "cons");
    let cons = Constraints {
        must_link: vec![vec![0, 1, 2], vec![30, 40]],
        cannot_link: vec![(3, 4), (5, 99)],
    };
    let run = |par: Parallelism| {
        Aba::builder()
            .constraints(cons.clone())
            .parallelism(par)
            .build()
            .unwrap()
            .partition(&ds, 6)
            .unwrap()
            .labels
    };
    assert_eq!(run(Parallelism::Serial), run(Parallelism::Threads(4)));
}

#[test]
fn parallel_flat_large_k_matches_serial() {
    // Large enough that per-batch cost matrices cross the pooled
    // threshold (m * k * d = 256 * 256 * 8), so the chunk-parallel
    // kernel itself is exercised, not just the fan-out.
    use aba::runtime::Parallelism;
    let ds = generate(
        SynthKind::GaussianMixture { components: 8, spread: 3.0 },
        2_048,
        8,
        92,
        "big",
    );
    let run = |par: Parallelism| {
        let mut s = Aba::builder()
            .auto_hier(false)
            .parallelism(par)
            .build()
            .unwrap();
        s.partition(&ds, 256).unwrap()
    };
    let a = run(Parallelism::Serial);
    let b = run(Parallelism::Threads(4));
    assert_eq!(a.labels, b.labels);
    assert_eq!(a.objective, b.objective);
}

#[test]
fn prop_scalar_kernels_bit_identical_to_auto_selection() {
    // The Auto-selected vector kernels keep scalar `dot8`'s exact
    // reduction order (8 vertical lanes, same combine tree, same tail),
    // so forcing `KernelMode::Scalar` must not move a single bit — on
    // any host, across the flat, explicit-hierarchical, sparse
    // large-K, and online-bootstrap dispatch paths, serial and pooled.
    use aba::assignment::CandidateMode;
    use aba::runtime::{KernelMode, Parallelism};
    PropRunner::new(10).run("scalar kernels == auto kernels", |rng| {
        let ds = rand_dataset(rng, 280, 7);
        let mode = rng.gen_index(4);
        let par = if rng.gen_index(2) == 0 { Parallelism::Serial } else { Parallelism::Threads(3) };
        let mut hier: Option<Vec<usize>> = None;
        if mode == 1 {
            let (k1, k2) = (2 + rng.gen_index(2), 2 + rng.gen_index(2));
            if k1 * k2 <= ds.n {
                hier = Some(vec![k1, k2]);
            }
        }
        let k: usize = match &hier {
            Some(spec) => spec.iter().product(),
            None if mode == 2 => (8 + rng.gen_index(25)).min(ds.n),
            None => 1 + rng.gen_index(ds.n.min(24)),
        };
        let build = |km: KernelMode| -> Result<aba::Aba, String> {
            let mut b = Aba::builder().parallelism(par).kernels(km);
            if let Some(spec) = &hier {
                b = b.hier(spec.clone());
            }
            if mode == 2 {
                // Force the candidate-pruned sparse assignment path.
                b = b.auto_hier(false).candidates(CandidateMode::Fixed(4));
            }
            b.build().map_err(|e| e.to_string())
        };
        let solve = |km: KernelMode| -> Result<aba::Partition, String> {
            let mut s = build(km)?;
            if mode == 3 {
                // Online bootstrap: same labels contract as frozen.
                let live = s.partition_online(&ds.view(), k).map_err(|e| e.to_string())?;
                Ok(live.into_partition())
            } else {
                s.partition(&ds, k).map_err(|e| e.to_string())
            }
        };
        let auto = solve(KernelMode::Auto)?;
        let scalar = solve(KernelMode::Scalar)?;
        prop_assert!(scalar.timings.kernel_isa == "scalar", "forced mode ignored");
        prop_assert!(
            auto.labels == scalar.labels,
            "labels diverge (n={} k={k} mode={mode} isa={})",
            ds.n,
            auto.timings.kernel_isa
        );
        prop_assert!(
            auto.objective.to_bits() == scalar.objective.to_bits(),
            "objective {} vs {} (n={} k={k} mode={mode})",
            auto.objective,
            scalar.objective,
            ds.n
        );
        prop_assert!(auto.pairwise.to_bits() == scalar.pairwise.to_bits(), "pairwise diverges");
        Ok(())
    });
}

#[test]
fn prop_fast_math_objective_gap_stays_ppm_scale() {
    // The relaxed-determinism contract of `KernelMode::FastMath`: labels
    // may differ from the scalar reference (free reduction order flips
    // near-ties in the assignment step), but the partition must stay
    // valid and balanced and its objective must stay within ppm-scale of
    // scalar — across the flat, explicit-hierarchical, sparse large-K,
    // and online-bootstrap dispatch paths, serial and pooled. The
    // ceiling here is deliberately coarse (1%, i.e. 10^4 ppm, vs the
    // ~1-digit ppm gaps the bench records): random tiny datasets make
    // near-tie cascades worst-case, and the tight gate lives in
    // `BENCH_aba.json`'s kernel_e2e records, per the contract.
    use aba::assignment::CandidateMode;
    use aba::runtime::{KernelMode, Parallelism};
    PropRunner::new(10).run("fast-math objective gap in ppm", |rng| {
        let ds = rand_dataset(rng, 280, 7);
        let mode = rng.gen_index(4);
        let par = if rng.gen_index(2) == 0 { Parallelism::Serial } else { Parallelism::Threads(3) };
        let mut hier: Option<Vec<usize>> = None;
        if mode == 1 {
            let (k1, k2) = (2 + rng.gen_index(2), 2 + rng.gen_index(2));
            if k1 * k2 <= ds.n {
                hier = Some(vec![k1, k2]);
            }
        }
        let k: usize = match &hier {
            Some(spec) => spec.iter().product(),
            None if mode == 2 => (8 + rng.gen_index(25)).min(ds.n),
            None => 1 + rng.gen_index(ds.n.min(24)),
        };
        let solve = |km: KernelMode| -> Result<aba::Partition, String> {
            let mut b = Aba::builder().parallelism(par).kernels(km);
            if let Some(spec) = &hier {
                b = b.hier(spec.clone());
            }
            if mode == 2 {
                b = b.auto_hier(false).candidates(CandidateMode::Fixed(4));
            }
            let mut s = b.build().map_err(|e| e.to_string())?;
            if mode == 3 {
                let live = s.partition_online(&ds.view(), k).map_err(|e| e.to_string())?;
                Ok(live.into_partition())
            } else {
                s.partition(&ds, k).map_err(|e| e.to_string())
            }
        };
        let fast = solve(KernelMode::FastMath)?;
        let scalar = solve(KernelMode::Scalar)?;
        prop_assert!(!fast.timings.kernel_isa.is_empty(), "isa not stamped");
        prop_assert!(fast.labels.len() == ds.n, "label length");
        prop_assert!(fast.labels.iter().all(|&l| (l as usize) < k), "label range");
        let stats = ClusterStats::compute(&ds, &fast.labels, k);
        let (min, max) = (
            *stats.sizes.iter().min().unwrap(),
            *stats.sizes.iter().max().unwrap(),
        );
        prop_assert!(max - min <= 1, "balance n={} k={k} mode={mode}", ds.n);
        let gap_ppm =
            (fast.objective - scalar.objective).abs() / scalar.objective.max(1e-9) * 1e6;
        prop_assert!(
            gap_ppm <= 10_000.0,
            "objective gap {gap_ppm:.1} ppm (fast {} vs scalar {}, n={} k={k} mode={mode} isa={})",
            fast.objective,
            scalar.objective,
            ds.n,
            fast.timings.kernel_isa
        );
        Ok(())
    });
}

#[test]
fn prop_view_path_bit_identical_to_owned_copy_path() {
    // The zero-copy DataView path must be observationally identical to
    // materializing the same subset into an owned Dataset first: labels
    // and both objectives bit-equal, across the flat, hierarchical,
    // categorical, and constrained dispatch paths, under both serial
    // and threaded execution.
    use aba::algo::Constraints;
    use aba::runtime::Parallelism;
    PropRunner::new(6).run("view == owned copy", |rng| {
        let plain = rand_dataset(rng, 200, 5);
        if plain.n < 48 {
            return Ok(()); // need room for a >= 24-row subset
        }
        // Categorical twin of the same geometry (categories attached to
        // the *base*, so the view must indirect them too).
        let g = 2 + rng.gen_index(3);
        let cats: Vec<u32> = (0..plain.n).map(|_| rng.gen_below(g as u32)).collect();
        let catted = plain.clone().with_categories(cats).map_err(|e| e.to_string())?;
        // A random subset in shuffled order, at least 24 rows.
        let mut idx: Vec<usize> = (0..plain.n).collect();
        rng.shuffle(&mut idx);
        idx.truncate((24 + rng.gen_index(plain.n - 23)).min(plain.n));
        let m = idx.len();

        for par in [Parallelism::Serial, Parallelism::Threads(3)] {
            for mode in 0..4usize {
                let base = if mode == 2 { &catted } else { &plain };
                let (k, hier): (usize, Option<Vec<usize>>) = match mode {
                    1 => (4, Some(vec![2, 2])),
                    _ => (2 + rng.gen_index(6.min(m / 2)), None),
                };
                let build = || -> Result<aba::Aba, String> {
                    let mut b = Aba::builder().parallelism(par);
                    if let Some(spec) = &hier {
                        b = b.hier(spec.clone());
                    }
                    if mode == 3 {
                        b = b.constraints(Constraints {
                            must_link: vec![vec![0, 1]],
                            cannot_link: vec![(2, 3)],
                        });
                    }
                    b.build().map_err(|e| e.to_string())
                };
                let owned_ds = base.subset(&idx, "owned");
                let owned = build()?
                    .partition(&owned_ds, k)
                    .map_err(|e| e.to_string())?;
                let view = base.view().select(&idx);
                let viewed = build()?
                    .partition_view(&view, k)
                    .map_err(|e| e.to_string())?;
                prop_assert!(
                    owned.labels == viewed.labels,
                    "labels diverge (mode={mode} par={par:?} m={m} k={k})"
                );
                prop_assert!(
                    owned.objective == viewed.objective,
                    "objective {} vs {} (mode={mode} par={par:?})",
                    owned.objective,
                    viewed.objective
                );
                prop_assert!(
                    owned.pairwise == viewed.pairwise,
                    "pairwise diverges (mode={mode} par={par:?})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_full_candidates_bit_identical_to_dense() {
    // The sparse knob at C = k is *defined* as "no pruning": a session
    // with full candidate lists must take the literal dense code path,
    // so labels and objectives are bit-identical to an explicitly dense
    // session across the flat, hierarchical, categorical, and
    // constrained dispatch paths, under serial and threaded execution.
    // (On the constrained path the knob does not apply at all — that
    // mode pins the documented no-op behaviour rather than exercising
    // the sparse machinery.)
    use aba::algo::Constraints;
    use aba::assignment::CandidateMode;
    use aba::runtime::Parallelism;
    PropRunner::new(6).run("candidates C=k == dense", |rng| {
        let plain = rand_dataset(rng, 200, 5);
        if plain.n < 48 {
            return Ok(());
        }
        let g = 2 + rng.gen_index(3);
        let cats: Vec<u32> = (0..plain.n).map(|_| rng.gen_below(g as u32)).collect();
        let catted = plain.clone().with_categories(cats).map_err(|e| e.to_string())?;

        for par in [Parallelism::Serial, Parallelism::Threads(3)] {
            for mode in 0..4usize {
                let ds = if mode == 2 { &catted } else { &plain };
                let (k, hier): (usize, Option<Vec<usize>>) = match mode {
                    1 => (4, Some(vec![2, 2])),
                    _ => (2 + rng.gen_index(6), None),
                };
                let build = |cand: CandidateMode| -> Result<aba::Aba, String> {
                    let mut b = Aba::builder().parallelism(par).candidates(cand);
                    if let Some(spec) = &hier {
                        b = b.hier(spec.clone());
                    }
                    if mode == 3 {
                        b = b.constraints(Constraints {
                            must_link: vec![vec![0, 1]],
                            cannot_link: vec![(2, 3)],
                        });
                    }
                    b.build().map_err(|e| e.to_string())
                };
                let dense = build(CandidateMode::Dense)?
                    .partition(ds, k)
                    .map_err(|e| e.to_string())?;
                let full = build(CandidateMode::Fixed(k))?
                    .partition(ds, k)
                    .map_err(|e| e.to_string())?;
                prop_assert!(
                    dense.labels == full.labels,
                    "labels diverge (mode={mode} par={par:?} k={k})"
                );
                prop_assert!(
                    dense.objective == full.objective,
                    "objective {} vs {} (mode={mode} par={par:?})",
                    dense.objective,
                    full.objective
                );
                prop_assert!(dense.pairwise == full.pairwise, "pairwise diverges");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_lapjv_matches_dense_lapjv_on_full_graphs() {
    // The CSR-aware LAPJV is exact: with every edge present (no
    // pruning) its assignment cost must equal the dense solver's, on
    // both access paths (dense wrapper and a materialized full CSR).
    use aba::assignment::sparse::{CsrCost, DenseCost, SparseLapjv};
    PropRunner::new(60).run("sparse lapjv exact", |rng| {
        let nr = 1 + rng.gen_index(7);
        let nc = nr + rng.gen_index(4);
        let scale = [0.01f32, 1.0, 100.0][rng.gen_index(3)];
        let cost: Vec<f32> = (0..nr * nc).map(|_| (rng.f32() - 0.4) * scale).collect();
        let want = Lapjv::new().solve(&cost, nr, nc, true);
        let wc = assignment_cost(&cost, nc, &want);

        let via_dense = SparseLapjv::new()
            .solve_max(&DenseCost { cost: &cost, nr, nc })
            .ok_or("full graph reported infeasible")?;
        prop_assert!(is_valid_assignment(&via_dense, nc), "validity (dense access)");
        let dc = assignment_cost(&cost, nc, &via_dense);
        prop_assert!(
            (dc - wc).abs() <= 1e-4 * wc.abs().max(1.0),
            "dense-access {dc} vs lapjv {wc} ({nr}x{nc})"
        );

        let mut row_ptr = vec![0usize];
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for i in 0..nr {
            for j in 0..nc {
                cols.push(j as u32);
                vals.push(cost[i * nc + j]);
            }
            row_ptr.push(cols.len());
        }
        let csr = CsrCost { row_ptr: &row_ptr, cols: &cols, vals: &vals, nc };
        let via_csr = SparseLapjv::new()
            .solve_max(&csr)
            .ok_or("full CSR reported infeasible")?;
        prop_assert!(is_valid_assignment(&via_csr, nc), "validity (csr access)");
        let cc = assignment_cost(&cost, nc, &via_csr);
        prop_assert!(
            (cc - wc).abs() <= 1e-4 * wc.abs().max(1.0),
            "csr {cc} vs lapjv {wc} ({nr}x{nc})"
        );
        Ok(())
    });
}

#[test]
fn prop_sparse_path_partitions_stay_valid_and_deterministic() {
    // With real pruning (C < k) the partition is an approximation, but
    // it must remain a *valid* balanced partition, identical between
    // serial and threaded runs, and no worse than random on the
    // pairwise objective.
    use aba::assignment::CandidateMode;
    use aba::runtime::Parallelism;
    PropRunner::new(10).run("sparse path validity", |rng| {
        let ds = rand_dataset(rng, 280, 6);
        if ds.n < 60 {
            return Ok(());
        }
        let k = 8 + rng.gen_index(8);
        let c = 2 + rng.gen_index(4); // genuinely pruned: c << k
        let build = |par: Parallelism| -> Result<aba::Aba, String> {
            Aba::builder()
                .auto_hier(false)
                .candidates(CandidateMode::Fixed(c))
                .parallelism(par)
                .build()
                .map_err(|e| e.to_string())
        };
        let a = build(Parallelism::Serial)?
            .partition(&ds, k)
            .map_err(|e| e.to_string())?;
        let b = build(Parallelism::Threads(3))?
            .partition(&ds, k)
            .map_err(|e| e.to_string())?;
        prop_assert!(a.labels == b.labels, "serial vs threads diverge (n={} k={k} c={c})", ds.n);
        let stats = ClusterStats::compute(&ds, &a.labels, k);
        let (min, max) = (
            *stats.sizes.iter().min().unwrap(),
            *stats.sizes.iter().max().unwrap(),
        );
        prop_assert!(max - min <= 1, "balance (n={} k={k} c={c}): {:?}", ds.n, stats.sizes);
        prop_assert!(stats.sizes.iter().sum::<usize>() == ds.n, "coverage");
        let rand = aba::baselines::random_part::random_partition(ds.n, k, rng.next_u64());
        let rand_w = ClusterStats::compute(&ds, &rand, k).pairwise_total();
        prop_assert!(
            a.pairwise >= rand_w * 0.98,
            "sparse {} vs random {} (n={} k={k} c={c})",
            a.pairwise,
            rand_w,
            ds.n
        );
        Ok(())
    });
}

#[test]
fn prop_hierarchical_proposition1() {
    PropRunner::new(25).run("proposition 1 sizes", |rng| {
        let ds = rand_dataset(rng, 400, 6);
        let k1 = 2 + rng.gen_index(4);
        let k2 = 2 + rng.gen_index(4);
        if k1 * k2 > ds.n {
            return Ok(());
        }
        let labels =
            run_hierarchical(&ds, &[k1, k2], &AbaConfig::default()).map_err(|e| e.to_string())?;
        let stats = ClusterStats::compute(&ds, &labels, k1 * k2);
        let (min, max) = (
            *stats.sizes.iter().min().unwrap(),
            *stats.sizes.iter().max().unwrap(),
        );
        prop_assert!(
            max - min <= 1,
            "n={} spec={k1}x{k2} sizes={:?}",
            ds.n,
            stats.sizes
        );
        prop_assert!(stats.sizes.iter().sum::<usize>() == ds.n, "coverage");
        Ok(())
    });
}

#[test]
fn prop_categorical_bounds_never_violated() {
    PropRunner::new(25).run("constraint (5)", |rng| {
        let base = rand_dataset(rng, 200, 5);
        let g = 2 + rng.gen_index(3);
        let cats: Vec<u32> = (0..base.n).map(|_| rng.gen_below(g as u32)).collect();
        let ds = base.with_categories(cats.clone()).map_err(|e| e.to_string())?;
        let k = 2 + rng.gen_index(8.min(ds.n / 2));
        let labels = aba_labels(&ds, k)?;
        for cat in 0..g as u32 {
            let total = cats.iter().filter(|&&c| c == cat).count();
            let (lo, hi) = (total / k, total.div_ceil(k));
            for cl in 0..k as u32 {
                let cnt = (0..ds.n)
                    .filter(|&i| labels[i] == cl && cats[i] == cat)
                    .count();
                prop_assert!(
                    (lo..=hi).contains(&cnt),
                    "cat {cat} cluster {cl}: {cnt} not in [{lo},{hi}] (n={} k={k} g={g})",
                    ds.n
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_aba_never_worse_than_random_on_pairwise_objective() {
    PropRunner::new(20).run("aba >= random", |rng| {
        let ds = rand_dataset(rng, 250, 6);
        let k = 2 + rng.gen_index(10.min(ds.n / 4).max(1));
        let aba = aba_labels(&ds, k)?;
        let aba_w = ClusterStats::compute(&ds, &aba, k).pairwise_total();
        let rand = aba::baselines::random_part::random_partition(ds.n, k, rng.next_u64());
        let rand_w = ClusterStats::compute(&ds, &rand, k).pairwise_total();
        // Allow a hair of slack: on structureless data the two can tie.
        prop_assert!(
            aba_w >= rand_w * 0.999,
            "aba {aba_w} vs random {rand_w} (n={} k={k})",
            ds.n
        );
        Ok(())
    });
}

#[test]
fn prop_exchange_preserves_balance_and_never_decreases_objective() {
    PropRunner::new(15).run("exchange invariants", |rng| {
        use aba::baselines::exchange::{fast_anticlustering, ExchangeConfig};
        let ds = rand_dataset(rng, 150, 5);
        let k = 2 + rng.gen_index(6.min(ds.n / 3).max(1));
        let seed = rng.next_u64();
        let res = fast_anticlustering(&ds, k, &ExchangeConfig::random(10, seed));
        let stats = ClusterStats::compute(&ds, &res.labels, k);
        let (min, max) = (
            *stats.sizes.iter().min().unwrap(),
            *stats.sizes.iter().max().unwrap(),
        );
        prop_assert!(max - min <= 1, "balance: {:?}", stats.sizes);
        Ok(())
    });
}

#[test]
fn prop_batch_orders_are_permutations() {
    use aba::algo::batching::{rearrange_categorical, rearrange_small};
    PropRunner::new(60).run("rearrangements permute", |rng| {
        let n = 2 + rng.gen_index(300);
        let k = 1 + rng.gen_index(n);
        let sorted: Vec<usize> = (0..n).collect();
        let small = rearrange_small(&sorted, k);
        let mut s = small.clone();
        s.sort_unstable();
        prop_assert!(s == sorted, "small not a permutation (n={n} k={k})");
        let g = 1 + rng.gen_index(5);
        let cats: Vec<u32> = (0..n).map(|_| rng.gen_below(g as u32)).collect();
        let cat = rearrange_categorical(&sorted, &cats, k);
        let mut c = cat.clone();
        c.sort_unstable();
        prop_assert!(c == sorted, "categorical not a permutation (n={n} k={k} g={g})");
        Ok(())
    });
}

#[test]
fn prop_kmeans_labels_dense_and_deterministic() {
    PropRunner::new(15).run("kmeans sanity", |rng| {
        let ds = rand_dataset(rng, 150, 4);
        let k = 1 + rng.gen_index(6.min(ds.n));
        let seed = rng.next_u64();
        let a = aba::data::kmeans::kmeans(&ds, k, 20, seed);
        let b = aba::data::kmeans::kmeans(&ds, k, 20, seed);
        prop_assert!(a.labels == b.labels, "determinism");
        prop_assert!(a.labels.iter().all(|&l| (l as usize) < k), "range");
        prop_assert!(a.inertia.is_finite() && a.inertia >= 0.0, "inertia");
        Ok(())
    });
}

#[test]
fn prop_graph_partition_valid_and_cut_bounded() {
    use aba::graph::builder::random_neighbor_graph;
    use aba::graph::metis_like::{partition, PartitionConfig};
    PropRunner::new(10).run("metis-like validity", |rng| {
        let ds = rand_dataset(rng, 200, 4);
        let k = 2 + rng.gen_index(6);
        if k > ds.n / 4 {
            return Ok(());
        }
        let g = random_neighbor_graph(&ds, 8, rng.next_u64());
        let part = partition(&g, &PartitionConfig::new(k));
        prop_assert!(part.len() == g.n, "length");
        prop_assert!(part.iter().all(|&p| (p as usize) < k), "range");
        let total: u64 = g.w.iter().sum::<u64>() / 2;
        prop_assert!(g.cut_cost(&part) <= total, "cut bounded by total weight");
        Ok(())
    });
}
