//! End-to-end tests for the `aba serve` subsystem: full HTTP lifecycle,
//! evict → snapshot → warm-restart bit-identity, fingerprint-mismatch
//! conflicts, concurrent handle operations, shard-merge invariants, and
//! queue backpressure.

use aba::algo::objective::ClusterStats;
use aba::algo::AbaConfig;
use aba::assignment::SolverKind;
use aba::data::synth::{generate, SynthKind};
use aba::data::Dataset;
use aba::online::inspect_snapshot;
use aba::runtime::Parallelism;
use aba::serve::metrics::Metrics;
use aba::serve::registry::Registry;
use aba::serve::shard::solve_sharded;
use aba::serve::{ServeConfig, Server};
use aba::util::json::{self, Json};
use aba::{Aba, Anticlusterer};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;

/// A per-test snapshot directory, wiped on entry.
fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("aba_serve_it_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn base_cfg() -> AbaConfig {
    AbaConfig { auto_hier: false, ..AbaConfig::default() }
}

/// Headered CSV (`f0..f{d-1}`) for a dataset, as the service expects.
fn csv_of(ds: &Dataset) -> String {
    let header: Vec<String> = (0..ds.d).map(|j| format!("f{j}")).collect();
    let mut out = header.join(",");
    out.push('\n');
    for i in 0..ds.n {
        let cells: Vec<String> = ds.row(i).iter().map(|v| format!("{v}")).collect();
        out.push_str(&cells.join(","));
        out.push('\n');
    }
    out
}

fn jobj(pairs: Vec<(&str, Json)>) -> String {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    json::to_string(&Json::Obj(m))
}

/// One-shot HTTP exchange; returns (status, raw response, body text).
fn request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    let raw = format!(
        "{method} {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(raw.as_bytes()).unwrap();
    let mut text = String::new();
    s.read_to_string(&mut text).unwrap();
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("unparseable response: {text}"));
    let body_start = text.find("\r\n\r\n").map(|p| p + 4).unwrap_or(text.len());
    let resp_body = text[body_start..].to_string();
    (status, text, resp_body)
}

fn parse_json(body: &str) -> Json {
    json::parse(body).unwrap_or_else(|e| panic!("bad JSON response '{body}': {e}"))
}

#[test]
fn serve_lifecycle_end_to_end() {
    let dir = fresh_dir("lifecycle");
    let server = Server::start(ServeConfig {
        workers: 2,
        snapshot_dir: dir.clone(),
        cfg: base_cfg(),
        ..ServeConfig::default()
    })
    .unwrap();
    let addr = server.addr();

    // Create: 48 rows into k=4 anticlusters from inline CSV.
    let ds = generate(SynthKind::Uniform, 48, 3, 21, "alpha");
    let body = jobj(vec![
        ("id", Json::Str("alpha".into())),
        ("k", Json::Num(4.0)),
        ("csv", Json::Str(csv_of(&ds))),
    ]);
    let (status, _, resp) = request(addr, "POST", "/v1/partitions", &body);
    assert_eq!(status, 201, "{resp}");
    let created = parse_json(&resp);
    assert_eq!(created.get("n").and_then(Json::as_usize), Some(48));
    assert_eq!(created.get("k").and_then(Json::as_usize), Some(4));
    let created_gap = created.get("gap").and_then(Json::as_f64).unwrap();
    assert!((0.0..=1.0).contains(&created_gap), "gap {created_gap}");

    // Duplicate id is a conflict, not a clobber.
    let (status, _, _) = request(addr, "POST", "/v1/partitions", &body);
    assert_eq!(status, 409);

    // Insert 8 arrivals; the response carries their stable ids.
    let arrivals = generate(SynthKind::Uniform, 8, 3, 22, "arrivals");
    let body = jobj(vec![("csv", Json::Str(csv_of(&arrivals)))]);
    let (status, _, resp) = request(addr, "POST", "/v1/partitions/alpha/insert", &body);
    assert_eq!(status, 200, "{resp}");
    let inserted = parse_json(&resp);
    let ids: Vec<f64> = inserted
        .get("ids")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    assert_eq!(ids.len(), 8);
    assert_eq!(inserted.get("n").and_then(Json::as_usize), Some(56));

    // Remove the first 4 of them.
    let body = jobj(vec![(
        "ids",
        Json::Arr(ids[..4].iter().map(|&i| Json::Num(i)).collect()),
    )]);
    let (status, _, resp) = request(addr, "POST", "/v1/partitions/alpha/remove", &body);
    assert_eq!(status, 200, "{resp}");
    assert_eq!(parse_json(&resp).get("n").and_then(Json::as_usize), Some(52));

    // Refine with a small budget reports its swap accounting.
    let (status, _, resp) = request(addr, "POST", "/v1/partitions/alpha/refine", "{}");
    assert_eq!(status, 200, "{resp}");
    assert!(parse_json(&resp).get("evaluated").is_some());

    // Read back: balanced sizes summing to n, one label per row.
    let (status, _, resp) = request(addr, "GET", "/v1/partitions/alpha", "");
    assert_eq!(status, 200, "{resp}");
    let got = parse_json(&resp);
    assert_eq!(got.get("n").and_then(Json::as_usize), Some(52));
    let sizes: Vec<usize> = got
        .get("sizes")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();
    assert_eq!(sizes.iter().sum::<usize>(), 52);
    assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    assert_eq!(got.get("labels").and_then(Json::as_arr).unwrap().len(), 52);

    // Quality certificate: the served bound dominates the served
    // objective and the gap is a valid fraction.
    let obj = got.get("objective").and_then(Json::as_f64).unwrap();
    let ub = got.get("upper_bound").and_then(Json::as_f64).unwrap();
    let gap = got.get("gap").and_then(Json::as_f64).unwrap();
    assert!(ub >= obj, "bound {ub} below objective {obj}");
    assert!((0.0..=1.0).contains(&gap), "gap {gap}");

    // Unknown partitions are 404, unknown routes too.
    assert_eq!(request(addr, "GET", "/v1/partitions/ghost", "").0, 404);
    assert_eq!(request(addr, "GET", "/v1/nope", "").0, 404);

    // Metrics is plain text with the service counters.
    let (status, _, resp) = request(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    assert!(resp.contains("aba_requests_total"), "{resp}");
    assert!(resp.contains("aba_handles 1"), "{resp}");
    // Gap telemetry: create + get each observed one gap.
    assert!(resp.contains("aba_gap_observations 2"), "{resp}");
    assert!(resp.contains("aba_gap_last_ppm"), "{resp}");
    // The kernel gauge carries a concrete ISA token, never empty.
    let isa_line = resp.lines().find(|l| l.starts_with("aba_kernel_isa")).unwrap();
    assert!(
        ["scalar", "avx2", "avx2+fma", "avx512f", "neon"]
            .contains(&isa_line.trim_start_matches("aba_kernel_isa").trim()),
        "{isa_line}"
    );

    // Drain: stop accepting, snapshot the resident handle, exit.
    let (status, _, resp) = request(addr, "POST", "/v1/admin/drain", "");
    assert_eq!(status, 200, "{resp}");
    let written = server.wait().unwrap();
    assert_eq!(written, 1);
    let snap = dir.join("alpha.json");
    assert!(snap.exists());
    let info = inspect_snapshot(&snap).unwrap();
    assert_eq!(info.n, 52);
    assert_eq!(info.k, 4);
}

#[test]
fn evict_snapshot_warm_restart_bit_identity() {
    // Registry-level: capacity 1 forces an eviction, and the reloaded
    // handle must serialize bit-identically to the evicted one.
    let cfg = base_cfg();
    let metrics = Arc::new(Metrics::new());
    let reg = Registry::new(fresh_dir("evict"), 1, cfg.clone(), Arc::clone(&metrics)).unwrap();
    let mut session = Aba::from_config(cfg.clone()).unwrap();

    let ds = generate(SynthKind::GaussianMixture { components: 4, spread: 3.0 }, 60, 3, 31, "a");
    let mut live = session.partition_online(&ds.view(), 4).unwrap();
    // Churn before eviction so the snapshot carries non-trivial state.
    let arrivals = generate(SynthKind::Uniform, 6, 3, 32, "arr");
    let ids = live.insert_batch(&arrivals.view()).unwrap();
    live.remove(&ids[..2]).unwrap();
    live.refine(5_000);
    let reference = live.snapshot_string();
    reg.insert("a", live).unwrap();

    let ds_b = generate(SynthKind::Uniform, 40, 3, 33, "b");
    let live_b = session.partition_online(&ds_b.view(), 4).unwrap();
    reg.insert("b", live_b).unwrap();
    assert!(reg.snapshot_path("a").exists(), "capacity-1 insert must evict 'a'");

    let back = reg.get_or_load("a").unwrap().unwrap();
    assert_eq!(back.lock().unwrap().snapshot_string(), reference);
}

#[test]
fn fingerprint_mismatch_is_http_409() {
    let dir = fresh_dir("fp409");
    std::fs::create_dir_all(&dir).unwrap();
    // Snapshot written under a Greedy-solver config...
    let greedy = AbaConfig { solver: SolverKind::Greedy, ..base_cfg() };
    let ds = generate(SynthKind::Uniform, 40, 3, 41, "m");
    Aba::from_config(greedy.clone())
        .unwrap()
        .partition_online(&ds.view(), 4)
        .unwrap()
        .save(dir.join("mismatch.json"))
        .unwrap();
    // ... served under the default (LAPJV) config is a conflict.
    let server = Server::start(ServeConfig {
        workers: 1,
        snapshot_dir: dir,
        cfg: base_cfg(),
        ..ServeConfig::default()
    })
    .unwrap();
    let (status, _, resp) = request(server.addr(), "GET", "/v1/partitions/mismatch", "");
    assert_eq!(status, 409, "{resp}");
    assert!(resp.contains("fingerprint") || resp.contains("snapshot"), "{resp}");
    server.drain().unwrap();
}

#[test]
fn concurrent_ops_on_distinct_partitions_match_serial() {
    // The server runs its solves under Threads(3); a local Serial
    // session doing the identical operations must agree bit-for-bit
    // (pool determinism), including across concurrent HTTP clients.
    let threaded = AbaConfig { parallelism: Parallelism::Threads(3), ..base_cfg() };
    let server = Server::start(ServeConfig {
        workers: 3,
        snapshot_dir: fresh_dir("conc"),
        cfg: threaded,
        ..ServeConfig::default()
    })
    .unwrap();
    let addr = server.addr();

    let ds = generate(SynthKind::GaussianMixture { components: 5, spread: 2.5 }, 90, 4, 51, "c");
    let arrivals = generate(SynthKind::Uniform, 9, 4, 52, "carr");
    let create_body = |id: &str| {
        jobj(vec![
            ("id", Json::Str(id.into())),
            ("k", Json::Num(3.0)),
            ("csv", Json::Str(csv_of(&ds))),
        ])
    };
    // Create "a" and "b" from two threads at once.
    let handles: Vec<_> = ["a", "b"]
        .into_iter()
        .map(|id| {
            let body = create_body(id);
            std::thread::spawn(move || request(addr, "POST", "/v1/partitions", &body).0)
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().unwrap(), 201);
    }
    // Concurrent inserts of the same arrivals into both partitions.
    let insert_body = jobj(vec![("csv", Json::Str(csv_of(&arrivals)))]);
    let handles: Vec<_> = ["a", "b"]
        .into_iter()
        .map(|id| {
            let body = insert_body.clone();
            std::thread::spawn(move || {
                request(addr, "POST", &format!("/v1/partitions/{id}/insert"), &body).0
            })
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().unwrap(), 200);
    }

    // Local reference: identical ops under Serial.
    let mut session = Aba::from_config(base_cfg()).unwrap();
    let mut reference = session.partition_online(&ds.view(), 3).unwrap();
    reference.insert_batch(&arrivals.view()).unwrap();
    let ref_sizes = reference.sizes();
    let ref_entries = reference.entries();
    let ref_obj = reference.objective();

    for id in ["a", "b"] {
        let (status, _, resp) = request(addr, "GET", &format!("/v1/partitions/{id}"), "");
        assert_eq!(status, 200, "{resp}");
        let got = parse_json(&resp);
        assert_eq!(got.get("n").and_then(Json::as_usize), Some(99));
        let sizes: Vec<usize> = got
            .get("sizes")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(sizes, ref_sizes);
        let labels: Vec<(u64, u32)> = got
            .get("labels")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|pair| {
                let p = pair.as_arr().unwrap();
                (p[0].as_f64().unwrap() as u64, p[1].as_f64().unwrap() as u32)
            })
            .collect();
        assert_eq!(labels, ref_entries, "partition '{id}' diverged from the serial reference");
        let obj = got.get("objective").and_then(Json::as_f64).unwrap();
        assert!(
            (obj - ref_obj).abs() <= 1e-6 * ref_obj.abs().max(1.0),
            "objective {obj} vs serial {ref_obj}"
        );
    }
    server.drain().unwrap();
}

#[test]
fn shard_merge_balanced_and_close_to_flat() {
    let ds = generate(SynthKind::GaussianMixture { components: 6, spread: 3.0 }, 200, 4, 61, "sh");
    let cfg = base_cfg();

    // Library-level invariants on >= 4 shards.
    let labels = solve_sharded(&ds.view(), 5, 4, &cfg).unwrap();
    assert_eq!(labels.len(), 200);
    let mut sizes = vec![0usize; 5];
    for &l in &labels {
        assert!(l < 5);
        sizes[l as usize] += 1;
    }
    assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1, "{sizes:?}");
    let sharded_obj = ClusterStats::compute(ds.view(), &labels, 5).ssd_total();
    let flat = Aba::from_config(cfg.clone()).unwrap().partition_view(&ds.view(), 5).unwrap();
    let flat_obj = ClusterStats::compute(ds.view(), &flat.labels, 5).ssd_total();
    assert!(
        sharded_obj >= 0.9 * flat_obj,
        "shard-merge objective {sharded_obj} below 0.9x flat {flat_obj}"
    );

    // The fan-out is a wall-clock knob only.
    let threaded = AbaConfig { parallelism: Parallelism::Threads(3), ..cfg.clone() };
    assert_eq!(labels, solve_sharded(&ds.view(), 5, 4, &threaded).unwrap());

    // And the HTTP create path accepts `"shards": 4`.
    let server = Server::start(ServeConfig {
        workers: 1,
        snapshot_dir: fresh_dir("shards"),
        cfg,
        ..ServeConfig::default()
    })
    .unwrap();
    let body = jobj(vec![
        ("id", Json::Str("sharded".into())),
        ("k", Json::Num(5.0)),
        ("shards", Json::Num(4.0)),
        ("csv", Json::Str(csv_of(&ds))),
    ]);
    let (status, _, resp) = request(server.addr(), "POST", "/v1/partitions", &body);
    assert_eq!(status, 201, "{resp}");
    assert_eq!(parse_json(&resp).get("n").and_then(Json::as_usize), Some(200));
    server.drain().unwrap();
}

#[test]
fn pareto_endpoint_returns_front_and_counts_in_metrics() {
    let server = Server::start(ServeConfig {
        workers: 1,
        snapshot_dir: fresh_dir("pareto"),
        cfg: base_cfg(),
        ..ServeConfig::default()
    })
    .unwrap();
    let addr = server.addr();

    let ds = generate(SynthKind::GaussianMixture { components: 4, spread: 3.0 }, 48, 3, 71, "pf");
    let body = jobj(vec![
        ("id", Json::Str("pf".into())),
        ("k", Json::Num(4.0)),
        ("csv", Json::Str(csv_of(&ds))),
    ]);
    let (status, _, resp) = request(addr, "POST", "/v1/partitions", &body);
    assert_eq!(status, 201, "{resp}");
    let served_obj = parse_json(&resp).get("objective").and_then(Json::as_f64).unwrap();

    let body = jobj(vec![("restarts", Json::Num(5.0)), ("seed", Json::Num(9.0))]);
    let (status, _, resp) = request(addr, "POST", "/v1/partitions/pf/pareto", &body);
    assert_eq!(status, 200, "{resp}");
    let got = parse_json(&resp);
    let front_size = got.get("front_size").and_then(Json::as_usize).unwrap();
    assert!(front_size >= 1, "{resp}");
    assert!(got.get("hypervolume").and_then(Json::as_f64).unwrap() > 0.0, "{resp}");
    let front = got.get("front").and_then(Json::as_arr).unwrap();
    assert_eq!(front.len(), front_size);
    for p in front {
        let div = p.get("diversity").and_then(Json::as_f64).unwrap();
        let ub = p.get("upper_bound").and_then(Json::as_f64).unwrap();
        let gap = p.get("gap").and_then(Json::as_f64).unwrap();
        assert!(ub >= div, "bound {ub} below diversity {div}");
        assert!((0.0..=1.0).contains(&gap), "gap {gap}");
    }
    // Restart 0 seeds from the handle's own labels, so the front's
    // diversity extreme weakly dominates the served partition's point.
    let best_div = front[0].get("diversity").and_then(Json::as_f64).unwrap();
    assert!(
        best_div >= served_obj * (1.0 - 1e-9),
        "front diversity {best_div} below served objective {served_obj}"
    );

    // A balanced k=4 split of 7 rows has a singleton cluster, so the
    // dispersion criterion is degenerate — a typed 400, not a crash.
    let tiny = generate(SynthKind::Uniform, 7, 3, 72, "tiny");
    let body = jobj(vec![
        ("id", Json::Str("tiny".into())),
        ("k", Json::Num(4.0)),
        ("csv", Json::Str(csv_of(&tiny))),
    ]);
    assert_eq!(request(addr, "POST", "/v1/partitions", &body).0, 201);
    let (status, _, resp) = request(addr, "POST", "/v1/partitions/tiny/pareto", "{}");
    assert_eq!(status, 400, "{resp}");
    assert!(resp.contains("dispersion"), "{resp}");

    let (status, _, resp) = request(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    assert!(resp.contains("aba_pareto_requests_total 1"), "{resp}");
    assert!(resp.contains("aba_pareto_restarts_total 5"), "{resp}");
    assert!(resp.contains(&format!("aba_pareto_front_size_last {front_size}")), "{resp}");
    server.drain().unwrap();
}

#[test]
fn backpressure_returns_429_with_retry_after() {
    // One slow worker (300 ms per request) and a queue of one: a burst
    // of six concurrent requests must overflow into 429s.
    let server = Server::start(ServeConfig {
        workers: 1,
        queue: 1,
        test_delay_ms: 300,
        snapshot_dir: fresh_dir("bp"),
        cfg: base_cfg(),
        ..ServeConfig::default()
    })
    .unwrap();
    let addr = server.addr();
    let handles: Vec<_> = (0..6)
        .map(|_| std::thread::spawn(move || request(addr, "GET", "/healthz", "")))
        .collect();
    let results: Vec<(u16, String, String)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    let ok = results.iter().filter(|(s, _, _)| *s == 200).count();
    let rejected: Vec<&(u16, String, String)> =
        results.iter().filter(|(s, _, _)| *s == 429).collect();
    assert!(ok >= 1, "no request got through");
    assert!(!rejected.is_empty(), "burst of 6 into queue=1 produced no 429");
    for (_, raw, _) in &results {
        if raw.starts_with("HTTP/1.1 429") {
            assert!(raw.contains("Retry-After:"), "{raw}");
        }
    }
    assert!(server.metrics().rejected_429.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    server.drain().unwrap();
}
