//! Property-testing driver.
//!
//! The offline vendor set has no `proptest`, so this is a small
//! deterministic stand-in: each property runs over `cases` seeds derived
//! from a root seed; failures report the seed so they can be replayed
//! exactly (`PropRunner::replay`).

use crate::rng::Pcg32;

pub mod oracle;

/// Runs a property over many deterministic seeds.
pub struct PropRunner {
    root_seed: u64,
    cases: usize,
}

impl PropRunner {
    pub fn new(cases: usize) -> Self {
        Self { root_seed: 0xABA0_BA5E, cases }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.root_seed = seed;
        self
    }

    /// Run `prop` with a fresh RNG per case; panics (with the case seed)
    /// on the first failure.
    pub fn run(&self, name: &str, mut prop: impl FnMut(&mut Pcg32) -> Result<(), String>) {
        for case in 0..self.cases {
            let seed = self.root_seed.wrapping_add(case as u64);
            let mut rng = Pcg32::new(seed);
            if let Err(msg) = prop(&mut rng) {
                panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
            }
        }
    }

    /// Re-run a single failing seed.
    pub fn replay(
        seed: u64,
        name: &str,
        mut prop: impl FnMut(&mut Pcg32) -> Result<(), String>,
    ) {
        let mut rng = Pcg32::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on replay (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert-like helper returning `Err` instead of panicking, for use
/// inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        PropRunner::new(25).run("trivial", |rng| {
            count += 1;
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        PropRunner::new(5).run("fails", |rng| {
            let x = rng.f64();
            if x < 2.0 {
                Err("always".to_string())
            } else {
                Ok(())
            }
        });
    }
}
