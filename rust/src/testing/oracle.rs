//! Ground-truth oracles for tiny instances.
//!
//! The exact K=2 dispersion solver ([`crate::cert::two_color`]) is
//! itself polynomial and serves as the production fast path; this
//! module provides the *independent* brute-force reference the test
//! suite checks it against, in the same spirit as the exhaustive
//! checks in [`crate::baselines::ExactSolver`]'s tests: enumerate
//! every cardinality-feasible 2-partition by bitmask and take the
//! best. Exponential — guarded to `n <= 20`.

use crate::data::DataView;

/// Exhaustive K=2 dispersion optimum for `view` with exactly `m0`
/// objects in group 0: returns `(dispersion, labels)` maximizing the
/// minimum within-group squared distance (`f64::INFINITY` when both
/// groups are singletons). Panics on `n > 20` (the search is
/// `C(n, m0)` subsets) or infeasible `m0`.
pub fn dispersion_k2_exhaustive(view: &DataView, m0: usize) -> (f64, Vec<u32>) {
    let n = view.n();
    assert!((2..=20).contains(&n), "exhaustive oracle is for 2 <= n <= 20, got n={n}");
    assert!((1..n).contains(&m0), "need 1 <= m0 <= n-1, got m0={m0}");

    let mut dist = vec![0f64; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let d2 = view.dist2(i, j);
            dist[i * n + j] = d2;
            dist[j * n + i] = d2;
        }
    }

    let mut best = f64::NEG_INFINITY;
    let mut best_mask = 0u32;
    for mask in 0u32..(1u32 << n) {
        if mask.count_ones() as usize != m0 {
            continue;
        }
        // Dispersion of this split: min distance over same-side pairs.
        let mut disp = f64::INFINITY;
        for i in 0..n {
            for j in (i + 1)..n {
                if (mask >> i) & 1 == (mask >> j) & 1 {
                    disp = disp.min(dist[i * n + j]);
                }
            }
        }
        if disp > best {
            best = disp;
            best_mask = mask;
        }
    }

    let labels = (0..n)
        .map(|i| if (best_mask >> i) & 1 == 1 { 0u32 } else { 1u32 })
        .collect();
    (best, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cert::two_color;
    use crate::data::Dataset;

    #[test]
    fn oracle_agrees_with_the_coloring_solver_on_a_line() {
        let rows: Vec<Vec<f32>> = [0.0f32, 1.0, 10.0, 11.0, 20.0, 21.0]
            .iter()
            .map(|&x| vec![x])
            .collect();
        let ds = Dataset::from_rows("line6", &rows).unwrap();
        let (opt, labels) = dispersion_k2_exhaustive(&ds.view(), 3);
        assert_eq!(labels.iter().filter(|&&l| l == 0).count(), 3);
        let fast = two_color::solve_balanced(&ds.view()).unwrap();
        assert_eq!(fast.dispersion, opt);
    }

    #[test]
    fn two_point_instance_is_infinite() {
        let ds = Dataset::from_rows("pair", &[vec![0.0f32], vec![5.0]]).unwrap();
        let (opt, _) = dispersion_k2_exhaustive(&ds.view(), 1);
        assert!(opt.is_infinite());
    }
}
