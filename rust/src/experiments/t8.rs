//! Table 8: very large K on the imagenet32 stand-in, with hierarchical
//! decomposition (Table 7 settings derived automatically).
//!
//! The paper sweeps K = 10k … 640k on N = 1,281,167 so the smallest
//! anticlusters have 2–3 objects; the scaled-down sweep keeps the same
//! *min-size* progression (128 → 2) on N = 131,072. Only Rand can keep up
//! as a benchmark (as in the paper); the expected shape is ABA's
//! advantage growing as K grows, reaching tens of percent at min size 2.

use super::common::{run_algo, Algo, ExpOptions};
use crate::algo::{effective_spec, AbaConfig};
use crate::data::synth::{load, Scale};
use crate::util::fmt_secs;
use crate::util::table::Table;
use anyhow::Result;

/// K sweep preserving the paper's min-size progression on the scaled N.
pub fn k_sweep(n: usize, quick: bool) -> Vec<usize> {
    let sizes: &[usize] = if quick { &[128, 8, 2] } else { &[128, 64, 32, 16, 8, 4, 2] };
    sizes.iter().map(|&s| n / s).collect()
}

pub fn table8(opts: &ExpOptions) -> Result<Table> {
    let scale = if opts.quick { Scale::Tiny } else { opts.scale };
    let ds = load("imagenet32", scale)?;
    let ks = match opts.k {
        Some(k) => vec![k],
        None => k_sweep(ds.n, opts.quick),
    };
    let mut t = Table::new(
        format!(
            "Table 8 — huge-K sweep on {} (n={}, d={}) with hierarchical decomposition",
            ds.name, ds.n, ds.d
        ),
        &[
            "K", "spec", "min size", "max size", "cpu ABA [s]", "ofv ABA", "ofv Rand",
            "dev Rand [%]",
        ],
    );
    for k in ks {
        eprintln!("  [t8] k={k}");
        let cfg = AbaConfig::default();
        let spec = effective_spec(ds.n, k, &cfg)
            .map(|s| s.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("x"))
            .unwrap_or_else(|| "flat".into());
        let aba = run_algo(&ds, k, Algo::Aba, 0, opts.time_limit_secs)
            .expect("ABA completes");
        let stats = &aba.partition.stats;
        let ofv = aba.partition.objective;
        let rand = run_algo(&ds, k, Algo::Rand, 1, opts.time_limit_secs).unwrap();
        let rofv = rand.partition.objective;
        t.row(vec![
            k.to_string(),
            spec,
            stats.sizes.iter().min().unwrap().to_string(),
            stats.sizes.iter().max().unwrap().to_string(),
            fmt_secs(aba.secs),
            format!("{ofv:.1}"),
            format!("{rofv:.1}"),
            format!("{:.4}", crate::util::pct_dev(rofv, ofv)),
        ]);
    }
    t.save_csv(&opts.out_dir, "t8")?;
    println!("{}", t.render());
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_preserves_min_sizes() {
        let ks = k_sweep(131_072, false);
        assert_eq!(ks[0], 1024);
        assert_eq!(*ks.last().unwrap(), 65_536);
    }

    #[test]
    fn table8_quick_shape_and_monotonicity() {
        let opts = ExpOptions {
            quick: true,
            out_dir: std::env::temp_dir().join("aba_results_test"),
            ..ExpOptions::default()
        };
        let t = table8(&opts).unwrap();
        assert_eq!(t.rows.len(), 3);
        // Headline shape: Rand's deficit grows (more negative) with K.
        let devs: Vec<f64> = t.rows.iter().map(|r| r[7].parse::<f64>().unwrap()).collect();
        assert!(devs[0] <= 0.5, "{devs:?}");
        assert!(
            devs.last().unwrap() < &devs[0],
            "deviation should worsen with K: {devs:?}"
        );
        // Sizes respect the bound.
        for r in &t.rows {
            let (min, max): (usize, usize) = (r[2].parse().unwrap(), r[3].parse().unwrap());
            assert!(max - min <= 1);
        }
    }
}
