//! T4x — the paper's companion tables for K ∈ {2, 50, 500, 1000, 2000,
//! 5000} (published on the authors' GitHub alongside Table 4).
//!
//! Same row structure as Table 4, swept over K with the Table-5
//! hierarchical-decomposition policy applied automatically. The paper's
//! headline for this sweep: ABA's quality *advantage* over the exchange
//! heuristics grows with K while staying orders of magnitude faster.

use super::common::{dev_cell, quality_dev, run_algo, time_dev, Algo, ExpOptions};
use super::t4::dataset_list;
use crate::data::synth::{load, Scale};
use crate::util::fmt_secs;
use crate::util::table::Table;
use anyhow::Result;

/// The published K sweep; values exceeding N/2 are skipped per dataset.
pub const K_SWEEP: &[usize] = &[2, 50, 500, 1_000, 2_000, 5_000];

pub fn table4x(opts: &ExpOptions) -> Result<Table> {
    let scale = if opts.quick { Scale::Tiny } else { opts.scale };
    let ks: Vec<usize> = match opts.k {
        Some(k) => vec![k],
        None if opts.quick => vec![2, 50],
        None => K_SWEEP.to_vec(),
    };
    // The full 10-dataset suite over 6 K values is hours of exchange-
    // heuristic runtime; default to a 3-dataset core unless overridden.
    let datasets = match &opts.datasets {
        Some(_) => dataset_list(opts),
        None if opts.quick => vec!["travel".into()],
        None => vec!["travel".into(), "npi".into(), "survival".into()],
    };
    let algos = [Algo::PR(5), Algo::PR(50), Algo::Rand];

    let mut t = Table::new(
        "T4x — K sweep (dev % from ABA; — = no solution in time limit)",
        &[
            "dataset", "N", "K", "ofv ABA", "P-R5", "P-R50", "Rand", "cpu ABA [s]",
            "cpu P-R5", "cpu P-R50",
        ],
    )
    .left(0);
    for name in &datasets {
        let ds = load(name, scale)?;
        for &k in &ks {
            if k > ds.n / 2 {
                continue;
            }
            eprintln!("  [t4x] {name} k={k}");
            let aba = run_algo(&ds, k, Algo::Aba, 0, opts.time_limit_secs).unwrap();
            let aba_ofv = aba.partition.objective;
            let runs: Vec<_> = algos
                .iter()
                .map(|&a| (a, run_algo(&ds, k, a, 1, opts.time_limit_secs)))
                .collect();
            let mut cells = vec![
                name.clone(),
                ds.n.to_string(),
                k.to_string(),
                format!("{aba_ofv:.2}"),
            ];
            for (_, run) in &runs {
                cells.push(dev_cell(quality_dev(aba_ofv, run), 4));
            }
            cells.push(fmt_secs(aba.secs));
            for (algo, run) in &runs {
                if *algo == Algo::Rand {
                    continue;
                }
                cells.push(dev_cell(time_dev(aba.secs, run), 1));
            }
            t.row(cells);
        }
    }
    t.save_csv(&opts.out_dir, "t4x")?;
    println!("{}", t.render());
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn t4x_quick_runs() {
        let opts = ExpOptions {
            quick: true,
            out_dir: std::env::temp_dir().join("aba_results_test"),
            ..ExpOptions::default()
        };
        let t = table4x(&opts).unwrap();
        assert_eq!(t.rows.len(), 2); // travel x K in {2, 50}
        assert_eq!(t.headers.len(), 10);
    }
}
