//! Tables 4 and 6: ABA vs fast_anticlustering variants and Rand on the
//! standard anticlustering task.
//!
//! Table 4 reports the centroid-form objective (ofv) of ABA, percentage
//! deviations of each benchmark from it, ABA's runtime, and runtime
//! deviations. Table 6 reports, for the same runs, the sd and range of
//! per-anticluster diversity. Both come from a single suite run here.

use super::common::{dev_cell, quality_dev, run_algo, time_dev, Algo, AlgoRun, ExpOptions};
use crate::data::synth::{load, Scale};
use crate::data::Dataset;
use crate::util::fmt_secs;
use crate::util::table::Table;
use anyhow::Result;

/// Datasets of the paper's Table 4 (the 16-row standard suite). The
/// heaviest rows are excluded from the *default* run on this single-core
/// box; pass `--datasets all` to include them.
pub const TABLE4_DEFAULT: &[&str] = &[
    "travel", "npi", "creditcard", "adult", "plants", "bank", "cifar10", "mnist", "survival",
    "diabetes",
];
pub const TABLE4_ALL: &[&str] = &[
    "travel", "npi", "creditcard", "adult", "plants", "bank", "cifar10", "mnist", "survival",
    "diabetes", "music", "covtype", "imagenet8", "imagenet32", "census", "finance",
];

const ALGOS: &[Algo] = &[Algo::PN5, Algo::PR(5), Algo::PR(50), Algo::PR(500), Algo::Rand];

/// One dataset's complete suite run. ABA's objective and stats are read
/// off `aba.partition` — no recomputation.
pub struct SuiteRow {
    pub ds: Dataset,
    pub aba: AlgoRun,
    pub others: Vec<(Algo, Option<AlgoRun>)>,
}

/// Resolve the dataset list for these options.
pub fn dataset_list(opts: &ExpOptions) -> Vec<String> {
    match &opts.datasets {
        Some(list) if list.len() == 1 && list[0] == "all" => {
            TABLE4_ALL.iter().map(|s| s.to_string()).collect()
        }
        Some(list) => list.clone(),
        None if opts.quick => vec!["travel".into(), "npi".into()],
        None => TABLE4_DEFAULT.iter().map(|s| s.to_string()).collect(),
    }
}

/// Run the standard suite at the given K.
pub fn run_suite(opts: &ExpOptions, k: usize) -> Result<Vec<SuiteRow>> {
    let scale = if opts.quick { Scale::Tiny } else { opts.scale };
    let mut rows = Vec::new();
    for name in dataset_list(opts) {
        let ds = load(&name, scale)?;
        eprintln!("  [t4] {} (n={}, d={}) k={k}", ds.name, ds.n, ds.d);
        let aba = run_algo(&ds, k, Algo::Aba, 0, opts.time_limit_secs)
            .expect("ABA always completes");
        let others: Vec<(Algo, Option<AlgoRun>)> = ALGOS
            .iter()
            .map(|&a| (a, run_algo(&ds, k, a, 1, opts.time_limit_secs)))
            .collect();
        rows.push(SuiteRow { ds, aba, others });
    }
    Ok(rows)
}

/// Format and print Table 4; returns the rendered table.
pub fn table4(opts: &ExpOptions) -> Result<Table> {
    let k = opts.k.unwrap_or(5);
    let rows = run_suite(opts, k)?;
    let mut t = Table::new(
        format!("Table 4 — quality and runtime, K={k} (dev % from ABA; — = no solution in time limit)"),
        &[
            "dataset", "N", "D", "ofv ABA", "P-N5", "P-R5", "P-R50", "P-R500", "Rand",
            "cpu ABA [s]", "cpu P-N5", "cpu P-R5", "cpu P-R50", "cpu P-R500",
        ],
    )
    .left(0);
    for row in &rows {
        let mut cells = vec![
            row.ds.name.clone(),
            row.ds.n.to_string(),
            row.ds.d.to_string(),
            format!("{:.2}", row.aba.partition.objective),
        ];
        for (_, run) in &row.others {
            cells.push(dev_cell(quality_dev(row.aba.partition.objective, run), 4));
        }
        cells.push(fmt_secs(row.aba.secs));
        for (algo, run) in &row.others {
            if *algo == Algo::Rand {
                continue;
            }
            cells.push(dev_cell(time_dev(row.aba.secs, run), 1));
        }
        t.row(cells);
    }
    t.save_csv(&opts.out_dir, &format!("t4_k{k}"))?;
    println!("{}", t.render());
    Ok(t)
}

/// Format and print Table 6 (diversity balance) from the same suite.
pub fn table6(opts: &ExpOptions) -> Result<Table> {
    let k = opts.k.unwrap_or(5);
    let rows = run_suite(opts, k)?;
    let mut t = Table::new(
        format!("Table 6 — diversity balance (sd / range), K={k} (dev % from ABA)"),
        &[
            "dataset", "sd ABA", "sd P-N5", "sd P-R5", "sd P-R50", "sd P-R500", "sd Rand",
            "range ABA", "rg P-N5", "rg P-R5", "rg P-R50", "rg P-R500", "rg Rand",
        ],
    )
    .left(0);
    for row in &rows {
        let sd_aba = row.aba.partition.stats.diversity_sd();
        let rg_aba = row.aba.partition.stats.diversity_range();
        let mut cells = vec![row.ds.name.clone(), format!("{sd_aba:.3}")];
        let stats_of =
            |run: &Option<AlgoRun>| run.as_ref().map(|r| &r.partition.stats);
        for (_, run) in &row.others {
            let dev = stats_of(run).map(|s| crate::util::pct_dev(s.diversity_sd(), sd_aba));
            cells.push(dev_cell(dev, 1));
        }
        cells.push(format!("{rg_aba:.3}"));
        for (_, run) in &row.others {
            let dev = stats_of(run).map(|s| crate::util::pct_dev(s.diversity_range(), rg_aba));
            cells.push(dev_cell(dev, 1));
        }
        t.row(cells);
    }
    t.save_csv(&opts.out_dir, &format!("t6_k{k}"))?;
    println!("{}", t.render());
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> ExpOptions {
        ExpOptions {
            quick: true,
            time_limit_secs: 20.0,
            out_dir: std::env::temp_dir().join("aba_results_test"),
            ..ExpOptions::default()
        }
    }

    #[test]
    fn table4_quick_runs_and_has_shape() {
        let t = table4(&quick_opts()).unwrap();
        assert_eq!(t.rows.len(), 2); // travel + npi at tiny scale
        assert_eq!(t.headers.len(), 14);
        // ABA ofv column is positive.
        for row in &t.rows {
            assert!(row[3].parse::<f64>().unwrap() > 0.0);
        }
    }

    #[test]
    fn table6_quick_aba_has_lowest_or_close_sd() {
        let t = table6(&quick_opts()).unwrap();
        // The Rand sd deviation (column 6) should be positive (worse) in
        // the typical case; assert it is not strongly negative for all
        // rows (shape check, not exact numbers).
        let devs: Vec<f64> = t
            .rows
            .iter()
            .filter_map(|r| r[6].parse::<f64>().ok())
            .collect();
        assert!(!devs.is_empty());
        assert!(devs.iter().any(|&d| d > 0.0), "{devs:?}");
    }
}
