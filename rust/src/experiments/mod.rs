//! The experiment harness: one module per table/figure of the paper's
//! evaluation (§5), each printing the paper-shaped table and writing a
//! CSV under `results/`.
//!
//! | command        | reproduces |
//! |----------------|------------|
//! | `aba table t4`  | Table 4 — quality + runtime vs P-N5/P-R5/P-R50/P-R500/Rand |
//! | `aba table t6`  | Table 6 — diversity sd/range balance |
//! | `aba table t8`  | Table 8 — huge-K sweep on imagenet32-sim with hierarchical decomposition |
//! | `aba table t9`  | Table 9 — categorical anticlustering vs MILP-like/P-R*/Rand |
//! | `aba table t10` | Table 10 — categorical diversity sd/range |
//! | `aba table t11` | Table 11 — balanced k-cut vs METIS-like/Rand |
//! | `aba fig f5`    | Figure 5 — diversity distributions, large K |
//! | `aba fig f6`    | Figure 6 — within-anticluster distance distributions |
//! | `aba fig f7`    | Figure 7 — hierarchical decomposition strategy sweep |
//!
//! Scaled-down workloads stand in for the paper's (see DESIGN.md §3);
//! `--scale paper` runs the original sizes where feasible.

pub mod common;
pub mod figs;
pub mod t11;
pub mod t4;
pub mod t4x;
pub mod t8;
pub mod t9;

pub use common::ExpOptions;
