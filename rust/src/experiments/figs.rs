//! Figures 5–7 of the paper, as ASCII renderings + CSV series.

use super::common::{run_algo, Algo, ExpOptions};
use crate::algo::AbaConfig;
use crate::data::dataset::sq_dist_to_f64;
use crate::solver::{Aba, Anticlusterer};
use crate::data::synth::{load, Scale};
use crate::metrics::{ascii_histogram, quartiles};
use crate::util::fmt_secs;
use crate::util::table::Table;
use crate::util::timer::Timer;
use anyhow::Result;

/// Figure 5: distributions of per-anticluster diversity, ABA vs P-R5, at
/// large K on an image-like dataset. The paper's headline: ABA's
/// distribution has a higher mean *and* a much smaller spread.
pub fn fig5(opts: &ExpOptions) -> Result<Table> {
    let (name, k) = if opts.quick { ("mnist", 20) } else { ("mnist", 200) };
    let scale = if opts.quick { Scale::Tiny } else { opts.scale };
    let ds = load(name, scale)?;
    let k = opts.k.unwrap_or(k).min(ds.n / 2);
    eprintln!("  [f5] {} (n={}, d={}) k={k}", ds.name, ds.n, ds.d);

    let aba = run_algo(&ds, k, Algo::Aba, 0, opts.time_limit_secs).unwrap();
    let pr5 = run_algo(&ds, k, Algo::PR(5), 1, opts.time_limit_secs);
    let (bench_name, bench) = match pr5 {
        Some(run) => ("P-R5", run),
        None => (
            "Rand",
            run_algo(&ds, k, Algo::Rand, 1, opts.time_limit_secs).unwrap(),
        ),
    };

    let div_aba = aba.partition.stats.ssd;
    let div_bench = bench.partition.stats.ssd;

    println!("== Figure 5 — per-anticluster diversity distribution, {name}, K={k} ==");
    println!("--- ABA ---");
    for line in ascii_histogram(&div_aba, 12, 40) {
        println!("{line}");
    }
    println!("--- {bench_name} ---");
    for line in ascii_histogram(&div_bench, 12, 40) {
        println!("{line}");
    }

    let mut t = Table::new("fig5 series", &["algo", "anticluster", "diversity"]).left(0);
    for (i, &v) in div_aba.iter().enumerate() {
        t.row(vec!["ABA".into(), i.to_string(), format!("{v:.4}")]);
    }
    for (i, &v) in div_bench.iter().enumerate() {
        t.row(vec![bench_name.into(), i.to_string(), format!("{v:.4}")]);
    }
    t.save_csv(&opts.out_dir, "f5")?;

    let sa = crate::metrics::Summary::of(&div_aba);
    let sb = crate::metrics::Summary::of(&div_bench);
    println!(
        "ABA: mean={:.2} sd={:.2} range={:.2}   {bench_name}: mean={:.2} sd={:.2} range={:.2}",
        sa.mean,
        sa.sd,
        sa.range(),
        sb.mean,
        sb.sd,
        sb.range()
    );
    Ok(t)
}

/// Figure 6: within-anticluster distance distributions (boxplot table)
/// for the Travel dataset with K = 50.
pub fn fig6(opts: &ExpOptions) -> Result<Table> {
    let scale = if opts.quick { Scale::Tiny } else { opts.scale };
    let ds = load("travel", scale)?;
    let k = opts.k.unwrap_or(if opts.quick { 10 } else { 50 });
    eprintln!("  [f6] travel (n={}) k={k}", ds.n);

    let algos: Vec<(&str, Option<super::common::AlgoRun>)> = vec![
        ("ABA", run_algo(&ds, k, Algo::Aba, 0, opts.time_limit_secs)),
        ("P-N5", run_algo(&ds, k, Algo::PN5, 1, opts.time_limit_secs)),
        ("P-R5", run_algo(&ds, k, Algo::PR(5), 1, opts.time_limit_secs)),
        ("Rand", run_algo(&ds, k, Algo::Rand, 1, opts.time_limit_secs)),
    ];

    let mut t = Table::new(
        format!("Figure 6 — per-anticluster distance quartiles, travel, K={k}"),
        &["algo", "anticluster", "q1", "median", "q3"],
    )
    .left(0);
    println!("== Figure 6 — spread of per-anticluster medians (lower = more uniform) ==");
    for (name, run) in &algos {
        let Some(run) = run else {
            println!("{name:>6}: —");
            continue;
        };
        let labels = run.labels();
        // Distances of objects to their anticluster centroid.
        let d = ds.d;
        let mut sums = vec![0f64; k * d];
        let mut counts = vec![0usize; k];
        for i in 0..ds.n {
            let c = labels[i] as usize;
            counts[c] += 1;
            for (s, &v) in sums[c * d..(c + 1) * d].iter_mut().zip(ds.row(i)) {
                *s += v as f64;
            }
        }
        for c in 0..k {
            for v in sums[c * d..(c + 1) * d].iter_mut() {
                *v /= counts[c].max(1) as f64;
            }
        }
        // Single pass: bin each object's centroid distance into its
        // cluster (per-cluster walks via members_of would rescan the
        // label vector k times here — see t9 for the one-cluster case).
        let mut per_cluster: Vec<Vec<f64>> = vec![Vec::new(); k];
        for i in 0..ds.n {
            let c = labels[i] as usize;
            per_cluster[c].push(sq_dist_to_f64(ds.row(i), &sums[c * d..(c + 1) * d]).sqrt());
        }
        let mut medians = Vec::with_capacity(k);
        for (c, dists) in per_cluster.iter().enumerate() {
            let (q1, q2, q3) = quartiles(dists);
            medians.push(q2);
            t.row(vec![
                name.to_string(),
                c.to_string(),
                format!("{q1:.4}"),
                format!("{q2:.4}"),
                format!("{q3:.4}"),
            ]);
        }
        let s = crate::metrics::Summary::of(&medians);
        println!(
            "{name:>6}: median-of-medians={:.3}  sd(medians)={:.4}  range={:.4}",
            s.mean,
            s.sd,
            s.range()
        );
    }
    t.save_csv(&opts.out_dir, "f6")?;
    Ok(t)
}

/// Figure 7: hierarchical decomposition strategy sweep — objective and
/// runtime per factorization of K.
pub fn fig7(opts: &ExpOptions) -> Result<Table> {
    // Scaled from the paper's (imagenet32, K = 5000): the sweep varies
    // (K1 x K2) factorizations plus the flat baseline.
    let (n_cap, k) = if opts.quick { (4_096, 64) } else { (32_768, 1_024) };
    let scale = if opts.quick { Scale::Tiny } else { opts.scale };
    let full = load("imagenet32", scale)?;
    let ds = if full.n > n_cap {
        full.subset(&(0..n_cap).collect::<Vec<_>>(), "imagenet32-f7")
    } else {
        full
    };
    let k = opts.k.unwrap_or(k).min(ds.n / 2);
    eprintln!("  [f7] {} (n={}) k={k}", ds.name, ds.n);

    // All two-level factorizations of K (plus flat).
    let mut strategies: Vec<Vec<usize>> = vec![vec![k]];
    let mut d = 2usize;
    while d * d <= k {
        if k % d == 0 {
            strategies.push(vec![d, k / d]);
            if d != k / d {
                strategies.push(vec![k / d, d]);
            }
        }
        d += 1;
    }

    let mut t = Table::new(
        format!("Figure 7 — decomposition sweep on {} (n={}), K={k}", ds.name, ds.n),
        &["strategy", "cpu [s]", "ofv", "dev from best [%]"],
    )
    .left(0);
    let mut results: Vec<(String, f64, f64)> = Vec::new();
    for spec in &strategies {
        let label = spec
            .iter()
            .map(|x| x.to_string())
            .collect::<Vec<_>>()
            .join("x");
        let cfg = AbaConfig {
            auto_hier: false,
            hier: if spec.len() > 1 { Some(spec.clone()) } else { None },
            // The flat row is the figure's *exact* reference: keep it on
            // the dense solve even at large K (no candidate pruning).
            candidates: crate::assignment::CandidateMode::Dense,
            ..AbaConfig::default()
        };
        let mut session = Aba::from_config(cfg)?;
        let timer = Timer::start();
        let part = session.partition(&ds, k)?;
        let secs = timer.secs();
        let ofv = part.objective;
        eprintln!("    {label}: {} s, ofv {ofv:.1}", fmt_secs(secs));
        results.push((label, secs, ofv));
    }
    let best = results.iter().map(|r| r.2).fold(f64::NEG_INFINITY, f64::max);
    for (label, secs, ofv) in &results {
        t.row(vec![
            label.clone(),
            fmt_secs(*secs),
            format!("{ofv:.1}"),
            format!("{:.4}", crate::util::pct_dev(*ofv, best)),
        ]);
    }
    t.save_csv(&opts.out_dir, "f7")?;
    println!("{}", t.render());
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> ExpOptions {
        ExpOptions {
            quick: true,
            out_dir: std::env::temp_dir().join("aba_results_test"),
            ..ExpOptions::default()
        }
    }

    #[test]
    fn fig5_aba_spread_smaller() {
        let t = fig5(&quick_opts()).unwrap();
        // Collect per-algo diversity series from the table.
        let series = |algo: &str| -> Vec<f64> {
            t.rows
                .iter()
                .filter(|r| r[0] == algo)
                .map(|r| r[2].parse().unwrap())
                .collect()
        };
        let aba = crate::metrics::Summary::of(&series("ABA"));
        let other_name = t
            .rows
            .iter()
            .map(|r| r[0].clone())
            .find(|n| n != "ABA")
            .unwrap();
        let other = crate::metrics::Summary::of(&series(&other_name));
        assert!(aba.sd <= other.sd * 1.5, "aba.sd={} other.sd={}", aba.sd, other.sd);
    }

    #[test]
    fn fig6_runs() {
        let t = fig6(&quick_opts()).unwrap();
        assert!(t.rows.len() >= 20);
    }

    #[test]
    fn fig7_balanced_fastest_or_close() {
        let t = fig7(&quick_opts()).unwrap();
        assert!(t.rows.len() >= 3);
        // Quality loss of every decomposition < 5% from best.
        for row in &t.rows {
            let dev: f64 = row[3].parse().unwrap();
            assert!(dev > -5.0, "{row:?}");
        }
    }
}
