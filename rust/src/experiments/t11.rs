//! Table 11: ABA as a balanced k-cut method vs the METIS-like multilevel
//! partitioner and Rand.
//!
//! On tabular data with squared-Euclidean edge weights, minimizing the
//! balanced-cut cost is equivalent to maximizing the within-anticluster
//! pairwise sum `W(C)` (§5.5), so all three algorithms are scored by
//! `W(C)` on the full data. The METIS-like partitioner consumes the
//! paper's input construction: p = 30 random neighbors per node, integer
//! weights (`graph::builder`); its input-construction time is reported
//! separately, as in the paper.

use super::common::{run_algo, Algo, ExpOptions};
use crate::algo::ClusterStats;
use crate::data::synth::{load, Scale};
use crate::graph::builder::random_neighbor_graph;
use crate::graph::metis_like::{partition, PartitionConfig};
use crate::util::fmt_secs;
use crate::util::table::Table;
use crate::util::timer::Timer;
use anyhow::Result;

/// (dataset, K sweep) — §5.5 of the paper (Table 11 instances).
pub const INSTANCES: &[(&str, &[usize])] = &[
    ("abalone", &[4, 5, 6, 8, 10]),
    ("facebook", &[7, 8, 10, 13, 18]),
    ("frogs", &[8, 10, 13, 15, 16]),
    ("electric", &[10, 15, 20, 25, 30]),
    ("npi", &[2, 4, 6]),
    ("pulsar", &[18, 20, 25, 30, 35]),
    ("creditcard", &[2, 4, 6]),
    ("adult", &[2, 4, 6]),
    ("plants", &[2, 4, 6]),
    ("bank", &[2, 4, 6]),
];

pub fn table11(opts: &ExpOptions) -> Result<Table> {
    let scale = if opts.quick { Scale::Tiny } else { opts.scale };
    let p_neighbors = 30;
    let mut t = Table::new(
        "Table 11 — balanced k-cut: W(C), deviations, runtimes, size ratios",
        &[
            "dataset", "N", "K", "W(C) ABA", "dev METIS [%]", "dev Rand [%]", "cpu ABA",
            "cpu METIS", "cpu input", "ratio ABA", "ratio METIS",
        ],
    )
    .left(0);
    for &(name, ks) in INSTANCES {
        if let Some(filter) = &opts.datasets {
            if !filter.iter().any(|f| f == name || f == "all") {
                continue;
            }
        }
        let ds = load(name, scale)?;
        // METIS input construction (timed once per dataset, as in the
        // paper — the graph is reused across K values).
        let tg = Timer::start();
        let graph = random_neighbor_graph(&ds, p_neighbors, 17);
        let input_secs = tg.secs();
        let ks: Vec<usize> = match opts.k {
            Some(k) => vec![k],
            None if opts.quick => vec![ks[0]],
            None => ks.to_vec(),
        };
        for k in ks {
            eprintln!("  [t11] {name} (n={}) k={k}", ds.n);
            let aba = run_algo(&ds, k, Algo::Aba, 0, opts.time_limit_secs).unwrap();
            let aba_w = aba.partition.pairwise;

            let tm = Timer::start();
            let metis_labels = partition(&graph, &PartitionConfig::new(k));
            let metis_secs = tm.secs();
            let metis_stats = ClusterStats::compute(&ds, &metis_labels, k);
            let metis_w = metis_stats.pairwise_total();

            let rand = run_algo(&ds, k, Algo::Rand, 1, opts.time_limit_secs).unwrap();
            let rand_w = rand.partition.pairwise;

            t.row(vec![
                name.into(),
                ds.n.to_string(),
                k.to_string(),
                format!("{aba_w:.1}"),
                format!("{:.3}", crate::util::pct_dev(metis_w, aba_w)),
                format!("{:.3}", crate::util::pct_dev(rand_w, aba_w)),
                fmt_secs(aba.secs),
                fmt_secs(metis_secs),
                fmt_secs(input_secs),
                format!("{:.2}", aba.partition.stats.min_max_ratio_pct()),
                format!("{:.2}", metis_stats.min_max_ratio_pct()),
            ]);
        }
    }
    t.save_csv(&opts.out_dir, "t11")?;
    println!("{}", t.render());
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table11_quick_shape() {
        let opts = ExpOptions {
            quick: true,
            datasets: Some(vec!["abalone".into(), "npi".into()]),
            out_dir: std::env::temp_dir().join("aba_results_test"),
            ..ExpOptions::default()
        };
        let t = table11(&opts).unwrap();
        assert_eq!(t.rows.len(), 2);
        for row in &t.rows {
            // ABA is perfectly balanced (ratio 100).
            assert_eq!(row[9], "100.00");
            // W(C) positive.
            assert!(row[3].parse::<f64>().unwrap() > 0.0);
            // Rand deviation should be <= 0 (ABA at least as good).
            assert!(row[5].parse::<f64>().unwrap() <= 0.05, "{row:?}");
        }
    }
}
