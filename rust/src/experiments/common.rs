//! Shared experiment machinery: algorithm specs, timed runs, time caps,
//! and the dash convention for algorithms that fail to finish.
//!
//! Every benchmark algorithm is driven through the
//! [`crate::solver::Anticlusterer`] trait, so the harness holds one code
//! path for all of them and reads objectives/stats straight off the
//! returned [`Partition`] instead of recomputing them per table.

use crate::baselines::exchange::{ExchangeConfig, Partners};
use crate::baselines::{ExactSolver, FastAnticlustering, RandomPartition};
use crate::data::synth::Scale;
use crate::data::Dataset;
use crate::error::AbaError;
use crate::solver::{Aba, Anticlusterer, Partition};

use std::path::PathBuf;
use std::time::Duration;

/// Options common to all experiment commands.
#[derive(Clone, Debug)]
pub struct ExpOptions {
    pub scale: Scale,
    /// Override the K sweep (single value).
    pub k: Option<usize>,
    /// Restrict to these dataset names (`None` = experiment default).
    pub datasets: Option<Vec<String>>,
    /// Per-algorithm-per-instance time cap in seconds (the paper's 2 h,
    /// scaled to this box).
    pub time_limit_secs: f64,
    /// Where CSVs go.
    pub out_dir: PathBuf,
    /// Sharply reduced workloads (used by integration tests / bench-all).
    pub quick: bool,
}

impl Default for ExpOptions {
    fn default() -> Self {
        Self {
            scale: Scale::Small,
            k: None,
            datasets: None,
            time_limit_secs: 60.0,
            out_dir: PathBuf::from("results"),
            quick: false,
        }
    }
}

/// The benchmark algorithms of Table 3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// This paper.
    Aba,
    /// fast_anticlustering, 5 nearest-neighbor partners.
    PN5,
    /// fast_anticlustering, `p` random partners (P-R5/P-R50/P-R500).
    PR(usize),
    /// Random (category-aware when the dataset has categories).
    Rand,
    /// Time-capped branch-and-bound (the AVOC-MILP stand-in).
    MilpLike,
}

impl Algo {
    pub fn name(&self) -> String {
        match self {
            Algo::Aba => "ABA".into(),
            Algo::PN5 => "P-N5".into(),
            Algo::PR(p) => format!("P-R{p}"),
            Algo::Rand => "Rand".into(),
            Algo::MilpLike => "MILP-like".into(),
        }
    }
}

/// Build the [`Anticlusterer`] session for a benchmark algorithm.
pub fn solver_for(algo: Algo, seed: u64, limit_secs: f64) -> Box<dyn Anticlusterer> {
    let limit = Duration::from_secs_f64(limit_secs);
    match algo {
        Algo::Aba => Box::new(Aba::new().expect("native ABA session always builds")),
        Algo::PN5 => Box::new(FastAnticlustering::new(ExchangeConfig {
            partners: Partners::Nearest(5),
            seed,
            time_limit: Some(limit),
        })),
        Algo::PR(p) => Box::new(FastAnticlustering::new(ExchangeConfig {
            partners: Partners::Random(p),
            seed,
            time_limit: Some(limit),
        })),
        Algo::Rand => Box::new(RandomPartition::new(seed)),
        Algo::MilpLike => Box::new(ExactSolver::new(Some(limit))),
    }
}

/// A completed run: the rich partition plus algorithm-only seconds
/// (ordering + assignment; the stats pass is excluded, matching the
/// paper's runtime convention).
#[derive(Clone, Debug)]
pub struct AlgoRun {
    pub partition: Partition,
    pub secs: f64,
}

impl AlgoRun {
    /// Anticluster label per object (convenience accessor).
    pub fn labels(&self) -> &[u32] {
        &self.partition.labels
    }

    /// Iterate one anticluster's member indices without materializing
    /// `Partition::groups()` — the per-cluster walks of the figure/table
    /// code go through this.
    pub fn members_of(&self, c: usize) -> impl Iterator<Item = usize> + Clone + '_ {
        self.partition.members_of(c)
    }
}

/// Run one algorithm with a time cap. `None` = the paper's dash (no
/// solution within the limit / infeasible configuration).
pub fn run_algo(ds: &Dataset, k: usize, algo: Algo, seed: u64, limit_secs: f64) -> Option<AlgoRun> {
    if algo == Algo::PN5 {
        // The brute-force kNN behind P-N5 is O(n^2 d) — like the paper,
        // the configuration simply fails (dash) on datasets where it
        // cannot finish within the cap.
        let est_ops = (ds.n as f64) * (ds.n as f64) * (ds.d as f64);
        if ds.d > 16 && est_ops > 2.5e10 {
            return None;
        }
    }
    let mut solver = solver_for(algo, seed, limit_secs);
    match solver.partition(ds, k) {
        Ok(partition) => {
            let secs = partition.timings.algo_secs();
            Some(AlgoRun { secs, partition })
        }
        Err(AbaError::TimeLimit { .. }) => None,
        Err(e) => {
            eprintln!("  [warn] {} failed on {} (k={k}): {e}", solver.name(), ds.name);
            None
        }
    }
}

/// Format a percentage deviation cell (paper convention, 4 decimals for
/// quality, 1 for time); `None` renders as the paper's dash.
pub fn dev_cell(value: Option<f64>, digits: usize) -> String {
    match value {
        Some(v) if v.is_finite() => format!("{v:.digits$}"),
        _ => "—".into(),
    }
}

/// Quality deviation of `run`'s objective from ABA's objective
/// (centroid-form ofv, read off the partitions — no recomputation).
pub fn quality_dev(aba_ofv: f64, run: &Option<AlgoRun>) -> Option<f64> {
    run.as_ref()
        .map(|r| crate::util::pct_dev(r.partition.objective, aba_ofv))
}

/// Runtime deviation of `run` from ABA's runtime.
pub fn time_dev(aba_secs: f64, run: &Option<AlgoRun>) -> Option<f64> {
    run.as_ref().map(|r| crate::util::pct_dev(r.secs, aba_secs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::generate;
    use crate::data::synth::SynthKind;

    #[test]
    fn run_algo_all_kinds_on_tiny_data() {
        let ds = generate(SynthKind::Uniform, 60, 4, 91, "t");
        for algo in [Algo::Aba, Algo::PN5, Algo::PR(5), Algo::Rand] {
            let run = run_algo(&ds, 5, algo, 1, 10.0).unwrap_or_else(|| panic!("{algo:?}"));
            assert_eq!(run.labels().len(), 60);
            assert_eq!(run.partition.sizes().iter().sum::<usize>(), 60);
            assert!(run.partition.objective > 0.0);
        }
        // MILP-like with a tiny cap still returns an incumbent.
        let run = run_algo(&ds, 5, Algo::MilpLike, 1, 0.05).unwrap();
        assert_eq!(run.labels().len(), 60);
    }

    #[test]
    fn pn5_dashes_on_oversized_high_d() {
        let ds = generate(SynthKind::Uniform, 200_000, 64, 92, "big");
        assert!(run_algo(&ds, 5, Algo::PN5, 1, 0.001).is_none());
    }

    #[test]
    fn exchange_timeout_becomes_dash() {
        let ds = generate(SynthKind::Uniform, 400, 4, 93, "t");
        assert!(run_algo(&ds, 5, Algo::PR(50), 1, 0.0).is_none());
    }

    #[test]
    fn dev_cells() {
        assert_eq!(dev_cell(Some(1.23456), 4), "1.2346");
        assert_eq!(dev_cell(None, 4), "—");
    }

    #[test]
    fn algo_names_match_solver_names() {
        for algo in [Algo::Aba, Algo::PN5, Algo::PR(50), Algo::Rand, Algo::MilpLike] {
            assert_eq!(solver_for(algo, 1, 1.0).name(), algo.name());
        }
    }
}
