//! Shared experiment machinery: algorithm specs, timed runs, time caps,
//! and the dash convention for algorithms that fail to finish.

use crate::algo::{run_aba, AbaConfig, ClusterStats};
use crate::baselines::exact;
use crate::baselines::exchange::{fast_anticlustering, ExchangeConfig, Partners};
use crate::baselines::random_part;
use crate::data::synth::Scale;
use crate::data::Dataset;
use crate::util::timer::Timer;

use std::path::PathBuf;
use std::time::Duration;

/// Options common to all experiment commands.
#[derive(Clone, Debug)]
pub struct ExpOptions {
    pub scale: Scale,
    /// Override the K sweep (single value).
    pub k: Option<usize>,
    /// Restrict to these dataset names (`None` = experiment default).
    pub datasets: Option<Vec<String>>,
    /// Per-algorithm-per-instance time cap in seconds (the paper's 2 h,
    /// scaled to this box).
    pub time_limit_secs: f64,
    /// Where CSVs go.
    pub out_dir: PathBuf,
    /// Sharply reduced workloads (used by integration tests / bench-all).
    pub quick: bool,
}

impl Default for ExpOptions {
    fn default() -> Self {
        Self {
            scale: Scale::Small,
            k: None,
            datasets: None,
            time_limit_secs: 60.0,
            out_dir: PathBuf::from("results"),
            quick: false,
        }
    }
}

/// The benchmark algorithms of Table 3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// This paper.
    Aba,
    /// fast_anticlustering, 5 nearest-neighbor partners.
    PN5,
    /// fast_anticlustering, `p` random partners (P-R5/P-R50/P-R500).
    PR(usize),
    /// Random (category-aware when the dataset has categories).
    Rand,
    /// Time-capped branch-and-bound (the AVOC-MILP stand-in).
    MilpLike,
}

impl Algo {
    pub fn name(&self) -> String {
        match self {
            Algo::Aba => "ABA".into(),
            Algo::PN5 => "P-N5".into(),
            Algo::PR(p) => format!("P-R{p}"),
            Algo::Rand => "Rand".into(),
            Algo::MilpLike => "MILP-like".into(),
        }
    }
}

/// A completed run.
#[derive(Clone, Debug)]
pub struct AlgoRun {
    pub labels: Vec<u32>,
    pub secs: f64,
}

/// Run one algorithm with a time cap. `None` = the paper's dash (no
/// solution within the limit / infeasible configuration).
pub fn run_algo(ds: &Dataset, k: usize, algo: Algo, seed: u64, limit_secs: f64) -> Option<AlgoRun> {
    let limit = Duration::from_secs_f64(limit_secs);
    let t = Timer::start();
    match algo {
        Algo::Aba => {
            let labels = run_aba(ds, k, &AbaConfig::default()).ok()?;
            Some(AlgoRun { labels, secs: t.secs() })
        }
        Algo::PN5 => {
            // The brute-force kNN behind P-N5 is O(n^2 d) — like the
            // paper, the configuration simply fails (dash) on datasets
            // where it cannot finish within the cap.
            let est_ops = (ds.n as f64) * (ds.n as f64) * (ds.d as f64);
            if ds.d > 16 && est_ops > 2.5e10 {
                return None;
            }
            let cfg = ExchangeConfig {
                partners: Partners::Nearest(5),
                seed,
                time_limit: Some(limit),
            };
            let res = fast_anticlustering(ds, k, &cfg);
            if res.timed_out {
                return None;
            }
            Some(AlgoRun { labels: res.labels, secs: t.secs() })
        }
        Algo::PR(p) => {
            let cfg = ExchangeConfig {
                partners: Partners::Random(p),
                seed,
                time_limit: Some(limit),
            };
            let res = fast_anticlustering(ds, k, &cfg);
            if res.timed_out {
                return None;
            }
            Some(AlgoRun { labels: res.labels, secs: t.secs() })
        }
        Algo::Rand => {
            let labels = match &ds.categories {
                Some(c) => random_part::random_partition_categorical(c, k, seed),
                None => random_part::random_partition(ds.n, k, seed),
            };
            Some(AlgoRun { labels, secs: t.secs() })
        }
        Algo::MilpLike => {
            let res = exact::solve(ds, k, Some(limit));
            Some(AlgoRun { labels: res.labels, secs: t.secs() })
        }
    }
}

/// Format a percentage deviation cell (paper convention, 4 decimals for
/// quality, 1 for time); `None` renders as the paper's dash.
pub fn dev_cell(value: Option<f64>, digits: usize) -> String {
    match value {
        Some(v) if v.is_finite() => format!("{v:.digits$}"),
        _ => "—".into(),
    }
}

/// Quality deviation of `run` from ABA's objective (centroid-form ofv).
pub fn quality_dev(ds: &Dataset, k: usize, aba_ofv: f64, run: &Option<AlgoRun>) -> Option<f64> {
    run.as_ref().map(|r| {
        let ofv = ClusterStats::compute(ds, &r.labels, k).ssd_total();
        crate::util::pct_dev(ofv, aba_ofv)
    })
}

/// Runtime deviation of `run` from ABA's runtime.
pub fn time_dev(aba_secs: f64, run: &Option<AlgoRun>) -> Option<f64> {
    run.as_ref().map(|r| crate::util::pct_dev(r.secs, aba_secs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::generate;
    use crate::data::synth::SynthKind;

    #[test]
    fn run_algo_all_kinds_on_tiny_data() {
        let ds = generate(SynthKind::Uniform, 60, 4, 91, "t");
        for algo in [Algo::Aba, Algo::PN5, Algo::PR(5), Algo::Rand] {
            let run = run_algo(&ds, 5, algo, 1, 10.0).unwrap_or_else(|| panic!("{algo:?}"));
            assert_eq!(run.labels.len(), 60);
        }
        // MILP-like with a tiny cap still returns an incumbent.
        let run = run_algo(&ds, 5, Algo::MilpLike, 1, 0.05).unwrap();
        assert_eq!(run.labels.len(), 60);
    }

    #[test]
    fn pn5_dashes_on_oversized_high_d() {
        let ds = generate(SynthKind::Uniform, 200_000, 64, 92, "big");
        assert!(run_algo(&ds, 5, Algo::PN5, 1, 0.001).is_none());
    }

    #[test]
    fn dev_cells() {
        assert_eq!(dev_cell(Some(1.23456), 4), "1.2346");
        assert_eq!(dev_cell(None, 4), "—");
    }

    #[test]
    fn algo_names() {
        assert_eq!(Algo::PR(50).name(), "P-R50");
        assert_eq!(Algo::Aba.name(), "ABA");
    }
}
