//! Tables 9 and 10: anticlustering with a categorical feature.
//!
//! As in Croella et al. (2025), the categorical feature is derived by
//! k-means on the raw features (G clusters per dataset below), and each
//! dataset is solved for five values of K. Benchmarks: the time-capped
//! branch-and-bound (AVOC-MILP stand-in), P-R5/P-R50/P-R500 with
//! same-category random exchange partners, and category-aware Rand.

use super::common::{dev_cell, quality_dev, run_algo, time_dev, Algo, AlgoRun, ExpOptions};
use crate::data::kmeans::kmeans;
use crate::data::synth::{load, Scale};
use crate::data::Dataset;
use crate::util::fmt_secs;
use crate::util::table::Table;
use anyhow::Result;

/// (dataset, G = categories via k-means, K sweep) — §5.4 of the paper.
pub const INSTANCES: &[(&str, usize, &[usize])] = &[
    ("abalone", 3, &[4, 5, 6, 8, 10]),
    ("facebook", 3, &[7, 8, 10, 13, 18]),
    ("frogs", 4, &[8, 10, 13, 15, 16]),
    ("electric", 3, &[10, 15, 20, 25, 30]),
    ("pulsar", 2, &[18, 20, 25, 30, 35]),
];

const ALGOS: &[Algo] = &[Algo::MilpLike, Algo::PR(5), Algo::PR(50), Algo::PR(500), Algo::Rand];

pub struct CatRow {
    pub ds: Dataset,
    pub k: usize,
    pub aba: AlgoRun,
    pub others: Vec<(Algo, Option<AlgoRun>)>,
}

/// Run the categorical suite.
pub fn run_suite(opts: &ExpOptions) -> Result<Vec<CatRow>> {
    let scale = if opts.quick { Scale::Tiny } else { opts.scale };
    // The MILP stand-in gets a tighter cap: its role is "exhausts its
    // budget and returns a worse incumbent", and the budget must not
    // dominate the whole table's runtime.
    let milp_cap = if opts.quick { 0.3 } else { (opts.time_limit_secs / 10.0).clamp(1.0, 10.0) };
    let mut rows = Vec::new();
    for &(name, g, ks) in INSTANCES {
        if let Some(filter) = &opts.datasets {
            if !filter.iter().any(|f| f == name || f == "all") {
                continue;
            }
        }
        let mut ds = load(name, scale)?;
        let cats = kmeans(&ds, g, 50, 7).labels;
        ds = ds.with_categories(cats)?;
        let ks: Vec<usize> = match opts.k {
            Some(k) => vec![k],
            None if opts.quick => vec![ks[0]],
            None => ks.to_vec(),
        };
        for k in ks {
            eprintln!("  [t9] {name} (n={}, g={g}) k={k}", ds.n);
            let aba = run_algo(&ds, k, Algo::Aba, 0, opts.time_limit_secs).unwrap();
            let others = ALGOS
                .iter()
                .map(|&a| {
                    let cap = if a == Algo::MilpLike { milp_cap } else { opts.time_limit_secs };
                    (a, run_algo(&ds, k, a, 1, cap))
                })
                .collect();
            rows.push(CatRow { ds: ds.clone(), k, aba, others });
        }
    }
    Ok(rows)
}

pub fn table9(opts: &ExpOptions) -> Result<Table> {
    let rows = run_suite(opts)?;
    let mut t = Table::new(
        "Table 9 — categorical anticlustering (dev % from ABA ofv; cpu dev % from ABA)",
        &[
            "dataset", "N", "K", "ofv ABA", "MILP-like", "P-R5", "P-R50", "P-R500", "Rand",
            "cpu ABA [s]", "cpu MILP", "cpu P-R5", "cpu P-R50", "cpu P-R500",
        ],
    )
    .left(0);
    for row in &rows {
        let mut cells = vec![
            row.ds.name.clone(),
            row.ds.n.to_string(),
            row.k.to_string(),
            format!("{:.2}", row.aba.partition.objective),
        ];
        for (_, run) in &row.others {
            cells.push(dev_cell(quality_dev(row.aba.partition.objective, run), 4));
        }
        cells.push(fmt_secs(row.aba.secs));
        for (algo, run) in &row.others {
            if *algo == Algo::Rand {
                continue;
            }
            cells.push(dev_cell(time_dev(row.aba.secs, run), 1));
        }
        t.row(cells);
    }
    t.save_csv(&opts.out_dir, "t9")?;
    println!("{}", t.render());
    Ok(t)
}

pub fn table10(opts: &ExpOptions) -> Result<Table> {
    let rows = run_suite(opts)?;
    let mut t = Table::new(
        "Table 10 — categorical diversity balance (sd / range, dev % from ABA)",
        &[
            "dataset", "K", "sd ABA", "sd MILP", "sd P-R5", "sd P-R50", "sd P-R500", "sd Rand",
            "range ABA", "rg MILP", "rg P-R5", "rg P-R50", "rg P-R500", "rg Rand",
        ],
    )
    .left(0);
    for row in &rows {
        let sd_aba = row.aba.partition.stats.diversity_sd();
        let rg_aba = row.aba.partition.stats.diversity_range();
        let stats_of =
            |run: &Option<AlgoRun>| run.as_ref().map(|r| &r.partition.stats);
        let mut cells = vec![row.ds.name.clone(), row.k.to_string(), format!("{sd_aba:.3}")];
        for (_, run) in &row.others {
            let dev = stats_of(run).map(|s| crate::util::pct_dev(s.diversity_sd(), sd_aba));
            cells.push(dev_cell(dev, 1));
        }
        cells.push(format!("{rg_aba:.3}"));
        for (_, run) in &row.others {
            let dev = stats_of(run).map(|s| crate::util::pct_dev(s.diversity_range(), rg_aba));
            cells.push(dev_cell(dev, 1));
        }
        t.row(cells);
    }
    t.save_csv(&opts.out_dir, "t10")?;
    println!("{}", t.render());
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> ExpOptions {
        ExpOptions {
            quick: true,
            datasets: Some(vec!["abalone".into(), "pulsar".into()]),
            out_dir: std::env::temp_dir().join("aba_results_test"),
            ..ExpOptions::default()
        }
    }

    #[test]
    fn table9_runs_and_constraints_hold() {
        let rows = run_suite(&quick_opts()).unwrap();
        assert_eq!(rows.len(), 2);
        for row in &rows {
            let cats = row.ds.categories.as_ref().unwrap();
            let g = row.ds.n_categories();
            // Constraint (5) on the ABA solution.
            for cat in 0..g as u32 {
                let total = cats.iter().filter(|&&c| c == cat).count();
                let (lo, hi) = (total / row.k, total.div_ceil(row.k));
                for cl in 0..row.k as u32 {
                    let cnt = row
                        .aba
                        .members_of(cl as usize)
                        .filter(|&i| cats[i] == cat)
                        .count();
                    assert!(
                        (lo..=hi).contains(&cnt),
                        "{} k={} cat={cat} cl={cl}: {cnt} not in [{lo},{hi}]",
                        row.ds.name,
                        row.k
                    );
                }
            }
        }
    }

    #[test]
    fn table9_formats() {
        let t = table9(&quick_opts()).unwrap();
        assert_eq!(t.headers.len(), 14);
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn table10_formats() {
        let t = table10(&quick_opts()).unwrap();
        assert_eq!(t.headers.len(), 14);
    }
}
