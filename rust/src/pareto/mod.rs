//! Bicriterion Pareto search over the diversity/dispersion trade-off.
//!
//! ABA optimizes a single diversity objective, but the bicriterion
//! anticlustering literature (Brusco, Cradit & Steinley's MBPI; §3 of
//! the paper) asks for the *trade-off* between
//!
//! * **diversity** — total within-anticluster SSD (maximized), and
//! * **dispersion** — the minimum within-anticluster pairwise squared
//!   distance (maximized),
//!
//! made explicit as a Pareto set of partitions. This subsystem provides
//! exactly that, in three layers:
//!
//! * [`archive`] — a bounded non-dominated [`Archive`] with
//!   deterministic tie-breaking and crowding-style thinning, plus the
//!   2-D [`hypervolume`] indicator;
//! * [`interchange`] — the bicriterion pairwise-[`Interchange`] local
//!   search: O(d) diversity pricing through
//!   [`crate::algo::objective::ClusterDelta`], incremental dispersion
//!   through a per-cluster near-pair threshold list
//!   ([`DispersionState`]), both maintained bit-identical to
//!   from-scratch recomputes;
//! * [`engine`] — the multi-restart driver: restarts seeded from ABA
//!   solutions, `fast_anticlustering`, and random partitions under
//!   weight-sampled scalarizations, fanned out on the session
//!   [`crate::runtime::WorkerPool`] with per-restart
//!   [`crate::rng::Pcg32::stream`] seed streams so Serial ≡ Threads(n)
//!   fronts are bit-identical.
//!
//! Entry points: [`crate::Aba::pareto_front`] (sessions), `aba pareto`
//! (CLI), `POST /v1/partitions/{id}/pareto` (serve).

pub mod archive;
pub mod engine;
pub mod interchange;

pub use archive::{hypervolume, Archive, ParetoPoint};
pub use engine::{pareto_front, FrontPoint, ParetoConfig, ParetoFront};
pub use interchange::{recompute_diversity, DispersionState, Interchange};
