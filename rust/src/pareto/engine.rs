//! Multi-restart bicriterion driver (the MBPI shape of Brusco et al.).
//!
//! Each restart is a self-contained unit of work: restart `r` draws all
//! of its randomness from [`Pcg32::stream`]`(seed, r)` (the same
//! stream-split scheme as [`crate::baselines::exchange`]), seeds a
//! starting partition from one of three sources in rotation — the
//! caller's ABA solution, a [`fast_anticlustering`] run, or a balanced
//! random partition — samples a scalarization weight `w ∈ [0, 1)`, and
//! runs [`Interchange`] passes, feeding every visited state into a
//! restart-local [`Archive`].
//!
//! Restarts fan out over the session [`WorkerPool`] with `run_mut`;
//! because each restart touches only its own slot and its own seed
//! stream, and the local archives are merged serially in restart
//! order afterwards, **Serial and Threads(n) produce bit-identical
//! fronts** (property-tested).

use super::archive::{hypervolume, Archive, ParetoPoint};
use super::interchange::Interchange;
use crate::algo::objective::ClusterStats;
use crate::baselines::exchange::{fast_anticlustering, initial_partition, ExchangeConfig};
use crate::data::DataView;
use crate::error::{AbaError, AbaResult};
use crate::rng::Pcg32;
use crate::runtime::WorkerPool;

/// Knobs of the multi-restart bicriterion engine.
#[derive(Clone, Debug)]
pub struct ParetoConfig {
    /// Independent restarts (each one interchange search).
    pub restarts: usize,
    /// Maximum points the front may hold (crowding-thinned beyond).
    pub archive_cap: usize,
    /// Interchange passes per restart (a restart stops early once a
    /// pass applies no swap).
    pub passes: usize,
    /// Candidate exchange partners drawn per object per pass.
    pub partners: usize,
    /// Root seed of the per-restart [`Pcg32::stream`] split.
    pub seed: u64,
}

impl Default for ParetoConfig {
    fn default() -> Self {
        Self { restarts: 12, archive_cap: 24, passes: 3, partners: 8, seed: 0xA17C }
    }
}

/// One partition on the returned front, with its diversity certificate.
#[derive(Clone, Debug, PartialEq)]
pub struct FrontPoint {
    /// Anticluster label per object (view-relative row order).
    pub labels: Vec<u32>,
    /// Centroid-form diversity objective (total within-anticluster SSD).
    pub diversity: f64,
    /// Minimum within-anticluster pairwise squared distance.
    pub dispersion: f64,
    /// Certified upper bound on the diversity of **any** balanced
    /// k-partition of this data: `diversity + BGSS` (see
    /// [`crate::cert::bounds`]); `>= diversity` exactly in fp.
    pub upper_bound: f64,
    /// Relative diversity optimality gap in `[0, 1]`.
    pub gap: f64,
}

/// A diversity/dispersion Pareto front (both criteria maximized),
/// sorted by diversity descending — equivalently dispersion ascending.
#[derive(Clone, Debug, PartialEq)]
pub struct ParetoFront {
    pub points: Vec<FrontPoint>,
    /// Restarts that produced it.
    pub restarts: usize,
}

impl ParetoFront {
    /// The diversity-extreme point (first: maximum diversity).
    pub fn best_diversity(&self) -> Option<&FrontPoint> {
        self.points.first()
    }

    /// The dispersion-extreme point (last: maximum dispersion).
    pub fn best_dispersion(&self) -> Option<&FrontPoint> {
        self.points.last()
    }

    /// 2-D hypervolume against a reference `(diversity, dispersion)`
    /// point — e.g. the single-ABA solution's pair scaled down, so the
    /// front's improvement over the one-objective solver is one number.
    pub fn hypervolume(&self, ref_point: (f64, f64)) -> f64 {
        let pts: Vec<(f64, f64)> =
            self.points.iter().map(|p| (p.diversity, p.dispersion)).collect();
        hypervolume(&pts, ref_point)
    }
}

/// Preconditions of the bicriterion engine, surfaced as typed errors at
/// the API boundary: beyond the standard shape checks, a balanced
/// partition with `n < 2k` forces singleton anticlusters, whose
/// dispersion is undefined (`objective::dispersion` returns
/// `f64::INFINITY`) — refused up front instead of leaking `inf` into
/// front output.
pub fn validate(n: usize, k: usize) -> AbaResult<()> {
    crate::algo::validate(n, k, false)?;
    if n < 2 * k {
        return Err(AbaError::InvalidK {
            k,
            n,
            reason: format!(
                "bicriterion search needs every anticluster to hold at least two objects \
                 (n >= 2k, got n={n} < {}); singleton anticlusters have undefined \
                 (infinite) dispersion",
                2 * k
            ),
        });
    }
    Ok(())
}

/// Run the engine. `aba_seed` is the single-ABA solution used as the
/// rotation's first seed source (and therefore always on or weakly
/// dominated by the returned front); `pool` fans restarts out when
/// present — the front is bit-identical either way.
pub fn pareto_front(
    view: &DataView<'_>,
    k: usize,
    cfg: &ParetoConfig,
    aba_seed: Option<&[u32]>,
    pool: Option<&WorkerPool>,
) -> AbaResult<ParetoFront> {
    validate(view.n(), k)?;
    if cfg.restarts == 0 {
        return Err(AbaError::InvalidInput("pareto: restarts must be >= 1".into()));
    }
    if let Some(seed) = aba_seed {
        if seed.len() != view.n() {
            return Err(AbaError::BadShape(format!(
                "pareto: ABA seed labels have {} rows, view has {}",
                seed.len(),
                view.n()
            )));
        }
    }
    let mut slots: Vec<Option<Archive>> = (0..cfg.restarts).map(|_| None).collect();
    let work = |r: usize, slot: &mut Option<Archive>| {
        *slot = Some(run_restart(view, k, cfg, aba_seed, r));
    };
    match pool {
        Some(p) if p.threads() > 1 => p.run_mut(&mut slots, &work),
        _ => {
            for (r, slot) in slots.iter_mut().enumerate() {
                work(r, slot);
            }
        }
    }
    let mut archive = Archive::new(cfg.archive_cap);
    for local in slots.into_iter().flatten() {
        archive.merge(local);
    }
    let points = archive
        .into_points()
        .into_iter()
        .map(|p| certify(view, k, p))
        .collect();
    Ok(ParetoFront { points, restarts: cfg.restarts })
}

/// Attach the diversity certificate (upper bound + gap) to a front
/// point — same construction as [`crate::Partition::upper_bound`].
fn certify(view: &DataView<'_>, k: usize, p: ParetoPoint) -> FrontPoint {
    let stats = ClusterStats::compute(view, &p.labels, k);
    let upper_bound = p.diversity + stats.bgss;
    let gap = crate::cert::bounds::gap(p.diversity, upper_bound);
    FrontPoint {
        labels: p.labels,
        diversity: p.diversity,
        dispersion: p.dispersion,
        upper_bound,
        gap,
    }
}

/// One restart: deterministic given `(view, k, cfg, aba_seed, r)`.
fn run_restart(
    view: &DataView<'_>,
    k: usize,
    cfg: &ParetoConfig,
    aba_seed: Option<&[u32]>,
    r: usize,
) -> Archive {
    let mut rng = Pcg32::stream(cfg.seed, r as u64);
    let w = rng.f64();
    let labels = match (r % 3, aba_seed) {
        (0, Some(seed)) => seed.to_vec(),
        (1, _) => {
            let p = cfg.partners.max(2);
            fast_anticlustering(view, k, &ExchangeConfig::random(p, rng.next_u64())).labels
        }
        _ => initial_partition(view, k, rng.next_u64()),
    };
    let mut local = Archive::new(cfg.archive_cap);
    let mut search = Interchange::new(view.clone(), labels, k);
    local.insert(ParetoPoint {
        labels: search.labels().to_vec(),
        diversity: search.diversity(),
        dispersion: search.dispersion(),
    });
    for _ in 0..cfg.passes {
        let swaps = search.pass(&mut rng, w, cfg.partners, |labels, div, disp| {
            local.insert(ParetoPoint {
                labels: labels.to_vec(),
                diversity: div,
                dispersion: disp,
            });
        });
        if swaps == 0 {
            break;
        }
    }
    local
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::objective::dispersion;
    use crate::data::synth::{generate, SynthKind};
    use crate::data::Dataset;
    use crate::solver::{Aba, Anticlusterer};

    fn gaussian(n: usize, d: usize, seed: u64) -> Dataset {
        generate(SynthKind::GaussianMixture { components: 4, spread: 4.0 }, n, d, seed, "g")
    }

    fn front_key(f: &ParetoFront) -> Vec<(u64, u64, Vec<u32>)> {
        f.points
            .iter()
            .map(|p| (p.diversity.to_bits(), p.dispersion.to_bits(), p.labels.clone()))
            .collect()
    }

    /// The determinism contract: Serial and Threads(3) runs produce
    /// bit-identical fronts, on flat, categorical, and zero-copy
    /// subset (hier-style) views.
    #[test]
    fn serial_and_pooled_fronts_bit_identical() {
        let flat = gaussian(90, 4, 61);
        let cats: Vec<u32> = (0..90).map(|i| (i % 2) as u32).collect();
        let categorical = gaussian(90, 4, 62).with_categories(cats).unwrap();
        let parent = gaussian(150, 4, 63);
        let idx: Vec<usize> = (0..90).map(|i| i + 30).collect();
        let hier_view = parent.view().select(&idx);
        let views: Vec<DataView<'_>> = vec![flat.view(), categorical.view(), hier_view];
        let pool = WorkerPool::new(3);
        let cfg = ParetoConfig { restarts: 7, passes: 2, partners: 6, ..Default::default() };
        for (t, view) in views.into_iter().enumerate() {
            let k = 5;
            let serial = pareto_front(&view, k, &cfg, None, None).unwrap();
            let pooled = pareto_front(&view, k, &cfg, None, Some(&pool)).unwrap();
            assert_eq!(front_key(&serial), front_key(&pooled), "view {t}");
            assert!(!serial.points.is_empty());
        }
    }

    /// The front weakly dominates the ABA seed's (diversity,
    /// dispersion) point at its extremes, and every reported point is
    /// internally consistent with a recompute.
    #[test]
    fn front_dominates_aba_seed_and_is_consistent() {
        let ds = gaussian(120, 4, 64);
        let view = ds.view();
        let k = 6;
        let aba = Aba::new().unwrap().partition(&ds, k).unwrap();
        let aba_div = super::super::interchange::recompute_diversity(&view, &aba.labels, k);
        let aba_disp = dispersion(&view, &aba.labels, k);
        let cfg = ParetoConfig { restarts: 6, ..Default::default() };
        let front = pareto_front(&view, k, &cfg, Some(&aba.labels), None).unwrap();
        let best_div = front.best_diversity().unwrap();
        let best_disp = front.best_dispersion().unwrap();
        assert!(best_div.diversity >= aba_div, "{} < {aba_div}", best_div.diversity);
        assert!(best_disp.dispersion >= aba_disp, "{} < {aba_disp}", best_disp.dispersion);
        for p in &front.points {
            assert_eq!(
                p.diversity.to_bits(),
                super::super::interchange::recompute_diversity(&view, &p.labels, k).to_bits()
            );
            assert_eq!(p.dispersion.to_bits(), dispersion(&view, &p.labels, k).to_bits());
            assert!(p.upper_bound >= p.diversity);
            assert!((0.0..=1.0).contains(&p.gap));
        }
        assert!(front.hypervolume((0.0, 0.0)) > 0.0);
    }

    /// Satellite: the singleton-dispersion precondition is a typed
    /// error, not `inf` in output.
    #[test]
    fn singleton_clusters_are_a_typed_error() {
        let ds = gaussian(9, 3, 65);
        let err = pareto_front(&ds.view(), 5, &ParetoConfig::default(), None, None).unwrap_err();
        match err {
            AbaError::InvalidK { k, n, reason } => {
                assert_eq!((k, n), (5, 9));
                assert!(reason.contains("dispersion"), "{reason}");
            }
            other => panic!("expected InvalidK, got {other:?}"),
        }
        // n == 2k is the smallest legal instance.
        let ds = gaussian(10, 3, 66);
        let cfg = ParetoConfig { restarts: 2, ..Default::default() };
        assert!(pareto_front(&ds.view(), 5, &cfg, None, None).is_ok());
    }

    #[test]
    fn zero_restarts_rejected() {
        let ds = gaussian(20, 3, 67);
        let cfg = ParetoConfig { restarts: 0, ..Default::default() };
        assert!(matches!(
            pareto_front(&ds.view(), 2, &cfg, None, None),
            Err(AbaError::InvalidInput(_))
        ));
    }

    #[test]
    fn mismatched_seed_shape_rejected() {
        let ds = gaussian(20, 3, 68);
        let seed = vec![0u32; 7];
        assert!(matches!(
            pareto_front(&ds.view(), 2, &ParetoConfig::default(), Some(&seed), None),
            Err(AbaError::BadShape(_))
        ));
    }
}
