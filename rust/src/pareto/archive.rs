//! Non-dominated archive of partitions over (diversity, dispersion).
//!
//! Both criteria are maximized. The archive keeps a mutually
//! non-dominated set of [`ParetoPoint`]s sorted by diversity descending
//! (equivalently dispersion ascending — on a front the two orders
//! coincide), with deterministic tie-breaking: a candidate whose
//! (diversity, dispersion) pair is weakly dominated by an incumbent —
//! including an exact duplicate — is rejected, so the first partition to
//! reach a point owns it. When the archive exceeds its configured
//! capacity it thins by crowding distance (NSGA-II style), never
//! dropping the two extreme points, removing the lowest-index point of
//! minimal crowding — all comparisons on exact `f64` values, so the
//! archive contents are a pure function of the insertion sequence.

/// One partition on (or once on) the front.
#[derive(Clone, Debug, PartialEq)]
pub struct ParetoPoint {
    /// Anticluster label per object (view-relative row order).
    pub labels: Vec<u32>,
    /// Centroid-form diversity objective (total within-anticluster SSD).
    pub diversity: f64,
    /// Minimum within-anticluster pairwise squared distance.
    pub dispersion: f64,
}

/// Bounded non-dominated archive (both criteria maximized).
#[derive(Clone, Debug)]
pub struct Archive {
    /// Sorted by diversity descending / dispersion ascending.
    points: Vec<ParetoPoint>,
    cap: usize,
}

/// `a` weakly dominates `b`: no worse on either criterion.
#[inline]
fn weakly_dominates(a: (f64, f64), b: (f64, f64)) -> bool {
    a.0 >= b.0 && a.1 >= b.1
}

impl Archive {
    /// An empty archive holding at most `cap` points (`cap >= 2` so the
    /// two extremes always survive thinning).
    pub fn new(cap: usize) -> Self {
        Self { points: Vec::new(), cap: cap.max(2) }
    }

    /// Configured capacity.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Points currently on the front, diversity descending.
    pub fn points(&self) -> &[ParetoPoint] {
        &self.points
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Consume the archive, yielding the front (diversity descending).
    pub fn into_points(self) -> Vec<ParetoPoint> {
        self.points
    }

    /// Offer a point. Returns `true` if it entered the archive (it may
    /// still be thinned away later by a capacity squeeze).
    pub fn insert(&mut self, p: ParetoPoint) -> bool {
        let key = (p.diversity, p.dispersion);
        if !key.0.is_finite() || !key.1.is_finite() {
            return false; // degenerate partitions never enter the front
        }
        if self
            .points
            .iter()
            .any(|q| weakly_dominates((q.diversity, q.dispersion), key))
        {
            return false; // an incumbent is at least as good everywhere
        }
        self.points
            .retain(|q| !weakly_dominates(key, (q.diversity, q.dispersion)));
        // Insertion sort position: diversity descending. Survivors never
        // tie with `key` on diversity (a tie would have resolved above).
        let pos = self
            .points
            .iter()
            .position(|q| q.diversity < p.diversity)
            .unwrap_or(self.points.len());
        self.points.insert(pos, p);
        while self.points.len() > self.cap {
            self.thin_once();
        }
        true
    }

    /// Drain another archive into this one (its insertion order).
    pub fn merge(&mut self, other: Archive) {
        for p in other.points {
            self.insert(p);
        }
    }

    /// Remove the lowest-index interior point of minimal crowding
    /// distance. Requires `len() > 2`.
    fn thin_once(&mut self) {
        debug_assert!(self.points.len() > 2);
        let last = self.points.len() - 1;
        let div_span =
            (self.points[0].diversity - self.points[last].diversity).max(f64::MIN_POSITIVE);
        let disp_span =
            (self.points[last].dispersion - self.points[0].dispersion).max(f64::MIN_POSITIVE);
        let mut victim = 1usize;
        let mut best = f64::INFINITY;
        for i in 1..last {
            let crowd = (self.points[i - 1].diversity - self.points[i + 1].diversity) / div_span
                + (self.points[i + 1].dispersion - self.points[i - 1].dispersion) / disp_span;
            if crowd < best {
                best = crowd;
                victim = i;
            }
        }
        self.points.remove(victim);
    }
}

/// 2-D hypervolume (both criteria maximized) of `points` against a
/// reference point `(ref_div, ref_disp)`: the area weakly dominated by
/// the set and dominating the reference. Points not strictly better
/// than the reference on both criteria contribute nothing.
pub fn hypervolume(points: &[(f64, f64)], ref_point: (f64, f64)) -> f64 {
    let mut ps: Vec<(f64, f64)> = points
        .iter()
        .copied()
        .filter(|&(a, b)| a > ref_point.0 && b > ref_point.1)
        .collect();
    // Diversity descending; the dominated-area sweep below only credits
    // dispersion above the running maximum, so dominated entries in the
    // list contribute zero and need no explicit filtering.
    ps.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut hv = 0f64;
    let mut prev_disp = ref_point.1;
    for (div, disp) in ps {
        if disp > prev_disp {
            hv += (div - ref_point.0) * (disp - prev_disp);
            prev_disp = disp;
        }
    }
    hv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn pt(div: f64, disp: f64) -> ParetoPoint {
        ParetoPoint { labels: vec![0], diversity: div, dispersion: disp }
    }

    fn is_front(points: &[ParetoPoint]) -> bool {
        for (i, a) in points.iter().enumerate() {
            for (j, b) in points.iter().enumerate() {
                if i != j
                    && weakly_dominates((a.diversity, a.dispersion), (b.diversity, b.dispersion))
                {
                    return false;
                }
            }
        }
        true
    }

    #[test]
    fn dominated_points_are_rejected_and_evicted() {
        let mut ar = Archive::new(10);
        assert!(ar.insert(pt(5.0, 1.0)));
        assert!(ar.insert(pt(3.0, 2.0)));
        assert!(!ar.insert(pt(4.0, 0.5))); // dominated by (5, 1)
        assert!(!ar.insert(pt(5.0, 1.0))); // exact duplicate: keep incumbent
        assert!(ar.insert(pt(6.0, 1.5))); // evicts (5, 1)
        let keys: Vec<(f64, f64)> =
            ar.points().iter().map(|p| (p.diversity, p.dispersion)).collect();
        assert_eq!(keys, vec![(6.0, 1.5), (3.0, 2.0)]);
    }

    #[test]
    fn non_domination_invariant_under_random_inserts() {
        // Property: after any insertion sequence, the archive is a
        // mutually non-dominated set, sorted by diversity descending,
        // within capacity, and still holds both extreme points.
        let mut rng = Pcg32::new(42);
        for cap in [2usize, 3, 8, 64] {
            let mut ar = Archive::new(cap);
            let mut best_div = f64::NEG_INFINITY;
            let mut best_disp = f64::NEG_INFINITY;
            for _ in 0..500 {
                let div = (rng.gen_below(50) as f64) / 3.0;
                let disp = (rng.gen_below(50) as f64) / 7.0;
                best_div = best_div.max(div.max(0.0));
                best_disp = best_disp.max(disp.max(0.0));
                ar.insert(pt(div, disp));
                assert!(ar.len() <= cap);
                assert!(is_front(ar.points()), "dominated pair survived");
                for w in ar.points().windows(2) {
                    assert!(w[0].diversity > w[1].diversity);
                    assert!(w[0].dispersion < w[1].dispersion);
                }
            }
            // Thinning never drops the extremes.
            assert_eq!(ar.points()[0].diversity, best_div);
            assert_eq!(ar.points()[ar.len() - 1].dispersion, best_disp);
        }
    }

    #[test]
    fn non_finite_points_never_enter() {
        let mut ar = Archive::new(4);
        assert!(!ar.insert(pt(f64::INFINITY, 1.0)));
        assert!(!ar.insert(pt(1.0, f64::NAN)));
        assert!(ar.is_empty());
    }

    #[test]
    fn merge_is_insertion_in_order() {
        let mut a = Archive::new(8);
        a.insert(pt(5.0, 1.0));
        let mut b = Archive::new(8);
        b.insert(pt(6.0, 2.0));
        b.insert(pt(4.0, 3.0));
        a.merge(b);
        assert_eq!(a.len(), 2); // (5,1) evicted by (6,2)
        assert!(is_front(a.points()));
    }

    #[test]
    fn hypervolume_rectangles() {
        // Two staircase points over the origin.
        let hv = hypervolume(&[(2.0, 1.0), (1.0, 3.0)], (0.0, 0.0));
        // (2,1): 2x1 = 2; (1,3) adds 1 * (3-1) = 2.
        assert_eq!(hv, 4.0);
        // Points at or below the reference contribute nothing.
        assert_eq!(hypervolume(&[(0.0, 5.0), (5.0, 0.0)], (0.0, 0.0)), 0.0);
        // Dominated points add nothing.
        let hv2 = hypervolume(&[(2.0, 1.0), (1.0, 3.0), (1.0, 0.5)], (0.0, 0.0));
        assert_eq!(hv2, 4.0);
        assert_eq!(hypervolume(&[], (0.0, 0.0)), 0.0);
    }
}
