//! Bicriterion pairwise-interchange local search.
//!
//! One search state holds a balanced partition and maintains both
//! criteria under object swaps:
//!
//! * **Diversity** (total within-anticluster SSD) is priced in O(d) per
//!   candidate by cloning the two touched [`ClusterDelta`]s into
//!   scratch and folding the swap through them. Applied swaps rebuild
//!   the two touched clusters *canonically* (ascending member order via
//!   [`ClusterDelta::from_rows`]) — the online subsystem's convention —
//!   so the maintained total is **bit-identical** to a from-scratch
//!   recompute ([`recompute_diversity`]) at every step.
//! * **Dispersion** (minimum within-anticluster pairwise squared
//!   distance) is maintained by [`DispersionState`]: a per-cluster
//!   *near-pair threshold list* holding every pair at distance ≤ τ_c.
//!   Because any listed survivor is ≤ τ_c while every unlisted pair is
//!   > τ_c, the list alone prices "minimum with member x swapped out"
//!   exactly; when a removal drains a list the cluster falls back to a
//!   full scan / rebuild. Minima are folds over exact `f64` distance
//!   values, so incremental maintenance is bit-identical to
//!   [`crate::algo::objective::dispersion`] by construction (and
//!   property-tested to be).
//!
//! Candidate swaps are scored by a weighted scalarization
//! `w·Δdiversity/scale_div + (1−w)·Δdispersion/scale_disp` (scales
//! frozen at the starting point); the per-object best strictly
//! improving swap is applied, one pass touching every object once.
//! Swaps exchange two objects' memberships, so anticluster sizes (and
//! per-category counts in categorical mode) are invariant.

use crate::algo::objective::ClusterDelta;
use crate::data::DataView;
use crate::metrics::members_of;
use crate::rng::Pcg32;
use std::borrow::Cow;

/// Minimum scalarized score for a swap to count as improving.
const GAIN_EPS: f64 = 1e-9;

/// Canonical from-scratch diversity recompute: per-cluster
/// [`ClusterDelta::from_rows`] in ascending member order, summed in
/// cluster order — the bit-identity anchor for the maintained value.
pub fn recompute_diversity(ds: &DataView<'_>, labels: &[u32], k: usize) -> f64 {
    (0..k)
        .map(|c| {
            ClusterDelta::from_rows(ds.d(), members_of(labels, c as u32).map(|i| ds.row(i))).ssd()
        })
        .sum()
}

/// Incrementally maintained dispersion state: per-cluster sorted member
/// lists, near-pair threshold lists, and cached exact minima.
#[derive(Clone, Debug)]
pub struct DispersionState {
    /// `members[c]`: ascending view-row ids of anticluster `c`.
    members: Vec<Vec<u32>>,
    /// `pairs[c]`: every within-cluster pair `(i, j, dist2)` with
    /// `dist2 <= tau[c]` (`i < j`).
    pairs: Vec<Vec<(u32, u32, f64)>>,
    tau: Vec<f64>,
    /// Cached exact per-cluster minima (`INFINITY` below two members).
    min: Vec<f64>,
}

impl DispersionState {
    pub fn build(ds: &DataView<'_>, labels: &[u32], k: usize) -> Self {
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); k];
        for (i, &c) in labels.iter().enumerate() {
            members[c as usize].push(i as u32);
        }
        let mut st = Self {
            members,
            pairs: vec![Vec::new(); k],
            tau: vec![f64::INFINITY; k],
            min: vec![f64::INFINITY; k],
        };
        for c in 0..k {
            st.rebuild_cluster(ds, c);
        }
        st
    }

    /// Near pairs to keep for a cluster of `m` members.
    fn keep_target(m: usize) -> usize {
        (4 * m).max(16)
    }

    fn rebuild_cluster(&mut self, ds: &DataView<'_>, c: usize) {
        let ms = &self.members[c];
        let m = ms.len();
        self.pairs[c].clear();
        if m < 2 {
            self.tau[c] = f64::INFINITY;
            self.min[c] = f64::INFINITY;
            return;
        }
        let mut all: Vec<f64> = Vec::with_capacity(m * (m - 1) / 2);
        for (a, &i) in ms.iter().enumerate() {
            for &j in &ms[a + 1..] {
                all.push(ds.dist2(i as usize, j as usize));
            }
        }
        let keep = Self::keep_target(m).min(all.len());
        let mut sorted = all.clone();
        sorted.sort_by(|x, y| x.partial_cmp(y).expect("finite distances"));
        let tau = sorted[keep - 1];
        self.tau[c] = tau;
        let mut flat = all.into_iter();
        for (a, &i) in ms.iter().enumerate() {
            for &j in &ms[a + 1..] {
                let d2 = flat.next().expect("pair count");
                if d2 <= tau {
                    self.pairs[c].push((i, j, d2));
                }
            }
        }
        self.min[c] = sorted[0];
    }

    /// Exact global dispersion (minimum over clusters).
    pub fn dispersion(&self) -> f64 {
        self.min.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Exact minimum of one cluster.
    pub fn cluster_min(&self, c: usize) -> f64 {
        self.min[c]
    }

    /// Price the minimum of cluster `c` after member `out` is replaced
    /// by non-member `inc` — exact, without mutating. Listed survivors
    /// are ≤ τ_c while unlisted pairs are > τ_c, so the list minimum is
    /// the true minimum whenever any survivor remains; otherwise the
    /// remaining members are scanned in full.
    pub fn price_swap(&self, ds: &DataView<'_>, c: usize, out: u32, inc: u32) -> f64 {
        let ms = &self.members[c];
        let mut best = f64::INFINITY;
        let mut survivors = 0usize;
        for &(a, b, d2) in &self.pairs[c] {
            if a != out && b != out {
                survivors += 1;
                best = best.min(d2);
            }
        }
        if survivors == 0 && ms.len() >= 3 {
            for (ai, &i) in ms.iter().enumerate() {
                if i == out {
                    continue;
                }
                for &j in &ms[ai + 1..] {
                    if j != out {
                        best = best.min(ds.dist2(i as usize, j as usize));
                    }
                }
            }
        }
        for &i in ms {
            if i != out {
                best = best.min(ds.dist2(inc as usize, i as usize));
            }
        }
        best
    }

    /// Apply a swap on cluster `c`: member `out` leaves, `inc` arrives.
    pub fn apply_swap(&mut self, ds: &DataView<'_>, c: usize, out: u32, inc: u32) {
        let pos = self.members[c].binary_search(&out).expect("departing member present");
        self.members[c].remove(pos);
        self.pairs[c].retain(|&(a, b, _)| a != out && b != out);
        let tau = self.tau[c];
        for &i in &self.members[c] {
            let d2 = ds.dist2(inc as usize, i as usize);
            if d2 <= tau {
                self.pairs[c].push((inc.min(i), inc.max(i), d2));
            }
        }
        let pos = self.members[c].binary_search(&inc).expect_err("arriving member absent");
        self.members[c].insert(pos, inc);
        let m = self.members[c].len();
        if m < 2 {
            self.min[c] = f64::INFINITY;
            self.pairs[c].clear();
        } else if self.pairs[c].is_empty() || self.pairs[c].len() > 4 * Self::keep_target(m) {
            // Drained (threshold no longer witnesses the minimum) or
            // bloated (stale τ lists too many pairs): re-tighten.
            self.rebuild_cluster(ds, c);
        } else {
            self.min[c] = self.pairs[c].iter().map(|p| p.2).fold(f64::INFINITY, f64::min);
        }
    }
}

/// One bicriterion local-search state over a balanced partition.
pub struct Interchange<'a> {
    ds: DataView<'a>,
    k: usize,
    labels: Vec<u32>,
    cats: Option<Cow<'a, [u32]>>,
    deltas: Vec<ClusterDelta>,
    disp: DispersionState,
    diversity: f64,
    div_scale: f64,
    disp_scale: f64,
    scratch_a: ClusterDelta,
    scratch_b: ClusterDelta,
}

impl<'a> Interchange<'a> {
    pub fn new(ds: DataView<'a>, labels: Vec<u32>, k: usize) -> Self {
        assert_eq!(labels.len(), ds.n());
        let d = ds.d();
        let deltas: Vec<ClusterDelta> = (0..k)
            .map(|c| ClusterDelta::from_rows(d, members_of(&labels, c as u32).map(|i| ds.row(i))))
            .collect();
        let diversity: f64 = deltas.iter().map(|cd| cd.ssd()).sum();
        let disp = DispersionState::build(&ds, &labels, k);
        let dispersion = disp.dispersion();
        let cats = ds.categories();
        Self {
            k,
            labels,
            cats,
            deltas,
            disp,
            diversity,
            div_scale: if diversity > 0.0 { diversity } else { 1.0 },
            disp_scale: if dispersion.is_finite() && dispersion > 0.0 { dispersion } else { 1.0 },
            scratch_a: ClusterDelta::new(d),
            scratch_b: ClusterDelta::new(d),
            ds,
        }
    }

    pub fn labels(&self) -> &[u32] {
        &self.labels
    }

    /// Maintained diversity — bit-identical to [`recompute_diversity`].
    pub fn diversity(&self) -> f64 {
        self.diversity
    }

    /// Maintained dispersion — bit-identical to
    /// [`crate::algo::objective::dispersion`].
    pub fn dispersion(&self) -> f64 {
        self.disp.dispersion()
    }

    /// Scalarized score of swapping objects `i` and `j` under weight
    /// `w` (1 = pure diversity, 0 = pure dispersion). O(d + L).
    fn price(&mut self, i: usize, j: usize, w: f64) -> f64 {
        let (a, b) = (self.labels[i] as usize, self.labels[j] as usize);
        let (xi, xj) = (self.ds.row(i), self.ds.row(j));
        self.scratch_a.clone_from(&self.deltas[a]);
        self.scratch_a.remove(xi);
        self.scratch_a.add(xj);
        self.scratch_b.clone_from(&self.deltas[b]);
        self.scratch_b.remove(xj);
        self.scratch_b.add(xi);
        let new_div = self.diversity - self.deltas[a].ssd() - self.deltas[b].ssd()
            + self.scratch_a.ssd()
            + self.scratch_b.ssd();
        let mut new_disp = f64::INFINITY;
        for c in 0..self.k {
            if c != a && c != b {
                new_disp = new_disp.min(self.disp.cluster_min(c));
            }
        }
        new_disp = new_disp.min(self.disp.price_swap(&self.ds, a, i as u32, j as u32));
        new_disp = new_disp.min(self.disp.price_swap(&self.ds, b, j as u32, i as u32));
        w * (new_div - self.diversity) / self.div_scale
            + (1.0 - w) * (new_disp - self.disp.dispersion()) / self.disp_scale
    }

    /// Apply the swap `i <-> j`, rebuilding the two touched clusters
    /// canonically so both maintained criteria stay recompute-exact.
    fn apply(&mut self, i: usize, j: usize) {
        let (a, b) = (self.labels[i] as usize, self.labels[j] as usize);
        self.disp.apply_swap(&self.ds, a, i as u32, j as u32);
        self.disp.apply_swap(&self.ds, b, j as u32, i as u32);
        self.labels[i] = b as u32;
        self.labels[j] = a as u32;
        let d = self.ds.d();
        let da =
            ClusterDelta::from_rows(d, members_of(&self.labels, a as u32).map(|r| self.ds.row(r)));
        let db =
            ClusterDelta::from_rows(d, members_of(&self.labels, b as u32).map(|r| self.ds.row(r)));
        self.deltas[a] = da;
        self.deltas[b] = db;
        self.diversity = self.deltas.iter().map(|cd| cd.ssd()).sum();
    }

    /// One full pass: for each object, draw `partners` random candidate
    /// partners from `rng`, apply the best strictly improving swap
    /// (same-category only in categorical mode), and report each new
    /// state through `on_swap(labels, diversity, dispersion)`. Returns
    /// the number of swaps applied.
    pub fn pass(
        &mut self,
        rng: &mut Pcg32,
        w: f64,
        partners: usize,
        mut on_swap: impl FnMut(&[u32], f64, f64),
    ) -> usize {
        let n = self.ds.n();
        let mut swaps = 0usize;
        for i in 0..n {
            let a = self.labels[i] as usize;
            let mut best: Option<(usize, f64)> = None;
            for _ in 0..partners {
                let j = rng.gen_index(n);
                if j == i || self.labels[j] as usize == a {
                    continue;
                }
                if let Some(cats) = &self.cats {
                    if cats[i] != cats[j] {
                        continue;
                    }
                }
                let score = self.price(i, j, w);
                if score > GAIN_EPS && best.map_or(true, |(_, s)| score > s) {
                    best = Some((j, score));
                }
            }
            if let Some((j, _)) = best {
                self.apply(i, j);
                swaps += 1;
                on_swap(&self.labels, self.diversity, self.disp.dispersion());
            }
        }
        swaps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::objective::dispersion;
    use crate::baselines::random_part::random_partition;
    use crate::data::synth::{generate, SynthKind};
    use crate::data::Dataset;

    fn gaussian(n: usize, d: usize, seed: u64) -> Dataset {
        generate(SynthKind::GaussianMixture { components: 4, spread: 4.0 }, n, d, seed, "g")
    }

    /// The satellite matrix: maintained criteria must equal the
    /// from-scratch recomputes bit for bit after every pass, on flat,
    /// categorical, and zero-copy subset (hier-style) views.
    #[test]
    fn maintained_criteria_bit_identical_to_recompute() {
        let flat = gaussian(120, 5, 31);
        let cats: Vec<u32> = (0..120).map(|i| (i % 2) as u32).collect();
        let categorical = gaussian(120, 5, 32).with_categories(cats).unwrap();
        let parent = gaussian(200, 5, 33);
        let idx: Vec<usize> = (0..120).map(|i| i + 40).collect();
        let hier_view = parent.view().select(&idx);
        let views: Vec<DataView<'_>> =
            vec![flat.view(), categorical.view(), hier_view];
        for (t, view) in views.into_iter().enumerate() {
            let k = 6;
            let labels = random_partition(view.n(), k, 100 + t as u64);
            let mut search = Interchange::new(view.clone(), labels, k);
            let mut rng = Pcg32::new(7 + t as u64);
            for (pass, w) in [1.0, 0.5, 0.0, 0.8].into_iter().enumerate() {
                search.pass(&mut rng, w, 8, |_, _, _| {});
                let div = recompute_diversity(&view, search.labels(), k);
                let disp = dispersion(&view, search.labels(), k);
                assert_eq!(
                    search.diversity().to_bits(),
                    div.to_bits(),
                    "view {t} pass {pass}: diversity {} vs recompute {div}",
                    search.diversity()
                );
                assert_eq!(
                    search.dispersion().to_bits(),
                    disp.to_bits(),
                    "view {t} pass {pass}: dispersion {} vs recompute {disp}",
                    search.dispersion()
                );
            }
        }
    }

    /// `price_swap` must predict the post-swap cluster minimum exactly.
    #[test]
    fn dispersion_pricing_matches_applied_swap() {
        let ds = gaussian(80, 4, 40);
        let view = ds.view();
        let k = 4;
        let labels = random_partition(80, k, 9);
        let mut st = DispersionState::build(&view, &labels, k);
        let mut labels = labels;
        let mut rng = Pcg32::new(11);
        for _ in 0..200 {
            let i = rng.gen_index(80);
            let j = rng.gen_index(80);
            let (a, b) = (labels[i] as usize, labels[j] as usize);
            if i == j || a == b {
                continue;
            }
            let pa = st.price_swap(&view, a, i as u32, j as u32);
            let pb = st.price_swap(&view, b, j as u32, i as u32);
            st.apply_swap(&view, a, i as u32, j as u32);
            st.apply_swap(&view, b, j as u32, i as u32);
            labels[i] = b as u32;
            labels[j] = a as u32;
            assert_eq!(st.cluster_min(a).to_bits(), pa.to_bits());
            assert_eq!(st.cluster_min(b).to_bits(), pb.to_bits());
            assert_eq!(st.dispersion().to_bits(), dispersion(&view, &labels, k).to_bits());
        }
    }

    #[test]
    fn swaps_preserve_sizes_and_categories() {
        let n = 90;
        let cats: Vec<u32> = (0..n).map(|i| (i % 3) as u32).collect();
        let ds = gaussian(n, 3, 50).with_categories(cats.clone()).unwrap();
        let view = ds.view();
        let k = 3;
        let labels = crate::baselines::random_part::random_partition_categorical(&cats, k, 4);
        let init = labels.clone();
        let mut search = Interchange::new(view, labels, k);
        let mut rng = Pcg32::new(3);
        let swaps = search.pass(&mut rng, 0.7, 10, |_, _, _| {});
        assert!(swaps > 0, "expected the pass to find improving swaps");
        // Per-category-per-cluster counts are invariant under swaps.
        for g in 0..3u32 {
            for c in 0..k as u32 {
                let cnt = |ls: &[u32]| (0..n).filter(|&i| cats[i] == g && ls[i] == c).count();
                assert_eq!(cnt(&init), cnt(search.labels()), "category {g} cluster {c}");
            }
        }
    }
}
