//! The unified solver API: [`Anticlusterer`] sessions and rich
//! [`Partition`] results.
//!
//! Every partitioning algorithm in the crate — ABA itself and the
//! baselines (`Rand`, `fast_anticlustering`, branch-and-bound) — sits
//! behind one trait, so callers (the CLI, the mini-batch pipeline, the
//! experiment harness) can swap algorithms without changing shape:
//!
//! ```no_run
//! use aba::{Aba, Anticlusterer};
//! use aba::baselines::RandomPartition;
//! use aba::data::synth::{generate, SynthKind};
//!
//! let ds = generate(SynthKind::Uniform, 1_000, 8, 1, "demo");
//! let mut solvers: Vec<Box<dyn Anticlusterer>> = vec![
//!     Box::new(Aba::builder().build()?),
//!     Box::new(RandomPartition::new(7)),
//! ];
//! for s in solvers.iter_mut() {
//!     let part = s.partition(&ds, 10)?;
//!     println!("{:>12}: objective {:.1}", s.name(), part.objective);
//! }
//! # Ok::<(), aba::AbaError>(())
//! ```
//!
//! An [`Aba`] value is a *session*: it owns its cost backend (including
//! any compiled XLA executables), its constraint set, and the assignment
//! loop's scratch buffers, all of which are reused across `partition`
//! calls. Repeated partitioning — K-fold CV, per-epoch mini-batch
//! construction, serving — should build one session and keep calling it
//! rather than paying construction and warm-up on every call (see
//! `benches/bench_aba.rs` for the measured difference).

use crate::algo::{self, AbaConfig, ClusterStats, Constraints, Criterion, Variant};
use crate::assignment::{CandidateMode, SolverKind, SparseStats};
use crate::cert;
use crate::data::{DataView, Dataset};
use crate::error::{AbaError, AbaResult};
use crate::online::OnlinePartition;
use crate::pareto::{ParetoConfig, ParetoFront};
use crate::runtime::{make_backend, BackendKind, CostBackend, KernelMode, Kernels, Parallelism};
use std::time::Instant;

/// A configured, reusable anticlustering algorithm.
///
/// `&mut self` lets implementations keep state across calls: scratch
/// buffers, compiled executables, RNG state.
///
/// The required entry point is [`Anticlusterer::partition_view`], which
/// consumes a borrowed zero-copy [`DataView`] — partitioning any index
/// subset of a dataset costs no feature-row copy. [`Anticlusterer::partition`]
/// is a provided convenience over the identity view, so existing
/// `partition(&ds, k)` call sites keep working unchanged.
pub trait Anticlusterer {
    /// Partition the rows of `view` into `k` anticlusters.
    fn partition_view(&mut self, view: &DataView<'_>, k: usize) -> AbaResult<Partition>;

    /// Partition a whole dataset — a convenience over
    /// [`Anticlusterer::partition_view`] on the identity view.
    fn partition(&mut self, ds: &Dataset, k: usize) -> AbaResult<Partition> {
        self.partition_view(&ds.view(), k)
    }

    /// Short human-readable algorithm name (used in tables and logs).
    fn name(&self) -> String;
}

/// Wall-clock breakdown of one `partition` call, in seconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimings {
    /// Building the processing order (centroid distances + sort).
    pub order_secs: f64,
    /// The assignment loop (cost matrices + LAP solves), or the whole
    /// solve for algorithms without a separate ordering phase.
    pub assign_secs: f64,
    /// Computing the result's `ClusterStats`.
    pub stats_secs: f64,
    /// Sum of the phases.
    pub total_secs: f64,
    /// The distance-kernel ISA the solve ran with (`"scalar"`, `"avx2"`,
    /// `"avx2+fma"`, `"avx512f"`, `"neon"` — see
    /// [`crate::runtime::Kernels::isa`]). Empty for algorithms that do
    /// not go through the kernel layer's f32 cost tier (the baselines).
    pub kernel_isa: &'static str,
}

impl PhaseTimings {
    /// Algorithm-only seconds (ordering + assignment), excluding the
    /// stats pass — what runtime tables should report, matching the
    /// paper's convention.
    pub fn algo_secs(&self) -> f64 {
        self.order_secs + self.assign_secs
    }
}

/// A partition plus everything callers previously recomputed by hand:
/// cluster sizes, both paper objectives, per-cluster diversity stats, and
/// a phase-timing breakdown.
#[derive(Clone, Debug)]
pub struct Partition {
    /// Anticluster label in `0..k` per object.
    pub labels: Vec<u32>,
    /// Number of anticlusters.
    pub k: usize,
    /// Centroid-form objective: total SSD to anticluster centroids (the
    /// `ofv` of the paper's Tables 4/9).
    pub objective: f64,
    /// Pairwise objective `W(C)` via Fact 1.
    pub pairwise: f64,
    /// Per-anticluster sizes and diversities.
    pub stats: ClusterStats,
    /// Where the time went.
    pub timings: PhaseTimings,
}

impl Partition {
    /// Assemble a `Partition` from raw labels, computing the stats and
    /// stamping the stats phase into `timings`. Accepts a `&Dataset` or
    /// the [`DataView`] the labels were computed over.
    pub fn from_labels<'a>(
        data: impl Into<DataView<'a>>,
        labels: Vec<u32>,
        k: usize,
        mut timings: PhaseTimings,
    ) -> Self {
        let t = Instant::now();
        let stats = ClusterStats::compute(data, &labels, k);
        timings.stats_secs = t.elapsed().as_secs_f64();
        timings.total_secs = timings.order_secs + timings.assign_secs + timings.stats_secs;
        let objective = stats.ssd_total();
        let pairwise = stats.pairwise_total();
        Self { labels, k, objective, pairwise, stats, timings }
    }

    /// Objects per anticluster.
    pub fn sizes(&self) -> &[usize] {
        &self.stats.sizes
    }

    /// Object indices grouped by anticluster (e.g. one group = one
    /// mini-batch in the SGD pipeline). Walking a *single* cluster does
    /// not need this materialization — use [`Partition::members_of`].
    pub fn groups(&self) -> Vec<Vec<usize>> {
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.k];
        for (i, &l) in self.labels.iter().enumerate() {
            groups[l as usize].push(i);
        }
        groups
    }

    /// Iterate the object indices of anticluster `c` without allocating
    /// (the non-materializing alternative to [`Partition::groups`];
    /// shared with raw label vectors via [`crate::metrics::members_of`]).
    pub fn members_of(&self, c: usize) -> impl Iterator<Item = usize> + Clone + '_ {
        crate::metrics::members_of(&self.labels, c as u32)
    }

    /// Certified upper bound on the centroid-form diversity objective
    /// of **any** balanced partition of this data: `objective + BGSS`
    /// by the total-sum identity `TSS = WGSS + BGSS` (see
    /// [`crate::cert::bounds`]). `BGSS` is a sum of non-negative
    /// terms, so `upper_bound() >= objective` holds exactly in
    /// floating point. Free: derived from the stats every solve
    /// already computes.
    pub fn upper_bound(&self) -> f64 {
        cert::bounds::upper_bound_from_stats(&self.stats)
    }

    /// Relative optimality gap `(upper_bound − objective) /
    /// upper_bound` in `[0, 1]`: `0.02` certifies the solution within
    /// 2% of the best possible diversity (0 on degenerate data).
    pub fn gap(&self) -> f64 {
        cert::bounds::gap(self.objective, self.upper_bound())
    }
}

/// Builder for an [`Aba`] session. All knobs default to the paper's
/// production configuration (LAPJV, native backend, automatic variant and
/// hierarchical decomposition).
#[derive(Clone, Debug, Default)]
pub struct AbaBuilder {
    cfg: AbaConfig,
    constraints: Option<Constraints>,
    pareto: Option<ParetoConfig>,
}

impl AbaBuilder {
    /// Batch-ordering variant (§4.1/§4.2).
    pub fn variant(mut self, v: Variant) -> Self {
        self.cfg.variant = v;
        self
    }

    /// Per-batch assignment solver.
    pub fn solver(mut self, s: SolverKind) -> Self {
        self.cfg.solver = s;
        self
    }

    /// Cost-matrix backend (native loops or the AOT Pallas/XLA artifact).
    pub fn backend(mut self, b: BackendKind) -> Self {
        self.cfg.backend = b;
        self
    }

    /// Explicit hierarchical decomposition `[K1, K2, ...]`; the product
    /// must equal the `k` later passed to `partition`.
    pub fn hier(mut self, spec: Vec<usize>) -> Self {
        self.cfg.hier = Some(spec);
        self
    }

    /// Apply the Table-5 decomposition policy automatically for large K.
    pub fn auto_hier(mut self, on: bool) -> Self {
        self.cfg.auto_hier = on;
        self
    }

    /// How much parallelism the session may use ([`Parallelism::Serial`]
    /// by default). A non-serial setting builds one worker pool per
    /// session — reused across `partition` calls — that
    /// chunk-parallelizes cost matrices, double-buffers batch staging,
    /// and fans hierarchical subproblems out. With the native backend
    /// (the default), parallel and serial runs produce bit-identical
    /// labels; with the XLA backend, fanned-out hierarchical levels use
    /// the native kernels and match serial results only within numeric
    /// tolerance (see [`crate::algo::hierarchical`]).
    pub fn parallelism(mut self, p: Parallelism) -> Self {
        self.cfg.parallelism = p;
        self
    }

    /// Fan work out over all cores (`true` maps to
    /// [`Parallelism::Auto`]).
    #[deprecated(
        since = "0.2.0",
        note = "superseded by `parallelism(Parallelism::Auto)`; will be removed in 0.3.0"
    )]
    pub fn parallel(self, on: bool) -> Self {
        self.parallelism(if on { Parallelism::Auto } else { Parallelism::Serial })
    }

    /// Error (instead of warn) when `n % k != 0`, i.e. when anticlusters
    /// cannot all have exactly equal size.
    pub fn strict_divisibility(mut self, on: bool) -> Self {
        self.cfg.strict_divisibility = on;
        self
    }

    /// Candidate pruning for the per-batch assignment (the sparse
    /// large-K path; CLI: `--candidates auto|<C>|dense`).
    /// [`CandidateMode::Dense`] is the paper-exact dense solve;
    /// [`CandidateMode::Fixed`]`(C)` scores each object only against its
    /// top-`C` highest-cost anticlusters (with automatic feasibility
    /// repair and dense fallback); [`CandidateMode::Auto`] (default)
    /// goes sparse once `k >= 512`. `C >= k` is bit-identical to
    /// `Dense`. Telemetry: [`Aba::sparse_stats`].
    pub fn candidates(mut self, c: CandidateMode) -> Self {
        self.cfg.candidates = c;
        self
    }

    /// Override the LAPJV warm-start heuristic for this session. The
    /// default (unset) consults the `ABA_LAPJV_WARM` env var once, here
    /// at construction — the per-run hot path never reads the
    /// environment. Cold start is the measured-faster default on ABA's
    /// structured cost matrices.
    pub fn lapjv_warm_start(mut self, on: bool) -> Self {
        self.cfg.lapjv_warm = Some(on);
        self
    }

    /// The objective to optimize ([`Criterion::Diversity`] by
    /// default). [`Criterion::Dispersion`] dispatches `k == 2` solves
    /// to the exact polynomial coloring algorithm
    /// ([`crate::cert::two_color`]) and rejects `k != 2`, constrained
    /// sessions, and online partitioning with typed errors — the
    /// dispersion objective has no heuristic fallback in this crate.
    pub fn criterion(mut self, c: Criterion) -> Self {
        self.cfg.criterion = c;
        self
    }

    /// Compute a standalone quality certificate
    /// ([`crate::cert::bounds::Certificate`]) on every solve,
    /// readable via [`Aba::last_certificate`]. The certification pass
    /// is O(nd), runs on the session worker pool under a non-serial
    /// [`AbaBuilder::parallelism`], and is timed separately from the
    /// solve phases. `Partition::upper_bound()`/`gap()` work without
    /// this knob; enable it when you want the certificate's wall time
    /// reported (CLI `run --certify`, the `certify` bench section).
    pub fn certify(mut self, on: bool) -> Self {
        self.cfg.certify = on;
        self
    }

    /// Override the distance-kernel dispatch mode for this session. The
    /// default (unset) consults the `ABA_KERNELS` env var once, here at
    /// construction — the per-run hot path never reads the environment.
    /// [`KernelMode::Auto`] and [`KernelMode::Scalar`] are bit-identical
    /// to each other on every host; [`KernelMode::Fma`] opts into
    /// fused-multiply-add contraction (ULP-bounded, not bit-identical);
    /// [`KernelMode::FastMath`] opts into the relaxed-determinism
    /// throughput tier (register-blocked FMA panels, AVX-512 where
    /// available — labels may differ from scalar, objective gap
    /// bench-gated in ppm). The selection is surfaced as
    /// [`PhaseTimings::kernel_isa`] and never enters snapshot
    /// fingerprints.
    pub fn kernels(mut self, mode: KernelMode) -> Self {
        self.cfg.kernels = Some(mode);
        self
    }

    /// Configuration for [`Aba::pareto_front`] (the bicriterion
    /// multi-restart engine of [`crate::pareto`]). Optional: sessions
    /// built without it fall back to [`ParetoConfig::default`] when
    /// `pareto_front` is called. Like `constraints`, this rides on the
    /// session beside [`AbaConfig`] — it never enters config
    /// fingerprints or snapshots.
    pub fn pareto(mut self, cfg: ParetoConfig) -> Self {
        self.pareto = Some(cfg);
        self
    }

    /// Must-link / cannot-link constraints enforced on every partition.
    /// The constrained loop uses its own super-object ordering and
    /// masking-heavy dense costs, so `variant`, `hier`, `auto_hier`,
    /// and `candidates` (the sparse path) do not apply when constraints
    /// are set; `solver` and `backend` do.
    pub fn constraints(mut self, cons: Constraints) -> Self {
        self.constraints = Some(cons);
        self
    }

    /// Construct the session. Fails with
    /// [`AbaError::BackendUnavailable`] when the requested backend cannot
    /// be built (e.g. XLA artifacts missing) and with
    /// [`AbaError::BadHierSpec`] for a degenerate explicit spec.
    pub fn build(self) -> AbaResult<Aba> {
        if let Some(spec) = &self.cfg.hier {
            if spec.is_empty() || spec.iter().any(|&f| f == 0) {
                return Err(AbaError::BadHierSpec(format!(
                    "factors must be >= 1, got {spec:?}"
                )));
            }
        }
        let mut backend = make_backend(self.cfg.backend)?;
        // Like the warm-start hoist below: kernel dispatch happens
        // exactly once, here — runtime CPU-feature detection and the
        // `ABA_KERNELS` env var are never consulted on the hot path.
        let kernels = match self.cfg.kernels {
            Some(mode) => Kernels::select(mode),
            None => Kernels::get(),
        };
        backend.set_kernels(kernels);
        // The satellite of the warm-start hoist: the env var is read
        // exactly once, here, unless the builder overrode it.
        let warm = self
            .cfg
            .lapjv_warm
            .unwrap_or_else(algo::core::warm_start_env_default);
        Ok(Aba {
            cfg: self.cfg,
            constraints: self.constraints,
            pareto: self.pareto,
            backend,
            kernels,
            scratch: algo::core::Scratch::with_lapjv_warm(warm),
            last_cert: None,
        })
    }
}

/// A reusable ABA session: configuration + owned backend + scratch.
///
/// Build with [`Aba::builder`] (or [`Aba::new`] / [`Aba::from_config`]),
/// then call [`Anticlusterer::partition`] as many times as needed; the
/// cost backend (and, for `--backend xla`, its compiled PJRT
/// executables) and the assignment loop's scratch buffers persist across
/// calls.
pub struct Aba {
    cfg: AbaConfig,
    constraints: Option<Constraints>,
    pareto: Option<ParetoConfig>,
    backend: Box<dyn CostBackend>,
    kernels: Kernels,
    scratch: algo::core::Scratch,
    last_cert: Option<cert::Certificate>,
}

impl Aba {
    /// Start building a session.
    pub fn builder() -> AbaBuilder {
        AbaBuilder::default()
    }

    /// A session with the default configuration.
    pub fn new() -> AbaResult<Self> {
        Self::builder().build()
    }

    /// A session from an existing [`AbaConfig`].
    pub fn from_config(cfg: AbaConfig) -> AbaResult<Self> {
        AbaBuilder { cfg, constraints: None, pareto: None }.build()
    }

    /// The session's configuration.
    pub fn config(&self) -> &AbaConfig {
        &self.cfg
    }

    /// The distance-kernel ISA this session dispatches to (`"scalar"`,
    /// `"avx2"`, `"avx2+fma"`, `"avx512f"`, `"neon"`). Fixed at
    /// [`AbaBuilder::build`]; also stamped on every solve as
    /// [`PhaseTimings::kernel_isa`].
    pub fn kernel_isa(&self) -> &'static str {
        self.kernels.isa()
    }

    /// Telemetry for the candidate-pruned assignment path, accumulated
    /// across this session's `partition` calls: batches solved sparsely
    /// vs densely, feasibility-repair escalations and fallbacks, and
    /// the peak per-batch cost-structure bytes.
    pub fn sparse_stats(&self) -> SparseStats {
        self.scratch.sparse_stats()
    }

    /// The quality certificate computed by the most recent solve, when
    /// the session was built with [`AbaBuilder::certify`]`(true)`
    /// (`None` otherwise, and before the first solve). Carries the
    /// instance's total sum of squares, the diversity and pairwise
    /// upper bounds, and the certification wall time.
    pub fn last_certificate(&self) -> Option<&cert::Certificate> {
        self.last_cert.as_ref()
    }

    /// Reset the accumulated [`Aba::sparse_stats`] counters to zero.
    /// Serving processes call this between requests (paired with
    /// [`crate::data::view::reset_gathered_bytes`]) so telemetry is
    /// per-request rather than session-lifetime.
    pub fn reset_sparse_stats(&mut self) {
        self.scratch.reset_sparse_stats();
    }

    /// The label-producing core shared by [`Aba::partition_online`] and
    /// the frozen [`Anticlusterer::partition_view`] path. Each branch
    /// validates exactly once: the constrained loop validates
    /// internally; the other paths validate here.
    fn partition_labels(
        &mut self,
        view: &DataView<'_>,
        k: usize,
    ) -> AbaResult<(Vec<u32>, PhaseTimings)> {
        let (labels, mut timings) = self.partition_labels_inner(view, k)?;
        // Stamp the effective kernel ISA once here so both the frozen
        // and online paths report it.
        timings.kernel_isa = self.kernels.isa();
        // The optional standalone certificate rides on every solve so
        // both the frozen and online paths report it. Timed on its
        // own: the O(nd) pass is not part of the solve phases.
        self.last_cert = if self.cfg.certify {
            let pool = self.scratch.pool_for(self.cfg.parallelism);
            Some(cert::bounds::certify_with_pool(view, k, pool.as_deref())?)
        } else {
            None
        };
        Ok((labels, timings))
    }

    fn partition_labels_inner(
        &mut self,
        view: &DataView<'_>,
        k: usize,
    ) -> AbaResult<(Vec<u32>, PhaseTimings)> {
        if self.cfg.criterion == Criterion::Dispersion {
            // Exact-or-error: the crate has no dispersion heuristic, so
            // anything the coloring oracle cannot solve is refused
            // rather than silently scored under the wrong objective.
            if self.constraints.is_some() {
                return Err(AbaError::ConstraintInfeasible(
                    "the dispersion criterion does not support must-link/cannot-link \
                     constraints; use the diversity criterion"
                        .into(),
                ));
            }
            algo::validate(view.n(), k, self.cfg.strict_divisibility)?;
            if k != 2 {
                return Err(AbaError::InvalidInput(format!(
                    "the dispersion criterion is exactly solvable only for k=2 \
                     (got k={k}); use the diversity criterion for other k"
                )));
            }
            let t = Instant::now();
            let res = cert::two_color::solve_balanced(view)?;
            let timings = PhaseTimings {
                assign_secs: t.elapsed().as_secs_f64(),
                ..PhaseTimings::default()
            };
            return Ok((res.labels, timings));
        }
        if let Some(cons) = &self.constraints {
            // The constrained loop computes its costs directly through
            // the backend, so parallelism rides on the backend pool.
            self.backend
                .set_pool(self.scratch.pool_for(self.cfg.parallelism));
            let mut timings = PhaseTimings::default();
            let t = Instant::now();
            let labels = algo::constraints::constrained_with_backend(
                view,
                k,
                &self.cfg,
                cons,
                self.backend.as_mut(),
            )?;
            timings.assign_secs = t.elapsed().as_secs_f64();
            return Ok((labels, timings));
        }
        algo::validate(view.n(), k, self.cfg.strict_divisibility)?;
        if let Some(spec) = algo::effective_spec(view.n(), k, &self.cfg) {
            let prod: usize = spec.iter().product();
            if prod != k {
                return Err(AbaError::BadHierSpec(format!(
                    "product of {spec:?} is {prod}, but k={k} was requested"
                )));
            }
            let mut timings = PhaseTimings::default();
            let t = Instant::now();
            // Single-group levels reuse the session's backend and
            // scratch (one XLA compilation, one persistent worker pool
            // for the whole decomposition); fanned-out levels run on
            // that pool with thread-local native backends. Groups
            // descend as zero-copy index views of `view`.
            let labels = algo::hierarchical::run_hierarchical_with_backend(
                view,
                &spec,
                &self.cfg,
                self.backend.as_mut(),
                &mut self.scratch,
            )?;
            timings.assign_secs = t.elapsed().as_secs_f64();
            return Ok((labels, timings));
        }
        // Flat path: one shared implementation with
        // run_aba_with_backend; the session threads its own backend and
        // scratch through it.
        let (labels, order_secs, assign_secs) = algo::flat_with_scratch(
            view,
            k,
            &self.cfg,
            self.backend.as_mut(),
            &mut self.scratch,
        )?;
        Ok((labels, PhaseTimings { order_secs, assign_secs, ..PhaseTimings::default() }))
    }

    /// Partition into a **live** [`OnlinePartition`] handle: the same
    /// solve as [`Anticlusterer::partition_view`] (hierarchical
    /// decomposition and the sparse candidate path both apply), but the
    /// result stays updatable — `insert_batch`,
    /// `remove`, `refine`, delta-maintained `objective()`/`sizes()`,
    /// and `save`/`load` persistence. The handle owns a copy of the
    /// partitioned rows (ids `0..n` in view-row order), so the borrowed
    /// view can be dropped immediately.
    ///
    /// [`Anticlusterer::partition_view`] runs the same solving core and
    /// freezes on return without building a handle (zero extra copies);
    /// [`OnlinePartition::into_partition`] converts a live handle into
    /// the identical frozen [`Partition`] (property-tested).
    ///
    /// Sessions carrying must-link / cannot-link constraints are
    /// rejected ([`AbaError::ConstraintInfeasible`]): the handle's
    /// incremental operations (insert rounds, balance repair, refine
    /// swaps) do not maintain pairwise constraints, and silently
    /// dropping them after the initial solve would be worse than
    /// refusing. Constrained workloads stay on the frozen
    /// [`Anticlusterer::partition_view`] path.
    pub fn partition_online(
        &mut self,
        view: &DataView<'_>,
        k: usize,
    ) -> AbaResult<OnlinePartition> {
        if self.constraints.is_some() {
            return Err(AbaError::ConstraintInfeasible(
                "online partitions do not maintain must-link/cannot-link constraints; \
                 use partition_view for constrained sessions"
                    .into(),
            ));
        }
        if self.cfg.criterion == Criterion::Dispersion {
            return Err(AbaError::InvalidInput(
                "online partitions maintain the diversity objective; the dispersion \
                 criterion has no incremental maintenance — use partition_view"
                    .into(),
            ));
        }
        let (labels, timings) = self.partition_labels(view, k)?;
        Ok(OnlinePartition::from_labels(view, labels, k, self.cfg.clone(), timings))
    }

    /// Resume a persisted [`OnlinePartition`] under this session's
    /// configuration (fingerprint-checked —
    /// [`AbaError::SnapshotMismatch`] when incompatible). Constrained
    /// sessions are rejected for the same reason as
    /// [`Aba::partition_online`].
    pub fn resume_online(&self, path: impl AsRef<std::path::Path>) -> AbaResult<OnlinePartition> {
        if self.constraints.is_some() {
            return Err(AbaError::ConstraintInfeasible(
                "online partitions do not maintain must-link/cannot-link constraints"
                    .into(),
            ));
        }
        if self.cfg.criterion == Criterion::Dispersion {
            return Err(AbaError::InvalidInput(
                "online partitions maintain the diversity objective; the dispersion \
                 criterion has no incremental maintenance"
                    .into(),
            ));
        }
        OnlinePartition::load(path, &self.cfg)
    }

    /// Diversity/dispersion Pareto front over `view` (see
    /// [`crate::pareto`]): the session solves once with ABA to anchor
    /// the front, then runs the multi-restart bicriterion interchange
    /// engine under this session's [`AbaBuilder::pareto`] configuration
    /// (defaults when unset), fanning restarts out on the session
    /// worker pool — Serial and Threads(n) fronts are bit-identical.
    ///
    /// Typed refusals: `n < 2k` ([`AbaError::InvalidK`] — balanced
    /// singleton anticlusters have undefined dispersion) and
    /// constrained sessions ([`AbaError::ConstraintInfeasible`] — the
    /// interchange does not maintain pairwise constraints).
    pub fn pareto_front(&mut self, view: &DataView<'_>, k: usize) -> AbaResult<ParetoFront> {
        crate::pareto::engine::validate(view.n(), k)?;
        if self.constraints.is_some() {
            return Err(AbaError::ConstraintInfeasible(
                "the bicriterion interchange does not maintain must-link/cannot-link \
                 constraints; use partition_view for constrained sessions"
                    .into(),
            ));
        }
        let cfg = self.pareto.clone().unwrap_or_default();
        // The session's own ABA solution seeds the restart rotation
        // (and is therefore weakly dominated by the returned front).
        let (aba_labels, _) = self.partition_labels(view, k)?;
        let pool = self.scratch.pool_for(self.cfg.parallelism);
        crate::pareto::engine::pareto_front(view, k, &cfg, Some(&aba_labels), pool.as_deref())
    }
}

impl Anticlusterer for Aba {
    fn partition_view(&mut self, view: &DataView<'_>, k: usize) -> AbaResult<Partition> {
        // The freeze-on-return sibling of [`Aba::partition_online`]:
        // both are thin wrappers over the same `partition_labels` core.
        // The frozen path stamps the result straight off the borrowed
        // view — zero feature-row copies, preserving the zero-copy
        // contract of the DataView layer — while the online path pays
        // the handle's owned-row ingest only when the caller actually
        // wants a live handle. `OnlinePartition::into_partition`
        // produces the identical `Partition` (property-tested).
        let (labels, timings) = self.partition_labels(view, k)?;
        Ok(Partition::from_labels(view, labels, k, timings))
    }

    fn name(&self) -> String {
        "ABA".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};

    #[test]
    fn session_reuse_is_deterministic() {
        let ds = generate(SynthKind::Uniform, 200, 4, 9, "s");
        let mut session = Aba::new().unwrap();
        let a = session.partition(&ds, 8).unwrap();
        let b = session.partition(&ds, 8).unwrap();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.objective, b.objective);
    }

    #[test]
    fn parallel_session_matches_serial_session() {
        // Flat path: repeated calls on one parallel session (the pool is
        // created once and must stay invisible in the labels).
        let flat_ds = generate(SynthKind::Uniform, 300, 5, 17, "s");
        let mut serial = Aba::new().unwrap();
        let mut threaded = Aba::builder()
            .parallelism(Parallelism::Threads(4))
            .build()
            .unwrap();
        for k in [10usize, 6] {
            let a = serial.partition(&flat_ds, k).unwrap();
            let b = threaded.partition(&flat_ds, k).unwrap();
            assert_eq!(a.labels, b.labels, "k={k}");
            assert_eq!(a.objective, b.objective, "k={k}");
        }
        // Explicit hierarchical path: the fan-out runs on the pool.
        let hier_ds = generate(SynthKind::Uniform, 600, 3, 18, "s");
        let a = Aba::builder()
            .hier(vec![3, 4])
            .build()
            .unwrap()
            .partition(&hier_ds, 12)
            .unwrap();
        let b = Aba::builder()
            .hier(vec![3, 4])
            .parallelism(Parallelism::Threads(4))
            .build()
            .unwrap()
            .partition(&hier_ds, 12)
            .unwrap();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.objective, b.objective);
    }

    #[test]
    fn partition_view_subset_matches_owned_subset() {
        // The zero-copy view path must be observationally identical to
        // materializing the subset first — labels and objectives bit-equal.
        let ds = generate(SynthKind::Uniform, 240, 4, 19, "s");
        let idx: Vec<usize> = (0..240).rev().step_by(2).collect();
        let owned = ds.subset(&idx, "owned");
        let a = Aba::new().unwrap().partition(&owned, 6).unwrap();
        let view = ds.view().select(&idx);
        let b = Aba::new().unwrap().partition_view(&view, 6).unwrap();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.pairwise, b.pairwise);
    }

    #[test]
    fn partition_carries_consistent_stats() {
        let ds = generate(SynthKind::Uniform, 120, 3, 10, "s");
        let part = Aba::new().unwrap().partition(&ds, 6).unwrap();
        assert_eq!(part.k, 6);
        assert_eq!(part.labels.len(), 120);
        assert_eq!(part.sizes().iter().sum::<usize>(), 120);
        let recomputed = ClusterStats::compute(&ds, &part.labels, 6);
        assert_eq!(part.sizes(), &recomputed.sizes[..]);
        assert!((part.objective - recomputed.ssd_total()).abs() < 1e-9);
        assert!((part.pairwise - recomputed.pairwise_total()).abs() < 1e-9);
        assert!(part.timings.total_secs >= part.timings.stats_secs);
    }

    #[test]
    fn groups_partition_all_objects() {
        let ds = generate(SynthKind::Uniform, 60, 2, 11, "s");
        let part = Aba::new().unwrap().partition(&ds, 5).unwrap();
        let groups = part.groups();
        assert_eq!(groups.len(), 5);
        // members_of is the non-allocating view of the same structure.
        for (c, group) in groups.iter().enumerate() {
            assert_eq!(&part.members_of(c).collect::<Vec<_>>(), group);
        }
        let mut seen: Vec<usize> = groups.into_iter().flatten().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..60).collect::<Vec<_>>());
    }

    #[test]
    fn constrained_sessions_cannot_go_online() {
        // The handle's incremental ops do not maintain pairwise
        // constraints, so a constrained session must refuse to hand one
        // out instead of silently dropping the constraints after the
        // initial solve. The frozen path still honors them.
        let ds = generate(SynthKind::Uniform, 40, 3, 24, "s");
        let cons = crate::algo::Constraints {
            must_link: vec![vec![0, 1]],
            cannot_link: vec![(2, 3)],
        };
        let mut session = Aba::builder().constraints(cons).build().unwrap();
        let err = session.partition_online(&ds.view(), 4).unwrap_err();
        assert!(matches!(err, AbaError::ConstraintInfeasible(_)), "{err}");
        assert!(matches!(
            session.resume_online("nonexistent.json").unwrap_err(),
            AbaError::ConstraintInfeasible(_)
        ));
        assert!(session.partition(&ds, 4).is_ok());
    }

    #[test]
    fn partition_online_matches_the_frozen_path() {
        let ds = generate(SynthKind::Uniform, 90, 3, 23, "s");
        let mut session = Aba::new().unwrap();
        let frozen = session.partition(&ds, 6).unwrap();
        let live = session.partition_online(&ds.view(), 6).unwrap();
        assert_eq!(live.len(), 90);
        assert_eq!(live.sizes(), frozen.sizes());
        for (i, &(id, label)) in live.entries().iter().enumerate() {
            assert_eq!(id, i as u64);
            assert_eq!(label, frozen.labels[i]);
        }
        let refrozen = live.into_partition();
        assert_eq!(refrozen.labels, frozen.labels);
        assert_eq!(refrozen.objective, frozen.objective);
    }

    #[test]
    fn sparse_session_partitions_validly_and_reports_stats() {
        let ds = generate(SynthKind::Uniform, 260, 4, 21, "s");
        let mut sparse = Aba::builder()
            .auto_hier(false)
            .candidates(CandidateMode::Fixed(5))
            .build()
            .unwrap();
        let part = sparse.partition(&ds, 13).unwrap();
        assert_eq!(part.sizes().iter().sum::<usize>(), 260);
        let stats = sparse.sparse_stats();
        assert!(
            stats.sparse_batches + stats.dense_batches > 0,
            "no batches counted: {stats:?}"
        );
        // Full candidate lists dispatch to the dense path bit-identically.
        let a = Aba::builder()
            .auto_hier(false)
            .candidates(CandidateMode::Fixed(500))
            .build()
            .unwrap()
            .partition(&ds, 13)
            .unwrap();
        let b = Aba::builder()
            .auto_hier(false)
            .candidates(CandidateMode::Dense)
            .build()
            .unwrap()
            .partition(&ds, 13)
            .unwrap();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.objective, b.objective);
    }

    #[test]
    fn lapjv_warm_start_option_only_affects_speed() {
        let ds = generate(SynthKind::Uniform, 120, 3, 22, "s");
        let warm = Aba::builder()
            .lapjv_warm_start(true)
            .build()
            .unwrap()
            .partition(&ds, 6)
            .unwrap();
        let cold = Aba::builder()
            .lapjv_warm_start(false)
            .build()
            .unwrap()
            .partition(&ds, 6)
            .unwrap();
        // Both are exact max-cost solves; on tie-free random data the
        // per-batch optima coincide, so the objectives must agree (tie
        // instances could legitimately diverge, hence a tolerance).
        let rel = (warm.objective - cold.objective).abs() / cold.objective.max(1.0);
        assert!(rel < 1e-6, "warm {} vs cold {}", warm.objective, cold.objective);
    }

    #[test]
    fn builder_rejects_zero_factor_spec() {
        let err = Aba::builder().hier(vec![4, 0]).build().unwrap_err();
        assert!(matches!(err, AbaError::BadHierSpec(_)), "{err}");
    }

    #[test]
    fn hier_product_must_match_k() {
        let ds = generate(SynthKind::Uniform, 100, 3, 12, "s");
        let mut session = Aba::builder().hier(vec![2, 3]).build().unwrap();
        let err = session.partition(&ds, 5).unwrap_err();
        assert!(matches!(err, AbaError::BadHierSpec(_)), "{err}");
        assert!(session.partition(&ds, 6).is_ok());
    }

    #[test]
    fn k1_is_trivial_through_the_session() {
        let ds = generate(SynthKind::Uniform, 10, 2, 13, "s");
        let part = Aba::new().unwrap().partition(&ds, 1).unwrap();
        assert!(part.labels.iter().all(|&l| l == 0));
        assert_eq!(part.sizes(), &[10]);
    }

    #[test]
    fn invalid_k_is_typed() {
        let ds = generate(SynthKind::Uniform, 10, 2, 14, "s");
        let mut session = Aba::new().unwrap();
        assert!(matches!(
            session.partition(&ds, 0),
            Err(AbaError::InvalidK { .. })
        ));
        assert!(matches!(
            session.partition(&ds, 11),
            Err(AbaError::InvalidK { .. })
        ));
    }

    #[test]
    fn strict_divisibility_rejects_ragged_sizes() {
        let ds = generate(SynthKind::Uniform, 10, 2, 15, "s");
        let mut strict = Aba::builder().strict_divisibility(true).build().unwrap();
        assert!(matches!(
            strict.partition(&ds, 3),
            Err(AbaError::InvalidK { .. })
        ));
        assert!(strict.partition(&ds, 5).is_ok());
        // Non-strict only warns.
        let mut lax = Aba::new().unwrap();
        assert!(lax.partition(&ds, 3).is_ok());
    }

    #[test]
    fn partition_reports_valid_certificate_bound() {
        let ds = generate(SynthKind::Uniform, 150, 4, 25, "s");
        let part = Aba::new().unwrap().partition(&ds, 5).unwrap();
        assert!(part.upper_bound() >= part.objective);
        let g = part.gap();
        assert!((0.0..=1.0).contains(&g), "gap {g}");
        // The bound is the TSS identity: objective + bgss.
        assert_eq!(part.upper_bound(), part.objective + part.stats.bgss);
    }

    #[test]
    fn certify_knob_attaches_a_certificate() {
        let ds = generate(SynthKind::Uniform, 200, 3, 26, "s");
        let mut plain = Aba::new().unwrap();
        plain.partition(&ds, 4).unwrap();
        assert!(plain.last_certificate().is_none());
        let mut certified = Aba::builder().certify(true).build().unwrap();
        let part = certified.partition(&ds, 4).unwrap();
        let cert = certified.last_certificate().expect("certificate attached");
        assert_eq!(cert.n, 200);
        assert_eq!(cert.k, 4);
        assert!(cert.upper_bound >= part.objective);
        // The standalone certificate and the stats-derived bound agree
        // up to accumulation order.
        let rel = (cert.upper_bound - part.upper_bound()).abs() / cert.upper_bound.max(1.0);
        assert!(rel < 1e-9, "certificate {} vs stats {}", cert.upper_bound, part.upper_bound());
        assert!(cert.secs >= 0.0);
    }

    #[test]
    fn dispersion_criterion_solves_k2_exactly_and_rejects_the_rest() {
        let rows: Vec<Vec<f32>> = vec![
            vec![0.0], vec![1.0], vec![10.0], vec![11.0],
        ];
        let ds = crate::data::Dataset::from_rows("line", &rows).unwrap();
        let mut session = Aba::builder()
            .criterion(crate::algo::Criterion::Dispersion)
            .build()
            .unwrap();
        let part = session.partition(&ds, 2).unwrap();
        // The known optimum of the line instance: {0,10} vs {1,11}.
        assert_eq!(crate::algo::objective::dispersion(&ds, &part.labels, 2), 100.0);
        assert_eq!(part.sizes(), &[2, 2]);
        // k != 2, online, and resume are typed refusals.
        assert!(matches!(
            session.partition(&ds, 4),
            Err(AbaError::InvalidInput(_))
        ));
        assert!(matches!(
            session.partition_online(&ds.view(), 2),
            Err(AbaError::InvalidInput(_))
        ));
        assert!(matches!(
            session.resume_online("nonexistent.json"),
            Err(AbaError::InvalidInput(_))
        ));
    }

    #[test]
    fn kernel_isa_is_stamped_and_scalar_mode_is_bit_identical() {
        let ds = generate(
            SynthKind::GaussianMixture { components: 3, spread: 2.0 },
            400,
            9,
            31,
            "s",
        );
        let mut default = Aba::new().unwrap();
        let a = default.partition(&ds, 8).unwrap();
        // The stamp reports whatever the host selected; it is never empty
        // on the session path.
        assert!(!a.timings.kernel_isa.is_empty());
        let mut scalar = Aba::builder().kernels(KernelMode::Scalar).build().unwrap();
        let b = scalar.partition(&ds, 8).unwrap();
        assert_eq!(b.timings.kernel_isa, "scalar");
        // Auto's vector path preserves scalar `dot8` reduction order, so
        // forcing the fallback must not move a single bit.
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    }

    #[test]
    fn pareto_front_rides_the_session() {
        let ds = generate(
            SynthKind::GaussianMixture { components: 4, spread: 4.0 },
            90,
            4,
            27,
            "s",
        );
        let mut session = Aba::builder()
            .pareto(ParetoConfig { restarts: 4, seed: 3, ..Default::default() })
            .parallelism(Parallelism::Threads(2))
            .build()
            .unwrap();
        let aba = session.partition(&ds, 5).unwrap();
        let front = session.pareto_front(&ds.view(), 5).unwrap();
        assert!(!front.points.is_empty());
        // The ABA seed anchors the diversity extreme: the front's best
        // diversity can only weakly dominate the single solve's.
        let best = front.best_diversity().unwrap();
        assert!(best.diversity >= aba.objective * (1.0 - 1e-9));
        // Same run on a serial session: bit-identical front.
        let mut serial = Aba::builder()
            .pareto(ParetoConfig { restarts: 4, seed: 3, ..Default::default() })
            .build()
            .unwrap();
        let front2 = serial.pareto_front(&ds.view(), 5).unwrap();
        assert_eq!(front, front2);
        // Typed refusals at the session boundary.
        assert!(matches!(
            session.pareto_front(&ds.view(), 60),
            Err(AbaError::InvalidK { .. })
        ));
        let cons = crate::algo::Constraints { must_link: vec![vec![0, 1]], cannot_link: vec![] };
        let mut constrained = Aba::builder().constraints(cons).build().unwrap();
        assert!(matches!(
            constrained.pareto_front(&ds.view(), 5),
            Err(AbaError::ConstraintInfeasible(_))
        ));
    }

    #[test]
    fn matches_config_equivalent_free_function_path() {
        let ds = generate(SynthKind::Uniform, 300, 5, 16, "s");
        let cfg = AbaConfig::default();
        let mut session = Aba::from_config(cfg.clone()).unwrap();
        let part = session.partition(&ds, 10).unwrap();
        let mut backend = make_backend(cfg.backend).unwrap();
        let labels = algo::run_aba_with_backend(&ds, 10, &cfg, backend.as_mut()).unwrap();
        assert_eq!(part.labels, labels);
    }
}
