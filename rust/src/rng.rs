//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set ships no `rand` crate, so the repository carries
//! its own small, well-tested generators. Every stochastic component in
//! the system (synthetic datasets, random partitions, exchange partners,
//! benchmark workloads) draws from [`Pcg32`] seeded explicitly — runs are
//! bit-reproducible across machines.

/// SplitMix64 — used to expand a single `u64` seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32 — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

impl Pcg32 {
    /// Seed a generator; distinct `seed` values give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = sm.next_u64();
        let inc = sm.next_u64() | 1;
        let mut rng = Self { state, inc, spare_normal: None };
        rng.next_u32(); // burn-in so low-entropy seeds decorrelate
        rng
    }

    /// Derive an independent child stream (for per-thread / per-dataset use).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        Pcg32::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Deterministic stream split: the generator for stream `index` of a
    /// root `seed`. Unlike [`Pcg32::fork`] this does not consume state
    /// from a parent, so `stream(seed, i)` is the same generator no
    /// matter how many draws any other stream has made — the property
    /// multi-restart engines need for serial ≡ pooled bit-identity
    /// (restart `i` always sees stream `i`). `stream(seed, 0)` equals
    /// `Pcg32::new(seed)`.
    pub fn stream(seed: u64, index: u64) -> Pcg32 {
        Pcg32::new(seed ^ index.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32())
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire rejection).
    #[inline]
    pub fn gen_below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = u64::from(r) * u64::from(bound);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn gen_index(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0 && bound <= u32::MAX as usize);
        self.gen_below(bound as u32) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn gen_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (second draw cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean / standard deviation, as f32.
    pub fn normal_f32(&mut self, mean: f32, sd: f32) -> f32 {
        (mean as f64 + sd as f64 * self.normal()) as f32
    }

    /// Bernoulli draw.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // For small k relative to n use rejection on a set-in-vec; for
        // large k shuffle a full index vector.
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            let mut chosen = Vec::with_capacity(k);
            while chosen.len() < k {
                let c = self.gen_index(n);
                if !chosen.contains(&c) {
                    chosen.push(c);
                }
            }
            chosen
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_is_deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_give_distinct_streams() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_below_unbiased_smoke() {
        let mut rng = Pcg32::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.gen_below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let z = rng.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg32::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::new(9);
        for &(n, k) in &[(10, 3), (100, 50), (5, 5), (1000, 10)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), k);
            assert!(t.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn stream_split_is_order_independent() {
        // stream(seed, i) depends only on (seed, i) — not on how many
        // draws other streams made (the contrast with fork()).
        let mut a = Pcg32::stream(77, 3);
        let mut other = Pcg32::stream(77, 1);
        for _ in 0..1000 {
            other.next_u32(); // unrelated stream activity
        }
        let mut b = Pcg32::stream(77, 3);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn stream_zero_matches_new() {
        let mut a = Pcg32::stream(9001, 0);
        let mut b = Pcg32::new(9001);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_decorrelate() {
        let mut a = Pcg32::stream(5, 0);
        let mut b = Pcg32::stream(5, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Pcg32::new(123);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Pcg32::new(17);
        let hits = (0..50_000).filter(|_| rng.bernoulli(0.25)).count();
        assert!((11_000..14_000).contains(&hits));
    }
}
