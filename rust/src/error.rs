//! Typed errors for the library core.
//!
//! Every fallible public function in `algo/`, `baselines/`, `solver`, and
//! `pipeline/` returns [`AbaError`]; `anyhow` survives only at the CLI /
//! experiment-harness boundary (where `AbaError` converts automatically
//! via `std::error::Error`). Matching on a variant is part of the public
//! contract — e.g. the experiment harness maps [`AbaError::TimeLimit`] to
//! the paper's "—" (no solution within the cap) cell.

use std::fmt;

/// Crate-wide result alias for the typed error.
pub type AbaResult<T> = Result<T, AbaError>;

/// Everything that can go wrong inside the anticlustering core.
#[derive(Debug, Clone, PartialEq)]
pub enum AbaError {
    /// The dataset has no objects.
    EmptyDataset,
    /// A buffer or shape mismatch while building or transforming a
    /// dataset (ragged rows, wrong buffer length, category-length
    /// mismatch).
    BadShape(String),
    /// A data file could not be parsed (1-based line number).
    ParseError { line: usize, msg: String },
    /// An I/O failure reading or writing a data file.
    Io(String),
    /// `k` is out of range for the dataset (or violates strict
    /// divisibility when requested).
    InvalidK { k: usize, n: usize, reason: String },
    /// A processing order was not a permutation of `0..n`.
    InvalidOrder { expected: usize, got: usize },
    /// A hierarchical decomposition spec is unusable for this instance.
    BadHierSpec(String),
    /// The requested cost backend could not be constructed (e.g. XLA
    /// artifacts missing, or the crate was built without the `xla`
    /// feature).
    BackendUnavailable(String),
    /// Pairwise constraints are inconsistent or unsatisfiable under `k`.
    ConstraintInfeasible(String),
    /// A solver gave up after exhausting its wall-clock budget.
    TimeLimit { limit_secs: f64 },
    /// A persisted [`crate::online::OnlinePartition`] snapshot cannot be
    /// resumed: its config fingerprint (or format version) does not
    /// match the session trying to load it.
    SnapshotMismatch { expected: String, found: String },
    /// Malformed input that fits no more specific variant.
    InvalidInput(String),
}

impl fmt::Display for AbaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AbaError::EmptyDataset => write!(f, "dataset has no objects"),
            AbaError::BadShape(msg) => write!(f, "bad data shape: {msg}"),
            AbaError::ParseError { line, msg } => write!(f, "parse error at line {line}: {msg}"),
            AbaError::Io(msg) => write!(f, "i/o error: {msg}"),
            AbaError::InvalidK { k, n, reason } => {
                write!(f, "invalid k={k} for n={n}: {reason}")
            }
            AbaError::InvalidOrder { expected, got } => {
                write!(f, "processing order has length {got}, expected a permutation of 0..{expected}")
            }
            AbaError::BadHierSpec(msg) => write!(f, "bad hierarchy spec: {msg}"),
            AbaError::BackendUnavailable(msg) => write!(f, "cost backend unavailable: {msg}"),
            AbaError::ConstraintInfeasible(msg) => write!(f, "infeasible constraints: {msg}"),
            AbaError::TimeLimit { limit_secs } => {
                write!(f, "no solution within the {limit_secs}s time limit")
            }
            AbaError::SnapshotMismatch { expected, found } => {
                write!(
                    f,
                    "online-partition snapshot is incompatible with this session: \
                     expected '{expected}', found '{found}'"
                )
            }
            AbaError::InvalidInput(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for AbaError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = AbaError::InvalidK { k: 7, n: 3, reason: "k exceeds n".into() };
        let msg = e.to_string();
        assert!(msg.contains("k=7") && msg.contains("n=3"), "{msg}");
        assert!(AbaError::EmptyDataset.to_string().contains("no objects"));
        assert!(AbaError::TimeLimit { limit_secs: 2.0 }.to_string().contains("2s"));
        assert!(AbaError::BadShape("row 3".into()).to_string().contains("row 3"));
        let p = AbaError::ParseError { line: 7, msg: "bad float".into() }.to_string();
        assert!(p.contains("line 7") && p.contains("bad float"), "{p}");
        let s = AbaError::SnapshotMismatch { expected: "aba/1|x".into(), found: "aba/1|y".into() }
            .to_string();
        assert!(s.contains("aba/1|x") && s.contains("aba/1|y"), "{s}");
    }

    #[test]
    fn converts_into_anyhow_at_the_cli_boundary() {
        fn cli() -> anyhow::Result<()> {
            Err(AbaError::BadHierSpec("empty".into()))?;
            Ok(())
        }
        let err = cli().unwrap_err();
        assert!(format!("{err:#}").contains("bad hierarchy spec"));
    }

    #[test]
    fn variants_are_comparable() {
        assert_eq!(AbaError::EmptyDataset, AbaError::EmptyDataset);
        assert_ne!(
            AbaError::EmptyDataset,
            AbaError::InvalidInput("x".into())
        );
    }
}
