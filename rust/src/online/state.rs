//! Internal state of an [`super::OnlinePartition`]: the owned row store
//! with stable external ids, and the per-anticluster maintained state.
//!
//! The row store is slot-based: removing a row frees its slot for the
//! next insert instead of compacting the matrix, so ids handed to
//! callers stay valid across arbitrary churn. Everything observable is
//! keyed by *id*, and every canonical walk (objective refresh,
//! persistence, freezing) iterates ids in ascending order — that fixed
//! order is what makes exact reads and save/load round-trips
//! bit-reproducible.

use crate::algo::objective::ClusterDelta;
use std::collections::BTreeMap;

/// Label sentinel for a slot that is free or not yet assigned.
pub(super) const UNASSIGNED: u32 = u32::MAX;

/// Owned feature rows with stable external ids and free-slot reuse.
pub(super) struct RowStore {
    /// Features per row.
    pub d: usize,
    /// Slot-major feature matrix (`capacity_slots * d`).
    pub rows: Vec<f32>,
    /// External id per slot (stale for free slots).
    pub ids: Vec<u64>,
    /// Anticluster per slot; [`UNASSIGNED`] marks free/staged slots.
    pub labels: Vec<u32>,
    /// Category per slot (only meaningful when the handle is
    /// categorical; 0 otherwise).
    pub cats: Vec<u32>,
    /// Recyclable slots.
    free: Vec<usize>,
    /// id -> slot. A BTreeMap so iteration order is ascending id — the
    /// canonical order of every exact walk.
    index: BTreeMap<u64, usize>,
    /// The next id to hand out.
    pub next_id: u64,
}

impl RowStore {
    pub fn new(d: usize) -> Self {
        Self {
            d,
            rows: Vec::new(),
            ids: Vec::new(),
            labels: Vec::new(),
            cats: Vec::new(),
            free: Vec::new(),
            index: BTreeMap::new(),
            next_id: 0,
        }
    }

    /// Live rows.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// Feature slice of a slot.
    #[inline]
    pub fn row(&self, slot: usize) -> &[f32] {
        &self.rows[slot * self.d..(slot + 1) * self.d]
    }

    /// Slot of an id, if live.
    #[inline]
    pub fn slot_of(&self, id: u64) -> Option<usize> {
        self.index.get(&id).copied()
    }

    /// `(id, slot)` pairs in ascending-id order — the canonical full
    /// walk (no second per-row tree lookup).
    pub fn iter(&self) -> impl Iterator<Item = (u64, usize)> + '_ {
        self.index.iter().map(|(&id, &slot)| (id, slot))
    }

    /// Stage a new unassigned row, allocating the next id. Returns
    /// `(id, slot)`.
    pub fn insert(&mut self, row: &[f32], cat: u32) -> (u64, usize) {
        let id = self.next_id;
        self.next_id += 1;
        let slot = self.insert_with_id(id, row, cat, UNASSIGNED);
        (id, slot)
    }

    /// Stage a row under an explicit id/label (the persistence loader).
    /// The caller guarantees the id is fresh.
    pub fn insert_with_id(&mut self, id: u64, row: &[f32], cat: u32, label: u32) -> usize {
        debug_assert_eq!(row.len(), self.d);
        debug_assert!(!self.index.contains_key(&id), "duplicate id {id}");
        let slot = match self.free.pop() {
            Some(slot) => {
                self.rows[slot * self.d..(slot + 1) * self.d].copy_from_slice(row);
                self.ids[slot] = id;
                self.labels[slot] = label;
                self.cats[slot] = cat;
                slot
            }
            None => {
                let slot = self.ids.len();
                self.rows.extend_from_slice(row);
                self.ids.push(id);
                self.labels.push(label);
                self.cats.push(cat);
                slot
            }
        };
        self.index.insert(id, slot);
        slot
    }

    /// Free the slot behind an id. Returns the freed slot.
    pub fn remove(&mut self, id: u64) -> Option<usize> {
        let slot = self.index.remove(&id)?;
        debug_assert_eq!(self.ids[slot], id, "index/slot id drift");
        self.labels[slot] = UNASSIGNED;
        self.free.push(slot);
        Some(slot)
    }
}

/// Maintained state of one anticluster.
pub(super) struct ClusterState {
    /// Member ids, kept sorted ascending (the canonical walk order).
    pub members: Vec<u64>,
    /// Running O(d)-updated sufficient statistics, used to price
    /// prospective moves. Mathematically exact; bit-wise it may drift
    /// from a fresh accumulation under long churn, which is why exact
    /// reads go through `cached_ssd`.
    pub delta: ClusterDelta,
    /// Canonical SSD contribution: the value a from-scratch member-order
    /// accumulation produces. Valid only when `!dirty`.
    pub cached_ssd: f64,
    /// Whether membership changed since `cached_ssd` was computed.
    pub dirty: bool,
    /// Per-category member counts (len = handle `n_cats`).
    pub cat_counts: Vec<usize>,
}

impl ClusterState {
    pub fn new(d: usize, n_cats: usize) -> Self {
        Self {
            members: Vec::new(),
            delta: ClusterDelta::new(d),
            cached_ssd: 0.0,
            dirty: false,
            cat_counts: vec![0; n_cats],
        }
    }

    pub fn size(&self) -> usize {
        self.members.len()
    }

    /// Record a new member (keeps `members` sorted, updates the running
    /// delta, marks dirty). The caller updates category counters.
    pub fn add_member(&mut self, id: u64, row: &[f32]) {
        match self.members.binary_search(&id) {
            Err(pos) => self.members.insert(pos, id),
            Ok(_) => unreachable!("id {id} already a member"),
        }
        self.delta.add(row);
        self.dirty = true;
    }

    /// Drop a member (must be present).
    pub fn remove_member(&mut self, id: u64, row: &[f32]) {
        match self.members.binary_search(&id) {
            Ok(pos) => {
                self.members.remove(pos);
            }
            Err(_) => unreachable!("id {id} is not a member"),
        }
        self.delta.remove(row);
        self.dirty = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_recycle_and_ids_stay_stable() {
        let mut store = RowStore::new(2);
        let (a, sa) = store.insert(&[1.0, 2.0], 0);
        let (b, sb) = store.insert(&[3.0, 4.0], 1);
        assert_eq!((a, b), (0, 1));
        assert_eq!(store.len(), 2);
        assert_eq!(store.remove(a), Some(sa));
        assert_eq!(store.len(), 1);
        assert_eq!(store.slot_of(a), None);
        // The freed slot is reused, the id is fresh.
        let (c, sc) = store.insert(&[5.0, 6.0], 2);
        assert_eq!(c, 2);
        assert_eq!(sc, sa);
        assert_eq!(store.row(sc), &[5.0, 6.0]);
        assert_eq!(store.row(sb), &[3.0, 4.0]);
        assert_eq!(store.cats[sc], 2);
        assert_eq!(
            store.iter().map(|(id, _)| id).collect::<Vec<_>>(),
            vec![1, 2]
        );
    }

    #[test]
    fn cluster_state_keeps_members_sorted() {
        let mut cl = ClusterState::new(1, 0);
        cl.add_member(5, &[1.0]);
        cl.add_member(2, &[2.0]);
        cl.add_member(9, &[3.0]);
        assert_eq!(cl.members, vec![2, 5, 9]);
        assert_eq!(cl.size(), 3);
        assert!(cl.dirty);
        cl.remove_member(5, &[1.0]);
        assert_eq!(cl.members, vec![2, 9]);
        assert_eq!(cl.delta.len(), 2);
    }
}
