//! Live, incrementally maintained partitions — the serving-shaped API.
//!
//! A batch `partition` call answers "how do I split *this* dataset right
//! now"; the paper's headline applications (representative CV folds, SGD
//! mini-batches, balanced K-cut serving) are long-lived: rows arrive,
//! rows expire, and re-solving from scratch on every change wastes the
//! work the previous solution already encodes. An [`OnlinePartition`] —
//! obtained from [`crate::Aba::partition_online`], or grown from an
//! [`OnlinePartition::empty`] handle — turns the frozen result into a
//! first-class, updatable artifact:
//!
//! * [`OnlinePartition::insert_batch`] assigns a batch of new rows to
//!   anticlusters by solving small max-*gain* rectangular assignments
//!   (the same dense LAPJV / auction / greedy solvers as the batch
//!   algorithm, switching to the candidate-pruned CSR solvers of
//!   [`crate::assignment::sparse`] at large K), with per-cluster
//!   capacities derived from the post-insert balanced target sizes and
//!   §4.3 categorical masking;
//! * [`OnlinePartition::remove`] drops rows by id and repairs the
//!   balance (and category) invariants with cheapest-loss relocations;
//! * [`OnlinePartition::refine`] runs a bounded exchange pass scoped to
//!   the clusters touched since the last refine;
//! * [`OnlinePartition::objective`] / [`OnlinePartition::sizes`] read
//!   delta-maintained state instead of recomputing `O(n·d)`: per-cluster
//!   [`ClusterDelta`] sums price moves in O(d), and exact reads
//!   re-accumulate only the clusters dirtied since the last read —
//!   bit-identical to a from-scratch recompute
//!   ([`OnlinePartition::recompute_objective`]);
//! * [`OnlinePartition::save`] / [`OnlinePartition::load`] persist the
//!   handle as versioned JSON with a config fingerprint
//!   ([`crate::algo::AbaConfig::fingerprint`]) so a serving process can
//!   warm-restart — resuming under an incompatible session is a typed
//!   [`crate::AbaError::SnapshotMismatch`].
//!
//! Invariants after **every** operation: anticluster sizes within one
//! of each other (unconditional), §4.3 per-(cluster, category) counts
//! at most `ceil(total_g / k)` (restored whenever any cap-respecting
//! relocation exists; best-effort under adversarial category geometry
//! where the two invariants genuinely conflict), and `insert_batch`
//! into an *empty* handle reproduces the flat batch solver's partition
//! exactly (it runs the identical ordering + assignment loop). All of
//! this is property-tested (`rust/tests/online.rs`).

mod persist;
mod state;

pub use persist::{inspect_snapshot, inspect_snapshot_str, SnapshotInfo};

use crate::algo::batching;
use crate::algo::core::{warm_start_env_default, Scratch, MASK_COST};
use crate::algo::objective::ClusterDelta;
use crate::algo::{self, AbaConfig};
use crate::assignment::sparse::{CsrCost, SparseAuction, SparseLapjv};
use crate::assignment::{auction, greedy, Lapjv, SolverKind};
use crate::data::dataset::sq_dist;
use crate::data::{DataView, Dataset};
use crate::error::{AbaError, AbaResult};
use crate::knn::farthest::FarthestIndex;
use crate::runtime::{Kernels, NativeBackend, Parallelism};
use crate::solver::{Partition, PhaseTimings};
use state::{ClusterState, RowStore};
use std::collections::BTreeSet;
use std::time::Instant;

/// Outcome of one [`OnlinePartition::refine`] pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct RefineStats {
    /// Candidate swaps priced (the budget currency).
    pub evaluated: usize,
    /// Swaps applied.
    pub swapped: usize,
    /// Sum of the applied swaps' priced gains (running-sum estimate;
    /// read [`OnlinePartition::objective`] for the exact value).
    pub est_gain: f64,
}

/// A live anticlustering: owned rows with stable ids, delta-maintained
/// per-cluster state, and bounded local repair. See the module docs.
pub struct OnlinePartition {
    k: usize,
    n_cats: usize,
    store: RowStore,
    clusters: Vec<ClusterState>,
    /// Per-category live totals (len `n_cats`).
    cat_totals: Vec<usize>,
    /// Clusters whose membership changed since the last refine.
    touched: BTreeSet<usize>,
    cfg: AbaConfig,
    /// Reused solvers/buffers for insert rounds.
    lapjv: Lapjv,
    farthest: FarthestIndex,
    sparse_jv: SparseLapjv,
    sparse_auction: SparseAuction,
    cost: Vec<f32>,
    /// Timings of the initial solve (carried into a frozen `Partition`).
    timings: PhaseTimings,
}

impl OnlinePartition {
    fn with_parts(k: usize, d: usize, cfg: AbaConfig) -> Self {
        let mut lapjv = Lapjv::new();
        lapjv.warm_start = cfg.lapjv_warm.unwrap_or_else(warm_start_env_default);
        // Resolve the handle's kernel table once, from the same knob the
        // batch session uses, so sparse insert rounds evaluate centroid
        // distances on the selected tier.
        let mut farthest = FarthestIndex::new();
        farthest.set_kernels(match cfg.kernels {
            Some(mode) => Kernels::select(mode),
            None => Kernels::get(),
        });
        Self {
            k,
            n_cats: 0,
            store: RowStore::new(d),
            clusters: (0..k).map(|_| ClusterState::new(d, 0)).collect(),
            cat_totals: Vec::new(),
            touched: BTreeSet::new(),
            cfg,
            lapjv,
            farthest,
            sparse_jv: SparseLapjv::new(),
            sparse_auction: SparseAuction::new(),
            cost: Vec::new(),
            timings: PhaseTimings::default(),
        }
    }

    /// An empty handle over `d`-feature rows: the first
    /// [`OnlinePartition::insert_batch`] bootstraps it through the exact
    /// flat batch algorithm (serial, native backend), so filling an
    /// empty handle with a whole dataset reproduces the batch solver's
    /// partition.
    pub fn empty(k: usize, d: usize, cfg: &AbaConfig) -> AbaResult<Self> {
        if k == 0 {
            return Err(AbaError::InvalidK { k, n: 0, reason: "k must be >= 1".into() });
        }
        if d == 0 {
            return Err(AbaError::BadShape("online partition needs d >= 1".into()));
        }
        Ok(Self::with_parts(k, d, cfg.clone()))
    }

    /// Build a handle from a solved batch partition (the
    /// [`crate::Aba::partition_online`] path). Labels are per view row;
    /// ids are assigned `0..n` in view-row order.
    pub(crate) fn from_labels(
        view: &DataView<'_>,
        labels: Vec<u32>,
        k: usize,
        cfg: AbaConfig,
        timings: PhaseTimings,
    ) -> Self {
        let n_cats = view.n_categories();
        let mut part = Self::with_parts(k, view.d(), cfg);
        if n_cats > 0 {
            part.grow_categories(n_cats);
        }
        part.timings = timings;
        for (i, &label) in labels.iter().enumerate() {
            let cat = if n_cats > 0 { view.category(i) } else { 0 };
            if n_cats > 0 {
                part.cat_totals[cat as usize] += 1;
            }
            let (id, slot) = part.store.insert(view.row(i), cat);
            part.attach(id, slot, label as usize);
        }
        part.seal();
        part.touched.clear();
        part
    }

    // ---- observers -----------------------------------------------------

    /// Live rows.
    pub fn len(&self) -> usize {
        self.store.len()
    }

    /// Whether the handle holds no rows.
    pub fn is_empty(&self) -> bool {
        self.store.len() == 0
    }

    /// Number of anticlusters.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Features per row.
    pub fn d(&self) -> usize {
        self.store.d
    }

    /// Distinct categories (0 when the handle is not categorical).
    pub fn n_categories(&self) -> usize {
        self.n_cats
    }

    /// The config fingerprint stamped into this handle's snapshots —
    /// always derived from the owning config
    /// ([`AbaConfig::fingerprint`]), never stored separately.
    pub fn fingerprint(&self) -> String {
        self.cfg.fingerprint()
    }

    /// Objects per anticluster, off the maintained state — O(k).
    pub fn sizes(&self) -> Vec<usize> {
        self.clusters.iter().map(|c| c.size()).collect()
    }

    /// Member ids of one anticluster, sorted ascending.
    pub fn members(&self, c: usize) -> &[u64] {
        &self.clusters[c].members
    }

    /// Member ids grouped by anticluster (the online analogue of
    /// [`Partition::groups`]).
    pub fn groups_ids(&self) -> Vec<Vec<u64>> {
        self.clusters.iter().map(|c| c.members.clone()).collect()
    }

    /// `(id, anticluster)` pairs in ascending-id order.
    pub fn entries(&self) -> Vec<(u64, u32)> {
        self.store
            .iter()
            .map(|(id, slot)| (id, self.store.labels[slot]))
            .collect()
    }

    /// Anticluster of a live row id.
    pub fn label_of(&self, id: u64) -> Option<u32> {
        self.store.slot_of(id).map(|slot| self.store.labels[slot])
    }

    /// Centroid-form objective (total SSD to anticluster centroids),
    /// read from the maintained state: only clusters dirtied since the
    /// last read are re-accumulated (canonically, in ascending-id member
    /// order), so the result is **bit-identical** to
    /// [`OnlinePartition::recompute_objective`].
    pub fn objective(&mut self) -> f64 {
        for c in 0..self.k {
            if self.clusters[c].dirty {
                self.refresh_cluster(c);
            }
        }
        self.clusters.iter().map(|cl| cl.cached_ssd).sum()
    }

    /// Certified upper bound on the diversity objective of **any**
    /// balanced k-partition of the handle's current contents:
    /// `objective + BGSS` by the total-sum identity (see
    /// [`crate::cert::bounds`]). Maintained lazily off the existing
    /// per-cluster [`ClusterDelta`] stats — after the same
    /// dirty-cluster refresh as [`OnlinePartition::objective`], the
    /// between-group term folds the k `(m, S)` moments in O(kd); no
    /// pass over the rows. `BGSS` is a sum of non-negative terms, so
    /// `upper_bound() >= objective()` holds exactly in floating point.
    pub fn upper_bound(&mut self) -> f64 {
        let objective = self.objective(); // refreshes dirty clusters
        objective + self.bgss()
    }

    /// Relative optimality gap `(upper_bound − objective) /
    /// upper_bound` in `[0, 1]` (0 for empty or degenerate handles) —
    /// the live analogue of [`Partition::gap`], reported by
    /// `GET /v1/partitions/{id}` and the serve metrics.
    pub fn gap(&mut self) -> f64 {
        let objective = self.objective();
        crate::cert::bounds::gap(objective, objective + self.bgss())
    }

    /// Between-group sum of squares `Σ_c m_c ||μ_c − μ||²` from the
    /// maintained cluster moments. Callers refresh dirty clusters
    /// first (via [`OnlinePartition::objective`]).
    fn bgss(&self) -> f64 {
        let n: usize = self.clusters.iter().map(|cl| cl.delta.len()).sum();
        if n == 0 {
            return 0.0;
        }
        let d = self.store.d;
        let mut global = vec![0f64; d];
        for cl in &self.clusters {
            for (g, s) in global.iter_mut().zip(cl.delta.sum()) {
                *g += s;
            }
        }
        for g in global.iter_mut() {
            *g /= n as f64;
        }
        let mut bgss = 0f64;
        for cl in &self.clusters {
            let m = cl.delta.len();
            if m == 0 {
                continue;
            }
            // `global` is already a mean, so its count is exactly 1.0
            // (division by 1.0 is exact — same folds as the inline loop).
            let dev = crate::runtime::simd::centroid_sq_dist(cl.delta.sum(), m as f64, &global, 1.0);
            bgss += m as f64 * dev;
        }
        bgss
    }

    /// Per-anticluster SSD contributions (same maintenance as
    /// [`OnlinePartition::objective`]).
    pub fn cluster_objectives(&mut self) -> Vec<f64> {
        for c in 0..self.k {
            if self.clusters[c].dirty {
                self.refresh_cluster(c);
            }
        }
        self.clusters.iter().map(|cl| cl.cached_ssd).collect()
    }

    /// From-scratch objective recompute over the current membership —
    /// the verification oracle for [`OnlinePartition::objective`]
    /// (property-tested to match it bit for bit) and the CLI's
    /// delta-vs-scratch report.
    pub fn recompute_objective(&self) -> f64 {
        let d = self.store.d;
        let mut total = 0f64;
        for cl in &self.clusters {
            let mut fresh = ClusterDelta::new(d);
            for &id in &cl.members {
                let slot = self.store.slot_of(id).expect("member resolves");
                fresh.add(self.store.row(slot));
            }
            total += fresh.ssd();
        }
        total
    }

    /// Timings of the initial solve that produced this handle.
    pub fn timings(&self) -> PhaseTimings {
        self.timings
    }

    /// Materialize the current rows (ascending-id order) into an owned
    /// [`Dataset`] — e.g. to hand the *current* contents to a
    /// from-scratch re-solve for comparison.
    pub fn to_dataset(&self, name: impl Into<String>) -> AbaResult<Dataset> {
        let (n, d) = (self.store.len(), self.store.d);
        let mut x = Vec::with_capacity(n * d);
        let mut cats = Vec::with_capacity(if self.n_cats > 0 { n } else { 0 });
        for (_, slot) in self.store.iter() {
            x.extend_from_slice(self.store.row(slot));
            if self.n_cats > 0 {
                cats.push(self.store.cats[slot]);
            }
        }
        let ds = Dataset::from_flat(name, n, d, x)?;
        if self.n_cats > 0 {
            ds.with_categories(cats)
        } else {
            Ok(ds)
        }
    }

    /// Freeze into an immutable [`Partition`] (labels in ascending-id
    /// order) — identical to what
    /// [`crate::Anticlusterer::partition_view`] returns for the same
    /// data (property-tested); the frozen path just skips the handle
    /// and stamps labels off the borrowed view directly.
    pub fn into_partition(self) -> Partition {
        let (n, d) = (self.store.len(), self.store.d);
        let mut x = Vec::with_capacity(n * d);
        let mut labels = Vec::with_capacity(n);
        for (_, slot) in self.store.iter() {
            x.extend_from_slice(self.store.row(slot));
            labels.push(self.store.labels[slot]);
        }
        let view = DataView::over("online", &x, n, d);
        Partition::from_labels(&view, labels, self.k, self.timings)
    }

    // ---- updates -------------------------------------------------------

    /// Insert a batch of rows, assigning each to an anticluster so that
    /// diversity gain is maximized subject to the balance invariant:
    /// per-cluster capacities come from the post-insert target sizes
    /// (`n' = n + b` split `q`/`q+1` across the k clusters), and each
    /// round solves a max-gain rectangular assignment of up to one new
    /// row per capacity-bearing cluster — dense LAPJV/auction/greedy, or
    /// the candidate-pruned CSR solvers once the active-cluster count
    /// crosses the session's [`crate::assignment::CandidateMode`]
    /// threshold. §4.3 category caps are masked exactly like the batch
    /// loop. Returns the assigned row ids, in incoming row order.
    ///
    /// Inserting into an **empty** handle instead runs the exact flat
    /// batch algorithm (serial, native) over the incoming view, so it
    /// reproduces the batch solver's partition.
    pub fn insert_batch(&mut self, view: &DataView<'_>) -> AbaResult<Vec<u64>> {
        let b = view.n();
        if b == 0 {
            return Ok(Vec::new());
        }
        if view.d() != self.store.d {
            return Err(AbaError::BadShape(format!(
                "insert batch has d={}, the partition has d={}",
                view.d(),
                self.store.d
            )));
        }
        if self.store.len() == 0 {
            return self.bootstrap(view);
        }
        let vcats = view.n_categories();
        if (self.n_cats > 0) != (vcats > 0) {
            return Err(AbaError::BadShape(
                "categorical presence of the batch does not match the partition".into(),
            ));
        }
        if vcats > self.n_cats {
            self.grow_categories(vcats);
        }
        // Stage the rows; ids are assigned in incoming order.
        let mut ids = Vec::with_capacity(b);
        let mut slots = Vec::with_capacity(b);
        for i in 0..b {
            let cat = if self.n_cats > 0 { view.category(i) } else { 0 };
            if self.n_cats > 0 {
                self.cat_totals[cat as usize] += 1;
            }
            let (id, slot) = self.store.insert(view.row(i), cat);
            ids.push(id);
            slots.push(slot);
        }
        let mut caps = self.insert_caps(b);
        let cat_caps = self.cat_caps();
        // N↓ over the incoming rows: decreasing distance to the
        // maintained global centroid (ties by arrival order), mirroring
        // the batch algorithm's processing order.
        let mu = self.global_centroid_f64();
        let dist: Vec<f64> = slots
            .iter()
            .map(|&slot| crate::runtime::simd::sq_dist_to_f64(self.store.row(slot), &mu))
            .collect();
        let mut order: Vec<usize> = (0..b).collect();
        order.sort_unstable_by(|&x, &y| dist[y].total_cmp(&dist[x]).then(x.cmp(&y)));
        // Rounds: at most one new row per capacity-bearing cluster each.
        let mut pos = 0usize;
        let mut round_slots: Vec<usize> = Vec::new();
        while pos < b {
            let active: Vec<usize> = (0..self.k).filter(|&c| caps[c] > 0).collect();
            debug_assert!(!active.is_empty(), "capacities exhausted before all rows placed");
            let m = (b - pos).min(active.len());
            round_slots.clear();
            round_slots.extend(order[pos..pos + m].iter().map(|&oi| slots[oi]));
            let assign = self.solve_round(&round_slots, &active, &cat_caps);
            for (j, &oi) in order[pos..pos + m].iter().enumerate() {
                let c = active[assign[j]];
                self.attach(ids[oi], slots[oi], c);
                caps[c] -= 1;
            }
            pos += m;
        }
        // Masked rounds can be forced past a §4.3 cap on adversarially
        // skewed batches — repair restores the invariants if so.
        self.repair();
        Ok(ids)
    }

    /// Remove rows by id, then repair the balance (and §4.3) invariants
    /// with cheapest-loss relocations. The call is atomic: unknown or
    /// duplicated ids fail with [`AbaError::InvalidInput`] before
    /// anything is removed.
    pub fn remove(&mut self, ids: &[u64]) -> AbaResult<()> {
        let mut unique = BTreeSet::new();
        for &id in ids {
            if self.store.slot_of(id).is_none() {
                return Err(AbaError::InvalidInput(format!("unknown row id {id}")));
            }
            if !unique.insert(id) {
                return Err(AbaError::InvalidInput(format!("duplicate row id {id}")));
            }
        }
        let d = self.store.d;
        for &id in ids {
            let slot = self.store.slot_of(id).expect("validated above");
            let c = self.store.labels[slot] as usize;
            let cat = self.store.cats[slot] as usize;
            {
                let row = &self.store.rows[slot * d..(slot + 1) * d];
                let cl = &mut self.clusters[c];
                cl.remove_member(id, row);
                if self.n_cats > 0 {
                    cl.cat_counts[cat] -= 1;
                }
            }
            if self.n_cats > 0 {
                self.cat_totals[cat] -= 1;
            }
            self.touched.insert(c);
            self.store.remove(id);
        }
        self.repair();
        Ok(())
    }

    /// One bounded exchange pass scoped to the clusters touched since
    /// the last refine: candidate swaps between a touched cluster and
    /// every other cluster are priced in O(d) off the maintained sums
    /// and applied when they improve the objective (category-cap-safe
    /// swaps only). `budget` caps the number of priced candidates;
    /// `refine(0)` is a no-op that preserves the touched set, and when
    /// the budget runs out mid-scope the unwalked clusters stay
    /// touched, so repeated calls resume instead of dropping them.
    /// Put every cluster in scope for the next [`OnlinePartition::refine`]
    /// — a *global* polish pass. Freshly built or loaded handles have an
    /// empty touched set (their state is exactly the solved partition),
    /// so a standalone refine with no preceding churn wants this first;
    /// the CLI's `update --refine` without `--insert`/`--remove` does it
    /// automatically.
    pub fn touch_all(&mut self) {
        self.touched.extend(0..self.k);
    }

    pub fn refine(&mut self, budget: usize) -> RefineStats {
        let mut stats = RefineStats::default();
        if budget == 0 || self.k < 2 {
            return stats;
        }
        let scope: Vec<usize> = self.touched.iter().copied().collect();
        self.touched.clear();
        let cat_caps = self.cat_caps();
        // Scope entries leave the touched set only once fully walked:
        // when the budget runs out mid-scope, the unfinished tail is
        // put back so the next refine resumes where this one stopped.
        let mut completed = 0usize;
        'outer: for (si, &a) in scope.iter().enumerate() {
            // One snapshot of a's members per touched cluster (stale
            // entries are re-checked below); b's members are walked by
            // position, which is safe because the list only mutates on
            // an applied swap — and a swap exits the position loop.
            let mems_a = self.clusters[a].members.clone();
            for b in 0..self.k {
                if b == a {
                    continue;
                }
                'ia: for &ida in &mems_a {
                    let mut pos_b = 0usize;
                    while let Some(&idb) = self.clusters[b].members.get(pos_b) {
                        pos_b += 1;
                        if stats.evaluated >= budget {
                            break 'outer;
                        }
                        // Snapshots go stale as swaps apply: skip pairs
                        // whose rows have moved (or been removed).
                        let (Some(sa), Some(sb)) =
                            (self.store.slot_of(ida), self.store.slot_of(idb))
                        else {
                            continue;
                        };
                        if self.store.labels[sa] as usize != a
                            || self.store.labels[sb] as usize != b
                        {
                            continue;
                        }
                        stats.evaluated += 1;
                        let Some(gain) = self.swap_gain(a, sa, b, sb, &cat_caps) else {
                            continue;
                        };
                        let eps = 1e-9
                            * (1.0
                                + self.clusters[a].delta.ssd().abs()
                                + self.clusters[b].delta.ssd().abs());
                        if gain > eps {
                            self.apply_swap(ida, sa, a, idb, sb, b);
                            stats.swapped += 1;
                            stats.est_gain += gain;
                            continue 'ia;
                        }
                    }
                }
            }
            completed = si + 1;
        }
        for &a in &scope[completed..] {
            self.touched.insert(a);
        }
        stats
    }

    // ---- internals -----------------------------------------------------

    /// Record `id` (staged at `slot`) as a member of cluster `c`.
    fn attach(&mut self, id: u64, slot: usize, c: usize) {
        debug_assert!(c < self.k, "cluster {c} out of range (k={})", self.k);
        let d = self.store.d;
        self.store.labels[slot] = c as u32;
        let cat = self.store.cats[slot] as usize;
        let row = &self.store.rows[slot * d..(slot + 1) * d];
        let cl = &mut self.clusters[c];
        cl.add_member(id, row);
        if self.n_cats > 0 {
            cl.cat_counts[cat] += 1;
        }
        self.touched.insert(c);
    }

    /// Move a live row between clusters.
    fn relocate(&mut self, id: u64, from: usize, to: usize) {
        debug_assert_ne!(from, to);
        let slot = self.store.slot_of(id).expect("id resolves");
        let d = self.store.d;
        let cat = self.store.cats[slot] as usize;
        {
            let row = &self.store.rows[slot * d..(slot + 1) * d];
            let cl = &mut self.clusters[from];
            cl.remove_member(id, row);
            if self.n_cats > 0 {
                cl.cat_counts[cat] -= 1;
            }
        }
        {
            let row = &self.store.rows[slot * d..(slot + 1) * d];
            let cl = &mut self.clusters[to];
            cl.add_member(id, row);
            if self.n_cats > 0 {
                cl.cat_counts[cat] += 1;
            }
        }
        self.store.labels[slot] = to as u32;
        self.touched.insert(from);
        self.touched.insert(to);
    }

    /// Mark every cluster's cached SSD from its (canonically built)
    /// running delta. Only valid right after a canonical full build
    /// (`from_labels`, bootstrap, load).
    fn seal(&mut self) {
        for cl in &mut self.clusters {
            cl.cached_ssd = cl.delta.ssd();
            cl.dirty = false;
        }
    }

    /// Canonically re-accumulate one cluster: ascending-id member
    /// order, fresh f64 sums. Re-syncs the running delta (bounding
    /// drift) and refreshes the cached SSD.
    fn refresh_cluster(&mut self, c: usize) {
        let d = self.store.d;
        let mut fresh = ClusterDelta::new(d);
        for idx in 0..self.clusters[c].members.len() {
            let id = self.clusters[c].members[idx];
            let slot = self.store.slot_of(id).expect("member resolves");
            fresh.add(self.store.row(slot));
        }
        let cl = &mut self.clusters[c];
        cl.cached_ssd = fresh.ssd();
        cl.delta = fresh;
        cl.dirty = false;
    }

    fn grow_categories(&mut self, n_cats: usize) {
        debug_assert!(n_cats >= self.n_cats);
        self.n_cats = n_cats;
        self.cat_totals.resize(n_cats, 0);
        for cl in &mut self.clusters {
            cl.cat_counts.resize(n_cats, 0);
        }
    }

    /// §4.3 upper bounds against the current totals.
    fn cat_caps(&self) -> Vec<usize> {
        (0..self.n_cats)
            .map(|g| self.cat_totals[g].div_ceil(self.k))
            .collect()
    }

    /// Per-cluster insert capacities by water-filling: the `b` new rows
    /// raise the **smallest** clusters first, so insertion always moves
    /// toward balance. On already-balanced sizes this reduces to the
    /// `q`/`q+1` post-insert targets; on skewed sizes (a hand-edited
    /// snapshot, or any future path that relaxes the invariant) it
    /// assigns no capacity to oversized clusters instead of
    /// under-allocating — the trailing `repair()` then finishes
    /// whatever imbalance the inserts could not absorb. Always sums to
    /// exactly `b`.
    fn insert_caps(&self, b: usize) -> Vec<usize> {
        // Largest level L with sum(max(0, L - size_c)) <= b, by binary
        // search (the fill cost is monotone in L).
        let fill_cost = |level: usize| -> usize {
            self.clusters
                .iter()
                .map(|c| level.saturating_sub(c.size()))
                .sum()
        };
        let min_size = self.clusters.iter().map(|c| c.size()).min().unwrap_or(0);
        let (mut lo, mut hi) = (min_size, min_size + b);
        while lo < hi {
            let mid = lo + (hi - lo + 1) / 2;
            if fill_cost(mid) <= b {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        let level = lo;
        let mut caps: Vec<usize> =
            self.clusters.iter().map(|c| level.saturating_sub(c.size())).collect();
        // Distribute the remainder one-by-one to the lowest-water
        // clusters (ties by index, deterministic).
        let mut remainder = b - fill_cost(level);
        let mut order: Vec<usize> = (0..self.k)
            .filter(|&c| self.clusters[c].size() <= level)
            .collect();
        order.sort_by_key(|&c| (self.clusters[c].size(), c));
        for &c in &order {
            if remainder == 0 {
                break;
            }
            caps[c] += 1;
            remainder -= 1;
        }
        debug_assert_eq!(remainder, 0, "water level left remainder unplaced");
        debug_assert_eq!(caps.iter().sum::<usize>(), b, "capacity mass mismatch");
        caps
    }

    /// Mean of all live rows off the maintained cluster sums.
    fn global_centroid_f64(&self) -> Vec<f64> {
        let d = self.store.d;
        let n: usize = self.clusters.iter().map(|c| c.size()).sum();
        let mut mu = vec![0f64; d];
        for cl in &self.clusters {
            for (m, &s) in mu.iter_mut().zip(cl.delta.sum()) {
                *m += s;
            }
        }
        if n > 0 {
            for m in mu.iter_mut() {
                *m /= n as f64;
            }
        }
        mu
    }

    /// Solve one insert round: max-gain assignment of `row_slots` to the
    /// `active` clusters (cost = `m/(m+1) * ||x - centroid||^2`, §4.3
    /// masked). Dispatches to the candidate-pruned CSR solvers when the
    /// session's candidate mode prunes at this round's width, with dense
    /// fallback on infeasibility — the same escape hatch as the batch
    /// loop.
    fn solve_round(&mut self, row_slots: &[usize], active: &[usize], cat_caps: &[usize]) -> Vec<usize> {
        let m = row_slots.len();
        let na = active.len();
        let d = self.store.d;
        // Active-cluster centroids and marginal-gain weights m/(m+1).
        let mut cents = vec![0f32; na * d];
        let mut w = vec![0f64; na];
        for (a, &c) in active.iter().enumerate() {
            let delta = &self.clusters[c].delta;
            let sz = delta.len();
            if sz > 0 {
                for (t, &sv) in delta.sum().iter().enumerate() {
                    cents[a * d + t] = (sv / sz as f64) as f32;
                }
                w[a] = sz as f64 / (sz as f64 + 1.0);
            }
        }
        let c_eff = self.cfg.candidates.effective(na);
        if c_eff < na && matches!(self.cfg.solver, SolverKind::Lapjv | SolverKind::Auction) {
            if let Some(assign) =
                self.solve_round_sparse(row_slots, active, &cents, &w, cat_caps, c_eff)
            {
                return assign;
            }
        }
        self.cost.clear();
        self.cost.resize(m * na, 0.0);
        for (j, &slot) in row_slots.iter().enumerate() {
            let row = self.store.row(slot);
            let cat = self.store.cats[slot] as usize;
            for (a, &c) in active.iter().enumerate() {
                let masked = self.n_cats > 0 && self.clusters[c].cat_counts[cat] >= cat_caps[cat];
                self.cost[j * na + a] = if masked {
                    MASK_COST
                } else {
                    (w[a] * sq_dist(row, &cents[a * d..(a + 1) * d])) as f32
                };
            }
        }
        let cost = &self.cost[..m * na];
        match self.cfg.solver {
            SolverKind::Greedy => greedy::solve_max(cost, m, na),
            SolverKind::Auction => auction::solve_max(cost, m, na),
            SolverKind::Lapjv => self.lapjv.solve(cost, m, na, true),
        }
    }

    /// The candidate-pruned round: top-`c0` farthest active centroids
    /// per row (capacity-aware) via [`FarthestIndex`], CSR assembly,
    /// CSR-aware LAPJV / sparse auction; on infeasibility the candidate
    /// count escalates (×2) until it would reach the active width.
    fn solve_round_sparse(
        &mut self,
        row_slots: &[usize],
        active: &[usize],
        cents: &[f32],
        w: &[f64],
        cat_caps: &[usize],
        c0: usize,
    ) -> Option<Vec<usize>> {
        let m = row_slots.len();
        let na = active.len();
        let d = self.store.d;
        self.farthest.build(cents, na, d);
        let mut c = c0.max(1);
        let mut row_ptr: Vec<usize> = Vec::with_capacity(m + 1);
        let mut cols: Vec<u32> = Vec::new();
        let mut vals: Vec<f32> = Vec::new();
        let mut best: Vec<(f64, u32)> = Vec::new();
        loop {
            row_ptr.clear();
            row_ptr.push(0);
            cols.clear();
            vals.clear();
            {
                let farthest = &self.farthest;
                let clusters = &self.clusters;
                let n_cats = self.n_cats;
                for &slot in row_slots {
                    let row = self.store.row(slot);
                    let cat = self.store.cats[slot] as usize;
                    let valid = |a: usize| {
                        n_cats == 0 || clusters[active[a]].cat_counts[cat] < cat_caps[cat]
                    };
                    farthest.farthest_into(cents, row, c, &valid, &mut best);
                    if best.is_empty() {
                        // No §4.3-valid candidate at any C: only the
                        // masked dense path can place this row.
                        return None;
                    }
                    for &(dist, col) in &best {
                        cols.push(col);
                        vals.push((w[col as usize] * dist) as f32);
                    }
                    row_ptr.push(cols.len());
                }
            }
            let csr = CsrCost { row_ptr: &row_ptr, cols: &cols, vals: &vals, nc: na };
            let solved = match self.cfg.solver {
                SolverKind::Auction => self.sparse_auction.solve_max(&csr, 1e-6),
                _ => self.sparse_jv.solve_max(&csr),
            };
            if let Some(assign) = solved {
                return Some(assign);
            }
            if c * 2 >= na {
                return None;
            }
            c *= 2;
        }
    }

    /// Restore the invariants: the §4.3 upper bounds (removals shrink
    /// totals, so caps can tighten under a cluster's count) and size
    /// balance (`max - min <= 1`), by relocating best-gain members.
    /// The two stages alternate until a fixed point; the bound exists
    /// only to guarantee termination against pathological oscillation
    /// (a size move forced through a saturated category — taken only
    /// when no cap-safe candidate exists anywhere — re-dirties the cap
    /// its next category round then fixes). The size invariant is
    /// unconditional: the loop always ends on a size stage and the size
    /// stage always converges. The §4.3 bound is restored whenever any
    /// cap-respecting relocation exists; under adversarial category
    /// geometry where none does, it is best-effort.
    fn repair(&mut self) {
        for _ in 0..2 * self.k + 8 {
            let cat_moves = self.repair_categories();
            let size_moves = self.repair_sizes();
            if cat_moves == 0 && size_moves == 0 {
                return;
            }
        }
        // Bound hit: one final unconditional size pass so the hard
        // invariant holds no matter what the alternation was doing.
        self.repair_sizes();
    }

    /// Relocate members of §4.3-overfull (cluster, category) cells to
    /// the least-loaded cluster for that category. Returns moves made.
    fn repair_categories(&mut self) -> usize {
        let mut moves = 0usize;
        if self.n_cats > 0 {
            let caps = self.cat_caps();
            for g in 0..self.n_cats {
                loop {
                    // Most-violating cluster for category g.
                    let mut from = usize::MAX;
                    for c in 0..self.k {
                        if self.clusters[c].cat_counts[g] > caps[g]
                            && (from == usize::MAX
                                || self.clusters[c].cat_counts[g]
                                    > self.clusters[from].cat_counts[g])
                        {
                            from = c;
                        }
                    }
                    if from == usize::MAX {
                        break;
                    }
                    // Recipient with the fewest g members (one with
                    // headroom always exists while a violator does).
                    let mut to = usize::MAX;
                    for c in 0..self.k {
                        if c == from || self.clusters[c].cat_counts[g] >= caps[g] {
                            continue;
                        }
                        if to == usize::MAX
                            || self.clusters[c].cat_counts[g] < self.clusters[to].cat_counts[g]
                            || (self.clusters[c].cat_counts[g]
                                == self.clusters[to].cat_counts[g]
                                && self.clusters[c].size() < self.clusters[to].size())
                        {
                            to = c;
                        }
                    }
                    if to == usize::MAX {
                        break;
                    }
                    // Best g-member of the violator to relocate.
                    let mut pick: Option<(u64, f64)> = None;
                    for &id in &self.clusters[from].members {
                        let slot = self.store.slot_of(id).expect("member resolves");
                        if self.store.cats[slot] as usize != g {
                            continue;
                        }
                        let row = self.store.row(slot);
                        let gain = self.clusters[to].delta.add_gain(row)
                            - self.clusters[from].delta.remove_loss(row);
                        if pick.map_or(true, |(_, bg)| gain > bg) {
                            pick = Some((id, gain));
                        }
                    }
                    let Some((id, _)) = pick else { break };
                    self.relocate(id, from, to);
                    moves += 1;
                }
            }
        }
        moves
    }

    /// Move best-gain members from largest to smallest clusters until
    /// `max - min <= 1`. Returns moves made.
    fn repair_sizes(&mut self) -> usize {
        let mut moves = 0usize;
        loop {
            let mut min_c = 0usize;
            let mut max_c = 0usize;
            for c in 1..self.k {
                if self.clusters[c].size() < self.clusters[min_c].size() {
                    min_c = c;
                }
                if self.clusters[c].size() > self.clusters[max_c].size() {
                    max_c = c;
                }
            }
            let (min_sz, max_sz) = (self.clusters[min_c].size(), self.clusters[max_c].size());
            if max_sz - min_sz <= 1 {
                break;
            }
            let donors: Vec<usize> =
                (0..self.k).filter(|&c| self.clusters[c].size() == max_sz).collect();
            let recipients: Vec<usize> =
                (0..self.k).filter(|&c| self.clusters[c].size() == min_sz).collect();
            let caps = self.cat_caps();
            let mv = self
                .best_move(&donors, &recipients, &caps, true)
                .or_else(|| self.best_move(&donors, &recipients, &caps, false));
            let Some((id, from, to, _)) = mv else { break };
            self.relocate(id, from, to);
            moves += 1;
        }
        moves
    }

    /// Highest-gain single relocation from a donor to a recipient
    /// cluster; `require_cat_ok` restricts to moves that respect the
    /// §4.3 caps.
    fn best_move(
        &self,
        donors: &[usize],
        recipients: &[usize],
        cat_caps: &[usize],
        require_cat_ok: bool,
    ) -> Option<(u64, usize, usize, f64)> {
        let mut best: Option<(u64, usize, usize, f64)> = None;
        for &from in donors {
            for &id in &self.clusters[from].members {
                let slot = self.store.slot_of(id).expect("member resolves");
                let row = self.store.row(slot);
                let cat = self.store.cats[slot] as usize;
                let loss = self.clusters[from].delta.remove_loss(row);
                for &to in recipients {
                    if to == from {
                        continue;
                    }
                    if require_cat_ok
                        && self.n_cats > 0
                        && self.clusters[to].cat_counts[cat] >= cat_caps[cat]
                    {
                        continue;
                    }
                    let gain = self.clusters[to].delta.add_gain(row) - loss;
                    if best.map_or(true, |(_, _, _, bg)| gain > bg) {
                        best = Some((id, from, to, gain));
                    }
                }
            }
        }
        best
    }

    /// Price the swap of member `sa` (cluster `a`) with member `sb`
    /// (cluster `b`) — O(d) off the running sums. `None` when the swap
    /// would break a §4.3 cap.
    fn swap_gain(
        &self,
        a: usize,
        sa: usize,
        b: usize,
        sb: usize,
        cat_caps: &[usize],
    ) -> Option<f64> {
        let d = self.store.d;
        let xa = &self.store.rows[sa * d..(sa + 1) * d];
        let xb = &self.store.rows[sb * d..(sb + 1) * d];
        if self.n_cats > 0 {
            let ca = self.store.cats[sa] as usize;
            let cb = self.store.cats[sb] as usize;
            if ca != cb
                && (self.clusters[b].cat_counts[ca] >= cat_caps[ca]
                    || self.clusters[a].cat_counts[cb] >= cat_caps[cb])
            {
                return None;
            }
        }
        let da = &self.clusters[a].delta;
        let db = &self.clusters[b].delta;
        let (ma, mb) = (da.len() as f64, db.len() as f64);
        let (mut sa2, mut sb2, mut xa2, mut xb2) = (0f64, 0f64, 0f64, 0f64);
        for t in 0..d {
            let (va, vb) = (xa[t] as f64, xb[t] as f64);
            xa2 += va * va;
            xb2 += vb * vb;
            let at = da.sum()[t] - va + vb;
            sa2 += at * at;
            let bt = db.sum()[t] - vb + va;
            sb2 += bt * bt;
        }
        let before = da.ssd() + db.ssd();
        let after =
            (da.sumsq() - xa2 + xb2 - sa2 / ma) + (db.sumsq() - xb2 + xa2 - sb2 / mb);
        Some(after - before)
    }

    fn apply_swap(&mut self, ida: u64, sa: usize, a: usize, idb: u64, sb: usize, b: usize) {
        let d = self.store.d;
        let (ca, cb) = (self.store.cats[sa] as usize, self.store.cats[sb] as usize);
        {
            let xa = &self.store.rows[sa * d..(sa + 1) * d];
            let cl = &mut self.clusters[a];
            cl.remove_member(ida, xa);
            if self.n_cats > 0 {
                cl.cat_counts[ca] -= 1;
            }
        }
        {
            let xb = &self.store.rows[sb * d..(sb + 1) * d];
            let cl = &mut self.clusters[b];
            cl.remove_member(idb, xb);
            if self.n_cats > 0 {
                cl.cat_counts[cb] -= 1;
            }
        }
        {
            let xb = &self.store.rows[sb * d..(sb + 1) * d];
            let cl = &mut self.clusters[a];
            cl.add_member(idb, xb);
            if self.n_cats > 0 {
                cl.cat_counts[cb] += 1;
            }
        }
        {
            let xa = &self.store.rows[sa * d..(sa + 1) * d];
            let cl = &mut self.clusters[b];
            cl.add_member(ida, xa);
            if self.n_cats > 0 {
                cl.cat_counts[ca] += 1;
            }
        }
        self.store.labels[sa] = b as u32;
        self.store.labels[sb] = a as u32;
        self.touched.insert(a);
        self.touched.insert(b);
    }

    /// Bootstrap an empty handle: the exact flat batch algorithm
    /// (serial, native backend) over the incoming view.
    fn bootstrap(&mut self, view: &DataView<'_>) -> AbaResult<Vec<u64>> {
        // Adopt the batch's categorical structure wholesale, and reset
        // the per-cluster state completely: a previously drained handle
        // leaves residual f64 drift in the running deltas, and `seal`
        // below assumes a canonical from-zero accumulation.
        let n_cats = view.n_categories();
        let d = self.store.d;
        self.n_cats = 0;
        self.cat_totals.clear();
        for cl in &mut self.clusters {
            debug_assert!(cl.members.is_empty(), "bootstrap on a non-empty handle");
            *cl = ClusterState::new(d, 0);
        }
        if n_cats > 0 {
            self.grow_categories(n_cats);
        }
        algo::validate(view.n(), self.k, self.cfg.strict_divisibility)?;
        let (labels, order_secs, assign_secs) = if self.k == 1 {
            (vec![0u32; view.n()], 0.0, 0.0)
        } else {
            let mut backend = NativeBackend::default();
            let t = Instant::now();
            let variant = algo::resolve_variant(self.cfg.variant, view.n(), self.k);
            let order = batching::build_order(view, self.k, variant, &mut backend);
            let order_secs = t.elapsed().as_secs_f64();
            let t = Instant::now();
            let mut scratch = Scratch::with_lapjv_warm(
                self.cfg.lapjv_warm.unwrap_or_else(warm_start_env_default),
            );
            let labels = algo::core::run_with_order_scratch(
                view,
                self.k,
                &order,
                self.cfg.solver,
                &mut backend,
                &mut scratch,
                Parallelism::Serial,
                self.cfg.candidates,
            )?;
            (labels, order_secs, t.elapsed().as_secs_f64())
        };
        self.timings = PhaseTimings { order_secs, assign_secs, ..PhaseTimings::default() };
        let mut ids = Vec::with_capacity(view.n());
        for (i, &label) in labels.iter().enumerate() {
            let cat = if n_cats > 0 { view.category(i) } else { 0 };
            if n_cats > 0 {
                self.cat_totals[cat as usize] += 1;
            }
            let (id, slot) = self.store.insert(view.row(i), cat);
            self.attach(id, slot, label as usize);
            ids.push(id);
        }
        self.seal();
        self.touched.clear();
        Ok(ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};
    use crate::solver::{Aba, Anticlusterer};

    fn handle(n: usize, k: usize, seed: u64) -> (OnlinePartition, Dataset) {
        let ds = generate(SynthKind::Uniform, n, 3, seed, "online");
        let mut session = Aba::builder().auto_hier(false).build().unwrap();
        let part = session.partition_online(&ds.view(), k).unwrap();
        (part, ds)
    }

    fn assert_balanced(p: &OnlinePartition) {
        let sizes = p.sizes();
        let (min, max) = (
            *sizes.iter().min().unwrap(),
            *sizes.iter().max().unwrap(),
        );
        assert!(max - min <= 1, "unbalanced: {sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), p.len());
    }

    #[test]
    fn gap_is_maintained_under_churn() {
        let (mut p, _ds) = handle(80, 4, 31);
        assert!(p.upper_bound() >= p.objective());
        assert!((0.0..=1.0).contains(&p.gap()));
        // Churn dirties clusters; the lazy bound must stay valid and
        // agree with the frozen partition's stats-derived bound.
        let arrivals = generate(SynthKind::Uniform, 20, 3, 32, "arrivals");
        let ids = p.insert_batch(&arrivals.view()).unwrap();
        p.remove(&ids[..8]).unwrap();
        p.refine(5_000);
        let (obj, ub, gap) = (p.objective(), p.upper_bound(), p.gap());
        assert!(ub >= obj, "bound {ub} below objective {obj}");
        assert!((0.0..=1.0).contains(&gap));
        let frozen = p.into_partition();
        let rel = (ub - frozen.upper_bound()).abs() / ub.max(1.0);
        assert!(rel < 1e-9, "live {ub} vs frozen {}", frozen.upper_bound());
    }

    #[test]
    fn empty_handle_has_zero_gap() {
        let mut p =
            OnlinePartition::empty(3, 2, &crate::algo::AbaConfig::default()).unwrap();
        assert_eq!(p.upper_bound(), 0.0);
        assert_eq!(p.gap(), 0.0);
    }

    #[test]
    fn handle_mirrors_the_frozen_partition() {
        let (mut p, ds) = handle(60, 5, 1);
        assert_eq!(p.len(), 60);
        assert_eq!(p.k(), 5);
        assert_eq!(p.d(), 3);
        assert_balanced(&p);
        let obj = p.objective();
        assert_eq!(obj, p.recompute_objective());
        let mut session = Aba::builder().auto_hier(false).build().unwrap();
        let part = session.partition(&ds, 5).unwrap();
        let entries = p.entries();
        for (i, &(id, label)) in entries.iter().enumerate() {
            assert_eq!(id, i as u64);
            assert_eq!(label, part.labels[i]);
        }
        assert!((obj - part.objective).abs() <= 1e-6 * part.objective.max(1.0));
    }

    #[test]
    fn insert_then_remove_round_trips_objective_reads() {
        let (mut p, _) = handle(60, 5, 2);
        let extra = generate(SynthKind::Uniform, 7, 3, 3, "extra");
        let ids = p.insert_batch(&extra.view()).unwrap();
        assert_eq!(ids, (60..67).collect::<Vec<u64>>());
        assert_eq!(p.len(), 67);
        assert_balanced(&p);
        assert_eq!(p.objective(), p.recompute_objective());
        p.remove(&ids).unwrap();
        assert_eq!(p.len(), 60);
        assert_balanced(&p);
        assert_eq!(p.objective(), p.recompute_objective());
    }

    #[test]
    fn remove_rejects_unknown_and_duplicate_ids_atomically() {
        let (mut p, _) = handle(20, 4, 4);
        assert!(matches!(p.remove(&[99]), Err(AbaError::InvalidInput(_))));
        assert!(matches!(p.remove(&[3, 3]), Err(AbaError::InvalidInput(_))));
        assert_eq!(p.len(), 20, "failed removes must not mutate");
        assert_balanced(&p);
    }

    #[test]
    fn refine_never_decreases_the_objective() {
        let (mut p, _) = handle(80, 4, 5);
        let extra = generate(SynthKind::GaussianMixture { components: 3, spread: 5.0 }, 12, 3, 6, "x");
        p.insert_batch(&extra.view()).unwrap();
        let before = p.objective();
        let stats = p.refine(50_000);
        let after = p.objective();
        assert!(after >= before - 1e-9 * before.abs().max(1.0), "{before} -> {after}");
        assert_eq!(after, p.recompute_objective());
        assert_balanced(&p);
        assert!(stats.evaluated > 0);
    }

    #[test]
    fn touch_all_enables_standalone_refine() {
        // A fresh handle has nothing touched: scoped refine is a no-op
        // until churn (or an explicit global touch) gives it scope.
        let (mut p, _) = handle(60, 4, 15);
        assert_eq!(p.refine(10_000).evaluated, 0);
        p.touch_all();
        let stats = p.refine(10_000);
        assert!(stats.evaluated > 0);
        assert_eq!(p.objective(), p.recompute_objective());
        assert_balanced(&p);
    }

    #[test]
    fn empty_handle_insert_reproduces_the_batch_solver() {
        let ds = generate(SynthKind::Uniform, 72, 4, 7, "boot");
        let cfg = AbaConfig { auto_hier: false, ..AbaConfig::default() };
        let mut empty = OnlinePartition::empty(6, 4, &cfg).unwrap();
        let ids = empty.insert_batch(&ds.view()).unwrap();
        assert_eq!(ids.len(), 72);
        let mut session = Aba::from_config(cfg).unwrap();
        let part = session.partition(&ds, 6).unwrap();
        for (i, &(id, label)) in empty.entries().iter().enumerate() {
            assert_eq!(id, ids[i]);
            assert_eq!(label, part.labels[i], "row {i}");
        }
    }

    #[test]
    fn drained_handle_bootstraps_again() {
        let (mut p, ds) = handle(30, 3, 8);
        let all: Vec<u64> = p.entries().iter().map(|&(id, _)| id).collect();
        p.remove(&all).unwrap();
        assert!(p.is_empty());
        assert_eq!(p.objective(), 0.0);
        let ids = p.insert_batch(&ds.view()).unwrap();
        assert_eq!(ids.len(), 30);
        assert_eq!(ids[0], 30, "fresh ids continue after the old ones");
        assert_balanced(&p);
    }

    #[test]
    fn categorical_churn_respects_caps() {
        let n = 60;
        let ds = generate(SynthKind::Uniform, n, 3, 9, "cat")
            .with_categories((0..n).map(|i| (i % 3) as u32).collect())
            .unwrap();
        let mut session = Aba::builder().auto_hier(false).build().unwrap();
        let mut p = session.partition_online(&ds.view(), 5).unwrap();
        let extra = generate(SynthKind::Uniform, 9, 3, 10, "cx")
            .with_categories((0..9).map(|i| (i % 3) as u32).collect())
            .unwrap();
        let ids = p.insert_batch(&extra.view()).unwrap();
        p.remove(&ids[..4]).unwrap();
        p.refine(20_000);
        assert_balanced(&p);
        // §4.3 upper bounds on every (cluster, category) count.
        let caps: Vec<usize> = (0..3).map(|g| p.cat_totals[g].div_ceil(p.k())).collect();
        for c in 0..p.k() {
            for g in 0..3 {
                assert!(
                    p.clusters[c].cat_counts[g] <= caps[g],
                    "cluster {c} cat {g}: {} > cap {}",
                    p.clusters[c].cat_counts[g],
                    caps[g]
                );
            }
        }
        assert_eq!(p.objective(), p.recompute_objective());
    }

    #[test]
    fn mismatched_batch_shapes_are_typed_errors() {
        let (mut p, _) = handle(20, 4, 11);
        let wrong_d = generate(SynthKind::Uniform, 5, 2, 12, "w");
        assert!(matches!(p.insert_batch(&wrong_d.view()), Err(AbaError::BadShape(_))));
        let catted = generate(SynthKind::Uniform, 5, 3, 13, "c")
            .with_categories(vec![0, 1, 0, 1, 0])
            .unwrap();
        assert!(matches!(p.insert_batch(&catted.view()), Err(AbaError::BadShape(_))));
    }

    #[test]
    fn freeze_matches_partition_view() {
        let ds = generate(SynthKind::Uniform, 48, 3, 14, "f");
        let mut a = Aba::builder().auto_hier(false).build().unwrap();
        let mut b = Aba::builder().auto_hier(false).build().unwrap();
        let frozen = a.partition_online(&ds.view(), 4).unwrap().into_partition();
        let direct = crate::solver::Anticlusterer::partition_view(&mut b, &ds.view(), 4).unwrap();
        assert_eq!(frozen.labels, direct.labels);
        assert_eq!(frozen.objective, direct.objective);
    }
}
