//! Persistence for [`OnlinePartition`]: versioned JSON snapshots with a
//! config fingerprint, so serving processes can warm-restart.
//!
//! Format (version 1), written through [`crate::util::json`]:
//!
//! ```json
//! {
//!   "format": 1,
//!   "fingerprint": "aba/1|variant=auto|solver=lapjv|candidates=auto|strict=false",
//!   "k": 16, "d": 8, "n_cats": 0, "next_id": 8200,
//!   "ids":    [0, 1, 5, ...],          // ascending
//!   "labels": [3, 0, 12, ...],         // parallel to ids
//!   "cats":   [0, 2, 1, ...],          // only when n_cats > 0
//!   "rows":   [0.25, -1.5, ...]        // row-major f32, ids order
//! }
//! ```
//!
//! Rows are f32 values embedded exactly in f64 JSON numbers, and Rust's
//! shortest-round-trip float formatting preserves them bit for bit —
//! `save -> load -> save` reproduces the file byte-identically
//! (property-tested). Loading checks the format version and the
//! [`crate::algo::AbaConfig::fingerprint`] and fails with
//! [`AbaError::SnapshotMismatch`] rather than resuming a partition
//! under an incompatible session.

use super::OnlinePartition;
use crate::algo::AbaConfig;
use crate::error::{AbaError, AbaResult};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::Path;

/// Snapshot format version.
const FORMAT: usize = 1;

fn io_err(action: &str, path: &Path, e: std::io::Error) -> AbaError {
    AbaError::Io(format!("{action} {path:?}: {e}"))
}

fn field<'a>(doc: &'a Json, key: &str) -> AbaResult<&'a Json> {
    doc.get(key).ok_or_else(|| AbaError::ParseError {
        line: 1,
        msg: format!("snapshot is missing '{key}'"),
    })
}

fn as_usize(doc: &Json, key: &str) -> AbaResult<usize> {
    field(doc, key)?.as_usize().ok_or_else(|| AbaError::ParseError {
        line: 1,
        msg: format!("snapshot field '{key}' is not a number"),
    })
}

fn num_array<'a>(doc: &'a Json, key: &str) -> AbaResult<&'a [Json]> {
    field(doc, key)?.as_arr().ok_or_else(|| AbaError::ParseError {
        line: 1,
        msg: format!("snapshot field '{key}' is not an array"),
    })
}

/// Header + shape summary of a snapshot file, readable without a
/// session config (ops debugging: `aba snapshot inspect <file>`).
#[derive(Clone, Debug, PartialEq)]
pub struct SnapshotInfo {
    /// Snapshot format version (currently always 1).
    pub format: usize,
    /// The [`AbaConfig::fingerprint`] the snapshot was taken under.
    pub fingerprint: String,
    /// Live rows.
    pub n: usize,
    /// Anticluster count.
    pub k: usize,
    /// Feature dimension.
    pub d: usize,
    /// Categorical levels (0 = no categorical feature).
    pub n_cats: usize,
    /// Per-anticluster sizes, counted from the label vector.
    pub sizes: Vec<usize>,
}

/// Inspect a snapshot document without constructing an
/// [`OnlinePartition`] (and without a config: the fingerprint is
/// *reported*, not checked). Unlike [`OnlinePartition::load`] this
/// never rebuilds cluster state — it only parses the header and counts
/// labels — so it is safe to point at a snapshot from any session.
pub fn inspect_snapshot_str(text: &str) -> AbaResult<SnapshotInfo> {
    let doc = json::parse(text).map_err(|e| AbaError::ParseError {
        line: 1,
        msg: format!("snapshot json: {e}"),
    })?;
    let format = as_usize(&doc, "format")?;
    let fingerprint = field(&doc, "fingerprint")?
        .as_str()
        .ok_or_else(|| AbaError::ParseError {
            line: 1,
            msg: "snapshot fingerprint is not a string".into(),
        })?
        .to_string();
    let k = as_usize(&doc, "k")?;
    let d = as_usize(&doc, "d")?;
    let n_cats = as_usize(&doc, "n_cats")?;
    let ids = num_array(&doc, "ids")?;
    let labels = num_array(&doc, "labels")?;
    let n = ids.len();
    if labels.len() != n {
        return Err(AbaError::ParseError {
            line: 1,
            msg: format!("snapshot shape mismatch: {n} ids, {} labels", labels.len()),
        });
    }
    let mut sizes = vec![0usize; k];
    for (i, l) in labels.iter().enumerate() {
        let label = l.as_f64().ok_or_else(|| AbaError::ParseError {
            line: 1,
            msg: format!("snapshot label #{i} is not a valid number"),
        })? as usize;
        if label >= k {
            return Err(AbaError::ParseError {
                line: 1,
                msg: format!("snapshot label {label} out of range (k={k})"),
            });
        }
        sizes[label] += 1;
    }
    Ok(SnapshotInfo { format, fingerprint, n, k, d, n_cats, sizes })
}

/// [`inspect_snapshot_str`] over a file path.
pub fn inspect_snapshot(path: impl AsRef<Path>) -> AbaResult<SnapshotInfo> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path).map_err(|e| io_err("read", path, e))?;
    inspect_snapshot_str(&text)
}

impl OnlinePartition {
    /// Serialize the handle to the version-1 JSON snapshot format.
    pub fn save(&self, path: impl AsRef<Path>) -> AbaResult<()> {
        let path = path.as_ref();
        std::fs::write(path, self.snapshot_string()).map_err(|e| io_err("write", path, e))
    }

    /// The snapshot document as a string (what [`OnlinePartition::save`]
    /// writes) — exposed so tests can assert byte-identical round trips.
    pub fn snapshot_string(&self) -> String {
        let mut doc: BTreeMap<String, Json> = BTreeMap::new();
        doc.insert("format".into(), Json::Num(FORMAT as f64));
        doc.insert("fingerprint".into(), Json::Str(self.cfg.fingerprint()));
        doc.insert("k".into(), Json::Num(self.k as f64));
        doc.insert("d".into(), Json::Num(self.store.d as f64));
        doc.insert("n_cats".into(), Json::Num(self.n_cats as f64));
        doc.insert("next_id".into(), Json::Num(self.store.next_id as f64));
        let mut ids = Vec::with_capacity(self.store.len());
        let mut labels = Vec::with_capacity(self.store.len());
        let mut cats = Vec::with_capacity(if self.n_cats > 0 { self.store.len() } else { 0 });
        let mut rows = Vec::with_capacity(self.store.len() * self.store.d);
        for (id, slot) in self.store.iter() {
            ids.push(Json::Num(id as f64));
            labels.push(Json::Num(f64::from(self.store.labels[slot])));
            if self.n_cats > 0 {
                cats.push(Json::Num(f64::from(self.store.cats[slot])));
            }
            for &v in self.store.row(slot) {
                rows.push(Json::Num(f64::from(v)));
            }
        }
        doc.insert("ids".into(), Json::Arr(ids));
        doc.insert("labels".into(), Json::Arr(labels));
        if self.n_cats > 0 {
            doc.insert("cats".into(), Json::Arr(cats));
        }
        doc.insert("rows".into(), Json::Arr(rows));
        json::to_string(&Json::Obj(doc))
    }

    /// Load a snapshot written by [`OnlinePartition::save`]. The
    /// session config must produce the same fingerprint the snapshot
    /// was taken under — [`AbaError::SnapshotMismatch`] otherwise.
    pub fn load(path: impl AsRef<Path>, cfg: &AbaConfig) -> AbaResult<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| io_err("read", path, e))?;
        Self::from_snapshot_str(&text, cfg)
    }

    /// Parse a snapshot document (the inverse of
    /// [`OnlinePartition::snapshot_string`]).
    pub fn from_snapshot_str(text: &str, cfg: &AbaConfig) -> AbaResult<Self> {
        let doc = json::parse(text).map_err(|e| AbaError::ParseError {
            line: 1,
            msg: format!("snapshot json: {e}"),
        })?;
        let format = as_usize(&doc, "format")?;
        if format != FORMAT {
            return Err(AbaError::SnapshotMismatch {
                expected: format!("format {FORMAT}"),
                found: format!("format {format}"),
            });
        }
        let found = field(&doc, "fingerprint")?
            .as_str()
            .ok_or_else(|| AbaError::ParseError {
                line: 1,
                msg: "snapshot fingerprint is not a string".into(),
            })?
            .to_string();
        let expected = cfg.fingerprint();
        if found != expected {
            return Err(AbaError::SnapshotMismatch { expected, found });
        }
        let k = as_usize(&doc, "k")?;
        let d = as_usize(&doc, "d")?;
        let n_cats = as_usize(&doc, "n_cats")?;
        let next_id = as_usize(&doc, "next_id")? as u64;
        let ids = num_array(&doc, "ids")?;
        let labels = num_array(&doc, "labels")?;
        let rows = num_array(&doc, "rows")?;
        let n = ids.len();
        if labels.len() != n || rows.len() != n * d {
            return Err(AbaError::ParseError {
                line: 1,
                msg: format!(
                    "snapshot shape mismatch: {n} ids, {} labels, {} row values (d={d})",
                    labels.len(),
                    rows.len()
                ),
            });
        }
        let cats: Option<&[Json]> = if n_cats > 0 {
            let cats = num_array(&doc, "cats")?;
            if cats.len() != n {
                return Err(AbaError::ParseError {
                    line: 1,
                    msg: format!("snapshot has {} cats for {n} ids", cats.len()),
                });
            }
            Some(cats)
        } else {
            None
        };
        let mut part = Self::empty(k, d, cfg)?;
        if n_cats > 0 {
            part.grow_categories(n_cats);
        }
        let bad = |what: &str, i: usize| AbaError::ParseError {
            line: 1,
            msg: format!("snapshot {what} #{i} is not a valid number"),
        };
        let mut row = vec![0f32; d];
        let mut prev_id: Option<u64> = None;
        for i in 0..n {
            let id = ids[i].as_f64().ok_or_else(|| bad("id", i))? as u64;
            if prev_id.is_some_and(|p| p >= id) {
                return Err(AbaError::ParseError {
                    line: 1,
                    msg: format!("snapshot ids are not strictly ascending at #{i}"),
                });
            }
            prev_id = Some(id);
            let label = labels[i].as_f64().ok_or_else(|| bad("label", i))? as usize;
            if label >= k {
                return Err(AbaError::ParseError {
                    line: 1,
                    msg: format!("snapshot label {label} out of range (k={k})"),
                });
            }
            for (t, dst) in row.iter_mut().enumerate() {
                *dst = rows[i * d + t].as_f64().ok_or_else(|| bad("row value", i))? as f32;
            }
            let cat = match cats {
                Some(cats) => {
                    let c = cats[i].as_f64().ok_or_else(|| bad("category", i))? as usize;
                    if c >= n_cats {
                        return Err(AbaError::ParseError {
                            line: 1,
                            msg: format!("snapshot category {c} out of range (n_cats={n_cats})"),
                        });
                    }
                    part.cat_totals[c] += 1;
                    c as u32
                }
                None => 0,
            };
            let slot = part.store.insert_with_id(id, &row, cat, super::state::UNASSIGNED);
            part.attach(id, slot, label);
        }
        part.store.next_id = next_id.max(prev_id.map_or(0, |p| p + 1));
        part.seal();
        part.touched.clear();
        Ok(part)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};
    use crate::solver::Aba;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(name)
    }

    #[test]
    fn save_load_round_trips_byte_identically() {
        let ds = generate(SynthKind::Uniform, 40, 3, 31, "p");
        let mut session = Aba::builder().auto_hier(false).build().unwrap();
        let mut part = session.partition_online(&ds.view(), 4).unwrap();
        // Churn so ids are non-contiguous and slots recycled.
        let extra = generate(SynthKind::Uniform, 6, 3, 32, "px");
        let ids = part.insert_batch(&extra.view()).unwrap();
        part.remove(&ids[..3]).unwrap();
        let path = tmp("aba_online_rt.json");
        part.save(&path).unwrap();
        let mut back = OnlinePartition::load(&path, session.config()).unwrap();
        assert_eq!(back.len(), part.len());
        assert_eq!(back.entries(), part.entries());
        assert_eq!(back.sizes(), part.sizes());
        assert_eq!(back.objective(), part.objective());
        assert_eq!(back.snapshot_string(), part.snapshot_string());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn incompatible_fingerprint_is_a_typed_error() {
        let ds = generate(SynthKind::Uniform, 20, 2, 33, "p");
        let mut session = Aba::builder().auto_hier(false).build().unwrap();
        let part = session.partition_online(&ds.view(), 4).unwrap();
        let path = tmp("aba_online_fp.json");
        part.save(&path).unwrap();
        let other = AbaConfig {
            solver: crate::assignment::SolverKind::Greedy,
            ..AbaConfig::default()
        };
        let err = OnlinePartition::load(&path, &other).unwrap_err();
        assert!(matches!(err, AbaError::SnapshotMismatch { .. }), "{err}");
        assert!(err.to_string().contains("greedy"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn inspect_reports_header_without_a_config() {
        let ds = generate(SynthKind::Uniform, 30, 3, 34, "p");
        let mut session = Aba::builder().auto_hier(false).build().unwrap();
        let mut part = session.partition_online(&ds.view(), 5).unwrap();
        let info = inspect_snapshot_str(&part.snapshot_string()).unwrap();
        assert_eq!(info.format, 1);
        assert_eq!(info.fingerprint, session.config().fingerprint());
        assert_eq!(info.n, 30);
        assert_eq!(info.k, 5);
        assert_eq!(info.d, 3);
        assert_eq!(info.n_cats, 0);
        assert_eq!(info.sizes, part.sizes());
        // Truncated snapshots fail with a located parse error, not a
        // bare failure (the util/json context excerpt flows through).
        let text = part.snapshot_string();
        let err = inspect_snapshot_str(&text[..text.len() / 2]).unwrap_err();
        assert!(err.to_string().contains("byte"), "{err}");
        assert!(matches!(
            inspect_snapshot(tmp("aba_online_nonexistent.json")),
            Err(AbaError::Io(_))
        ));
    }

    #[test]
    fn corrupt_snapshots_are_parse_errors() {
        let cfg = AbaConfig::default();
        assert!(matches!(
            OnlinePartition::from_snapshot_str("{not json", &cfg),
            Err(AbaError::ParseError { .. })
        ));
        assert!(matches!(
            OnlinePartition::from_snapshot_str("{\"format\": 1}", &cfg),
            Err(AbaError::ParseError { .. })
        ));
        assert!(matches!(
            OnlinePartition::from_snapshot_str("{\"format\": 2}", &cfg),
            Err(AbaError::SnapshotMismatch { .. })
        ));
        assert!(matches!(
            OnlinePartition::load(tmp("aba_online_nonexistent.json"), &cfg),
            Err(AbaError::Io(_))
        ));
    }
}
