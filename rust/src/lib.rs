//! # aba — Assignment-Based Anticlustering
//!
//! A production-grade reproduction of *"A Fast and Effective Method for
//! Euclidean Anticlustering: The Assignment-Based-Anticlustering
//! Algorithm"* (Baumann, Goldschmidt, Hochbaum, Yang, 2026) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: sorting/batching, LAPJV
//!   assignment, centroid state, hierarchical decomposition, categorical
//!   balancing, the mini-batch streaming pipeline, every baseline from the
//!   paper's evaluation, and the experiment harness that regenerates each
//!   table and figure.
//! * **L2 (`python/compile/model.py`)** — JAX compute graphs, AOT-lowered
//!   to HLO text at build time (`make artifacts`).
//! * **L1 (`python/compile/kernels/`)** — the Pallas cost-matrix kernel the
//!   L2 graphs call.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT (`xla`
//! crate); Python never runs on the request path.
//!
//! ## Quick start
//!
//! ```no_run
//! use aba::algo::{AbaConfig, run_aba};
//! use aba::data::synth::{generate, SynthKind};
//!
//! let ds = generate(SynthKind::GaussianMixture { components: 8, spread: 4.0 },
//!                   10_000, 16, 42, "demo");
//! let labels = run_aba(&ds, 50, &AbaConfig::default()).unwrap();
//! ```

pub mod algo;
pub mod assignment;
pub mod baselines;
pub mod data;
pub mod experiments;
pub mod graph;
pub mod knn;
pub mod metrics;
pub mod pipeline;
pub mod rng;
pub mod runtime;
pub mod testing;
pub mod util;

/// Crate-wide result type (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
