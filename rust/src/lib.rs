//! # aba — Assignment-Based Anticlustering
//!
//! A production-grade reproduction of *"A Fast and Effective Method for
//! Euclidean Anticlustering: The Assignment-Based-Anticlustering
//! Algorithm"* (Baumann, Goldschmidt, Hochbaum, Yang, 2026) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: sorting/batching, LAPJV
//!   assignment, centroid state, hierarchical decomposition, categorical
//!   balancing, the mini-batch streaming pipeline, every baseline from the
//!   paper's evaluation, and the experiment harness that regenerates each
//!   table and figure.
//! * **L2 (`python/compile/model.py`)** — JAX compute graphs, AOT-lowered
//!   to HLO text at build time (`make artifacts`).
//! * **L1 (`python/compile/kernels/`)** — the Pallas cost-matrix kernel the
//!   L2 graphs call.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT (`xla`
//! crate, behind the `xla` feature); Python never runs on the request
//! path.
//!
//! ## Quick start
//!
//! Build a reusable [`Aba`] session with the builder, then call
//! [`Anticlusterer::partition`]; the result is a rich [`Partition`]
//! carrying labels, sizes, both paper objectives, per-cluster diversity
//! stats, and a phase-timing breakdown:
//!
//! ```no_run
//! use aba::{Aba, Anticlusterer};
//! use aba::data::synth::{generate, SynthKind};
//!
//! let ds = generate(SynthKind::GaussianMixture { components: 8, spread: 4.0 },
//!                   10_000, 16, 42, "demo");
//! let mut solver = Aba::builder().build()?;
//! let part = solver.partition(&ds, 50)?;
//! println!(
//!     "objective {:.1}, sizes {}..{}, {:.3}s ({:.3}s ordering + {:.3}s assignment)",
//!     part.objective,
//!     part.sizes().iter().min().unwrap(),
//!     part.sizes().iter().max().unwrap(),
//!     part.timings.total_secs,
//!     part.timings.order_secs,
//!     part.timings.assign_secs,
//! );
//! // The session owns its backend and scratch — reuse it for repeated
//! // partitioning (K-fold CV, per-epoch mini-batches, serving):
//! for k in [10, 25, 50] {
//!     let p = solver.partition(&ds, k)?;
//!     println!("k={k}: {:.1}", p.objective);
//! }
//! # Ok::<(), aba::AbaError>(())
//! ```
//!
//! Baselines implement the same [`Anticlusterer`] trait and are
//! interchangeable behind `Box<dyn Anticlusterer>` — see
//! [`baselines::RandomPartition`], [`baselines::FastAnticlustering`],
//! and [`baselines::ExactSolver`].
//!
//! Errors are typed ([`AbaError`]) throughout the library core; `anyhow`
//! survives only at the CLI / experiment-harness boundary. The old free
//! functions `algo::run_aba` / `algo::run_aba_constrained` remain as
//! deprecated shims for one release.

pub mod algo;
pub mod assignment;
pub mod baselines;
pub mod data;
pub mod error;
pub mod experiments;
pub mod graph;
pub mod knn;
pub mod metrics;
pub mod pipeline;
pub mod rng;
pub mod runtime;
pub mod solver;
pub mod testing;
pub mod util;

pub use error::{AbaError, AbaResult};
pub use solver::{Aba, AbaBuilder, Anticlusterer, Partition, PhaseTimings};

/// CLI-boundary result type (anyhow-backed). Library-core functions
/// return [`AbaResult`] instead.
pub type Result<T> = anyhow::Result<T>;
