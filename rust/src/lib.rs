//! # aba — Assignment-Based Anticlustering
//!
//! A production-grade reproduction of *"A Fast and Effective Method for
//! Euclidean Anticlustering: The Assignment-Based-Anticlustering
//! Algorithm"* (Baumann, Goldschmidt, Hochbaum, Yang, 2026) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: sorting/batching, LAPJV
//!   assignment, centroid state, hierarchical decomposition, categorical
//!   balancing, the mini-batch streaming pipeline, every baseline from the
//!   paper's evaluation, and the experiment harness that regenerates each
//!   table and figure.
//! * **L2 (`python/compile/model.py`)** — JAX compute graphs, AOT-lowered
//!   to HLO text at build time (`make artifacts`).
//! * **L1 (`python/compile/kernels/`)** — the Pallas cost-matrix kernel the
//!   L2 graphs call.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT (`xla`
//! crate, behind the `xla` feature); Python never runs on the request
//! path.
//!
//! ## Module map (code ↔ paper)
//!
//! | Module | Paper | What lives there |
//! |---|---|---|
//! | [`algo::batching`] | §4.1–§4.3, Figs. 1–3 | The `N↓` sorted order and the small-anticluster / categorical rearrangements that define the batches |
//! | [`algo::core`] | §4, Algorithm 1 | The assignment loop: per-batch cost matrix → max-cost solve → incremental centroid updates, with categorical cost masking |
//! | [`assignment`] | §4.2 | The per-batch solvers: LAPJV (default), auction, greedy, and the brute-force oracle the property tests compare against |
//! | [`assignment::sparse`] | §4.5 (scale), §6 | The candidate-pruned large-K path: CSR cost structures, a CSR-aware LAPJV, and a sparse auction, generic over a cost-access trait |
//! | [`knn::farthest`] | §4.5 (scale) | Bounding-box kd-tree answering top-`C` *farthest*-centroid queries — the per-batch candidate index |
//! | [`algo::constraints`] | §4.3 (extension) | Must-link / cannot-link via super-object contraction and cost masking |
//! | [`algo::hierarchical`] | §4.4, Lemma 1, Prop. 1 | Multi-level decomposition for large K, fanned out on the worker pool |
//! | [`algo::objective`] | §3, Fact 1 | Both paper objectives, the per-cluster diversity stats, and the O(d) [`algo::objective::ClusterDelta`] add/remove deltas behind the online handles |
//! | [`cert`] | §3 (objective), §7 (quality) | Quality certificates: scalable diversity upper bounds / optimality gaps, and the exact polynomial K=2 dispersion solver used as solver fast path and test oracle |
//! | [`pareto`] | §3 (bicriterion) | Multi-restart bicriterion interchange engine (MBPI-style) producing deterministic diversity/dispersion Pareto fronts over ABA seeds |
//! | [`online`] | §1, §6 (serving) | Live [`OnlinePartition`] handles: delta-maintained insert/remove/refine with balance repair, plus fingerprinted save/load persistence |
//! | [`serve`] | §6 (serving) | The `aba serve` HTTP service: a bounded accept/worker server managing concurrent [`OnlinePartition`] handles behind an LRU registry, with shard-and-merge solves and text metrics |
//! | [`runtime`] | §5 (implementation) | Cost backends (native / Pallas-XLA via PJRT), the [`runtime::pool`] parallel runtime, and the [`runtime::simd`] runtime-dispatched distance kernels |
//! | [`baselines`] | §5 (competitors) | `Rand`, the exchange heuristic, branch-and-bound |
//! | [`data`] | §5, Table 2 | Dataset catalog, synthetic generators, k-means/k-plus seeding |
//! | [`data::view`] | §4.4 (scale) | Zero-copy [`data::DataView`]s — the borrowed (matrix, index, categories) currency every consumer layer reads; what lets hierarchical levels descend without per-level matrix copies |
//! | [`experiments`] | §5, Tables 4–11, Figs. 5–7 | The harness that regenerates each table and figure |
//! | [`pipeline`] | §6 (application) | Streaming anticlustered mini-batches into an SGD consumer |
//! | [`graph`], [`knn`] | §6 (application) | Balanced K-cut partitioning on kNN graphs |
//!
//! ## Quick start
//!
//! Build a reusable [`Aba`] session with the builder, then call
//! [`Anticlusterer::partition`]; the result is a rich [`Partition`]
//! carrying labels, sizes, both paper objectives, per-cluster diversity
//! stats, and a phase-timing breakdown:
//!
//! ```
//! use aba::{Aba, Anticlusterer};
//! use aba::data::synth::{generate, SynthKind};
//!
//! let ds = generate(SynthKind::GaussianMixture { components: 4, spread: 4.0 },
//!                   120, 4, 42, "demo");
//! let mut solver = Aba::builder().build()?;
//! let part = solver.partition(&ds, 6)?;
//! assert_eq!(part.labels.len(), 120);
//! assert!(part.sizes().iter().all(|&s| s == 20)); // balanced anticlusters
//! assert!(part.objective > 0.0 && part.timings.total_secs >= 0.0);
//! // The session owns its backend, scratch, and worker pool — reuse it
//! // for repeated partitioning (K-fold CV, per-epoch mini-batches,
//! // serving) instead of paying construction and warm-up every call:
//! for k in [4, 10, 12] {
//!     let p = solver.partition(&ds, k)?;
//!     assert_eq!(p.k, k);
//! }
//! # Ok::<(), aba::AbaError>(())
//! ```
//!
//! ## Zero-copy data views
//!
//! Every consumer layer reads data through a borrowed
//! [`data::DataView`]: constructing one from a [`data::Dataset`] is
//! free, and selecting any index subset borrows the indices instead of
//! gathering feature rows. [`Anticlusterer::partition_view`] partitions
//! a subset — and the hierarchical driver splits its groups level by
//! level — without copying the feature matrix once; the only copies
//! left are the assignment loop's bounded per-batch stagings (metered
//! by [`data::view::gathered_bytes`]):
//!
//! ```
//! use aba::{Aba, Anticlusterer};
//! use aba::data::synth::{generate, SynthKind};
//!
//! let ds = generate(SynthKind::Uniform, 400, 8, 3, "views");
//! // Hierarchically partition only the even rows — zero-copy: the view
//! // borrows the matrix and the 2x5 decomposition descends through
//! // index selections, never materializing a sub-dataset.
//! let even: Vec<usize> = (0..ds.n).step_by(2).collect();
//! let view = ds.view().select(&even);
//! let part = Aba::builder().hier(vec![2, 5]).build()?.partition_view(&view, 10)?;
//! assert_eq!(part.labels.len(), 200);
//! assert!(part.sizes().iter().all(|&s| s == 20));
//! # Ok::<(), aba::AbaError>(())
//! ```
//!
//! ## Sparse candidate-pruned assignment (large K)
//!
//! The dense per-batch solve costs `O(k²d)` to build the cost matrix
//! and `O(k³)` to solve it — unrepresentable at the paper's
//! "hundreds of thousands of anticlusters" scale (`k = 100_000` means
//! a ~40 GB matrix per batch). The [`assignment::CandidateMode`] knob
//! (`Aba::builder().candidates(..)`, CLI `--candidates auto|<C>|dense`)
//! switches large-K batches to a sparse path: a per-batch
//! farthest-point index over the centroids ([`knn::farthest`]) gives
//! each object its top-`C` highest-cost candidate anticlusters, a CSR
//! structure is assembled in the session scratch, and a CSR-aware
//! LAPJV ([`assignment::sparse`]) solves it — `O(k·C·(d + log k))`
//! per batch, with automatic feasibility repair (escalate `C`, then
//! dense fallback) when the pruned graph admits no perfect matching.
//! `Auto` (the default) stays dense below `k = 512`; `C >= k` is
//! bit-identical to `Dense` (property-tested):
//!
//! ```
//! use aba::{Aba, Anticlusterer};
//! use aba::assignment::CandidateMode;
//! use aba::data::synth::{generate, SynthKind};
//!
//! let ds = generate(SynthKind::Uniform, 64, 4, 11, "sparse");
//! let mut solver = Aba::builder()
//!     .auto_hier(false)
//!     .candidates(CandidateMode::Fixed(4)) // top-4 candidates per object
//!     .build()?;
//! let part = solver.partition(&ds, 8)?;
//! assert!(part.sizes().iter().all(|&s| s == 8));
//! // Every solved batch went through the candidate machinery: either
//! // sparsely, or via the dense fallback of feasibility repair.
//! let stats = solver.sparse_stats();
//! assert_eq!(stats.sparse_batches + stats.dense_batches, 7);
//! # Ok::<(), aba::AbaError>(())
//! ```
//!
//! ## Online partitions: serving under churn
//!
//! Batch calls freeze their result; long-lived workloads (serving
//! representative folds or mini-batches while users arrive and expire)
//! instead hold a live [`OnlinePartition`] from
//! [`Aba::partition_online`]. Inserts solve small max-gain rectangular
//! assignments against capacity targets (reusing the dense and sparse
//! per-batch solvers), removals repair the balance invariant, `refine`
//! runs bounded exchange passes scoped to touched clusters, and
//! `objective()`/`sizes()` read delta-maintained state instead of
//! recomputing `O(n·d)` — exactly equal to a from-scratch recompute
//! (property-tested). Versioned, fingerprinted snapshots let a serving
//! process warm-restart:
//!
//! ```
//! use aba::{Aba, OnlinePartition};
//! use aba::data::synth::{generate, SynthKind};
//!
//! let ds = generate(SynthKind::Uniform, 120, 4, 5, "live");
//! let mut session = Aba::builder().auto_hier(false).build()?;
//! let mut live = session.partition_online(&ds.view(), 6)?;
//!
//! // New rows arrive; stale rows expire; a bounded polish follows.
//! let arrivals = generate(SynthKind::Uniform, 12, 4, 6, "arrivals");
//! let ids = live.insert_batch(&arrivals.view())?;
//! assert_eq!(ids.len(), 12);
//! live.remove(&ids[..6])?;
//! live.refine(10_000);
//! let sizes = live.sizes();
//! assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
//! assert_eq!(live.objective(), live.recompute_objective());
//!
//! // Persist, then warm-restart under a compatible session.
//! let path = std::env::temp_dir().join("aba_doc_online.json");
//! live.save(&path)?;
//! let mut back = OnlinePartition::load(&path, session.config())?;
//! assert_eq!(back.objective(), live.objective());
//! # std::fs::remove_file(&path).ok();
//! # Ok::<(), aba::AbaError>(())
//! ```
//!
//! ## Quality certificates
//!
//! ABA is a heuristic for an NP-hard problem, so every result carries
//! evidence of how good it is. The total-sum identity
//! `TSS = WGSS + BGSS` makes the total sum of squares an upper bound
//! on any balanced partition's diversity (see [`cert::bounds`] for the
//! MSSC-relaxation framing), so each [`Partition`] reports
//! [`Partition::upper_bound`] and a relative [`Partition::gap`] in
//! `[0, 1]` for free — and live [`OnlinePartition`] handles maintain
//! the same gap lazily off their per-cluster delta stats. Building a
//! session with `.certify(true)` additionally times a standalone
//! solver-independent [`cert::Certificate`] (one chunked O(nd) pass,
//! pool-parallel, deterministic), which `aba run --certify` prints and
//! the `certify` bench section records. For the *dispersion* objective
//! at `k == 2`, [`cert::two_color`] is exact — available as a solver
//! fast path via `.criterion(Criterion::Dispersion)` and as the test
//! suite's ground-truth oracle ([`testing::oracle`]):
//!
//! ```
//! use aba::{Aba, Anticlusterer};
//! use aba::algo::Criterion;
//! use aba::data::synth::{generate, SynthKind};
//!
//! let ds = generate(SynthKind::Uniform, 300, 6, 9, "certified");
//! // --certify on the CLI does exactly this:
//! let mut solver = Aba::builder().certify(true).build()?;
//! let part = solver.partition(&ds, 10)?;
//! assert!(part.upper_bound() >= part.objective);
//! assert!((0.0..=1.0).contains(&part.gap()));
//! let cert = solver.last_certificate().expect("certify(true) attaches one");
//! assert!(cert.upper_bound >= part.objective);
//! assert!(cert.gap(part.objective) < 0.25); // ABA lands close to the bound
//!
//! // Exact K=2 dispersion through the same session API.
//! let mut exact = Aba::builder().criterion(Criterion::Dispersion).build()?;
//! let two = exact.partition(&ds, 2)?;
//! assert_eq!(two.sizes(), &[150, 150]);
//! # Ok::<(), aba::AbaError>(())
//! ```
//!
//! ## Bicriterion Pareto search
//!
//! A single ABA solve maximizes diversity alone; the [`pareto`]
//! subsystem makes the diversity/dispersion trade-off explicit.
//! [`Aba::pareto_front`] runs a multi-restart bicriterion interchange
//! engine — restarts seeded from the session's own ABA solution,
//! `fast_anticlustering`, and random partitions under weight-sampled
//! scalarizations — and returns a non-dominated front of partitions,
//! each carrying both criteria plus the same diversity certificate
//! (upper bound, gap) a [`Partition`] reports. Restarts fan out on the
//! session worker pool under per-restart [`rng::Pcg32::stream`] seed
//! streams, so Serial and Threads(n) fronts are **bit-identical**
//! (property-tested). `aba pareto` on the CLI does exactly this:
//!
//! ```
//! use aba::pareto::ParetoConfig;
//! use aba::data::synth::{generate, SynthKind};
//! use aba::Aba;
//!
//! let ds = generate(SynthKind::GaussianMixture { components: 4, spread: 4.0 },
//!                   120, 4, 42, "front");
//! let cfg = ParetoConfig { restarts: 6, seed: 7, ..Default::default() };
//! let mut session = Aba::builder().pareto(cfg).build()?;
//! let front = session.pareto_front(&ds.view(), 6)?;
//! // Points arrive diversity-descending / dispersion-ascending; the
//! // extremes weakly dominate the single-ABA solution's pair.
//! assert!(!front.points.is_empty());
//! for pair in front.points.windows(2) {
//!     assert!(pair[0].diversity > pair[1].diversity);
//!     assert!(pair[0].dispersion < pair[1].dispersion);
//! }
//! let best = front.best_diversity().unwrap();
//! assert!(best.upper_bound >= best.diversity && (0.0..=1.0).contains(&best.gap));
//! // One number for "how much front is there": hypervolume vs a
//! // reference point at the origin.
//! assert!(front.hypervolume((0.0, 0.0)) > 0.0);
//! # Ok::<(), aba::AbaError>(())
//! ```
//!
//! Balanced partitions with `n < 2k` would force singleton anticlusters
//! (undefined, infinite dispersion) — refused up front with a typed
//! [`AbaError::InvalidK`] instead of leaking `inf` into front output.
//!
//! ## Serving
//!
//! The [`serve`] module wraps the online handles in a dependency-light
//! HTTP/1.1 service (`aba serve` on the CLI, [`serve::Server`] embedded):
//! a bounded accept/worker model on [`std::net::TcpListener`], one
//! solver session per worker, and an LRU handle registry that evicts
//! cold partitions to fingerprinted snapshots and warm-restarts them on
//! demand — bit-identically, and with HTTP 409 when the snapshot was
//! written under an incompatible config. `POST /v1/partitions` solves
//! inline CSV (optionally via [`serve::shard::solve_sharded`]:
//! `S` independent shard solves reconciled by centroid-level Ward
//! assignment, near-linear speedup for a few percent of objective);
//! `insert` / `remove` / `refine` hit the delta-maintained handle ops;
//! `GET /metrics` exposes request counts, latency percentiles, queue
//! depth, evictions, and the library's own staging/sparse meters. When
//! the bounded queue fills, new connections get `429 Retry-After`
//! instead of unbounded latency; `SIGTERM` (or
//! `POST /v1/admin/drain`) stops accepting, finishes queued requests,
//! and snapshots every resident handle. See the README's "Serving over
//! HTTP" section for a curl quickstart.
//!
//! ## SIMD distance kernels
//!
//! Every squared-Euclidean distance flows through one runtime-dispatched
//! table ([`runtime::Kernels`]), selected once at session construction:
//! AVX2 on x86-64, NEON on aarch64, a scalar fallback everywhere — and
//! the vector paths keep the scalar kernel's exact reduction order, so
//! `auto` and `scalar` produce **bit-identical** partitions on every
//! host (property-tested across the flat, hierarchical, sparse, and
//! online paths). `fma` opts into fused-multiply-add contraction
//! (faster, ULP-bounded rather than bit-identical). `fast-math` opts
//! into the **relaxed-determinism** tier for the large-K regime:
//! cache-blocked, register-blocked FMA cost micro-kernels — AVX-512F
//! where the hardware and toolchain (rustc ≥ 1.89) allow, else
//! AVX2+FMA, degrading cleanly to `auto` — with free reduction order.
//! Under `fast-math`, partitions stay valid and balanced and the k-d
//! pruning bound still dominates the true distance, but labels may
//! differ from scalar at near-ties; the objective gap is property-
//! tested and bench-tracked *in ppm*, never bit-identity-gated, and
//! `auto`/`scalar`/`fma` determinism is unchanged. Select per session
//! with the builder, per run with `--kernels auto|scalar|fma|fast-math`,
//! or process-wide with the `ABA_KERNELS` env var; the selection is
//! reported in [`PhaseTimings::kernel_isa`], the CLI `cpu` line, and
//! serve's `aba_kernel_isa` metric:
//!
//! ```
//! use aba::{Aba, Anticlusterer};
//! use aba::runtime::KernelMode;
//! use aba::data::synth::{generate, SynthKind};
//!
//! let ds = generate(SynthKind::Uniform, 160, 8, 13, "simd");
//! // `--kernels scalar` on the CLI does exactly this:
//! let mut forced = Aba::builder().kernels(KernelMode::Scalar).build()?;
//! let a = forced.partition(&ds, 8)?;
//! assert_eq!(a.timings.kernel_isa, "scalar");
//! // The default (auto) dispatch may pick a vector ISA, but the result
//! // cannot move a bit.
//! let b = Aba::builder().build()?.partition(&ds, 8)?;
//! assert!(!b.timings.kernel_isa.is_empty());
//! assert_eq!(a.labels, b.labels);
//! assert_eq!(a.objective.to_bits(), b.objective.to_bits());
//! // `--kernels fast-math` on the CLI does exactly this: the relaxed
//! // tier still yields a valid balanced partition (its objective is
//! // ppm-close to scalar, but *not* asserted bit-identical).
//! let mut fast = Aba::builder().kernels(KernelMode::FastMath).build()?;
//! let c = fast.partition(&ds, 8)?;
//! assert!(!c.timings.kernel_isa.is_empty());
//! assert_eq!(c.labels.len(), 160);
//! # Ok::<(), aba::AbaError>(())
//! ```
//!
//! ## Parallel execution
//!
//! Parallelism is a session knob ([`runtime::Parallelism`]): `Serial`
//! (default), `Threads(n)`, or `Auto` (all cores). One worker pool per
//! session chunk-parallelizes cost matrices, double-buffers batch
//! staging, and fans hierarchical subproblems out — and with the native
//! backend every setting produces **bit-identical labels**
//! (property-tested), so it is purely a wall-clock knob (XLA caveat:
//! see [`algo::hierarchical`]):
//!
//! ```
//! use aba::{Aba, Anticlusterer};
//! use aba::runtime::Parallelism;
//! use aba::data::synth::{generate, SynthKind};
//!
//! let ds = generate(SynthKind::Uniform, 240, 8, 7, "par");
//! let mut serial = Aba::builder().parallelism(Parallelism::Serial).build()?;
//! let mut threaded = Aba::builder().parallelism(Parallelism::Threads(2)).build()?;
//! assert_eq!(serial.partition(&ds, 8)?.labels, threaded.partition(&ds, 8)?.labels);
//! # Ok::<(), aba::AbaError>(())
//! ```
//!
//! Baselines implement the same [`Anticlusterer`] trait and are
//! interchangeable behind `Box<dyn Anticlusterer>` — see
//! [`baselines::RandomPartition`], [`baselines::FastAnticlustering`],
//! and [`baselines::ExactSolver`].
//!
//! Errors are typed ([`AbaError`]) throughout the library core,
//! including the data layer ([`AbaError::BadShape`],
//! [`AbaError::ParseError`], [`AbaError::Io`]); `anyhow` survives only
//! at the CLI / experiment-harness boundary. The free functions
//! `algo::run_aba` / `algo::run_aba_constrained` are deprecated shims,
//! deleted in 0.3.0 — see their docs for the migration path.

pub mod algo;
pub mod assignment;
pub mod baselines;
pub mod cert;
pub mod data;
pub mod error;
pub mod experiments;
pub mod graph;
pub mod knn;
pub mod metrics;
pub mod online;
pub mod pareto;
pub mod pipeline;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod testing;
pub mod util;

pub use error::{AbaError, AbaResult};
pub use online::OnlinePartition;
pub use solver::{Aba, AbaBuilder, Anticlusterer, Partition, PhaseTimings};

/// CLI-boundary result type (anyhow-backed). Library-core functions
/// return [`AbaResult`] instead.
pub type Result<T> = anyhow::Result<T>;
