//! The paper's benchmark algorithms, re-implemented in Rust.
//!
//! * [`random_part`] — Rand: random balanced partitioning (with and
//!   without a categorical feature).
//! * [`exchange`] — the `fast_anticlustering` exchange heuristic of
//!   Papenberg & Klau (2021): P-N5 / P-R5 / P-R50 / P-R500 configs.
//! * [`exact`] — branch-and-bound exact anticlustering for small N; its
//!   time-capped mode stands in for the AVOC MILP of Croella et al.
//!   (2025) in the Table 9/10 experiments (see DESIGN.md §3).
//!
//! Each baseline also ships a session adapter implementing
//! [`crate::solver::Anticlusterer`] — [`RandomPartition`],
//! [`FastAnticlustering`], and [`ExactSolver`] — so any of them can be
//! swapped for ABA behind `Box<dyn Anticlusterer>` in the pipeline, the
//! CLI, and the experiment harness.

pub mod exact;
pub mod exchange;
pub mod random_part;

pub use exact::ExactSolver;
pub use exchange::FastAnticlustering;
pub use random_part::RandomPartition;
