//! Random balanced partitioning — the `Rand` baseline.
//!
//! Shuffle and deal round-robin: sizes differ by at most one. The
//! categorical variant deals each category independently (with a rotating
//! starting cluster so the `N mod K` remainders spread out), satisfying
//! the §2 constraint (5) bounds.

use crate::data::DataView;
use crate::error::AbaResult;
use crate::rng::Pcg32;
use crate::solver::{Anticlusterer, Partition, PhaseTimings};
use std::time::Instant;

/// The `Rand` baseline as a reusable [`Anticlusterer`] session.
/// Category-aware: when the data carries a categorical feature, each
/// category is dealt independently (constraint (5)).
pub struct RandomPartition {
    pub seed: u64,
}

impl RandomPartition {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }
}

impl Anticlusterer for RandomPartition {
    fn partition_view(&mut self, view: &DataView<'_>, k: usize) -> AbaResult<Partition> {
        crate::algo::validate(view.n(), k, false)?;
        let mut timings = PhaseTimings::default();
        let t = Instant::now();
        let labels = match view.categories() {
            Some(cats) => random_partition_categorical(&cats, k, self.seed),
            None => random_partition(view.n(), k, self.seed),
        };
        timings.assign_secs = t.elapsed().as_secs_f64();
        Ok(Partition::from_labels(view, labels, k, timings))
    }

    fn name(&self) -> String {
        "Rand".into()
    }
}

/// Random balanced partition of `n` objects into `k` groups.
pub fn random_partition(n: usize, k: usize, seed: u64) -> Vec<u32> {
    assert!((1..=n).contains(&k));
    let mut rng = Pcg32::new(seed);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let mut labels = vec![0u32; n];
    for (pos, &obj) in idx.iter().enumerate() {
        labels[obj] = (pos % k) as u32;
    }
    labels
}

/// Random partition with a categorical feature: each category's objects
/// are dealt round-robin so every anticluster receives
/// `floor(|N_g|/K)..=ceil(|N_g|/K)` objects of category g.
pub fn random_partition_categorical(categories: &[u32], k: usize, seed: u64) -> Vec<u32> {
    let n = categories.len();
    assert!((1..=n).contains(&k));
    let g = categories.iter().copied().max().map_or(0, |m| m as usize + 1);
    let mut rng = Pcg32::new(seed);
    let mut labels = vec![0u32; n];
    let mut start = 0usize;
    for cat in 0..g as u32 {
        let mut members: Vec<usize> =
            (0..n).filter(|&i| categories[i] == cat).collect();
        rng.shuffle(&mut members);
        for (pos, &obj) in members.iter().enumerate() {
            labels[obj] = ((start + pos) % k) as u32;
        }
        start = (start + members.len()) % k;
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_sizes() {
        for &(n, k) in &[(10usize, 3usize), (100, 7), (5, 5), (9, 2)] {
            let labels = random_partition(n, k, 1);
            let mut counts = vec![0usize; k];
            for &l in &labels {
                counts[l as usize] += 1;
            }
            let (min, max) = (
                *counts.iter().min().unwrap(),
                *counts.iter().max().unwrap(),
            );
            assert!(max - min <= 1, "n={n} k={k} {counts:?}");
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(random_partition(50, 5, 1), random_partition(50, 5, 2));
        assert_eq!(random_partition(50, 5, 3), random_partition(50, 5, 3));
    }

    #[test]
    fn adapter_matches_free_function_and_respects_categories() {
        use crate::data::synth::{generate, SynthKind};
        let ds = generate(SynthKind::Uniform, 40, 2, 7, "r");
        let part = RandomPartition::new(9).partition(&ds, 4).unwrap();
        assert_eq!(part.labels, random_partition(40, 4, 9));
        assert_eq!(part.sizes().iter().sum::<usize>(), 40);

        let cats: Vec<u32> = (0..40).map(|i| (i % 2) as u32).collect();
        let cds = ds.with_categories(cats.clone()).unwrap();
        let part = RandomPartition::new(9).partition(&cds, 4).unwrap();
        assert_eq!(part.labels, random_partition_categorical(&cats, 4, 9));
    }

    #[test]
    fn categorical_respects_per_category_bounds() {
        let cats: Vec<u32> = (0..47).map(|i| (i % 3) as u32).collect();
        let k = 4;
        let labels = random_partition_categorical(&cats, k, 7);
        for g in 0..3u32 {
            let total = cats.iter().filter(|&&c| c == g).count();
            let (lo, hi) = (total / k, total.div_ceil(k));
            for cl in 0..k as u32 {
                let cnt = (0..cats.len())
                    .filter(|&i| cats[i] == g && labels[i] == cl)
                    .count();
                assert!((lo..=hi).contains(&cnt), "g={g} cl={cl} cnt={cnt}");
            }
        }
        // Overall sizes also within one (since categories deal evenly and
        // starts rotate).
        let mut counts = vec![0usize; k];
        for &l in &labels {
            counts[l as usize] += 1;
        }
        let (min, max) = (*counts.iter().min().unwrap(), *counts.iter().max().unwrap());
        assert!(max - min <= 3, "{counts:?}"); // loose: rotation keeps it small
    }
}
