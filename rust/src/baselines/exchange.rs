//! `fast_anticlustering` — the exchange-based heuristic of Papenberg &
//! Klau (2021), the paper's main benchmark.
//!
//! Faithful re-implementation of the R/C `anticlust::fast_anticlustering`
//! behaviour:
//! * start from a random equal-size partition (category-aware when a
//!   categorical feature is present),
//! * for each object in turn, evaluate swapping it with each of its
//!   *exchange partners* (its `p` nearest neighbors — P-N5 — or `p`
//!   random objects — P-R5/R50/R500; partners are restricted to the same
//!   category in categorical mode),
//! * apply the swap with the largest positive improvement of the
//!   centroid-form objective; one full pass over all objects.
//!
//! The O(D) swap evaluation uses the same centroid decomposition as the
//! paper: maintaining per-cluster feature sums `S_k` and squared-norm
//! sums `SS_k`, the cluster SSD is `SS_k - ||S_k||^2 / m_k`, so a swap
//! only touches two clusters.

use super::random_part;
use crate::data::DataView;
use crate::error::{AbaError, AbaResult};
use crate::knn;
use crate::rng::Pcg32;
use crate::solver::{Anticlusterer, Partition, PhaseTimings};
use std::time::Instant;

/// How exchange partners are generated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partners {
    /// `p` nearest neighbors (the paper's P-N5 with p = 5).
    Nearest(usize),
    /// `p` uniformly random partners (P-R5 / P-R50 / P-R500).
    Random(usize),
}

/// Configuration for a fast_anticlustering run.
#[derive(Clone, Debug)]
pub struct ExchangeConfig {
    pub partners: Partners,
    pub seed: u64,
    /// Abort (returning the current labels) once this much wall time has
    /// elapsed; mirrors the paper's two-hour cap, scaled down.
    pub time_limit: Option<std::time::Duration>,
}

impl ExchangeConfig {
    pub fn nearest(p: usize, seed: u64) -> Self {
        Self { partners: Partners::Nearest(p), seed, time_limit: None }
    }
    pub fn random(p: usize, seed: u64) -> Self {
        Self { partners: Partners::Random(p), seed, time_limit: None }
    }
}

/// Result of a run.
#[derive(Clone, Debug)]
pub struct ExchangeResult {
    pub labels: Vec<u32>,
    /// Swaps applied.
    pub swaps: usize,
    /// True if the run hit the time limit before completing its pass.
    pub timed_out: bool,
}

/// `fast_anticlustering` as a reusable [`Anticlusterer`] session.
///
/// A run that hits its configured `time_limit` before completing the
/// exchange pass fails with [`AbaError::TimeLimit`] — the paper's "—"
/// (no solution within the cap) convention, which the experiment harness
/// relies on.
pub struct FastAnticlustering {
    cfg: ExchangeConfig,
}

impl FastAnticlustering {
    pub fn new(cfg: ExchangeConfig) -> Self {
        Self { cfg }
    }

    /// P-N`p`: `p` nearest-neighbor exchange partners.
    pub fn nearest(p: usize, seed: u64) -> Self {
        Self::new(ExchangeConfig::nearest(p, seed))
    }

    /// P-R`p`: `p` random exchange partners.
    pub fn random(p: usize, seed: u64) -> Self {
        Self::new(ExchangeConfig::random(p, seed))
    }

    pub fn config(&self) -> &ExchangeConfig {
        &self.cfg
    }
}

impl Anticlusterer for FastAnticlustering {
    fn partition_view(&mut self, view: &DataView<'_>, k: usize) -> AbaResult<Partition> {
        crate::algo::validate(view.n(), k, false)?;
        let mut timings = PhaseTimings::default();
        let t = Instant::now();
        let res = fast_anticlustering(view, k, &self.cfg);
        timings.assign_secs = t.elapsed().as_secs_f64();
        if res.timed_out {
            let limit_secs = self.cfg.time_limit.map(|d| d.as_secs_f64()).unwrap_or(0.0);
            return Err(AbaError::TimeLimit { limit_secs });
        }
        Ok(Partition::from_labels(view, res.labels, k, timings))
    }

    fn name(&self) -> String {
        match self.cfg.partners {
            Partners::Nearest(p) => format!("P-N{p}"),
            Partners::Random(p) => format!("P-R{p}"),
        }
    }
}

/// Seed-stream layout shared by every restart-style consumer of a
/// single `u64` seed (this baseline and [`crate::pareto`]'s engine):
/// stream 0 drives the initial partition, stream 1 drives partner /
/// neighbor draws. Derived with [`Pcg32::stream`], so the two streams
/// are independent of each other's draw counts.
const STREAM_INIT: u64 = 0;
const STREAM_PARTNERS: u64 = 1;

/// The balanced random starting partition for a given seed
/// (category-aware when the view carries categories). Exposed so tests
/// and other engines can reproduce the exact starting point of
/// [`fast_anticlustering`] without re-deriving the seeding scheme.
pub fn initial_partition<'a>(data: impl Into<DataView<'a>>, k: usize, seed: u64) -> Vec<u32> {
    let ds: DataView<'a> = data.into();
    let mut rng = Pcg32::stream(seed, STREAM_INIT);
    match ds.categories() {
        Some(cats) => random_part::random_partition_categorical(&cats, k, rng.next_u64()),
        None => random_part::random_partition(ds.n(), k, rng.next_u64()),
    }
}

/// Run the exchange heuristic. Accepts a `&Dataset` or a zero-copy
/// [`DataView`] subset.
pub fn fast_anticlustering<'a>(
    data: impl Into<DataView<'a>>,
    k: usize,
    cfg: &ExchangeConfig,
) -> ExchangeResult {
    let ds: DataView<'a> = data.into();
    let n = ds.n();
    let d = ds.d();
    assert!((1..=n).contains(&k));
    let start = Instant::now();
    let mut rng = Pcg32::stream(cfg.seed, STREAM_PARTNERS);

    // Initial random partition (category-aware when present). For
    // identity views `categories()` is a zero-copy borrow.
    let categories = ds.categories();
    let mut labels = initial_partition(&ds, k, cfg.seed);

    // Cluster state: S_k (feature sums), SS_k (sum of ||x||^2), m_k.
    let mut sums = vec![0f64; k * d];
    let mut sumsq = vec![0f64; k];
    let mut counts = vec![0usize; k];
    // Per-object squared norms, reused in the O(D) delta evaluation
    // (objective tier: f64 index-order accumulation, see `runtime::simd`).
    let norms: Vec<f64> = (0..n).map(|i| crate::runtime::simd::sumsq_f64(ds.row(i))).collect();
    for i in 0..n {
        let c = labels[i] as usize;
        counts[c] += 1;
        sumsq[c] += norms[i];
        crate::runtime::simd::add_assign_row(&mut sums[c * d..(c + 1) * d], ds.row(i));
    }
    // ssd_k = SS_k - ||S_k||^2 / m_k.
    let cluster_ssd = |sums: &[f64], sumsq: &[f64], counts: &[usize], c: usize| -> f64 {
        if counts[c] == 0 {
            return 0.0;
        }
        let s2: f64 = sums[c * d..(c + 1) * d].iter().map(|&v| v * v).sum();
        sumsq[c] - s2 / counts[c] as f64
    };

    // Exchange partner lists.
    let partner_count = match cfg.partners {
        Partners::Nearest(p) | Partners::Random(p) => p,
    };
    let partner_count = partner_count.min(n - 1);
    let partner_table: Option<Vec<usize>> = match cfg.partners {
        Partners::Nearest(_) => {
            // Nearest-neighbor search; in categorical mode anticlust
            // cannot use NN partners (the paper notes this), so callers
            // use Random there — but be safe and fall back to same-cat NN.
            Some(knn::knn_all(&ds, partner_count))
        }
        Partners::Random(_) => None,
    };

    let mut swaps = 0usize;
    let mut timed_out = false;
    // Scratch for candidate partner list.
    let mut candidates: Vec<usize> = Vec::with_capacity(partner_count);

    'outer: for i in 0..n {
        if let Some(limit) = cfg.time_limit {
            if start.elapsed() >= limit {
                timed_out = true;
                break 'outer;
            }
        }
        // Build the candidate list for object i.
        candidates.clear();
        match &partner_table {
            Some(table) => {
                candidates.extend_from_slice(&table[i * partner_count..(i + 1) * partner_count]);
            }
            None => {
                for _ in 0..partner_count {
                    let j = rng.gen_index(n);
                    if j != i {
                        candidates.push(j);
                    }
                }
            }
        }
        // In categorical mode a swap must stay within the category (it
        // would otherwise violate constraint (5)).
        if let Some(cats) = &categories {
            let ci = cats[i];
            candidates.retain(|&j| cats[j] == ci);
        }

        let a = labels[i] as usize;
        let base_a = cluster_ssd(&sums, &sumsq, &counts, a);
        let mut best: Option<(usize, f64)> = None;
        for &j in &candidates {
            let b = labels[j] as usize;
            if b == a {
                continue;
            }
            // Evaluate the swap i<->j in O(D): clusters a and b exchange
            // the two objects; counts unchanged.
            let base_b = cluster_ssd(&sums, &sumsq, &counts, b);
            let mut sa2 = 0f64;
            let mut sb2 = 0f64;
            let xi = ds.row(i);
            let xj = ds.row(j);
            for t in 0..d {
                let delta = (xj[t] - xi[t]) as f64;
                let na = sums[a * d + t] + delta;
                let nb = sums[b * d + t] - delta;
                sa2 += na * na;
                sb2 += nb * nb;
            }
            let new_a = sumsq[a] - norms[i] + norms[j] - sa2 / counts[a] as f64;
            let new_b = sumsq[b] - norms[j] + norms[i] - sb2 / counts[b] as f64;
            let gain = (new_a + new_b) - (base_a + base_b);
            if gain > 1e-9 && best.map_or(true, |(_, g)| gain > g) {
                best = Some((j, gain));
            }
        }
        if let Some((j, _)) = best {
            // Apply the swap: update sums, sumsq, labels.
            let b = labels[j] as usize;
            let xi = ds.row(i);
            let xj = ds.row(j);
            for t in 0..d {
                let delta = (xj[t] - xi[t]) as f64;
                sums[a * d + t] += delta;
                sums[b * d + t] -= delta;
            }
            sumsq[a] += norms[j] - norms[i];
            sumsq[b] += norms[i] - norms[j];
            labels.swap(i, j);
            swaps += 1;
        }
    }

    ExchangeResult { labels, swaps, timed_out }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::objective::ClusterStats;
    use crate::data::synth::{generate, SynthKind};

    #[test]
    fn improves_over_random_start() {
        let ds = generate(
            SynthKind::GaussianMixture { components: 4, spread: 5.0 },
            300,
            4,
            41,
            "g",
        );
        let k = 6;
        let seed = 5;
        // The exposed seeding helper reproduces the internal start.
        let init = initial_partition(&ds, k, seed);
        let init_obj = ClusterStats::compute(&ds, &init, k).ssd_total();
        let res = fast_anticlustering(&ds, k, &ExchangeConfig::random(20, seed));
        let obj = ClusterStats::compute(&ds, &res.labels, k).ssd_total();
        assert!(obj >= init_obj, "obj={obj} init={init_obj}");
        assert!(res.swaps > 0);
        assert!(!res.timed_out);
    }

    #[test]
    fn preserves_balanced_sizes() {
        let ds = generate(SynthKind::Uniform, 101, 3, 42, "u");
        let k = 7;
        let res = fast_anticlustering(&ds, k, &ExchangeConfig::random(10, 1));
        let stats = ClusterStats::compute(&ds, &res.labels, k);
        let (min, max) = (
            *stats.sizes.iter().min().unwrap(),
            *stats.sizes.iter().max().unwrap(),
        );
        assert!(max - min <= 1, "{:?}", stats.sizes);
    }

    #[test]
    fn nearest_variant_runs() {
        let ds = generate(SynthKind::Uniform, 200, 3, 43, "u");
        let res = fast_anticlustering(&ds, 5, &ExchangeConfig::nearest(5, 2));
        assert_eq!(res.labels.len(), 200);
    }

    #[test]
    fn categorical_swaps_stay_in_category() {
        let n = 90;
        let cats: Vec<u32> = (0..n).map(|i| (i % 3) as u32).collect();
        let ds = generate(SynthKind::Uniform, n, 3, 44, "u")
            .with_categories(cats.clone())
            .unwrap();
        let k = 3;
        let res = fast_anticlustering(&ds, k, &ExchangeConfig::random(15, 3));
        for g in 0..3u32 {
            let total = cats.iter().filter(|&&c| c == g).count();
            let (lo, hi) = (total / k, total.div_ceil(k));
            for cl in 0..k as u32 {
                let cnt = (0..n)
                    .filter(|&i| cats[i] == g && res.labels[i] == cl)
                    .count();
                assert!((lo..=hi).contains(&cnt));
            }
        }
    }

    #[test]
    fn adapter_maps_timeout_to_typed_error_and_reports_partner_names() {
        use crate::error::AbaError;
        let ds = generate(SynthKind::Uniform, 300, 3, 47, "u");
        let mut ok = FastAnticlustering::random(10, 1);
        let part = ok.partition(&ds, 5).unwrap();
        assert_eq!(part.labels.len(), 300);
        assert_eq!(ok.name(), "P-R10");
        assert_eq!(FastAnticlustering::nearest(5, 1).name(), "P-N5");

        let mut capped = FastAnticlustering::new(ExchangeConfig {
            partners: Partners::Random(50),
            seed: 1,
            time_limit: Some(std::time::Duration::ZERO),
        });
        assert!(matches!(
            capped.partition(&ds, 5),
            Err(AbaError::TimeLimit { .. })
        ));
    }

    #[test]
    fn time_limit_zero_aborts_immediately() {
        let ds = generate(SynthKind::Uniform, 500, 3, 45, "u");
        let cfg = ExchangeConfig {
            partners: Partners::Random(50),
            seed: 1,
            time_limit: Some(std::time::Duration::ZERO),
        };
        let res = fast_anticlustering(&ds, 5, &cfg);
        assert!(res.timed_out);
        assert_eq!(res.labels.len(), 500);
    }

    #[test]
    fn more_partners_no_worse_quality() {
        let ds = generate(
            SynthKind::GaussianMixture { components: 3, spread: 4.0 },
            240,
            4,
            46,
            "g",
        );
        let k = 8;
        let few = fast_anticlustering(&ds, k, &ExchangeConfig::random(2, 7));
        let many = fast_anticlustering(&ds, k, &ExchangeConfig::random(60, 7));
        let of = ClusterStats::compute(&ds, &few.labels, k).ssd_total();
        let om = ClusterStats::compute(&ds, &many.labels, k).ssd_total();
        // Not a strict guarantee per-seed, but with 30x partners it holds
        // comfortably on this seed; guards against sign errors in gains.
        assert!(om >= of * 0.999, "many={om} few={of}");
    }
}
