//! Exact anticlustering by branch-and-bound — small-N ground truth and
//! the time-capped MILP stand-in.
//!
//! Maximizes the pairwise objective `W(C)` under the equal-size bounds
//! (2). Objects are assigned in order; pruning via (a) cluster-symmetry
//! breaking (an object may open at most one new cluster), (b) capacity
//! bounds, and (c) an optimistic bound that fills all remaining
//! within-cluster pair slots with an upper bound on the pairwise
//! distance.
//!
//! The incremental gain of adding object `i` to cluster `c` uses the
//! centroid decomposition — `sum_{j in c} ||x_i - x_j||^2 =
//! m_c ||x_i||^2 + SS_c - 2 <x_i, S_c>` — so no pairwise matrix is ever
//! materialized and the solver scales to large N *per node* (the search
//! tree, of course, stays exponential).
//!
//! Exact for N ≲ 16; with `deadline` set it returns the incumbent when
//! time runs out (`optimal = false`) — the role the Gurobi-backed AVOC
//! MILP plays in the paper's Table 9 (slow, and worse than heuristics
//! under a time cap).

use crate::data::dataset::sq_dist_to_f64;
use crate::data::DataView;
use crate::error::AbaResult;
use crate::solver::{Anticlusterer, Partition, PhaseTimings};
use std::time::{Duration, Instant};

/// Result of an exact run.
#[derive(Clone, Debug)]
pub struct ExactResult {
    pub labels: Vec<u32>,
    /// Pairwise objective `W(C)` of `labels`.
    pub objective: f64,
    /// Whether the search completed (vs hit the deadline).
    pub optimal: bool,
    /// Search nodes explored.
    pub nodes: u64,
}

/// Branch-and-bound as a reusable [`Anticlusterer`] session. With a
/// `deadline` it plays the paper's time-capped AVOC-MILP role: it always
/// returns its incumbent (recorded as non-optimal), never a dash.
pub struct ExactSolver {
    pub deadline: Option<Duration>,
    /// Whether the last `partition` call proved optimality.
    pub last_optimal: bool,
}

impl ExactSolver {
    pub fn new(deadline: Option<Duration>) -> Self {
        Self { deadline, last_optimal: false }
    }
}

impl Anticlusterer for ExactSolver {
    fn partition_view(&mut self, view: &DataView<'_>, k: usize) -> AbaResult<Partition> {
        crate::algo::validate(view.n(), k, false)?;
        let mut timings = PhaseTimings::default();
        let t = Instant::now();
        let res = solve(view, k, self.deadline);
        timings.assign_secs = t.elapsed().as_secs_f64();
        self.last_optimal = res.optimal;
        Ok(Partition::from_labels(view, res.labels, k, timings))
    }

    fn name(&self) -> String {
        if self.deadline.is_some() {
            "MILP-like".into()
        } else {
            "exact".into()
        }
    }
}

/// Exact (or time-capped) max-diversity anticlustering. Accepts a
/// `&Dataset` or a zero-copy [`DataView`] subset.
pub fn solve<'a>(
    data: impl Into<DataView<'a>>,
    k: usize,
    deadline: Option<Duration>,
) -> ExactResult {
    let ds: DataView<'a> = data.into();
    let n = ds.n();
    let d = ds.d();
    assert!((1..=n).contains(&k));
    // Per-object squared norms.
    let norms: Vec<f64> = (0..n)
        .map(|i| ds.row(i).iter().map(|&v| (v as f64) * (v as f64)).sum())
        .collect();
    // Admissible pairwise-distance upper bound:
    // d(i,j) <= 2 d(i,mu) + 2 d(j,mu) <= 4 max_i d(i,mu)   (all squared).
    let mu = ds.global_centroid();
    let dmax = 4.0
        * (0..n)
            .map(|i| sq_dist_to_f64(ds.row(i), &mu.iter().map(|&v| v as f64).collect::<Vec<_>>()))
            .fold(0f64, f64::max);

    let cap_hi = n.div_ceil(k);
    let cap_low = n / k;
    let n_high = n - cap_low * k; // clusters allowed to hit cap_hi

    let mut st = Search {
        ds,
        norms,
        n,
        k,
        d,
        dmax,
        cap_hi,
        cap_low,
        n_high,
        labels: vec![0u32; n],
        sizes: vec![0usize; k],
        sums: vec![0f64; k * d],
        sumsq: vec![0f64; k],
        best: vec![0u32; n],
        best_obj: f64::NEG_INFINITY,
        nodes: 0,
        start: Instant::now(),
        deadline,
        timed_out: false,
    };
    st.recurse(0, 0.0, 0);
    let optimal = !st.timed_out;
    ExactResult { labels: st.best, objective: st.best_obj, optimal, nodes: st.nodes }
}

struct Search<'a> {
    ds: DataView<'a>,
    norms: Vec<f64>,
    n: usize,
    k: usize,
    d: usize,
    dmax: f64,
    cap_hi: usize,
    cap_low: usize,
    n_high: usize,
    labels: Vec<u32>,
    sizes: Vec<usize>,
    /// Per-cluster feature sums S_c (k x d).
    sums: Vec<f64>,
    /// Per-cluster sums of squared norms SS_c.
    sumsq: Vec<f64>,
    best: Vec<u32>,
    best_obj: f64,
    nodes: u64,
    start: Instant,
    deadline: Option<Duration>,
    timed_out: bool,
}

impl Search<'_> {
    fn recurse(&mut self, obj_idx: usize, acc: f64, used_clusters: usize) {
        self.nodes += 1;
        if self.timed_out {
            return;
        }
        if self.nodes % 4096 == 0 {
            if let Some(dl) = self.deadline {
                if self.start.elapsed() >= dl {
                    self.timed_out = true;
                    return;
                }
            }
        }
        if obj_idx == self.n {
            if acc > self.best_obj {
                self.best_obj = acc;
                self.best.copy_from_slice(&self.labels);
            }
            return;
        }
        // Optimistic bound: fill remaining capacity greedily; each new
        // within-cluster pair contributes at most dmax.
        let remaining = self.n - obj_idx;
        let mut slots = 0usize;
        let mut rem = remaining;
        let mut szs: Vec<usize> = self.sizes.clone();
        szs.sort_unstable_by(|a, b| b.cmp(a));
        for s in szs {
            if rem == 0 {
                break;
            }
            let add = self.cap_hi.saturating_sub(s).min(rem);
            if add == 0 {
                continue;
            }
            slots += s * add + add * (add - 1) / 2;
            rem -= add;
        }
        if acc + slots as f64 * self.dmax <= self.best_obj {
            return;
        }

        let xi = self.ds.row(obj_idx);
        // Candidate clusters: used ones plus at most one fresh (symmetry).
        let try_up_to = (used_clusters + 1).min(self.k);
        for c in 0..try_up_to {
            let sz = self.sizes[c];
            if sz >= self.cap_hi {
                continue;
            }
            // Only n_high clusters may exceed cap_low.
            if sz == self.cap_low {
                if self.cap_hi == self.cap_low {
                    continue;
                }
                let highs = self.sizes.iter().filter(|&&s| s > self.cap_low).count();
                if highs >= self.n_high {
                    continue;
                }
            }
            // Gain of adding obj to c (centroid decomposition, O(D)).
            let mut dot = 0f64;
            for (t, &v) in xi.iter().enumerate() {
                dot += v as f64 * self.sums[c * self.d + t];
            }
            let gain =
                sz as f64 * self.norms[obj_idx] + self.sumsq[c] - 2.0 * dot;

            // Apply.
            self.labels[obj_idx] = c as u32;
            self.sizes[c] += 1;
            self.sumsq[c] += self.norms[obj_idx];
            for (t, &v) in xi.iter().enumerate() {
                self.sums[c * self.d + t] += v as f64;
            }

            // Remaining-capacity feasibility.
            let highs = self.sizes.iter().filter(|&&s| s > self.cap_low).count();
            let high_left = self.n_high.saturating_sub(highs);
            let base: usize = self
                .sizes
                .iter()
                .map(|&s| self.cap_low.saturating_sub(s))
                .sum();
            if base + high_left >= remaining - 1 {
                self.recurse(obj_idx + 1, acc + gain, used_clusters.max(c + 1));
            }

            // Undo.
            self.sizes[c] -= 1;
            self.sumsq[c] -= self.norms[obj_idx];
            for (t, &v) in xi.iter().enumerate() {
                self.sums[c * self.d + t] -= v as f64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::objective::{pairwise_within_brute, ClusterStats};
    use crate::data::synth::{generate, SynthKind};
    use crate::data::Dataset;

    #[test]
    fn four_points_two_clusters_optimal() {
        // Line 0,1,10,11: optimum pairs {0,11},{1,10}: W = 121 + 81 = 202.
        let ds = Dataset::from_rows(
            "line",
            &[vec![0.0], vec![1.0], vec![10.0], vec![11.0]],
        )
        .unwrap();
        let res = solve(&ds, 2, None);
        assert!(res.optimal);
        assert!((res.objective - 202.0).abs() < 1e-9, "obj={}", res.objective);
        assert_ne!(res.labels[0], res.labels[1]);
        assert_ne!(res.labels[2], res.labels[3]);
    }

    #[test]
    fn objective_matches_brute_recount() {
        let ds = generate(SynthKind::Uniform, 9, 2, 51, "u");
        let res = solve(&ds, 3, None);
        assert!(res.optimal);
        let recount = pairwise_within_brute(&ds, &res.labels, 3);
        assert!((res.objective - recount).abs() < 1e-6 * recount.max(1.0));
    }

    #[test]
    fn respects_size_bounds_non_divisible() {
        let ds = generate(SynthKind::Uniform, 10, 2, 52, "u");
        let res = solve(&ds, 3, None);
        let stats = ClusterStats::compute(&ds, &res.labels, 3);
        let mut sizes = stats.sizes.clone();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![3, 3, 4]);
    }

    #[test]
    fn exact_at_least_as_good_as_aba() {
        let ds = generate(SynthKind::Uniform, 12, 3, 53, "u");
        let k = 3;
        let res = solve(&ds, k, None);
        let aba = crate::solver::Aba::new().unwrap().partition(&ds, k).unwrap().labels;
        let aba_obj = pairwise_within_brute(&ds, &aba, k);
        assert!(
            res.objective >= aba_obj - 1e-9,
            "exact={} aba={aba_obj}",
            res.objective
        );
        // And ABA should be close (within 15%) on tiny uniform data.
        assert!(aba_obj >= 0.85 * res.objective, "exact={} aba={aba_obj}", res.objective);
    }

    #[test]
    fn deadline_returns_incumbent_at_scale() {
        // N far beyond exact reach: must return a feasible incumbent fast.
        let ds = generate(SynthKind::Uniform, 500, 4, 54, "u");
        let res = solve(&ds, 5, Some(Duration::from_millis(50)));
        assert!(!res.optimal);
        assert_eq!(res.labels.len(), 500);
        let stats = ClusterStats::compute(&ds, &res.labels, 5);
        assert_eq!(stats.sizes.iter().sum::<usize>(), 500);
        assert!(res.objective > 0.0);
    }

    #[test]
    fn adapter_reports_optimality_and_consistent_objective() {
        let ds = generate(SynthKind::Uniform, 9, 2, 56, "u");
        let mut solver = ExactSolver::new(None);
        let part = solver.partition(&ds, 3).unwrap();
        assert!(solver.last_optimal);
        assert_eq!(solver.name(), "exact");
        // Partition.pairwise (Fact 1) must agree with the search's own
        // pairwise objective.
        let res = solve(&ds, 3, None);
        assert!((part.pairwise - res.objective).abs() < 1e-6 * res.objective.max(1.0));

        let mut capped = ExactSolver::new(Some(Duration::from_millis(5)));
        assert_eq!(capped.name(), "MILP-like");
        let big = generate(SynthKind::Uniform, 200, 3, 57, "u");
        let part = capped.partition(&big, 5).unwrap();
        assert_eq!(part.labels.len(), 200);
        assert!(!capped.last_optimal);
    }

    #[test]
    fn matches_exhaustive_on_random_tiny() {
        // Cross-check against a direct enumeration over all labelings.
        let ds = generate(SynthKind::Uniform, 6, 2, 55, "u");
        let k = 2;
        let res = solve(&ds, k, None);
        // Enumerate all 2^6 labelings with balanced sizes.
        let mut best = f64::NEG_INFINITY;
        for mask in 0u32..64 {
            if mask.count_ones() == 3 {
                let labels: Vec<u32> = (0..6).map(|i| (mask >> i) & 1).collect();
                best = best.max(pairwise_within_brute(&ds, &labels, k));
            }
        }
        assert!((res.objective - best).abs() < 1e-9, "bnb={} enum={best}", res.objective);
    }
}
