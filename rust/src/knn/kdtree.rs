//! kd-tree for exact kNN in low dimension.
//!
//! Median-split construction over index slices (no point copies), bounded
//! best-first descent with hypersphere/plane pruning for queries.

use crate::data::dataset::sq_dist;
use crate::data::DataView;

struct Node {
    /// Splitting dimension.
    dim: usize,
    /// Split value (coordinate of the median point).
    split: f32,
    /// Index into `points` of the median object.
    point: usize,
    left: Option<Box<Node>>,
    right: Option<Box<Node>>,
}

/// An immutable kd-tree over a view's rows (a `&Dataset` or any
/// zero-copy index subset).
pub struct KdTree<'a> {
    ds: DataView<'a>,
    root: Option<Box<Node>>,
}

impl<'a> KdTree<'a> {
    /// Build in O(n log² n) (median via sort per level).
    pub fn build(data: impl Into<DataView<'a>>) -> Self {
        let ds: DataView<'a> = data.into();
        let mut idx: Vec<usize> = (0..ds.n()).collect();
        let root = build_node(&ds, &mut idx, 0);
        Self { ds, root }
    }

    /// Indices of the `k` nearest rows to `query` (may include an
    /// identical point; callers filter self-matches).
    pub fn knn(&self, query: &[f32], k: usize) -> Vec<usize> {
        assert_eq!(query.len(), self.ds.d());
        let k = k.min(self.ds.n());
        // Max-heap by distance, capped at k, as a sorted vec (k is small).
        let mut best: Vec<(f64, usize)> = Vec::with_capacity(k + 1);
        search(&self.ds, self.root.as_deref(), query, k, &mut best);
        best.into_iter().map(|(_, i)| i).collect()
    }
}

fn build_node(ds: &DataView<'_>, idx: &mut [usize], depth: usize) -> Option<Box<Node>> {
    if idx.is_empty() {
        return None;
    }
    let dim = depth % ds.d();
    idx.sort_unstable_by(|&a, &b| ds.row(a)[dim].total_cmp(&ds.row(b)[dim]));
    let mid = idx.len() / 2;
    let point = idx[mid];
    let split = ds.row(point)[dim];
    let (left_idx, rest) = idx.split_at_mut(mid);
    let right_idx = &mut rest[1..];
    Some(Box::new(Node {
        dim,
        split,
        point,
        left: build_node(ds, left_idx, depth + 1),
        right: build_node(ds, right_idx, depth + 1),
    }))
}

fn search(
    ds: &DataView<'_>,
    node: Option<&Node>,
    query: &[f32],
    k: usize,
    best: &mut Vec<(f64, usize)>,
) {
    let Some(n) = node else { return };
    let dist = sq_dist(query, ds.row(n.point));
    // Insert into the sorted candidate list.
    if best.len() < k || dist < best.last().unwrap().0 {
        let pos = best.partition_point(|&(d0, _)| d0 <= dist);
        best.insert(pos, (dist, n.point));
        if best.len() > k {
            best.pop();
        }
    }
    let delta = (query[n.dim] - n.split) as f64;
    let (near, far) = if delta <= 0.0 {
        (n.left.as_deref(), n.right.as_deref())
    } else {
        (n.right.as_deref(), n.left.as_deref())
    };
    search(ds, near, query, k, best);
    // Prune the far side unless the splitting plane is closer than the
    // current k-th best.
    if best.len() < k || delta * delta < best.last().unwrap().0 {
        search(ds, far, query, k, best);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};
    use crate::knn::brute;
    use crate::rng::Pcg32;

    #[test]
    fn matches_brute_force_random() {
        let ds = generate(SynthKind::Uniform, 300, 3, 55, "u");
        let tree = KdTree::build(&ds);
        let mut rng = Pcg32::new(1);
        for _ in 0..50 {
            let q: Vec<f32> = (0..3).map(|_| rng.f32()).collect();
            let got = tree.knn(&q, 4);
            let want = brute::knn_query(&ds, &q, 4);
            let dg: f64 = got.iter().map(|&j| sq_dist(&q, ds.row(j))).sum();
            let dw: f64 = want.iter().map(|&j| sq_dist(&q, ds.row(j))).sum();
            assert!((dg - dw).abs() < 1e-9, "got {got:?} want {want:?}");
        }
    }

    #[test]
    fn exact_match_returns_self_first() {
        let ds = generate(SynthKind::Uniform, 100, 2, 56, "u");
        let tree = KdTree::build(&ds);
        for i in (0..100).step_by(13) {
            let got = tree.knn(ds.row(i), 1);
            assert_eq!(sq_dist(ds.row(i), ds.row(got[0])), 0.0);
        }
    }

    #[test]
    fn k_larger_than_n_is_clamped() {
        let ds = generate(SynthKind::Uniform, 5, 2, 57, "u");
        let tree = KdTree::build(&ds);
        let got = tree.knn(&[0.5, 0.5], 50);
        assert_eq!(got.len(), 5);
    }

    #[test]
    fn duplicate_points_handled() {
        let rows = vec![vec![1.0, 1.0]; 20];
        let ds = crate::data::Dataset::from_rows("dup", &rows).unwrap();
        let tree = KdTree::build(&ds);
        let got = tree.knn(&[1.0, 1.0], 5);
        assert_eq!(got.len(), 5);
    }
}
