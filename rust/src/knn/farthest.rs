//! Farthest-candidate queries over a point set (the per-batch centroid
//! index of the sparse assignment path).
//!
//! The assignment objective is **max**-cost, so candidate pruning needs
//! each object's top-`C` *farthest* centroids — the opposite of what
//! [`super::kdtree`] answers. Plane-distance pruning is useless for
//! farthest queries (the near half-space is unbounded away from the
//! query), so this index stores a bounding box per kd-node and prunes a
//! subtree when the maximum possible squared distance from the query to
//! the box cannot beat the current `C`-th best.
//!
//! Centroids move every batch, so the index is rebuilt per batch
//! (`O(k log² k)`, sort-based median); [`FarthestIndex`] therefore owns
//! its buffers and [`FarthestIndex::build`] reuses them, making repeated
//! rebuilds allocation-free after warm-up. Queries take a `valid`
//! predicate so capacity-aware callers (the §4.3 categorical bounds)
//! exclude saturated anticlusters *during* the search instead of
//! post-filtering a too-short list.

// Point distances and bounding-box bounds go through the session
// `Kernels` table (`sq_dist` / `bbox_far`, installed via
// `set_kernels`). Every table pairs the two lane-for-lane — in the
// deterministic modes both are the scalar objective-tier loops, in
// fast-math both vectorize with one shared chunk structure — so
// bound >= point distance holds exactly in every mode (see
// `crate::runtime::simd`).
use crate::runtime::simd::Kernels;

/// A kd-tree with per-node bounding boxes over `n` points in `d`
/// dimensions, answering top-`C` farthest-point queries. The tree is
/// implicit: the subtree of slice `[lo, hi)` has its median point at
/// `ids[(lo + hi) / 2]` and stores that slice's bounding box at the
/// median slot of `lo`/`hi`.
#[derive(Default)]
pub struct FarthestIndex {
    d: usize,
    n: usize,
    ids: Vec<u32>,
    bb_lo: Vec<f32>,
    bb_hi: Vec<f32>,
    /// Distance-kernel table for leaf scans and box bounds. `Default` is
    /// the process selection; sessions install their own via
    /// [`FarthestIndex::set_kernels`]. Deterministic tables dispatch
    /// both entries to the scalar objective-tier loops, so results are
    /// unchanged from a private-loop implementation.
    kern: Kernels,
}

impl FarthestIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install the session's distance-kernel table (leaf `sq_dist` and
    /// box `bbox_far` evaluations). Called once per session; queries
    /// never re-probe CPU features.
    pub fn set_kernels(&mut self, kern: Kernels) {
        self.kern = kern;
    }

    /// Points indexed.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// (Re)build over `n` row-major `d`-dimensional points, reusing the
    /// index's buffers.
    pub fn build(&mut self, pts: &[f32], n: usize, d: usize) {
        assert_eq!(pts.len(), n * d, "point matrix shape mismatch");
        assert!(d > 0 || n == 0, "zero-dimensional points");
        self.d = d;
        self.n = n;
        self.ids.clear();
        self.ids.extend(0..n as u32);
        self.bb_lo.clear();
        self.bb_lo.resize(n * d, 0.0);
        self.bb_hi.clear();
        self.bb_hi.resize(n * d, 0.0);
        if n > 0 {
            build_rec(pts, d, &mut self.ids, 0, n, 0, &mut self.bb_lo, &mut self.bb_hi);
        }
    }

    /// Collect into `best` the up-to-`c` valid points farthest from `q`
    /// (squared distance, descending; ties broken by traversal order,
    /// which is deterministic). `valid` filters points during the
    /// search — e.g. capacity-saturated anticlusters.
    pub fn farthest_into(
        &self,
        pts: &[f32],
        q: &[f32],
        c: usize,
        valid: &dyn Fn(usize) -> bool,
        best: &mut Vec<(f64, u32)>,
    ) {
        assert_eq!(q.len(), self.d, "query dimension mismatch");
        best.clear();
        if c == 0 || self.n == 0 {
            return;
        }
        self.rec(pts, q, c, valid, 0, self.n, 0, best);
    }

    /// Max possible squared distance from `q` to the bounding box stored
    /// at node `mid` (per-dimension farthest corner), via the session
    /// kernel table.
    fn bbox_bound(&self, q: &[f32], mid: usize) -> f64 {
        let d = self.d;
        let lo = &self.bb_lo[mid * d..(mid + 1) * d];
        let hi = &self.bb_hi[mid * d..(mid + 1) * d];
        self.kern.bbox_far(q, lo, hi)
    }

    #[allow(clippy::too_many_arguments)]
    fn rec(
        &self,
        pts: &[f32],
        q: &[f32],
        c: usize,
        valid: &dyn Fn(usize) -> bool,
        lo_i: usize,
        hi_i: usize,
        depth: usize,
        best: &mut Vec<(f64, u32)>,
    ) {
        if lo_i >= hi_i {
            return;
        }
        let mid = (lo_i + hi_i) / 2;
        // The node's box covers its whole subtree (median point
        // included): prune everything when it cannot beat the kept set.
        if best.len() == c && self.bbox_bound(q, mid) <= best[c - 1].0 {
            return;
        }
        let id = self.ids[mid] as usize;
        if valid(id) {
            let dist = self.kern.sq_dist(q, &pts[id * self.d..(id + 1) * self.d]);
            if best.len() < c || dist > best[best.len() - 1].0 {
                let pos = best.partition_point(|&(d0, _)| d0 >= dist);
                best.insert(pos, (dist, id as u32));
                if best.len() > c {
                    best.pop();
                }
            }
        }
        let dim = depth % self.d;
        let split = pts[id * self.d + dim];
        // Descend the half farther from the query first — it is the one
        // more likely to tighten the kept set and enable pruning.
        let (first, second) = if q[dim] <= split {
            ((mid + 1, hi_i), (lo_i, mid))
        } else {
            ((lo_i, mid), (mid + 1, hi_i))
        };
        self.rec(pts, q, c, valid, first.0, first.1, depth + 1, best);
        self.rec(pts, q, c, valid, second.0, second.1, depth + 1, best);
    }
}

/// Sort `ids[lo_i..hi_i]` by the cycling dimension, store the slice's
/// bounding box at the median slot, recurse into both halves.
#[allow(clippy::too_many_arguments)]
fn build_rec(
    pts: &[f32],
    d: usize,
    ids: &mut [u32],
    lo_i: usize,
    hi_i: usize,
    depth: usize,
    bb_lo: &mut [f32],
    bb_hi: &mut [f32],
) {
    if lo_i >= hi_i {
        return;
    }
    let mid = (lo_i + hi_i) / 2;
    {
        let first = ids[lo_i] as usize;
        let prow = &pts[first * d..(first + 1) * d];
        let blo = &mut bb_lo[mid * d..(mid + 1) * d];
        let bhi = &mut bb_hi[mid * d..(mid + 1) * d];
        blo.copy_from_slice(prow);
        bhi.copy_from_slice(prow);
        for &idp in &ids[lo_i..hi_i] {
            let row = &pts[idp as usize * d..(idp as usize + 1) * d];
            for t in 0..d {
                if row[t] < blo[t] {
                    blo[t] = row[t];
                }
                if row[t] > bhi[t] {
                    bhi[t] = row[t];
                }
            }
        }
    }
    let dim = depth % d;
    // Secondary id order makes ties fully canonical, so candidate sets
    // are reproducible across builds.
    ids[lo_i..hi_i].sort_unstable_by(|&a, &b| {
        pts[a as usize * d + dim]
            .total_cmp(&pts[b as usize * d + dim])
            .then(a.cmp(&b))
    });
    build_rec(pts, d, ids, lo_i, mid, depth + 1, bb_lo, bb_hi);
    build_rec(pts, d, ids, mid + 1, hi_i, depth + 1, bb_lo, bb_hi);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::runtime::simd::sq_dist;
    use crate::runtime::KernelMode;

    fn rand_pts(rng: &mut Pcg32, n: usize, d: usize) -> Vec<f32> {
        (0..n * d).map(|_| rng.normal_f32(0.0, 2.0)).collect()
    }

    /// Brute-force top-c farthest among valid points (distance sums are
    /// compared, so tie permutations don't matter).
    fn brute_farthest(
        pts: &[f32],
        n: usize,
        d: usize,
        q: &[f32],
        c: usize,
        valid: &dyn Fn(usize) -> bool,
    ) -> Vec<(f64, u32)> {
        let mut all: Vec<(f64, u32)> = (0..n)
            .filter(|&i| valid(i))
            .map(|i| (sq_dist(q, &pts[i * d..(i + 1) * d]), i as u32))
            .collect();
        all.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        all.truncate(c);
        all
    }

    #[test]
    fn matches_brute_force_random() {
        let mut rng = Pcg32::new(71);
        for &(n, d, c) in &[(50usize, 2usize, 4usize), (300, 3, 8), (200, 6, 16), (64, 4, 64)] {
            let pts = rand_pts(&mut rng, n, d);
            let mut index = FarthestIndex::new();
            index.build(&pts, n, d);
            let mut best = Vec::new();
            for _ in 0..20 {
                let q: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 2.0)).collect();
                index.farthest_into(&pts, &q, c, &|_| true, &mut best);
                let want = brute_farthest(&pts, n, d, &q, c, &|_| true);
                assert_eq!(best.len(), want.len(), "n={n} d={d} c={c}");
                let got_sum: f64 = best.iter().map(|&(dd, _)| dd).sum();
                let want_sum: f64 = want.iter().map(|&(dd, _)| dd).sum();
                assert!(
                    (got_sum - want_sum).abs() < 1e-9 * want_sum.max(1.0),
                    "n={n} d={d} c={c}: {got_sum} vs {want_sum}"
                );
                // Descending order.
                for w in best.windows(2) {
                    assert!(w[0].0 >= w[1].0);
                }
            }
        }
    }

    #[test]
    fn fast_math_kernels_still_match_brute_force() {
        // Under the relaxed tier the per-point distances may differ from
        // scalar in the last ULPs, but the search must still return the
        // true farthest set: the bound/distance pair is constructed so
        // pruning never cuts a winner. Brute force is computed with the
        // same fast `sq_dist`, so sums compare within f64 noise.
        let fast = Kernels::select(KernelMode::FastMath);
        let mut rng = Pcg32::new(75);
        for &(n, d, c) in &[(300usize, 3usize, 8usize), (200, 6, 16), (150, 16, 5)] {
            let pts = rand_pts(&mut rng, n, d);
            let mut index = FarthestIndex::new();
            index.set_kernels(fast);
            index.build(&pts, n, d);
            let mut best = Vec::new();
            for _ in 0..10 {
                let q: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 2.0)).collect();
                index.farthest_into(&pts, &q, c, &|_| true, &mut best);
                let mut all: Vec<(f64, u32)> = (0..n)
                    .map(|i| (fast.sq_dist(&q, &pts[i * d..(i + 1) * d]), i as u32))
                    .collect();
                all.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
                all.truncate(c);
                let got_sum: f64 = best.iter().map(|&(dd, _)| dd).sum();
                let want_sum: f64 = all.iter().map(|&(dd, _)| dd).sum();
                assert!(
                    (got_sum - want_sum).abs() < 1e-9 * want_sum.max(1.0),
                    "n={n} d={d} c={c} isa={}: {got_sum} vs {want_sum}",
                    fast.isa()
                );
            }
        }
    }

    #[test]
    fn validity_filter_is_respected() {
        let mut rng = Pcg32::new(72);
        let (n, d, c) = (120usize, 3usize, 6usize);
        let pts = rand_pts(&mut rng, n, d);
        let mut index = FarthestIndex::new();
        index.build(&pts, n, d);
        let valid = |i: usize| i % 3 != 0;
        let q = [0.5f32, -0.25, 1.0];
        let mut best = Vec::new();
        index.farthest_into(&pts, &q, c, &valid, &mut best);
        assert_eq!(best.len(), c);
        assert!(best.iter().all(|&(_, i)| valid(i as usize)));
        let want = brute_farthest(&pts, n, d, &q, c, &valid);
        let got_sum: f64 = best.iter().map(|&(dd, _)| dd).sum();
        let want_sum: f64 = want.iter().map(|&(dd, _)| dd).sum();
        assert!((got_sum - want_sum).abs() < 1e-9 * want_sum.max(1.0));
    }

    #[test]
    fn duplicate_points_yield_distinct_ids() {
        let pts = vec![1.0f32; 20 * 2];
        let mut index = FarthestIndex::new();
        index.build(&pts, 20, 2);
        let mut best = Vec::new();
        index.farthest_into(&pts, &[1.0, 1.0], 5, &|_| true, &mut best);
        assert_eq!(best.len(), 5);
        let mut ids: Vec<u32> = best.iter().map(|&(_, i)| i).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 5, "must return 5 distinct points");
    }

    #[test]
    fn rebuild_reuses_buffers_and_stays_correct() {
        let mut rng = Pcg32::new(73);
        let mut index = FarthestIndex::new();
        let mut best = Vec::new();
        for &(n, d) in &[(60usize, 2usize), (33, 5), (60, 2)] {
            let pts = rand_pts(&mut rng, n, d);
            index.build(&pts, n, d);
            assert_eq!(index.len(), n);
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            index.farthest_into(&pts, &q, 3, &|_| true, &mut best);
            let want = brute_farthest(&pts, n, d, &q, 3, &|_| true);
            let got_sum: f64 = best.iter().map(|&(dd, _)| dd).sum();
            let want_sum: f64 = want.iter().map(|&(dd, _)| dd).sum();
            assert!((got_sum - want_sum).abs() < 1e-9 * want_sum.max(1.0));
        }
    }

    #[test]
    fn fewer_valid_points_than_c_returns_them_all() {
        let mut rng = Pcg32::new(74);
        let pts = rand_pts(&mut rng, 10, 2);
        let mut index = FarthestIndex::new();
        index.build(&pts, 10, 2);
        let mut best = Vec::new();
        index.farthest_into(&pts, &[0.0, 0.0], 50, &|i| i < 4, &mut best);
        assert_eq!(best.len(), 4);
    }

    #[test]
    fn empty_and_zero_c_are_empty() {
        let mut index = FarthestIndex::new();
        index.build(&[], 0, 3);
        let mut best = vec![(1.0, 0u32)];
        index.farthest_into(&[], &[0.0, 0.0, 0.0], 4, &|_| true, &mut best);
        assert!(best.is_empty());
        let pts = vec![0.5f32, 0.5, 0.5];
        index.build(&pts, 1, 3);
        index.farthest_into(&pts, &[0.0, 0.0, 0.0], 0, &|_| true, &mut best);
        assert!(best.is_empty());
    }
}
