//! k-nearest-neighbor search.
//!
//! Needed by the `P-N5` baseline (fast_anticlustering with
//! nearest-neighbor exchange partners) and by the graph builder. A
//! kd-tree handles the low-dimensional tabular datasets; brute force is
//! both the oracle and the high-D fallback (kd-trees degrade past ~16
//! dimensions). Everything consumes a zero-copy [`DataView`] — a
//! `&Dataset` or any index subset works without gathering rows.
//!
//! [`farthest`] is the inverse query: top-`C` *farthest* points via a
//! bounding-box kd-tree — the per-batch centroid index behind the
//! sparse (candidate-pruned) assignment path.

pub mod brute;
pub mod farthest;
pub mod kdtree;

use crate::data::DataView;

/// Find the `k` nearest neighbors (by squared Euclidean distance,
/// excluding self) of every object. Returns an `n x k` row-major index
/// matrix. Picks kd-tree vs brute force by dimensionality.
pub fn knn_all<'a>(data: impl Into<DataView<'a>>, k: usize) -> Vec<usize> {
    let view: DataView<'a> = data.into();
    let n = view.n();
    assert!(k < n, "k={k} must be < n={n}");
    if view.d() <= 16 {
        let tree = kdtree::KdTree::build(&view);
        let mut out = Vec::with_capacity(n * k);
        for i in 0..n {
            out.extend(tree.knn(view.row(i), k + 1).into_iter().filter(|&j| j != i).take(k));
        }
        out
    } else {
        brute::knn_all(&view, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};

    #[test]
    fn dispatcher_matches_brute_low_d() {
        let ds = generate(SynthKind::Uniform, 200, 3, 77, "u");
        let k = 5;
        let fast = knn_all(&ds, k);
        let slow = brute::knn_all(&ds, k);
        for i in 0..ds.n {
            let mut a = fast[i * k..(i + 1) * k].to_vec();
            let mut b = slow[i * k..(i + 1) * k].to_vec();
            a.sort_unstable();
            b.sort_unstable();
            // Distances, not identities, must agree (ties may reorder).
            let da: f64 = a.iter().map(|&j| ds.dist2(i, j)).sum();
            let db: f64 = b.iter().map(|&j| ds.dist2(i, j)).sum();
            assert!((da - db).abs() < 1e-9, "row {i}");
        }
    }

    #[test]
    fn excludes_self() {
        let ds = generate(SynthKind::Uniform, 50, 2, 78, "u");
        let k = 3;
        let nn = knn_all(&ds, k);
        for i in 0..ds.n {
            assert!(!nn[i * k..(i + 1) * k].contains(&i));
        }
    }

    #[test]
    fn view_subset_matches_owned_subset() {
        let ds = generate(SynthKind::Uniform, 160, 3, 79, "u");
        let idx: Vec<usize> = (0..160).step_by(2).collect();
        let owned = knn_all(&ds.subset(&idx, "owned"), 4);
        let viewed = knn_all(&ds.view().select(&idx), 4);
        assert_eq!(owned, viewed);
    }
}
