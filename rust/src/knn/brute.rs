//! Brute-force kNN — oracle and high-dimensional fallback.

use crate::data::DataView;
use crate::runtime::simd::sq_dist;

/// `k` nearest neighbors of every object (excluding self), row-major
/// `n x k`. O(n² d) — fine for the sizes the exchange baseline handles.
pub fn knn_all<'a>(data: impl Into<DataView<'a>>, k: usize) -> Vec<usize> {
    let ds: DataView<'a> = data.into();
    let n = ds.n();
    assert!(k < n);
    let mut out = Vec::with_capacity(n * k);
    // Reused per-row heap of (dist, idx) as a simple insertion buffer.
    let mut best: Vec<(f64, usize)> = Vec::with_capacity(k + 1);
    for i in 0..n {
        best.clear();
        let ri = ds.row(i);
        let mut worst = f64::INFINITY;
        for j in 0..n {
            if j == i {
                continue;
            }
            let dist = sq_dist(ri, ds.row(j));
            if best.len() < k {
                best.push((dist, j));
                if best.len() == k {
                    best.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
                    worst = best[k - 1].0;
                }
            } else if dist < worst {
                // Insert in sorted position, drop the tail.
                let pos = best.partition_point(|&(d0, _)| d0 <= dist);
                best.insert(pos, (dist, j));
                best.pop();
                worst = best[k - 1].0;
            }
        }
        if best.len() < k {
            best.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
        }
        out.extend(best.iter().map(|&(_, j)| j));
    }
    out
}

/// `k` nearest neighbors of a single query point among the view's rows.
pub fn knn_query<'a>(data: impl Into<DataView<'a>>, query: &[f32], k: usize) -> Vec<usize> {
    let ds: DataView<'a> = data.into();
    let mut d: Vec<(f64, usize)> = (0..ds.n()).map(|j| (sq_dist(query, ds.row(j)), j)).collect();
    d.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
    d.truncate(k);
    d.into_iter().map(|(_, j)| j).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    fn line() -> Dataset {
        // Points at x = 0, 1, 2, 10.
        Dataset::from_rows(
            "line",
            &[vec![0.0], vec![1.0], vec![2.0], vec![10.0]],
        )
        .unwrap()
    }

    #[test]
    fn neighbors_on_a_line() {
        let ds = line();
        let nn = knn_all(&ds, 2);
        assert_eq!(&nn[0..2], &[1, 2]); // from 0: 1 then 2
        assert_eq!(&nn[2..4], &[0, 2]); // from 1: 0 and 2 (tie order by dist)
        assert_eq!(&nn[6..8], &[2, 1]); // from 10: 2 then 1
    }

    #[test]
    fn query_interface() {
        let ds = line();
        assert_eq!(knn_query(&ds, &[9.0], 1), vec![3]);
        assert_eq!(knn_query(&ds, &[0.4], 2), vec![0, 1]);
    }
}
