//! The assignment loop of Algorithm 1.
//!
//! Given the processing order (from [`super::batching`]), the loop:
//! 1. assigns the first batch's K objects to the K anticlusters 1:1 and
//!    seeds each centroid with its object's feature vector,
//! 2. for every later batch, computes the `|B| x K` squared-distance cost
//!    matrix through the [`CostBackend`] (native or the AOT Pallas/XLA
//!    artifact), solves a **max-cost** assignment (LAPJV), and
//! 3. folds each assigned object into its anticluster's running centroid
//!    (`UPDATE_CENTROID`: `mu += (x - mu) / counter`).
//!
//! When the dataset carries categories, per-(anticluster, category)
//! counters enforce the §4.3 upper bounds by masking violating cost
//! entries to a large negative value before the solve.
//!
//! # Parallel execution
//!
//! With a non-serial [`Parallelism`], the loop drives two overlapping
//! optimizations off the session's [`WorkerPool`] (owned by [`Scratch`],
//! so the threads persist across runs):
//!
//! * the backend chunk-parallelizes each batch's cost matrix over rows
//!   (installed via [`CostBackend::set_pool`]), and
//! * batch staging is **double-buffered**: while the assignment solver
//!   runs on batch *t* (on the calling thread), a deferred pool task
//!   gathers batch *t+1*'s feature rows into the back buffer.
//!
//! The cost matrix of batch *t+1* itself cannot be overlapped with the
//! solve of batch *t*: every full batch assigns one object to *every*
//! anticluster, so all centroids move between consecutive batches and
//! the next cost matrix depends on the previous solve. Only the
//! centroid-independent staging work is hoisted. Both optimizations are
//! bit-identical to the serial path — tasks compute the same values in
//! the same per-entry order — which the determinism property tests
//! assert.
//!
//! # Sparse (candidate-pruned) batches
//!
//! When the session's [`CandidateMode`] resolves to `C < k` candidates
//! (large K), the loop skips the dense `m x k` cost matrix entirely:
//! a per-batch farthest-point index over the centroids
//! ([`crate::knn::farthest`]) yields each object's top-`C` highest-cost
//! candidate anticlusters (capacity-aware — §4.3-saturated clusters are
//! excluded during the query), a CSR cost structure is assembled in the
//! scratch (chunk-parallel over objects on the worker pool), and a
//! sparse solver ([`crate::assignment::sparse`]) runs on it. When the
//! pruned graph admits no perfect matching, feasibility repair doubles
//! `C` and regenerates; once `C` would reach `k` the batch falls back
//! to the exact dense path. Per-batch work drops from `O(k²d + k³)` to
//! roughly `O(k·C·(d + log k))`; telemetry accumulates in
//! [`SparseStats`] on the scratch.

use super::batching::batch_ranges;
use crate::assignment::auction::Auction;
use crate::assignment::sparse::{CsrCost, SparseAuction, SparseLapjv, SparseStats};
use crate::assignment::{greedy, CandidateMode, Lapjv, SolverKind};
use crate::data::DataView;
use crate::error::{AbaError, AbaResult};
use crate::knn::farthest::FarthestIndex;
use crate::runtime::{CostBackend, Parallelism, WorkerPool};
use std::sync::{Arc, Mutex};

/// Mask value for forbidden (anticluster, category) assignments. Large
/// and negative so a max-cost solver avoids it whenever the instance is
/// feasible, yet far from f32 infinity to keep dual arithmetic finite.
/// Shared with the online subsystem's insert rounds so both paths mask
/// with the same sentinel.
pub(crate) const MASK_COST: f32 = -1e30;

/// The single §4.3 saturation predicate shared by the dense mask and
/// the sparse candidate filter — one definition, so the two paths can
/// never drift on cap semantics.
#[inline]
fn cat_saturated(cat_counts: &[usize], caps: &[usize], kk: usize, cat: usize, g: usize) -> bool {
    cat_counts[kk * g + cat] >= caps[cat]
}

/// The default for [`Lapjv::warm_start`] on the assignment loop,
/// consulted **once** per scratch construction (session build time) —
/// never on the per-run hot path.
///
/// Profiling finding (EXPERIMENTS.md §Perf): the JV column/row-
/// reduction warm start speeds up *random* cost matrices ~1.7x, but
/// ABA's structured matrices (all entries = distances to centroids that
/// have contracted toward the global mean, heavy ties) make the greedy
/// tight matching adversarial for the remaining augmenting paths —
/// measured ~1.5–2x SLOWER end to end. Hence cold start by default;
/// `ABA_LAPJV_WARM=1` (or `Aba::builder().lapjv_warm_start(true)`)
/// re-enables it for ablation.
pub(crate) fn warm_start_env_default() -> bool {
    std::env::var_os("ABA_LAPJV_WARM").is_some()
}

/// Reusable buffers for the assignment loop. An [`crate::solver::Aba`]
/// session owns one of these so repeated `partition` calls perform no
/// large allocations after the first call; `run_with_order` creates a
/// throwaway one for one-shot use.
pub struct Scratch {
    /// f64 anticluster centroids (`k * d`).
    centroids: Vec<f64>,
    /// Objects per anticluster.
    counts: Vec<usize>,
    /// f32 mirror of `centroids` handed to the backend.
    centroids_f32: Vec<f32>,
    /// Gathered rows of the current batch (`m * d`).
    xb: Vec<f32>,
    /// Back buffer: the next batch's rows, staged during the solve.
    xb_next: Vec<f32>,
    /// Per-batch cost matrix (dense path only).
    cost: Vec<f32>,
    /// Per-(anticluster, category) counters for the §4.3 variant.
    cat_counts: Vec<usize>,
    /// Per-category saturated-cluster lists, rebuilt per batch (the fast
    /// §4.3 masking path).
    saturated: Vec<Vec<u32>>,
    /// The dense LAP solver (owns its own scratch). `warm_start` is set
    /// at construction — see [`warm_start_env_default`].
    lapjv: Lapjv,
    /// The dense auction solver (reused so its rectangular padding
    /// scratch survives across batches).
    auction: Auction,
    /// Everything the candidate-pruned path needs (centroid index,
    /// candidate/CSR buffers, sparse solvers, telemetry).
    sparse: SparseScratch,
    /// Session worker pool, built lazily on the first parallel run and
    /// kept across runs (thread spawning is the expensive part).
    pool: Option<Arc<WorkerPool>>,
}

impl Default for Scratch {
    /// Consults `ABA_LAPJV_WARM` once, here at construction; sessions
    /// built through `Aba::builder()` can override with
    /// `lapjv_warm_start(..)`.
    fn default() -> Self {
        Self::with_lapjv_warm(warm_start_env_default())
    }
}

impl Scratch {
    /// A scratch with an explicit LAPJV warm-start setting (the session
    /// builder resolves its `lapjv_warm_start` option into this).
    pub fn with_lapjv_warm(warm: bool) -> Self {
        let mut lapjv = Lapjv::new();
        lapjv.warm_start = warm;
        Self {
            centroids: Vec::new(),
            counts: Vec::new(),
            centroids_f32: Vec::new(),
            xb: Vec::new(),
            xb_next: Vec::new(),
            cost: Vec::new(),
            cat_counts: Vec::new(),
            saturated: Vec::new(),
            lapjv,
            auction: Auction::new(),
            sparse: SparseScratch::default(),
            pool: None,
        }
    }

    /// Sparse-path telemetry accumulated by every run through this
    /// scratch (see [`SparseStats`]).
    pub fn sparse_stats(&self) -> SparseStats {
        self.sparse.stats
    }

    /// Zero the sparse-path telemetry (benches call this between
    /// measured configurations).
    pub fn reset_sparse_stats(&mut self) {
        self.sparse.stats = SparseStats::default();
    }

    /// The pool for `par`, if it resolves to more than one thread.
    /// Cached: rebuilt only when the requested thread count changes.
    pub(crate) fn pool_for(&mut self, par: Parallelism) -> Option<Arc<WorkerPool>> {
        let want = par.effective_threads();
        if want <= 1 {
            return None;
        }
        if self.pool.as_ref().map(|p| p.threads()) != Some(want) {
            self.pool = Some(Arc::new(WorkerPool::new(want)));
        }
        self.pool.clone()
    }
}

/// Buffers and solvers for the candidate-pruned batches, bundled so the
/// assignment loop can borrow them disjointly from the rest of
/// [`Scratch`].
#[derive(Default)]
pub(crate) struct SparseScratch {
    /// Per-batch farthest-point index over the centroids (buffers
    /// reused across rebuilds).
    index: FarthestIndex,
    /// Fixed-width candidate staging: row `j`'s candidates at
    /// `j*C..j*C+len[j]`. Filled chunk-parallel (disjoint slices).
    cand_cols: Vec<u32>,
    cand_vals: Vec<f32>,
    cand_len: Vec<u32>,
    /// The compacted CSR handed to the sparse solvers.
    row_ptr: Vec<usize>,
    csr_cols: Vec<u32>,
    csr_vals: Vec<f32>,
    jv: SparseLapjv,
    auction: SparseAuction,
    pub(crate) stats: SparseStats,
}

impl SparseScratch {
    /// Fill the candidate staging buffers with each batch object's
    /// top-`c` farthest non-saturated centroids and compact them into
    /// CSR. `cents` is the `k x d` centroid matrix the index was built
    /// over. Chunk-parallel over objects when a pool is present — each
    /// task writes a disjoint slice, so serial and pooled fills are
    /// bit-identical.
    #[allow(clippy::too_many_arguments)]
    fn build_candidates(
        &mut self,
        xb: &[f32],
        d: usize,
        cents: &[f32],
        c: usize,
        batch: &[usize],
        ds: &DataView<'_>,
        g: usize,
        caps: &[usize],
        cat_counts: &[usize],
        pool: Option<&WorkerPool>,
    ) {
        let m = batch.len();
        self.cand_cols.clear();
        self.cand_cols.resize(m * c, 0);
        self.cand_vals.clear();
        self.cand_vals.resize(m * c, 0.0);
        self.cand_len.clear();
        self.cand_len.resize(m, 0);
        let index = &self.index;
        let fill_rows = |r0: usize, cols: &mut [u32], vals: &mut [f32], lens: &mut [u32]| {
            let mut best: Vec<(f64, u32)> = Vec::with_capacity(c + 1);
            for (local, len_slot) in lens.iter_mut().enumerate() {
                let j = r0 + local;
                let q = &xb[j * d..(j + 1) * d];
                if g > 0 {
                    let cat = ds.category(batch[j]) as usize;
                    // Capacity-aware: §4.3-saturated clusters are not
                    // candidates (the dense path masks them instead).
                    let valid = |kk: usize| !cat_saturated(cat_counts, caps, kk, cat, g);
                    index.farthest_into(cents, q, c, &valid, &mut best);
                } else {
                    index.farthest_into(cents, q, c, &|_| true, &mut best);
                }
                *len_slot = best.len() as u32;
                for (t, &(dist, col)) in best.iter().enumerate() {
                    cols[local * c + t] = col;
                    vals[local * c + t] = dist as f32;
                }
            }
        };
        match pool {
            Some(pool) if pool.threads() > 1 && m >= 2 => {
                let rows_per = m.div_ceil(pool.threads() * 4).max(8);
                struct Chunk<'b> {
                    r0: usize,
                    cols: &'b mut [u32],
                    vals: &'b mut [f32],
                    lens: &'b mut [u32],
                }
                let mut chunks: Vec<Chunk<'_>> = self
                    .cand_cols
                    .chunks_mut(rows_per * c)
                    .zip(self.cand_vals.chunks_mut(rows_per * c))
                    .zip(self.cand_len.chunks_mut(rows_per))
                    .enumerate()
                    .map(|(ci, ((cols, vals), lens))| Chunk {
                        r0: ci * rows_per,
                        cols,
                        vals,
                        lens,
                    })
                    .collect();
                pool.run_mut(&mut chunks, &|_i, ch| {
                    fill_rows(ch.r0, ch.cols, ch.vals, ch.lens);
                });
            }
            _ => fill_rows(0, &mut self.cand_cols, &mut self.cand_vals, &mut self.cand_len),
        }
        // Compact the fixed-width staging into CSR (cheap O(m·c) copy;
        // short rows occur when saturation filtered candidates out).
        self.row_ptr.clear();
        self.row_ptr.reserve(m + 1);
        self.row_ptr.push(0);
        let mut nnz = 0usize;
        for &l in &self.cand_len {
            nnz += l as usize;
            self.row_ptr.push(nnz);
        }
        self.csr_cols.clear();
        self.csr_cols.reserve(nnz);
        self.csr_vals.clear();
        self.csr_vals.reserve(nnz);
        for j in 0..m {
            let l = self.cand_len[j] as usize;
            self.csr_cols.extend_from_slice(&self.cand_cols[j * c..j * c + l]);
            self.csr_vals.extend_from_slice(&self.cand_vals[j * c..j * c + l]);
        }
    }
}

/// Escalation stops once the *next* candidate structure would cross
/// this byte budget: past it, a doubled CSR rivals the dense matrix and
/// the dense path is the better exact escape hatch (repair must stay
/// bounded — it must never allocate more than the thing it avoids).
const ESCALATION_BYTES_CAP: usize = 256 << 20;

/// One batch through the candidate-pruned path: build the centroid
/// index, generate top-`c0` candidates, solve sparsely; on an
/// infeasible pruned graph escalate `C` (×2) and regenerate. Returns
/// `None` when repair would reach `C = k` or blow the escalation byte
/// budget — the caller then runs the exact dense path for this batch.
/// (That fallback allocates the full `m x k` matrix: it is the exact
/// escape hatch, so at scales where even that cannot be represented a
/// repair-exhausted batch is a hard stop by design.)
#[allow(clippy::too_many_arguments)]
fn solve_batch_sparse(
    sp: &mut SparseScratch,
    xb: &[f32],
    m: usize,
    d: usize,
    centroids_f32: &[f32],
    k: usize,
    c0: usize,
    solver: SolverKind,
    batch: &[usize],
    ds: &DataView<'_>,
    g: usize,
    caps: &[usize],
    cat_counts: &[usize],
    pool: Option<&WorkerPool>,
) -> Option<Vec<usize>> {
    debug_assert_eq!(xb.len(), m * d);
    debug_assert!((1..k).contains(&c0));
    if matches!(solver, SolverKind::Greedy) {
        return None; // no sparse mode for greedy; the caller gates this
    }
    sp.index.build(centroids_f32, k, d);
    let mut c = c0;
    loop {
        sp.build_candidates(xb, d, centroids_f32, c, batch, ds, g, caps, cat_counts, pool);
        // A row with zero valid candidates can never match at any C —
        // its §4.3-valid cluster set itself is empty, so escalation
        // cannot help; only the dense path (masked costs) can place it.
        if (0..m).any(|j| sp.row_ptr[j] == sp.row_ptr[j + 1]) {
            return None;
        }
        let nnz = sp.row_ptr[m];
        let csr_bytes = nnz * (std::mem::size_of::<u32>() + std::mem::size_of::<f32>())
            + (m + 1) * std::mem::size_of::<usize>();
        sp.stats.peak_cost_bytes = sp.stats.peak_cost_bytes.max(csr_bytes);
        let csr = CsrCost {
            row_ptr: &sp.row_ptr,
            cols: &sp.csr_cols,
            vals: &sp.csr_vals,
            nc: k,
        };
        let solved = match solver {
            SolverKind::Lapjv => sp.jv.solve_max(&csr),
            SolverKind::Auction => sp.auction.solve_max(&csr, 1e-6),
            // Greedy has no sparse mode; the caller never routes it here.
            SolverKind::Greedy => None,
        };
        if let Some(assign) = solved {
            sp.stats.sparse_batches += 1;
            return Some(assign);
        }
        let next_bytes = m * (c * 2) * (std::mem::size_of::<u32>() + std::mem::size_of::<f32>());
        if c * 2 >= k || next_bytes > ESCALATION_BYTES_CAP {
            return None;
        }
        c *= 2;
        sp.stats.escalations += 1;
    }
}

/// §4.3 categorical masking on a dense cost matrix. Instead of scanning
/// all `m x k` (object, cluster) pairs, build the per-category list of
/// saturated clusters once per batch (`O(k·g)`) and only touch those
/// entries — same entries, same mask value, bit-identical to the old
/// full scan.
#[allow(clippy::too_many_arguments)]
fn mask_saturated(
    cost: &mut [f32],
    k: usize,
    batch: &[usize],
    ds: &DataView<'_>,
    g: usize,
    caps: &[usize],
    cat_counts: &[usize],
    saturated: &mut Vec<Vec<u32>>,
) {
    if g == 0 {
        return;
    }
    if saturated.len() < g {
        saturated.resize_with(g, Vec::new);
    }
    for list in saturated.iter_mut() {
        list.clear();
    }
    for kk in 0..k {
        for cat in 0..g {
            if cat_saturated(cat_counts, caps, kk, cat, g) {
                saturated[cat].push(kk as u32);
            }
        }
    }
    for (j, &obj) in batch.iter().enumerate() {
        let cat = ds.category(obj) as usize;
        let row = &mut cost[j * k..(j + 1) * k];
        for &kk in &saturated[cat] {
            row[kk as usize] = MASK_COST;
        }
    }
}

/// Dense per-batch solve through the scratch-owned solvers.
fn dense_solve(
    solver: SolverKind,
    cost: &[f32],
    m: usize,
    k: usize,
    lapjv: &mut Lapjv,
    auction: &mut Auction,
) -> Vec<usize> {
    match solver {
        SolverKind::Lapjv => lapjv.solve(cost, m, k, true),
        SolverKind::Auction => auction.solve_max(cost, m, k),
        SolverKind::Greedy => greedy::solve_max(cost, m, k),
    }
}

/// Run Algorithm 1 over the given processing order with throwaway
/// scratch, serially and densely (no candidate pruning — the exact
/// paper algorithm). Accepts a `&Dataset` or a zero-copy [`DataView`];
/// `order` must be a permutation of `0..n` (view rows).
pub fn run_with_order<'a>(
    data: impl Into<DataView<'a>>,
    k: usize,
    order: &[usize],
    solver: SolverKind,
    backend: &mut dyn CostBackend,
) -> AbaResult<Vec<u32>> {
    run_with_order_scratch(
        &data.into(),
        k,
        order,
        solver,
        backend,
        &mut Scratch::default(),
        Parallelism::Serial,
        CandidateMode::Dense,
    )
}

/// Floor for engaging the pooled centroid mirror: below ~64k elements
/// the f64→f32 cast loop finishes faster than one pool dispatch.
const PAR_MIRROR_MIN: usize = 1 << 16;

/// Mirror the f64 centroid state into the backend's f32 buffer. The
/// cast is elementwise — no accumulation — so the pooled chunked copy
/// is bit-identical to the serial loop for any thread count and chunk
/// shape; at large `k * d` (the sparse large-K regime rebuilds this
/// mirror every batch) the copy is memory-bound and splits cleanly.
fn mirror_centroids_f32(pool: Option<&WorkerPool>, src: &[f64], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    match pool {
        Some(pool) if src.len() >= PAR_MIRROR_MIN => {
            let chunk = src.len().div_ceil(pool.threads() * 4).max(1 << 12);
            let mut chunks: Vec<(usize, &mut [f32])> = dst
                .chunks_mut(chunk)
                .enumerate()
                .map(|(ci, c)| (ci * chunk, c))
                .collect();
            pool.run_mut(&mut chunks, &|_ti, (o0, c)| {
                for (dd, &s) in c.iter_mut().zip(&src[*o0..*o0 + c.len()]) {
                    *dd = s as f32;
                }
            });
        }
        _ => {
            for (dd, &s) in dst.iter_mut().zip(src) {
                *dd = s as f32;
            }
        }
    }
}

/// Run Algorithm 1 over the given processing order, reusing the caller's
/// [`Scratch`] across calls (the session hot path). `par` selects the
/// execution strategy — see the module docs; any setting produces
/// bit-identical labels. `candidates` selects the dense vs
/// candidate-pruned per-batch solve; any resolution with `C >= k`
/// (including `Dense` and `Fixed(C >= k)`) runs the identical dense
/// code path. The view is read in place: the only feature copies are
/// the per-batch stagings into `Scratch.xb`/`xb_next`.
#[allow(clippy::too_many_arguments)]
pub fn run_with_order_scratch(
    ds: &DataView<'_>,
    k: usize,
    order: &[usize],
    solver: SolverKind,
    backend: &mut dyn CostBackend,
    scratch: &mut Scratch,
    par: Parallelism,
    candidates: CandidateMode,
) -> AbaResult<Vec<u32>> {
    let n = ds.n();
    if order.len() != n {
        return Err(AbaError::InvalidOrder { expected: n, got: order.len() });
    }
    if k == 0 || k > n {
        return Err(AbaError::InvalidK {
            k,
            n,
            reason: "k must be in 1..=n".into(),
        });
    }
    // Resolve the worker pool once per run and hand it to the backend so
    // large cost matrices chunk-parallelize. `None` (serial) explicitly
    // clears any pool installed by a previous run.
    let pool = scratch.pool_for(par);
    backend.set_pool(pool.clone());
    // The sparse candidate index evaluates distances too: install the
    // backend's kernel table so leaf scans and box bounds run on the
    // same tier as the cost matrices (scalar-identical in every
    // deterministic mode).
    scratch.sparse.index.set_kernels(backend.kernels());
    let d = ds.d();
    let mut labels = vec![u32::MAX; n];

    // Anticluster state: f64 centroids (for exact incremental updates),
    // object counts, and the f32 mirror handed to the backend. All live
    // in the scratch; clear+resize zeroes them without reallocating once
    // capacity exists.
    scratch.centroids.clear();
    scratch.centroids.resize(k * d, 0.0);
    scratch.counts.clear();
    scratch.counts.resize(k, 0);
    scratch.centroids_f32.clear();
    scratch.centroids_f32.resize(k * d, 0.0);
    let centroids = &mut scratch.centroids;
    let counts = &mut scratch.counts;
    let centroids_f32 = &mut scratch.centroids_f32;

    // Categorical state (§4.3): cap and per-(cluster, category) counters.
    // `n_categories` is cached on the view (carried through subsetting),
    // so no rescans happen here.
    let g = ds.n_categories();
    let caps: Vec<usize> = if g > 0 {
        let mut totals = vec![0usize; g];
        for i in 0..n {
            totals[ds.category(i) as usize] += 1;
        }
        totals.iter().map(|&t| t.div_ceil(k)).collect()
    } else {
        Vec::new()
    };
    scratch.cat_counts.clear();
    scratch.cat_counts.resize(k * g, 0);
    let cat_counts = &mut scratch.cat_counts;

    // --- First batch: one object per anticluster -----------------------
    let batches = batch_ranges(n, k);
    let (b0_lo, b0_hi) = batches[0];
    for (slot, &obj) in order[b0_lo..b0_hi].iter().enumerate() {
        labels[obj] = slot as u32;
        counts[slot] = 1;
        for (dst, &v) in centroids[slot * d..(slot + 1) * d].iter_mut().zip(ds.row(obj)) {
            *dst = v as f64;
        }
        if g > 0 {
            cat_counts[slot * g + ds.category(obj) as usize] += 1;
        }
    }

    // Per-batch buffers reused across batches and, via `scratch`, across
    // whole runs (on the serial path: zero allocation per batch after
    // warm-up — see EXPERIMENTS.md §Perf; parallel runs add one small
    // `Arc` job allocation per batch for the deferred staging, plus a
    // task vector per pooled cost matrix). `xb` carries the current
    // batch's rows, `xb_next` the staged next batch; they swap every
    // iteration.
    let xb = &mut scratch.xb;
    let xb_next = &mut scratch.xb_next;
    let cost = &mut scratch.cost;
    let lapjv = &mut scratch.lapjv;
    let auction = &mut scratch.auction;
    let saturated = &mut scratch.saturated;
    let sparse = &mut scratch.sparse;
    // `lapjv.warm_start` was fixed at scratch construction (session
    // build time) — see `warm_start_env_default`; no env reads here.

    // Candidate pruning resolves once per run; `C >= k` (incl. `Dense`)
    // is the dense path. Greedy has no sparse mode — it falls through
    // to dense regardless of the candidate setting.
    let cand_c = candidates.effective(k);
    let use_sparse = cand_c < k && matches!(solver, SolverKind::Lapjv | SolverKind::Auction);

    // Contiguous row gather for one batch (centroid-independent, so it
    // is safe to stage ahead of the solve). This bounded staging is the
    // only feature-row copy on the whole path — metered by
    // `data::view::gathered_bytes`.
    let gather = |batch: &[usize], dst: &mut Vec<f32>| ds.gather_rows(batch, dst);

    if batches.len() > 1 {
        let (lo, hi) = batches[1];
        gather(&order[lo..hi], xb);
    }
    for (t, &(lo, hi)) in batches.iter().enumerate().skip(1) {
        let m = hi - lo;
        let batch = &order[lo..hi];
        debug_assert_eq!(xb.len(), m * d, "batch {t} was staged with the wrong shape");
        // Mirror centroids to f32 for the backend / candidate index —
        // chunked over the pool at large k*d, bit-identical to serial.
        mirror_centroids_f32(pool.as_deref(), centroids, centroids_f32);
        if !use_sparse {
            // Dense path: cost matrix through the backend (Pallas/XLA
            // artifact or native), then §4.3 masking.
            backend.batch_costs(&xb[..], m, d, &centroids_f32[..], k, cost);
            mask_saturated(cost, k, batch, ds, g, &caps, cat_counts, saturated);
        }

        // Max-cost assignment on the calling thread; meanwhile a
        // deferred pool task stages batch t+1's rows into the back
        // buffer (serial runs stage after the solve instead).
        let next_batch = batches.get(t + 1).map(|&(nlo, nhi)| &order[nlo..nhi]);
        let assign = {
            let staged = Mutex::new(std::mem::take(xb_next));
            let prefetch = |_task: usize| {
                if let Some(nb) = next_batch {
                    gather(nb, &mut staged.lock().unwrap());
                }
            };
            let deferred = match (&pool, next_batch) {
                (Some(p), Some(_)) => Some(p.defer(&prefetch)),
                _ => None,
            };
            let assign = if use_sparse {
                match solve_batch_sparse(
                    sparse,
                    &xb[..],
                    m,
                    d,
                    &centroids_f32[..],
                    k,
                    cand_c,
                    solver,
                    batch,
                    ds,
                    g,
                    &caps,
                    cat_counts,
                    pool.as_deref(),
                ) {
                    Some(a) => a,
                    None => {
                        // Feasibility repair exhausted: even the
                        // escalated candidate graph admits no perfect
                        // matching — run this batch on the exact dense
                        // path instead.
                        sparse.stats.fallback_batches += 1;
                        sparse.stats.dense_batches += 1;
                        sparse.stats.peak_cost_bytes =
                            sparse.stats.peak_cost_bytes.max(m * k * 4);
                        backend.batch_costs(&xb[..], m, d, &centroids_f32[..], k, cost);
                        mask_saturated(cost, k, batch, ds, g, &caps, cat_counts, saturated);
                        dense_solve(solver, &cost[..], m, k, lapjv, auction)
                    }
                }
            } else {
                sparse.stats.dense_batches += 1;
                sparse.stats.peak_cost_bytes = sparse.stats.peak_cost_bytes.max(m * k * 4);
                dense_solve(solver, &cost[..], m, k, lapjv, auction)
            };
            match deferred {
                Some(df) => df.wait(),
                None => prefetch(0),
            }
            *xb_next = staged.into_inner().unwrap();
            assign
        };

        // Apply assignments + incremental centroid updates.
        for (j, &obj) in batch.iter().enumerate() {
            let kk = assign[j];
            labels[obj] = kk as u32;
            counts[kk] += 1;
            let counter = counts[kk] as f64;
            let mu = &mut centroids[kk * d..(kk + 1) * d];
            for (m_d, &x_d) in mu.iter_mut().zip(ds.row(obj)) {
                *m_d += (x_d as f64 - *m_d) / counter;
            }
            if g > 0 {
                cat_counts[kk * g + ds.category(obj) as usize] += 1;
            }
        }
        std::mem::swap(xb, xb_next);
    }

    debug_assert!(labels.iter().all(|&l| l != u32::MAX));
    Ok(labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::objective::ClusterStats;
    use crate::data::synth::{generate, SynthKind};
    use crate::data::Dataset;
    use crate::runtime::NativeBackend;

    fn run_base(ds: &Dataset, k: usize) -> Vec<u32> {
        let mut be = NativeBackend::default();
        let order =
            crate::algo::batching::build_order(&ds.view(), k, crate::algo::Variant::Base, &mut be);
        run_with_order(ds, k, &order, SolverKind::Lapjv, &mut be).unwrap()
    }

    #[test]
    fn pooled_centroid_mirror_is_bit_identical_to_serial() {
        let mut rng = crate::rng::Pcg32::new(911);
        // Above and below the pooled floor, ragged against the chunk size.
        for len in [100usize, PAR_MIRROR_MIN, PAR_MIRROR_MIN + 4097] {
            let src: Vec<f64> = (0..len).map(|_| rng.normal_f32(0.0, 3.0) as f64).collect();
            let (mut serial, mut pooled) = (vec![0f32; len], vec![0f32; len]);
            mirror_centroids_f32(None, &src, &mut serial);
            let pool = WorkerPool::new(3);
            mirror_centroids_f32(Some(&pool), &src, &mut pooled);
            assert!(
                serial.iter().zip(&pooled).all(|(a, b)| a.to_bits() == b.to_bits()),
                "len={len}"
            );
        }
    }

    #[test]
    fn sizes_differ_by_at_most_one() {
        for &(n, k) in &[(100usize, 7usize), (99, 10), (20, 20), (50, 3), (10, 1)] {
            let ds = generate(SynthKind::Uniform, n, 3, 5, "u");
            let labels = run_base(&ds, k);
            let stats = ClusterStats::compute(&ds, &labels, k);
            let min = *stats.sizes.iter().min().unwrap();
            let max = *stats.sizes.iter().max().unwrap();
            assert!(max - min <= 1, "n={n} k={k} sizes={:?}", stats.sizes);
            assert_eq!(stats.sizes.iter().sum::<usize>(), n);
        }
    }

    #[test]
    fn beats_random_partition_on_objective() {
        let ds = generate(
            SynthKind::GaussianMixture { components: 5, spread: 6.0 },
            600,
            4,
            6,
            "g",
        );
        let k = 10;
        let labels = run_base(&ds, k);
        let aba = ClusterStats::compute(&ds, &labels, k).ssd_total();
        // Random balanced partition.
        let rnd = crate::baselines::random_part::random_partition(ds.n, k, 3);
        let rand_obj = ClusterStats::compute(&ds, &rnd, k).ssd_total();
        assert!(aba > rand_obj, "aba={aba} rand={rand_obj}");
    }

    #[test]
    fn two_clusters_of_two_points_pair_far_apart() {
        // 4 points on a line: 0, 1, 10, 11. Optimal anticlustering with
        // K=2 pairs {0,10|11} and {1,11|10} — i.e. each anticluster spans
        // the gap; within-cluster ssd is maximal when distant points are
        // together.
        let ds = Dataset::from_rows(
            "line",
            &[vec![0.0], vec![1.0], vec![10.0], vec![11.0]],
        )
        .unwrap();
        let labels = run_base(&ds, 2);
        // Each cluster must contain one low point and one high point.
        assert_ne!(labels[0], labels[1], "{labels:?}");
        assert_ne!(labels[2], labels[3], "{labels:?}");
    }

    #[test]
    fn categorical_caps_respected() {
        let n = 60;
        let mut ds = generate(SynthKind::Uniform, n, 3, 8, "u");
        // 3 categories with unequal counts: 30 / 20 / 10.
        let cats: Vec<u32> = (0..n)
            .map(|i| if i < 30 { 0 } else if i < 50 { 1 } else { 2 })
            .collect();
        ds = ds.with_categories(cats.clone()).unwrap();
        let k = 5;
        let mut be = NativeBackend::default();
        let order =
            crate::algo::batching::build_order(&ds.view(), k, crate::algo::Variant::Base, &mut be);
        let labels = run_with_order(&ds, k, &order, SolverKind::Lapjv, &mut be).unwrap();
        // Constraint (5): per category, cluster counts within floor/ceil.
        for gcat in 0..3u32 {
            let total = cats.iter().filter(|&&c| c == gcat).count();
            let (floor, ceil) = (total / k, total.div_ceil(k));
            for kk in 0..k as u32 {
                let cnt = (0..n)
                    .filter(|&i| labels[i] == kk && cats[i] == gcat)
                    .count();
                assert!(
                    (floor..=ceil).contains(&cnt),
                    "cat {gcat} cluster {kk}: {cnt} not in [{floor},{ceil}]"
                );
            }
        }
    }

    #[test]
    fn deterministic() {
        let ds = generate(SynthKind::Uniform, 200, 4, 9, "u");
        assert_eq!(run_base(&ds, 8), run_base(&ds, 8));
    }

    #[test]
    fn order_must_be_full_permutation() {
        let ds = generate(SynthKind::Uniform, 10, 2, 1, "u");
        let mut be = NativeBackend::default();
        let short = vec![0usize, 1, 2];
        let err = run_with_order(&ds, 2, &short, SolverKind::Lapjv, &mut be).unwrap_err();
        assert_eq!(err, crate::error::AbaError::InvalidOrder { expected: 10, got: 3 });
    }

    #[test]
    fn scratch_reuse_matches_fresh_scratch_across_shapes() {
        // Reusing one Scratch across different (n, k, categorical) runs
        // must be invisible in the results — buffers are fully re-zeroed.
        let mut be = NativeBackend::default();
        let mut scratch = Scratch::default();
        for &(n, k, seed) in &[(100usize, 7usize, 5u64), (60, 10, 6), (100, 7, 5)] {
            let ds = generate(SynthKind::Uniform, n, 3, seed, "u");
            let order = crate::algo::batching::build_order(
                &ds.view(),
                k,
                crate::algo::Variant::Base,
                &mut be,
            );
            let reused = run_with_order_scratch(
                &ds.view(),
                k,
                &order,
                SolverKind::Lapjv,
                &mut be,
                &mut scratch,
                Parallelism::Serial,
                CandidateMode::Dense,
            )
            .unwrap();
            let fresh = run_with_order(&ds, k, &order, SolverKind::Lapjv, &mut be).unwrap();
            assert_eq!(reused, fresh, "n={n} k={k}");
        }
    }

    #[test]
    fn parallel_loop_matches_serial_bitwise() {
        // Exercises the double-buffered staging path (the pool is present
        // even when individual cost matrices stay below the parallel
        // threshold) and pool reuse across shapes within one scratch.
        let mut scratch = Scratch::default();
        for &(n, k, seed) in &[(240usize, 8usize, 21u64), (90, 9, 22), (64, 16, 23)] {
            let ds = generate(SynthKind::Uniform, n, 4, seed, "u");
            let mut be = NativeBackend::default();
            let order = crate::algo::batching::build_order(
                &ds.view(),
                k,
                crate::algo::Variant::Base,
                &mut be,
            );
            let serial = run_with_order(&ds, k, &order, SolverKind::Lapjv, &mut be).unwrap();
            let parallel = run_with_order_scratch(
                &ds.view(),
                k,
                &order,
                SolverKind::Lapjv,
                &mut be,
                &mut scratch,
                Parallelism::Threads(3),
                CandidateMode::Dense,
            )
            .unwrap();
            assert_eq!(serial, parallel, "n={n} k={k}");
        }
    }

    /// Run with an explicit candidate mode (serial), returning labels
    /// and the scratch for stats inspection.
    fn run_with_candidates(
        ds: &Dataset,
        k: usize,
        solver: SolverKind,
        cand: CandidateMode,
        par: Parallelism,
    ) -> (Vec<u32>, Scratch) {
        let mut be = NativeBackend::default();
        let order =
            crate::algo::batching::build_order(&ds.view(), k, crate::algo::Variant::Base, &mut be);
        let mut scratch = Scratch::default();
        let labels = run_with_order_scratch(
            &ds.view(),
            k,
            &order,
            solver,
            &mut be,
            &mut scratch,
            par,
            cand,
        )
        .unwrap();
        (labels, scratch)
    }

    #[test]
    fn sparse_path_produces_valid_balanced_partitions() {
        for solver in [SolverKind::Lapjv, SolverKind::Auction] {
            let ds = generate(
                SynthKind::GaussianMixture { components: 6, spread: 4.0 },
                240,
                4,
                77,
                "g",
            );
            let k = 24;
            let (labels, scratch) =
                run_with_candidates(&ds, k, solver, CandidateMode::Fixed(6), Parallelism::Serial);
            let stats = ClusterStats::compute(&ds, &labels, k);
            assert!(stats.sizes.iter().all(|&s| s == 10), "{solver:?}: {:?}", stats.sizes);
            let sp = scratch.sparse_stats();
            assert!(sp.sparse_batches > 0, "{solver:?}: sparse path never engaged: {sp:?}");
        }
    }

    #[test]
    fn sparse_path_serial_and_parallel_bit_identical() {
        let ds = generate(SynthKind::Uniform, 300, 5, 78, "u");
        let k = 20;
        let (serial, _) = run_with_candidates(
            &ds,
            k,
            SolverKind::Lapjv,
            CandidateMode::Fixed(5),
            Parallelism::Serial,
        );
        let (parallel, _) = run_with_candidates(
            &ds,
            k,
            SolverKind::Lapjv,
            CandidateMode::Fixed(5),
            Parallelism::Threads(3),
        );
        assert_eq!(serial, parallel);
    }

    #[test]
    fn full_candidate_count_dispatches_to_the_dense_path_bitwise() {
        // C >= k is defined as "no pruning": the run must take the
        // literal dense code path, so labels are bit-identical and no
        // sparse batch is ever counted.
        let ds = generate(SynthKind::Uniform, 180, 4, 79, "u");
        let k = 12;
        let (dense, _) = run_with_candidates(
            &ds,
            k,
            SolverKind::Lapjv,
            CandidateMode::Dense,
            Parallelism::Serial,
        );
        for cand in [CandidateMode::Fixed(k), CandidateMode::Fixed(10 * k), CandidateMode::Auto] {
            let (got, scratch) =
                run_with_candidates(&ds, k, SolverKind::Lapjv, cand, Parallelism::Serial);
            assert_eq!(dense, got, "{cand:?}");
            let sp = scratch.sparse_stats();
            assert_eq!(sp.sparse_batches, 0, "{cand:?}: {sp:?}");
        }
    }

    #[test]
    fn sparse_infeasible_candidates_fall_back_to_dense() {
        // All-identical points: every object's top-C candidate list is
        // the same C clusters (distances all tie, traversal order is
        // canonical), so for C < k the pruned bipartite graph violates
        // Hall's condition; feasibility repair must escalate and then
        // hand the batch to the exact dense path — and the result must
        // still be a valid balanced partition.
        let rows = vec![vec![1.0f32, 2.0]; 40];
        let ds = Dataset::from_rows("dup", &rows).unwrap();
        let k = 8;
        let (labels, scratch) = run_with_candidates(
            &ds,
            k,
            SolverKind::Lapjv,
            CandidateMode::Fixed(2),
            Parallelism::Serial,
        );
        let sp = scratch.sparse_stats();
        assert!(sp.escalations > 0, "repair never escalated: {sp:?}");
        assert!(sp.fallback_batches > 0, "dense fallback never engaged: {sp:?}");
        assert_eq!(sp.sparse_batches, 0, "{sp:?}");
        let stats = ClusterStats::compute(&ds, &labels, k);
        assert!(stats.sizes.iter().all(|&s| s == 5), "{:?}", stats.sizes);
    }

    #[test]
    fn sparse_path_respects_categorical_caps() {
        let n = 120;
        let mut ds = generate(SynthKind::Uniform, n, 3, 80, "u");
        let cats: Vec<u32> = (0..n).map(|i| (i % 3) as u32).collect();
        ds = ds.with_categories(cats.clone()).unwrap();
        let k = 12;
        let (labels, _) = run_with_candidates(
            &ds,
            k,
            SolverKind::Lapjv,
            CandidateMode::Fixed(4),
            Parallelism::Serial,
        );
        for gcat in 0..3u32 {
            let total = cats.iter().filter(|&&c| c == gcat).count();
            let (floor, ceil) = (total / k, total.div_ceil(k));
            for kk in 0..k as u32 {
                let cnt = (0..n)
                    .filter(|&i| labels[i] == kk && cats[i] == gcat)
                    .count();
                assert!(
                    (floor..=ceil).contains(&cnt),
                    "cat {gcat} cluster {kk}: {cnt} not in [{floor},{ceil}]"
                );
            }
        }
    }

    #[test]
    fn all_solvers_produce_valid_partitions() {
        let ds = generate(SynthKind::Uniform, 90, 3, 10, "u");
        let k = 9;
        for solver in [SolverKind::Lapjv, SolverKind::Auction, SolverKind::Greedy] {
            let mut be = NativeBackend::default();
            let order = crate::algo::batching::build_order(
                &ds.view(),
                k,
                crate::algo::Variant::Base,
                &mut be,
            );
            let labels = run_with_order(&ds, k, &order, solver, &mut be).unwrap();
            let stats = ClusterStats::compute(&ds, &labels, k);
            assert!(stats.sizes.iter().all(|&s| s == 10), "{solver:?}");
        }
    }

    #[test]
    fn lapjv_not_worse_than_greedy_objective() {
        let ds = generate(
            SynthKind::GaussianMixture { components: 4, spread: 5.0 },
            240,
            6,
            11,
            "g",
        );
        let k = 12;
        let obj = |solver| {
            let mut be = NativeBackend::default();
            let order = crate::algo::batching::build_order(
                &ds.view(),
                k,
                crate::algo::Variant::Base,
                &mut be,
            );
            let labels = run_with_order(&ds, k, &order, solver, &mut be).unwrap();
            ClusterStats::compute(&ds, &labels, k).ssd_total()
        };
        let lap = obj(SolverKind::Lapjv);
        let gre = obj(SolverKind::Greedy);
        assert!(lap >= gre * 0.999, "lapjv={lap} greedy={gre}");
    }
}
