//! The assignment loop of Algorithm 1.
//!
//! Given the processing order (from [`super::batching`]), the loop:
//! 1. assigns the first batch's K objects to the K anticlusters 1:1 and
//!    seeds each centroid with its object's feature vector,
//! 2. for every later batch, computes the `|B| x K` squared-distance cost
//!    matrix through the [`CostBackend`] (native or the AOT Pallas/XLA
//!    artifact), solves a **max-cost** assignment (LAPJV), and
//! 3. folds each assigned object into its anticluster's running centroid
//!    (`UPDATE_CENTROID`: `mu += (x - mu) / counter`).
//!
//! When the dataset carries categories, per-(anticluster, category)
//! counters enforce the §4.3 upper bounds by masking violating cost
//! entries to a large negative value before the solve.
//!
//! # Parallel execution
//!
//! With a non-serial [`Parallelism`], the loop drives two overlapping
//! optimizations off the session's [`WorkerPool`] (owned by [`Scratch`],
//! so the threads persist across runs):
//!
//! * the backend chunk-parallelizes each batch's cost matrix over rows
//!   (installed via [`CostBackend::set_pool`]), and
//! * batch staging is **double-buffered**: while the assignment solver
//!   runs on batch *t* (on the calling thread), a deferred pool task
//!   gathers batch *t+1*'s feature rows into the back buffer.
//!
//! The cost matrix of batch *t+1* itself cannot be overlapped with the
//! solve of batch *t*: every full batch assigns one object to *every*
//! anticluster, so all centroids move between consecutive batches and
//! the next cost matrix depends on the previous solve. Only the
//! centroid-independent staging work is hoisted. Both optimizations are
//! bit-identical to the serial path — tasks compute the same values in
//! the same per-entry order — which the determinism property tests
//! assert.

use super::batching::batch_ranges;
use crate::assignment::{self, Lapjv, SolverKind};
use crate::data::DataView;
use crate::error::{AbaError, AbaResult};
use crate::runtime::{CostBackend, Parallelism, WorkerPool};
use std::sync::{Arc, Mutex};

/// Mask value for forbidden (anticluster, category) assignments. Large
/// and negative so a max-cost solver avoids it whenever the instance is
/// feasible, yet far from f32 infinity to keep dual arithmetic finite.
const MASK_COST: f32 = -1e30;

/// Reusable buffers for the assignment loop. An [`crate::solver::Aba`]
/// session owns one of these so repeated `partition` calls perform no
/// large allocations after the first call; `run_with_order` creates a
/// throwaway one for one-shot use.
#[derive(Default)]
pub struct Scratch {
    /// f64 anticluster centroids (`k * d`).
    centroids: Vec<f64>,
    /// Objects per anticluster.
    counts: Vec<usize>,
    /// f32 mirror of `centroids` handed to the backend.
    centroids_f32: Vec<f32>,
    /// Gathered rows of the current batch (`m * d`).
    xb: Vec<f32>,
    /// Back buffer: the next batch's rows, staged during the solve.
    xb_next: Vec<f32>,
    /// Per-batch cost matrix.
    cost: Vec<f32>,
    /// Per-(anticluster, category) counters for the §4.3 variant.
    cat_counts: Vec<usize>,
    /// The LAP solver (owns its own scratch).
    lapjv: Lapjv,
    /// Session worker pool, built lazily on the first parallel run and
    /// kept across runs (thread spawning is the expensive part).
    pool: Option<Arc<WorkerPool>>,
}

impl Scratch {
    /// The pool for `par`, if it resolves to more than one thread.
    /// Cached: rebuilt only when the requested thread count changes.
    pub(crate) fn pool_for(&mut self, par: Parallelism) -> Option<Arc<WorkerPool>> {
        let want = par.effective_threads();
        if want <= 1 {
            return None;
        }
        if self.pool.as_ref().map(|p| p.threads()) != Some(want) {
            self.pool = Some(Arc::new(WorkerPool::new(want)));
        }
        self.pool.clone()
    }
}

/// Run Algorithm 1 over the given processing order with throwaway
/// scratch, serially. Accepts a `&Dataset` or a zero-copy [`DataView`];
/// `order` must be a permutation of `0..n` (view rows).
pub fn run_with_order<'a>(
    data: impl Into<DataView<'a>>,
    k: usize,
    order: &[usize],
    solver: SolverKind,
    backend: &mut dyn CostBackend,
) -> AbaResult<Vec<u32>> {
    run_with_order_scratch(
        &data.into(),
        k,
        order,
        solver,
        backend,
        &mut Scratch::default(),
        Parallelism::Serial,
    )
}

/// Run Algorithm 1 over the given processing order, reusing the caller's
/// [`Scratch`] across calls (the session hot path). `par` selects the
/// execution strategy — see the module docs; any setting produces
/// bit-identical labels. The view is read in place: the only feature
/// copies are the per-batch stagings into `Scratch.xb`/`xb_next`.
pub fn run_with_order_scratch(
    ds: &DataView<'_>,
    k: usize,
    order: &[usize],
    solver: SolverKind,
    backend: &mut dyn CostBackend,
    scratch: &mut Scratch,
    par: Parallelism,
) -> AbaResult<Vec<u32>> {
    let n = ds.n();
    if order.len() != n {
        return Err(AbaError::InvalidOrder { expected: n, got: order.len() });
    }
    if k == 0 || k > n {
        return Err(AbaError::InvalidK {
            k,
            n,
            reason: "k must be in 1..=n".into(),
        });
    }
    // Resolve the worker pool once per run and hand it to the backend so
    // large cost matrices chunk-parallelize. `None` (serial) explicitly
    // clears any pool installed by a previous run.
    let pool = scratch.pool_for(par);
    backend.set_pool(pool.clone());
    let d = ds.d();
    let mut labels = vec![u32::MAX; n];

    // Anticluster state: f64 centroids (for exact incremental updates),
    // object counts, and the f32 mirror handed to the backend. All live
    // in the scratch; clear+resize zeroes them without reallocating once
    // capacity exists.
    scratch.centroids.clear();
    scratch.centroids.resize(k * d, 0.0);
    scratch.counts.clear();
    scratch.counts.resize(k, 0);
    scratch.centroids_f32.clear();
    scratch.centroids_f32.resize(k * d, 0.0);
    let centroids = &mut scratch.centroids;
    let counts = &mut scratch.counts;
    let centroids_f32 = &mut scratch.centroids_f32;

    // Categorical state (§4.3): cap and per-(cluster, category) counters.
    // `n_categories` is cached on the view (carried through subsetting),
    // so no rescans happen here.
    let g = ds.n_categories();
    let caps: Vec<usize> = if g > 0 {
        let mut totals = vec![0usize; g];
        for i in 0..n {
            totals[ds.category(i) as usize] += 1;
        }
        totals.iter().map(|&t| t.div_ceil(k)).collect()
    } else {
        Vec::new()
    };
    scratch.cat_counts.clear();
    scratch.cat_counts.resize(k * g, 0);
    let cat_counts = &mut scratch.cat_counts;

    // --- First batch: one object per anticluster -----------------------
    let batches = batch_ranges(n, k);
    let (b0_lo, b0_hi) = batches[0];
    for (slot, &obj) in order[b0_lo..b0_hi].iter().enumerate() {
        labels[obj] = slot as u32;
        counts[slot] = 1;
        for (dst, &v) in centroids[slot * d..(slot + 1) * d].iter_mut().zip(ds.row(obj)) {
            *dst = v as f64;
        }
        if g > 0 {
            cat_counts[slot * g + ds.category(obj) as usize] += 1;
        }
    }

    // Per-batch buffers reused across batches and, via `scratch`, across
    // whole runs (on the serial path: zero allocation per batch after
    // warm-up — see EXPERIMENTS.md §Perf; parallel runs add one small
    // `Arc` job allocation per batch for the deferred staging, plus a
    // task vector per pooled cost matrix). `xb` carries the current
    // batch's rows, `xb_next` the staged next batch; they swap every
    // iteration.
    let xb = &mut scratch.xb;
    let xb_next = &mut scratch.xb_next;
    let cost = &mut scratch.cost;
    let lapjv = &mut scratch.lapjv;
    // Profiling finding (EXPERIMENTS.md §Perf): the JV column/row-
    // reduction warm start speeds up *random* cost matrices ~1.7x, but
    // ABA's structured matrices (all entries = distances to centroids
    // that have contracted toward the global mean, heavy ties) make the
    // greedy tight matching adversarial for the remaining augmenting
    // paths — measured ~1.5–2x SLOWER end to end. Default to the cold
    // start here; ABA_LAPJV_WARM=1 re-enables it for ablation.
    lapjv.warm_start = std::env::var_os("ABA_LAPJV_WARM").is_some();

    // Contiguous row gather for one batch (centroid-independent, so it
    // is safe to stage ahead of the solve). This bounded staging is the
    // only feature-row copy on the whole path — metered by
    // `data::view::gathered_bytes`.
    let gather = |batch: &[usize], dst: &mut Vec<f32>| ds.gather_rows(batch, dst);

    if batches.len() > 1 {
        let (lo, hi) = batches[1];
        gather(&order[lo..hi], xb);
    }
    for (t, &(lo, hi)) in batches.iter().enumerate().skip(1) {
        let m = hi - lo;
        let batch = &order[lo..hi];
        debug_assert_eq!(xb.len(), m * d, "batch {t} was staged with the wrong shape");
        // Mirror centroids to f32 for the backend.
        for (dst, &src) in centroids_f32.iter_mut().zip(centroids.iter()) {
            *dst = src as f32;
        }
        // Cost matrix through the backend (Pallas/XLA artifact or native).
        backend.batch_costs(&xb[..], m, d, &centroids_f32[..], k, cost);

        // Categorical upper-bound masking (§4.3).
        if g > 0 {
            for (j, &obj) in batch.iter().enumerate() {
                let c = ds.category(obj) as usize;
                for kk in 0..k {
                    if cat_counts[kk * g + c] >= caps[c] {
                        cost[j * k + kk] = MASK_COST;
                    }
                }
            }
        }

        // Max-cost assignment on the calling thread; meanwhile a
        // deferred pool task stages batch t+1's rows into the back
        // buffer (serial runs stage after the solve instead).
        let next_batch = batches.get(t + 1).map(|&(nlo, nhi)| &order[nlo..nhi]);
        let assign = {
            let staged = Mutex::new(std::mem::take(xb_next));
            let prefetch = |_task: usize| {
                if let Some(nb) = next_batch {
                    gather(nb, &mut staged.lock().unwrap());
                }
            };
            let deferred = match (&pool, next_batch) {
                (Some(p), Some(_)) => Some(p.defer(&prefetch)),
                _ => None,
            };
            let assign = match solver {
                SolverKind::Lapjv => lapjv.solve(&cost[..], m, k, true),
                other => assignment::solve_max(other, &cost[..], m, k),
            };
            match deferred {
                Some(df) => df.wait(),
                None => prefetch(0),
            }
            *xb_next = staged.into_inner().unwrap();
            assign
        };

        // Apply assignments + incremental centroid updates.
        for (j, &obj) in batch.iter().enumerate() {
            let kk = assign[j];
            labels[obj] = kk as u32;
            counts[kk] += 1;
            let counter = counts[kk] as f64;
            let mu = &mut centroids[kk * d..(kk + 1) * d];
            for (m_d, &x_d) in mu.iter_mut().zip(ds.row(obj)) {
                *m_d += (x_d as f64 - *m_d) / counter;
            }
            if g > 0 {
                cat_counts[kk * g + ds.category(obj) as usize] += 1;
            }
        }
        std::mem::swap(xb, xb_next);
    }

    debug_assert!(labels.iter().all(|&l| l != u32::MAX));
    Ok(labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::objective::ClusterStats;
    use crate::data::synth::{generate, SynthKind};
    use crate::data::Dataset;
    use crate::runtime::NativeBackend;

    fn run_base(ds: &Dataset, k: usize) -> Vec<u32> {
        let mut be = NativeBackend::default();
        let order =
            crate::algo::batching::build_order(&ds.view(), k, crate::algo::Variant::Base, &mut be);
        run_with_order(ds, k, &order, SolverKind::Lapjv, &mut be).unwrap()
    }

    #[test]
    fn sizes_differ_by_at_most_one() {
        for &(n, k) in &[(100usize, 7usize), (99, 10), (20, 20), (50, 3), (10, 1)] {
            let ds = generate(SynthKind::Uniform, n, 3, 5, "u");
            let labels = run_base(&ds, k);
            let stats = ClusterStats::compute(&ds, &labels, k);
            let min = *stats.sizes.iter().min().unwrap();
            let max = *stats.sizes.iter().max().unwrap();
            assert!(max - min <= 1, "n={n} k={k} sizes={:?}", stats.sizes);
            assert_eq!(stats.sizes.iter().sum::<usize>(), n);
        }
    }

    #[test]
    fn beats_random_partition_on_objective() {
        let ds = generate(
            SynthKind::GaussianMixture { components: 5, spread: 6.0 },
            600,
            4,
            6,
            "g",
        );
        let k = 10;
        let labels = run_base(&ds, k);
        let aba = ClusterStats::compute(&ds, &labels, k).ssd_total();
        // Random balanced partition.
        let rnd = crate::baselines::random_part::random_partition(ds.n, k, 3);
        let rand_obj = ClusterStats::compute(&ds, &rnd, k).ssd_total();
        assert!(aba > rand_obj, "aba={aba} rand={rand_obj}");
    }

    #[test]
    fn two_clusters_of_two_points_pair_far_apart() {
        // 4 points on a line: 0, 1, 10, 11. Optimal anticlustering with
        // K=2 pairs {0,10|11} and {1,11|10} — i.e. each anticluster spans
        // the gap; within-cluster ssd is maximal when distant points are
        // together.
        let ds = Dataset::from_rows(
            "line",
            &[vec![0.0], vec![1.0], vec![10.0], vec![11.0]],
        )
        .unwrap();
        let labels = run_base(&ds, 2);
        // Each cluster must contain one low point and one high point.
        assert_ne!(labels[0], labels[1], "{labels:?}");
        assert_ne!(labels[2], labels[3], "{labels:?}");
    }

    #[test]
    fn categorical_caps_respected() {
        let n = 60;
        let mut ds = generate(SynthKind::Uniform, n, 3, 8, "u");
        // 3 categories with unequal counts: 30 / 20 / 10.
        let cats: Vec<u32> = (0..n)
            .map(|i| if i < 30 { 0 } else if i < 50 { 1 } else { 2 })
            .collect();
        ds = ds.with_categories(cats.clone()).unwrap();
        let k = 5;
        let mut be = NativeBackend::default();
        let order =
            crate::algo::batching::build_order(&ds.view(), k, crate::algo::Variant::Base, &mut be);
        let labels = run_with_order(&ds, k, &order, SolverKind::Lapjv, &mut be).unwrap();
        // Constraint (5): per category, cluster counts within floor/ceil.
        for gcat in 0..3u32 {
            let total = cats.iter().filter(|&&c| c == gcat).count();
            let (floor, ceil) = (total / k, total.div_ceil(k));
            for kk in 0..k as u32 {
                let cnt = (0..n)
                    .filter(|&i| labels[i] == kk && cats[i] == gcat)
                    .count();
                assert!(
                    (floor..=ceil).contains(&cnt),
                    "cat {gcat} cluster {kk}: {cnt} not in [{floor},{ceil}]"
                );
            }
        }
    }

    #[test]
    fn deterministic() {
        let ds = generate(SynthKind::Uniform, 200, 4, 9, "u");
        assert_eq!(run_base(&ds, 8), run_base(&ds, 8));
    }

    #[test]
    fn order_must_be_full_permutation() {
        let ds = generate(SynthKind::Uniform, 10, 2, 1, "u");
        let mut be = NativeBackend::default();
        let short = vec![0usize, 1, 2];
        let err = run_with_order(&ds, 2, &short, SolverKind::Lapjv, &mut be).unwrap_err();
        assert_eq!(err, crate::error::AbaError::InvalidOrder { expected: 10, got: 3 });
    }

    #[test]
    fn scratch_reuse_matches_fresh_scratch_across_shapes() {
        // Reusing one Scratch across different (n, k, categorical) runs
        // must be invisible in the results — buffers are fully re-zeroed.
        let mut be = NativeBackend::default();
        let mut scratch = Scratch::default();
        for &(n, k, seed) in &[(100usize, 7usize, 5u64), (60, 10, 6), (100, 7, 5)] {
            let ds = generate(SynthKind::Uniform, n, 3, seed, "u");
            let order = crate::algo::batching::build_order(
                &ds.view(),
                k,
                crate::algo::Variant::Base,
                &mut be,
            );
            let reused = run_with_order_scratch(
                &ds.view(),
                k,
                &order,
                SolverKind::Lapjv,
                &mut be,
                &mut scratch,
                Parallelism::Serial,
            )
            .unwrap();
            let fresh = run_with_order(&ds, k, &order, SolverKind::Lapjv, &mut be).unwrap();
            assert_eq!(reused, fresh, "n={n} k={k}");
        }
    }

    #[test]
    fn parallel_loop_matches_serial_bitwise() {
        // Exercises the double-buffered staging path (the pool is present
        // even when individual cost matrices stay below the parallel
        // threshold) and pool reuse across shapes within one scratch.
        let mut scratch = Scratch::default();
        for &(n, k, seed) in &[(240usize, 8usize, 21u64), (90, 9, 22), (64, 16, 23)] {
            let ds = generate(SynthKind::Uniform, n, 4, seed, "u");
            let mut be = NativeBackend::default();
            let order = crate::algo::batching::build_order(
                &ds.view(),
                k,
                crate::algo::Variant::Base,
                &mut be,
            );
            let serial = run_with_order(&ds, k, &order, SolverKind::Lapjv, &mut be).unwrap();
            let parallel = run_with_order_scratch(
                &ds.view(),
                k,
                &order,
                SolverKind::Lapjv,
                &mut be,
                &mut scratch,
                Parallelism::Threads(3),
            )
            .unwrap();
            assert_eq!(serial, parallel, "n={n} k={k}");
        }
    }

    #[test]
    fn all_solvers_produce_valid_partitions() {
        let ds = generate(SynthKind::Uniform, 90, 3, 10, "u");
        let k = 9;
        for solver in [SolverKind::Lapjv, SolverKind::Auction, SolverKind::Greedy] {
            let mut be = NativeBackend::default();
            let order = crate::algo::batching::build_order(
                &ds.view(),
                k,
                crate::algo::Variant::Base,
                &mut be,
            );
            let labels = run_with_order(&ds, k, &order, solver, &mut be).unwrap();
            let stats = ClusterStats::compute(&ds, &labels, k);
            assert!(stats.sizes.iter().all(|&s| s == 10), "{solver:?}");
        }
    }

    #[test]
    fn lapjv_not_worse_than_greedy_objective() {
        let ds = generate(
            SynthKind::GaussianMixture { components: 4, spread: 5.0 },
            240,
            6,
            11,
            "g",
        );
        let k = 12;
        let obj = |solver| {
            let mut be = NativeBackend::default();
            let order = crate::algo::batching::build_order(
                &ds.view(),
                k,
                crate::algo::Variant::Base,
                &mut be,
            );
            let labels = run_with_order(&ds, k, &order, solver, &mut be).unwrap();
            ClusterStats::compute(&ds, &labels, k).ssd_total()
        };
        let lap = obj(SolverKind::Lapjv);
        let gre = obj(SolverKind::Greedy);
        assert!(lap >= gre * 0.999, "lapjv={lap} greedy={gre}");
    }
}
