//! Objectives and diversity-balance metrics (paper §2, Fact 1, §5.3).
//!
//! Two equivalent objectives appear in the paper:
//! * the *pairwise* within-anticluster sum `W(C)` (problem definition,
//!   Table 11), and
//! * the *centroid-form* sum of squared object→centroid distances (the
//!   `ofv` reported in Tables 4 and 9).
//!
//! Fact 1 links them: `pairwise_k = |C_k| * ssd_k`. Both are provided,
//! plus the per-anticluster diversity statistics (sd, range) of Tables
//! 6/10 and the min/max size ratio of Table 11.

use crate::data::DataView;
use crate::runtime::simd::{accumulate, add_assign_row, decumulate, sq_dist_to_f64};

/// Per-anticluster statistics of a partition.
#[derive(Clone, Debug)]
pub struct ClusterStats {
    /// Objects per anticluster.
    pub sizes: Vec<usize>,
    /// Per-anticluster sum of squared distances to the anticluster
    /// centroid (the "diversity" of Tables 6/10).
    pub ssd: Vec<f64>,
    /// Between-group sum of squares `Σ_c m_c ||μ_c − μ||²` — the gap
    /// term of the total-sum identity `TSS = ssd_total + bgss`. A sum
    /// of non-negative terms, so `ssd_total + bgss >= ssd_total` holds
    /// exactly in floating point; [`crate::Partition::upper_bound`] and
    /// [`crate::Partition::gap`] are derived from it.
    pub bgss: f64,
}

impl ClusterStats {
    /// Compute centroids and per-cluster SSDs in two passes. Accepts a
    /// `&Dataset` or a zero-copy [`DataView`] (labels are per view row).
    pub fn compute<'a>(data: impl Into<DataView<'a>>, labels: &[u32], k: usize) -> Self {
        let ds: DataView<'a> = data.into();
        let n = ds.n();
        assert_eq!(labels.len(), n);
        let d = ds.d();
        let mut sums = vec![0f64; k * d];
        let mut sizes = vec![0usize; k];
        for i in 0..n {
            let c = labels[i] as usize;
            assert!(c < k, "label {c} out of range (k={k})");
            sizes[c] += 1;
            add_assign_row(&mut sums[c * d..(c + 1) * d], ds.row(i));
        }
        // Global centroid from the per-cluster sums (O(kd)) — feeds the
        // between-group term below without another pass over the rows.
        let mut global = vec![0f64; d];
        for c in 0..k {
            for (g, s) in global.iter_mut().zip(&sums[c * d..(c + 1) * d]) {
                *g += s;
            }
        }
        if n > 0 {
            for g in global.iter_mut() {
                *g /= n as f64;
            }
        }
        let mut centroids = sums;
        for c in 0..k {
            if sizes[c] > 0 {
                for v in centroids[c * d..(c + 1) * d].iter_mut() {
                    *v /= sizes[c] as f64;
                }
            }
        }
        let mut bgss = 0f64;
        for c in 0..k {
            if sizes[c] == 0 {
                continue;
            }
            let dev: f64 = centroids[c * d..(c + 1) * d]
                .iter()
                .zip(&global)
                .map(|(&m, &g)| (m - g) * (m - g))
                .sum();
            bgss += sizes[c] as f64 * dev;
        }
        let mut ssd = vec![0f64; k];
        for i in 0..n {
            let c = labels[i] as usize;
            ssd[c] += sq_dist_to_f64(ds.row(i), &centroids[c * d..(c + 1) * d]);
        }
        Self { sizes, ssd, bgss }
    }

    /// Centroid-form objective: total SSD to anticluster centroids (the
    /// `ofv` of Tables 4/9).
    pub fn ssd_total(&self) -> f64 {
        self.ssd.iter().sum()
    }

    /// Pairwise objective `W(C)` via Fact 1: `sum_k |C_k| * ssd_k`.
    pub fn pairwise_total(&self) -> f64 {
        self.sizes
            .iter()
            .zip(&self.ssd)
            .map(|(&n, &s)| n as f64 * s)
            .sum()
    }

    /// Total sum of squares around the global centroid, via the
    /// identity `TSS = ssd_total + bgss`. Partition-independent up to
    /// accumulation order; the partition-attached diversity upper
    /// bound ([`crate::Partition::upper_bound`]).
    pub fn total_ss(&self) -> f64 {
        self.ssd_total() + self.bgss
    }

    /// Standard deviation of per-anticluster diversity (Table 6).
    pub fn diversity_sd(&self) -> f64 {
        let k = self.ssd.len() as f64;
        if k < 2.0 {
            return 0.0;
        }
        let mean = self.ssd_total() / k;
        let var = self.ssd.iter().map(|&s| (s - mean) * (s - mean)).sum::<f64>() / k;
        var.sqrt()
    }

    /// Range (max - min) of per-anticluster diversity (Table 6).
    pub fn diversity_range(&self) -> f64 {
        let max = self.ssd.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let min = self.ssd.iter().copied().fold(f64::INFINITY, f64::min);
        max - min
    }

    /// Min/max anticluster size ratio in percent; sizes whose spread is
    /// at most one object count as 100 (Table 11 convention).
    pub fn min_max_ratio_pct(&self) -> f64 {
        let min = *self.sizes.iter().min().unwrap_or(&0);
        let max = *self.sizes.iter().max().unwrap_or(&0);
        if max == 0 {
            return 0.0;
        }
        if max - min <= 1 {
            return 100.0;
        }
        100.0 * min as f64 / max as f64
    }
}

/// Delta-maintained sufficient statistics of one anticluster: size `m`,
/// f64 feature sum `S`, and squared-norm sum `Q = sum ||x_i||^2`.
///
/// The within-cluster SSD follows from the standard identity
/// `ssd = Q - ||S||^2 / m`, so membership changes are **O(d)**:
/// [`ClusterDelta::add`] / [`ClusterDelta::remove`] update `(m, S, Q)`,
/// and [`ClusterDelta::add_gain`] / [`ClusterDelta::remove_loss`] price a
/// prospective change without applying it. This is the currency of the
/// online subsystem ([`crate::online`]): live handles maintain one
/// `ClusterDelta` per anticluster for decision-making, while exact
/// objective reads rebuild drifting clusters canonically via
/// [`ClusterDelta::from_rows`] (incremental f64 sums are mathematically
/// exact but not bit-stable under long add/remove sequences, so reads
/// that must match a from-scratch recompute re-accumulate in member
/// order).
#[derive(Clone, Debug)]
pub struct ClusterDelta {
    m: usize,
    s: Vec<f64>,
    q: f64,
}

#[inline]
fn norm2(s: &[f64]) -> f64 {
    s.iter().map(|&v| v * v).sum()
}

impl ClusterDelta {
    /// An empty cluster over `d` features.
    pub fn new(d: usize) -> Self {
        Self { m: 0, s: vec![0.0; d], q: 0.0 }
    }

    /// Canonical (from-scratch) accumulation: fold rows in iteration
    /// order. Two calls over the same rows in the same order produce
    /// bit-identical state — the anchor the online subsystem's exact
    /// reads are defined against.
    pub fn from_rows<'r>(d: usize, rows: impl IntoIterator<Item = &'r [f32]>) -> Self {
        let mut delta = Self::new(d);
        for row in rows {
            delta.add(row);
        }
        delta
    }

    /// Members currently folded in.
    pub fn len(&self) -> usize {
        self.m
    }

    /// Whether the cluster is empty.
    pub fn is_empty(&self) -> bool {
        self.m == 0
    }

    /// The maintained feature sum `S`.
    pub fn sum(&self) -> &[f64] {
        &self.s
    }

    /// The maintained squared-norm sum `Q`.
    pub fn sumsq(&self) -> f64 {
        self.q
    }

    /// Fold a member in — O(d), via the objective-tier
    /// [`accumulate`] kernel (f64, index order in every kernel mode).
    pub fn add(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.s.len());
        self.q += accumulate(&mut self.s, row);
        self.m += 1;
    }

    /// Fold a member out — O(d). The row must currently be a member.
    pub fn remove(&mut self, row: &[f32]) {
        debug_assert!(self.m > 0, "remove from an empty ClusterDelta");
        debug_assert_eq!(row.len(), self.s.len());
        self.q -= decumulate(&mut self.s, row);
        self.m -= 1;
    }

    /// Within-cluster SSD to the centroid: `Q - ||S||^2 / m` (0 for an
    /// empty cluster).
    pub fn ssd(&self) -> f64 {
        if self.m == 0 {
            return 0.0;
        }
        self.q - norm2(&self.s) / self.m as f64
    }

    /// Exact SSD increase from adding `row`, without applying it — O(d).
    /// Equals `m/(m+1) * ||row - centroid||^2`; 0 for an empty cluster.
    pub fn add_gain(&self, row: &[f32]) -> f64 {
        if self.m == 0 {
            return 0.0;
        }
        let (mut sx, mut xx) = (0f64, 0f64);
        for (&acc, &v) in self.s.iter().zip(row) {
            let v = v as f64;
            sx += acc * v;
            xx += v * v;
        }
        let ss = norm2(&self.s);
        let m = self.m as f64;
        (self.q + xx - (ss + 2.0 * sx + xx) / (m + 1.0)) - (self.q - ss / m)
    }

    /// Exact SSD decrease from removing `row` (a current member),
    /// without applying it — O(d). For a singleton this is the whole
    /// remaining SSD.
    pub fn remove_loss(&self, row: &[f32]) -> f64 {
        debug_assert!(self.m > 0);
        if self.m == 1 {
            return self.ssd();
        }
        let (mut sx, mut xx) = (0f64, 0f64);
        for (&acc, &v) in self.s.iter().zip(row) {
            let v = v as f64;
            sx += acc * v;
            xx += v * v;
        }
        let ss = norm2(&self.s);
        let m = self.m as f64;
        (self.q - ss / m) - (self.q - xx - (ss - 2.0 * sx + xx) / (m - 1.0))
    }
}

/// Dispersion of a partition: the minimum pairwise distance between two
/// objects in the same anticluster (the second criterion of the
/// bicriterion anticlustering literature — Brusco et al. 2020, Papenberg
/// et al. 2025a — which the paper reviews in §3). O(sum |C_k|^2 d);
/// intended for evaluation, not the hot path. Returns `f64::INFINITY`
/// when every anticluster is a singleton.
pub fn dispersion<'a>(data: impl Into<DataView<'a>>, labels: &[u32], k: usize) -> f64 {
    let ds: DataView<'a> = data.into();
    let mut min = f64::INFINITY;
    for c in 0..k as u32 {
        let members: Vec<usize> = crate::metrics::members_of(labels, c).collect();
        for (a, &i) in members.iter().enumerate() {
            for &j in &members[a + 1..] {
                let d = ds.dist2(i, j);
                if d < min {
                    min = d;
                }
            }
        }
    }
    min
}

/// Brute-force pairwise within-cluster sum — O(sum |C_k|^2 d), the
/// independent ground truth used to validate Fact 1 in tests.
pub fn pairwise_within_brute<'a>(data: impl Into<DataView<'a>>, labels: &[u32], k: usize) -> f64 {
    let ds: DataView<'a> = data.into();
    let mut total = 0f64;
    for c in 0..k as u32 {
        let members: Vec<usize> = crate::metrics::members_of(labels, c).collect();
        for (a, &i) in members.iter().enumerate() {
            for &j in &members[a + 1..] {
                total += ds.dist2(i, j);
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};
    use crate::rng::Pcg32;

    #[test]
    fn fact1_pairwise_equals_centroid_form() {
        let ds = generate(SynthKind::Uniform, 60, 4, 21, "u");
        let mut rng = Pcg32::new(2);
        let k = 5;
        let labels: Vec<u32> = (0..ds.n).map(|_| rng.gen_below(k as u32)).collect();
        let stats = ClusterStats::compute(&ds, &labels, k);
        let brute = pairwise_within_brute(&ds, &labels, k);
        let fact1 = stats.pairwise_total();
        assert!(
            (brute - fact1).abs() < 1e-6 * brute.max(1.0),
            "brute={brute} fact1={fact1}"
        );
    }

    #[test]
    fn empty_cluster_contributes_zero() {
        let ds = generate(SynthKind::Uniform, 10, 2, 22, "u");
        let labels = vec![0u32; 10]; // cluster 1 empty
        let stats = ClusterStats::compute(&ds, &labels, 2);
        assert_eq!(stats.sizes, vec![10, 0]);
        assert_eq!(stats.ssd[1], 0.0);
    }

    #[test]
    fn diversity_stats() {
        let stats = ClusterStats { sizes: vec![2, 2, 2], ssd: vec![1.0, 3.0, 5.0], bgss: 0.0 };
        assert!((stats.diversity_sd() - (8.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(stats.diversity_range(), 4.0);
        assert_eq!(stats.ssd_total(), 9.0);
        assert_eq!(stats.pairwise_total(), 18.0);
    }

    #[test]
    fn ratio_convention_matches_table11() {
        // Spread <= 1 counts as perfectly balanced.
        let s = ClusterStats { sizes: vec![3, 4, 4], ssd: vec![0.0; 3], bgss: 0.0 };
        assert_eq!(s.min_max_ratio_pct(), 100.0);
        let s = ClusterStats { sizes: vec![2, 4], ssd: vec![0.0; 2], bgss: 0.0 };
        assert_eq!(s.min_max_ratio_pct(), 50.0);
    }

    #[test]
    fn single_cluster_sd_zero() {
        let s = ClusterStats { sizes: vec![5], ssd: vec![2.0], bgss: 0.0 };
        assert_eq!(s.diversity_sd(), 0.0);
    }

    #[test]
    fn dispersion_is_min_within_pair() {
        use crate::data::Dataset;
        // Clusters {0,1} at distance 1 and {2,3} at distance 4.
        let ds = Dataset::from_rows(
            "disp",
            &[vec![0.0], vec![1.0], vec![10.0], vec![12.0]],
        )
        .unwrap();
        let labels = vec![0u32, 0, 1, 1];
        assert_eq!(dispersion(&ds, &labels, 2), 1.0);
        // Cross pairing raises dispersion to 100 / 121 -> min 100.
        let labels = vec![0u32, 1, 0, 1];
        assert_eq!(dispersion(&ds, &labels, 2), 100.0);
    }

    #[test]
    fn cluster_delta_matches_cluster_stats() {
        let ds = generate(SynthKind::Uniform, 40, 3, 25, "u");
        let mut rng = Pcg32::new(5);
        let k = 4usize;
        let labels: Vec<u32> = (0..ds.n).map(|_| rng.gen_below(k as u32)).collect();
        let stats = ClusterStats::compute(&ds, &labels, k);
        for c in 0..k {
            let delta = ClusterDelta::from_rows(
                ds.d,
                crate::metrics::members_of(&labels, c as u32).map(|i| ds.row(i)),
            );
            assert_eq!(delta.len(), stats.sizes[c]);
            assert!(
                (delta.ssd() - stats.ssd[c]).abs() <= 1e-8 * stats.ssd[c].max(1.0),
                "cluster {c}: {} vs {}",
                delta.ssd(),
                stats.ssd[c]
            );
        }
    }

    #[test]
    fn cluster_delta_add_remove_round_trip() {
        let ds = generate(SynthKind::Uniform, 12, 4, 26, "u");
        let mut delta = ClusterDelta::new(ds.d);
        for i in 0..8 {
            delta.add(ds.row(i));
        }
        let before = delta.ssd();
        // Priced gain must equal the applied difference.
        let gain = delta.add_gain(ds.row(9));
        delta.add(ds.row(9));
        let applied = delta.ssd() - before;
        assert!((gain - applied).abs() < 1e-9 * (1.0 + applied.abs()), "{gain} vs {applied}");
        // ... and remove_loss must price the inverse move exactly.
        let loss = delta.remove_loss(ds.row(9));
        assert!((loss - applied).abs() < 1e-9 * (1.0 + loss.abs()), "loss {loss} vs gain {applied}");
        delta.remove(ds.row(9));
        assert!((delta.ssd() - before).abs() < 1e-9 * (1.0 + before.abs()));
        assert_eq!(delta.len(), 8);
    }

    #[test]
    fn cluster_delta_edge_cases() {
        let delta = ClusterDelta::new(3);
        assert!(delta.is_empty());
        assert_eq!(delta.ssd(), 0.0);
        assert_eq!(delta.add_gain(&[1.0, 2.0, 3.0]), 0.0);
        let mut single = ClusterDelta::new(2);
        single.add(&[1.0, 2.0]);
        // A singleton has zero SSD and removing it loses exactly that.
        assert!(single.ssd().abs() < 1e-12);
        assert_eq!(single.remove_loss(&[1.0, 2.0]), single.ssd());
        // Adding a second member prices m/(m+1) * dist^2 = 0.5 * 8.
        let gain = single.add_gain(&[3.0, 4.0]);
        assert!((gain - 4.0).abs() < 1e-9, "{gain}");
    }

    #[test]
    fn dispersion_singletons_infinite() {
        let ds = generate(SynthKind::Uniform, 4, 2, 23, "u");
        let labels = vec![0u32, 1, 2, 3];
        assert_eq!(dispersion(&ds, &labels, 4), f64::INFINITY);
    }

    #[test]
    fn dispersion_evaluates_on_aba_partitions() {
        // Diversity-optimal partitions need not have good dispersion
        // (that is exactly why the bicriterion literature exists — §3 of
        // the paper); here we only check the metric is well-defined and
        // strictly positive on non-singleton ABA anticlusters.
        let ds = generate(
            SynthKind::GaussianMixture { components: 4, spread: 6.0 },
            200,
            3,
            24,
            "g",
        );
        let k = 50;
        use crate::solver::{Aba, Anticlusterer};
        let aba = Aba::new().unwrap().partition(&ds, k).unwrap().labels;
        let da = dispersion(&ds, &aba, k);
        assert!(da.is_finite() && da > 0.0, "dispersion {da}");
    }
}
