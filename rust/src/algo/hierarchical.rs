//! Hierarchical decomposition (paper §4.4).
//!
//! For large K, solving K×K assignment problems is the bottleneck
//! (`O(N K^2)` total). The decomposition first builds `K_1` anticlusters,
//! then splits each into `K_2`, and so on: total work
//! `O(N * sum K_l^2)`, minimized by balanced factors (Lemma 1:
//! `K_l = K^(1/L)`), giving `O(N L K^(2/L))`.
//!
//! Proposition 1: because every level splits into parts whose sizes
//! differ by at most one, the final anticluster sizes also differ by at
//! most one — verified by property tests.
//!
//! Groups are passed down the levels as zero-copy index views
//! ([`DataView::select`]): no feature row is ever gathered per level —
//! the old `Dataset::subset` copy (one full `n x d` matrix per level)
//! is gone, and the only staging left is the assignment loop's bounded
//! per-batch `Scratch.xb` gather. That is what makes deep specs (e.g.
//! `--hier 50x40x25`) on very large datasets memory-feasible.
//!
//! Subproblems at each level are independent. With a non-serial
//! [`Parallelism`] they fan out as tasks on the session's worker pool
//! (the same pool that chunk-parallelizes flat cost matrices —
//! [`crate::runtime::pool`]); each pool thread keeps a thread-local
//! native backend + scratch that persist across levels and calls, and
//! the index views mean worker tasks allocate no per-group sub-dataset.
//! Fanned-out subproblems run their inner loops serially (the pool
//! already owns every core), while levels with a single group — always
//! including the root level — keep the caller's backend and inner
//! parallelism. Task *i* always solves group *i*, so with the native
//! backend serial and parallel decompositions produce bit-identical
//! labels. With the XLA backend the fanned-out levels compute costs
//! through the native kernels instead of PJRT (clients are not shared
//! across threads), so parallel results there match serial ones only up
//! to the usual XLA/native numeric tolerance.

use super::{core, AbaConfig};
use crate::data::DataView;
use crate::error::{AbaError, AbaResult};
use crate::runtime::{make_backend, CostBackend, NativeBackend, Parallelism};
use std::cell::RefCell;
use std::sync::Mutex;

/// Derive a balanced decomposition for (n, k), mirroring the paper's
/// Table 5/7 policy: single level for small K; otherwise the fewest
/// levels whose balanced factors stay <= 200 (the assignment-size sweet
/// spot measured in Figure 7). Returns `[k]` when K is small or has no
/// usable factorization (e.g. large primes).
pub fn auto_spec(_n: usize, k: usize) -> Vec<usize> {
    if k <= 128 {
        return vec![k];
    }
    let mut l = 2usize;
    while (k as f64).powf(1.0 / l as f64) > 200.0 && l < 8 {
        l += 1;
    }
    balanced_factorization(k, l).unwrap_or_else(|| vec![k])
}

/// Factor `k` into `l` integer factors (each >= 2 when possible), chosen
/// greedily closest to `k^(1/l)`. Returns `None` if no nontrivial
/// factorization exists at this depth.
pub fn balanced_factorization(k: usize, l: usize) -> Option<Vec<usize>> {
    if l <= 1 {
        return Some(vec![k]);
    }
    let ideal = (k as f64).powf(1.0 / l as f64);
    // Candidate divisors of k, pick the one closest to ideal (>= 2).
    let mut best: Option<usize> = None;
    let mut best_gap = f64::INFINITY;
    let mut d = 2usize;
    while d * d <= k {
        if k % d == 0 {
            for cand in [d, k / d] {
                if (2..k).contains(&cand) {
                    let gap = (cand as f64 - ideal).abs();
                    if gap < best_gap {
                        best_gap = gap;
                        best = Some(cand);
                    }
                }
            }
        }
        d += 1;
    }
    let first = best?;
    let mut rest = balanced_factorization(k / first, l - 1)?;
    let mut out = vec![first];
    out.append(&mut rest);
    Some(out)
}

/// Run ABA with an explicit multi-level decomposition. The final number
/// of anticlusters is `prod(spec)`; labels are in `0..prod(spec)`.
/// Accepts a `&Dataset` or a zero-copy [`DataView`]. Builds one backend
/// and throwaway scratch for the whole run; sessions that already own
/// both use [`run_hierarchical_with_backend`] instead.
pub fn run_hierarchical<'a>(
    data: impl Into<DataView<'a>>,
    spec: &[usize],
    cfg: &AbaConfig,
) -> AbaResult<Vec<u32>> {
    let mut backend = make_backend(cfg.backend)?;
    run_hierarchical_with_backend(
        &data.into(),
        spec,
        cfg,
        backend.as_mut(),
        &mut core::Scratch::default(),
    )
}

thread_local! {
    /// Per-thread (backend, scratch) for pool fan-out tasks. Living in a
    /// thread-local rather than per task, they persist across levels and
    /// `partition` calls for as long as the pool threads do.
    static WORKER_STATE: RefCell<(NativeBackend, core::Scratch)> =
        RefCell::new(Default::default());
}

/// Split one group into `kl` balanced parts with a flat ABA run over a
/// zero-copy index view of the group (no feature-row gather), mapping
/// local labels back to global object indices.
fn split_group(
    view: &DataView<'_>,
    group: &[usize],
    kl: usize,
    cfg: &AbaConfig,
    backend: &mut dyn CostBackend,
    scratch: &mut core::Scratch,
) -> AbaResult<Vec<Vec<usize>>> {
    if kl == 1 {
        return Ok(vec![group.to_vec()]);
    }
    let sub = view.select(group);
    let (labels, _, _) = super::flat_with_scratch(&sub, kl, cfg, backend, scratch)?;
    let mut parts: Vec<Vec<usize>> = vec![Vec::new(); kl];
    for (local, &global) in group.iter().enumerate() {
        parts[labels[local] as usize].push(global);
    }
    Ok(parts)
}

/// As [`run_hierarchical`] against a caller-supplied backend and
/// scratch. Single-group levels (always including the root) share
/// `backend` and `scratch`, so an XLA backend compiles its executables
/// once for the whole decomposition and the worker pool persists across
/// session calls; fanned-out levels run on the pool with thread-local
/// native backends (PJRT clients are not shared across threads).
pub fn run_hierarchical_with_backend(
    view: &DataView<'_>,
    spec: &[usize],
    cfg: &AbaConfig,
    backend: &mut dyn CostBackend,
    scratch: &mut core::Scratch,
) -> AbaResult<Vec<u32>> {
    if spec.is_empty() {
        return Err(AbaError::BadHierSpec("empty hierarchy spec".into()));
    }
    let n = view.n();
    let k_total: usize = spec.iter().product();
    if k_total == 0 || k_total > n {
        return Err(AbaError::BadHierSpec(format!(
            "product {k_total} of {spec:?} is invalid for n={n}"
        )));
    }
    // Flat config for the per-group subproblems (no recursion). The
    // fanned-out variant additionally forces serial inner loops: the
    // pool already owns every core, so nested parallel cost matrices
    // would only contend with the fan-out itself.
    let flat_cfg = AbaConfig { hier: None, auto_hier: false, ..cfg.clone() };
    let fan_cfg = AbaConfig { parallelism: Parallelism::Serial, ..flat_cfg.clone() };
    let pool = scratch.pool_for(cfg.parallelism);

    // Current groups of object indices; starts with everything. Groups
    // travel down the levels as index views over `view` — the feature
    // matrix is never gathered.
    let mut groups: Vec<Vec<usize>> = vec![(0..n).collect()];
    for &kl in spec.iter() {
        let results: Vec<Vec<Vec<usize>>> = match &pool {
            Some(pool) if groups.len() > 1 => {
                let slots: Vec<Mutex<Option<AbaResult<Vec<Vec<usize>>>>>> =
                    groups.iter().map(|_| Mutex::new(None)).collect();
                pool.run(groups.len(), &|gi| {
                    let res = WORKER_STATE.with(|state| {
                        let mut guard = state.borrow_mut();
                        let (be, sc) = &mut *guard;
                        split_group(view, &groups[gi], kl, &fan_cfg, be, sc)
                    });
                    *slots[gi].lock().unwrap() = Some(res);
                });
                let mut out = Vec::with_capacity(groups.len());
                for s in slots {
                    out.push(s.into_inner().unwrap().expect("pool task ran")?);
                }
                out
            }
            _ => {
                let mut out = Vec::with_capacity(groups.len());
                for g in &groups {
                    out.push(split_group(view, g, kl, &flat_cfg, backend, scratch)?);
                }
                out
            }
        };

        groups = results.into_iter().flatten().collect();
    }

    debug_assert_eq!(groups.len(), k_total);
    let mut labels = vec![0u32; n];
    for (gi, group) in groups.iter().enumerate() {
        for &obj in group {
            labels[obj] = gi as u32;
        }
    }
    Ok(labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::objective::ClusterStats;
    use crate::data::synth::{generate, SynthKind};

    #[test]
    fn factorization_products_hold() {
        for &(k, l) in &[(5_000usize, 2usize), (1_024, 2), (1_024, 3), (640_000, 3), (72, 2)] {
            let f = balanced_factorization(k, l).unwrap();
            assert_eq!(f.iter().product::<usize>(), k, "{f:?}");
            assert_eq!(f.len(), l, "{f:?}");
        }
        // Primes can't be factored at depth 2.
        assert!(balanced_factorization(257, 2).is_none());
    }

    #[test]
    fn auto_spec_small_k_single_level() {
        assert_eq!(auto_spec(10_000, 50), vec![50]);
        assert_eq!(auto_spec(10_000, 128), vec![128]);
    }

    #[test]
    fn auto_spec_large_k_balanced() {
        let spec = auto_spec(1_000_000, 40_000);
        assert!(spec.len() >= 2);
        assert_eq!(spec.iter().product::<usize>(), 40_000);
        assert!(spec.iter().all(|&f| f <= 210), "{spec:?}");
    }

    #[test]
    fn proposition1_sizes_differ_by_at_most_one() {
        // N=1000, K=12 via (3 x 4): N mod K = 4 extras.
        let ds = generate(SynthKind::Uniform, 1_000, 3, 30, "u");
        let cfg = AbaConfig::default();
        let labels = run_hierarchical(&ds, &[3, 4], &cfg).unwrap();
        let stats = ClusterStats::compute(&ds, &labels, 12);
        let (min, max) = (
            *stats.sizes.iter().min().unwrap(),
            *stats.sizes.iter().max().unwrap(),
        );
        assert!(max - min <= 1, "{:?}", stats.sizes);
        assert_eq!(stats.sizes.iter().sum::<usize>(), 1_000);
    }

    #[test]
    fn hierarchical_close_to_flat_quality() {
        let ds = generate(
            SynthKind::GaussianMixture { components: 6, spread: 4.0 },
            1_200,
            6,
            31,
            "g",
        );
        use crate::solver::{Aba, Anticlusterer};
        let cfg = AbaConfig { auto_hier: false, ..AbaConfig::default() };
        let flat = Aba::from_config(cfg.clone()).unwrap().partition(&ds, 24).unwrap().labels;
        let hier = run_hierarchical(&ds, &[4, 6], &cfg).unwrap();
        let of = ClusterStats::compute(&ds, &flat, 24).ssd_total();
        let oh = ClusterStats::compute(&ds, &hier, 24).ssd_total();
        // Figure 7: hierarchical loses well under 1%.
        assert!(oh > 0.98 * of, "flat={of} hier={oh}");
    }

    #[test]
    fn parallel_matches_serial() {
        let ds = generate(SynthKind::Uniform, 800, 4, 32, "u");
        let mut cfg = AbaConfig::default();
        let serial = run_hierarchical(&ds, &[4, 5], &cfg).unwrap();
        for par in [Parallelism::Threads(2), Parallelism::Threads(4), Parallelism::Auto] {
            cfg.parallelism = par;
            let parallel = run_hierarchical(&ds, &[4, 5], &cfg).unwrap();
            assert_eq!(serial, parallel, "{par:?}");
        }
    }

    #[test]
    fn rejects_oversized_spec() {
        let ds = generate(SynthKind::Uniform, 10, 2, 33, "u");
        assert!(run_hierarchical(&ds, &[4, 5], &AbaConfig::default()).is_err());
    }
}
