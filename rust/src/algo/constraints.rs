//! Must-link / cannot-link constrained anticlustering.
//!
//! The `anticlust` package the paper benchmarks against supports
//! pairwise constraints in its exchange heuristic; this module is the
//! ABA-native adaptation (an "extension" feature beyond the paper's core
//! algorithm):
//!
//! * **must-link** — groups that must share an anticluster are contracted
//!   into weighted super-objects (weight = group size, features = group
//!   mean). One super-object still goes to one anticluster per batch, so
//!   anticluster *weights* can drift by up to the largest group size; a
//!   soft balance penalty keeps the drift tight (and the result is
//!   exactly balanced whenever all groups have equal size).
//! * **cannot-link** — enforced exactly, via the same cost-masking
//!   mechanism as the §4.3 categorical bounds: an anticluster already
//!   containing a conflicting object gets a large negative cost.

use super::batching;
use crate::assignment::{self, Lapjv, SolverKind};
use crate::data::{DataView, Dataset};
use crate::error::{AbaError, AbaResult};
use crate::runtime::{make_backend, CostBackend};

/// Pairwise constraints over object indices.
#[derive(Clone, Debug, Default)]
pub struct Constraints {
    /// Each inner vec is a group that must end up in one anticluster.
    pub must_link: Vec<Vec<usize>>,
    /// Pairs that must end up in different anticlusters.
    pub cannot_link: Vec<(usize, usize)>,
}

const MASK_COST: f32 = -1e30;

/// Run ABA under pairwise constraints. Returns a label per (original)
/// object.
///
/// # Deprecation path
///
/// This shim survives exactly one release: deprecated in 0.2.0, deleted
/// in 0.3.0. It rebuilds the backend on every call and runs serially;
/// the session form —
/// `Aba::builder().constraints(cons).build()?.partition(ds, k)` — keeps
/// the backend (and any worker pool) warm across calls and honors the
/// builder's `parallelism` setting.
#[deprecated(
    since = "0.2.0",
    note = "superseded by sessions \
            (`Aba::builder().constraints(cons).build()?.partition(ds, k)`); \
            will be removed in 0.3.0"
)]
pub fn run_aba_constrained(
    ds: &Dataset,
    k: usize,
    cfg: &super::AbaConfig,
    cons: &Constraints,
) -> AbaResult<Vec<u32>> {
    let mut backend = make_backend(cfg.backend)?;
    constrained_with_backend(&ds.view(), k, cfg, cons, backend.as_mut())
}

/// The constrained Algorithm-1 loop against a caller-supplied backend
/// (the [`crate::solver::Aba`] session path). Honors `cfg.solver`,
/// `cfg.backend` (via the supplied backend), and
/// `cfg.strict_divisibility`; the variant / hierarchy settings do not
/// apply to the constrained loop, which has its own super-object
/// ordering. Validates exactly once (callers do not pre-validate).
pub fn constrained_with_backend(
    ds: &DataView<'_>,
    k: usize,
    cfg: &super::AbaConfig,
    cons: &Constraints,
    backend: &mut dyn CostBackend,
) -> AbaResult<Vec<u32>> {
    let n = ds.n();
    super::validate(n, k, cfg.strict_divisibility)?;
    // --- Union-find over must-link groups -------------------------------
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(parent: &mut Vec<usize>, mut x: usize) -> usize {
        while parent[x] != x {
            parent[x] = parent[parent[x]];
            x = parent[x];
        }
        x
    }
    for group in &cons.must_link {
        for &i in group {
            if i >= n {
                return Err(AbaError::InvalidInput(format!(
                    "must-link index {i} out of range (n={n})"
                )));
            }
        }
        for w in group.windows(2) {
            let (a, b) = (find(&mut parent, w[0]), find(&mut parent, w[1]));
            if a != b {
                parent[a] = b;
            }
        }
    }
    // Super-object ids.
    let mut super_of = vec![usize::MAX; n];
    let mut supers: Vec<Vec<usize>> = Vec::new();
    for i in 0..n {
        let root = find(&mut parent, i);
        if super_of[root] == usize::MAX {
            super_of[root] = supers.len();
            supers.push(Vec::new());
        }
        super_of[i] = super_of[root];
        supers[super_of[root]].push(i);
    }
    let ns = supers.len();
    if ns < k {
        return Err(AbaError::ConstraintInfeasible(format!(
            "must-link contraction leaves {ns} groups < k={k}"
        )));
    }
    let max_group = supers.iter().map(|g| g.len()).max().unwrap_or(1);

    // Cannot-link at super-object granularity; validate consistency.
    let mut conflicts: Vec<(usize, usize)> = Vec::new();
    for &(a, b) in &cons.cannot_link {
        if a >= n || b >= n {
            return Err(AbaError::InvalidInput(format!(
                "cannot-link index out of range: ({a},{b}) for n={n}"
            )));
        }
        let (sa, sb) = (super_of[a], super_of[b]);
        if sa == sb {
            return Err(AbaError::ConstraintInfeasible(format!(
                "objects {a} and {b} are must-linked but also cannot-linked"
            )));
        }
        conflicts.push((sa.min(sb), sa.max(sb)));
    }
    conflicts.sort_unstable();
    conflicts.dedup();

    // --- Build the super-object matrix ----------------------------------
    // Genuinely new data (group means), so it is owned; everything
    // downstream reads it through a borrowed view like any other input.
    let d = ds.d();
    let mut sx = vec![0f32; ns * d];
    let mut weight = vec![0usize; ns];
    for (s, members) in supers.iter().enumerate() {
        weight[s] = members.len();
        for &i in members {
            for (dst, &v) in sx[s * d..(s + 1) * d].iter_mut().zip(ds.row(i)) {
                *dst += v;
            }
        }
        let wl = members.len() as f32;
        for v in sx[s * d..(s + 1) * d].iter_mut() {
            *v /= wl;
        }
    }
    let sds = DataView::over("super", &sx, ns, d);

    // Conflict adjacency for masking.
    let mut conflict_adj: Vec<Vec<usize>> = vec![Vec::new(); ns];
    for &(a, b) in &conflicts {
        conflict_adj[a].push(b);
        conflict_adj[b].push(a);
    }

    // --- Modified Algorithm-1 loop over super-objects --------------------
    let order = batching::sorted_by_centroid_distance(&sds, backend);
    let mut labels_s = vec![u32::MAX; ns];
    let mut centroids = vec![0f64; k * d];
    let mut counts = vec![0usize; k]; // super-object counts (centroid counter)
    let mut weights = vec![0usize; k]; // original-object weights (balance)
    let mut centroids_f32 = vec![0f32; k * d];

    // Soft balance penalty: strong enough to dominate distance terms.
    let mu = sds.global_centroid();
    let mut dists = Vec::new();
    backend.centroid_distances(&sx, ns, d, &mu, &mut dists);
    let scale = dists.iter().copied().fold(0f64, f64::max).max(1.0) as f32;
    let penalty = 16.0 * scale;

    let batches = batching::batch_ranges(ns, k);
    let (lo, hi) = batches[0];
    for (slot, &s) in order[lo..hi].iter().enumerate() {
        labels_s[s] = slot as u32;
        counts[slot] = 1;
        weights[slot] = weight[s];
        for (dst, &v) in centroids[slot * d..(slot + 1) * d].iter_mut().zip(sds.row(s)) {
            *dst = v as f64;
        }
    }

    let mut xb = vec![0f32; k * d];
    let mut cost: Vec<f32> = Vec::with_capacity(k * k);
    let mut lapjv = Lapjv::new();
    for &(lo, hi) in &batches[1..] {
        let m = hi - lo;
        let batch = &order[lo..hi];
        xb.resize(m * d, 0.0);
        for (j, &s) in batch.iter().enumerate() {
            xb[j * d..(j + 1) * d].copy_from_slice(sds.row(s));
        }
        for (dst, &src) in centroids_f32.iter_mut().zip(centroids.iter()) {
            *dst = src as f32;
        }
        backend.batch_costs(&xb, m, d, &centroids_f32, k, &mut cost);
        // Weight-balance penalty + cannot-link masking.
        let min_w = *weights.iter().min().unwrap();
        for (j, &s) in batch.iter().enumerate() {
            for kk in 0..k {
                let over = (weights[kk] - min_w) as f32;
                cost[j * k + kk] -= penalty * over;
                if conflict_adj[s]
                    .iter()
                    .any(|&other| labels_s[other] == kk as u32)
                {
                    cost[j * k + kk] = MASK_COST;
                }
            }
        }
        let assign = match cfg.solver {
            SolverKind::Lapjv => lapjv.solve(&cost, m, k, true),
            other => assignment::solve_max(other, &cost, m, k),
        };
        for (j, &s) in batch.iter().enumerate() {
            let kk = assign[j];
            labels_s[s] = kk as u32;
            counts[kk] += 1;
            weights[kk] += weight[s];
            let counter = counts[kk] as f64;
            for (m_d, &x_d) in centroids[kk * d..(kk + 1) * d].iter_mut().zip(sds.row(s)) {
                *m_d += (x_d as f64 - *m_d) / counter;
            }
        }
    }

    // Expand to original objects.
    let mut labels = vec![0u32; n];
    for (s, members) in supers.iter().enumerate() {
        for &i in members {
            labels[i] = labels_s[s];
        }
    }
    // Post-condition check: cannot-link satisfied (must-link by
    // construction). Unsatisfiable instances surface here.
    for &(a, b) in &cons.cannot_link {
        if labels[a] == labels[b] {
            return Err(AbaError::ConstraintInfeasible(format!(
                "cannot-link ({a},{b}) unsatisfiable under k={k} (max group {max_group})"
            )));
        }
    }
    Ok(labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::ClusterStats;
    use crate::data::synth::{generate, SynthKind};
    use crate::solver::{Aba, Anticlusterer};

    fn ds100() -> Dataset {
        generate(SynthKind::Uniform, 100, 4, 61, "cons")
    }

    /// Session-API entry used by all constraint tests.
    fn constrained(ds: &Dataset, k: usize, cons: &Constraints) -> AbaResult<Vec<u32>> {
        let mut session = Aba::builder().constraints(cons.clone()).build()?;
        Ok(session.partition(ds, k)?.labels)
    }

    #[test]
    fn unconstrained_matches_plain_balance() {
        let ds = ds100();
        let labels = constrained(&ds, 5, &Constraints::default()).unwrap();
        let stats = ClusterStats::compute(&ds, &labels, 5);
        assert!(stats.sizes.iter().all(|&s| s == 20), "{:?}", stats.sizes);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shim_matches_session_path() {
        let ds = ds100();
        let cons = Constraints {
            must_link: vec![vec![1, 2]],
            cannot_link: vec![(3, 4)],
        };
        let shim =
            run_aba_constrained(&ds, 4, &crate::algo::AbaConfig::default(), &cons).unwrap();
        let session = constrained(&ds, 4, &cons).unwrap();
        assert_eq!(shim, session);
    }

    #[test]
    fn must_link_groups_stay_together() {
        let ds = ds100();
        let cons = Constraints {
            must_link: vec![vec![0, 1, 2], vec![10, 50], vec![3, 4]],
            cannot_link: vec![],
        };
        let labels = constrained(&ds, 4, &cons).unwrap();
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[1], labels[2]);
        assert_eq!(labels[10], labels[50]);
        assert_eq!(labels[3], labels[4]);
        // Balance within the largest group size.
        let stats = ClusterStats::compute(&ds, &labels, 4);
        let (min, max) = (
            *stats.sizes.iter().min().unwrap(),
            *stats.sizes.iter().max().unwrap(),
        );
        assert!(max - min <= 3, "{:?}", stats.sizes);
    }

    #[test]
    fn transitive_must_link_via_overlapping_groups() {
        let ds = ds100();
        let cons = Constraints {
            must_link: vec![vec![0, 1], vec![1, 2], vec![2, 3]],
            cannot_link: vec![],
        };
        let labels = constrained(&ds, 5, &cons).unwrap();
        assert!(labels[0] == labels[1] && labels[1] == labels[2] && labels[2] == labels[3]);
    }

    #[test]
    fn cannot_link_pairs_separated() {
        let ds = ds100();
        let cons = Constraints {
            must_link: vec![],
            cannot_link: vec![(0, 1), (2, 3), (4, 5), (0, 99)],
        };
        let labels = constrained(&ds, 3, &cons).unwrap();
        for &(a, b) in &cons.cannot_link {
            assert_ne!(labels[a], labels[b], "({a},{b})");
        }
        let stats = ClusterStats::compute(&ds, &labels, 3);
        let (min, max) = (
            *stats.sizes.iter().min().unwrap(),
            *stats.sizes.iter().max().unwrap(),
        );
        assert!(max - min <= 1);
    }

    #[test]
    fn combined_constraints() {
        let ds = ds100();
        let cons = Constraints {
            must_link: vec![vec![0, 1], vec![2, 3]],
            cannot_link: vec![(0, 2), (1, 50)],
        };
        let labels = constrained(&ds, 4, &cons).unwrap();
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[2], labels[3]);
        assert_ne!(labels[0], labels[2]);
        assert_ne!(labels[1], labels[50]);
    }

    #[test]
    fn conflicting_constraints_rejected() {
        let ds = ds100();
        let cons = Constraints {
            must_link: vec![vec![0, 1]],
            cannot_link: vec![(0, 1)],
        };
        let err = constrained(&ds, 4, &cons).unwrap_err();
        assert!(matches!(err, AbaError::ConstraintInfeasible(_)), "{err}");
    }

    #[test]
    fn too_much_contraction_rejected() {
        let ds = generate(SynthKind::Uniform, 6, 2, 62, "tiny");
        let cons = Constraints {
            must_link: vec![vec![0, 1, 2], vec![3, 4, 5]],
            cannot_link: vec![],
        };
        // 2 super-objects < k = 3.
        let err = constrained(&ds, 3, &cons).unwrap_err();
        assert!(matches!(err, AbaError::ConstraintInfeasible(_)), "{err}");
    }

    #[test]
    fn out_of_range_indices_rejected() {
        let ds = ds100();
        let bad_ml = Constraints { must_link: vec![vec![0, 200]], cannot_link: vec![] };
        assert!(constrained(&ds, 3, &bad_ml).is_err());
        let bad_cl = Constraints { must_link: vec![], cannot_link: vec![(0, 200)] };
        assert!(constrained(&ds, 3, &bad_cl).is_err());
    }

    #[test]
    fn quality_close_to_unconstrained_with_few_constraints() {
        let ds = generate(
            SynthKind::GaussianMixture { components: 4, spread: 4.0 },
            200,
            4,
            63,
            "q",
        );
        let k = 10;
        let plain = Aba::new().unwrap().partition(&ds, k).unwrap().labels;
        let cons = Constraints {
            must_link: vec![vec![0, 10]],
            cannot_link: vec![(5, 6)],
        };
        let constrained = constrained(&ds, k, &cons).unwrap();
        let po = ClusterStats::compute(&ds, &plain, k).ssd_total();
        let co = ClusterStats::compute(&ds, &constrained, k).ssd_total();
        assert!(co >= 0.95 * po, "plain {po} vs constrained {co}");
    }
}
