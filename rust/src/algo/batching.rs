//! Batch-order construction (paper §4.1–§4.3).
//!
//! Algorithm 1 processes objects in a specific global order, cut into
//! batches of size K. The order is what distinguishes the variants:
//!
//! * **Base (§4.1)** — indices sorted by *decreasing* squared distance to
//!   the global centroid (`N↓`).
//! * **Small anticlusters (§4.2)** — `N↓` interleaved across K sublists so
//!   every batch spans the full distance spectrum (Figures 1–2).
//! * **Categories (§4.3)** — `N↓` regrouped into per-category K-sized
//!   blocks, concatenated round-robin, partial blocks last (Figure 3).

use super::Variant;
use crate::data::DataView;
use crate::runtime::CostBackend;

/// Indices sorted by decreasing distance to the global centroid — the
/// paper's `N↓`. Ties broken by index for determinism. Identity views
/// hand the backend their contiguous matrix directly (i.e. the AOT
/// artifact when running `--backend xla`); index views compute each
/// distance straight off the view's rows with the same f64 accumulation
/// as [`crate::runtime::NativeBackend`], so no row is ever staged and
/// the result is bit-identical to the contiguous native path. (With
/// `--backend xla` this means index views order through native math —
/// the same caveat as the hierarchical fan-out, see
/// [`crate::algo::hierarchical`].)
pub fn sorted_by_centroid_distance(
    view: &DataView<'_>,
    backend: &mut dyn CostBackend,
) -> Vec<usize> {
    let mu = view.global_centroid();
    let n = view.n();
    let mut dist = Vec::with_capacity(n);
    match view.contiguous() {
        Some(x) => backend.centroid_distances(x, n, view.d(), &mu, &mut dist),
        None => dist.extend((0..n).map(|i| crate::runtime::simd::sq_dist(view.row(i), &mu))),
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_unstable_by(|&a, &b| dist[b].total_cmp(&dist[a]).then(a.cmp(&b)));
    idx
}

/// Build the processing order for a variant (categorical rearrangement is
/// applied on top when the view carries categories; see `build_order`).
pub fn build_order(
    view: &DataView<'_>,
    k: usize,
    variant: Variant,
    backend: &mut dyn CostBackend,
) -> Vec<usize> {
    let sorted = sorted_by_centroid_distance(view, backend);
    if let Some(cats) = view.categories() {
        return rearrange_categorical(&sorted, &cats, k);
    }
    match variant {
        Variant::Base => sorted,
        Variant::Small => rearrange_small(&sorted, k),
        Variant::Auto => unreachable!("Auto resolved by caller"),
    }
}

/// §4.2 rearrangement. Splits `sorted` into K sublists and interleaves
/// them so each batch contains one object from every distance range.
///
/// When `n % k != 0`, the first `ceil(n/k)*k - n` sublists are short
/// (length `floor(n/k)`) and the rest long (length `ceil(n/k)`); the long
/// sublists' final elements form the last (partial) batch — they are
/// closest to the global centroid and least likely to shift centroids
/// (Figure 2).
pub fn rearrange_small(sorted: &[usize], k: usize) -> Vec<usize> {
    let n = sorted.len();
    if k <= 1 || k >= n {
        return sorted.to_vec();
    }
    let q = n / k;
    let qbar = n.div_ceil(k);
    let n_short = qbar * k - n; // sublists of length q
    let mut out = Vec::with_capacity(n);
    // Sublist s occupies a contiguous span of `sorted`.
    let start_of = |s: usize| -> usize {
        if s < n_short {
            s * q
        } else {
            n_short * q + (s - n_short) * qbar
        }
    };
    // Round-robin: q rounds over all K sublists.
    for round in 0..q {
        for s in 0..k {
            out.push(sorted[start_of(s) + round]);
        }
    }
    // Remaining objects (only when n % k != 0): the last element of each
    // long sublist, appended in sublist order — they form the final
    // partial batch B_B.
    if qbar > q {
        for s in n_short..k {
            out.push(sorted[start_of(s) + q]);
        }
    }
    debug_assert_eq!(out.len(), n);
    out
}

/// §4.3 rearrangement for the categorical variant. Splits `sorted` into
/// per-category sublists (preserving sort order), cuts each into K-sized
/// blocks, and concatenates: all *full* blocks round-robin across
/// categories first, then the partial blocks in the same order (Figure 3).
pub fn rearrange_categorical(sorted: &[usize], categories: &[u32], k: usize) -> Vec<usize> {
    let g = categories.iter().copied().max().map_or(0, |m| m as usize + 1);
    if g <= 1 {
        return sorted.to_vec();
    }
    // Per-category sublists in sorted order.
    let mut sub: Vec<Vec<usize>> = vec![Vec::new(); g];
    for &i in sorted {
        sub[categories[i] as usize].push(i);
    }
    let mut out = Vec::with_capacity(sorted.len());
    // Full K-sized blocks, round-robin across categories.
    let max_blocks = sub.iter().map(|s| s.len().div_ceil(k)).max().unwrap_or(0);
    for b in 0..max_blocks {
        for s in sub.iter() {
            let lo = b * k;
            let hi = lo + k;
            if hi <= s.len() {
                out.extend_from_slice(&s[lo..hi]);
            }
        }
    }
    // Partial trailing blocks, same alternating order.
    for s in sub.iter() {
        let full = (s.len() / k) * k;
        out.extend_from_slice(&s[full..]);
    }
    debug_assert_eq!(out.len(), sorted.len());
    out
}

/// Batch boundaries: `ceil(n/k)` batches of size K (last may be short).
pub fn batch_ranges(n: usize, k: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(n.div_ceil(k));
    let mut start = 0;
    while start < n {
        let end = (start + k).min(n);
        out.push((start, end));
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};
    use crate::data::Dataset;
    use crate::runtime::NativeBackend;

    #[test]
    fn sorted_is_descending() {
        let ds = generate(SynthKind::Uniform, 100, 3, 2, "u");
        let mut be = NativeBackend::default();
        let order = sorted_by_centroid_distance(&ds.view(), &mut be);
        let mu = ds.global_centroid();
        let d = |i: usize| crate::data::dataset::sq_dist(ds.row(i), &mu);
        for w in order.windows(2) {
            assert!(d(w[0]) >= d(w[1]) - 1e-12);
        }
    }

    /// Figure 1: N=18, K=6 — sublists of length 3; the rearranged list
    /// interleaves them: positions 0,3,6,9,12,15 then 1,4,... etc.
    #[test]
    fn figure1_layout_exact() {
        let sorted: Vec<usize> = (0..18).collect();
        let got = rearrange_small(&sorted, 6);
        let want = vec![
            0, 3, 6, 9, 12, 15, //
            1, 4, 7, 10, 13, 16, //
            2, 5, 8, 11, 14, 17,
        ];
        assert_eq!(got, want);
    }

    /// Figure 2: N=22, K=6 — Q=3, Q̄=4; the first Q̄K−N = 2 sublists are
    /// short (len 3), the remaining 4 long (len 4). Sublist starts:
    /// 0,3,6,10,14,18. Three round-robin rounds, then the long sublists'
    /// last elements (9, 13, 17, 21).
    #[test]
    fn figure2_layout_exact() {
        let sorted: Vec<usize> = (0..22).collect();
        let got = rearrange_small(&sorted, 6);
        let want = vec![
            0, 3, 6, 10, 14, 18, //
            1, 4, 7, 11, 15, 19, //
            2, 5, 8, 12, 16, 20, //
            9, 13, 17, 21,
        ];
        assert_eq!(got, want);
    }

    /// Figure 3: N=22, K=3, two categories. Category A has 13 objects (4
    /// full blocks + partial of 1), category B has 9 (3 full + 0). Full
    /// blocks alternate A,B,A,B,...; partials appended last.
    #[test]
    fn figure3_layout_categorical() {
        // Objects 0..22 in sorted order; even-ish split of categories.
        let sorted: Vec<usize> = (0..22).collect();
        let categories: Vec<u32> = (0..22).map(|i| u32::from(i >= 13)).collect();
        let got = rearrange_categorical(&sorted, &categories, 3);
        // Sublists: A = 0..13 (blocks [0,1,2][3,4,5][6,7,8][9,10,11] + [12]),
        //           B = 13..22 (blocks [13,14,15][16,17,18][19,20,21]).
        let want = vec![
            0, 1, 2, 13, 14, 15, //
            3, 4, 5, 16, 17, 18, //
            6, 7, 8, 19, 20, 21, //
            9, 10, 11, // A block 4 (B exhausted)
            12, // partial A
        ];
        assert_eq!(got, want);
    }

    #[test]
    fn rearrangements_are_permutations() {
        for &(n, k) in &[(18usize, 6usize), (22, 6), (100, 7), (13, 13), (5, 2)] {
            let sorted: Vec<usize> = (0..n).rev().collect();
            let got = rearrange_small(&sorted, k);
            let mut s = got.clone();
            s.sort_unstable();
            assert_eq!(s, (0..n).collect::<Vec<_>>(), "n={n} k={k}");
        }
    }

    #[test]
    fn categorical_is_permutation_with_many_categories() {
        let n = 97;
        let sorted: Vec<usize> = (0..n).collect();
        let cats: Vec<u32> = (0..n).map(|i| (i % 5) as u32).collect();
        let got = rearrange_categorical(&sorted, &cats, 4);
        let mut s = got.clone();
        s.sort_unstable();
        assert_eq!(s, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn batch_ranges_cover() {
        assert_eq!(batch_ranges(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(batch_ranges(8, 4), vec![(0, 4), (4, 8)]);
        assert_eq!(batch_ranges(3, 5), vec![(0, 3)]);
    }

    #[test]
    fn small_variant_batches_span_distance_spectrum() {
        // After rearrangement, each full batch should contain objects from
        // every K-quantile of the sorted order.
        let ds = generate(SynthKind::Uniform, 60, 2, 3, "u");
        let mut be = NativeBackend::default();
        let sorted = sorted_by_centroid_distance(&ds.view(), &mut be);
        let k = 6;
        let pos_in_sorted: std::collections::HashMap<usize, usize> =
            sorted.iter().enumerate().map(|(p, &i)| (i, p)).collect();
        let order = rearrange_small(&sorted, k);
        let q = 60 / k;
        for (b, chunk) in order.chunks(k).enumerate().take(q) {
            let mut deciles: Vec<usize> =
                chunk.iter().map(|i| pos_in_sorted[i] / q).collect();
            deciles.sort_unstable();
            assert_eq!(deciles, (0..k).collect::<Vec<_>>(), "batch {b}");
        }
    }

    #[test]
    fn single_category_degenerates_to_sorted() {
        let sorted: Vec<usize> = (0..10).collect();
        let cats = vec![0u32; 10];
        assert_eq!(rearrange_categorical(&sorted, &cats, 3), sorted);
    }

    #[test]
    fn order_uses_categories_when_present() {
        let mut ds = generate(SynthKind::Uniform, 30, 2, 4, "u");
        ds = ds
            .with_categories((0..30).map(|i| (i % 3) as u32).collect())
            .unwrap();
        let mut be = NativeBackend::default();
        let order = build_order(&ds.view(), 5, Variant::Base, &mut be);
        // First 5 objects of the order must share one category (a full
        // K-block from one category sublist).
        let cats = ds.categories.as_ref().unwrap();
        let first: Vec<u32> = order[..5].iter().map(|&i| cats[i]).collect();
        assert!(first.iter().all(|&c| c == first[0]), "{first:?}");
    }

    #[test]
    fn duplicate_distance_ties_are_deterministic() {
        let ds = Dataset::from_rows("dup", &vec![vec![1.0, 1.0]; 10]).unwrap();
        let mut be = NativeBackend::default();
        let a = sorted_by_centroid_distance(&ds.view(), &mut be);
        let b = sorted_by_centroid_distance(&ds.view(), &mut be);
        assert_eq!(a, b);
        assert_eq!(a, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn index_view_order_matches_contiguous_order() {
        // An index view over all rows takes the row-wise (zero-staging)
        // path; the order must be bit-identical to the contiguous fast
        // path through the backend.
        let ds = generate(SynthKind::Uniform, 500, 3, 8, "u");
        let mut be = NativeBackend::default();
        let idx: Vec<usize> = (0..ds.n).collect();
        let contiguous = sorted_by_centroid_distance(&ds.view(), &mut be);
        let rowwise = sorted_by_centroid_distance(&ds.view().select(&idx), &mut be);
        assert_eq!(contiguous, rowwise);
    }
}
