//! The Assignment-Based Anticlustering algorithm (paper §4).
//!
//! * [`batching`] — sorted-list construction and the §4.1/§4.2/§4.3 batch
//!   orderings (Figures 1–3).
//! * [`core`] — the assignment loop of Algorithm 1 (shared by all
//!   variants), including categorical cost masking and the reusable
//!   [`core::Scratch`] owned by [`crate::solver::Aba`] sessions.
//! * [`hierarchical`] — the §4.4 decomposition with Proposition-1 size
//!   guarantees, fanning subproblems out over the session worker pool
//!   ([`crate::runtime::pool`]) when the config enables parallelism.
//! * [`objective`] — Fact-1 objectives and the diversity-balance metrics
//!   the evaluation tables report.
//!
//! The preferred entry point is a [`crate::solver::Aba`] session built
//! with `Aba::builder()`. The free functions [`run_aba`] and
//! [`run_aba_constrained`] are deprecated shims kept for exactly one
//! release: they were superseded by the session API in 0.2.0 and will be
//! deleted in 0.3.0 — migrate via
//! `Aba::builder().build()?.partition(ds, k)` (plus
//! `.constraints(cons)` for the constrained variant), which also returns
//! the richer [`crate::solver::Partition`] instead of bare labels.

pub mod batching;
pub mod constraints;
pub mod core;
pub mod hierarchical;
pub mod objective;

pub use self::core::run_with_order;
pub use constraints::Constraints;
#[allow(deprecated)]
pub use constraints::run_aba_constrained;
pub use hierarchical::{auto_spec, run_hierarchical};
pub use objective::ClusterStats;

use crate::assignment::{CandidateMode, SolverKind};
use crate::data::dataset::ensure_nonempty;
use crate::data::{DataView, Dataset};
use crate::error::{AbaError, AbaResult};
use crate::runtime::{BackendKind, CostBackend, Parallelism};

/// Batch-ordering variant (paper §4.1–§4.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// §4.1: batches in decreasing distance to the global centroid.
    Base,
    /// §4.2: interleaved sublists — better for small anticlusters.
    Small,
    /// Pick `Small` when `N/K <= 4`, else `Base`.
    Auto,
}

impl Variant {
    /// Every variant, in display order. The single source of truth for
    /// accepted CLI values: `Display`, `FromStr`, and help text all
    /// derive from this list.
    pub const ALL: [Variant; 3] = [Variant::Base, Variant::Small, Variant::Auto];

    /// The canonical (CLI) spelling.
    pub const fn as_str(self) -> &'static str {
        match self {
            Variant::Base => "base",
            Variant::Small => "small",
            Variant::Auto => "auto",
        }
    }

    /// Accepted spellings joined with `|`, for help and error messages.
    pub fn accepted() -> String {
        Self::ALL
            .iter()
            .map(|v| v.as_str())
            .collect::<Vec<_>>()
            .join("|")
    }
}

impl std::fmt::Display for Variant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Variant {
    type Err = AbaError;
    fn from_str(s: &str) -> AbaResult<Self> {
        Self::ALL
            .iter()
            .copied()
            .find(|v| v.as_str() == s)
            .ok_or_else(|| {
                AbaError::InvalidInput(format!(
                    "unknown variant '{s}' (accepted: {})",
                    Variant::accepted()
                ))
            })
    }
}

/// The objective a session optimizes.
///
/// ABA itself maximizes *diversity* (the within-anticluster sum of
/// squares, in both its centroid and pairwise forms). *Dispersion* —
/// the minimum within-group pairwise distance — is a different
/// objective with a different complexity landscape: NP-hard for
/// `k >= 3`, but exactly solvable in polynomial time for `k == 2`
/// under cardinality constraints via the coloring construction in
/// [`crate::cert::two_color`]. Selecting
/// [`Criterion::Dispersion`] therefore dispatches `k == 2` solves to
/// that exact oracle and rejects everything else with a typed error
/// rather than silently approximating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Criterion {
    /// Maximize within-anticluster diversity (the paper's objective;
    /// the default).
    Diversity,
    /// Maximize the minimum within-group pairwise distance. Exact for
    /// `k == 2` (O(n² log n)); other `k` are rejected.
    Dispersion,
}

impl Criterion {
    /// Every criterion, in display order — single source of truth for
    /// the CLI (`Display`, `FromStr`, help text).
    pub const ALL: [Criterion; 2] = [Criterion::Diversity, Criterion::Dispersion];

    /// The canonical (CLI) spelling.
    pub const fn as_str(self) -> &'static str {
        match self {
            Criterion::Diversity => "diversity",
            Criterion::Dispersion => "dispersion",
        }
    }

    /// Accepted spellings joined with `|`, for help and error messages.
    pub fn accepted() -> String {
        Self::ALL
            .iter()
            .map(|v| v.as_str())
            .collect::<Vec<_>>()
            .join("|")
    }
}

impl std::fmt::Display for Criterion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Criterion {
    type Err = AbaError;
    fn from_str(s: &str) -> AbaResult<Self> {
        Self::ALL
            .iter()
            .copied()
            .find(|v| v.as_str() == s)
            .ok_or_else(|| {
                AbaError::InvalidInput(format!(
                    "unknown criterion '{s}' (accepted: {})",
                    Criterion::accepted()
                ))
            })
    }
}

/// Configuration for an ABA run. Prefer building a
/// [`crate::solver::Aba`] session via `Aba::builder()`, which owns this
/// plus a backend and scratch.
#[derive(Clone, Debug)]
pub struct AbaConfig {
    pub variant: Variant,
    pub solver: SolverKind,
    pub backend: BackendKind,
    /// Explicit hierarchical decomposition `[K1, K2, ...]` with
    /// `prod(Ki) == K`. `None` + `auto_hier` derives one for large K.
    pub hier: Option<Vec<usize>>,
    /// Apply the Table-5-style decomposition rule automatically when K is
    /// large.
    pub auto_hier: bool,
    /// How much parallelism the run may use: chunk-parallel cost
    /// matrices on the flat path and subproblem fan-out on the
    /// hierarchical path, all on one session-owned worker pool. With
    /// the native backend, serial and parallel runs produce
    /// bit-identical labels (XLA caveat: see [`hierarchical`]).
    pub parallelism: Parallelism,
    /// Reject (instead of warn about) `n % k != 0`, where anticluster
    /// sizes must differ by one.
    pub strict_divisibility: bool,
    /// Candidate pruning for the per-batch assignment: `Dense` is the
    /// paper-exact solve; `Fixed(C)` / `Auto` switch large-K batches to
    /// the sparse candidate-pruned path
    /// ([`crate::assignment::sparse`]), dropping per-batch work from
    /// `O(k²d + k³)` to roughly `O(k·C·(d + log k))`.
    pub candidates: CandidateMode,
    /// LAPJV warm-start override. `None` (default) consults the
    /// `ABA_LAPJV_WARM` env var **once at session construction** — never
    /// on the per-run hot path. Cold start is the measured-faster
    /// default on ABA's structured matrices (see the note on
    /// [`core::Scratch`]).
    pub lapjv_warm: Option<bool>,
    /// The objective to optimize. [`Criterion::Dispersion`] routes
    /// `k == 2` to the exact coloring solver and rejects other shapes;
    /// excluded from [`AbaConfig::fingerprint`] because dispersion
    /// sessions refuse to hand out online partitions at all.
    pub criterion: Criterion,
    /// Also compute a standalone, solver-independent quality
    /// certificate ([`crate::cert::bounds::Certificate`]) on every
    /// solve, retrievable via
    /// [`crate::Aba::last_certificate`]. Off by default: the
    /// partition-attached `upper_bound()`/`gap()` are free either way;
    /// this knob adds the separately-timed O(nd) certification pass
    /// (pool-parallel under `parallelism`) that the CLI and benches
    /// report.
    pub certify: bool,
    /// Distance-kernel mode override
    /// ([`crate::runtime::simd::KernelMode`]). `None` (default) consults
    /// the `ABA_KERNELS` env var **once at session construction** —
    /// never on the per-run hot path. `Auto` and `Scalar` are
    /// bit-identical by construction; `Fma` trades bit-identity for a
    /// contracted multiply-add; `FastMath` relaxes determinism entirely
    /// (blocked FMA panels, AVX-512 where available — labels may
    /// differ, objective gap bench-gated in ppm). Excluded from
    /// [`AbaConfig::fingerprint`], like the other wall-clock-only knobs.
    pub kernels: Option<crate::runtime::KernelMode>,
}

impl AbaConfig {
    /// Stable fingerprint of the configuration knobs that change how a
    /// partition is *maintained* online: variant (bootstrap ordering),
    /// solver, candidate mode, and strict divisibility. Persisted into
    /// [`crate::online::OnlinePartition`] snapshots so a saved partition
    /// cannot be resumed under an incompatible session
    /// ([`AbaError::SnapshotMismatch`]). Wall-clock-only knobs
    /// (`parallelism`, `backend`) and batch-only knobs (`hier`,
    /// `auto_hier` — online updates never re-decompose) are deliberately
    /// excluded.
    pub fn fingerprint(&self) -> String {
        format!(
            "aba/1|variant={}|solver={}|candidates={}|strict={}",
            self.variant, self.solver, self.candidates, self.strict_divisibility
        )
    }
}

impl Default for AbaConfig {
    fn default() -> Self {
        Self {
            variant: Variant::Auto,
            solver: SolverKind::Lapjv,
            backend: BackendKind::Native,
            hier: None,
            auto_hier: true,
            parallelism: Parallelism::Serial,
            strict_divisibility: false,
            candidates: CandidateMode::Auto,
            lapjv_warm: None,
            criterion: Criterion::Diversity,
            certify: false,
            kernels: None,
        }
    }
}

/// Resolve `Auto` to a concrete variant for this instance.
pub fn resolve_variant(variant: Variant, n: usize, k: usize) -> Variant {
    match variant {
        Variant::Auto if n / k <= 4 => Variant::Small,
        Variant::Auto => Variant::Base,
        v => v,
    }
}

/// Validate `(n, k)` once, up front (callers pass `view.n()` / `ds.n`).
/// Emptiness is rejected through the same [`ensure_nonempty`] check the
/// data layer applies at construction — one source of truth for
/// [`AbaError::EmptyDataset`]. `strict` additionally rejects
/// `n % k != 0`; otherwise the ragged case is only logged, since ABA
/// still guarantees sizes within one of each other.
pub fn validate(n: usize, k: usize, strict: bool) -> AbaResult<()> {
    ensure_nonempty(n)?;
    if k == 0 {
        return Err(AbaError::InvalidK { k, n, reason: "k must be >= 1".into() });
    }
    if k > n {
        return Err(AbaError::InvalidK {
            k,
            n,
            reason: "k exceeds the number of objects".into(),
        });
    }
    if n % k != 0 {
        if strict {
            return Err(AbaError::InvalidK {
                k,
                n,
                reason: format!(
                    "n % k = {} != 0 and strict divisibility was requested",
                    n % k
                ),
            });
        }
        // eprintln rather than log::warn!: no logger is initialized in
        // the CLI, and this message must actually reach users.
        eprintln!(
            "warning: n={n} is not divisible by k={k}; anticluster sizes will differ by one"
        );
    }
    Ok(())
}

/// Run ABA on a dataset, returning an anticluster label in `0..k` per
/// object. Honors the categorical variant automatically when the dataset
/// carries categories (§4.3), and hierarchical decomposition per config.
///
/// # Deprecation path
///
/// This shim survives exactly one release: deprecated in 0.2.0, deleted
/// in 0.3.0. It rebuilds the backend, scratch buffers, and worker pool
/// on every call — the [`crate::solver::Aba`] session keeps all three
/// warm. Migrate one-shot calls as
/// `Aba::builder().build()?.partition(ds, k)?.labels` and repeated calls
/// by holding the session.
#[deprecated(
    since = "0.2.0",
    note = "superseded by sessions (`Aba::builder().build()?.partition(ds, k)`); \
            will be removed in 0.3.0"
)]
pub fn run_aba(ds: &Dataset, k: usize, cfg: &AbaConfig) -> AbaResult<Vec<u32>> {
    // Labels-only path: legacy callers don't pay the Partition stats
    // pass the session API computes.
    validate(ds.n, k, cfg.strict_divisibility)?;
    if let Some(spec) = effective_spec(ds.n, k, cfg) {
        return run_hierarchical(ds, &spec, cfg);
    }
    let mut backend = crate::runtime::make_backend(cfg.backend)?;
    Ok(flat_with_scratch(&ds.view(), k, cfg, backend.as_mut(), &mut core::Scratch::default())?.0)
}

/// As the `Aba` session but with a caller-supplied backend (lets the
/// hierarchical driver and tests reuse compiled XLA executables /
/// scratch). Validates exactly once.
pub fn run_aba_with_backend(
    ds: &Dataset,
    k: usize,
    cfg: &AbaConfig,
    backend: &mut dyn CostBackend,
) -> AbaResult<Vec<u32>> {
    validate(ds.n, k, cfg.strict_divisibility)?;
    Ok(flat_with_scratch(&ds.view(), k, cfg, backend, &mut core::Scratch::default())?.0)
}

/// The single flat-run implementation shared by [`run_aba_with_backend`],
/// the hierarchical driver, and [`crate::solver::Aba`] sessions: build
/// the order, run the assignment loop — both straight off the borrowed
/// view (the hierarchical driver passes zero-copy group selections
/// here). Does **not** validate — callers validate exactly once at
/// their entry point (k bounds are still enforced by the core loop).
/// Returns `(labels, order_secs, assign_secs)` so sessions can report
/// phase timings.
pub(crate) fn flat_with_scratch(
    view: &DataView<'_>,
    k: usize,
    cfg: &AbaConfig,
    backend: &mut dyn CostBackend,
    scratch: &mut core::Scratch,
) -> AbaResult<(Vec<u32>, f64, f64)> {
    if k == 1 {
        return Ok((vec![0; view.n()], 0.0, 0.0));
    }
    let variant = resolve_variant(cfg.variant, view.n(), k);
    let t = std::time::Instant::now();
    let order = batching::build_order(view, k, variant, backend);
    let order_secs = t.elapsed().as_secs_f64();
    let t = std::time::Instant::now();
    let labels = core::run_with_order_scratch(
        view,
        k,
        &order,
        cfg.solver,
        backend,
        scratch,
        cfg.parallelism,
        cfg.candidates,
    )?;
    Ok((labels, order_secs, t.elapsed().as_secs_f64()))
}

/// The decomposition actually used for a run on `n` objects, if any.
pub fn effective_spec(n: usize, k: usize, cfg: &AbaConfig) -> Option<Vec<usize>> {
    if let Some(spec) = &cfg.hier {
        if spec.len() > 1 {
            return Some(spec.clone());
        }
        return None;
    }
    if cfg.auto_hier {
        let spec = auto_spec(n, k);
        if spec.len() > 1 {
            return Some(spec);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};
    use crate::solver::{Aba, Anticlusterer};

    #[test]
    fn rejects_bad_k() {
        let ds = generate(SynthKind::Uniform, 10, 2, 1, "u");
        let mut s = Aba::new().unwrap();
        assert!(s.partition(&ds, 0).is_err());
        assert!(s.partition(&ds, 11).is_err());
    }

    #[test]
    fn validate_rejects_empty_dataset() {
        // Same single-sourced check the data layer applies at
        // construction time (`Dataset::from_flat`).
        assert_eq!(validate(0, 1, false), Err(AbaError::EmptyDataset));
        assert_eq!(
            Dataset::from_flat("empty", 0, 2, Vec::new()).unwrap_err(),
            AbaError::EmptyDataset
        );
    }

    #[test]
    fn validate_rejects_k_zero_and_k_beyond_n() {
        assert!(matches!(
            validate(10, 0, false),
            Err(AbaError::InvalidK { k: 0, n: 10, .. })
        ));
        assert!(matches!(
            validate(10, 11, false),
            Err(AbaError::InvalidK { k: 11, n: 10, .. })
        ));
    }

    #[test]
    fn validate_divisibility_strict_vs_lax() {
        assert!(validate(10, 3, false).is_ok());
        assert!(matches!(
            validate(10, 3, true),
            Err(AbaError::InvalidK { k: 3, n: 10, .. })
        ));
        assert!(validate(10, 5, true).is_ok());
    }

    #[test]
    fn k1_is_trivial() {
        let ds = generate(SynthKind::Uniform, 10, 2, 1, "u");
        let labels = Aba::new().unwrap().partition(&ds, 1).unwrap().labels;
        assert!(labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn variant_display_round_trips_with_fromstr() {
        for v in Variant::ALL {
            assert_eq!(v.to_string().parse::<Variant>().unwrap(), v);
        }
        assert_eq!(Variant::accepted(), "base|small|auto");
        let err = "x".parse::<Variant>().unwrap_err();
        assert!(err.to_string().contains("base|small|auto"), "{err}");
    }

    #[test]
    fn criterion_display_round_trips_with_fromstr() {
        for c in Criterion::ALL {
            assert_eq!(c.to_string().parse::<Criterion>().unwrap(), c);
        }
        assert_eq!(Criterion::accepted(), "diversity|dispersion");
        let err = "minmax".parse::<Criterion>().unwrap_err();
        assert!(err.to_string().contains("diversity|dispersion"), "{err}");
    }

    #[test]
    fn criterion_does_not_perturb_the_fingerprint() {
        // Snapshot compatibility: dispersion sessions never produce
        // online partitions, so the fingerprint ignores the criterion
        // (and the certify toggle) and existing snapshots keep loading.
        let mut cfg = AbaConfig::default();
        let base = cfg.fingerprint();
        cfg.criterion = Criterion::Dispersion;
        cfg.certify = true;
        assert_eq!(cfg.fingerprint(), base);
    }

    #[test]
    fn kernels_do_not_perturb_the_fingerprint() {
        // Snapshot compatibility: the default and scalar kernel modes
        // are bit-identical, and even the FMA mode only perturbs cost
        // matrices (assignment inputs), not the maintained moments — so
        // the kernel knob, like `parallelism` and `backend`, must not
        // invalidate existing snapshots.
        let mut cfg = AbaConfig::default();
        let base = cfg.fingerprint();
        for m in crate::runtime::KernelMode::ALL {
            cfg.kernels = Some(m);
            assert_eq!(cfg.fingerprint(), base, "mode={m}");
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shim_still_works() {
        let ds = generate(SynthKind::Uniform, 60, 3, 4, "u");
        let shim = run_aba(&ds, 6, &AbaConfig::default()).unwrap();
        let session = Aba::new().unwrap().partition(&ds, 6).unwrap().labels;
        assert_eq!(shim, session);
    }
}
