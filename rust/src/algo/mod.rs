//! The Assignment-Based Anticlustering algorithm (paper §4).
//!
//! * [`batching`] — sorted-list construction and the §4.1/§4.2/§4.3 batch
//!   orderings (Figures 1–3).
//! * [`core`] — the assignment loop of Algorithm 1 (shared by all
//!   variants), including categorical cost masking.
//! * [`hierarchical`] — the §4.4 decomposition with Proposition-1 size
//!   guarantees and threaded subproblem fan-out.
//! * [`objective`] — Fact-1 objectives and the diversity-balance metrics
//!   the evaluation tables report.

pub mod batching;
pub mod constraints;
pub mod core;
pub mod hierarchical;
pub mod objective;

pub use self::core::run_with_order;
pub use constraints::{run_aba_constrained, Constraints};
pub use hierarchical::{auto_spec, run_hierarchical};
pub use objective::ClusterStats;

use crate::assignment::SolverKind;
use crate::data::Dataset;
use crate::runtime::{make_backend, BackendKind, CostBackend};
use anyhow::{bail, Result};

/// Batch-ordering variant (paper §4.1–§4.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// §4.1: batches in decreasing distance to the global centroid.
    Base,
    /// §4.2: interleaved sublists — better for small anticlusters.
    Small,
    /// Pick `Small` when `N/K <= 4`, else `Base`.
    Auto,
}

impl std::str::FromStr for Variant {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "base" => Ok(Variant::Base),
            "small" => Ok(Variant::Small),
            "auto" => Ok(Variant::Auto),
            _ => bail!("unknown variant '{s}' (base|small|auto)"),
        }
    }
}

/// Configuration for an ABA run.
#[derive(Clone, Debug)]
pub struct AbaConfig {
    pub variant: Variant,
    pub solver: SolverKind,
    pub backend: BackendKind,
    /// Explicit hierarchical decomposition `[K1, K2, ...]` with
    /// `prod(Ki) == K`. `None` + `auto_hier` derives one for large K.
    pub hier: Option<Vec<usize>>,
    /// Apply the Table-5-style decomposition rule automatically when K is
    /// large.
    pub auto_hier: bool,
    /// Fan subproblems out over threads at each hierarchy level.
    pub parallel: bool,
}

impl Default for AbaConfig {
    fn default() -> Self {
        Self {
            variant: Variant::Auto,
            solver: SolverKind::Lapjv,
            backend: BackendKind::Native,
            hier: None,
            auto_hier: true,
            parallel: false,
        }
    }
}

/// Run ABA on a dataset, returning an anticluster label in `0..k` per
/// object. Honors the categorical variant automatically when the dataset
/// carries categories (§4.3), and hierarchical decomposition per config.
pub fn run_aba(ds: &Dataset, k: usize, cfg: &AbaConfig) -> Result<Vec<u32>> {
    validate(ds, k)?;
    if let Some(spec) = effective_spec(ds, k, cfg) {
        return run_hierarchical(ds, &spec, cfg);
    }
    let mut backend = make_backend(cfg.backend)?;
    run_aba_with_backend(ds, k, cfg, backend.as_mut())
}

/// As [`run_aba`] but with a caller-supplied backend (lets the pipeline
/// and hierarchical driver reuse compiled XLA executables / scratch).
pub fn run_aba_with_backend(
    ds: &Dataset,
    k: usize,
    cfg: &AbaConfig,
    backend: &mut dyn CostBackend,
) -> Result<Vec<u32>> {
    validate(ds, k)?;
    if k == 1 {
        return Ok(vec![0; ds.n]);
    }
    let variant = match cfg.variant {
        Variant::Auto if ds.n / k <= 4 => Variant::Small,
        Variant::Auto => Variant::Base,
        v => v,
    };
    let order = batching::build_order(ds, k, variant, backend);
    core::run_with_order(ds, k, &order, cfg.solver, backend)
}

/// The decomposition actually used for this run, if any.
pub fn effective_spec(ds: &Dataset, k: usize, cfg: &AbaConfig) -> Option<Vec<usize>> {
    if let Some(spec) = &cfg.hier {
        if spec.len() > 1 {
            return Some(spec.clone());
        }
        return None;
    }
    if cfg.auto_hier {
        let spec = auto_spec(ds.n, k);
        if spec.len() > 1 {
            return Some(spec);
        }
    }
    None
}

fn validate(ds: &Dataset, k: usize) -> Result<()> {
    if k == 0 {
        bail!("k must be >= 1");
    }
    if k > ds.n {
        bail!("k={k} exceeds number of objects n={}", ds.n);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};

    #[test]
    fn rejects_bad_k() {
        let ds = generate(SynthKind::Uniform, 10, 2, 1, "u");
        assert!(run_aba(&ds, 0, &AbaConfig::default()).is_err());
        assert!(run_aba(&ds, 11, &AbaConfig::default()).is_err());
    }

    #[test]
    fn k1_is_trivial() {
        let ds = generate(SynthKind::Uniform, 10, 2, 1, "u");
        let labels = run_aba(&ds, 1, &AbaConfig::default()).unwrap();
        assert!(labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn variant_parses() {
        assert_eq!("base".parse::<Variant>().unwrap(), Variant::Base);
        assert_eq!("small".parse::<Variant>().unwrap(), Variant::Small);
        assert!("x".parse::<Variant>().is_err());
    }
}
