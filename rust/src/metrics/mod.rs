//! Summary statistics shared by the experiment harness and the pipeline.

/// Iterate the object indices belonging to cluster `c` of a label
/// vector, without materializing per-cluster index vectors. The shared
/// non-allocating alternative to building `Vec<Vec<usize>>` via
/// `Partition::groups()` when only one cluster is walked at a time;
/// [`crate::solver::Partition::members_of`] delegates here. The
/// iterator is `Clone`, so nested pair loops can fork it.
pub fn members_of(labels: &[u32], c: u32) -> impl Iterator<Item = usize> + Clone + '_ {
    labels
        .iter()
        .enumerate()
        .filter_map(move |(i, &l)| (l == c).then_some(i))
}

/// Basic descriptive statistics of a sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        let n = xs.len();
        if n == 0 {
            return Self { n: 0, mean: 0.0, sd: 0.0, min: 0.0, max: 0.0 };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|&x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Self {
            n,
            mean,
            sd: var.sqrt(),
            min: xs.iter().copied().fold(f64::INFINITY, f64::min),
            max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    pub fn range(&self) -> f64 {
        self.max - self.min
    }
}

/// Quartiles (q1, median, q3) via linear interpolation — used for the
/// Figure 6 boxplot table.
pub fn quartiles(xs: &[f64]) -> (f64, f64, f64) {
    assert!(!xs.is_empty());
    let mut s = xs.to_vec();
    s.sort_unstable_by(|a, b| a.total_cmp(b));
    let q = |p: f64| -> f64 {
        let pos = p * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
        }
    };
    (q(0.25), q(0.5), q(0.75))
}

/// Fixed-width ASCII histogram rows (value range binned into `bins`),
/// used for the Figure 5 diversity-distribution comparison.
pub fn ascii_histogram(xs: &[f64], bins: usize, width: usize) -> Vec<String> {
    assert!(bins > 0);
    if xs.is_empty() {
        return vec![];
    }
    let lo = xs.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let b = (((x - lo) / span) * bins as f64) as usize;
        counts[b.min(bins - 1)] += 1;
    }
    let max_count = *counts.iter().max().unwrap();
    counts
        .iter()
        .enumerate()
        .map(|(b, &c)| {
            let bar_len = if max_count == 0 { 0 } else { c * width / max_count };
            format!(
                "[{:>10.3}, {:>10.3})  {:>6}  {}",
                lo + span * b as f64 / bins as f64,
                lo + span * (b + 1) as f64 / bins as f64,
                c,
                "#".repeat(bar_len)
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn members_of_partitions_indices() {
        let labels = [0u32, 2, 1, 0, 2, 2];
        assert_eq!(members_of(&labels, 0).collect::<Vec<_>>(), vec![0, 3]);
        assert_eq!(members_of(&labels, 1).collect::<Vec<_>>(), vec![2]);
        assert_eq!(members_of(&labels, 2).collect::<Vec<_>>(), vec![1, 4, 5]);
        assert_eq!(members_of(&labels, 3).count(), 0);
        // Clone lets pair loops fork the iterator mid-walk.
        let mut it = members_of(&labels, 2);
        it.next();
        assert_eq!(it.clone().collect::<Vec<_>>(), it.collect::<Vec<_>>());
    }

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.range(), 3.0);
        assert!((s.sd - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(Summary::of(&[]).n, 0);
    }

    #[test]
    fn quartiles_median() {
        let (q1, q2, q3) = quartiles(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(q2, 3.0);
        assert_eq!(q1, 2.0);
        assert_eq!(q3, 4.0);
    }

    #[test]
    fn histogram_counts_sum() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let rows = ascii_histogram(&xs, 10, 40);
        assert_eq!(rows.len(), 10);
        let total: usize = rows
            .iter()
            .map(|r| r.split_whitespace().nth(3).unwrap().parse::<usize>().unwrap())
            .sum();
        assert_eq!(total, 100);
    }
}
