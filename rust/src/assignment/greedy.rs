//! Greedy assignment — the cheap ablation.
//!
//! Rows are processed in order of their best available gain; each takes its
//! best free column. O(nr·nc·log) via a simple re-scan. Not optimal, but
//! fast; used in the ablation bench to quantify what LAPJV's optimality is
//! worth to ABA solution quality.

/// Max-cost greedy assignment. Returns row -> column.
pub fn solve_max(cost: &[f32], nr: usize, nc: usize) -> Vec<usize> {
    assert!(nr <= nc);
    let mut assign = vec![usize::MAX; nr];
    let mut col_used = vec![false; nc];
    let mut row_done = vec![false; nr];
    // Repeatedly pick the (row, col) pair with max cost among free ones —
    // "greedy by global best", which is noticeably better than row-order
    // greedy while still simple.
    for _ in 0..nr {
        let mut best = (0usize, 0usize, f64::NEG_INFINITY);
        for i in 0..nr {
            if row_done[i] {
                continue;
            }
            let row = &cost[i * nc..(i + 1) * nc];
            for (j, &c) in row.iter().enumerate() {
                if !col_used[j] && (c as f64) > best.2 {
                    best = (i, j, c as f64);
                }
            }
        }
        let (i, j, _) = best;
        assign[i] = j;
        row_done[i] = true;
        col_used[j] = true;
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::{assignment_cost, brute, is_valid_assignment};
    use crate::rng::Pcg32;

    #[test]
    fn valid_and_reasonable() {
        let mut rng = Pcg32::new(21);
        for _ in 0..20 {
            let (nr, nc) = (6, 8);
            let cost: Vec<f32> = (0..nr * nc).map(|_| rng.f32()).collect();
            let g = solve_max(&cost, nr, nc);
            assert!(is_valid_assignment(&g, nc));
            let opt = brute::solve_max(&cost, nr, nc);
            let gc = assignment_cost(&cost, nc, &g);
            let oc = assignment_cost(&cost, nc, &opt);
            assert!(gc <= oc + 1e-9);
            // Global-best greedy achieves at least half the optimum.
            assert!(gc >= 0.5 * oc, "greedy={gc} opt={oc}");
        }
    }

    #[test]
    fn picks_unique_maxima() {
        let cost = vec![
            10.0, 1.0, //
            10.0, 2.0,
        ];
        let g = solve_max(&cost, 2, 2);
        assert!(is_valid_assignment(&g, 2));
        assert_eq!(assignment_cost(&cost, 2, &g), 12.0);
    }
}
