//! Linear assignment problem (LAP) solvers.
//!
//! Algorithm 1 of the paper solves one **max-cost** rectangular assignment
//! per batch: `nr` batch objects (rows) must be matched to distinct
//! anticlusters among `nc >= nr` (columns), maximizing total squared
//! distance to the anticluster centroids.
//!
//! Solvers:
//! * [`lapjv`] — Jonker–Volgenant-style shortest-augmenting-path solver
//!   with dual potentials (the paper's LAPJV; exact, O(nr·nc²)). This is
//!   the production solver on the dense hot path.
//! * [`sparse`] — the candidate-pruned subsystem for large K: CSR cost
//!   structures plus CSR-aware LAPJV and auction variants generalized
//!   over a [`sparse::CostAccess`] trait. Selected per session through
//!   [`CandidateMode`].
//! * [`auction`] — Bertsekas auction with ε-scaling (the paper's §6
//!   future-work item; exact for integer-scaled costs, benchmarked as an
//!   ablation).
//! * [`greedy`] — row-by-row argmax (cheap lower-quality ablation).
//! * [`brute`] — exhaustive permutation search, the test oracle for tiny
//!   instances.

pub mod auction;
pub mod brute;
pub mod greedy;
pub mod lapjv;
pub mod sparse;

pub use lapjv::Lapjv;
pub use sparse::SparseStats;

/// Which solver to use for the per-batch assignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    Lapjv,
    Auction,
    Greedy,
}

impl SolverKind {
    /// Every solver, in display order — the single source of the
    /// accepted CLI values (`Display`, `FromStr`, and help text all
    /// derive from it).
    pub const ALL: [SolverKind; 3] = [SolverKind::Lapjv, SolverKind::Auction, SolverKind::Greedy];

    /// The canonical (CLI) spelling.
    pub const fn as_str(self) -> &'static str {
        match self {
            SolverKind::Lapjv => "lapjv",
            SolverKind::Auction => "auction",
            SolverKind::Greedy => "greedy",
        }
    }

    /// Accepted spellings joined with `|`, for help and error messages.
    pub fn accepted() -> String {
        Self::ALL
            .iter()
            .map(|s| s.as_str())
            .collect::<Vec<_>>()
            .join("|")
    }
}

impl std::fmt::Display for SolverKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for SolverKind {
    type Err = crate::error::AbaError;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::ALL
            .iter()
            .copied()
            .find(|v| v.as_str() == s)
            .ok_or_else(|| {
                crate::error::AbaError::InvalidInput(format!(
                    "unknown solver '{s}' (accepted: {})",
                    SolverKind::accepted()
                ))
            })
    }
}

/// How many candidate anticlusters each batch object is scored against
/// (the sparse large-K assignment path, see [`sparse`]). `Dense` scores
/// every object against all `k` anticlusters — the paper's exact
/// per-batch solve; a candidate count `C < k` prunes the per-batch work
/// from `O(k²d + k³)` to roughly `O(k·C·(d + log k))` at a small,
/// bench-tracked objective cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum CandidateMode {
    /// Dense below [`CandidateMode::AUTO_MIN_K`] anticlusters, top-
    /// [`CandidateMode::AUTO_C`] candidates at or above it. The default.
    #[default]
    Auto,
    /// Always the dense path.
    Dense,
    /// Exactly this many candidates per object (clamped to `1..=k`;
    /// `C >= k` means no pruning and dispatches to the dense path).
    Fixed(usize),
}

impl CandidateMode {
    /// `Auto` stays dense below this many anticlusters: the dense solve
    /// is exact and still cheap, and the candidate machinery only pays
    /// for itself once `k²`-sized matrices start to hurt.
    pub const AUTO_MIN_K: usize = 512;
    /// Candidates per object once `Auto` goes sparse.
    pub const AUTO_C: usize = 32;

    /// The per-object candidate count for a `k`-anticluster batch. A
    /// result `>= k` means "run the dense path" (no pruning).
    pub fn effective(self, k: usize) -> usize {
        match self {
            CandidateMode::Dense => k,
            CandidateMode::Fixed(c) => c.clamp(1, k.max(1)),
            CandidateMode::Auto => {
                if k < Self::AUTO_MIN_K {
                    k
                } else {
                    Self::AUTO_C
                }
            }
        }
    }

    /// Accepted CLI spellings, for help and error messages.
    pub fn accepted() -> &'static str {
        "auto|dense|<C>"
    }
}

impl std::fmt::Display for CandidateMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CandidateMode::Auto => f.write_str("auto"),
            CandidateMode::Dense => f.write_str("dense"),
            CandidateMode::Fixed(c) => write!(f, "{c}"),
        }
    }
}

impl std::str::FromStr for CandidateMode {
    type Err = crate::error::AbaError;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "auto" => Ok(CandidateMode::Auto),
            "dense" => Ok(CandidateMode::Dense),
            _ => match s.parse::<usize>() {
                Ok(c) if c >= 1 => Ok(CandidateMode::Fixed(c)),
                _ => Err(crate::error::AbaError::InvalidInput(format!(
                    "invalid candidate count '{s}' (accepted: {})",
                    CandidateMode::accepted()
                ))),
            },
        }
    }
}

/// Solve a max-cost rectangular assignment (`nr <= nc`), returning for each
/// row the assigned column. `cost` is row-major `nr x nc`.
pub fn solve_max(kind: SolverKind, cost: &[f32], nr: usize, nc: usize) -> Vec<usize> {
    match kind {
        SolverKind::Lapjv => Lapjv::new().solve(cost, nr, nc, true),
        SolverKind::Auction => auction::solve_max(cost, nr, nc),
        SolverKind::Greedy => greedy::solve_max(cost, nr, nc),
    }
}

/// Total cost of an assignment (rows -> columns).
pub fn assignment_cost(cost: &[f32], nc: usize, assign: &[usize]) -> f64 {
    assign
        .iter()
        .enumerate()
        .map(|(i, &j)| cost[i * nc + j] as f64)
        .sum()
}

/// Check that an assignment is a valid partial injection rows -> columns.
pub fn is_valid_assignment(assign: &[usize], nc: usize) -> bool {
    let mut seen = vec![false; nc];
    for &j in assign {
        if j >= nc || seen[j] {
            return false;
        }
        seen[j] = true;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solver_kind_parses() {
        assert_eq!("lapjv".parse::<SolverKind>().unwrap(), SolverKind::Lapjv);
        assert_eq!("auction".parse::<SolverKind>().unwrap(), SolverKind::Auction);
        assert!("nope".parse::<SolverKind>().is_err());
    }

    #[test]
    fn solver_kind_display_round_trips() {
        for s in SolverKind::ALL {
            assert_eq!(s.to_string().parse::<SolverKind>().unwrap(), s);
        }
        assert_eq!(SolverKind::accepted(), "lapjv|auction|greedy");
        let err = "nope".parse::<SolverKind>().unwrap_err();
        assert!(err.to_string().contains("lapjv|auction|greedy"), "{err}");
    }

    #[test]
    fn candidate_mode_round_trips_and_resolves() {
        for (s, want) in [
            ("auto", CandidateMode::Auto),
            ("dense", CandidateMode::Dense),
            ("24", CandidateMode::Fixed(24)),
        ] {
            assert_eq!(s.parse::<CandidateMode>().unwrap(), want);
            assert_eq!(want.to_string(), s);
        }
        for bad in ["0", "-3", "sparse", ""] {
            assert!(bad.parse::<CandidateMode>().is_err(), "{bad}");
        }
        // Dense and any C >= k resolve to "no pruning" (effective == k).
        assert_eq!(CandidateMode::Dense.effective(100), 100);
        assert_eq!(CandidateMode::Fixed(100).effective(100), 100);
        assert_eq!(CandidateMode::Fixed(500).effective(100), 100);
        assert_eq!(CandidateMode::Fixed(8).effective(100), 8);
        // Auto: dense below the threshold, AUTO_C above it.
        assert_eq!(CandidateMode::Auto.effective(100), 100);
        assert_eq!(
            CandidateMode::Auto.effective(CandidateMode::AUTO_MIN_K),
            CandidateMode::AUTO_C
        );
    }

    #[test]
    fn validity_checker() {
        assert!(is_valid_assignment(&[2, 0, 1], 3));
        assert!(!is_valid_assignment(&[0, 0], 3));
        assert!(!is_valid_assignment(&[3], 3));
    }

    #[test]
    fn all_solvers_agree_on_diagonal_dominant() {
        // A matrix where the identity assignment is clearly optimal.
        let n = 5;
        let mut cost = vec![0f32; n * n];
        for i in 0..n {
            cost[i * n + i] = 100.0;
        }
        for kind in [SolverKind::Lapjv, SolverKind::Auction, SolverKind::Greedy] {
            let a = solve_max(kind, &cost, n, n);
            assert_eq!(a, vec![0, 1, 2, 3, 4], "{kind:?}");
        }
    }
}
