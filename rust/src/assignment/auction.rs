//! Bertsekas auction algorithm with ε-scaling.
//!
//! The paper's §6 names approximate assignment solvers (specifically the
//! auction algorithm, Bertsekas 1979) as future work for ABA; this module
//! implements it so the repo can benchmark that future-work path today
//! (see `benches/bench_assignment.rs` and the ablation in EXPERIMENTS.md).
//!
//! Forward auction: unassigned rows (bidders) bid for their most valuable
//! column (object) at price increment `best - second_best + ε`. Each
//! ε-phase terminates with an assignment within `nr·ε` of optimal;
//! ε-scaling (divide by 4 each phase) drives the gap to a configurable
//! tolerance.

/// Max-cost rectangular assignment (`nr <= nc`) via ε-scaled auction.
pub fn solve_max(cost: &[f32], nr: usize, nc: usize) -> Vec<usize> {
    solve_max_eps(cost, nr, nc, 1e-6)
}

/// As [`solve_max`] with an explicit final ε (relative to max |cost|).
pub fn solve_max_eps(cost: &[f32], nr: usize, nc: usize, rel_eps: f64) -> Vec<usize> {
    assert!(nr <= nc);
    assert_eq!(cost.len(), nr * nc);
    if nr == 0 {
        return Vec::new();
    }
    // Rectangular instances are squared by padding with zero-cost dummy
    // rows: the ε-CS optimality bound of the forward auction only holds
    // when every column ends up assigned (stale prices on abandoned
    // columns otherwise break the duality argument).
    if nr < nc {
        let mut square = vec![0f32; nc * nc];
        square[..nr * nc].copy_from_slice(cost);
        let full = solve_max_eps(&square, nc, nc, rel_eps);
        return full[..nr].to_vec();
    }
    let max_abs = cost
        .iter()
        .fold(0f64, |m, &c| m.max((c as f64).abs()))
        .max(1e-12);
    let eps_final = rel_eps * max_abs;
    let mut eps = (max_abs / 4.0).max(eps_final);
    let mut prices = vec![0f64; nc];
    let mut row_of = vec![usize::MAX; nc]; // column -> row
    let mut col_of = vec![usize::MAX; nr]; // row -> column

    loop {
        // Reset assignments for this ε-phase (prices persist — the warm
        // start is what makes ε-scaling effective).
        row_of.fill(usize::MAX);
        col_of.fill(usize::MAX);
        let mut unassigned: Vec<usize> = (0..nr).collect();
        while let Some(i) = unassigned.pop() {
            let row = &cost[i * nc..(i + 1) * nc];
            // Best and second-best net value.
            let mut best_j = 0usize;
            let mut best_v = f64::NEG_INFINITY;
            let mut second_v = f64::NEG_INFINITY;
            for (j, &c) in row.iter().enumerate() {
                let v = c as f64 - prices[j];
                if v > best_v {
                    second_v = best_v;
                    best_v = v;
                    best_j = j;
                } else if v > second_v {
                    second_v = v;
                }
            }
            if second_v == f64::NEG_INFINITY {
                second_v = best_v; // nc == 1 degenerate case
            }
            prices[best_j] += best_v - second_v + eps;
            if row_of[best_j] != usize::MAX {
                let evicted = row_of[best_j];
                col_of[evicted] = usize::MAX;
                unassigned.push(evicted);
            }
            row_of[best_j] = i;
            col_of[i] = best_j;
        }
        if eps <= eps_final {
            break;
        }
        eps = (eps / 4.0).max(eps_final * 0.999_999);
    }
    col_of
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::{assignment_cost, brute, is_valid_assignment};
    use crate::rng::Pcg32;

    #[test]
    fn matches_brute_on_small_instances() {
        let mut rng = Pcg32::new(31);
        for n in 2..=6 {
            for _ in 0..10 {
                let cost: Vec<f32> = (0..n * n).map(|_| rng.f32() * 9.0).collect();
                let a = solve_max(&cost, n, n);
                assert!(is_valid_assignment(&a, n));
                let b = brute::solve_max(&cost, n, n);
                let (ac, bc) = (
                    assignment_cost(&cost, n, &a),
                    assignment_cost(&cost, n, &b),
                );
                assert!((ac - bc).abs() <= 1e-3 * bc.abs().max(1.0), "auction={ac} opt={bc}");
            }
        }
    }

    #[test]
    fn rectangular_valid_and_near_optimal() {
        let mut rng = Pcg32::new(32);
        let (nr, nc) = (4, 9);
        for _ in 0..10 {
            let cost: Vec<f32> = (0..nr * nc).map(|_| rng.f32() * 5.0).collect();
            let a = solve_max(&cost, nr, nc);
            assert!(is_valid_assignment(&a, nc));
            let b = brute::solve_max(&cost, nr, nc);
            let (ac, bc) = (
                assignment_cost(&cost, nc, &a),
                assignment_cost(&cost, nc, &b),
            );
            assert!(ac >= bc - 1e-3 * bc.abs().max(1.0));
        }
    }

    #[test]
    fn single_column() {
        let a = solve_max(&[2.0], 1, 1);
        assert_eq!(a, vec![0]);
    }

    #[test]
    fn constant_costs_terminate() {
        let cost = vec![1.0f32; 5 * 5];
        let a = solve_max(&cost, 5, 5);
        assert!(is_valid_assignment(&a, 5));
    }
}
