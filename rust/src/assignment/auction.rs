//! Bertsekas auction algorithm with ε-scaling.
//!
//! The paper's §6 names approximate assignment solvers (specifically the
//! auction algorithm, Bertsekas 1979) as future work for ABA; this module
//! implements it so the repo can benchmark that future-work path today
//! (see `benches/bench_assignment.rs` and the ablation in EXPERIMENTS.md).
//!
//! Forward auction: unassigned rows (bidders) bid for their most valuable
//! column (object) at price increment `best - second_best + ε`. Each
//! ε-phase terminates with an assignment within `nr·ε` of optimal;
//! ε-scaling (divide by 4 each phase) drives the gap to a configurable
//! tolerance.
//!
//! The solver is a reusable struct ([`Auction`]): prices, assignment
//! arrays, and — for rectangular instances — the squared padding buffer
//! all live in owned scratch, so repeated per-batch solves (the
//! `--solver auction` hot path, where every final ragged batch is
//! rectangular) perform no allocations after warm-up beyond the returned
//! assignment itself. The free functions remain as one-shot conveniences.

/// Reusable ε-scaling auction solver. See the module docs; build once
/// (the assignment loop's scratch owns one) and call
/// [`Auction::solve_max`] per batch.
#[derive(Default)]
pub struct Auction {
    /// Zero-padded `nc x nc` copy for rectangular instances (reused —
    /// this used to be a fresh allocation on every call).
    square: Vec<f32>,
    prices: Vec<f64>,
    /// column -> row
    row_of: Vec<usize>,
    /// row -> column
    col_of: Vec<usize>,
    unassigned: Vec<usize>,
}

impl Auction {
    pub fn new() -> Self {
        Self::default()
    }

    /// Max-cost rectangular assignment (`nr <= nc`) with the default
    /// final ε (1e-6 relative to max |cost|).
    pub fn solve_max(&mut self, cost: &[f32], nr: usize, nc: usize) -> Vec<usize> {
        self.solve_max_eps(cost, nr, nc, 1e-6)
    }

    /// As [`Auction::solve_max`] with an explicit final ε (relative to
    /// max |cost|).
    pub fn solve_max_eps(
        &mut self,
        cost: &[f32],
        nr: usize,
        nc: usize,
        rel_eps: f64,
    ) -> Vec<usize> {
        assert!(nr <= nc);
        assert_eq!(cost.len(), nr * nc);
        if nr == 0 {
            return Vec::new();
        }
        // Rectangular instances are squared by padding with zero-cost
        // dummy rows: the ε-CS optimality bound of the forward auction
        // only holds when every column ends up assigned (stale prices on
        // abandoned columns otherwise break the duality argument). The
        // padded copy lives in reusable scratch.
        if nr < nc {
            let mut square = std::mem::take(&mut self.square);
            square.clear();
            square.resize(nc * nc, 0.0);
            square[..nr * nc].copy_from_slice(cost);
            let mut full = self.solve_square(&square, nc, rel_eps);
            self.square = square;
            full.truncate(nr);
            return full;
        }
        self.solve_square(cost, nc, rel_eps)
    }

    fn solve_square(&mut self, cost: &[f32], n: usize, rel_eps: f64) -> Vec<usize> {
        debug_assert_eq!(cost.len(), n * n);
        let max_abs = cost
            .iter()
            .fold(0f64, |m, &c| m.max((c as f64).abs()))
            .max(1e-12);
        let eps_final = rel_eps * max_abs;
        let mut eps = (max_abs / 4.0).max(eps_final);
        self.prices.clear();
        self.prices.resize(n, 0.0);
        self.row_of.clear();
        self.row_of.resize(n, usize::MAX);
        self.col_of.clear();
        self.col_of.resize(n, usize::MAX);

        loop {
            // Reset assignments for this ε-phase (prices persist — the
            // warm start is what makes ε-scaling effective).
            self.row_of.fill(usize::MAX);
            self.col_of.fill(usize::MAX);
            self.unassigned.clear();
            self.unassigned.extend(0..n);
            while let Some(i) = self.unassigned.pop() {
                let row = &cost[i * n..(i + 1) * n];
                // Best and second-best net value.
                let mut best_j = 0usize;
                let mut best_v = f64::NEG_INFINITY;
                let mut second_v = f64::NEG_INFINITY;
                for (j, &c) in row.iter().enumerate() {
                    let v = c as f64 - self.prices[j];
                    if v > best_v {
                        second_v = best_v;
                        best_v = v;
                        best_j = j;
                    } else if v > second_v {
                        second_v = v;
                    }
                }
                if second_v == f64::NEG_INFINITY {
                    second_v = best_v; // n == 1 degenerate case
                }
                self.prices[best_j] += best_v - second_v + eps;
                if self.row_of[best_j] != usize::MAX {
                    let evicted = self.row_of[best_j];
                    self.col_of[evicted] = usize::MAX;
                    self.unassigned.push(evicted);
                }
                self.row_of[best_j] = i;
                self.col_of[i] = best_j;
            }
            if eps <= eps_final {
                break;
            }
            eps = (eps / 4.0).max(eps_final * 0.999_999);
        }
        self.col_of.clone()
    }
}

/// Max-cost rectangular assignment (`nr <= nc`) via ε-scaled auction —
/// one-shot convenience over a throwaway [`Auction`].
pub fn solve_max(cost: &[f32], nr: usize, nc: usize) -> Vec<usize> {
    Auction::new().solve_max(cost, nr, nc)
}

/// As [`solve_max`] with an explicit final ε (relative to max |cost|).
pub fn solve_max_eps(cost: &[f32], nr: usize, nc: usize, rel_eps: f64) -> Vec<usize> {
    Auction::new().solve_max_eps(cost, nr, nc, rel_eps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::{assignment_cost, brute, is_valid_assignment};
    use crate::rng::Pcg32;

    #[test]
    fn matches_brute_on_small_instances() {
        let mut rng = Pcg32::new(31);
        for n in 2..=6 {
            for _ in 0..10 {
                let cost: Vec<f32> = (0..n * n).map(|_| rng.f32() * 9.0).collect();
                let a = solve_max(&cost, n, n);
                assert!(is_valid_assignment(&a, n));
                let b = brute::solve_max(&cost, n, n);
                let (ac, bc) = (
                    assignment_cost(&cost, n, &a),
                    assignment_cost(&cost, n, &b),
                );
                assert!((ac - bc).abs() <= 1e-3 * bc.abs().max(1.0), "auction={ac} opt={bc}");
            }
        }
    }

    #[test]
    fn rectangular_valid_and_near_optimal() {
        let mut rng = Pcg32::new(32);
        let (nr, nc) = (4, 9);
        for _ in 0..10 {
            let cost: Vec<f32> = (0..nr * nc).map(|_| rng.f32() * 5.0).collect();
            let a = solve_max(&cost, nr, nc);
            assert!(is_valid_assignment(&a, nc));
            let b = brute::solve_max(&cost, nr, nc);
            let (ac, bc) = (
                assignment_cost(&cost, nc, &a),
                assignment_cost(&cost, nc, &b),
            );
            assert!(ac >= bc - 1e-3 * bc.abs().max(1.0));
        }
    }

    #[test]
    fn reused_instance_matches_one_shot_across_shapes() {
        // Buffer reuse (incl. the rectangular padding scratch) must be
        // invisible: a solver instance cycled through mixed shapes gives
        // the same assignments as fresh one-shot calls.
        let mut solver = Auction::new();
        let mut rng = Pcg32::new(33);
        for &(nr, nc) in &[(4usize, 9usize), (5, 5), (2, 7), (6, 6), (3, 8)] {
            let cost: Vec<f32> = (0..nr * nc).map(|_| rng.f32() * 7.0).collect();
            let reused = solver.solve_max(&cost, nr, nc);
            let fresh = solve_max(&cost, nr, nc);
            assert!(is_valid_assignment(&reused, nc), "{nr}x{nc}");
            assert_eq!(reused, fresh, "{nr}x{nc}");
        }
    }

    #[test]
    fn single_column() {
        let a = solve_max(&[2.0], 1, 1);
        assert_eq!(a, vec![0]);
    }

    #[test]
    fn constant_costs_terminate() {
        let cost = vec![1.0f32; 5 * 5];
        let a = solve_max(&cost, 5, 5);
        assert!(is_valid_assignment(&a, 5));
    }
}
