//! LAPJV — Jonker–Volgenant-style shortest-augmenting-path solver for the
//! dense rectangular linear assignment problem.
//!
//! This is the exact solver Algorithm 1 calls once per batch. For each of
//! the `nr` rows it grows a shortest augmenting path in the reduced-cost
//! graph maintained by dual potentials `u` (rows) and `v` (columns) — the
//! augmentation phase of Jonker & Volgenant (1987). Complexity is
//! `O(nr * nc^2)` worst case, i.e. `O(K^3)` for the paper's square `K x K`
//! batches, matching the complexity analysis in §4.5.
//!
//! The struct owns its scratch buffers so the per-batch hot path performs
//! **zero allocations** after warm-up (see EXPERIMENTS.md §Perf).
//!
//! Costs are `f32` (as produced by the L1 kernel / native backend) and the
//! duals are accumulated in `f64` for numerical robustness.

/// Reusable Jonker–Volgenant solver.
pub struct Lapjv {
    /// Enable the JV column/row-reduction warm start (default on; the
    /// off switch exists for the §Perf ablation in `bench_assignment`).
    pub warm_start: bool,
    // p[j] = row assigned to column j (1-based; 0 = unassigned).
    p: Vec<usize>,
    way: Vec<usize>,
    u: Vec<f64>,
    v: Vec<f64>,
    minv: Vec<f64>,
    used: Vec<bool>,
}

impl Default for Lapjv {
    fn default() -> Self {
        Self::new()
    }
}

impl Lapjv {
    pub fn new() -> Self {
        Self {
            warm_start: true,
            p: Vec::new(),
            way: Vec::new(),
            u: Vec::new(),
            v: Vec::new(),
            minv: Vec::new(),
            used: Vec::new(),
        }
    }

    fn reserve(&mut self, nr: usize, nc: usize) {
        self.p.clear();
        self.p.resize(nc + 1, 0);
        self.way.clear();
        self.way.resize(nc + 1, 0);
        self.u.clear();
        self.u.resize(nr + 1, 0.0);
        self.v.clear();
        self.v.resize(nc + 1, 0.0);
        self.minv.resize(nc + 1, f64::INFINITY);
        self.used.resize(nc + 1, false);
    }

    /// Solve the assignment problem on a row-major `nr x nc` cost matrix
    /// (`nr <= nc`). Returns, for each row, its assigned column.
    /// `maximize` selects max-cost (the ABA objective) vs min-cost.
    pub fn solve(&mut self, cost: &[f32], nr: usize, nc: usize, maximize: bool) -> Vec<usize> {
        assert!(nr <= nc, "lapjv requires nr <= nc (got {nr} x {nc})");
        assert_eq!(cost.len(), nr * nc, "cost buffer shape mismatch");
        if nr == 0 {
            return Vec::new();
        }
        let sign = if maximize { -1.0f64 } else { 1.0f64 };
        self.reserve(nr, nc);
        let (p, way, u, v, minv, used) = (
            &mut self.p,
            &mut self.way,
            &mut self.u,
            &mut self.v,
            &mut self.minv,
            &mut self.used,
        );

        // --- JV initialization (square instances only): column reduction
        // + row reduction + tight greedy matching — the classic
        // Jonker–Volgenant warm start. It leaves dual-feasible potentials
        // (all reduced costs >= 0) and a partial matching on tight edges,
        // so the augmentation phase below only runs for the leftover
        // rows; typically 60–90% of rows are matched up front (see
        // EXPERIMENTS.md §Perf). Rectangular instances skip it: with
        // unmatched columns the LP dual requires v[j] = 0 on every column
        // that ends up unmatched, which column reduction cannot know in
        // advance — the cold start (v = 0, only ever decreased on matched
        // columns) is what preserves that complementary slackness. ABA's
        // batches are square except the final partial one, so this covers
        // the hot path.
        let mut row_assigned = vec![false; nr + 1];
        if self.warm_start && nr == nc {
            // Column reduction: v[j] = min_i c(i, j).
            for j in 1..=nc {
                let mut m = f64::INFINITY;
                for i in 0..nr {
                    let c = sign * cost[i * nc + (j - 1)] as f64;
                    if c < m {
                        m = c;
                    }
                }
                v[j] = m;
            }
            // Row reduction over reduced costs + greedy tight assignment.
            let mut assigned_rows = 0usize;
            for i in 1..=nr {
                let row = &cost[(i - 1) * nc..i * nc];
                let mut m = f64::INFINITY;
                let mut arg = 1usize;
                for j in 1..=nc {
                    let rc = sign * row[j - 1] as f64 - v[j];
                    if rc < m {
                        m = rc;
                        arg = j;
                    }
                }
                u[i] = m;
                if p[arg] == 0 {
                    p[arg] = i;
                    assigned_rows += 1;
                }
            }
            if assigned_rows == nr {
                let mut assign = vec![usize::MAX; nr];
                for j in 1..=nc {
                    if p[j] != 0 {
                        assign[p[j] - 1] = j - 1;
                    }
                }
                return assign;
            }
            for j in 1..=nc {
                if p[j] != 0 {
                    row_assigned[p[j]] = true;
                }
            }
        }

        for i in 1..=nr {
            if row_assigned[i] {
                continue;
            }
            p[0] = i;
            let mut j0 = 0usize;
            minv[..=nc].fill(f64::INFINITY);
            used[..=nc].fill(false);
            // Dijkstra over columns for the shortest augmenting path.
            loop {
                used[j0] = true;
                let i0 = p[j0];
                let row = &cost[(i0 - 1) * nc..i0 * nc];
                let mut delta = f64::INFINITY;
                let mut j1 = 0usize;
                let u_i0 = u[i0];
                for j in 1..=nc {
                    if !used[j] {
                        let cur = sign * row[j - 1] as f64 - u_i0 - v[j];
                        if cur < minv[j] {
                            minv[j] = cur;
                            way[j] = j0;
                        }
                        if minv[j] < delta {
                            delta = minv[j];
                            j1 = j;
                        }
                    }
                }
                debug_assert!(delta.is_finite(), "no augmenting path found");
                for j in 0..=nc {
                    if used[j] {
                        u[p[j]] += delta;
                        v[j] -= delta;
                    } else {
                        minv[j] -= delta;
                    }
                }
                j0 = j1;
                if p[j0] == 0 {
                    break;
                }
            }
            // Unwind the augmenting path.
            loop {
                let j1 = way[j0];
                p[j0] = p[j1];
                j0 = j1;
                if j0 == 0 {
                    break;
                }
            }
        }

        let mut assign = vec![usize::MAX; nr];
        for j in 1..=nc {
            if p[j] != 0 {
                assign[p[j] - 1] = j - 1;
            }
        }
        debug_assert!(assign.iter().all(|&j| j != usize::MAX));
        assign
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::{assignment_cost, brute, is_valid_assignment};
    use crate::rng::Pcg32;

    fn rand_cost(rng: &mut Pcg32, nr: usize, nc: usize, scale: f32) -> Vec<f32> {
        (0..nr * nc).map(|_| rng.f32() * scale).collect()
    }

    #[test]
    fn solves_trivial_1x1() {
        let a = Lapjv::new().solve(&[3.5], 1, 1, true);
        assert_eq!(a, vec![0]);
    }

    #[test]
    fn square_matches_brute_force_max() {
        let mut rng = Pcg32::new(10);
        for n in 1..=7 {
            for _ in 0..20 {
                let cost = rand_cost(&mut rng, n, n, 10.0);
                let got = Lapjv::new().solve(&cost, n, n, true);
                assert!(is_valid_assignment(&got, n));
                let want = brute::solve_max(&cost, n, n);
                let got_c = assignment_cost(&cost, n, &got);
                let want_c = assignment_cost(&cost, n, &want);
                assert!(
                    (got_c - want_c).abs() < 1e-4,
                    "n={n} lapjv={got_c} brute={want_c}"
                );
            }
        }
    }

    #[test]
    fn rectangular_matches_brute_force() {
        let mut rng = Pcg32::new(11);
        for &(nr, nc) in &[(1, 4), (2, 5), (3, 6), (4, 7), (5, 8)] {
            for _ in 0..10 {
                let cost = rand_cost(&mut rng, nr, nc, 5.0);
                let got = Lapjv::new().solve(&cost, nr, nc, true);
                assert!(is_valid_assignment(&got, nc));
                let want = brute::solve_max(&cost, nr, nc);
                let got_c = assignment_cost(&cost, nc, &got);
                let want_c = assignment_cost(&cost, nc, &want);
                assert!((got_c - want_c).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn minimize_matches_negated_maximize() {
        let mut rng = Pcg32::new(12);
        let (nr, nc) = (6, 6);
        let cost = rand_cost(&mut rng, nr, nc, 3.0);
        let min_a = Lapjv::new().solve(&cost, nr, nc, false);
        let neg: Vec<f32> = cost.iter().map(|&c| -c).collect();
        let max_a = Lapjv::new().solve(&neg, nr, nc, true);
        assert_eq!(
            assignment_cost(&cost, nc, &min_a),
            assignment_cost(&cost, nc, &max_a)
        );
    }

    #[test]
    fn handles_ties_and_constant_matrix() {
        let cost = vec![1.0f32; 4 * 4];
        let a = Lapjv::new().solve(&cost, 4, 4, true);
        assert!(is_valid_assignment(&a, 4));
    }

    #[test]
    fn handles_negative_costs() {
        // Categorical masking writes large negative entries.
        let cost = vec![
            -1e6, 5.0, 1.0, //
            2.0, -1e6, 1.0, //
            3.0, 4.0, -1e6,
        ];
        let a = Lapjv::new().solve(&cost, 3, 3, true);
        assert!(is_valid_assignment(&a, 3));
        // Optimal avoids all masked entries: rows take (1, 2, 0) or (1,0?..)
        let total = assignment_cost(&cost, 3, &a);
        assert!(total > 0.0, "picked a masked entry: {a:?} total={total}");
    }

    #[test]
    fn reusing_solver_instance_is_clean() {
        let mut solver = Lapjv::new();
        let mut rng = Pcg32::new(13);
        for n in [3usize, 7, 2, 9, 1] {
            let cost = rand_cost(&mut rng, n, n, 8.0);
            let a = solver.solve(&cost, n, n, true);
            assert!(is_valid_assignment(&a, n));
            let want = brute::solve_max(&cost, n, n);
            assert!(
                (assignment_cost(&cost, n, &a) - assignment_cost(&cost, n, &want)).abs() < 1e-4
            );
        }
    }

    #[test]
    fn warm_start_and_cold_start_agree() {
        let mut rng = Pcg32::new(14);
        for n in [1usize, 4, 9, 17, 33] {
            let cost = rand_cost(&mut rng, n, n, 50.0);
            let warm = Lapjv::new().solve(&cost, n, n, true);
            let mut cold_solver = Lapjv::new();
            cold_solver.warm_start = false;
            let cold = cold_solver.solve(&cost, n, n, true);
            assert!(
                (assignment_cost(&cost, n, &warm) - assignment_cost(&cost, n, &cold)).abs()
                    < 1e-6,
                "n={n}"
            );
        }
    }

    #[test]
    fn zero_rows_is_empty() {
        let a = Lapjv::new().solve(&[], 0, 5, true);
        assert!(a.is_empty());
    }

    #[test]
    #[should_panic(expected = "nr <= nc")]
    fn rejects_more_rows_than_cols() {
        Lapjv::new().solve(&[0.0; 6], 3, 2, true);
    }
}
