//! Exhaustive assignment solver — the correctness oracle.
//!
//! Enumerates all injections of rows into columns; exponential, so only
//! usable for `nr <= 9`-ish. Every exact solver in this crate is tested
//! against it.

/// Max-cost assignment by exhaustive search. Returns row -> column.
pub fn solve_max(cost: &[f32], nr: usize, nc: usize) -> Vec<usize> {
    assert!(nr <= nc);
    assert!(nr <= 10, "brute force limited to 10 rows (got {nr})");
    let mut best = vec![0usize; nr];
    let mut cur = vec![0usize; nr];
    let mut used = vec![false; nc];
    let mut best_cost = f64::NEG_INFINITY;
    recurse(cost, nr, nc, 0, 0.0, &mut cur, &mut used, &mut best, &mut best_cost);
    best
}

#[allow(clippy::too_many_arguments)]
fn recurse(
    cost: &[f32],
    nr: usize,
    nc: usize,
    row: usize,
    acc: f64,
    cur: &mut [usize],
    used: &mut [bool],
    best: &mut Vec<usize>,
    best_cost: &mut f64,
) {
    if row == nr {
        if acc > *best_cost {
            *best_cost = acc;
            best.copy_from_slice(cur);
        }
        return;
    }
    for j in 0..nc {
        if !used[j] {
            used[j] = true;
            cur[row] = j;
            recurse(
                cost,
                nr,
                nc,
                row + 1,
                acc + cost[row * nc + j] as f64,
                cur,
                used,
                best,
                best_cost,
            );
            used[j] = false;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::{assignment_cost, is_valid_assignment};

    #[test]
    fn two_by_two() {
        // max is anti-diagonal: 5 + 4 = 9 vs 1 + 2 = 3.
        let cost = vec![1.0, 5.0, 4.0, 2.0];
        assert_eq!(solve_max(&cost, 2, 2), vec![1, 0]);
    }

    #[test]
    fn rectangular_picks_best_columns() {
        // Single row: best column is the argmax.
        let cost = vec![1.0, 9.0, 3.0];
        assert_eq!(solve_max(&cost, 1, 3), vec![1]);
    }

    #[test]
    fn output_always_valid() {
        let cost: Vec<f32> = (0..3 * 5).map(|i| (i * 7 % 11) as f32).collect();
        let a = solve_max(&cost, 3, 5);
        assert!(is_valid_assignment(&a, 5));
        assert!(assignment_cost(&cost, 5, &a) > 0.0);
    }
}
