//! Sparse (candidate-pruned) max-cost assignment.
//!
//! The dense per-batch solve is `O(k^2 d)` to build the cost matrix and
//! `O(k^3)` to solve it — at `k = 100_000` the matrix alone is ~40 GB,
//! so the paper's large-K regime cannot even be *represented* densely.
//! This module solves the same max-cost assignment on a **restricted
//! edge set**: each batch object carries only its top-`C` highest-cost
//! candidate anticlusters (`C ≈ 16–64`, produced by
//! [`crate::knn::farthest`]), assembled into a CSR structure.
//!
//! Jonker & Volgenant (1987) note that the shortest-augmenting-path
//! solver stays exact on restricted edge sets as long as the pruned
//! bipartite graph still admits a perfect matching; when it does not
//! (Hall's condition fails), the solvers here report `None` and the
//! assignment loop's feasibility repair escalates `C` and ultimately
//! falls back to the dense path.
//!
//! Both solvers are generic over [`CostAccess`], so the same code runs
//! on a [`DenseCost`] wrapper (used by the exactness property tests to
//! compare against the dense LAPJV oracle) and on the production
//! [`CsrCost`]:
//!
//! * [`SparseLapjv`] — the augmenting-path LAPJV variant. Exact on the
//!   given edge set. Per augmentation it only touches columns reachable
//!   through candidate edges (a `touched` list), so a batch solves in
//!   roughly `O(k · C · path_len)` instead of `O(k^3)`.
//! * [`SparseAuction`] — Bertsekas ε-scaling auction over candidate
//!   lists (the paper's §6 future-work solver, naturally suited to
//!   sparse bids). Near-optimal rather than exact on rectangular
//!   instances; a bid cap detects price wars on infeasible instances.

use crate::assignment::is_valid_assignment;

/// Read access to a (possibly sparse) `nr x nc` cost structure. Rows
/// are batch objects, columns anticlusters; absent entries are
/// forbidden edges.
pub trait CostAccess {
    /// Number of rows (batch objects).
    fn nr(&self) -> usize;
    /// Number of columns (anticlusters).
    fn nc(&self) -> usize;
    /// Call `f(col, cost)` for every candidate entry of row `i`.
    fn for_row(&self, i: usize, f: &mut dyn FnMut(usize, f32));
}

/// A dense row-major matrix viewed through [`CostAccess`] (every entry
/// is a candidate). Used by tests/benches to compare the sparse solvers
/// against the dense oracle on identical inputs.
pub struct DenseCost<'a> {
    pub cost: &'a [f32],
    pub nr: usize,
    pub nc: usize,
}

impl CostAccess for DenseCost<'_> {
    fn nr(&self) -> usize {
        self.nr
    }
    fn nc(&self) -> usize {
        self.nc
    }
    fn for_row(&self, i: usize, f: &mut dyn FnMut(usize, f32)) {
        for (j, &c) in self.cost[i * self.nc..(i + 1) * self.nc].iter().enumerate() {
            f(j, c);
        }
    }
}

/// A borrowed CSR cost structure: row `i`'s candidates live at
/// `row_ptr[i]..row_ptr[i + 1]` in `cols`/`vals`. The assignment loop
/// assembles one per batch in its scratch and solves it in place.
pub struct CsrCost<'a> {
    pub row_ptr: &'a [usize],
    pub cols: &'a [u32],
    pub vals: &'a [f32],
    pub nc: usize,
}

impl CostAccess for CsrCost<'_> {
    fn nr(&self) -> usize {
        self.row_ptr.len().saturating_sub(1)
    }
    fn nc(&self) -> usize {
        self.nc
    }
    fn for_row(&self, i: usize, f: &mut dyn FnMut(usize, f32)) {
        for t in self.row_ptr[i]..self.row_ptr[i + 1] {
            f(self.cols[t] as usize, self.vals[t]);
        }
    }
}

/// Telemetry for the candidate-pruned assignment path, accumulated on
/// the session scratch across `partition` calls (see
/// [`crate::Aba::sparse_stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SparseStats {
    /// Batches solved on the sparse (candidate-pruned) path.
    pub sparse_batches: usize,
    /// Batches solved on the dense path (dense mode or fallback).
    pub dense_batches: usize,
    /// Dense batches caused by feasibility repair giving up (subset of
    /// `dense_batches`).
    pub fallback_batches: usize,
    /// Candidate-list regenerations (each doubles `C`) before either a
    /// sparse solve succeeded or the dense fallback engaged.
    pub escalations: usize,
    /// Peak bytes of the per-batch cost structure actually solved:
    /// `m * k * 4` for a dense batch, CSR entry + row-pointer bytes for
    /// a sparse one.
    pub peak_cost_bytes: usize,
}

// ---------------------------------------------------------------------------
// CSR-aware LAPJV
// ---------------------------------------------------------------------------

/// Reusable augmenting-path LAPJV over a [`CostAccess`]. Exact max-cost
/// assignment on the given edge set; `None` when the edge set admits no
/// perfect matching on the rows.
///
/// Identical dual machinery to the dense [`crate::assignment::Lapjv`]
/// (1-based columns, column 0 virtual, `f64` potentials), but each
/// Dijkstra step only relaxes the current row's candidate edges and the
/// delta scan runs over the `touched` column list instead of all `nc`
/// columns — untouched columns have `minv = +inf` and can never be the
/// argmin, so restricting the scan is exact, not approximate.
#[derive(Default)]
pub struct SparseLapjv {
    /// p[j] = row assigned to column j (1-based; 0 = unassigned).
    p: Vec<usize>,
    way: Vec<usize>,
    u: Vec<f64>,
    v: Vec<f64>,
    minv: Vec<f64>,
    used: Vec<bool>,
    /// Columns whose `minv` became finite during the current
    /// augmentation (the only delta-scan candidates).
    touched: Vec<u32>,
}

impl SparseLapjv {
    pub fn new() -> Self {
        Self::default()
    }

    fn reserve(&mut self, nr: usize, nc: usize) {
        self.p.clear();
        self.p.resize(nc + 1, 0);
        self.way.clear();
        self.way.resize(nc + 1, 0);
        self.u.clear();
        self.u.resize(nr + 1, 0.0);
        self.v.clear();
        self.v.resize(nc + 1, 0.0);
        self.minv.clear();
        self.minv.resize(nc + 1, f64::INFINITY);
        self.used.clear();
        self.used.resize(nc + 1, false);
        self.touched.clear();
    }

    /// Reset per-augmentation state so the next row (or the next solve)
    /// starts clean.
    fn clear_augmentation(&mut self) {
        for &jt in &self.touched {
            let j = jt as usize;
            self.minv[j] = f64::INFINITY;
            self.used[j] = false;
        }
        self.used[0] = false;
        self.touched.clear();
    }

    /// Max-cost assignment (`nr <= nc` rows to distinct columns over
    /// the candidate edges). Returns, for each row, its column — or
    /// `None` when no perfect matching exists on this edge set.
    pub fn solve_max<C: CostAccess>(&mut self, cost: &C) -> Option<Vec<usize>> {
        let (nr, nc) = (cost.nr(), cost.nc());
        assert!(nr <= nc, "sparse lapjv requires nr <= nc (got {nr} x {nc})");
        if nr == 0 {
            return Some(Vec::new());
        }
        self.reserve(nr, nc);
        for i in 1..=nr {
            self.p[0] = i;
            let mut j0 = 0usize;
            loop {
                self.used[j0] = true;
                let i0 = self.p[j0];
                let u_i0 = self.u[i0];
                {
                    let (minv, way, touched, used, v) = (
                        &mut self.minv,
                        &mut self.way,
                        &mut self.touched,
                        &self.used,
                        &self.v,
                    );
                    cost.for_row(i0 - 1, &mut |col, cval| {
                        let j = col + 1;
                        if !used[j] {
                            // Maximize: negate into the minimization duals.
                            let cur = -(cval as f64) - u_i0 - v[j];
                            if cur < minv[j] {
                                if minv[j].is_infinite() {
                                    touched.push(j as u32);
                                }
                                minv[j] = cur;
                                way[j] = j0;
                            }
                        }
                    });
                }
                let mut delta = f64::INFINITY;
                let mut j1 = 0usize;
                for &jt in &self.touched {
                    let j = jt as usize;
                    if !self.used[j] && self.minv[j] < delta {
                        delta = self.minv[j];
                        j1 = j;
                    }
                }
                if !delta.is_finite() {
                    // No augmenting path: Hall's condition fails on the
                    // pruned graph. The caller escalates / falls back.
                    self.clear_augmentation();
                    return None;
                }
                // Dual update. Used columns are always {0} ∪ (used ∩
                // touched); untouched unused columns keep minv = +inf.
                let p0 = self.p[0];
                self.u[p0] += delta;
                self.v[0] -= delta;
                for &jt in &self.touched {
                    let j = jt as usize;
                    if self.used[j] {
                        let pj = self.p[j];
                        self.u[pj] += delta;
                        self.v[j] -= delta;
                    } else {
                        self.minv[j] -= delta;
                    }
                }
                j0 = j1;
                if self.p[j0] == 0 {
                    break;
                }
            }
            // Unwind the augmenting path.
            loop {
                let j1 = self.way[j0];
                self.p[j0] = self.p[j1];
                j0 = j1;
                if j0 == 0 {
                    break;
                }
            }
            self.clear_augmentation();
        }
        let mut assign = vec![usize::MAX; nr];
        for j in 1..=nc {
            if self.p[j] != 0 {
                assign[self.p[j] - 1] = j - 1;
            }
        }
        debug_assert!(assign.iter().all(|&j| j != usize::MAX));
        debug_assert!(is_valid_assignment(&assign, nc));
        Some(assign)
    }
}

// ---------------------------------------------------------------------------
// Sparse auction
// ---------------------------------------------------------------------------

/// Reusable ε-scaling forward auction over a [`CostAccess`]. Bids only
/// on candidate edges, which is the sparse setting Bertsekas's
/// algorithm was designed for. Returns `None` when a row has no
/// candidates or when the bid cap trips (the signature of a price war
/// on an infeasible instance); near-optimal otherwise.
#[derive(Default)]
pub struct SparseAuction {
    prices: Vec<f64>,
    row_of: Vec<usize>,
    col_of: Vec<usize>,
    unassigned: Vec<usize>,
}

impl SparseAuction {
    pub fn new() -> Self {
        Self::default()
    }

    /// Max-cost assignment over the candidate edges; `rel_eps` is the
    /// final ε relative to the max absolute cost (1e-6 matches the
    /// dense auction default).
    pub fn solve_max<C: CostAccess>(&mut self, cost: &C, rel_eps: f64) -> Option<Vec<usize>> {
        let (nr, nc) = (cost.nr(), cost.nc());
        assert!(nr <= nc, "sparse auction requires nr <= nc (got {nr} x {nc})");
        if nr == 0 {
            return Some(Vec::new());
        }
        let mut max_abs = 1e-12f64;
        let mut min_len = usize::MAX;
        for i in 0..nr {
            let mut len = 0usize;
            cost.for_row(i, &mut |_, c| {
                len += 1;
                max_abs = max_abs.max((c as f64).abs());
            });
            min_len = min_len.min(len);
        }
        if min_len == 0 {
            return None; // a row with no candidates can never match
        }
        let eps_final = rel_eps * max_abs;
        let mut eps = (max_abs / 4.0).max(eps_final);
        self.prices.clear();
        self.prices.resize(nc, 0.0);
        self.row_of.clear();
        self.row_of.resize(nc, usize::MAX);
        self.col_of.clear();
        self.col_of.resize(nr, usize::MAX);
        // Generous per-phase bid budget: feasible instances settle in
        // O(nr) bids per phase in practice; an infeasible one bids
        // forever on its contested columns.
        let bid_cap = 200 * nr + 10_000;
        loop {
            self.row_of.fill(usize::MAX);
            self.col_of.fill(usize::MAX);
            self.unassigned.clear();
            self.unassigned.extend(0..nr);
            let mut bids = 0usize;
            while let Some(i) = self.unassigned.pop() {
                bids += 1;
                if bids > bid_cap {
                    return None;
                }
                let mut best_j = usize::MAX;
                let mut best_v = f64::NEG_INFINITY;
                let mut second_v = f64::NEG_INFINITY;
                {
                    let prices = &self.prices;
                    cost.for_row(i, &mut |j, c| {
                        let v = c as f64 - prices[j];
                        if v > best_v {
                            second_v = best_v;
                            best_v = v;
                            best_j = j;
                        } else if v > second_v {
                            second_v = v;
                        }
                    });
                }
                debug_assert!(best_j != usize::MAX, "rows checked non-empty above");
                if second_v == f64::NEG_INFINITY {
                    second_v = best_v; // single-candidate row
                }
                self.prices[best_j] += best_v - second_v + eps;
                if self.row_of[best_j] != usize::MAX {
                    let evicted = self.row_of[best_j];
                    self.col_of[evicted] = usize::MAX;
                    self.unassigned.push(evicted);
                }
                self.row_of[best_j] = i;
                self.col_of[i] = best_j;
            }
            if eps <= eps_final {
                break;
            }
            eps = (eps / 4.0).max(eps_final * 0.999_999);
        }
        Some(self.col_of.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::{assignment_cost, brute, Lapjv};
    use crate::rng::Pcg32;

    fn rand_cost(rng: &mut Pcg32, nr: usize, nc: usize, scale: f32) -> Vec<f32> {
        (0..nr * nc).map(|_| (rng.f32() - 0.3) * scale).collect()
    }

    /// Full CSR (every entry a candidate) over a dense matrix.
    fn full_csr(cost: &[f32], nr: usize, nc: usize) -> (Vec<usize>, Vec<u32>, Vec<f32>) {
        let mut row_ptr = Vec::with_capacity(nr + 1);
        let mut cols = Vec::with_capacity(nr * nc);
        let mut vals = Vec::with_capacity(nr * nc);
        row_ptr.push(0);
        for i in 0..nr {
            for j in 0..nc {
                cols.push(j as u32);
                vals.push(cost[i * nc + j]);
            }
            row_ptr.push(cols.len());
        }
        (row_ptr, cols, vals)
    }

    #[test]
    fn sparse_jv_on_dense_access_matches_dense_lapjv() {
        let mut rng = Pcg32::new(41);
        for nr in 1..=7 {
            for extra in 0..3 {
                let nc = nr + extra;
                for _ in 0..10 {
                    let cost = rand_cost(&mut rng, nr, nc, 10.0);
                    let want = Lapjv::new().solve(&cost, nr, nc, true);
                    let got = SparseLapjv::new()
                        .solve_max(&DenseCost { cost: &cost, nr, nc })
                        .expect("dense access is always feasible");
                    assert!(is_valid_assignment(&got, nc));
                    let (gc, wc) = (
                        assignment_cost(&cost, nc, &got),
                        assignment_cost(&cost, nc, &want),
                    );
                    assert!(
                        (gc - wc).abs() <= 1e-4 * wc.abs().max(1.0),
                        "sparse {gc} vs dense {wc} ({nr}x{nc})"
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_jv_on_full_csr_matches_brute() {
        let mut rng = Pcg32::new(42);
        for n in 1..=6 {
            for _ in 0..10 {
                let cost = rand_cost(&mut rng, n, n, 5.0);
                let (row_ptr, cols, vals) = full_csr(&cost, n, n);
                let csr = CsrCost { row_ptr: &row_ptr, cols: &cols, vals: &vals, nc: n };
                let got = SparseLapjv::new().solve_max(&csr).unwrap();
                let want = brute::solve_max(&cost, n, n);
                let (gc, wc) = (
                    assignment_cost(&cost, n, &got),
                    assignment_cost(&cost, n, &want),
                );
                assert!((gc - wc).abs() <= 1e-4 * wc.abs().max(1.0), "n={n}");
            }
        }
    }

    #[test]
    fn sparse_jv_respects_restricted_edges() {
        // 3 rows, 3 cols, but row i may only take columns {i, (i+1)%3}.
        // The optimum over the restricted set differs from the dense one.
        let row_ptr = vec![0usize, 2, 4, 6];
        let cols = vec![0u32, 1, 1, 2, 2, 0];
        let vals = vec![1.0f32, 5.0, 1.0, 5.0, 1.0, 5.0];
        let csr = CsrCost { row_ptr: &row_ptr, cols: &cols, vals: &vals, nc: 3 };
        let got = SparseLapjv::new().solve_max(&csr).unwrap();
        assert!(is_valid_assignment(&got, 3));
        // Every row can take its 5.0 edge simultaneously: 0->1, 1->2, 2->0.
        assert_eq!(got, vec![1, 2, 0]);
    }

    #[test]
    fn sparse_jv_detects_infeasibility() {
        // Two rows that can only take the same single column.
        let row_ptr = vec![0usize, 1, 2];
        let cols = vec![0u32, 0];
        let vals = vec![1.0f32, 2.0];
        let csr = CsrCost { row_ptr: &row_ptr, cols: &cols, vals: &vals, nc: 3 };
        assert_eq!(SparseLapjv::new().solve_max(&csr), None);
        // An empty row is infeasible too.
        let row_ptr = vec![0usize, 1, 1];
        let cols = vec![0u32];
        let vals = vec![1.0f32];
        let csr = CsrCost { row_ptr: &row_ptr, cols: &cols, vals: &vals, nc: 3 };
        assert_eq!(SparseLapjv::new().solve_max(&csr), None);
    }

    #[test]
    fn sparse_jv_instance_is_reusable_after_infeasibility() {
        let mut solver = SparseLapjv::new();
        let row_ptr = vec![0usize, 1, 2];
        let cols = vec![0u32, 0];
        let vals = vec![1.0f32, 2.0];
        let bad = CsrCost { row_ptr: &row_ptr, cols: &cols, vals: &vals, nc: 2 };
        assert_eq!(solver.solve_max(&bad), None);
        // The same instance must then solve a feasible system exactly.
        let mut rng = Pcg32::new(43);
        let cost = rand_cost(&mut rng, 5, 5, 8.0);
        let got = solver
            .solve_max(&DenseCost { cost: &cost, nr: 5, nc: 5 })
            .unwrap();
        let want = brute::solve_max(&cost, 5, 5);
        assert!(
            (assignment_cost(&cost, 5, &got) - assignment_cost(&cost, 5, &want)).abs() < 1e-4
        );
    }

    #[test]
    fn sparse_auction_near_optimal_on_full_graph() {
        let mut rng = Pcg32::new(44);
        for n in 2..=6 {
            for _ in 0..10 {
                let cost: Vec<f32> = (0..n * n).map(|_| rng.f32() * 9.0).collect();
                let (row_ptr, cols, vals) = full_csr(&cost, n, n);
                let csr = CsrCost { row_ptr: &row_ptr, cols: &cols, vals: &vals, nc: n };
                let got = SparseAuction::new().solve_max(&csr, 1e-6).unwrap();
                assert!(is_valid_assignment(&got, n));
                let want = brute::solve_max(&cost, n, n);
                let (gc, wc) = (
                    assignment_cost(&cost, n, &got),
                    assignment_cost(&cost, n, &want),
                );
                assert!(gc >= wc - 1e-3 * wc.abs().max(1.0), "auction {gc} vs opt {wc}");
            }
        }
    }

    #[test]
    fn sparse_auction_reports_infeasibility() {
        // Three rows fighting over two columns: the price war trips the
        // bid cap instead of looping forever.
        let row_ptr = vec![0usize, 2, 4, 6];
        let cols = vec![0u32, 1, 0, 1, 0, 1];
        let vals = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let csr = CsrCost { row_ptr: &row_ptr, cols: &cols, vals: &vals, nc: 4 };
        assert_eq!(SparseAuction::new().solve_max(&csr, 1e-6), None);
        // And a row with no candidates is rejected up front.
        let row_ptr = vec![0usize, 0, 1];
        let cols = vec![0u32];
        let vals = vec![1.0f32];
        let csr = CsrCost { row_ptr: &row_ptr, cols: &cols, vals: &vals, nc: 2 };
        assert_eq!(SparseAuction::new().solve_max(&csr, 1e-6), None);
    }

    #[test]
    fn zero_rows_solve_to_empty() {
        let row_ptr = vec![0usize];
        let csr = CsrCost { row_ptr: &row_ptr, cols: &[], vals: &[], nc: 4 };
        assert_eq!(SparseLapjv::new().solve_max(&csr), Some(Vec::new()));
        assert_eq!(SparseAuction::new().solve_max(&csr, 1e-6), Some(Vec::new()));
    }
}
