//! `aba` — leader entrypoint and CLI for the Assignment-Based
//! Anticlustering system.
//!
//! ```text
//! aba datasets                          list the synthetic Table-2 catalog
//! aba run --dataset travel --k 50       run ABA, print objective + stats
//! aba pareto --dataset travel --k 10    bicriterion diversity/dispersion front
//! aba table t4|t6|t8|t9|t10|t11         regenerate a paper table
//! aba fig f5|f6|f7                      regenerate a paper figure
//! aba pipeline --k 100 --epochs 3       stream mini-batches into the SGD consumer
//! aba selftest                          XLA artifacts vs native numerics check
//! ```

use aba::algo::{AbaConfig, Criterion, Variant};
use aba::assignment::{CandidateMode, SolverKind};
use aba::data::synth::{catalog, load, Scale};
use aba::experiments::{common::ExpOptions, figs, t11, t4, t4x, t8, t9};
use aba::pareto::ParetoConfig;
use aba::pipeline::{run_pipeline, BatchStrategy, PipelineConfig};
use aba::runtime::{BackendKind, KernelMode, Parallelism};
use aba::util::args::{parse_hier, Args};
use aba::util::fmt_secs;
use aba::{Aba, Anticlusterer, OnlinePartition};
use anyhow::{bail, Result};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        print_help();
        return Ok(());
    };
    match cmd {
        "datasets" => cmd_datasets(),
        "run" => cmd_run(&args),
        "pareto" => cmd_pareto(&args),
        "table" => cmd_table(&args),
        "fig" => cmd_fig(&args),
        "pipeline" => cmd_pipeline(&args),
        "update" => cmd_update(&args),
        "serve" => cmd_serve(&args),
        "snapshot" => cmd_snapshot(&args),
        "selftest" => cmd_selftest(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `aba help`)"),
    }
}

fn print_help() {
    // Accepted option values derive from the enums' own `ALL` lists, so
    // help can never drift from what `FromStr` accepts.
    println!(
        "aba — Assignment-Based Anticlustering (paper reproduction)\n\
         \n\
         commands:\n\
           datasets                         list the synthetic dataset catalog\n\
           run --dataset NAME --k K         run ABA on a catalog dataset\n\
               [--scale paper|small|tiny] [--variant {variants}]\n\
               [--solver {solvers}] [--backend {backends}]\n\
               [--hier K1xK2[xK3]] [--threads {threads}] [--parallel]\n\
               [--candidates {candidates}] [--flat] [--strict] [--out labels.csv]\n\
               [--save-partition part.json] [--certify] [--criterion {criterions}]\n\
               [--kernels {kernels}]\n\
           pareto --dataset NAME --k K      bicriterion diversity/dispersion Pareto front\n\
               [--restarts R] [--archive-cap C] [--passes P] [--partners P] [--seed S]\n\
               [--scale paper|small|tiny] [--threads {threads}]\n\
           table t4|t6|t8|t9|t10|t11        regenerate a paper table\n\
               [--k K] [--datasets a,b|all] [--scale ...] [--quick]\n\
               [--time-limit SECS] [--out-dir DIR]\n\
           fig f5|f6|f7                     regenerate a paper figure\n\
           pipeline [--dataset NAME] [--k K] [--epochs E] [--queue Q]\n\
                    [--strategy aba|evolving|random] [--churn N] [--refine B]\n\
                                            stream mini-batches into SGD\n\
           update --partition FILE          load a saved OnlinePartition, apply churn,\n\
               [--insert rows.csv] [--remove ids.csv] [--refine BUDGET]\n\
               [--save FILE] [--variant ...] [--solver ...] [--candidates ...] [--strict]\n\
                                            report delta vs from-scratch objective\n\
           serve [--addr HOST:PORT]         HTTP service over OnlinePartition handles\n\
               [--workers N] [--queue N] [--max-handles N] [--snapshot-dir DIR]\n\
               [--variant ...] [--solver ...] [--candidates ...] [--strict]\n\
               [--threads {threads}] [--kernels {kernels}]\n\
                                            (SIGTERM or POST /v1/admin/drain to stop)\n\
           snapshot inspect FILE            print snapshot header without loading it\n\
           selftest                         XLA artifacts vs native check",
        variants = Variant::accepted(),
        criterions = Criterion::accepted(),
        solvers = SolverKind::accepted(),
        backends = BackendKind::accepted(),
        threads = Parallelism::accepted(),
        candidates = CandidateMode::accepted(),
        kernels = KernelMode::accepted(),
    );
}

fn cmd_datasets() -> Result<()> {
    let mut t = aba::util::table::Table::new(
        "dataset catalog (synthetic stand-ins for Table 2; see DESIGN.md §3)",
        &["name", "paper N", "paper D", "small N", "small D", "kind"],
    )
    .left(0);
    for e in catalog() {
        t.row(vec![
            e.name.into(),
            e.paper_n.to_string(),
            e.paper_d.to_string(),
            e.small_n.to_string(),
            e.small_d.to_string(),
            format!("{:?}", e.kind),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let name = args.get("dataset").unwrap_or("travel");
    let scale: Scale = args.get_parse("scale")?.unwrap_or(Scale::Small);
    let k: usize = args.get_parse("k")?.unwrap_or(10);
    let mut builder = Aba::builder();
    if let Some(v) = args.get_parse("variant")? {
        builder = builder.variant(v);
    }
    if let Some(s) = args.get_parse("solver")? {
        builder = builder.solver(s);
    }
    if let Some(b) = args.get_parse("backend")? {
        builder = builder.backend(b);
    }
    if let Some(h) = args.get("hier") {
        builder = builder.hier(parse_hier(h)?);
    }
    // `--candidates auto|<C>|dense`: the sparse large-K assignment path.
    if let Some(c) = args.get_parse::<CandidateMode>("candidates")? {
        builder = builder.candidates(c);
    }
    // `--flat` disables the automatic Table-5 decomposition (e.g. to
    // exercise the sparse flat path at large K).
    if args.has_flag("flat") {
        builder = builder.auto_hier(false);
    }
    // `--criterion diversity|dispersion`: dispersion routes k=2 to the
    // exact coloring solver (and rejects other k with a typed error).
    let criterion = args
        .get_parse::<Criterion>("criterion")?
        .unwrap_or(Criterion::Diversity);
    builder = builder.criterion(criterion);
    // `--certify` attaches a timed, solver-independent quality
    // certificate to the solve and prints objective/bound/gap below.
    let certify = args.has_flag("certify");
    builder = builder.certify(certify);
    // `--kernels auto|scalar|fma`: distance-kernel dispatch. Unset
    // defers to the `ABA_KERNELS` env var, read once at construction.
    if let Some(m) = args.get_parse::<KernelMode>("kernels")? {
        builder = builder.kernels(m);
    }
    // `--threads serial|auto|<n>` is the parallelism knob; the bare
    // `--parallel` flag is kept as an alias for `--threads auto`.
    let par = match args.get_parse::<Parallelism>("threads")? {
        Some(p) => p,
        None if args.has_flag("parallel") => Parallelism::Auto,
        None => Parallelism::Serial,
    };
    builder = builder
        .parallelism(par)
        .strict_divisibility(args.has_flag("strict"));

    let ds = load(name, scale)?;
    println!(
        "dataset {} (n={}, d={}), k={k}, threads={}",
        ds.name,
        ds.n,
        ds.d,
        par.effective_threads()
    );
    let mut solver = builder.build()?;
    // `--save-partition FILE` keeps the result live long enough to
    // snapshot it for later `aba update` churn, then freezes it.
    let part = match args.get("save-partition") {
        Some(path) => {
            let live = solver.partition_online(&ds.view(), k)?;
            live.save(path)?;
            println!("online partition saved to {path}");
            live.into_partition()
        }
        None => solver.partition(&ds, k)?,
    };
    let stats = &part.stats;
    println!(
        "cpu            {} s (order {}, assign {}, stats {}, kernels {})",
        fmt_secs(part.timings.total_secs),
        fmt_secs(part.timings.order_secs),
        fmt_secs(part.timings.assign_secs),
        fmt_secs(part.timings.stats_secs),
        part.timings.kernel_isa
    );
    println!("ofv (ssd)      {:.4}", part.objective);
    println!("W(C) pairwise  {:.4}", part.pairwise);
    if criterion == Criterion::Dispersion {
        println!(
            "dispersion     {:.4} (exact k=2 optimum)",
            aba::algo::objective::dispersion(&ds, &part.labels, part.k)
        );
    }
    if certify {
        // Partition-attached bound (free, from the solve's own stats)
        // plus the standalone certificate's numbers and wall time.
        println!(
            "certificate    bound {:.4}  gap {:.4}%",
            part.upper_bound(),
            100.0 * part.gap()
        );
        if let Some(cert) = solver.last_certificate() {
            println!(
                "certify        total-sum {:.4}  pairwise bound {:.4}  ({} wall)",
                cert.total_ss,
                cert.pairwise_upper_bound,
                fmt_secs(cert.secs)
            );
        }
    }
    println!("diversity sd   {:.4}", stats.diversity_sd());
    println!("diversity rng  {:.4}", stats.diversity_range());
    println!(
        "sizes          min={} max={} (ratio {:.2}%)",
        part.sizes().iter().min().unwrap(),
        part.sizes().iter().max().unwrap(),
        stats.min_max_ratio_pct()
    );
    let sp = solver.sparse_stats();
    // Print whenever the candidate machinery was in play — including the
    // all-batches-fell-back case, which is exactly when users need to see
    // the escalation counters to understand a dense-speed run.
    if sp.sparse_batches + sp.fallback_batches + sp.escalations > 0 {
        println!(
            "sparse path    {} sparse / {} dense batches ({} escalations, \
             {} fallbacks), peak cost buffer {:.1} MiB",
            sp.sparse_batches,
            sp.dense_batches,
            sp.escalations,
            sp.fallback_batches,
            sp.peak_cost_bytes as f64 / (1u64 << 20) as f64
        );
    }
    if let Some(path) = args.get("out") {
        aba::data::csv::save_labels(&part.labels, path)?;
        println!("labels written to {path}");
    }
    Ok(())
}

/// `aba pareto`: multi-restart bicriterion interchange search on a
/// catalog dataset, printing the diversity/dispersion Pareto front with
/// per-point certificate upper bounds and gaps (see `aba::pareto`).
fn cmd_pareto(args: &Args) -> Result<()> {
    let name = args.get("dataset").unwrap_or("travel");
    let scale: Scale = args.get_parse("scale")?.unwrap_or(Scale::Small);
    let k: usize = args.get_parse("k")?.unwrap_or(10);
    let mut cfg = ParetoConfig::default();
    if let Some(r) = args.get_parse("restarts")? {
        cfg.restarts = r;
    }
    if let Some(c) = args.get_parse("archive-cap")? {
        cfg.archive_cap = c;
    }
    if let Some(p) = args.get_parse("passes")? {
        cfg.passes = p;
    }
    if let Some(p) = args.get_parse("partners")? {
        cfg.partners = p;
    }
    if let Some(s) = args.get_parse("seed")? {
        cfg.seed = s;
    }
    let par = match args.get_parse::<Parallelism>("threads")? {
        Some(p) => p,
        None if args.has_flag("parallel") => Parallelism::Auto,
        None => Parallelism::Serial,
    };
    let ds = load(name, scale)?;
    println!(
        "dataset {} (n={}, d={}), k={k}, restarts={}, threads={}",
        ds.name,
        ds.n,
        ds.d,
        cfg.restarts,
        par.effective_threads()
    );
    let restarts = cfg.restarts;
    let mut session = Aba::builder().parallelism(par).pareto(cfg).build()?;
    let t = std::time::Instant::now();
    // Surfaces the typed singleton-cluster precondition (n < 2k means a
    // balanced partition has a one-object cluster, so dispersion is
    // infinite and the bicriterion front degenerates) as a CLI error.
    let front = session.pareto_front(&ds.view(), k)?;
    let secs = t.elapsed().as_secs_f64();
    println!(
        "front          {} point(s) from {restarts} restart(s) in {} ({:.1} restarts/s)",
        front.points.len(),
        fmt_secs(secs),
        restarts as f64 / secs.max(1e-9)
    );
    println!("hypervolume    {:.4} (vs origin)", front.hypervolume((0.0, 0.0)));
    let mut t2 = aba::util::table::Table::new(
        "diversity/dispersion Pareto front (both maximized)",
        &["point", "diversity", "dispersion", "upper bound", "gap %"],
    );
    for (i, p) in front.points.iter().enumerate() {
        t2.row(vec![
            i.to_string(),
            format!("{:.4}", p.diversity),
            format!("{:.4}", p.dispersion),
            format!("{:.4}", p.upper_bound),
            format!("{:.2}", 100.0 * p.gap),
        ]);
    }
    println!("{}", t2.render());
    Ok(())
}

fn exp_options(args: &Args) -> Result<ExpOptions> {
    let mut opts = ExpOptions::default();
    if let Some(s) = args.get_parse("scale")? {
        opts.scale = s;
    }
    opts.k = args.get_parse("k")?;
    opts.datasets = args.get_list("datasets");
    if let Some(t) = args.get_parse("time-limit")? {
        opts.time_limit_secs = t;
    }
    if let Some(dir) = args.get("out-dir") {
        opts.out_dir = dir.into();
    }
    opts.quick = args.has_flag("quick");
    Ok(opts)
}

fn cmd_table(args: &Args) -> Result<()> {
    let id = args.pos(1, "table id (t4|t6|t8|t9|t10|t11)")?;
    let opts = exp_options(args)?;
    match id {
        "t4" => t4::table4(&opts).map(|_| ()),
        "t4x" => t4x::table4x(&opts).map(|_| ()),
        "t6" => t4::table6(&opts).map(|_| ()),
        "t8" => t8::table8(&opts).map(|_| ()),
        "t9" => t9::table9(&opts).map(|_| ()),
        "t10" => t9::table10(&opts).map(|_| ()),
        "t11" => t11::table11(&opts).map(|_| ()),
        other => bail!("unknown table '{other}'"),
    }
}

fn cmd_fig(args: &Args) -> Result<()> {
    let id = args.pos(1, "figure id (f5|f6|f7)")?;
    let opts = exp_options(args)?;
    match id {
        "f5" => figs::fig5(&opts).map(|_| ()),
        "f6" => figs::fig6(&opts).map(|_| ()),
        "f7" => figs::fig7(&opts).map(|_| ()),
        other => bail!("unknown figure '{other}'"),
    }
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let name = args.get("dataset").unwrap_or("diabetes");
    let scale: Scale = args.get_parse("scale")?.unwrap_or(Scale::Tiny);
    let ds = load(name, scale)?;
    let k: usize = args.get_parse("k")?.unwrap_or((ds.n / 64).max(2));
    let epochs: usize = args.get_parse("epochs")?.unwrap_or(3);
    let queue: usize = args.get_parse("queue")?.unwrap_or(4);
    let strategy = match args.get("strategy").unwrap_or("aba") {
        "aba" => BatchStrategy::Aba { cfg: AbaConfig::default(), shuffle_seed: 1 },
        "evolving" => BatchStrategy::Evolving {
            cfg: AbaConfig::default(),
            shuffle_seed: 1,
            churn: args.get_parse("churn")?.unwrap_or(ds.n / 20),
            refine_budget: args.get_parse("refine")?.unwrap_or(10_000),
        },
        "random" => BatchStrategy::Random { seed: 1 },
        other => bail!("unknown strategy '{other}' (aba|evolving|random)"),
    };
    let cfg = PipelineConfig { k, epochs, queue_depth: queue, strategy };
    println!(
        "pipeline: {} (n={}, d={}), k={k}, epochs={epochs}, queue={queue}",
        ds.name, ds.n, ds.d
    );

    let y = aba::pipeline::sgd::synth_labels(&ds, 0.05, 7);
    let mut model = aba::pipeline::sgd::LogReg::new(ds.d, 0.2);
    let mut losses: Vec<f64> = Vec::new();
    let stats = run_pipeline(&ds, &cfg, |batch| {
        let loss = model.train_batch(&ds, &y, &batch.indices);
        losses.push(loss);
    })?;
    println!(
        "batches={} produced in {} s (blocked {} s), total {} s",
        stats.batches_consumed,
        fmt_secs(stats.produce_secs),
        fmt_secs(stats.blocked_secs),
        fmt_secs(stats.total_secs)
    );
    println!(
        "throughput {:.1} batches/s",
        stats.batches_consumed as f64 / stats.total_secs.max(1e-9)
    );
    let last: Vec<f64> = losses.iter().rev().take(k).copied().collect();
    println!(
        "final-epoch loss mean={:.4} sd={:.4}   accuracy={:.3}",
        aba::metrics::Summary::of(&last).mean,
        aba::metrics::Summary::of(&last).sd,
        model.accuracy(&ds, &y)
    );
    Ok(())
}

/// Parse a one-column CSV of row ids (optional header line).
fn read_id_csv(path: &str) -> Result<Vec<u64>> {
    let text = std::fs::read_to_string(path)?;
    let mut ids = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match line.parse::<u64>() {
            Ok(id) => ids.push(id),
            // A non-numeric first line is a header; elsewhere it's bad.
            Err(_) if i == 0 => continue,
            Err(_) => bail!("{path}:{}: '{line}' is not a row id", i + 1),
        }
    }
    Ok(ids)
}

/// `aba update`: load a persisted OnlinePartition, apply churn from CSV
/// deltas, optionally refine and re-save, and report the maintained
/// (delta) objective against a from-scratch re-solve of the current
/// contents — the serving warm-restart loop, on the command line.
fn cmd_update(args: &Args) -> Result<()> {
    let Some(path) = args.get("partition") else {
        bail!("--partition FILE is required (see `aba help`)");
    };
    // The session config must reproduce the snapshot's fingerprint.
    let mut cfg = AbaConfig::default();
    if let Some(v) = args.get_parse("variant")? {
        cfg.variant = v;
    }
    if let Some(s) = args.get_parse("solver")? {
        cfg.solver = s;
    }
    if let Some(c) = args.get_parse::<CandidateMode>("candidates")? {
        cfg.candidates = c;
    }
    // `strict` participates in the fingerprint: snapshots written by
    // `run --strict --save-partition` need it to load.
    cfg.strict_divisibility = args.has_flag("strict");
    let mut handle = OnlinePartition::load(path, &cfg)?;
    println!(
        "loaded {path}: n={}, k={}, d={}, objective {:.4}",
        handle.len(),
        handle.k(),
        handle.d(),
        handle.objective()
    );
    if let Some(rm) = args.get("remove") {
        let ids = read_id_csv(rm)?;
        let t = std::time::Instant::now();
        handle.remove(&ids)?;
        println!(
            "removed {} rows (+balance repair) in {}",
            ids.len(),
            fmt_secs(t.elapsed().as_secs_f64())
        );
    }
    if let Some(ins) = args.get("insert") {
        let delta = aba::data::csv::load(ins, "delta")?;
        let t = std::time::Instant::now();
        let ids = handle.insert_batch(&delta.view())?;
        println!(
            "inserted {} rows (ids {}..={}) in {}",
            ids.len(),
            ids.first().unwrap(),
            ids.last().unwrap(),
            fmt_secs(t.elapsed().as_secs_f64())
        );
    }
    if let Some(budget) = args.get_parse::<usize>("refine")? {
        // With no preceding churn the loaded handle's touched set is
        // empty (refine is scoped to touched clusters) — a standalone
        // refine means "polish everything".
        if args.get("remove").is_none() && args.get("insert").is_none() {
            handle.touch_all();
        }
        let t = std::time::Instant::now();
        let r = handle.refine(budget);
        println!(
            "refine: {} swaps out of {} priced candidates in {}",
            r.swapped,
            r.evaluated,
            fmt_secs(t.elapsed().as_secs_f64())
        );
    }
    let delta_obj = handle.objective();
    let scratch = handle.recompute_objective();
    // The headline report: maintained state vs a full re-solve.
    let current = handle.to_dataset("current")?;
    let t = std::time::Instant::now();
    let fresh = Aba::from_config(cfg)?.partition(&current, handle.k())?;
    let resolve_secs = t.elapsed().as_secs_f64();
    println!("objective (delta-maintained)  {delta_obj:.4}");
    println!("objective (scratch recompute) {scratch:.4}");
    println!(
        "objective (from-scratch solve) {:.4} ({:+.4}% vs maintained, {} to re-solve)",
        fresh.objective,
        100.0 * (delta_obj - fresh.objective) / fresh.objective.max(1e-12),
        fmt_secs(resolve_secs)
    );
    let sizes = handle.sizes();
    println!(
        "sizes          min={} max={}",
        sizes.iter().min().unwrap(),
        sizes.iter().max().unwrap()
    );
    if let Some(out) = args.get("save") {
        handle.save(out)?;
        println!("partition saved to {out}");
    }
    Ok(())
}

/// Solver config for the serve session from CLI flags — the same
/// fingerprint-participating four as `aba update`, plus parallelism
/// (which shard-merge solves fan out on) and the kernel dispatch mode
/// (neither participates in the fingerprint).
fn serve_aba_config(args: &Args) -> Result<AbaConfig> {
    let mut cfg = AbaConfig::default();
    if let Some(v) = args.get_parse("variant")? {
        cfg.variant = v;
    }
    if let Some(s) = args.get_parse("solver")? {
        cfg.solver = s;
    }
    if let Some(c) = args.get_parse::<CandidateMode>("candidates")? {
        cfg.candidates = c;
    }
    cfg.strict_divisibility = args.has_flag("strict");
    if let Some(p) = args.get_parse::<Parallelism>("threads")? {
        cfg.parallelism = p;
    }
    if let Some(m) = args.get_parse::<KernelMode>("kernels")? {
        cfg.kernels = Some(m);
    }
    Ok(cfg)
}

/// `aba serve`: run the HTTP service in the foreground until SIGTERM or
/// `POST /v1/admin/drain`, then snapshot every resident handle and exit.
fn cmd_serve(args: &Args) -> Result<()> {
    let config = aba::serve::ServeConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7341").to_string(),
        workers: args.get_parse("workers")?.unwrap_or(4),
        queue: args.get_parse("queue")?.unwrap_or(64),
        max_handles: args.get_parse("max-handles")?.unwrap_or(64),
        snapshot_dir: args.get("snapshot-dir").unwrap_or("aba-snapshots").into(),
        cfg: serve_aba_config(args)?,
        test_delay_ms: args.get_parse("test-delay-ms")?.unwrap_or(0),
    };
    // CI's serve smoke greps this line; `/metrics` exposes the same
    // selection as `aba_kernel_isa`.
    let kernel_isa = match config.cfg.kernels {
        Some(m) => aba::runtime::Kernels::select(m).isa(),
        None => aba::runtime::Kernels::get().isa(),
    };
    let snapshot_dir = config.snapshot_dir.clone();
    let server = aba::serve::Server::start(config)?;
    // CI and scripts parse this line to discover the bound port.
    println!("listening on {}", server.addr());
    println!("distance kernels: {kernel_isa}");
    println!("snapshots in {} — SIGTERM or POST /v1/admin/drain to stop", snapshot_dir.display());
    let written = server.wait()?;
    println!("drained: {written} handle(s) snapshotted to {}", snapshot_dir.display());
    Ok(())
}

/// `aba snapshot inspect FILE`: print the snapshot header (format
/// version, config fingerprint, shape, cluster sizes) without
/// constructing a session or checking fingerprint compatibility.
fn cmd_snapshot(args: &Args) -> Result<()> {
    let verb = args.pos(1, "snapshot subcommand (inspect)")?;
    if verb != "inspect" {
        bail!("unknown snapshot subcommand '{verb}' (try `aba snapshot inspect FILE`)");
    }
    let path = args.pos(2, "snapshot file")?;
    let info = aba::online::inspect_snapshot(path)?;
    println!("file         {path}");
    println!("format       {}", info.format);
    println!("fingerprint  {}", info.fingerprint);
    println!("n            {}", info.n);
    println!("k            {}", info.k);
    println!("d            {}", info.d);
    println!("categories   {}", info.n_cats);
    let (min, max) = (
        info.sizes.iter().min().copied().unwrap_or(0),
        info.sizes.iter().max().copied().unwrap_or(0),
    );
    println!("sizes        min={min} max={max}");
    if info.k <= 24 {
        println!("             {:?}", info.sizes);
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_selftest() -> Result<()> {
    bail!("selftest needs the XLA runtime; rebuild with `cargo run --features xla -- selftest`")
}

#[cfg(feature = "xla")]
fn cmd_selftest() -> Result<()> {
    use aba::runtime::{CostBackend, NativeBackend, XlaBackend};
    let mut xla = XlaBackend::from_default_dir()?;
    let mut native = NativeBackend::default();
    let mut rng = aba::rng::Pcg32::new(7);
    let (m, k, d) = (100usize, 100usize, 20usize);
    let x: Vec<f32> = (0..m * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let c: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let (mut a, mut b) = (Vec::new(), Vec::new());
    xla.batch_costs(&x, m, d, &c, k, &mut a);
    native.batch_costs(&x, m, d, &c, k, &mut b);
    let max_err = a
        .iter()
        .zip(&b)
        .map(|(u, v)| (u - v).abs())
        .fold(0f32, f32::max);
    println!(
        "selftest: xla_calls={} fallbacks={} max_abs_err={max_err:.2e}",
        xla.xla_calls, xla.native_fallbacks
    );
    if max_err > 1e-3 {
        bail!("XLA vs native mismatch: {max_err}");
    }
    println!("selftest OK (artifacts round-trip through PJRT matches native)");
    Ok(())
}
