//! Fixed-width table formatting + CSV output for the experiment harness.
//!
//! Every `aba table <id>` / `aba fig <id>` command prints one of these and
//! mirrors it to `results/<id>.csv`.

use std::fs;
use std::path::Path;

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// An in-memory table: header + string rows.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub aligns: Vec<Align>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        let headers: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
        let aligns = headers.iter().map(|_| Align::Right).collect();
        Self { title: title.into(), headers, aligns, rows: Vec::new() }
    }

    /// Left-align the given column (first column is usually a name).
    pub fn left(mut self, col: usize) -> Self {
        if col < self.aligns.len() {
            self.aligns[col] = Align::Left;
        }
        self
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as a fixed-width text table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize], aligns: &[Align]| {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let w = widths[i];
                match aligns[i] {
                    Align::Left => line.push_str(&format!("{:<w$}", cells[i])),
                    Align::Right => line.push_str(&format!("{:>w$}", cells[i])),
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths, &self.aligns));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths, &self.aligns));
        }
        out
    }

    /// Render as CSV (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        out.push_str(
            &self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV next to the repo under `results/<name>.csv`.
    pub fn save_csv(&self, dir: impl AsRef<Path>, name: &str) -> std::io::Result<()> {
        let dir = dir.as_ref();
        fs::create_dir_all(dir)?;
        fs::write(dir.join(format!("{name}.csv")), self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("demo", &["name", "x"]).left(0);
        t.row(vec!["alpha".into(), "1.5".into()]);
        t.row(vec!["b".into(), "22".into()]);
        t
    }

    #[test]
    fn render_aligns_columns() {
        let s = sample().render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1], "name     x");
        assert_eq!(lines[3], "alpha  1.5");
        assert_eq!(lines[4], "b       22");
    }

    #[test]
    fn csv_quotes_when_needed() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "he said \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
