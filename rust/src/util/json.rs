//! Minimal JSON parser — just enough to read `artifacts/manifest.json`
//! (and to write simple reports). No serde in the offline vendor set.
//!
//! Supports the full JSON value grammar except `\u` surrogate pairs are
//! decoded leniently (unpaired surrogates become U+FFFD).

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

/// Parse error with byte offset and a context excerpt of the input
/// around that offset (so a truncated or hand-edited snapshot file is
/// diagnosable from the message alone). Display/Error are
/// hand-implemented: the offline vendor set ships no `thiserror`, and
/// the library core's error story is the typed [`crate::AbaError`]
/// anyway (callers convert via its `ParseError` variant).
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
    /// Up to ~20 bytes of input either side of `offset`, lossily
    /// decoded, control characters shown as `·`, truncation marked
    /// with `…`. Empty only for errors raised without input context.
    pub context: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)?;
        if !self.context.is_empty() {
            write!(f, " (near \"{}\")", self.context)?;
        }
        Ok(())
    }
}

/// The error-context window: the input around `pos`, lossily decoded
/// with control characters flattened to `·` and `…` marking truncated
/// ends, clamped to a UTF-8 boundary-safe slice via lossy decoding.
fn excerpt(bytes: &[u8], pos: usize) -> String {
    const WINDOW: usize = 20;
    let start = pos.saturating_sub(WINDOW);
    let end = (pos + WINDOW).min(bytes.len());
    let mut out = String::new();
    if start > 0 {
        out.push('…');
    }
    for c in String::from_utf8_lossy(&bytes[start..end]).chars() {
        out.push(if c.is_control() { '·' } else { c });
    }
    if end < bytes.len() {
        out.push('…');
    }
    out
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError {
            offset: self.pos,
            msg: msg.into(),
            context: excerpt(self.bytes, self.pos),
        })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            self.err(format!("expected '{lit}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x20 => return self.err("control in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    let start = self.pos - 1;
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.pos = end;
                        }
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut cp = 0u32;
        for _ in 0..4 {
            let c = match self.bump() {
                Some(c) => c,
                None => return self.err("eof in \\u"),
            };
            cp = match (c as char).to_digit(16) {
                Some(digit) => cp * 16 + digit,
                None => return self.err("bad hex"),
            };
        }
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(_) => self.err("bad number"),
        }
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

/// Serialize a [`Json`] value (compact; keys sorted by BTreeMap order).
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{"format": 1, "entries": [{"name": "cost_m64_k64_d16",
            "kind": "cost", "m": 64, "k": 64, "d": 16,
            "inputs": [[64, 16], [64, 16]], "output": [64, 64],
            "file": "cost_m64_k64_d16.hlo.txt"}]}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_usize(), Some(1));
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("kind").unwrap().as_str(), Some("cost"));
        assert_eq!(e.get("m").unwrap().as_usize(), Some(64));
        let inputs = e.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].as_arr().unwrap()[1].as_usize(), Some(16));
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_unicode_escape_and_utf8() {
        assert_eq!(parse("\"\\u00e9\"").unwrap(), Json::Str("é".into()));
        assert_eq!(parse("\"é✓\"").unwrap(), Json::Str("é✓".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn errors_carry_offset_and_context() {
        // A truncated snapshot-like document: the message must point at
        // the failure byte and quote the surrounding input.
        let doc = r#"{"format": 1, "ids": [0, 1, 2"#;
        let e = parse(doc).unwrap_err();
        assert_eq!(e.offset, doc.len());
        assert!(e.context.contains("[0, 1, 2"), "context: {}", e.context);
        let msg = e.to_string();
        assert!(msg.contains(&format!("byte {}", doc.len())), "{msg}");
        assert!(msg.contains("near"), "{msg}");
        // Control characters are flattened so messages stay one line.
        let e2 = parse("{\"a\"\n: }").unwrap_err();
        assert!(!e2.context.contains('\n'), "context: {:?}", e2.context);
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,null,true,"x\"y"],"b":{"c":-3}}"#;
        let v = parse(doc).unwrap();
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
