//! Small shared utilities: a minimal JSON parser (the vendor set has no
//! serde), wall-clock timers, and fixed-width table formatting.

pub mod args;
pub mod json;
pub mod table;
pub mod timer;

/// Round a float for stable display (used by report tables / CSV).
pub fn round_to(x: f64, digits: u32) -> f64 {
    let p = 10f64.powi(digits as i32);
    (x * p).round() / p
}

/// Format seconds compactly: `0.004`, `1.25`, `87.9`.
pub fn fmt_secs(s: f64) -> String {
    if s < 0.01 {
        format!("{s:.4}")
    } else if s < 1.0 {
        format!("{s:.3}")
    } else if s < 100.0 {
        format!("{s:.2}")
    } else {
        format!("{s:.1}")
    }
}

/// Percentage deviation of `x` from baseline `base` (paper convention:
/// positive means `x` is larger).
pub fn pct_dev(x: f64, base: f64) -> f64 {
    if base == 0.0 {
        if x == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        100.0 * (x - base) / base.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pct_dev_basics() {
        assert_eq!(pct_dev(110.0, 100.0), 10.0);
        assert_eq!(pct_dev(90.0, 100.0), -10.0);
        assert_eq!(pct_dev(0.0, 0.0), 0.0);
    }

    #[test]
    fn round_to_digits() {
        assert_eq!(round_to(1.23456, 2), 1.23);
        assert_eq!(round_to(-1.235, 2), -1.24);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(0.0042), "0.0042");
        assert_eq!(fmt_secs(0.25), "0.250");
        assert_eq!(fmt_secs(2.5), "2.50");
        assert_eq!(fmt_secs(123.4), "123.4");
    }
}
