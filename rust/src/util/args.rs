//! Tiny CLI argument parser (the offline vendor set has no clap).
//!
//! Grammar: positionals and `--key value` / `--key=value` options;
//! `--flag` followed by another option or nothing is boolean.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let items: Vec<String> = argv.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < items.len() {
            let a = &items[i];
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < items.len() && !items[i + 1].starts_with("--") {
                    out.options.insert(key.to_string(), items[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Parse an option value, with a helpful error naming the option.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => match v.parse::<T>() {
                Ok(t) => Ok(Some(t)),
                Err(e) => bail!("--{key} {v}: {e}"),
            },
        }
    }

    /// Comma-separated list option.
    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }

    /// Positional at index, with error message.
    pub fn pos(&self, idx: usize, what: &str) -> Result<&str> {
        self.positional
            .get(idx)
            .map(|s| s.as_str())
            .with_context(|| format!("missing {what}"))
    }
}

/// Parse a hierarchy spec like `4x125` or `8x200x200`.
pub fn parse_hier(s: &str) -> Result<Vec<usize>> {
    let parts: Result<Vec<usize>> = s
        .split(['x', 'X'])
        .map(|p| p.parse::<usize>().with_context(|| format!("bad factor '{p}' in '{s}'")))
        .collect();
    let parts = parts?;
    if parts.is_empty() || parts.iter().any(|&p| p == 0) {
        bail!("invalid hierarchy spec '{s}'");
    }
    Ok(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positionals_and_options() {
        let a = parse(&["table", "t4", "--k", "5", "--scale=small", "--quick"]);
        assert_eq!(a.positional, vec!["table", "t4"]);
        assert_eq!(a.get("k"), Some("5"));
        assert_eq!(a.get("scale"), Some("small"));
        assert!(a.has_flag("quick"));
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn typed_and_list() {
        let a = parse(&["--k", "12", "--datasets", "a, b,c"]);
        assert_eq!(a.get_parse::<usize>("k").unwrap(), Some(12));
        assert!(a.get_parse::<usize>("missing").unwrap().is_none());
        assert_eq!(
            a.get_list("datasets").unwrap(),
            vec!["a".to_string(), "b".into(), "c".into()]
        );
    }

    #[test]
    fn bad_parse_errors() {
        let a = parse(&["--k", "abc"]);
        assert!(a.get_parse::<usize>("k").is_err());
    }

    #[test]
    fn hier_spec() {
        assert_eq!(parse_hier("4x125").unwrap(), vec![4, 125]);
        assert_eq!(parse_hier("8x200x200").unwrap(), vec![8, 200, 200]);
        assert!(parse_hier("4x0").is_err());
        assert!(parse_hier("x").is_err());
    }
}
