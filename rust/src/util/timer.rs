//! Wall-clock timing helpers used by the experiment harness and benches.

use std::time::Instant;

/// A simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

/// Benchmark a closure: run `warmup` untimed iterations, then `iters`
/// timed ones; returns (mean_secs, min_secs, max_secs).
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t = Timer::start();
        std::hint::black_box(f());
        samples.push(t.secs());
    }
    BenchStats::from_samples(&samples)
}

/// Summary statistics for a set of timing samples.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl BenchStats {
    pub fn from_samples(samples: &[f64]) -> Self {
        let n = samples.len().max(1);
        let mean = samples.iter().sum::<f64>() / n as f64;
        let min = samples.iter().copied().fold(f64::INFINITY, f64::min);
        let max = samples.iter().copied().fold(0.0f64, f64::max);
        Self { mean, min, max, n: samples.len() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_measures_something() {
        let (v, s) = timed(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499_500);
        assert!(s >= 0.0);
    }

    #[test]
    fn bench_stats_ordering() {
        let st = bench(1, 5, || std::thread::sleep(std::time::Duration::from_micros(50)));
        assert!(st.min <= st.mean && st.mean <= st.max);
        assert_eq!(st.n, 5);
    }
}
