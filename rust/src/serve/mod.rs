//! `aba serve`: a sharded anticlustering service over
//! [`OnlinePartition`] handles.
//!
//! A dependency-light HTTP/1.1 server on [`std::net::TcpListener`] with
//! a bounded accept/worker model: one accept thread feeds a bounded
//! connection queue drained by a fixed pool of worker threads, each
//! owning its own [`Aba`] session. Live partitions sit behind a
//! [`registry::Registry`] keyed by id — an LRU cache that evicts cold
//! handles to fingerprinted snapshots and warm-restarts them on demand
//! (an incompatible snapshot is HTTP 409).
//!
//! Endpoints (all bodies JSON, every response `Connection: close`):
//!
//! | method & path                      | action                              |
//! |------------------------------------|-------------------------------------|
//! | `POST /v1/partitions`              | solve inline CSV into a new handle  |
//! | `GET  /v1/partitions/{id}`         | labels / sizes / objective          |
//! | `POST /v1/partitions/{id}/insert`  | stream new rows in (inline CSV)     |
//! | `POST /v1/partitions/{id}/remove`  | retire rows by id                   |
//! | `POST /v1/partitions/{id}/refine`  | budgeted swap repair                |
//! | `POST /v1/partitions/{id}/pareto`  | bicriterion front ([`crate::pareto`]) |
//! | `GET  /metrics`                    | text telemetry ([`metrics`])        |
//! | `GET  /healthz`                    | liveness                            |
//! | `POST /v1/admin/drain`             | graceful drain (as does `SIGTERM`)  |
//!
//! The create endpoint accepts `"shards": S` to route the solve through
//! [`shard::solve_sharded`] — `S` independent shard solves on the
//! worker pool reconciled by centroid-level rectangular assignment.
//!
//! When the queue is full the accept thread answers `429` with
//! `Retry-After` inline rather than letting latency grow unboundedly.
//! On `SIGTERM` (or the drain endpoint) the server stops accepting,
//! finishes queued requests, snapshots every resident handle, and
//! exits.
//!
//! The process-wide [`crate::data::view::gathered_bytes`] meter is
//! reported cumulatively in `/metrics` and deliberately *not* reset per
//! request: workers run concurrently, and a per-request reset would
//! race. Single-tenant embedders that want per-request numbers can call
//! [`crate::data::view::reset_gathered_bytes`] themselves.

pub mod http;
pub mod metrics;
pub mod registry;
pub mod shard;

use crate::algo::AbaConfig;
use crate::data::csv;
use crate::error::{AbaError, AbaResult};
use crate::online::OnlinePartition;
use crate::solver::{Aba, PhaseTimings};
use crate::util::json::{self, Json};
use http::{Request, Response};
use metrics::Metrics;
use registry::Registry;
use std::collections::{BTreeMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Set by the `SIGTERM` handler; polled by the accept loop.
static SIGTERM: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_sigterm_handler() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    extern "C" fn on_sigterm(_sig: i32) {
        // An atomic store is async-signal-safe; everything else happens
        // on the accept thread when it notices the flag.
        SIGTERM.store(true, Ordering::SeqCst);
    }
    const SIGTERM_NUM: i32 = 15;
    let handler: extern "C" fn(i32) = on_sigterm;
    unsafe {
        signal(SIGTERM_NUM, handler as usize);
    }
}

#[cfg(not(unix))]
fn install_sigterm_handler() {}

/// Server construction parameters (see [`Server::start`]).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address; port 0 picks a free port (reported by
    /// [`Server::addr`]).
    pub addr: String,
    /// Worker threads, each with its own solver session.
    pub workers: usize,
    /// Bounded pending-connection queue; overflow is answered `429`.
    pub queue: usize,
    /// Max resident [`OnlinePartition`] handles before LRU eviction.
    pub max_handles: usize,
    /// Where evicted/drained handles snapshot to.
    pub snapshot_dir: PathBuf,
    /// Solver configuration shared by all sessions and handles.
    pub cfg: AbaConfig,
    /// Artificial per-request delay, for backpressure tests only.
    pub test_delay_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            queue: 64,
            max_handles: 64,
            snapshot_dir: std::env::temp_dir().join("aba-serve"),
            cfg: AbaConfig::default(),
            test_delay_ms: 0,
        }
    }
}

/// Accept-queue state shared between the accept thread and workers.
struct Shared {
    queue: Mutex<VecDeque<TcpStream>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

impl Shared {
    /// Request shutdown and wake every waiting worker. Notifying while
    /// holding the queue lock closes the race where a worker checks the
    /// flag, misses the notify, and then blocks in `wait` forever.
    fn trigger_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _guard = self.queue.lock().unwrap();
        self.cv.notify_all();
    }
}

/// Per-request context handed to the router.
struct Ctx {
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
    shared: Arc<Shared>,
    cfg: AbaConfig,
    next_id: AtomicU64,
    test_delay_ms: u64,
}

/// A running service. Dropping it without [`Server::drain`] leaves the
/// threads running; call [`Server::drain`] (or [`Server::wait`] from a
/// CLI) for a clean shutdown with snapshots on disk.
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind, spawn the accept thread and `workers` worker threads, and
    /// return. Fails fast if the solver config or bind address is bad.
    pub fn start(config: ServeConfig) -> AbaResult<Server> {
        install_sigterm_handler();
        // Surface a bad solver config now, not on the first request.
        drop(Aba::from_config(config.cfg.clone())?);
        let metrics = Arc::new(Metrics::new());
        let registry = Arc::new(Registry::new(
            &config.snapshot_dir,
            config.max_handles,
            config.cfg.clone(),
            Arc::clone(&metrics),
        )?);
        let listener = TcpListener::bind(&config.addr)
            .map_err(|e| AbaError::Io(format!("bind {}: {e}", config.addr)))?;
        let addr = listener
            .local_addr()
            .map_err(|e| AbaError::Io(format!("local_addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| AbaError::Io(format!("set_nonblocking: {e}")))?;
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let ctx = Arc::new(Ctx {
            registry: Arc::clone(&registry),
            metrics: Arc::clone(&metrics),
            shared: Arc::clone(&shared),
            cfg: config.cfg.clone(),
            next_id: AtomicU64::new(0),
            test_delay_ms: config.test_delay_ms,
        });
        let mut workers = Vec::with_capacity(config.workers.max(1));
        for wi in 0..config.workers.max(1) {
            let ctx = Arc::clone(&ctx);
            let handle = std::thread::Builder::new()
                .name(format!("aba-serve-{wi}"))
                .spawn(move || worker_loop(&ctx))
                .map_err(|e| AbaError::Io(format!("spawn worker: {e}")))?;
            workers.push(handle);
        }
        let accept = {
            let shared = Arc::clone(&shared);
            let metrics = Arc::clone(&metrics);
            let queue_cap = config.queue.max(1);
            std::thread::Builder::new()
                .name("aba-serve-accept".into())
                .spawn(move || accept_loop(listener, &shared, &metrics, queue_cap))
                .map_err(|e| AbaError::Io(format!("spawn accept: {e}")))?
        };
        Ok(Server { addr, shared, registry, metrics, accept: Some(accept), workers })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    pub fn registry(&self) -> Arc<Registry> {
        Arc::clone(&self.registry)
    }

    /// Whether a drain has been requested (endpoint, `SIGTERM`, or
    /// [`Server::request_drain`]).
    pub fn draining(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Ask the server to stop accepting and finish queued work.
    pub fn request_drain(&self) {
        self.shared.trigger_shutdown();
    }

    /// Drain now: stop accepting, finish queued requests, snapshot all
    /// resident handles. Returns how many snapshots were written.
    pub fn drain(mut self) -> AbaResult<usize> {
        self.request_drain();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.registry.drain_all()
    }

    /// Block until a drain is requested (e.g. `SIGTERM`), then
    /// [`Server::drain`]. The CLI's foreground path.
    pub fn wait(self) -> AbaResult<usize> {
        while !self.draining() {
            std::thread::sleep(Duration::from_millis(50));
        }
        self.drain()
    }
}

/// Accept connections and enqueue them, rejecting with `429` when the
/// queue is full. Exits when a drain is requested.
fn accept_loop(listener: TcpListener, shared: &Shared, metrics: &Metrics, queue_cap: usize) {
    loop {
        if SIGTERM.load(Ordering::SeqCst) {
            shared.shutdown.store(true, Ordering::SeqCst);
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            shared.trigger_shutdown();
            return;
        }
        match listener.accept() {
            Ok((mut stream, _)) => {
                // Accepted sockets must block: workers read bodies with
                // a timeout, not busy-wait.
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
                let mut queue = shared.queue.lock().unwrap();
                if queue.len() >= queue_cap {
                    drop(queue);
                    metrics.rejected_429.fetch_add(1, Ordering::Relaxed);
                    metrics.observe(429, 0);
                    let resp =
                        Response::error(429, "request queue full").with_retry_after(1);
                    let _ = resp.write_to(&mut stream);
                } else {
                    queue.push_back(stream);
                    metrics.queue_depth.store(queue.len() as u64, Ordering::Relaxed);
                    drop(queue);
                    shared.cv.notify_one();
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Pop connections and serve them until the queue is empty *and* a
/// drain was requested — queued requests finish during a drain.
fn worker_loop(ctx: &Ctx) {
    // Config was validated in `Server::start`.
    let mut session = Aba::from_config(ctx.cfg.clone()).expect("config validated at start");
    loop {
        let next = {
            let mut queue = ctx.shared.queue.lock().unwrap();
            loop {
                if let Some(stream) = queue.pop_front() {
                    ctx.metrics.queue_depth.store(queue.len() as u64, Ordering::Relaxed);
                    break Some(stream);
                }
                if ctx.shared.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                queue = ctx.shared.cv.wait(queue).unwrap();
            }
        };
        let Some(mut stream) = next else { return };
        if ctx.test_delay_ms > 0 {
            std::thread::sleep(Duration::from_millis(ctx.test_delay_ms));
        }
        let start = Instant::now();
        match Request::read_from(&mut stream) {
            Ok(Some(req)) => {
                let resp = route(ctx, &mut session, &req);
                ctx.metrics.observe(resp.status, start.elapsed().as_micros() as u64);
                let _ = resp.write_to(&mut stream);
            }
            Ok(None) => {}
            Err(e) => {
                let resp = Response::error(400, &format!("bad request: {e}"));
                ctx.metrics.observe(400, start.elapsed().as_micros() as u64);
                let _ = resp.write_to(&mut stream);
            }
        }
    }
}

/// Map a solver error to its HTTP status: snapshot/config divergence is
/// a conflict, I/O is the server's fault, everything else is the
/// request's.
fn err_status(e: &AbaError) -> u16 {
    match e {
        AbaError::SnapshotMismatch { .. } => 409,
        AbaError::Io(_) => 500,
        _ => 400,
    }
}

fn err_response(e: &AbaError) -> Response {
    Response::error(err_status(e), &e.to_string())
}

/// Compact JSON object from literal pairs.
fn obj(pairs: Vec<(&str, Json)>) -> String {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    json::to_string(&Json::Obj(m))
}

fn num(x: f64) -> Json {
    Json::Num(x)
}

/// Parse and minimally validate a JSON request body.
fn parse_body(req: &Request) -> Result<Json, Response> {
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| Response::error(400, "body is not utf-8"))?;
    if text.trim().is_empty() {
        return Err(Response::error(400, "empty body (expected a JSON object)"));
    }
    json::parse(text).map_err(|e| Response::error(400, &format!("bad JSON body: {e}")))
}

fn route(ctx: &Ctx, session: &mut Aba, req: &Request) -> Response {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => Response::text(200, "ok\n".into()),
        ("GET", ["metrics"]) => {
            Response::text(200, ctx.metrics.render(ctx.registry.handles(), session.kernel_isa()))
        }
        ("POST", ["v1", "admin", "drain"]) => {
            ctx.shared.trigger_shutdown();
            Response::json(200, obj(vec![("draining", Json::Bool(true))]))
        }
        ("POST", ["v1", "partitions"]) => create_partition(ctx, session, req),
        ("GET", ["v1", "partitions", id]) => get_partition(ctx, id),
        ("POST", ["v1", "partitions", id, "insert"]) => op_insert(ctx, id, req),
        ("POST", ["v1", "partitions", id, "remove"]) => op_remove(ctx, id, req),
        ("POST", ["v1", "partitions", id, "refine"]) => op_refine(ctx, id, req),
        ("POST", ["v1", "partitions", id, "pareto"]) => op_pareto(ctx, id, req),
        _ => Response::error(404, &format!("no route for {} {}", req.method, req.path)),
    }
}

/// `POST /v1/partitions` — solve inline CSV into a new registered
/// handle. Body: `{"k": .., "csv": "..", "id"?: "..", "shards"?: S}`.
fn create_partition(ctx: &Ctx, session: &mut Aba, req: &Request) -> Response {
    let body = match parse_body(req) {
        Ok(b) => b,
        Err(resp) => return resp,
    };
    let Some(k) = body.get("k").and_then(Json::as_usize) else {
        return Response::error(400, "missing numeric field 'k'");
    };
    let Some(csv_text) = body.get("csv").and_then(Json::as_str) else {
        return Response::error(400, "missing string field 'csv'");
    };
    let id = match body.get("id").and_then(Json::as_str) {
        Some(s) => s.to_string(),
        None => format!("p{}", ctx.next_id.fetch_add(1, Ordering::Relaxed)),
    };
    if !Registry::valid_id(&id) {
        return Response::error(400, &format!("invalid partition id '{id}'"));
    }
    if ctx.registry.contains(&id) {
        return Response::error(409, &format!("partition '{id}' already exists"));
    }
    let ds = match csv::parse_str(csv_text, &id) {
        Ok(ds) => ds,
        Err(e) => return err_response(&e),
    };
    let shards = body.get("shards").and_then(Json::as_usize).unwrap_or(1);
    let part = if shards >= 2 {
        match shard::solve_sharded(&ds.view(), k, shards, &ctx.cfg) {
            Ok(labels) => OnlinePartition::from_labels(
                &ds.view(),
                labels,
                k,
                ctx.cfg.clone(),
                PhaseTimings::default(),
            ),
            Err(e) => return err_response(&e),
        }
    } else {
        match session.partition_online(&ds.view(), k) {
            Ok(p) => p,
            Err(e) => return err_response(&e),
        }
    };
    ctx.metrics.add_sparse(&session.sparse_stats());
    session.reset_sparse_stats();
    let mut part = part;
    let n = part.len();
    let objective = part.objective();
    let upper_bound = part.upper_bound();
    let gap = part.gap();
    ctx.metrics.observe_gap(gap);
    if let Err(e) = ctx.registry.insert(&id, part) {
        return err_response(&e);
    }
    Response::json(
        201,
        obj(vec![
            ("id", Json::Str(id)),
            ("n", num(n as f64)),
            ("k", num(k as f64)),
            ("objective", num(objective)),
            ("upper_bound", num(upper_bound)),
            ("gap", num(gap)),
        ]),
    )
}

/// Fetch a handle or the error response that explains why not.
fn load_handle(
    ctx: &Ctx,
    id: &str,
) -> Result<Arc<Mutex<OnlinePartition>>, Response> {
    match ctx.registry.get_or_load(id) {
        Ok(Some(handle)) => Ok(handle),
        Ok(None) => Err(Response::error(404, &format!("no partition '{id}'"))),
        Err(e) => Err(err_response(&e)),
    }
}

/// `GET /v1/partitions/{id}` — full state: sizes, objective, labels.
fn get_partition(ctx: &Ctx, id: &str) -> Response {
    let handle = match load_handle(ctx, id) {
        Ok(h) => h,
        Err(resp) => return resp,
    };
    let mut part = handle.lock().unwrap();
    let sizes = Json::Arr(part.sizes().iter().map(|&s| num(s as f64)).collect());
    let labels = Json::Arr(
        part.entries()
            .into_iter()
            .map(|(id, lab)| Json::Arr(vec![num(id as f64), num(lab as f64)]))
            .collect(),
    );
    let objective = part.objective();
    let upper_bound = part.upper_bound();
    let gap = part.gap();
    ctx.metrics.observe_gap(gap);
    Response::json(
        200,
        obj(vec![
            ("id", Json::Str(id.to_string())),
            ("n", num(part.len() as f64)),
            ("k", num(part.k() as f64)),
            ("d", num(part.d() as f64)),
            ("objective", num(objective)),
            ("upper_bound", num(upper_bound)),
            ("gap", num(gap)),
            ("sizes", sizes),
            ("labels", labels),
        ]),
    )
}

/// `POST /v1/partitions/{id}/insert` — body `{"csv": ".."}`; rows are
/// routed by delta objective and assigned fresh stable ids.
fn op_insert(ctx: &Ctx, id: &str, req: &Request) -> Response {
    let body = match parse_body(req) {
        Ok(b) => b,
        Err(resp) => return resp,
    };
    let Some(csv_text) = body.get("csv").and_then(Json::as_str) else {
        return Response::error(400, "missing string field 'csv'");
    };
    let ds = match csv::parse_str(csv_text, "insert") {
        Ok(ds) => ds,
        Err(e) => return err_response(&e),
    };
    let handle = match load_handle(ctx, id) {
        Ok(h) => h,
        Err(resp) => return resp,
    };
    let mut part = handle.lock().unwrap();
    match part.insert_batch(&ds.view()) {
        Ok(ids) => Response::json(
            200,
            obj(vec![
                ("ids", Json::Arr(ids.iter().map(|&i| num(i as f64)).collect())),
                ("n", num(part.len() as f64)),
            ]),
        ),
        Err(e) => err_response(&e),
    }
}

/// `POST /v1/partitions/{id}/remove` — body `{"ids": [..]}`.
fn op_remove(ctx: &Ctx, id: &str, req: &Request) -> Response {
    let body = match parse_body(req) {
        Ok(b) => b,
        Err(resp) => return resp,
    };
    let Some(raw) = body.get("ids").and_then(Json::as_arr) else {
        return Response::error(400, "missing array field 'ids'");
    };
    let mut ids = Vec::with_capacity(raw.len());
    for v in raw {
        match v.as_f64() {
            Some(x) if x >= 0.0 => ids.push(x as u64),
            _ => return Response::error(400, "'ids' must be non-negative numbers"),
        }
    }
    let handle = match load_handle(ctx, id) {
        Ok(h) => h,
        Err(resp) => return resp,
    };
    let mut part = handle.lock().unwrap();
    match part.remove(&ids) {
        Ok(()) => Response::json(
            200,
            obj(vec![
                ("removed", num(ids.len() as f64)),
                ("n", num(part.len() as f64)),
            ]),
        ),
        Err(e) => err_response(&e),
    }
}

/// `POST /v1/partitions/{id}/refine` — body
/// `{"budget"?: .., "global"?: true}`; `global` prices every cluster,
/// not just churn-touched ones.
fn op_refine(ctx: &Ctx, id: &str, req: &Request) -> Response {
    let body = match parse_body(req) {
        Ok(b) => b,
        Err(resp) => return resp,
    };
    let budget = body.get("budget").and_then(Json::as_usize).unwrap_or(10_000);
    let global = matches!(body.get("global"), Some(Json::Bool(true)));
    let handle = match load_handle(ctx, id) {
        Ok(h) => h,
        Err(resp) => return resp,
    };
    let mut part = handle.lock().unwrap();
    if global {
        part.touch_all();
    }
    let stats = part.refine(budget);
    Response::json(
        200,
        obj(vec![
            ("evaluated", num(stats.evaluated as f64)),
            ("swapped", num(stats.swapped as f64)),
            ("est_gain", num(stats.est_gain)),
        ]),
    )
}

/// `POST /v1/partitions/{id}/pareto` — body `{}` or any of
/// `{"restarts": .., "archive_cap": .., "passes": .., "partners": ..,
/// "seed": ..}`; runs the bicriterion multi-restart engine
/// ([`crate::pareto`]) over the handle's current contents and returns
/// the diversity/dispersion front with per-point certificate bounds.
fn op_pareto(ctx: &Ctx, id: &str, req: &Request) -> Response {
    let body = match parse_body(req) {
        Ok(b) => b,
        Err(resp) => return resp,
    };
    let mut cfg = crate::pareto::ParetoConfig::default();
    if let Some(r) = body.get("restarts").and_then(Json::as_usize) {
        cfg.restarts = r;
    }
    if let Some(c) = body.get("archive_cap").and_then(Json::as_usize) {
        cfg.archive_cap = c;
    }
    if let Some(p) = body.get("passes").and_then(Json::as_usize) {
        cfg.passes = p;
    }
    if let Some(p) = body.get("partners").and_then(Json::as_usize) {
        cfg.partners = p;
    }
    if let Some(s) = body.get("seed").and_then(Json::as_usize) {
        cfg.seed = s as u64;
    }
    let handle = match load_handle(ctx, id) {
        Ok(h) => h,
        Err(resp) => return resp,
    };
    // Copy the handle's contents out under the lock, then release it —
    // the multi-restart search must not block other requests on this
    // partition. `to_dataset` rows follow `entries()` ascending-id
    // order, so the handle's labels line up with the dataset rows and
    // seed restart 0: the front starts from (and must weakly dominate)
    // the served partition's own point.
    let part = handle.lock().unwrap();
    let ds = match part.to_dataset(id) {
        Ok(ds) => ds,
        Err(e) => return err_response(&e),
    };
    let seed_labels: Vec<u32> = part.entries().into_iter().map(|(_, lab)| lab).collect();
    let k = part.k();
    drop(part);
    let front =
        match crate::pareto::engine::pareto_front(&ds.view(), k, &cfg, Some(&seed_labels), None) {
            Ok(f) => f,
            Err(e) => return err_response(&e),
        };
    ctx.metrics.observe_pareto(cfg.restarts, front.points.len());
    let points = Json::Arr(
        front
            .points
            .iter()
            .map(|p| {
                let mut m = BTreeMap::new();
                m.insert("diversity".to_string(), num(p.diversity));
                m.insert("dispersion".to_string(), num(p.dispersion));
                m.insert("upper_bound".to_string(), num(p.upper_bound));
                m.insert("gap".to_string(), num(p.gap));
                Json::Obj(m)
            })
            .collect(),
    );
    Response::json(
        200,
        obj(vec![
            ("id", Json::Str(id.to_string())),
            ("restarts", num(front.restarts as f64)),
            ("front_size", num(front.points.len() as f64)),
            ("hypervolume", num(front.hypervolume((0.0, 0.0)))),
            ("front", points),
        ]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    /// One-shot raw HTTP exchange: write, read to EOF, return the text.
    fn exchange(addr: SocketAddr, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn healthz_and_drain_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("aba_serve_unit_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let server = Server::start(ServeConfig {
            workers: 1,
            snapshot_dir: dir,
            cfg: AbaConfig { auto_hier: false, ..AbaConfig::default() },
            ..ServeConfig::default()
        })
        .unwrap();
        let addr = server.addr();
        let ok = exchange(addr, "GET /healthz HTTP/1.1\r\n\r\n");
        assert!(ok.starts_with("HTTP/1.1 200"), "{ok}");
        assert!(ok.ends_with("ok\n"), "{ok}");
        let missing = exchange(addr, "GET /v1/partitions/ghost HTTP/1.1\r\n\r\n");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");
        let drain = exchange(addr, "POST /v1/admin/drain HTTP/1.1\r\n\r\n");
        assert!(drain.contains("\"draining\":true"), "{drain}");
        assert_eq!(server.wait().unwrap(), 0);
    }
}
