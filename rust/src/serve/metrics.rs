//! Service telemetry behind `GET /metrics`: request/response counters,
//! a bounded latency ring for p50/p99, queue depth, handle-cache
//! evictions, and the library's own meters (the process-wide
//! [`crate::data::view::gathered_bytes`] staging meter and the
//! per-session [`crate::assignment::sparse::SparseStats`] accumulated
//! across solve requests).
//!
//! Rendered as plain `name value` text lines — no exposition format
//! dependency, trivially curl-able and diffable.

use crate::assignment::sparse::SparseStats;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Latency samples kept for percentile estimation (a sliding window of
/// the most recent requests, not process-lifetime).
const LATENCY_RING: usize = 4096;

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_total: AtomicU64,
    pub responses_2xx: AtomicU64,
    pub responses_4xx: AtomicU64,
    pub responses_5xx: AtomicU64,
    /// Backpressure rejections (subset of `responses_4xx`).
    pub rejected_429: AtomicU64,
    /// Handles evicted from the registry to snapshots.
    pub evictions: AtomicU64,
    /// Current pending-connection queue depth (gauge).
    pub queue_depth: AtomicU64,
    pub sparse_batches: AtomicU64,
    pub dense_batches: AtomicU64,
    pub sparse_escalations: AtomicU64,
    pub sparse_fallbacks: AtomicU64,
    /// Bicriterion Pareto requests served
    /// (`POST /v1/partitions/{id}/pareto`), the restarts they ran, and
    /// the size of the most recent front (gauge).
    pub pareto_requests: AtomicU64,
    pub pareto_restarts: AtomicU64,
    pub pareto_front_size_last: AtomicU64,
    /// Optimality gaps observed on create/get responses
    /// ([`crate::OnlinePartition::gap`]), stored in parts-per-million:
    /// count, most recent, and running maximum.
    pub gap_observations: AtomicU64,
    pub gap_last_ppm: AtomicU64,
    pub gap_max_ppm: AtomicU64,
    /// Request latencies in microseconds, most recent `LATENCY_RING`.
    latencies_us: Mutex<VecDeque<u64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one finished request: status class and latency.
    pub fn observe(&self, status: u16, micros: u64) {
        self.requests_total.fetch_add(1, Ordering::Relaxed);
        let class = match status {
            200..=299 => &self.responses_2xx,
            400..=499 => &self.responses_4xx,
            _ => &self.responses_5xx,
        };
        class.fetch_add(1, Ordering::Relaxed);
        let mut ring = self.latencies_us.lock().unwrap();
        if ring.len() == LATENCY_RING {
            ring.pop_front();
        }
        ring.push_back(micros);
    }

    /// Fold one solve's [`SparseStats`] into the service totals (the
    /// caller resets the session counters afterwards, so each request
    /// contributes exactly once).
    pub fn add_sparse(&self, s: &SparseStats) {
        self.sparse_batches.fetch_add(s.sparse_batches as u64, Ordering::Relaxed);
        self.dense_batches.fetch_add(s.dense_batches as u64, Ordering::Relaxed);
        self.sparse_escalations.fetch_add(s.escalations as u64, Ordering::Relaxed);
        self.sparse_fallbacks.fetch_add(s.fallback_batches as u64, Ordering::Relaxed);
    }

    /// Record one bicriterion Pareto solve: the restarts it ran and the
    /// front size it produced.
    pub fn observe_pareto(&self, restarts: usize, front_size: usize) {
        self.pareto_requests.fetch_add(1, Ordering::Relaxed);
        self.pareto_restarts.fetch_add(restarts as u64, Ordering::Relaxed);
        self.pareto_front_size_last.store(front_size as u64, Ordering::Relaxed);
    }

    /// Record one partition's optimality gap (a fraction in `[0, 1]`,
    /// stored as parts-per-million so the atomics stay integer).
    /// Called wherever a handler computes a gap — create and get.
    pub fn observe_gap(&self, gap: f64) {
        let ppm = (gap.clamp(0.0, 1.0) * 1e6).round() as u64;
        self.gap_observations.fetch_add(1, Ordering::Relaxed);
        self.gap_last_ppm.store(ppm, Ordering::Relaxed);
        self.gap_max_ppm.fetch_max(ppm, Ordering::Relaxed);
    }

    /// (p50, p99) request latency in microseconds over the ring window.
    pub fn latency_percentiles_us(&self) -> (u64, u64) {
        let ring = self.latencies_us.lock().unwrap();
        if ring.is_empty() {
            return (0, 0);
        }
        let mut sorted: Vec<u64> = ring.iter().copied().collect();
        sorted.sort_unstable();
        let at = |q: f64| sorted[((sorted.len() - 1) as f64 * q).round() as usize];
        (at(0.50), at(0.99))
    }

    /// The `GET /metrics` text document. `handles` is the registry's
    /// current resident handle count; `kernel_isa` is the serving
    /// session's distance-kernel selection ([`crate::Aba::kernel_isa`])
    /// — the one textual gauge in the document.
    pub fn render(&self, handles: usize, kernel_isa: &str) -> String {
        let (p50, p99) = self.latency_percentiles_us();
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed);
        format!(
            "aba_requests_total {}\n\
             aba_responses_2xx {}\n\
             aba_responses_4xx {}\n\
             aba_responses_5xx {}\n\
             aba_rejected_429 {}\n\
             aba_queue_depth {}\n\
             aba_handles {}\n\
             aba_evictions {}\n\
             aba_latency_p50_us {}\n\
             aba_latency_p99_us {}\n\
             aba_gathered_bytes {}\n\
             aba_sparse_batches {}\n\
             aba_dense_batches {}\n\
             aba_sparse_escalations {}\n\
             aba_sparse_fallbacks {}\n\
             aba_gap_observations {}\n\
             aba_gap_last_ppm {}\n\
             aba_gap_max_ppm {}\n\
             aba_pareto_requests_total {}\n\
             aba_pareto_restarts_total {}\n\
             aba_pareto_front_size_last {}\n\
             aba_kernel_isa {}\n",
            g(&self.requests_total),
            g(&self.responses_2xx),
            g(&self.responses_4xx),
            g(&self.responses_5xx),
            g(&self.rejected_429),
            g(&self.queue_depth),
            handles,
            g(&self.evictions),
            p50,
            p99,
            crate::data::view::gathered_bytes(),
            g(&self.sparse_batches),
            g(&self.dense_batches),
            g(&self.sparse_escalations),
            g(&self.sparse_fallbacks),
            g(&self.gap_observations),
            g(&self.gap_last_ppm),
            g(&self.gap_max_ppm),
            g(&self.pareto_requests),
            g(&self.pareto_restarts),
            g(&self.pareto_front_size_last),
            kernel_isa,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let m = Metrics::new();
        for us in [100u64, 200, 300, 400, 1000] {
            m.observe(200, us);
        }
        m.observe(404, 50);
        m.observe(500, 50);
        assert_eq!(m.requests_total.load(Ordering::Relaxed), 7);
        assert_eq!(m.responses_2xx.load(Ordering::Relaxed), 5);
        assert_eq!(m.responses_4xx.load(Ordering::Relaxed), 1);
        assert_eq!(m.responses_5xx.load(Ordering::Relaxed), 1);
        let (p50, p99) = m.latency_percentiles_us();
        assert!((100..=400).contains(&p50), "{p50}");
        assert_eq!(p99, 1000);
        let text = m.render(3, "avx2");
        assert!(text.contains("aba_requests_total 7"), "{text}");
        assert!(text.contains("aba_handles 3"), "{text}");
        assert!(text.contains("aba_gathered_bytes "), "{text}");
        assert!(text.contains("aba_kernel_isa avx2"), "{text}");
    }

    #[test]
    fn gap_observations_track_last_and_max() {
        let m = Metrics::new();
        m.observe_gap(0.25);
        m.observe_gap(0.01);
        assert_eq!(m.gap_observations.load(Ordering::Relaxed), 2);
        assert_eq!(m.gap_last_ppm.load(Ordering::Relaxed), 10_000);
        assert_eq!(m.gap_max_ppm.load(Ordering::Relaxed), 250_000);
        // Out-of-range values clamp rather than wrap.
        m.observe_gap(7.0);
        assert_eq!(m.gap_max_ppm.load(Ordering::Relaxed), 1_000_000);
        let text = m.render(0, "scalar");
        assert!(text.contains("aba_gap_last_ppm 1000000"), "{text}");
        assert!(text.contains("aba_gap_observations 3"), "{text}");
    }

    #[test]
    fn pareto_counters_accumulate_and_render() {
        let m = Metrics::new();
        m.observe_pareto(12, 5);
        m.observe_pareto(4, 3);
        assert_eq!(m.pareto_requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.pareto_restarts.load(Ordering::Relaxed), 16);
        assert_eq!(m.pareto_front_size_last.load(Ordering::Relaxed), 3);
        let text = m.render(0, "scalar");
        assert!(text.contains("aba_pareto_requests_total 2"), "{text}");
        assert!(text.contains("aba_pareto_restarts_total 16"), "{text}");
        assert!(text.contains("aba_pareto_front_size_last 3"), "{text}");
    }

    #[test]
    fn sparse_stats_fold_in() {
        let m = Metrics::new();
        m.add_sparse(&SparseStats {
            sparse_batches: 3,
            dense_batches: 1,
            fallback_batches: 1,
            escalations: 2,
            peak_cost_bytes: 64,
        });
        m.add_sparse(&SparseStats { sparse_batches: 2, ..Default::default() });
        assert_eq!(m.sparse_batches.load(Ordering::Relaxed), 5);
        assert_eq!(m.dense_batches.load(Ordering::Relaxed), 1);
        assert_eq!(m.sparse_escalations.load(Ordering::Relaxed), 2);
        assert_eq!(m.sparse_fallbacks.load(Ordering::Relaxed), 1);
    }
}
