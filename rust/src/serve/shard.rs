//! Shard-and-merge solves for the service's create path: split the
//! dataset into `S` contiguous shards, solve each independently on the
//! worker pool (the [`crate::algo::hierarchical`] fan-out scaffold,
//! repurposed for a fixed split instead of a cluster tree), then
//! reconcile the `S·k` local clusters into `k` global groups via
//! rectangular assignment on Ward-style merge costs.
//!
//! # Complexity and quality
//!
//! Each shard solve is the flat ABA path on `n/S` rows:
//! `O((n/S)·(d + log(n/S) + k²))` per shard, run `S`-way parallel.
//! The merge solves `S−1` successive `k×k` max-cost assignments over
//! centroid-level Ward costs — `O(S·k²·d)` to build the cost matrices
//! plus `O(S·k³)` to solve them — and a bounded balance repair pass.
//! Against a single flat solve the merge loses only cross-shard
//! diversity information at the centroid level, so the objective lands
//! within a few percent of the flat solve (the test suite pins
//! `>= 0.9×`); wall-clock drops near-linearly in `S` because the
//! dominant shard solves don't synchronize.

use crate::algo::objective::ClusterDelta;
use crate::algo::{self, AbaConfig};
use crate::assignment;
use crate::data::view::DataView;
use crate::error::{AbaError, AbaResult};
use crate::runtime::{CostBackend, NativeBackend, Parallelism, WorkerPool};
use std::cell::RefCell;
use std::sync::Mutex;

/// One merged global group during reconciliation: its running moment
/// statistics and the parent-view row indices it owns.
struct Group {
    delta: ClusterDelta,
    members: Vec<usize>,
}

/// Ward-linkage merge cost between two clusters, maximized for
/// anticlustering: `(m_c·m_g/(m_c+m_g)) · ‖μ_c − μ_g‖²`. Folding the
/// *most separated* centroids together keeps every global group spread
/// across the feature space.
fn merge_cost(a: &ClusterDelta, b: &ClusterDelta) -> f64 {
    let (ma, mb) = (a.len() as f64, b.len() as f64);
    if ma == 0.0 || mb == 0.0 {
        return 0.0;
    }
    ma * mb / (ma + mb) * crate::runtime::simd::centroid_sq_dist(a.sum(), ma, b.sum(), mb)
}

/// Solve `view` into `k` anticlusters via `shards` independent shard
/// solves reconciled at the centroid level. Returns labels in view-row
/// order. Shards are solved with the `NativeBackend` regardless of
/// `cfg.backend` (per-shard problems are small; staging them to an
/// accelerator would cost more than it saves).
pub fn solve_sharded(
    view: &DataView<'_>,
    k: usize,
    shards: usize,
    cfg: &AbaConfig,
) -> AbaResult<Vec<u32>> {
    let n = view.n();
    if shards < 2 {
        return Err(AbaError::InvalidInput(format!(
            "shard-merge needs shards >= 2, got {shards} (use the flat path for 1)"
        )));
    }
    if view.n_categories() > 0 {
        return Err(AbaError::InvalidInput(
            "shard-merge does not support categorical constraints; \
             use the flat path for masked solves"
                .into(),
        ));
    }
    if n / shards < k {
        return Err(AbaError::InvalidInput(format!(
            "shard-merge needs each shard to hold >= k rows: n={n}, shards={shards}, k={k}"
        )));
    }
    algo::validate(n, k, cfg.strict_divisibility)?;

    // Contiguous balanced shards: base n/S rows, first n%S get one extra.
    let (base, extra) = (n / shards, n % shards);
    let mut groups_idx: Vec<Vec<usize>> = Vec::with_capacity(shards);
    let mut start = 0usize;
    for si in 0..shards {
        let len = base + usize::from(si < extra);
        groups_idx.push((start..start + len).collect());
        start += len;
    }

    // Shard solves run the flat path under a fixed config: no nested
    // hierarchy, and Serial inside each task so the only parallelism is
    // the shard fan-out itself — which is what makes Serial-vs-Threads
    // runs bit-identical (each shard is deterministic either way).
    let shard_cfg = AbaConfig {
        hier: None,
        auto_hier: false,
        parallelism: Parallelism::Serial,
        ..cfg.clone()
    };
    let threads = cfg.parallelism.effective_threads().min(shards);
    let mut shard_labels: Vec<Vec<u32>> = Vec::with_capacity(shards);
    if threads > 1 {
        thread_local! {
            static WORKER_STATE: RefCell<(NativeBackend, crate::algo::core::Scratch)> =
                RefCell::new(Default::default());
        }
        let slots: Vec<Mutex<Option<AbaResult<Vec<u32>>>>> =
            (0..shards).map(|_| Mutex::new(None)).collect();
        let pool = WorkerPool::new(threads);
        pool.run(shards, &|si| {
            let out = WORKER_STATE.with(|state| {
                let mut guard = state.borrow_mut();
                let (be, sc) = &mut *guard;
                let sub = view.select(&groups_idx[si]);
                algo::flat_with_scratch(&sub, k, &shard_cfg, be, sc).map(|(l, _, _)| l)
            });
            *slots[si].lock().unwrap() = Some(out);
        });
        for s in slots {
            shard_labels.push(s.into_inner().unwrap().expect("pool task ran")?);
        }
    } else {
        let mut be = NativeBackend::default();
        let mut sc = crate::algo::core::Scratch::default();
        for idx in &groups_idx {
            let sub = view.select(idx);
            let (labels, _, _) = algo::flat_with_scratch(
                &sub,
                k,
                &shard_cfg,
                &mut be as &mut dyn CostBackend,
                &mut sc,
            )?;
            shard_labels.push(labels);
        }
    }

    // Reconcile: shard 0's k local clusters seed the global groups;
    // every later shard's clusters are matched to groups by max-cost
    // k×k assignment on Ward merge costs, then folded in.
    let d = view.d();
    let build_local = |si: usize| -> Vec<Group> {
        let mut local: Vec<Group> =
            (0..k).map(|_| Group { delta: ClusterDelta::new(d), members: Vec::new() }).collect();
        for (pos, &lab) in shard_labels[si].iter().enumerate() {
            let row = groups_idx[si][pos];
            let g = &mut local[lab as usize];
            g.delta.add(view.row(row));
            g.members.push(row);
        }
        local
    };
    let mut merged = build_local(0);
    for si in 1..shards {
        let local = build_local(si);
        let mut cost = vec![0f32; k * k];
        for (c, lg) in local.iter().enumerate() {
            for (g, mg) in merged.iter().enumerate() {
                cost[c * k + g] = merge_cost(&lg.delta, &mg.delta) as f32;
            }
        }
        let assign = assignment::solve_max(cfg.solver, &cost, k, k);
        for (c, lg) in local.into_iter().enumerate() {
            let target = &mut merged[assign[c]];
            for &row in &lg.members {
                target.delta.add(view.row(row));
            }
            target.members.extend(lg.members);
        }
    }

    // Balance repair: shard sizes differ by at most one, but assignment
    // can still pair a shard's big cluster with a group that already got
    // big clusters. Move rows from the largest group to the smallest —
    // picking the row whose transfer costs the least objective — until
    // sizes differ by at most one. Each move shrinks max−min, so the
    // loop terminates well inside the 2n guard.
    for _ in 0..2 * n {
        let (mut max_g, mut min_g) = (0usize, 0usize);
        for g in 1..k {
            if merged[g].members.len() > merged[max_g].members.len() {
                max_g = g;
            }
            if merged[g].members.len() < merged[min_g].members.len() {
                min_g = g;
            }
        }
        if merged[max_g].members.len() - merged[min_g].members.len() <= 1 {
            break;
        }
        let mut best = (0usize, f64::NEG_INFINITY);
        for (pos, &row) in merged[max_g].members.iter().enumerate() {
            let x = view.row(row);
            let gain = merged[min_g].delta.add_gain(x) - merged[max_g].delta.remove_loss(x);
            if gain > best.1 {
                best = (pos, gain);
            }
        }
        let row = merged[max_g].members.swap_remove(best.0);
        merged[max_g].delta.remove(view.row(row));
        merged[min_g].delta.add(view.row(row));
        merged[min_g].members.push(row);
    }

    let mut labels = vec![0u32; n];
    for (g, group) in merged.iter().enumerate() {
        for &row in &group.members {
            labels[row] = g as u32;
        }
    }
    Ok(labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::objective::ClusterStats;
    use crate::data::synth::{generate, SynthKind};
    use crate::solver::{Aba, Anticlusterer};

    fn sizes(labels: &[u32], k: usize) -> Vec<usize> {
        let mut s = vec![0usize; k];
        for &l in labels {
            s[l as usize] += 1;
        }
        s
    }

    #[test]
    fn four_shards_balanced_and_near_flat() {
        let ds = generate(
            SynthKind::GaussianMixture { components: 6, spread: 3.0 },
            200,
            4,
            11,
            "sh",
        );
        let cfg = AbaConfig { auto_hier: false, ..AbaConfig::default() };
        let labels = solve_sharded(&ds.view(), 5, 4, &cfg).unwrap();
        assert_eq!(labels.len(), 200);
        assert!(labels.iter().all(|&l| l < 5));
        let s = sizes(&labels, 5);
        let (min, max) = (s.iter().min().unwrap(), s.iter().max().unwrap());
        assert!(max - min <= 1, "unbalanced groups: {s:?}");
        // Objective stays close to the single flat solve.
        let sharded = ClusterStats::compute(ds.view(), &labels, 5).ssd_total();
        let flat = Aba::from_config(cfg).unwrap().partition_view(&ds.view(), 5).unwrap();
        let flat_obj = ClusterStats::compute(ds.view(), &flat.labels, 5).ssd_total();
        assert!(
            sharded >= 0.9 * flat_obj,
            "shard-merge objective {sharded} fell below 0.9x flat {flat_obj}"
        );
    }

    #[test]
    fn serial_and_threaded_fanout_are_bit_identical() {
        let ds = generate(SynthKind::Uniform, 160, 3, 7, "sh");
        let serial_cfg = AbaConfig { auto_hier: false, ..AbaConfig::default() };
        let thread_cfg = AbaConfig {
            parallelism: Parallelism::Threads(3),
            ..serial_cfg.clone()
        };
        let a = solve_sharded(&ds.view(), 4, 4, &serial_cfg).unwrap();
        let b = solve_sharded(&ds.view(), 4, 4, &thread_cfg).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn rejects_bad_specs() {
        let ds = generate(SynthKind::Uniform, 40, 2, 1, "sh");
        let cfg = AbaConfig::default();
        assert!(matches!(
            solve_sharded(&ds.view(), 4, 1, &cfg),
            Err(AbaError::InvalidInput(_))
        ));
        // 40 rows over 12 shards leaves 3-row shards, below k=4.
        assert!(matches!(
            solve_sharded(&ds.view(), 4, 12, &cfg),
            Err(AbaError::InvalidInput(_))
        ));
    }
}
