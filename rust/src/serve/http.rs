//! Minimal HTTP/1.1 framing for the serve subsystem: request parsing
//! and response writing over blocking [`TcpStream`]s.
//!
//! Deliberately small — the offline vendor set ships no HTTP crate, and
//! the service only needs `Content-Length`-framed request/response
//! exchanges with `Connection: close` semantics (no keep-alive, no
//! chunked transfer, no TLS). Every request is one connection; clients
//! read to EOF.

use std::io::{self, Read, Write};
use std::net::TcpStream;

/// Largest accepted header block. Requests past this are malformed.
const MAX_HEAD: usize = 64 * 1024;
/// Largest accepted body (inline CSV uploads dominate; 64 MiB covers
/// millions of rows while bounding per-connection memory).
const MAX_BODY: usize = 64 * 1024 * 1024;

/// A parsed request: method, path (query string stripped), lowercased
/// headers, raw body bytes.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Header value by (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Read one request off the stream. `Ok(None)` means the peer
    /// closed the connection before sending anything (not an error).
    pub fn read_from(stream: &mut TcpStream) -> io::Result<Option<Request>> {
        let bad = |msg: &str| io::Error::new(io::ErrorKind::InvalidData, msg.to_string());
        let mut buf: Vec<u8> = Vec::new();
        let mut chunk = [0u8; 4096];
        let head_end = loop {
            if let Some(p) = find_head_end(&buf) {
                break p;
            }
            if buf.len() > MAX_HEAD {
                return Err(bad("header block too large"));
            }
            let n = stream.read(&mut chunk)?;
            if n == 0 {
                if buf.is_empty() {
                    return Ok(None);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed inside header block",
                ));
            }
            buf.extend_from_slice(&chunk[..n]);
        };
        let head = std::str::from_utf8(&buf[..head_end])
            .map_err(|_| bad("header block is not utf-8"))?;
        let mut lines = head.split("\r\n");
        let request_line = lines.next().unwrap_or("");
        let mut parts = request_line.split_whitespace();
        let method = parts.next().ok_or_else(|| bad("empty request line"))?.to_string();
        let target = parts.next().ok_or_else(|| bad("request line has no target"))?;
        let path = target.split('?').next().unwrap_or(target).to_string();
        let mut headers = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            if let Some((k, v)) = line.split_once(':') {
                headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
            }
        }
        let content_len = headers
            .iter()
            .find(|(k, _)| k == "content-length")
            .map(|(_, v)| v.parse::<usize>())
            .transpose()
            .map_err(|_| bad("bad content-length"))?
            .unwrap_or(0);
        if content_len > MAX_BODY {
            return Err(bad("body too large"));
        }
        let mut body = buf[head_end + 4..].to_vec();
        while body.len() < content_len {
            let n = stream.read(&mut chunk)?;
            if n == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed inside body",
                ));
            }
            body.extend_from_slice(&chunk[..n]);
        }
        body.truncate(content_len);
        Ok(Some(Request { method, path, headers, body }))
    }
}

/// Byte offset of the `\r\n\r\n` terminating the header block, if seen.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// An outgoing response. `Connection: close` always — one request per
/// connection keeps the worker model trivial and drain exact.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    content_type: &'static str,
    pub body: String,
    /// `Retry-After` seconds, set on 429 backpressure rejections.
    pub retry_after: Option<u64>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Self {
        Self { status, content_type: "application/json", body, retry_after: None }
    }

    pub fn text(status: u16, body: String) -> Self {
        Self { status, content_type: "text/plain; charset=utf-8", body, retry_after: None }
    }

    /// A `{"error": "..."}` body with proper JSON escaping.
    pub fn error(status: u16, msg: &str) -> Self {
        use crate::util::json::{to_string, Json};
        let mut m = std::collections::BTreeMap::new();
        m.insert("error".to_string(), Json::Str(msg.to_string()));
        Self::json(status, to_string(&Json::Obj(m)))
    }

    pub fn with_retry_after(mut self, secs: u64) -> Self {
        self.retry_after = Some(secs);
        self
    }

    pub fn write_to(&self, stream: &mut TcpStream) -> io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len()
        );
        if let Some(secs) = self.retry_after {
            head.push_str(&format!("Retry-After: {secs}\r\n"));
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes())?;
        stream.write_all(self.body.as_bytes())?;
        stream.flush()
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Round-trip one raw request through a real localhost socket.
    fn parse_raw(raw: &str) -> Request {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_string();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(raw.as_bytes()).unwrap();
        });
        let (mut stream, _) = listener.accept().unwrap();
        let req = Request::read_from(&mut stream).unwrap().unwrap();
        client.join().unwrap();
        req
    }

    #[test]
    fn parses_request_with_body() {
        let req = parse_raw(
            "POST /v1/partitions?x=1 HTTP/1.1\r\nHost: aba\r\nContent-Length: 11\r\n\r\nhello world",
        );
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/partitions");
        assert_eq!(req.header("host"), Some("aba"));
        assert_eq!(req.header("Content-Length"), Some("11"));
        assert_eq!(req.body, b"hello world");
    }

    #[test]
    fn parses_bodyless_get() {
        let req = parse_raw("GET /metrics HTTP/1.1\r\n\r\n");
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/metrics");
        assert!(req.body.is_empty());
    }

    #[test]
    fn head_end_detection() {
        assert_eq!(find_head_end(b"a\r\n\r\nb"), Some(1));
        assert_eq!(find_head_end(b"a\r\nb"), None);
    }
}
