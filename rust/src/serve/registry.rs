//! The partition-handle registry: many live [`OnlinePartition`]s keyed
//! by id, behind an LRU cache that spills to fingerprinted snapshots.
//!
//! Each handle lives in its own `Arc<Mutex<..>>`, so operations on
//! *distinct* partitions run concurrently across server workers while
//! operations on the *same* partition serialize. When the resident
//! count exceeds `max_handles`, the least-recently-used handle is
//! evicted: its snapshot (`{dir}/{id}.json`, the versioned
//! [`crate::online`] persistence format) is written and the in-memory
//! handle dropped. A later request for that id warm-restarts it from
//! the snapshot — gated by the session config fingerprint, so resuming
//! under an incompatible config is a typed
//! [`AbaError::SnapshotMismatch`] (HTTP 409 at the service boundary).
//!
//! Lock order is always registry → handle: eviction takes the handle
//! lock while holding the registry lock (so in-flight operations finish
//! before the snapshot is cut), and request handlers clone the `Arc`
//! out of the registry *before* locking the handle — never the other
//! way around — which rules out deadlock.

use super::metrics::Metrics;
use crate::algo::AbaConfig;
use crate::error::{AbaError, AbaResult};
use crate::online::OnlinePartition;
use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

struct Inner {
    map: HashMap<String, Arc<Mutex<OnlinePartition>>>,
    /// Ids from least- to most-recently used.
    lru: VecDeque<String>,
}

pub struct Registry {
    inner: Mutex<Inner>,
    snapshot_dir: PathBuf,
    max_handles: usize,
    cfg: AbaConfig,
    metrics: Arc<Metrics>,
}

impl Registry {
    /// Create a registry spilling to `snapshot_dir` (created if
    /// missing). `max_handles` is clamped to at least 1.
    pub fn new(
        snapshot_dir: impl Into<PathBuf>,
        max_handles: usize,
        cfg: AbaConfig,
        metrics: Arc<Metrics>,
    ) -> AbaResult<Self> {
        let snapshot_dir = snapshot_dir.into();
        std::fs::create_dir_all(&snapshot_dir)
            .map_err(|e| AbaError::Io(format!("create {snapshot_dir:?}: {e}")))?;
        Ok(Self {
            inner: Mutex::new(Inner { map: HashMap::new(), lru: VecDeque::new() }),
            snapshot_dir,
            max_handles: max_handles.max(1),
            cfg,
            metrics,
        })
    }

    /// Ids double as snapshot file stems, so they are restricted to a
    /// filesystem- and URL-safe alphabet.
    pub fn valid_id(id: &str) -> bool {
        !id.is_empty()
            && id.len() <= 64
            && id.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'-')
    }

    /// Where `id`'s snapshot lives (whether or not one exists yet).
    pub fn snapshot_path(&self, id: &str) -> PathBuf {
        self.snapshot_dir.join(format!("{id}.json"))
    }

    /// The session config handles are maintained (and loaded) under.
    pub fn config(&self) -> &AbaConfig {
        &self.cfg
    }

    /// Resident (in-memory) handle count.
    pub fn handles(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// Whether `id` is resident or has a snapshot on disk.
    pub fn contains(&self, id: &str) -> bool {
        self.inner.lock().unwrap().map.contains_key(id) || self.snapshot_path(id).exists()
    }

    /// Register a freshly solved partition under `id`, evicting LRU
    /// handles past capacity. Fails if the id is taken (resident or
    /// snapshotted) or invalid.
    pub fn insert(&self, id: &str, part: OnlinePartition) -> AbaResult<Arc<Mutex<OnlinePartition>>> {
        if !Self::valid_id(id) {
            return Err(AbaError::InvalidInput(format!(
                "invalid partition id '{id}' (want [A-Za-z0-9_-]{{1,64}})"
            )));
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.map.contains_key(id) || self.snapshot_path(id).exists() {
            return Err(AbaError::InvalidInput(format!("partition '{id}' already exists")));
        }
        let handle = Arc::new(Mutex::new(part));
        inner.map.insert(id.to_string(), Arc::clone(&handle));
        inner.lru.push_back(id.to_string());
        self.evict_over_capacity(&mut inner, id)?;
        Ok(handle)
    }

    /// Fetch a handle: resident → touch LRU and return; snapshot on
    /// disk → warm-restart it (fingerprint-gated, so an incompatible
    /// snapshot is [`AbaError::SnapshotMismatch`]); neither → `None`.
    pub fn get_or_load(&self, id: &str) -> AbaResult<Option<Arc<Mutex<OnlinePartition>>>> {
        if !Self::valid_id(id) {
            return Err(AbaError::InvalidInput(format!("invalid partition id '{id}'")));
        }
        let mut inner = self.inner.lock().unwrap();
        if let Some(handle) = inner.map.get(id).cloned() {
            touch(&mut inner.lru, id);
            return Ok(Some(handle));
        }
        let path = self.snapshot_path(id);
        if !path.exists() {
            return Ok(None);
        }
        // Load while holding the registry lock: slower than dropping it,
        // but it guarantees one load per id (no duplicate handles racing
        // to exist for the same partition).
        let part = OnlinePartition::load(&path, &self.cfg)?;
        let handle = Arc::new(Mutex::new(part));
        inner.map.insert(id.to_string(), Arc::clone(&handle));
        inner.lru.push_back(id.to_string());
        self.evict_over_capacity(&mut inner, id)?;
        Ok(Some(handle))
    }

    /// Snapshot and drop LRU handles until at most `max_handles` remain
    /// (never the just-touched `keep`).
    fn evict_over_capacity(&self, inner: &mut Inner, keep: &str) -> AbaResult<()> {
        while inner.map.len() > self.max_handles {
            let Some(victim_pos) = inner.lru.iter().position(|v| v != keep) else {
                return Ok(());
            };
            let victim = inner.lru.remove(victim_pos).expect("position is in range");
            let Some(handle) = inner.map.remove(&victim) else {
                continue;
            };
            // Taking the handle lock lets any in-flight operation on the
            // victim finish before its state is frozen to disk.
            let guard = handle.lock().unwrap();
            guard.save(self.snapshot_path(&victim))?;
            drop(guard);
            self.metrics.evictions.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Snapshot every resident handle to disk and drop it — the
    /// graceful-drain path. Returns how many snapshots were written.
    pub fn drain_all(&self) -> AbaResult<usize> {
        let mut inner = self.inner.lock().unwrap();
        let ids: Vec<String> = inner.lru.iter().cloned().collect();
        let mut written = 0usize;
        for id in ids {
            if let Some(handle) = inner.map.remove(&id) {
                handle.lock().unwrap().save(self.snapshot_path(&id))?;
                written += 1;
            }
        }
        inner.lru.clear();
        Ok(written)
    }

    /// Snapshot directory (for status/logging).
    pub fn snapshot_dir(&self) -> &Path {
        &self.snapshot_dir
    }
}

/// Move `id` to the most-recently-used end.
fn touch(lru: &mut VecDeque<String>, id: &str) {
    if let Some(pos) = lru.iter().position(|v| v == id) {
        lru.remove(pos);
    }
    lru.push_back(id.to_string());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};
    use crate::solver::Aba;

    fn fresh_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("aba_registry_{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn solve(seed: u64, cfg: &AbaConfig) -> OnlinePartition {
        let ds = generate(SynthKind::Uniform, 40, 3, seed, "r");
        Aba::from_config(cfg.clone()).unwrap().partition_online(&ds.view(), 4).unwrap()
    }

    #[test]
    fn id_validation() {
        assert!(Registry::valid_id("alpha-2_B"));
        assert!(!Registry::valid_id(""));
        assert!(!Registry::valid_id("a/b"));
        assert!(!Registry::valid_id("a b"));
        assert!(!Registry::valid_id(&"x".repeat(65)));
    }

    #[test]
    fn eviction_snapshots_and_warm_restart_is_bit_identical() {
        let cfg = AbaConfig { auto_hier: false, ..AbaConfig::default() };
        let metrics = Arc::new(Metrics::new());
        let reg =
            Registry::new(fresh_dir("evict"), 1, cfg.clone(), Arc::clone(&metrics)).unwrap();
        let part_a = solve(1, &cfg);
        let snap_a = part_a.snapshot_string();
        reg.insert("a", part_a).unwrap();
        // Capacity 1: inserting "b" evicts "a" to its snapshot file.
        reg.insert("b", solve(2, &cfg)).unwrap();
        assert_eq!(metrics.evictions.load(Ordering::Relaxed), 1);
        assert_eq!(reg.handles(), 1);
        assert!(reg.snapshot_path("a").exists());
        // Warm restart reproduces the evicted state bit for bit.
        let back = reg.get_or_load("a").unwrap().unwrap();
        assert_eq!(back.lock().unwrap().snapshot_string(), snap_a);
        // ... and pushed "b" out in turn.
        assert!(reg.snapshot_path("b").exists());
        assert_eq!(reg.handles(), 1);
    }

    #[test]
    fn duplicate_ids_and_misses() {
        let cfg = AbaConfig { auto_hier: false, ..AbaConfig::default() };
        let reg =
            Registry::new(fresh_dir("dup"), 4, cfg.clone(), Arc::new(Metrics::new())).unwrap();
        reg.insert("a", solve(3, &cfg)).unwrap();
        assert!(matches!(reg.insert("a", solve(4, &cfg)), Err(AbaError::InvalidInput(_))));
        assert!(reg.get_or_load("nope").unwrap().is_none());
        assert!(reg.contains("a"));
        assert!(!reg.contains("nope"));
    }

    #[test]
    fn incompatible_snapshot_surfaces_mismatch() {
        let cfg = AbaConfig { auto_hier: false, ..AbaConfig::default() };
        let dir = fresh_dir("fp");
        let reg = Registry::new(&dir, 4, cfg.clone(), Arc::new(Metrics::new())).unwrap();
        solve(5, &cfg).save(dir.join("old.json")).unwrap();
        let other = AbaConfig {
            solver: crate::assignment::SolverKind::Greedy,
            ..AbaConfig::default()
        };
        let reg2 = Registry::new(&dir, 4, other, Arc::new(Metrics::new())).unwrap();
        assert!(matches!(
            reg2.get_or_load("old"),
            Err(AbaError::SnapshotMismatch { .. })
        ));
        // The matching config loads it fine.
        assert!(reg.get_or_load("old").unwrap().is_some());
    }
}
