//! Quality certificates: upper bounds, optimality gaps, and an exact
//! K=2 dispersion oracle.
//!
//! ABA is a heuristic for an NP-hard maximization problem, so a raw
//! objective value says nothing about solution quality on its own.
//! This module supplies the evidence:
//!
//! - [`bounds`] — scalable upper bounds on the diversity objective via
//!   the total-sum decomposition `TSS = WGSS + BGSS`. Any partition's
//!   diversity (within-group sum of squares) is at most the total sum
//!   of squares minus a lower bound on the between-group term, so
//!   `upper_bound = TSS - bgss_lb` certifies every solver's output.
//!   A single pass over the rows (chunked, optionally spread over the
//!   [`WorkerPool`](crate::runtime::WorkerPool)) certifies
//!   million-scale instances in seconds.
//! - [`two_color`] — the exact polynomial cardinality-constrained
//!   K=2 *dispersion* solver built on Tran & Mu's coloring
//!   construction: binary-search the pairwise distances, forbid every
//!   pair closer than the threshold from sharing a group (a proper
//!   2-coloring of the conflict graph), and balance the color classes
//!   with a per-component subset-sum. Used as a fast path in solver
//!   dispatch (`k == 2` + the dispersion criterion) and as a ground
//!   truth oracle for the test suite.
//!
//! Entry points: [`Partition::upper_bound`](crate::Partition::upper_bound)
//! and [`Partition::gap`](crate::Partition::gap) on every solve result,
//! [`AbaBuilder::certify`](crate::AbaBuilder::certify) for timed
//! standalone certificates, `aba run --certify` on the CLI, and
//! [`OnlinePartition::gap`](crate::OnlinePartition::gap) for live
//! handles.

pub mod bounds;
pub mod two_color;

pub use bounds::{certify, certify_with_pool, gap, Certificate};
pub use two_color::{solve_balanced, solve_with_sizes, TwoColorResult};
