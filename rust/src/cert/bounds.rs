//! Scalable upper bounds on the anticlustering diversity objective.
//!
//! # The bound
//!
//! Write a partition's centroid-form diversity (what ABA maximizes) as
//! the within-group sum of squares `WGSS(C) = Σ_c Σ_{i∈c} ||x_i − μ_c||²`.
//! The classical total-sum decomposition says
//!
//! ```text
//! TSS = WGSS(C) + BGSS(C),    BGSS(C) = Σ_c m_c ||μ_c − μ||² ≥ 0
//! ```
//!
//! where `TSS = Σ_i ||x_i − μ||²` is partition-independent. Hence for
//! *every* partition, `WGSS(C) ≤ TSS − bgss_lb` for any valid lower
//! bound `bgss_lb` on the between-group term — this is the complement
//! of bounding MSSC (minimum sum-of-squares clustering) from below:
//! a lower bound on the clustering cost of the k group centroids
//! tightens the anticlustering upper bound. We ship the cheap
//! centroid relaxation of that family (group centroids uncon­strained,
//! so the infimum of `BGSS` is 0 and `upper_bound = TSS`); the
//! `bgss_lb` field keeps the seam open for stronger MSSC-style
//! relaxations without an API change.
//!
//! The pairwise form `W(C) = Σ_c m_c · ssd_c` (Fact 1 of the paper)
//! obeys `W(C) ≤ m_max · TSS` with `m_max = ⌈n/k⌉` under ABA's
//! balanced cardinalities.
//!
//! # Cost and determinism
//!
//! [`certify`] makes one pass over the rows accumulating the first and
//! second moments `(Σx, Σ||x||²)` in fixed 4096-row chunks; chunk
//! partials are folded in chunk order, so serial and
//! [`WorkerPool`]-parallel runs produce bit-identical certificates.
//! That is O(nd) work total — million-scale instances certify in
//! seconds on one core and fractions of a second on a pool.
//!
//! Partitions get the same bound for free: [`crate::Partition`] derives
//! `upper_bound() = objective + BGSS(C)` from its [`ClusterStats`],
//! which is exact in floating point (`BGSS` is a sum of non-negative
//! terms), so the property `upper_bound() ≥ diversity objective` holds
//! to the last bit.

use std::time::Instant;

use crate::algo::objective::ClusterStats;
use crate::data::DataView;
use crate::error::{AbaError, AbaResult};
use crate::runtime::WorkerPool;

/// Rows per accumulation chunk. Fixed so the fold order (and thus the
/// f64 result) does not depend on thread count.
const CHUNK: usize = 4096;

/// A solver-independent quality certificate for one `(dataset, k)`
/// instance: every balanced k-partition's diversity objective is at
/// most [`Certificate::upper_bound`].
#[derive(Debug, Clone, PartialEq)]
pub struct Certificate {
    /// Number of rows certified.
    pub n: usize,
    /// Number of anticlusters the bound is for.
    pub k: usize,
    /// Total sum of squares around the global centroid.
    pub total_ss: f64,
    /// Lower bound on the between-group term `BGSS` over balanced
    /// k-partitions. Currently the centroid relaxation (0.0); kept as
    /// a field so stronger MSSC-style bounds slot in transparently.
    pub bgss_lb: f64,
    /// Upper bound on the centroid-form diversity objective
    /// (`total_ss − bgss_lb`).
    pub upper_bound: f64,
    /// Upper bound on the pairwise form `W(C) = Σ_c m_c · ssd_c`,
    /// namely `⌈n/k⌉ · total_ss`.
    pub pairwise_upper_bound: f64,
    /// Wall-clock seconds spent computing the certificate.
    pub secs: f64,
}

impl Certificate {
    /// Relative optimality gap of `objective` against this
    /// certificate's bound — see the free function [`gap`].
    pub fn gap(&self, objective: f64) -> f64 {
        gap(objective, self.upper_bound)
    }
}

/// Relative optimality gap `(upper_bound − objective) / upper_bound`,
/// clamped to `[0, 1]`. A gap of `0.0` means the solution provably
/// attains the bound (or the instance is degenerate with
/// `upper_bound == 0`); `0.02` means the solution is certified within
/// 2% of optimal.
pub fn gap(objective: f64, upper_bound: f64) -> f64 {
    if upper_bound <= 0.0 {
        return 0.0;
    }
    ((upper_bound - objective) / upper_bound).clamp(0.0, 1.0)
}

/// Diversity upper bound derived from a partition's per-cluster stats:
/// `objective + BGSS` (the partition's own total-sum identity). Exact
/// in floating point because `BGSS` is a sum of non-negative terms.
pub(crate) fn upper_bound_from_stats(stats: &ClusterStats) -> f64 {
    stats.ssd_total() + stats.bgss
}

/// Certify `(view, k)` serially. See [`certify_with_pool`].
pub fn certify(view: &DataView, k: usize) -> AbaResult<Certificate> {
    certify_with_pool(view, k, None)
}

/// Compute a [`Certificate`] for `(view, k)`: one chunked pass over
/// the rows (spread over `pool` when given), folded deterministically.
///
/// Errors with [`AbaError::EmptyDataset`] / [`AbaError::InvalidK`] on
/// degenerate instances; never looks at labels, so the bound applies
/// to any solver's output on this data.
pub fn certify_with_pool(
    view: &DataView,
    k: usize,
    pool: Option<&WorkerPool>,
) -> AbaResult<Certificate> {
    let n = view.n();
    let d = view.d();
    if n == 0 {
        return Err(AbaError::EmptyDataset);
    }
    if k == 0 || k > n {
        return Err(AbaError::InvalidK {
            k,
            n,
            reason: "certificates need 1 <= k <= n".into(),
        });
    }
    let t0 = Instant::now();

    let n_chunks = n.div_ceil(CHUNK);
    let mut parts: Vec<(Vec<f64>, f64)> = vec![(vec![0.0; d], 0.0); n_chunks];
    let fill = |ci: usize, slot: &mut (Vec<f64>, f64)| {
        let lo = ci * CHUNK;
        let hi = (lo + CHUNK).min(n);
        for i in lo..hi {
            // Same objective-tier accumulate as `ClusterDelta::add`, so
            // certificate moments and online moments share one fold.
            slot.1 += crate::runtime::simd::accumulate(&mut slot.0, view.row(i));
        }
    };
    match pool {
        Some(p) => p.run_mut(&mut parts, &fill),
        None => {
            for (ci, slot) in parts.iter_mut().enumerate() {
                fill(ci, slot);
            }
        }
    }

    // Fold in chunk order: identical result for serial and pooled runs.
    let mut sum = vec![0.0f64; d];
    let mut sumsq = 0.0f64;
    for (s, q) in &parts {
        for (acc, v) in sum.iter_mut().zip(s) {
            *acc += *v;
        }
        sumsq += *q;
    }
    let norm2: f64 = sum.iter().map(|s| s * s).sum();
    let total_ss = (sumsq - norm2 / n as f64).max(0.0);

    // Centroid relaxation of the MSSC-complement bound: with the k
    // group centroids unconstrained, inf BGSS = 0. Stronger
    // relaxations land here without touching callers.
    let bgss_lb = 0.0;
    let m_max = n.div_ceil(k);

    Ok(Certificate {
        n,
        k,
        total_ss,
        bgss_lb,
        upper_bound: total_ss - bgss_lb,
        pairwise_upper_bound: m_max as f64 * total_ss,
        secs: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};
    use crate::runtime::Parallelism;
    use crate::solver::Anticlusterer;

    #[test]
    fn serial_and_pooled_certificates_are_bit_identical() {
        let ds = generate(SynthKind::GaussianMixture { components: 4, spread: 2.5 }, 9000, 7, 11, "cert");
        let pool = WorkerPool::new(3);
        let a = certify(&ds.view(), 5).unwrap();
        let b = certify_with_pool(&ds.view(), 5, Some(&pool)).unwrap();
        assert_eq!(a.total_ss.to_bits(), b.total_ss.to_bits());
        assert_eq!(a.upper_bound.to_bits(), b.upper_bound.to_bits());
        assert!(a.total_ss > 0.0);
        assert_eq!(a.pairwise_upper_bound, 1800.0 * a.total_ss);
    }

    #[test]
    fn bound_dominates_every_solve() {
        let ds = generate(SynthKind::Uniform, 240, 4, 3, "cert-dom");
        let cert = certify(&ds.view(), 6).unwrap();
        for par in [Parallelism::Serial, Parallelism::Threads(3)] {
            let part = crate::Aba::builder()
                .parallelism(par)
                .build()
                .unwrap()
                .partition(&ds, 6)
                .unwrap();
            assert!(
                part.objective <= cert.upper_bound + 1e-9 * cert.upper_bound.abs(),
                "objective {} exceeds certificate bound {}",
                part.objective,
                cert.upper_bound
            );
            assert!(cert.gap(part.objective) >= 0.0);
        }
    }

    #[test]
    fn gap_is_clamped_and_degenerate_safe() {
        assert_eq!(gap(5.0, 0.0), 0.0);
        assert_eq!(gap(10.0, 10.0), 0.0);
        assert_eq!(gap(11.0, 10.0), 0.0); // fp overshoot clamps, never negative
        assert!((gap(98.0, 100.0) - 0.02).abs() < 1e-12);
        assert_eq!(gap(-1.0, 10.0), 1.0);
    }

    #[test]
    fn degenerate_instances_error_typed() {
        let ds = generate(SynthKind::Uniform, 10, 2, 1, "cert-k");
        assert!(matches!(certify(&ds.view(), 0), Err(AbaError::InvalidK { .. })));
        assert!(matches!(certify(&ds.view(), 11), Err(AbaError::InvalidK { .. })));
    }

    #[test]
    fn constant_data_certifies_at_zero() {
        let rows = vec![vec![2.5f32, -1.0]; 50];
        let ds = crate::data::Dataset::from_rows("const", &rows).unwrap();
        let cert = certify(&ds.view(), 5).unwrap();
        assert_eq!(cert.upper_bound, 0.0);
        assert_eq!(cert.gap(0.0), 0.0);
    }
}
