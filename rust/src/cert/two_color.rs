//! Exact cardinality-constrained K=2 dispersion via Tran & Mu's
//! coloring construction.
//!
//! Maximizing *dispersion* (the minimum within-group pairwise
//! distance) over balanced 2-partitions is polynomial, unlike the
//! k ≥ 3 case: a partition has dispersion ≥ t exactly when every pair
//! closer than t is split across the two groups — i.e. when the
//! "conflict graph" on pairs with `d² < t` is properly 2-colored by
//! the partition. That yields the construction:
//!
//! 1. Sort the n(n−1)/2 pairwise squared distances; the optimum is one
//!    of the distinct values (or ∞ when both groups are singletons).
//! 2. Binary-search the threshold. A threshold `t` is *feasible* when
//!    the conflict graph is bipartite **and** the color classes can be
//!    balanced to the requested cardinalities: each connected component
//!    fixes its two sides up to a swap, so hitting the target size is a
//!    per-component subset-sum over `(a_i, b_i)` side sizes.
//! 3. Rebuild the partition at the largest feasible threshold.
//!
//! Feasibility is monotone (larger thresholds only add conflict
//! edges), so the binary search is sound; infeasibility of the next
//! distinct value certifies optimality of the returned partition.
//! Total cost is `O(n² log n)` time and `O(n²)` memory — exact at a
//! few thousand points, which is what the solver fast path
//! (`k == 2` + [`Criterion::Dispersion`](crate::algo::Criterion)) and
//! the test oracle need.

use crate::algo::objective;
use crate::data::DataView;
use crate::error::{AbaError, AbaResult};

/// An exact K=2 dispersion solution.
#[derive(Debug, Clone, PartialEq)]
pub struct TwoColorResult {
    /// Group label in `{0, 1}` per object.
    pub labels: Vec<u32>,
    /// The partition's dispersion: minimum within-group squared
    /// Euclidean distance (`f64::INFINITY` when both groups are
    /// singletons). Provably maximal for the requested cardinalities.
    pub dispersion: f64,
}

/// Solve with ABA's balanced cardinalities: group 0 gets `⌈n/2⌉`
/// objects, group 1 the rest.
pub fn solve_balanced(view: &DataView) -> AbaResult<TwoColorResult> {
    solve_with_sizes(view, view.n().div_ceil(2))
}

/// Solve with an explicit cardinality: group 0 gets exactly `m0`
/// objects (`1 <= m0 <= n-1`), group 1 the remaining `n − m0`.
pub fn solve_with_sizes(view: &DataView, m0: usize) -> AbaResult<TwoColorResult> {
    let n = view.n();
    if n == 0 {
        return Err(AbaError::EmptyDataset);
    }
    if n < 2 {
        return Err(AbaError::InvalidK {
            k: 2,
            n,
            reason: "two groups need at least two objects".into(),
        });
    }
    if m0 == 0 || m0 >= n {
        return Err(AbaError::InvalidInput(format!(
            "group-0 cardinality must satisfy 1 <= m0 <= n-1, got m0={m0} for n={n}"
        )));
    }

    // All pairwise squared distances, ascending; ties broken by index
    // so the construction is deterministic.
    let mut pairs: Vec<(f64, u32, u32)> = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in (i + 1)..n {
            pairs.push((view.dist2(i, j), i as u32, j as u32));
        }
    }
    pairs.sort_by(|a, b| a.partial_cmp(b).expect("finite distances"));

    // Candidate thresholds: each distinct distance paired with the
    // number of strictly-smaller pairs (the conflict-edge prefix), plus
    // the ∞ sentinel (all pairs in conflict — feasible only at n = 2).
    let mut cands: Vec<(f64, usize)> = Vec::new();
    for (idx, &(d, _, _)) in pairs.iter().enumerate() {
        if cands.last().map(|&(v, _)| v) != Some(d) {
            cands.push((d, idx));
        }
    }
    cands.push((f64::INFINITY, pairs.len()));

    // Binary search the largest feasible threshold. Index 0 is always
    // feasible: its conflict prefix is empty, so any split of the
    // requested sizes works.
    let mut lo = 0usize;
    let mut best = color_and_balance(n, &pairs[..cands[0].1], m0)
        .expect("empty conflict graph is always balanceable");
    let mut hi = cands.len() - 1;
    while lo < hi {
        let mid = lo + (hi - lo).div_ceil(2);
        match color_and_balance(n, &pairs[..cands[mid].1], m0) {
            Some(labels) => {
                best = labels;
                lo = mid;
            }
            None => hi = mid - 1,
        }
    }

    let dispersion = objective::dispersion(view, &best, 2);
    debug_assert!(dispersion >= cands[lo].0 || dispersion.is_infinite());
    Ok(TwoColorResult { labels: best, dispersion })
}

/// Properly 2-color the conflict graph given by `edges` and balance the
/// component sides to put exactly `m0` vertices in group 0. Returns
/// `None` when the graph is odd-cycled or no side-choice hits `m0`.
fn color_and_balance(n: usize, edges: &[(f64, u32, u32)], m0: usize) -> Option<Vec<u32>> {
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    for &(_, i, j) in edges {
        adj[i as usize].push(j);
        adj[j as usize].push(i);
    }

    // BFS 2-coloring per connected component; `comp_sides[c]` collects
    // the component's vertices split by color.
    let mut color: Vec<i8> = vec![-1; n];
    let mut comp_sides: Vec<[Vec<u32>; 2]> = Vec::new();
    let mut queue: Vec<u32> = Vec::new();
    for start in 0..n {
        if color[start] >= 0 {
            continue;
        }
        let mut sides: [Vec<u32>; 2] = [Vec::new(), Vec::new()];
        color[start] = 0;
        sides[0].push(start as u32);
        queue.clear();
        queue.push(start as u32);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head] as usize;
            head += 1;
            let cu = color[u];
            for &v in &adj[u] {
                let v = v as usize;
                if color[v] < 0 {
                    color[v] = 1 - cu;
                    sides[(1 - cu) as usize].push(v as u32);
                    queue.push(v as u32);
                } else if color[v] == cu {
                    return None; // odd cycle: not 2-colorable
                }
            }
        }
        comp_sides.push(sides);
    }

    // Subset-sum over component side sizes: pick side 0 or side 1 of
    // each component into group 0, hitting exactly m0. `choice[c][s]`
    // remembers which side reached sum `s` after component `c`.
    let nc = comp_sides.len();
    let mut reach = vec![false; m0 + 1];
    reach[0] = true;
    let mut choice: Vec<Vec<Option<u8>>> = vec![vec![None; m0 + 1]; nc];
    for (c, sides) in comp_sides.iter().enumerate() {
        let (a, b) = (sides[0].len(), sides[1].len());
        let mut next = vec![false; m0 + 1];
        for s in 0..=m0 {
            if !reach[s] {
                continue;
            }
            // Prefer side 0 on ties for a deterministic reconstruction.
            if s + a <= m0 && !next[s + a] {
                next[s + a] = true;
                choice[c][s + a] = Some(0);
            }
            if s + b <= m0 && !next[s + b] {
                next[s + b] = true;
                choice[c][s + b] = Some(1);
            }
        }
        reach = next;
    }
    if !reach[m0] {
        return None;
    }

    // Walk the choices back and emit labels.
    let mut labels = vec![1u32; n];
    let mut s = m0;
    for c in (0..nc).rev() {
        let side = choice[c][s].expect("reachable sum has a recorded choice") as usize;
        for &v in &comp_sides[c][side] {
            labels[v as usize] = 0;
        }
        s -= comp_sides[c][side].len();
    }
    debug_assert_eq!(s, 0);
    Some(labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    fn ds_1d(xs: &[f32]) -> Dataset {
        let rows: Vec<Vec<f32>> = xs.iter().map(|&x| vec![x]).collect();
        Dataset::from_rows("two-color", &rows).unwrap()
    }

    #[test]
    fn line_instance_has_known_optimum() {
        // Points 0, 1, 10, 11: the optimal balanced split is {0,10} vs
        // {1,11} (dispersion 100); any split keeping a near pair
        // together scores at most 81.
        let ds = ds_1d(&[0.0, 1.0, 10.0, 11.0]);
        let res = solve_balanced(&ds.view()).unwrap();
        assert_eq!(res.dispersion, 100.0);
        assert_eq!(res.labels[0], res.labels[2]);
        assert_eq!(res.labels[1], res.labels[3]);
        assert_ne!(res.labels[0], res.labels[1]);
    }

    #[test]
    fn two_points_disperse_to_infinity() {
        let ds = ds_1d(&[3.0, 7.0]);
        let res = solve_balanced(&ds.view()).unwrap();
        assert!(res.dispersion.is_infinite());
        let mut sorted = res.labels.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1]);
    }

    #[test]
    fn duplicate_points_yield_zero_dispersion() {
        let ds = ds_1d(&[5.0, 5.0, 5.0, 5.0]);
        let res = solve_balanced(&ds.view()).unwrap();
        assert_eq!(res.dispersion, 0.0);
        assert_eq!(res.labels.iter().filter(|&&l| l == 0).count(), 2);
    }

    #[test]
    fn unbalanced_cardinalities_are_respected() {
        let ds = ds_1d(&[0.0, 1.0, 2.0, 30.0, 31.0]);
        for m0 in 1..=4 {
            let res = solve_with_sizes(&ds.view(), m0).unwrap();
            assert_eq!(
                res.labels.iter().filter(|&&l| l == 0).count(),
                m0,
                "m0={m0}"
            );
        }
    }

    #[test]
    fn degenerate_inputs_error_typed() {
        let empty: Vec<Vec<f32>> = Vec::new();
        let ds = Dataset::from_rows("e", &empty);
        assert!(ds.is_err() || solve_balanced(&ds.unwrap().view()).is_err());
        let one = ds_1d(&[1.0]);
        assert!(matches!(
            solve_balanced(&one.view()),
            Err(AbaError::InvalidK { .. })
        ));
        let four = ds_1d(&[1.0, 2.0, 3.0, 4.0]);
        assert!(matches!(
            solve_with_sizes(&four.view(), 0),
            Err(AbaError::InvalidInput(_))
        ));
        assert!(matches!(
            solve_with_sizes(&four.view(), 4),
            Err(AbaError::InvalidInput(_))
        ));
    }
}
