//! Compressed-sparse-row undirected graph with integer edge weights
//! (METIS's input format uses integer weights; the paper rounds up).

/// Undirected weighted graph in CSR form. Every edge `{u, v}` is stored
/// twice (in `u`'s and `v`'s adjacency).
#[derive(Clone, Debug)]
pub struct Graph {
    pub n: usize,
    /// Offsets, length `n + 1`.
    pub xadj: Vec<usize>,
    /// Flattened neighbor lists.
    pub adj: Vec<u32>,
    /// Edge weights, parallel to `adj`.
    pub w: Vec<u64>,
    /// Vertex weights (1 at the finest level; merged counts when coarsened).
    pub vwgt: Vec<u64>,
}

impl Graph {
    /// Build from an edge list `{(u, v, w)}` (u != v; duplicates summed).
    pub fn from_edges(n: usize, edges: &[(u32, u32, u64)]) -> Self {
        let mut deg = vec![0usize; n];
        for &(u, v, _) in edges {
            assert!(u != v, "self loop {u}");
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut xadj = vec![0usize; n + 1];
        for i in 0..n {
            xadj[i + 1] = xadj[i] + deg[i];
        }
        let m2 = xadj[n];
        let mut adj = vec![0u32; m2];
        let mut w = vec![0u64; m2];
        let mut cursor = xadj.clone();
        for &(u, v, wt) in edges {
            adj[cursor[u as usize]] = v;
            w[cursor[u as usize]] = wt;
            cursor[u as usize] += 1;
            adj[cursor[v as usize]] = u;
            w[cursor[v as usize]] = wt;
            cursor[v as usize] += 1;
        }
        let mut g = Graph { n, xadj, adj, w, vwgt: vec![1; n] };
        g.dedupe();
        g
    }

    /// Merge parallel edges (summing weights); sorts each adjacency list.
    fn dedupe(&mut self) {
        let mut nx = Vec::with_capacity(self.n + 1);
        let mut na = Vec::with_capacity(self.adj.len());
        let mut nw = Vec::with_capacity(self.w.len());
        nx.push(0);
        let mut buf: Vec<(u32, u64)> = Vec::new();
        for u in 0..self.n {
            buf.clear();
            for e in self.xadj[u]..self.xadj[u + 1] {
                buf.push((self.adj[e], self.w[e]));
            }
            buf.sort_unstable_by_key(|&(v, _)| v);
            let mut i = 0;
            while i < buf.len() {
                let v = buf[i].0;
                let mut wt = 0u64;
                while i < buf.len() && buf[i].0 == v {
                    wt += buf[i].1;
                    i += 1;
                }
                na.push(v);
                nw.push(wt);
            }
            nx.push(na.len());
        }
        self.xadj = nx;
        self.adj = na;
        self.w = nw;
    }

    /// Neighbors of `u` with weights.
    #[inline]
    pub fn neighbors(&self, u: usize) -> impl Iterator<Item = (usize, u64)> + '_ {
        (self.xadj[u]..self.xadj[u + 1]).map(move |e| (self.adj[e] as usize, self.w[e]))
    }

    /// Degree of `u`.
    #[inline]
    pub fn degree(&self, u: usize) -> usize {
        self.xadj[u + 1] - self.xadj[u]
    }

    /// Number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.adj.len() / 2
    }

    /// Total cut weight of a partition (each crossing edge counted once).
    pub fn cut_cost(&self, part: &[u32]) -> u64 {
        assert_eq!(part.len(), self.n);
        let mut cut = 0u64;
        for u in 0..self.n {
            for (v, w) in self.neighbors(u) {
                if part[u] != part[v] {
                    cut += w;
                }
            }
        }
        cut / 2
    }

    /// Total vertex weight.
    pub fn total_vwgt(&self) -> u64 {
        self.vwgt.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle_plus_leaf() -> Graph {
        // 0-1 (w2), 1-2 (w3), 0-2 (w4), 2-3 (w10)
        Graph::from_edges(4, &[(0, 1, 2), (1, 2, 3), (0, 2, 4), (2, 3, 10)])
    }

    #[test]
    fn csr_shape() {
        let g = triangle_plus_leaf();
        assert_eq!(g.n, 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.degree(2), 3);
        assert_eq!(g.degree(3), 1);
    }

    #[test]
    fn duplicate_edges_summed() {
        let g = Graph::from_edges(2, &[(0, 1, 2), (1, 0, 5)]);
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.neighbors(0).next(), Some((1, 7)));
    }

    #[test]
    fn cut_cost_counts_crossings_once() {
        let g = triangle_plus_leaf();
        // Partition {0,1} vs {2,3}: crossing edges 1-2 (3) and 0-2 (4).
        assert_eq!(g.cut_cost(&[0, 0, 1, 1]), 7);
        // All in one part: no cut.
        assert_eq!(g.cut_cost(&[0, 0, 0, 0]), 0);
        // Isolate 3: only 2-3 crosses.
        assert_eq!(g.cut_cost(&[0, 0, 0, 1]), 10);
    }
}
