//! Sparse graphs and balanced k-cut partitioning.
//!
//! Substrate for the §5.5 experiment: the paper feeds METIS a sparse
//! graph built from each object's `p = 30` randomly selected neighbors
//! with squared-Euclidean edge weights rounded up to integers, then
//! compares balanced k-cuts against ABA. METIS itself is unavailable
//! offline, so [`metis_like`] implements the same algorithm family —
//! multilevel heavy-edge coarsening, greedy graph growing, FM-style
//! boundary refinement (Karypis & Kumar 1998) — which reproduces METIS's
//! qualitative behaviour: good cuts, slightly imperfect balance.

pub mod builder;
pub mod csr;
pub mod metis_like;

pub use csr::Graph;
