//! Multilevel balanced k-way partitioner — the METIS stand-in.
//!
//! Same algorithm family as Karypis & Kumar (1998): (1) coarsen by
//! heavy-edge matching until the graph is small, (2) greedy graph-growing
//! initial partition on the coarsest graph, (3) uncoarsen with FM-style
//! greedy boundary refinement under a vertex-weight balance constraint.
//! Minimizes total cut weight. Like METIS, balance is approximate (the
//! default 3% imbalance tolerance), which is exactly the behaviour Table
//! 11 of the paper contrasts with ABA's perfect balance.

use super::csr::Graph;
use crate::rng::Pcg32;

/// Partitioner configuration.
#[derive(Clone, Debug)]
pub struct PartitionConfig {
    /// Number of parts.
    pub k: usize,
    /// Allowed relative imbalance (METIS default ufactor=30 → 3%).
    pub imbalance: f64,
    /// RNG seed (matching order, refinement order).
    pub seed: u64,
    /// Stop coarsening when the graph has at most `max(coarse_factor * k,
    /// 100)` vertices.
    pub coarse_factor: usize,
    /// Refinement passes per level.
    pub refine_passes: usize,
}

impl PartitionConfig {
    pub fn new(k: usize) -> Self {
        // METIS's ufactor default allows 3% imbalance, but on the paper's
        // instances it *delivered* near-perfect balance (Table 11 ratios
        // 99.4–100%). We pin the tolerance to that observed behaviour so
        // the k-cut comparison is apples-to-apples.
        Self { k, imbalance: 0.005, seed: 1, coarse_factor: 30, refine_passes: 4 }
    }
}

/// Partition the graph into `k` parts minimizing cut weight; returns a
/// part label per vertex.
pub fn partition(g: &Graph, cfg: &PartitionConfig) -> Vec<u32> {
    assert!(cfg.k >= 1);
    if cfg.k == 1 {
        return vec![0; g.n];
    }
    let mut rng = Pcg32::new(cfg.seed);
    // --- Phase 1: coarsen ---------------------------------------------
    let mut levels: Vec<(Graph, Vec<usize>)> = Vec::new(); // (fine graph, fine->coarse map)
    let mut cur = g.clone();
    let stop_at = (cfg.coarse_factor * cfg.k).max(100);
    while cur.n > stop_at {
        let (coarse, map) = coarsen_once(&cur, &mut rng);
        // Diminishing returns: stop if we shrank < 5%.
        if coarse.n as f64 > 0.95 * cur.n as f64 {
            levels.push((cur, map));
            cur = coarse;
            break;
        }
        levels.push((cur, map));
        cur = coarse;
    }
    // --- Phase 2: initial partition on the coarsest graph ---------------
    let mut part = initial_partition(&cur, cfg, &mut rng);
    refine(&cur, &mut part, cfg, &mut rng);
    // --- Phase 3: uncoarsen + refine ------------------------------------
    let mut finest = cur;
    while let Some((fine, map)) = levels.pop() {
        let mut fine_part = vec![0u32; fine.n];
        for v in 0..fine.n {
            fine_part[v] = part[map[v]];
        }
        part = fine_part;
        refine(&fine, &mut part, cfg, &mut rng);
        finest = fine;
    }
    // METIS enforces its balance tolerance explicitly; do the same so the
    // final min/max ratio lands near (1 - imbalance), not wherever greedy
    // growing left it.
    force_balance(&finest, &mut part, cfg);
    refine(&finest, &mut part, cfg, &mut rng);
    part
}

/// Move least-connected vertices out of overweight parts into the
/// lightest parts until every part is within the balance tolerance.
fn force_balance(g: &Graph, part: &mut [u32], cfg: &PartitionConfig) {
    let k = cfg.k;
    let total = g.total_vwgt();
    let avg = total as f64 / k as f64;
    let max_w = ((1.0 + cfg.imbalance) * avg).ceil() as u64;
    let min_w = ((1.0 - cfg.imbalance) * avg).floor() as u64;
    let mut weights = vec![0u64; k];
    for v in 0..g.n {
        weights[part[v] as usize] += g.vwgt[v];
    }
    let mut moves = 0usize;
    loop {
        let heavy = (0..k).max_by_key(|&p| weights[p]).unwrap();
        let light = (0..k).min_by_key(|&p| weights[p]).unwrap();
        // Done once both tolerance bounds hold (or nothing left to move).
        if (weights[heavy] <= max_w && weights[light] >= min_w) || heavy == light {
            break;
        }
        moves += 1;
        if moves > 4 * g.n {
            break; // safety against pathological vertex weights
        }
        // Pick the member of `heavy` with the smallest internal minus
        // external(light) connectivity — cheapest to move.
        let mut best: Option<(i64, usize)> = None;
        for v in 0..g.n {
            if part[v] as usize != heavy {
                continue;
            }
            let mut internal = 0i64;
            let mut to_light = 0i64;
            for (nb, w) in g.neighbors(v) {
                if part[nb] as usize == heavy {
                    internal += w as i64;
                } else if part[nb] as usize == light {
                    to_light += w as i64;
                }
            }
            let score = internal - to_light;
            if best.map_or(true, |(s, _)| score < s) {
                best = Some((score, v));
            }
        }
        let Some((_, v)) = best else { break };
        part[v] = light as u32;
        weights[heavy] -= g.vwgt[v];
        weights[light] += g.vwgt[v];
    }
}

/// One round of heavy-edge matching; returns the coarse graph and the
/// fine-to-coarse vertex map.
fn coarsen_once(g: &Graph, rng: &mut Pcg32) -> (Graph, Vec<usize>) {
    let mut order: Vec<usize> = (0..g.n).collect();
    rng.shuffle(&mut order);
    let mut mate = vec![usize::MAX; g.n];
    for &u in &order {
        if mate[u] != usize::MAX {
            continue;
        }
        // Heaviest unmatched neighbor.
        let mut best = usize::MAX;
        let mut best_w = 0u64;
        for (v, w) in g.neighbors(u) {
            if mate[v] == usize::MAX && v != u && w >= best_w {
                best = v;
                best_w = w;
            }
        }
        if best != usize::MAX {
            mate[u] = best;
            mate[best] = u;
        } else {
            mate[u] = u; // singleton
        }
    }
    // Assign coarse ids.
    let mut map = vec![usize::MAX; g.n];
    let mut next = 0usize;
    for u in 0..g.n {
        if map[u] != usize::MAX {
            continue;
        }
        map[u] = next;
        let m = mate[u];
        if m != u {
            map[m] = next;
        }
        next += 1;
    }
    // Build coarse edges + vertex weights.
    let mut edges = Vec::new();
    let mut vwgt = vec![0u64; next];
    for u in 0..g.n {
        vwgt[map[u]] += g.vwgt[u];
        for (v, w) in g.neighbors(u) {
            let (cu, cv) = (map[u], map[v]);
            if cu < cv {
                edges.push((cu as u32, cv as u32, w));
            }
        }
    }
    let mut coarse = Graph::from_edges(next, &edges);
    coarse.vwgt = vwgt;
    (coarse, map)
}

/// Greedy graph growing: grow each part from a seed, preferring vertices
/// strongly connected to the growing region, until it reaches the target
/// weight.
fn initial_partition(g: &Graph, cfg: &PartitionConfig, rng: &mut Pcg32) -> Vec<u32> {
    let k = cfg.k;
    let total = g.total_vwgt();
    let target = total as f64 / k as f64;
    let mut part = vec![u32::MAX; g.n];
    let mut unassigned = g.n;
    for p in 0..k as u32 {
        if unassigned == 0 {
            break;
        }
        let budget = if p as usize == k - 1 { u64::MAX } else { target.round() as u64 };
        // Seed: random unassigned vertex.
        let mut seed = rng.gen_index(g.n);
        while part[seed] != u32::MAX {
            seed = (seed + 1) % g.n;
        }
        let mut weight = 0u64;
        // Gain map: connection weight into the region.
        let mut gain = vec![0i64; g.n];
        let mut frontier: Vec<usize> = vec![seed];
        while weight < budget && unassigned > 0 {
            // Pick the frontier vertex with max gain (fall back to any
            // unassigned vertex if the frontier is exhausted).
            frontier.retain(|&v| part[v] == u32::MAX);
            let pick = if let Some(&v) = frontier.iter().max_by_key(|&&v| gain[v]) {
                v
            } else {
                let mut v = rng.gen_index(g.n);
                while part[v] != u32::MAX {
                    v = (v + 1) % g.n;
                }
                v
            };
            part[pick] = p;
            weight += g.vwgt[pick];
            unassigned -= 1;
            for (nb, w) in g.neighbors(pick) {
                if part[nb] == u32::MAX {
                    if gain[nb] == 0 {
                        frontier.push(nb);
                    }
                    gain[nb] += w as i64;
                }
            }
        }
    }
    // Safety: anything left joins the lightest part.
    if unassigned > 0 {
        let mut weights = vec![0u64; k];
        for v in 0..g.n {
            if part[v] != u32::MAX {
                weights[part[v] as usize] += g.vwgt[v];
            }
        }
        for v in 0..g.n {
            if part[v] == u32::MAX {
                let lightest = (0..k).min_by_key(|&p| weights[p]).unwrap();
                part[v] = lightest as u32;
                weights[lightest] += g.vwgt[v];
            }
        }
    }
    part
}

/// FM-style greedy boundary refinement: move boundary vertices to the
/// neighboring part with max positive gain, subject to the balance
/// constraint.
fn refine(g: &Graph, part: &mut [u32], cfg: &PartitionConfig, rng: &mut Pcg32) {
    let k = cfg.k;
    let total = g.total_vwgt();
    let avg = total as f64 / k as f64;
    let max_w = ((1.0 + cfg.imbalance) * avg).ceil() as u64;
    let min_w = ((1.0 - cfg.imbalance) * avg).floor() as u64;
    let mut weights = vec![0u64; k];
    for v in 0..g.n {
        weights[part[v] as usize] += g.vwgt[v];
    }
    let mut order: Vec<usize> = (0..g.n).collect();
    let mut conn = vec![0i64; k]; // scratch: connection weight to each part
    for _ in 0..cfg.refine_passes {
        rng.shuffle(&mut order);
        let mut moved = 0usize;
        for &v in &order {
            let own = part[v] as usize;
            // Compute connection weights to adjacent parts.
            let mut touched: Vec<usize> = Vec::with_capacity(8);
            for (nb, w) in g.neighbors(v) {
                let p = part[nb] as usize;
                if conn[p] == 0 {
                    touched.push(p);
                }
                conn[p] += w as i64;
            }
            let internal = conn[own];
            let mut best_p = own;
            let mut best_gain = 0i64;
            for &p in &touched {
                if p == own {
                    continue;
                }
                let gain = conn[p] - internal;
                if gain > best_gain
                    && weights[p] + g.vwgt[v] <= max_w
                    && weights[own] >= min_w + g.vwgt[v]
                {
                    best_gain = gain;
                    best_p = p;
                }
            }
            for &p in &touched {
                conn[p] = 0;
            }
            if best_p != own {
                part[v] = best_p as u32;
                weights[own] -= g.vwgt[v];
                weights[best_p] += g.vwgt[v];
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

/// Min/max part-size ratio in percent (Table 11 columns 10–11); sizes in
/// vertex counts.
pub fn min_max_ratio(part: &[u32], k: usize) -> f64 {
    let mut counts = vec![0usize; k];
    for &p in part {
        counts[p as usize] += 1;
    }
    let min = *counts.iter().min().unwrap() as f64;
    let max = *counts.iter().max().unwrap() as f64;
    if max == 0.0 {
        0.0
    } else {
        100.0 * min / max
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};
    use crate::graph::builder::random_neighbor_graph;

    fn ring(n: usize) -> Graph {
        let edges: Vec<(u32, u32, u64)> = (0..n)
            .map(|i| (i as u32, ((i + 1) % n) as u32, 1))
            .collect();
        Graph::from_edges(n, &edges)
    }

    #[test]
    fn bisects_a_ring_with_cut_2ish() {
        let g = ring(64);
        let cfg = PartitionConfig::new(2);
        let part = partition(&g, &cfg);
        let cut = g.cut_cost(&part);
        // Optimal ring bisection cuts exactly 2 edges; accept small slack.
        assert!(cut <= 6, "cut={cut}");
        assert!(min_max_ratio(&part, 2) >= 80.0);
    }

    #[test]
    fn respects_k_parts_nonempty() {
        let ds = generate(SynthKind::GaussianMixture { components: 4, spread: 8.0 }, 500, 4, 9, "g");
        let g = random_neighbor_graph(&ds, 10, 1);
        let cfg = PartitionConfig::new(4);
        let part = partition(&g, &cfg);
        let mut counts = [0usize; 4];
        for &p in &part {
            counts[p as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
        assert!(min_max_ratio(&part, 4) > 70.0, "{counts:?}");
    }

    #[test]
    fn k_equals_one_trivial() {
        let g = ring(10);
        let part = partition(&g, &PartitionConfig::new(1));
        assert!(part.iter().all(|&p| p == 0));
    }

    #[test]
    fn cut_beats_random_partition() {
        let ds = generate(SynthKind::GaussianMixture { components: 8, spread: 6.0 }, 800, 6, 10, "g");
        let g = random_neighbor_graph(&ds, 12, 2);
        let cfg = PartitionConfig::new(8);
        let part = partition(&g, &cfg);
        // Random balanced partition for comparison.
        let mut rng = crate::rng::Pcg32::new(3);
        let mut idx: Vec<usize> = (0..g.n).collect();
        rng.shuffle(&mut idx);
        let mut rand_part = vec![0u32; g.n];
        for (pos, &v) in idx.iter().enumerate() {
            rand_part[v] = (pos % 8) as u32;
        }
        let (c1, c2) = (g.cut_cost(&part), g.cut_cost(&rand_part));
        assert!(c1 < c2, "metis-like {c1} vs random {c2}");
    }

    #[test]
    fn deterministic_for_seed() {
        let g = ring(128);
        let cfg = PartitionConfig::new(4);
        assert_eq!(partition(&g, &cfg), partition(&g, &cfg));
    }
}
