//! Graph construction from tabular data — the METIS input procedure of
//! §5.5: "for each object, we only list p = 30 randomly selected
//! neighbors with the corresponding edge weights as integers".

use super::csr::Graph;
use crate::data::DataView;
use crate::rng::Pcg32;

/// Build the paper's sparse random-neighbor graph: `p` random distinct
/// neighbors per node, edge weight `ceil(squared distance)` (METIS needs
/// integers; the paper rounds up). Zero-weight edges get weight 1 so the
/// graph stays connected-ish for the partitioner. Accepts a `&Dataset`
/// or a zero-copy [`DataView`] subset.
pub fn random_neighbor_graph<'a>(data: impl Into<DataView<'a>>, p: usize, seed: u64) -> Graph {
    let ds: DataView<'a> = data.into();
    let n = ds.n();
    let mut rng = Pcg32::new(seed);
    let p = p.min(n - 1);
    let mut edges = Vec::with_capacity(n * p);
    for u in 0..n {
        let mut picked = 0usize;
        let mut guard = 0usize;
        let mut seen: Vec<usize> = Vec::with_capacity(p);
        while picked < p && guard < 20 * p {
            guard += 1;
            let v = rng.gen_index(n);
            if v == u || seen.contains(&v) {
                continue;
            }
            seen.push(v);
            picked += 1;
            let w = ds.dist2(u, v).ceil() as u64;
            edges.push((u as u32, v as u32, w.max(1)));
        }
    }
    Graph::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};

    #[test]
    fn every_node_has_at_least_p_neighbors() {
        let ds = generate(SynthKind::Uniform, 200, 4, 3, "u");
        let g = random_neighbor_graph(&ds, 10, 1);
        assert_eq!(g.n, 200);
        for u in 0..g.n {
            assert!(g.degree(u) >= 10, "node {u} degree {}", g.degree(u));
        }
    }

    #[test]
    fn weights_are_positive_integers() {
        let ds = generate(SynthKind::Uniform, 100, 4, 4, "u");
        let g = random_neighbor_graph(&ds, 5, 2);
        assert!(g.w.iter().all(|&w| w >= 1));
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = generate(SynthKind::Uniform, 100, 4, 5, "u");
        let a = random_neighbor_graph(&ds, 5, 9);
        let b = random_neighbor_graph(&ds, 5, 9);
        assert_eq!(a.adj, b.adj);
        assert_eq!(a.w, b.w);
    }

    #[test]
    fn p_clamped_for_tiny_datasets() {
        let ds = generate(SynthKind::Uniform, 5, 2, 6, "u");
        let g = random_neighbor_graph(&ds, 30, 3);
        assert_eq!(g.n, 5);
        for u in 0..g.n {
            assert!(g.degree(u) <= 4);
        }
    }
}
