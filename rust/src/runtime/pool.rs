//! The shared worker pool behind every parallel path in the crate.
//!
//! One [`WorkerPool`] per `Aba` session (owned by the assignment loop's
//! `Scratch`, so it is created once and reused across `partition` calls)
//! serves both parallel workloads:
//!
//! * **chunk-parallel cost matrices** — the native backend splits batch
//!   rows into contiguous chunks and computes them concurrently
//!   (`runtime::backend`), and
//! * **hierarchical fan-out** — independent subproblems of one
//!   decomposition level run as pool tasks (`algo::hierarchical`).
//!
//! The pool is deliberately minimal: `threads - 1` persistent workers
//! plus the calling thread, a FIFO of jobs, and index-claiming inside a
//! job (a job with `tasks` units hands out indices `0..tasks` through an
//! atomic counter, so any mix of workers — including the caller, which
//! always participates — drains it without further coordination). The
//! caller blocks until its job is fully drained, which is what makes the
//! lifetime-erasure in [`WorkerPool::run`] sound and keeps results
//! deterministic: task *i* always computes exactly unit *i*, regardless
//! of which thread runs it or how many threads exist. Serial and
//! parallel executions of the same job are therefore bit-identical by
//! construction.
//!
//! How much parallelism a run uses is a session knob, [`Parallelism`]
//! (`Aba::builder().parallelism(...)`, `--threads` on the CLI), rather
//! than a per-call flag: `Serial` (the default) never builds a pool at
//! all.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::error::AbaError;

/// How much parallelism a session may use. With the native backend (the
/// default), parallel and serial runs produce bit-identical labels
/// (property-tested), so this is purely a wall-clock knob; the XLA
/// backend's fanned-out hierarchical levels match serial results only
/// within numeric tolerance (see [`crate::algo::hierarchical`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Parallelism {
    /// Everything on the calling thread; no pool is created. The
    /// default.
    #[default]
    Serial,
    /// A pool of exactly `n` threads (the calling thread counts as one
    /// of them). `Threads(0)` and `Threads(1)` behave like `Serial`.
    Threads(usize),
    /// One thread per available core
    /// ([`std::thread::available_parallelism`]).
    Auto,
}

impl Parallelism {
    /// The concrete thread count this setting resolves to on this
    /// machine (>= 1). `1` means "run serially, build no pool".
    pub fn effective_threads(self) -> usize {
        match self {
            Parallelism::Serial => 1,
            Parallelism::Threads(n) => n.max(1),
            Parallelism::Auto => std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
        }
    }

    /// Accepted CLI spellings, for help and error messages.
    pub fn accepted() -> &'static str {
        "serial|auto|<n>"
    }
}

impl std::fmt::Display for Parallelism {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Parallelism::Serial => f.write_str("serial"),
            Parallelism::Auto => f.write_str("auto"),
            Parallelism::Threads(n) => write!(f, "{n}"),
        }
    }
}

impl std::str::FromStr for Parallelism {
    type Err = AbaError;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "serial" => Ok(Parallelism::Serial),
            "auto" => Ok(Parallelism::Auto),
            _ => match s.parse::<usize>() {
                Ok(n) if n >= 1 => Ok(Parallelism::Threads(n)),
                _ => Err(AbaError::InvalidInput(format!(
                    "invalid thread count '{s}' (accepted: {})",
                    Parallelism::accepted()
                ))),
            },
        }
    }
}

/// The erased task callback a job fans out over its workers. Raw pointer
/// so the job (which is `'static` inside `Arc`) can reference a
/// stack-borrowed closure; `run`/`defer` guarantee the pointee outlives
/// every dereference by draining the job before the borrow ends.
struct TaskRef(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared calls from any thread are fine)
// and the pointer itself is only dereferenced between job creation and
// the final `pending == 0` handshake, during which the borrow it came
// from is provably alive (see `WorkerPool::run` / `Deferred`).
unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

/// One unit of pool work: `total` independent tasks drained by index
/// claiming. Also its own completion latch.
struct Job {
    task: TaskRef,
    total: usize,
    /// Next unclaimed task index (may grow past `total`).
    next: AtomicUsize,
    /// Tasks not yet finished; `0` means the job is complete.
    pending: AtomicUsize,
    /// Set when any task panicked; re-raised on the calling thread.
    panicked: AtomicBool,
    done: Mutex<bool>,
    done_cv: Condvar,
}

impl Job {
    fn new(f: &(dyn Fn(usize) + Sync), total: usize) -> Self {
        Self {
            task: TaskRef(f as *const (dyn Fn(usize) + Sync)),
            total,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(total),
            panicked: AtomicBool::new(false),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        }
    }

    /// Claim the next unprocessed task index, if any.
    fn claim(&self) -> Option<usize> {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        (i < self.total).then_some(i)
    }

    /// All indices handed out (some may still be executing).
    fn drained(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.total
    }

    fn run_task(&self, i: usize) {
        // SAFETY: `run`/`Deferred` keep the closure borrow alive until
        // `pending` reaches 0, and tasks only execute before that.
        let f = unsafe { &*self.task.0 };
        if catch_unwind(AssertUnwindSafe(|| f(i))).is_err() {
            self.panicked.store(true, Ordering::Relaxed);
        }
        if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            *self.done.lock().unwrap() = true;
            self.done_cv.notify_all();
        }
    }

    /// Execute any still-unclaimed tasks inline, then block until every
    /// claimed task has finished.
    fn help_and_wait(&self) {
        while let Some(i) = self.claim() {
            self.run_task(i);
        }
        let mut done = self.done.lock().unwrap();
        while !*done {
            done = self.done_cv.wait(done).unwrap();
        }
    }

    fn check_panic(&self) {
        if self.panicked.load(Ordering::Relaxed) {
            panic!("a worker-pool task panicked");
        }
    }
}

struct PoolState {
    queue: VecDeque<Arc<Job>>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    available: Condvar,
}

fn worker_loop(shared: &PoolShared) {
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        // Drop fully handed-out jobs off the front.
        while st.queue.front().is_some_and(|j| j.drained()) {
            st.queue.pop_front();
        }
        if let Some(job) = st.queue.front().cloned() {
            drop(st);
            while let Some(i) = job.claim() {
                job.run_task(i);
            }
            st = shared.state.lock().unwrap();
        } else {
            st = shared.available.wait(st).unwrap();
        }
    }
}

/// A fixed-size pool of persistent worker threads. See the module docs
/// for the execution model; construction is the only expensive step
/// (thread spawns), so sessions build one pool and keep it.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    /// A pool with `threads` total execution slots: `threads - 1` spawned
    /// workers plus the calling thread. `threads <= 1` spawns nothing and
    /// every `run` executes inline.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { queue: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("aba-pool-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawning worker-pool thread")
            })
            .collect();
        Self { shared, workers, threads }
    }

    /// Total execution slots (spawned workers + the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn enqueue(&self, job: Arc<Job>) {
        let single = job.total == 1;
        self.shared.state.lock().unwrap().queue.push_back(job);
        // Single-task jobs (the per-batch deferred gathers on the hot
        // loop) need exactly one worker; waking the whole pool for them
        // is pure context-switch overhead.
        if single {
            self.shared.available.notify_one();
        } else {
            self.shared.available.notify_all();
        }
    }

    /// Run `f(0), f(1), ..., f(tasks - 1)` across the pool and block
    /// until all of them finished. The calling thread participates, so
    /// this also works (serially) on a single-threaded pool. Panics if
    /// any task panicked.
    pub fn run(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if tasks == 0 {
            return;
        }
        if self.workers.is_empty() || tasks == 1 {
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        let job = Arc::new(Job::new(f, tasks));
        self.enqueue(Arc::clone(&job));
        job.help_and_wait();
        job.check_panic();
    }

    /// Run `f(i, &mut items[i])` for every element across the pool and
    /// block until all of them finished — the chunk-parallel building
    /// block shared by the cost-matrix kernel and the sparse path's
    /// candidate generation: callers split a large output buffer into
    /// disjoint `&mut` chunks and each task gets exclusive access to its
    /// own. The per-element `Mutex` only converts the shared borrow into
    /// the exclusive one the task body needs; task `i` is claimed exactly
    /// once, so it is never contended. Determinism matches [`Self::run`]:
    /// task `i` always processes element `i`.
    pub fn run_mut<T: Send>(&self, items: &mut [T], f: &(dyn Fn(usize, &mut T) + Sync)) {
        let cells: Vec<Mutex<&mut T>> = items.iter_mut().map(Mutex::new).collect();
        self.run(cells.len(), &|i| {
            let mut guard = cells[i].lock().unwrap();
            f(i, &mut **guard);
        });
    }

    /// Hand `f` to the pool as a single background task and return a
    /// [`Deferred`] that must be waited on (dropping waits too). The
    /// caller keeps its own thread free in the meantime — the overlap
    /// primitive behind the assignment loop's double-buffered batch
    /// staging. If no worker picks the task up, `wait` runs it inline.
    ///
    /// Crate-private on purpose: soundness rests on the `Deferred`
    /// guard actually running (wait-on-drop), so the handle must not
    /// escape to code that could `mem::forget` it while the borrow is
    /// live.
    pub(crate) fn defer<'a>(&self, f: &'a (dyn Fn(usize) + Sync)) -> Deferred<'a> {
        let job = Arc::new(Job::new(f, 1));
        if !self.workers.is_empty() {
            self.enqueue(Arc::clone(&job));
        }
        Deferred { job, _borrow: PhantomData }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A pending single-task job from [`WorkerPool::defer`]. Tied to the
/// task closure's borrow: the job is guaranteed finished by the time the
/// borrow ends, because both [`Deferred::wait`] and the drop guard block
/// on completion (running the task inline if nobody claimed it).
pub(crate) struct Deferred<'a> {
    job: Arc<Job>,
    _borrow: PhantomData<&'a ()>,
}

impl Deferred<'_> {
    /// Block until the task has run (panicking if it panicked).
    pub(crate) fn wait(self) {
        self.job.help_and_wait();
        self.job.check_panic();
    }
}

impl Drop for Deferred<'_> {
    fn drop(&mut self) {
        // Completion is a safety requirement (the task borrows caller
        // state), so the guard waits too; unlike `wait` it must not
        // panic, as it may already be running during an unwind.
        self.job.help_and_wait();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelism_round_trips_and_rejects_garbage() {
        for (s, want) in [
            ("serial", Parallelism::Serial),
            ("auto", Parallelism::Auto),
            ("4", Parallelism::Threads(4)),
        ] {
            assert_eq!(s.parse::<Parallelism>().unwrap(), want);
            assert_eq!(want.to_string(), s);
        }
        for bad in ["0", "-1", "fast", ""] {
            assert!(bad.parse::<Parallelism>().is_err(), "{bad}");
        }
        assert_eq!(Parallelism::Serial.effective_threads(), 1);
        assert_eq!(Parallelism::Threads(0).effective_threads(), 1);
        assert_eq!(Parallelism::Threads(7).effective_threads(), 7);
        assert!(Parallelism::Auto.effective_threads() >= 1);
    }

    #[test]
    fn run_executes_every_index_exactly_once() {
        let pool = WorkerPool::new(4);
        for tasks in [1usize, 2, 7, 64, 1000] {
            let hits: Vec<AtomicUsize> = (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(tasks, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "tasks={tasks}"
            );
        }
    }

    #[test]
    fn run_mut_gives_each_task_exclusive_chunk_access() {
        let pool = WorkerPool::new(4);
        let mut data = vec![0usize; 1000];
        let mut chunks: Vec<(usize, &mut [usize])> = data
            .chunks_mut(64)
            .enumerate()
            .map(|(ci, ch)| (ci * 64, ch))
            .collect();
        pool.run_mut(&mut chunks, &|_i, (r0, ch)| {
            for (off, v) in ch.iter_mut().enumerate() {
                *v = *r0 + off;
            }
        });
        drop(chunks);
        let want: Vec<usize> = (0..1000).collect();
        assert_eq!(data, want);
    }

    #[test]
    fn single_threaded_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let sum = AtomicUsize::new(0);
        pool.run(10, &|i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn pool_is_reusable_across_jobs() {
        let pool = WorkerPool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(16, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 800);
    }

    #[test]
    fn nested_run_from_a_worker_does_not_deadlock() {
        let pool = WorkerPool::new(3);
        let inner_hits = AtomicUsize::new(0);
        pool.run(4, &|_| {
            pool.run(8, &|_| {
                inner_hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(inner_hits.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn defer_overlaps_and_completes() {
        let pool = WorkerPool::new(2);
        let flag = AtomicBool::new(false);
        let task = |_i: usize| flag.store(true, Ordering::Relaxed);
        let deferred = pool.defer(&task);
        deferred.wait();
        assert!(flag.load(Ordering::Relaxed));
        // Dropping without an explicit wait also completes the task.
        let flag2 = AtomicBool::new(false);
        let task2 = |_i: usize| flag2.store(true, Ordering::Relaxed);
        drop(pool.defer(&task2));
        assert!(flag2.load(Ordering::Relaxed));
    }

    #[test]
    fn defer_on_single_threaded_pool_runs_at_wait() {
        let pool = WorkerPool::new(1);
        let hits = AtomicUsize::new(0);
        let task = |_i: usize| {
            hits.fetch_add(1, Ordering::Relaxed);
        };
        let deferred = pool.defer(&task);
        deferred.wait();
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    #[should_panic(expected = "worker-pool task panicked")]
    fn task_panics_propagate_to_the_caller() {
        let pool = WorkerPool::new(2);
        pool.run(8, &|i| {
            assert!(i != 3, "boom");
        });
    }
}
