//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! Rust hot path.
//!
//! The build-time Python (`make artifacts`) lowers the L2 JAX graphs —
//! which call the L1 Pallas kernel — to HLO **text** under `artifacts/`,
//! plus a `manifest.json` describing the shape buckets. This module:
//!
//! * [`artifacts`] — parses the manifest (no serde; see `util::json`),
//! * [`client`] — wraps `xla::PjRtClient` (CPU): text → `HloModuleProto`
//!   → compile once → cached executable → execute,
//! * [`backend`] — the [`backend::CostBackend`] abstraction the ABA core
//!   calls: `Native` (pure Rust) or `Xla` (pad to bucket → PJRT → crop),
//!   selectable per run,
//! * [`pool`] — the session worker pool ([`Parallelism`] /
//!   [`WorkerPool`]) behind chunk-parallel cost matrices and the
//!   hierarchical subproblem fan-out,
//! * [`simd`] — the runtime-dispatched distance microkernels
//!   ([`Kernels`] / [`KernelMode`]) every squared-Euclidean hot path
//!   funnels through, and the crate's accumulation-precision policy.
//!
//! Python never runs here; the binary is self-contained once artifacts
//! are built.

pub mod artifacts;
pub mod backend;
#[cfg(feature = "xla")]
pub mod client;
pub mod pool;
pub mod simd;

pub use backend::{make_backend, BackendKind, CostBackend, NativeBackend};
pub use pool::{Parallelism, WorkerPool};
pub use simd::{KernelMode, Kernels};
#[cfg(feature = "xla")]
pub use backend::XlaBackend;
#[cfg(feature = "xla")]
pub use client::XlaRuntime;

use std::path::PathBuf;

/// Default artifact directory: `$ABA_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("ABA_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // Relative to the crate manifest when running via cargo, else cwd.
    let base = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    PathBuf::from(base).join("artifacts")
}
