//! Runtime-dispatched SIMD microkernels for the squared-Euclidean hot
//! paths, plus the crate's single accumulation-precision policy.
//!
//! # Two precision tiers, one home
//!
//! Every distance/moment computation in the crate lives here, in one of
//! two documented tiers:
//!
//! * **f32 cost tier** — the per-batch cost matrices and row norms the
//!   assignment solver consumes ([`Kernels::cost_block`],
//!   [`Kernels::cost_panel`], [`Kernels::row_norms`], [`Kernels::dot`]).
//!   Accumulated in f32 over 8 vertical lanes; this is the tier that
//!   vectorizes.
//! * **f64 objective tier** — everything that feeds objectives,
//!   orderings, or maintained moments ([`sq_dist`], [`sq_dist_to_f64`],
//!   [`accumulate`] / [`decumulate`], [`add_assign_row`], [`sumsq_f64`],
//!   [`centroid_sq_dist`]). These accumulate in f64 **in index order**
//!   and deliberately stay scalar in every kernel mode: f64 chains are
//!   order-sensitive, and the crate's bit-identity contracts (serial ≡
//!   threaded, view ≡ owned, delta ≡ recompute, save ≡ load) are defined
//!   against this exact order. The single, documented exception is
//!   [`KernelMode::FastMath`], whose relaxed contract (below) lets the
//!   *candidate-search* distances ([`Kernels::sq_dist`],
//!   [`Kernels::bbox_far`]) vectorize too — final objectives and
//!   certificates still always go through the scalar index-order tier.
//!
//! # Dispatch and the bit-identity contract
//!
//! [`Kernels`] is a table of function pointers selected **once** — at
//! session construction (builder `.kernels(..)`, CLI `--kernels`) or
//! lazily for the process default ([`Kernels::get`], which consults the
//! `ABA_KERNELS` environment variable a single time). The default mode
//! ([`KernelMode::Auto`]) picks the widest ISA whose kernels are
//! **bit-identical** to the scalar reference: the vector `dot` keeps the
//! same 8 vertical f32 accumulator lanes as the scalar kernel (separate
//! multiply and add, never a fused one) and combines them in the same
//! fixed reduction tree, so by IEEE-754 every lane performs the same
//! correctly-rounded operations in the same order and the result cannot
//! differ. The property suite asserts this across the flat,
//! hierarchical, sparse, and online solver paths.
//!
//! | mode | x86_64 | aarch64 | other | numeric contract |
//! |---|---|---|---|---|
//! | `auto` | AVX2 (mul + add) | NEON (mul + add) | scalar | bit-identical to `scalar` |
//! | `scalar` | 8-lane unrolled | 8-lane unrolled | same | the reference |
//! | `fma` | AVX2 + FMA (`vfmadd`) | falls back to auto | scalar | ULP-bounded, not bit-equal |
//! | `fast-math` | AVX-512F, else AVX2 + FMA | falls back to auto | scalar | relaxed: labels may differ, objective gap bench-gated in ppm |
//!
//! [`KernelMode::Fma`] is opt-in precisely because fused multiply-add
//! contracts the intermediate rounding: it is slightly *more* accurate
//! (and a touch faster) but not bit-equal to the scalar reference, so it
//! is gated by ULP-bound tests and the `kernel` bench section's
//! objective-gap records instead of the bit-identity suite. Requesting a
//! mode the host cannot honor falls back down the same table (the
//! selected ISA is always visible via [`Kernels::isa`], surfaced in
//! `Partition` timings, `BENCH_aba.json`, and serve's `/metrics`).
//!
//! # The fast-math tier and its relaxed-determinism contract
//!
//! [`KernelMode::FastMath`] is the large-K throughput tier. It swaps the
//! per-entry dot kernels for a **register-blocked panel micro-kernel**
//! (4 object rows × 1 centroid per micro-tile, fused multiply-add,
//! centroid panels sized to stay L2-resident so the `k×d` matrix streams
//! once per row *quad* instead of once per row), adds an **AVX-512F
//! arm** when both the toolchain (rustc ≥ 1.89, probed by `build.rs`)
//! and the host support it, and vectorizes the candidate-search f64
//! distances with free reduction order. The contract is deliberately
//! weaker than every other mode:
//!
//! * **labels may differ from `scalar`** — reduction order is free, so
//!   near-ties in the assignment step can resolve differently;
//! * **the objective gap is bench-gated in ppm** (`kernel_e2e` section
//!   of `BENCH_aba.json`) and property-tested to stay small — never
//!   bit-identity-gated;
//! * **pruning stays exact**: [`Kernels::bbox_far`] and
//!   [`Kernels::sq_dist`] share one lane/chunk structure, and IEEE-754
//!   correctly-rounded ops are monotone, so `bound ≥ distance` holds
//!   exactly even under fast-math (see `knn::farthest`);
//! * snapshot fingerprints are unaffected — the kernels knob is
//!   excluded from [`crate::AbaConfig`]'s fingerprint in every mode.

use crate::error::AbaError;
use std::sync::OnceLock;

/// Kernel-selection knob: builder `.kernels(..)`, CLI `--kernels`, env
/// `ABA_KERNELS`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// Widest available bit-identical vector path (the default).
    Auto,
    /// Force the scalar reference kernels on any host.
    Scalar,
    /// FMA-contracted fast path — ULP-close to, but not bit-equal with,
    /// the scalar reference. Falls back to `Auto` where unavailable.
    Fma,
    /// Relaxed-determinism throughput tier: register-blocked FMA panel
    /// kernels, AVX-512F when toolchain + host allow, vectorized
    /// candidate-search distances. Labels may differ from `scalar`; the
    /// objective gap is bench-gated in ppm (see the module docs). Falls
    /// back through `fma` → `auto` → `scalar` where unavailable.
    FastMath,
}

impl KernelMode {
    /// Every mode, in display order — the single source of the accepted
    /// CLI/env values.
    pub const ALL: [KernelMode; 4] = [
        KernelMode::Auto,
        KernelMode::Scalar,
        KernelMode::Fma,
        KernelMode::FastMath,
    ];

    /// The canonical (CLI/env) spelling.
    pub const fn as_str(self) -> &'static str {
        match self {
            KernelMode::Auto => "auto",
            KernelMode::Scalar => "scalar",
            KernelMode::Fma => "fma",
            KernelMode::FastMath => "fast-math",
        }
    }

    /// Accepted spellings joined with `|`, for help and error messages.
    pub fn accepted() -> String {
        Self::ALL
            .iter()
            .map(|m| m.as_str())
            .collect::<Vec<_>>()
            .join("|")
    }
}

impl std::fmt::Display for KernelMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for KernelMode {
    type Err = AbaError;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::ALL
            .iter()
            .copied()
            .find(|m| m.as_str() == s)
            .ok_or_else(|| {
                AbaError::InvalidInput(format!(
                    "unknown kernel mode '{s}' (accepted: {})",
                    KernelMode::accepted()
                ))
            })
    }
}

/// The kernel mode requested by the `ABA_KERNELS` environment variable
/// (unset or unparsable → [`KernelMode::Auto`]). Consulted once by
/// [`Kernels::get`] and once per session build when the builder leaves
/// the knob unset — never on the hot path.
pub fn kernel_mode_env_default() -> KernelMode {
    match std::env::var("ABA_KERNELS") {
        // An exported-but-empty variable (common in CI matrices) means
        // "no override", not a parse error worth warning about.
        Ok(v) if v.trim().is_empty() => KernelMode::Auto,
        Ok(v) => v.parse().unwrap_or_else(|_| {
            log::warn!(
                "ignoring invalid ABA_KERNELS='{v}' (accepted: {})",
                KernelMode::accepted()
            );
            KernelMode::Auto
        }),
        Err(_) => KernelMode::Auto,
    }
}

type DotFn = fn(&[f32], &[f32]) -> f32;
type RowNormsFn = fn(&[f32], usize, &mut Vec<f32>);
type CostBlockFn =
    fn(&[f32], &[f32], usize, usize, usize, &[f32], &[f32], usize, &mut [f32]);
type SqDistFn = fn(&[f32], &[f32]) -> f64;
type BboxFarFn = fn(&[f32], &[f32], &[f32]) -> f64;

/// A dispatch table of f32-tier kernels, selected once per session (or
/// once per process for [`Kernels::get`]). Copy — holding one is free.
#[derive(Clone, Copy, Debug)]
pub struct Kernels {
    isa: &'static str,
    mode: KernelMode,
    dot: DotFn,
    row_norms: RowNormsFn,
    cost_block: CostBlockFn,
    cost_panel: CostBlockFn,
    sq_dist: SqDistFn,
    bbox_far: BboxFarFn,
}

static PROCESS_DEFAULT: OnceLock<Kernels> = OnceLock::new();

impl Kernels {
    /// The scalar reference table — the numeric anchor every vector path
    /// is bit-identical to.
    pub fn scalar() -> Self {
        Kernels {
            isa: "scalar",
            mode: KernelMode::Scalar,
            dot: dot_scalar,
            row_norms: row_norms_scalar,
            cost_block: cost_block_scalar,
            cost_panel: cost_panel_scalar,
            sq_dist,
            bbox_far: bbox_far_scalar,
        }
    }

    /// Select a table for `mode`, probing CPU features at most once per
    /// call. Unavailable requests degrade (`fast-math` → `fma` → `auto`
    /// → `scalar`) rather than fail; [`Kernels::isa`] reports what was
    /// picked.
    pub fn select(mode: KernelMode) -> Self {
        match mode {
            KernelMode::Scalar => Self::scalar(),
            KernelMode::Auto => vector_table()
                .map(|t| Kernels { mode: KernelMode::Auto, ..t })
                .unwrap_or_else(|| Kernels { mode: KernelMode::Auto, ..Self::scalar() }),
            KernelMode::Fma => fma_table()
                .or_else(vector_table)
                .map(|t| Kernels { mode: KernelMode::Fma, ..t })
                .unwrap_or_else(|| Kernels { mode: KernelMode::Fma, ..Self::scalar() }),
            KernelMode::FastMath => fast_table()
                .or_else(fma_table)
                .or_else(vector_table)
                .map(|t| Kernels { mode: KernelMode::FastMath, ..t })
                .unwrap_or_else(|| Kernels { mode: KernelMode::FastMath, ..Self::scalar() }),
        }
    }

    /// The process-default table: [`kernel_mode_env_default`] resolved
    /// through [`Kernels::select`], memoized on first use. Free-function
    /// consumers (`cost_matrix_native`, serve metrics) read this;
    /// sessions override it per builder.
    pub fn get() -> Kernels {
        *PROCESS_DEFAULT.get_or_init(|| Kernels::select(kernel_mode_env_default()))
    }

    /// The instruction set actually selected: `"scalar"`, `"avx2"`,
    /// `"avx2+fma"`, `"avx512f"`, or `"neon"`.
    pub fn isa(&self) -> &'static str {
        self.isa
    }

    /// The mode this table was requested under (the effective ISA may be
    /// narrower — see [`Kernels::select`]).
    pub fn mode(&self) -> KernelMode {
        self.mode
    }

    /// f32 dot product — 8 vertical accumulator lanes, fixed reduction
    /// order (see the module docs for the bit-identity contract).
    #[inline]
    pub fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        (self.dot)(a, b)
    }

    /// Squared L2 norm of every `d`-row of `x` into `out` (cleared),
    /// via the same dot kernel the cost tier uses — so precomputed and
    /// inline norms are bit-identical.
    pub fn row_norms(&self, x: &[f32], rows: usize, d: usize, out: &mut Vec<f32>) {
        debug_assert_eq!(x.len(), rows * d);
        (self.row_norms)(x, d, out)
    }

    /// Write rows `r0..r1` of the `m x k` cost matrix into `out`
    /// (`(r1 - r0) * k` entries): `||x_i||² + ||c_j||² − 2⟨x_i, c_j⟩`
    /// clamped at 0, with precomputed row norms `xn` (indexed by global
    /// row) and centroid norms `cn`. Tiled over centroid blocks so the
    /// active slice of `c` stays L1-resident while `x` streams; each
    /// entry depends only on its own row/column, so any row split or
    /// tile shape yields bit-identical results.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn cost_block(
        &self,
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        (self.cost_block)(x, xn, r0, r1, d, c, cn, k, out)
    }

    /// Cache-blocked variant of [`Kernels::cost_block`], same signature
    /// and semantics: the centroid matrix is walked in L2-sized *panels*
    /// (outer loop) so for large `k` the `k×d` panel streams from cache
    /// once per row block instead of once per row. In the deterministic
    /// tiers every entry is produced by the same per-entry dot as
    /// `cost_block`, so the two are bit-identical; the fast-math tier
    /// swaps in the register-blocked FMA micro-kernel (4 rows × 1
    /// centroid, free reduction order). This is what
    /// `CostBackend::batch_costs` routes through.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn cost_panel(
        &self,
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        (self.cost_panel)(x, xn, r0, r1, d, c, cn, k, out)
    }

    /// Candidate-search squared distance (f64). Every deterministic mode
    /// dispatches to the scalar index-order [`sq_dist`]; fast-math
    /// vectorizes the accumulation (relaxed contract — see module docs).
    #[inline]
    pub fn sq_dist(&self, a: &[f32], b: &[f32]) -> f64 {
        (self.sq_dist)(a, b)
    }

    /// Farthest-corner squared-distance bound of a query against an
    /// axis-aligned box `[lo, hi]`: `Σ_t max(|q_t − lo_t|, |q_t − hi_t|)²`.
    /// Paired with [`Kernels::sq_dist`] lane-for-lane in every table so
    /// that `bbox_far(q, lo, hi) ≥ sq_dist(q, p)` holds *exactly* for any
    /// `p` inside the box — the pruning invariant `knn::farthest` relies
    /// on (IEEE-754 rounding is monotone, and per coordinate the bound's
    /// addend dominates the distance's addend).
    #[inline]
    pub fn bbox_far(&self, q: &[f32], lo: &[f32], hi: &[f32]) -> f64 {
        (self.bbox_far)(q, lo, hi)
    }
}

impl Default for Kernels {
    fn default() -> Self {
        Kernels::get()
    }
}

// ---------------------------------------------------------------------------
// Shared kernel bodies
// ---------------------------------------------------------------------------

/// Centroid-tile width for [`Kernels::cost_block`]: 64 centroids x 64
/// features x 4 bytes = 16 KiB, comfortably L1-resident alongside the x
/// row.
const TILE_COLS: usize = 64;

/// f32 budget for one centroid panel of [`Kernels::cost_panel`]:
/// 32 Ki floats = 128 KiB — half a typical L2, leaving headroom for the
/// streaming object rows and the output slice.
const PANEL_F32: usize = 32 * 1024;

/// Centroid-panel width in columns for feature count `d`, never below
/// one L1 tile.
#[inline]
fn panel_cols(d: usize) -> usize {
    (PANEL_F32 / d.max(1)).max(TILE_COLS)
}

/// How many object rows one fast-math micro-tile covers: four rows share
/// every centroid-chunk load, quadrupling the FMA work per byte streamed
/// from the panel.
const PANEL_ROWS: usize = 4;

/// The fixed 8-lane reduction tree every dot kernel (scalar and vector)
/// funnels through — the order half of the bit-identity contract.
#[inline(always)]
fn reduce8(acc: &[f32; 8]) -> f32 {
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// 8-lane unrolled scalar dot product — the reference kernel. The
/// multiple independent accumulators break the f32 dependency chain so
/// LLVM auto-vectorizes even without the explicit paths below (a plain
/// `zip().map().sum()` cannot be reordered and stays scalar) — measured
/// ~3x on the cost-matrix hot path (EXPERIMENTS.md §Perf).
#[inline]
fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 8];
    let chunks = a.len() / 8;
    for t in 0..chunks {
        let (abase, bbase) = (&a[t * 8..t * 8 + 8], &b[t * 8..t * 8 + 8]);
        for l in 0..8 {
            acc[l] += abase[l] * bbase[l];
        }
    }
    let mut dot = reduce8(&acc);
    for t in chunks * 8..a.len() {
        dot += a[t] * b[t];
    }
    dot
}

/// Generic row-norms body, monomorphized per ISA so `dot` inlines.
#[inline(always)]
fn row_norms_impl<F: Fn(&[f32], &[f32]) -> f32>(dot: F, x: &[f32], d: usize, out: &mut Vec<f32>) {
    out.clear();
    out.extend(x.chunks_exact(d).map(|r| dot(r, r)));
}

/// Generic cost-block body, monomorphized per ISA so `dot` inlines into
/// the tiled loop (see [`Kernels::cost_block`] for the semantics).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn cost_block_impl<F: Fn(&[f32], &[f32]) -> f32>(
    dot: F,
    x: &[f32],
    xn: &[f32],
    r0: usize,
    r1: usize,
    d: usize,
    c: &[f32],
    cn: &[f32],
    k: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (r1 - r0) * k);
    let mut jt = 0;
    while jt < k {
        let jhi = (jt + TILE_COLS).min(k);
        for i in r0..r1 {
            let xi = &x[i * d..(i + 1) * d];
            let row = &mut out[(i - r0) * k..(i - r0) * k + k];
            for (j, cj) in c[jt * d..jhi * d].chunks_exact(d).enumerate() {
                let j = jt + j;
                row[j] = (xn[i] + cn[j] - 2.0 * dot(xi, cj)).max(0.0);
            }
        }
        jt = jhi;
    }
}

/// Generic panel-blocked cost body for the deterministic tiers: an outer
/// L2-sized centroid-panel loop wrapped around the same per-entry
/// arithmetic as [`cost_block_impl`]. Each entry depends only on its own
/// row and column and is produced by the same `dot`, so any panel/tile
/// shape is bit-identical to `cost_block` — only the streaming order
/// (and therefore cache traffic at large `k`) changes.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn cost_panel_impl<F: Fn(&[f32], &[f32]) -> f32>(
    dot: F,
    x: &[f32],
    xn: &[f32],
    r0: usize,
    r1: usize,
    d: usize,
    c: &[f32],
    cn: &[f32],
    k: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (r1 - r0) * k);
    let pc = panel_cols(d);
    let mut jp = 0;
    while jp < k {
        let jp_hi = (jp + pc).min(k);
        for i in r0..r1 {
            let xi = &x[i * d..(i + 1) * d];
            let row = &mut out[(i - r0) * k..(i - r0) * k + k];
            let mut jt = jp;
            while jt < jp_hi {
                let jhi = (jt + TILE_COLS).min(jp_hi);
                for (j, cj) in c[jt * d..jhi * d].chunks_exact(d).enumerate() {
                    let j = jt + j;
                    row[j] = (xn[i] + cn[j] - 2.0 * dot(xi, cj)).max(0.0);
                }
                jt = jhi;
            }
        }
        jp = jp_hi;
    }
}

fn row_norms_scalar(x: &[f32], d: usize, out: &mut Vec<f32>) {
    row_norms_impl(dot_scalar, x, d, out);
}

#[allow(clippy::too_many_arguments)]
fn cost_block_scalar(
    x: &[f32],
    xn: &[f32],
    r0: usize,
    r1: usize,
    d: usize,
    c: &[f32],
    cn: &[f32],
    k: usize,
    out: &mut [f32],
) {
    cost_block_impl(dot_scalar, x, xn, r0, r1, d, c, cn, k, out);
}

#[allow(clippy::too_many_arguments)]
fn cost_panel_scalar(
    x: &[f32],
    xn: &[f32],
    r0: usize,
    r1: usize,
    d: usize,
    c: &[f32],
    cn: &[f32],
    k: usize,
    out: &mut [f32],
) {
    cost_panel_impl(dot_scalar, x, xn, r0, r1, d, c, cn, k, out);
}

/// Scalar farthest-corner bound, the reference for
/// [`Kernels::bbox_far`]: f32 subtract / abs / max per coordinate (the
/// monotone mirror of [`sq_dist`]'s f32 subtract), widened to f64,
/// squared, accumulated in index order. For any `p` with
/// `lo ≤ p ≤ hi` per coordinate, `|q−p| ≤ max(|q−lo|, |q−hi|)` survives
/// correctly-rounded f32 arithmetic, so `bbox_far ≥ sq_dist` holds
/// exactly.
fn bbox_far_scalar(q: &[f32], lo: &[f32], hi: &[f32]) -> f64 {
    debug_assert_eq!(q.len(), lo.len());
    debug_assert_eq!(q.len(), hi.len());
    let mut s = 0f64;
    for t in 0..q.len() {
        let far = (q[t] - lo[t]).abs().max((q[t] - hi[t]).abs()) as f64;
        s += far * far;
    }
    s
}

// ---------------------------------------------------------------------------
// x86_64: AVX2 (bit-identical) and AVX2+FMA (contracted) paths
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{cost_block_impl, cost_panel_impl, panel_cols, reduce8, row_norms_impl, PANEL_ROWS};
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_andnot_pd, _mm256_cvtps_pd, _mm256_fmadd_pd, _mm256_fmadd_ps,
        _mm256_loadu_ps, _mm256_max_pd, _mm256_mul_ps, _mm256_set1_pd, _mm256_setzero_pd,
        _mm256_setzero_ps, _mm256_storeu_pd, _mm256_storeu_ps, _mm256_sub_pd, _mm_loadu_ps,
        __m256,
    };

    /// AVX2 dot body: per 8-wide chunk each lane performs exactly the
    /// multiply-then-add of the scalar kernel's matching accumulator, and
    /// the vector register is spilled to an array and reduced through the
    /// same [`reduce8`] tree — bit-identical by IEEE-754.
    ///
    /// `#[inline(always)]` with no `#[target_feature]` of its own: the
    /// callers below carry the feature, so after monomorphization the
    /// intrinsics inline into AVX2-enabled code.
    ///
    /// # Safety
    /// Callers must only reach this after `avx2` was detected.
    #[inline(always)]
    unsafe fn dot_avx2_body(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 8;
        let mut acc = _mm256_setzero_ps();
        for (ca, cb) in a.chunks_exact(8).zip(b.chunks_exact(8)) {
            let va = _mm256_loadu_ps(ca.as_ptr());
            let vb = _mm256_loadu_ps(cb.as_ptr());
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut dot = reduce8(&lanes);
        for t in chunks * 8..a.len() {
            dot += a[t] * b[t];
        }
        dot
    }

    /// FMA dot body: same lane layout, but multiply-add is fused
    /// (`vfmadd`), including the scalar tail — ULP-close to the scalar
    /// reference, not bit-equal.
    ///
    /// # Safety
    /// Callers must only reach this after `avx2` and `fma` were detected.
    #[inline(always)]
    unsafe fn dot_fma_body(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 8;
        let mut acc = _mm256_setzero_ps();
        for (ca, cb) in a.chunks_exact(8).zip(b.chunks_exact(8)) {
            let va = _mm256_loadu_ps(ca.as_ptr());
            let vb = _mm256_loadu_ps(cb.as_ptr());
            acc = _mm256_fmadd_ps(va, vb, acc);
        }
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut dot = reduce8(&lanes);
        for t in chunks * 8..a.len() {
            dot = a[t].mul_add(b[t], dot);
        }
        dot
    }

    // Safe `fn`-pointer wrappers. `#[target_feature]` functions must be
    // `unsafe fn` on this toolchain and cannot coerce to plain `fn`
    // pointers, so each wrapper pairs a feature-enabled unsafe inner
    // with a safe outer; the table constructors below only hand these
    // out after `is_x86_feature_detected!` succeeded, which is what
    // makes the inner calls sound.

    #[target_feature(enable = "avx2")]
    unsafe fn dot_avx2_inner(a: &[f32], b: &[f32]) -> f32 {
        dot_avx2_body(a, b)
    }

    pub fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: gated on runtime avx2 detection in `vector_table`.
        unsafe { dot_avx2_inner(a, b) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn row_norms_avx2_inner(x: &[f32], d: usize, out: &mut Vec<f32>) {
        // SAFETY: closure bodies do not inherit the enclosing unsafety;
        // the feature gate that makes this sound is the caller's.
        row_norms_impl(|a, b| unsafe { dot_avx2_body(a, b) }, x, d, out);
    }

    pub fn row_norms_avx2(x: &[f32], d: usize, out: &mut Vec<f32>) {
        // SAFETY: gated on runtime avx2 detection in `vector_table`.
        unsafe { row_norms_avx2_inner(x, d, out) }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    unsafe fn cost_block_avx2_inner(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: as in `row_norms_avx2_inner`.
        cost_block_impl(|a, b| unsafe { dot_avx2_body(a, b) }, x, xn, r0, r1, d, c, cn, k, out);
    }

    #[allow(clippy::too_many_arguments)]
    pub fn cost_block_avx2(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: gated on runtime avx2 detection in `vector_table`.
        unsafe { cost_block_avx2_inner(x, xn, r0, r1, d, c, cn, k, out) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot_fma_inner(a: &[f32], b: &[f32]) -> f32 {
        dot_fma_body(a, b)
    }

    pub fn dot_fma(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: gated on runtime avx2+fma detection in `fma_table`.
        unsafe { dot_fma_inner(a, b) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn row_norms_fma_inner(x: &[f32], d: usize, out: &mut Vec<f32>) {
        // SAFETY: as in `row_norms_avx2_inner`.
        row_norms_impl(|a, b| unsafe { dot_fma_body(a, b) }, x, d, out);
    }

    pub fn row_norms_fma(x: &[f32], d: usize, out: &mut Vec<f32>) {
        // SAFETY: gated on runtime avx2+fma detection in `fma_table`.
        unsafe { row_norms_fma_inner(x, d, out) }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn cost_block_fma_inner(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: as in `row_norms_avx2_inner`.
        cost_block_impl(|a, b| unsafe { dot_fma_body(a, b) }, x, xn, r0, r1, d, c, cn, k, out);
    }

    #[allow(clippy::too_many_arguments)]
    pub fn cost_block_fma(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: gated on runtime avx2+fma detection in `fma_table`.
        unsafe { cost_block_fma_inner(x, xn, r0, r1, d, c, cn, k, out) }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    unsafe fn cost_panel_avx2_inner(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: as in `row_norms_avx2_inner`.
        cost_panel_impl(|a, b| unsafe { dot_avx2_body(a, b) }, x, xn, r0, r1, d, c, cn, k, out);
    }

    #[allow(clippy::too_many_arguments)]
    pub fn cost_panel_avx2(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: gated on runtime avx2 detection in `vector_table`.
        unsafe { cost_panel_avx2_inner(x, xn, r0, r1, d, c, cn, k, out) }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn cost_panel_fma_inner(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: as in `row_norms_avx2_inner`.
        cost_panel_impl(|a, b| unsafe { dot_fma_body(a, b) }, x, xn, r0, r1, d, c, cn, k, out);
    }

    #[allow(clippy::too_many_arguments)]
    pub fn cost_panel_fma(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: gated on runtime avx2+fma detection in `fma_table`.
        unsafe { cost_panel_fma_inner(x, xn, r0, r1, d, c, cn, k, out) }
    }

    // -----------------------------------------------------------------
    // Fast-math tier (AVX2+FMA arm): register-blocked panel micro-kernel
    // and vectorized candidate-search f64 distances. Reduction order is
    // free here — these are only ever reachable from
    // `KernelMode::FastMath` tables.
    // -----------------------------------------------------------------

    /// Free-order horizontal sum of one 8-lane f32 register.
    ///
    /// # Safety
    /// Callers must only reach this after `avx2` was detected.
    #[inline(always)]
    unsafe fn hsum256(v: __m256) -> f32 {
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), v);
        lanes.iter().sum()
    }

    /// Register-blocked fast-math panel kernel: [`PANEL_ROWS`] object
    /// rows × 1 centroid per micro-tile, so each centroid chunk is
    /// loaded once and feeds four independent `vfmadd` chains; centroid
    /// panels are L2-sized via [`panel_cols`] so for large `k` the
    /// `k×d` matrix streams from cache once per row quad.
    ///
    /// # Safety
    /// Callers must only reach this after `avx2` and `fma` were
    /// detected.
    #[allow(clippy::too_many_arguments)]
    #[inline(always)]
    unsafe fn cost_panel_fast_body(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), (r1 - r0) * k);
        let pc = panel_cols(d);
        let chunks = d / 8;
        let mut jp = 0;
        while jp < k {
            let jp_hi = (jp + pc).min(k);
            let mut i = r0;
            while i + PANEL_ROWS <= r1 {
                let rows = [
                    &x[i * d..(i + 1) * d],
                    &x[(i + 1) * d..(i + 2) * d],
                    &x[(i + 2) * d..(i + 3) * d],
                    &x[(i + 3) * d..(i + 4) * d],
                ];
                for j in jp..jp_hi {
                    let cj = &c[j * d..(j + 1) * d];
                    let mut acc = [_mm256_setzero_ps(); PANEL_ROWS];
                    for t in 0..chunks {
                        let vc = _mm256_loadu_ps(cj.as_ptr().add(t * 8));
                        for (a, row) in acc.iter_mut().zip(&rows) {
                            *a = _mm256_fmadd_ps(_mm256_loadu_ps(row.as_ptr().add(t * 8)), vc, *a);
                        }
                    }
                    let mut dots = [0f32; PANEL_ROWS];
                    for (s, a) in dots.iter_mut().zip(&acc) {
                        *s = hsum256(*a);
                    }
                    for t in chunks * 8..d {
                        let cv = cj[t];
                        for (s, row) in dots.iter_mut().zip(&rows) {
                            *s = row[t].mul_add(cv, *s);
                        }
                    }
                    for (r, &dot) in dots.iter().enumerate() {
                        out[(i - r0 + r) * k + j] = (xn[i + r] + cn[j] - 2.0 * dot).max(0.0);
                    }
                }
                i += PANEL_ROWS;
            }
            // Ragged row tail: per-row fused dot, same panel residency.
            while i < r1 {
                let xi = &x[i * d..(i + 1) * d];
                let row = &mut out[(i - r0) * k..(i - r0) * k + k];
                for j in jp..jp_hi {
                    let dot = dot_fma_body(xi, &c[j * d..(j + 1) * d]);
                    row[j] = (xn[i] + cn[j] - 2.0 * dot).max(0.0);
                }
                i += 1;
            }
            jp = jp_hi;
        }
    }

    /// Vectorized candidate-search squared distance: four f64 lanes
    /// (f32 chunk converted up, subtracted, `vfmadd`-squared), free-order
    /// reduction, fused scalar tail.
    ///
    /// # Safety
    /// Callers must only reach this after `avx2` and `fma` were
    /// detected.
    #[inline(always)]
    unsafe fn sq_dist_fast_body(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 4;
        let mut acc = _mm256_setzero_pd();
        for t in 0..chunks {
            let va = _mm256_cvtps_pd(_mm_loadu_ps(a.as_ptr().add(t * 4)));
            let vb = _mm256_cvtps_pd(_mm_loadu_ps(b.as_ptr().add(t * 4)));
            let diff = _mm256_sub_pd(va, vb);
            acc = _mm256_fmadd_pd(diff, diff, acc);
        }
        let mut lanes = [0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut s = lanes[0] + lanes[1] + lanes[2] + lanes[3];
        for t in chunks * 4..a.len() {
            let diff = a[t] as f64 - b[t] as f64;
            s = diff.mul_add(diff, s);
        }
        s
    }

    /// Vectorized farthest-corner bound with *exactly* the lane/chunk
    /// structure of [`sq_dist_fast_body`]: per coordinate both sides
    /// compute an f64 subtraction of converted f32s, and since
    /// `lo ≤ p ≤ hi` puts the real `q−p` between `q−hi` and `q−lo`,
    /// monotonicity of correctly-rounded IEEE-754 ops gives
    /// `|fl(q−p)| ≤ max(|fl(q−lo)|, |fl(q−hi)|)` per lane, which FMA
    /// accumulation and the shared reduction preserve — so
    /// `bound ≥ distance` holds exactly even in the fast-math tier.
    ///
    /// # Safety
    /// Callers must only reach this after `avx2` and `fma` were
    /// detected.
    #[inline(always)]
    unsafe fn bbox_far_fast_body(q: &[f32], lo: &[f32], hi: &[f32]) -> f64 {
        debug_assert_eq!(q.len(), lo.len());
        debug_assert_eq!(q.len(), hi.len());
        let sign = _mm256_set1_pd(-0.0);
        let chunks = q.len() / 4;
        let mut acc = _mm256_setzero_pd();
        for t in 0..chunks {
            let vq = _mm256_cvtps_pd(_mm_loadu_ps(q.as_ptr().add(t * 4)));
            let vl = _mm256_cvtps_pd(_mm_loadu_ps(lo.as_ptr().add(t * 4)));
            let vh = _mm256_cvtps_pd(_mm_loadu_ps(hi.as_ptr().add(t * 4)));
            let dl = _mm256_andnot_pd(sign, _mm256_sub_pd(vq, vl));
            let dh = _mm256_andnot_pd(sign, _mm256_sub_pd(vq, vh));
            let far = _mm256_max_pd(dl, dh);
            acc = _mm256_fmadd_pd(far, far, acc);
        }
        let mut lanes = [0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut s = lanes[0] + lanes[1] + lanes[2] + lanes[3];
        for t in chunks * 4..q.len() {
            let far = (q[t] as f64 - lo[t] as f64).abs().max((q[t] as f64 - hi[t] as f64).abs());
            s = far.mul_add(far, s);
        }
        s
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn cost_panel_fast_inner(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        cost_panel_fast_body(x, xn, r0, r1, d, c, cn, k, out)
    }

    #[allow(clippy::too_many_arguments)]
    pub fn cost_panel_fast(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: gated on runtime avx2+fma detection in `fast_table`.
        unsafe { cost_panel_fast_inner(x, xn, r0, r1, d, c, cn, k, out) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn sq_dist_fast_inner(a: &[f32], b: &[f32]) -> f64 {
        sq_dist_fast_body(a, b)
    }

    pub fn sq_dist_fast(a: &[f32], b: &[f32]) -> f64 {
        // SAFETY: gated on runtime avx2+fma detection in `fast_table`.
        unsafe { sq_dist_fast_inner(a, b) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn bbox_far_fast_inner(q: &[f32], lo: &[f32], hi: &[f32]) -> f64 {
        bbox_far_fast_body(q, lo, hi)
    }

    pub fn bbox_far_fast(q: &[f32], lo: &[f32], hi: &[f32]) -> f64 {
        // SAFETY: gated on runtime avx2+fma detection in `fast_table`.
        unsafe { bbox_far_fast_inner(q, lo, hi) }
    }
}

// ---------------------------------------------------------------------------
// x86_64: AVX-512F fast-math arm. Compiled only when build.rs found a
// toolchain with stable AVX-512 intrinsics (rustc >= 1.89); selected only
// when the host reports `avx512f` at runtime; reachable only from
// `KernelMode::FastMath` — 16-lane reductions cannot be bit-identical to
// the 8-lane scalar reference, so this arm never backs `auto` or `fma`.
// ---------------------------------------------------------------------------

#[cfg(all(target_arch = "x86_64", aba_avx512))]
mod x86_avx512 {
    use super::{panel_cols, row_norms_impl, PANEL_ROWS};
    use std::arch::x86_64::{
        _mm256_loadu_ps, _mm512_abs_pd, _mm512_cvtps_pd, _mm512_fmadd_pd, _mm512_fmadd_ps,
        _mm512_loadu_ps, _mm512_max_pd, _mm512_setzero_pd, _mm512_setzero_ps, _mm512_storeu_pd,
        _mm512_storeu_ps, _mm512_sub_pd, __m512,
    };

    /// Free-order horizontal sum of one 16-lane f32 register.
    ///
    /// # Safety
    /// Callers must only reach this after `avx512f` was detected.
    #[inline(always)]
    unsafe fn hsum512(v: __m512) -> f32 {
        let mut lanes = [0f32; 16];
        _mm512_storeu_ps(lanes.as_mut_ptr(), v);
        lanes.iter().sum()
    }

    /// 16-lane fused dot with free reduction order (fast-math only).
    ///
    /// # Safety
    /// Callers must only reach this after `avx512f` was detected.
    #[inline(always)]
    unsafe fn dot_avx512_body(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 16;
        let mut acc = _mm512_setzero_ps();
        for t in 0..chunks {
            acc = _mm512_fmadd_ps(
                _mm512_loadu_ps(a.as_ptr().add(t * 16)),
                _mm512_loadu_ps(b.as_ptr().add(t * 16)),
                acc,
            );
        }
        let mut dot = hsum512(acc);
        for t in chunks * 16..a.len() {
            dot = a[t].mul_add(b[t], dot);
        }
        dot
    }

    /// The 512-bit sibling of `x86::cost_panel_fast_body`: same
    /// [`PANEL_ROWS`]-row micro-tile and [`panel_cols`] L2 panels, twice
    /// the lane width per centroid-chunk load.
    ///
    /// # Safety
    /// Callers must only reach this after `avx512f` was detected.
    #[allow(clippy::too_many_arguments)]
    #[inline(always)]
    unsafe fn cost_panel_avx512_body(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), (r1 - r0) * k);
        let pc = panel_cols(d);
        let chunks = d / 16;
        let mut jp = 0;
        while jp < k {
            let jp_hi = (jp + pc).min(k);
            let mut i = r0;
            while i + PANEL_ROWS <= r1 {
                let rows = [
                    &x[i * d..(i + 1) * d],
                    &x[(i + 1) * d..(i + 2) * d],
                    &x[(i + 2) * d..(i + 3) * d],
                    &x[(i + 3) * d..(i + 4) * d],
                ];
                for j in jp..jp_hi {
                    let cj = &c[j * d..(j + 1) * d];
                    let mut acc = [_mm512_setzero_ps(); PANEL_ROWS];
                    for t in 0..chunks {
                        let vc = _mm512_loadu_ps(cj.as_ptr().add(t * 16));
                        for (a, row) in acc.iter_mut().zip(&rows) {
                            *a = _mm512_fmadd_ps(
                                _mm512_loadu_ps(row.as_ptr().add(t * 16)),
                                vc,
                                *a,
                            );
                        }
                    }
                    let mut dots = [0f32; PANEL_ROWS];
                    for (s, a) in dots.iter_mut().zip(&acc) {
                        *s = hsum512(*a);
                    }
                    for t in chunks * 16..d {
                        let cv = cj[t];
                        for (s, row) in dots.iter_mut().zip(&rows) {
                            *s = row[t].mul_add(cv, *s);
                        }
                    }
                    for (r, &dot) in dots.iter().enumerate() {
                        out[(i - r0 + r) * k + j] = (xn[i + r] + cn[j] - 2.0 * dot).max(0.0);
                    }
                }
                i += PANEL_ROWS;
            }
            while i < r1 {
                let xi = &x[i * d..(i + 1) * d];
                let row = &mut out[(i - r0) * k..(i - r0) * k + k];
                for j in jp..jp_hi {
                    let dot = dot_avx512_body(xi, &c[j * d..(j + 1) * d]);
                    row[j] = (xn[i] + cn[j] - 2.0 * dot).max(0.0);
                }
                i += 1;
            }
            jp = jp_hi;
        }
    }

    /// Eight f64 lanes per chunk (f32 half-register converted up), fused
    /// square-accumulate, free-order reduction.
    ///
    /// # Safety
    /// Callers must only reach this after `avx512f` was detected.
    #[inline(always)]
    unsafe fn sq_dist_avx512_body(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 8;
        let mut acc = _mm512_setzero_pd();
        for t in 0..chunks {
            let va = _mm512_cvtps_pd(_mm256_loadu_ps(a.as_ptr().add(t * 8)));
            let vb = _mm512_cvtps_pd(_mm256_loadu_ps(b.as_ptr().add(t * 8)));
            let diff = _mm512_sub_pd(va, vb);
            acc = _mm512_fmadd_pd(diff, diff, acc);
        }
        let mut lanes = [0f64; 8];
        _mm512_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut s = lanes.iter().sum();
        for t in chunks * 8..a.len() {
            let diff = a[t] as f64 - b[t] as f64;
            s = diff.mul_add(diff, s);
        }
        s
    }

    /// Farthest-corner bound with the exact lane/chunk structure of
    /// [`sq_dist_avx512_body`] — same monotonicity argument as the AVX2
    /// fast pair, so `bound ≥ distance` holds exactly.
    ///
    /// # Safety
    /// Callers must only reach this after `avx512f` was detected.
    #[inline(always)]
    unsafe fn bbox_far_avx512_body(q: &[f32], lo: &[f32], hi: &[f32]) -> f64 {
        debug_assert_eq!(q.len(), lo.len());
        debug_assert_eq!(q.len(), hi.len());
        let chunks = q.len() / 8;
        let mut acc = _mm512_setzero_pd();
        for t in 0..chunks {
            let vq = _mm512_cvtps_pd(_mm256_loadu_ps(q.as_ptr().add(t * 8)));
            let vl = _mm512_cvtps_pd(_mm256_loadu_ps(lo.as_ptr().add(t * 8)));
            let vh = _mm512_cvtps_pd(_mm256_loadu_ps(hi.as_ptr().add(t * 8)));
            let dl = _mm512_abs_pd(_mm512_sub_pd(vq, vl));
            let dh = _mm512_abs_pd(_mm512_sub_pd(vq, vh));
            let far = _mm512_max_pd(dl, dh);
            acc = _mm512_fmadd_pd(far, far, acc);
        }
        let mut lanes = [0f64; 8];
        _mm512_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut s = lanes.iter().sum();
        for t in chunks * 8..q.len() {
            let far = (q[t] as f64 - lo[t] as f64).abs().max((q[t] as f64 - hi[t] as f64).abs());
            s = far.mul_add(far, s);
        }
        s
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn dot_avx512_inner(a: &[f32], b: &[f32]) -> f32 {
        dot_avx512_body(a, b)
    }

    pub fn dot_avx512(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: gated on runtime avx512f detection in `fast_table`.
        unsafe { dot_avx512_inner(a, b) }
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn row_norms_avx512_inner(x: &[f32], d: usize, out: &mut Vec<f32>) {
        // SAFETY: closure bodies do not inherit the enclosing unsafety;
        // the feature gate that makes this sound is the caller's.
        row_norms_impl(|a, b| unsafe { dot_avx512_body(a, b) }, x, d, out);
    }

    pub fn row_norms_avx512(x: &[f32], d: usize, out: &mut Vec<f32>) {
        // SAFETY: gated on runtime avx512f detection in `fast_table`.
        unsafe { row_norms_avx512_inner(x, d, out) }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx512f")]
    unsafe fn cost_panel_avx512_inner(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        cost_panel_avx512_body(x, xn, r0, r1, d, c, cn, k, out)
    }

    #[allow(clippy::too_many_arguments)]
    pub fn cost_panel_avx512(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: gated on runtime avx512f detection in `fast_table`.
        unsafe { cost_panel_avx512_inner(x, xn, r0, r1, d, c, cn, k, out) }
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn sq_dist_avx512_inner(a: &[f32], b: &[f32]) -> f64 {
        sq_dist_avx512_body(a, b)
    }

    pub fn sq_dist_avx512(a: &[f32], b: &[f32]) -> f64 {
        // SAFETY: gated on runtime avx512f detection in `fast_table`.
        unsafe { sq_dist_avx512_inner(a, b) }
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn bbox_far_avx512_inner(q: &[f32], lo: &[f32], hi: &[f32]) -> f64 {
        bbox_far_avx512_body(q, lo, hi)
    }

    pub fn bbox_far_avx512(q: &[f32], lo: &[f32], hi: &[f32]) -> f64 {
        // SAFETY: gated on runtime avx512f detection in `fast_table`.
        unsafe { bbox_far_avx512_inner(q, lo, hi) }
    }
}

#[cfg(target_arch = "x86_64")]
fn vector_table() -> Option<Kernels> {
    if std::arch::is_x86_feature_detected!("avx2") {
        Some(Kernels {
            isa: "avx2",
            mode: KernelMode::Auto,
            dot: x86::dot_avx2,
            row_norms: x86::row_norms_avx2,
            cost_block: x86::cost_block_avx2,
            cost_panel: x86::cost_panel_avx2,
            sq_dist,
            bbox_far: bbox_far_scalar,
        })
    } else {
        None
    }
}

#[cfg(target_arch = "x86_64")]
fn fma_table() -> Option<Kernels> {
    if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma") {
        Some(Kernels {
            isa: "avx2+fma",
            mode: KernelMode::Fma,
            dot: x86::dot_fma,
            row_norms: x86::row_norms_fma,
            cost_block: x86::cost_block_fma,
            cost_panel: x86::cost_panel_fma,
            sq_dist,
            bbox_far: bbox_far_scalar,
        })
    } else {
        None
    }
}

/// The relaxed-determinism table: AVX-512F when the toolchain compiled
/// the arm (`build.rs` cfg) and the host has it, else the AVX2+FMA
/// register-blocked micro-kernels. `None` sends `select` down the
/// deterministic fallback chain.
#[cfg(target_arch = "x86_64")]
fn fast_table() -> Option<Kernels> {
    #[cfg(aba_avx512)]
    if std::arch::is_x86_feature_detected!("avx512f") {
        return Some(Kernels {
            isa: "avx512f",
            mode: KernelMode::FastMath,
            dot: x86_avx512::dot_avx512,
            row_norms: x86_avx512::row_norms_avx512,
            cost_block: x86_avx512::cost_panel_avx512,
            cost_panel: x86_avx512::cost_panel_avx512,
            sq_dist: x86_avx512::sq_dist_avx512,
            bbox_far: x86_avx512::bbox_far_avx512,
        });
    }
    if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma") {
        Some(Kernels {
            isa: "avx2+fma",
            mode: KernelMode::FastMath,
            dot: x86::dot_fma,
            row_norms: x86::row_norms_fma,
            cost_block: x86::cost_panel_fast,
            cost_panel: x86::cost_panel_fast,
            sq_dist: x86::sq_dist_fast,
            bbox_far: x86::bbox_far_fast,
        })
    } else {
        None
    }
}

// ---------------------------------------------------------------------------
// aarch64: NEON (bit-identical) path
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{cost_block_impl, cost_panel_impl, reduce8, row_norms_impl};
    use std::arch::aarch64::{vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32};

    /// NEON dot body: two 4-wide registers cover the scalar kernel's 8
    /// accumulator lanes (lanes 0..3 and 4..7), multiply-then-add, same
    /// [`reduce8`] tree — bit-identical by IEEE-754.
    ///
    /// # Safety
    /// Callers must only reach this after `neon` was detected.
    #[inline(always)]
    unsafe fn dot_neon_body(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 8;
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        for (ca, cb) in a.chunks_exact(8).zip(b.chunks_exact(8)) {
            lo = vaddq_f32(lo, vmulq_f32(vld1q_f32(ca.as_ptr()), vld1q_f32(cb.as_ptr())));
            hi = vaddq_f32(
                hi,
                vmulq_f32(vld1q_f32(ca.as_ptr().add(4)), vld1q_f32(cb.as_ptr().add(4))),
            );
        }
        let mut lanes = [0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), hi);
        let mut dot = reduce8(&lanes);
        for t in chunks * 8..a.len() {
            dot += a[t] * b[t];
        }
        dot
    }

    #[target_feature(enable = "neon")]
    unsafe fn dot_neon_inner(a: &[f32], b: &[f32]) -> f32 {
        dot_neon_body(a, b)
    }

    pub fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: gated on runtime neon detection in `vector_table`.
        unsafe { dot_neon_inner(a, b) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn row_norms_neon_inner(x: &[f32], d: usize, out: &mut Vec<f32>) {
        // SAFETY: closure bodies do not inherit the enclosing unsafety;
        // the feature gate that makes this sound is the caller's.
        row_norms_impl(|a, b| unsafe { dot_neon_body(a, b) }, x, d, out);
    }

    pub fn row_norms_neon(x: &[f32], d: usize, out: &mut Vec<f32>) {
        // SAFETY: gated on runtime neon detection in `vector_table`.
        unsafe { row_norms_neon_inner(x, d, out) }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    unsafe fn cost_block_neon_inner(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: as in `row_norms_neon_inner`.
        cost_block_impl(|a, b| unsafe { dot_neon_body(a, b) }, x, xn, r0, r1, d, c, cn, k, out);
    }

    #[allow(clippy::too_many_arguments)]
    pub fn cost_block_neon(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: gated on runtime neon detection in `vector_table`.
        unsafe { cost_block_neon_inner(x, xn, r0, r1, d, c, cn, k, out) }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    unsafe fn cost_panel_neon_inner(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: as in `row_norms_neon_inner`.
        cost_panel_impl(|a, b| unsafe { dot_neon_body(a, b) }, x, xn, r0, r1, d, c, cn, k, out);
    }

    #[allow(clippy::too_many_arguments)]
    pub fn cost_panel_neon(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: gated on runtime neon detection in `vector_table`.
        unsafe { cost_panel_neon_inner(x, xn, r0, r1, d, c, cn, k, out) }
    }
}

#[cfg(target_arch = "aarch64")]
fn vector_table() -> Option<Kernels> {
    if std::arch::is_aarch64_feature_detected!("neon") {
        Some(Kernels {
            isa: "neon",
            mode: KernelMode::Auto,
            dot: arm::dot_neon,
            row_norms: arm::row_norms_neon,
            cost_block: arm::cost_block_neon,
            cost_panel: arm::cost_panel_neon,
            sq_dist,
            bbox_far: bbox_far_scalar,
        })
    } else {
        None
    }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn vector_table() -> Option<Kernels> {
    None
}

#[cfg(not(target_arch = "x86_64"))]
fn fma_table() -> Option<Kernels> {
    None
}

/// No dedicated fast-math kernels off x86-64 yet: `select` falls through
/// to `fma` → `auto` → `scalar`, which on aarch64 lands on NEON.
#[cfg(not(target_arch = "x86_64"))]
fn fast_table() -> Option<Kernels> {
    None
}

// ---------------------------------------------------------------------------
// f64 objective tier — scalar in every mode, by policy (see module docs)
// ---------------------------------------------------------------------------

/// Squared Euclidean distance between two f32 rows: per coordinate the
/// f32 difference is widened to f64 and squared, accumulated in index
/// order. The objective-tier `dist2` every consumer shares
/// (`Dataset::dist2`, `DataView::dist2`, batch ordering, kNN, pruning
/// bounds — the bound ≥ distance comparisons in [`crate::knn::farthest`]
/// hold exactly because both sides use this accumulation).
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0f64;
    for (&x, &y) in a.iter().zip(b) {
        let diff = (x - y) as f64;
        s += diff * diff;
    }
    s
}

/// Squared Euclidean distance from an f32 row to an f64 centroid (each
/// coordinate widened before subtracting) — the Lloyd/objective variant.
#[inline]
pub fn sq_dist_to_f64(a: &[f32], mu: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), mu.len());
    let mut s = 0f64;
    for (&x, &m) in a.iter().zip(mu) {
        let diff = x as f64 - m;
        s += diff * diff;
    }
    s
}

/// Fold `row` into the f64 running sums `acc` (`acc[j] += row[j]`) and
/// return the row's squared norm `Σ row[j]²`, both accumulated in index
/// order — the moment update of `ClusterDelta::add` and the certificate
/// chunk folds, kept here so the two stay bit-identical by construction.
#[inline]
pub fn accumulate(acc: &mut [f64], row: &[f32]) -> f64 {
    debug_assert_eq!(acc.len(), row.len());
    let mut xx = 0f64;
    for (a, &v) in acc.iter_mut().zip(row) {
        let v = v as f64;
        *a += v;
        xx += v * v;
    }
    xx
}

/// Inverse of [`accumulate`]: fold `row` out of `acc` and return the
/// row's squared norm (`ClusterDelta::remove`).
#[inline]
pub fn decumulate(acc: &mut [f64], row: &[f32]) -> f64 {
    debug_assert_eq!(acc.len(), row.len());
    let mut xx = 0f64;
    for (a, &v) in acc.iter_mut().zip(row) {
        let v = v as f64;
        *a -= v;
        xx += v * v;
    }
    xx
}

/// `acc[j] += row[j]` in f64, index order — the column-sum update behind
/// centroid and column-mean accumulation.
#[inline]
pub fn add_assign_row(acc: &mut [f64], row: &[f32]) {
    debug_assert_eq!(acc.len(), row.len());
    for (a, &v) in acc.iter_mut().zip(row) {
        *a += v as f64;
    }
}

/// Squared L2 norm of an f32 row accumulated in f64, index order.
#[inline]
pub fn sumsq_f64(row: &[f32]) -> f64 {
    row.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// Squared distance between two centroids given as f64 *sums* with
/// member counts: `Σ_j (sa[j]/ma − sb[j]/mb)²`. Pass `mb = 1.0` when `sb`
/// already is a mean (division by 1.0 is exact). Ward merge costs and
/// the online BGSS term share this one accumulation.
#[inline]
pub fn centroid_sq_dist(sa: &[f64], ma: f64, sb: &[f64], mb: f64) -> f64 {
    debug_assert_eq!(sa.len(), sb.len());
    let mut dist2 = 0f64;
    for (&a, &b) in sa.iter().zip(sb) {
        let diff = a / ma - b / mb;
        dist2 += diff * diff;
    }
    dist2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn rand_vec(rng: &mut Pcg32, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    fn dot_ref_f64(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    #[test]
    fn mode_display_round_trips() {
        for m in KernelMode::ALL {
            assert_eq!(m.to_string().parse::<KernelMode>().unwrap(), m);
        }
        assert_eq!(KernelMode::accepted(), "auto|scalar|fma|fast-math");
        let err = "avx512".parse::<KernelMode>().unwrap_err();
        assert!(err.to_string().contains("auto|scalar|fma|fast-math"), "{err}");
    }

    #[test]
    fn scalar_table_reports_scalar_everywhere() {
        let k = Kernels::select(KernelMode::Scalar);
        assert_eq!(k.isa(), "scalar");
        assert_eq!(k.mode(), KernelMode::Scalar);
    }

    #[test]
    fn auto_dot_bit_identical_to_scalar() {
        // On a host with AVX2/NEON this is the vector-vs-scalar
        // bit-identity microtest; on a host without, both tables are
        // scalar and it holds trivially.
        let auto = Kernels::select(KernelMode::Auto);
        let scalar = Kernels::scalar();
        let mut rng = Pcg32::new(901);
        for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 100, 128, 257] {
            let a = rand_vec(&mut rng, len);
            let b = rand_vec(&mut rng, len);
            let (va, vs) = (auto.dot(&a, &b), scalar.dot(&a, &b));
            assert_eq!(va.to_bits(), vs.to_bits(), "len={len} isa={}", auto.isa());
            let want = dot_ref_f64(&a, &b);
            assert!((vs as f64 - want).abs() < 1e-3 * (1.0 + want.abs()), "len={len}");
        }
    }

    #[test]
    fn auto_row_norms_and_cost_block_bit_identical_to_scalar() {
        let auto = Kernels::select(KernelMode::Auto);
        let scalar = Kernels::scalar();
        let mut rng = Pcg32::new(902);
        // k > TILE_COLS exercises tiling; ragged d exercises the tail.
        for &(m, k, d) in &[(5usize, 9usize, 4usize), (17, 70, 13), (3, 65, 32), (8, 128, 8)] {
            let x = rand_vec(&mut rng, m * d);
            let c = rand_vec(&mut rng, k * d);
            let (mut xn_a, mut xn_s) = (Vec::new(), Vec::new());
            auto.row_norms(&x, m, d, &mut xn_a);
            scalar.row_norms(&x, m, d, &mut xn_s);
            assert_eq!(xn_a, xn_s, "row_norms m={m} d={d}");
            let (mut cn_a, mut cn_s) = (Vec::new(), Vec::new());
            auto.row_norms(&c, k, d, &mut cn_a);
            scalar.row_norms(&c, k, d, &mut cn_s);
            let (mut out_a, mut out_s) = (vec![0f32; m * k], vec![0f32; m * k]);
            auto.cost_block(&x, &xn_a, 0, m, d, &c, &cn_a, k, &mut out_a);
            scalar.cost_block(&x, &xn_s, 0, m, d, &c, &cn_s, k, &mut out_s);
            assert_eq!(out_a, out_s, "cost_block m={m} k={k} d={d}");
            // And against the direct f64 definition, with tolerance.
            for i in 0..m {
                for j in 0..k {
                    let want = sq_dist(&x[i * d..(i + 1) * d], &c[j * d..(j + 1) * d]);
                    let got = out_s[i * k + j] as f64;
                    assert!((got - want).abs() < 1e-3 * (1.0 + want), "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn fma_mode_is_ulp_close_to_scalar() {
        let fma = Kernels::select(KernelMode::Fma);
        assert_eq!(fma.mode(), KernelMode::Fma);
        let scalar = Kernels::scalar();
        let mut rng = Pcg32::new(903);
        for len in [8usize, 32, 128, 1000] {
            let a = rand_vec(&mut rng, len);
            let b = rand_vec(&mut rng, len);
            let (vf, vs) = (fma.dot(&a, &b) as f64, scalar.dot(&a, &b) as f64);
            let want = dot_ref_f64(&a, &b);
            // Contraction only ever tightens the error bound; both stay
            // within a few f32 ULPs of the f64 reference. The magnitude
            // scale is Σ|a||b|, against which per-step rounding is bound.
            let scale: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
            let tol = 1e-5 * (1.0 + scale);
            assert!((vf - want).abs() <= tol, "len={len}: fma {vf} vs ref {want}");
            assert!((vf - vs).abs() <= tol, "len={len}: fma {vf} vs scalar {vs}");
        }
    }

    #[test]
    fn panel_kernel_bit_identical_to_cost_block_in_deterministic_tiers() {
        // `cost_panel` only reorders streaming in the non-fast tiers;
        // every entry is the same per-entry dot, so the panel and the
        // per-row kernel must agree to the bit on every deterministic
        // table (including a degraded `fma` on hosts without the ISA).
        let mut rng = Pcg32::new(906);
        for mode in [KernelMode::Scalar, KernelMode::Auto, KernelMode::Fma] {
            let kern = Kernels::select(mode);
            for &(m, k, d) in &[(1usize, 9usize, 4usize), (6, 70, 13), (5, 130, 32), (7, 65, 8)] {
                let x = rand_vec(&mut rng, m * d);
                let c = rand_vec(&mut rng, k * d);
                let (mut xn, mut cn) = (Vec::new(), Vec::new());
                kern.row_norms(&x, m, d, &mut xn);
                kern.row_norms(&c, k, d, &mut cn);
                let (mut block, mut panel) = (vec![0f32; m * k], vec![0f32; m * k]);
                kern.cost_block(&x, &xn, 0, m, d, &c, &cn, k, &mut block);
                kern.cost_panel(&x, &xn, 0, m, d, &c, &cn, k, &mut panel);
                assert_eq!(block, panel, "mode={mode} isa={} m={m} k={k} d={d}", kern.isa());
            }
        }
    }

    #[test]
    fn fast_math_is_ppm_close_and_its_bound_still_dominates() {
        let fast = Kernels::select(KernelMode::FastMath);
        assert_eq!(fast.mode(), KernelMode::FastMath);
        let scalar = Kernels::scalar();
        let mut rng = Pcg32::new(907);
        for &(m, k, d) in &[(4usize, 9usize, 3usize), (9, 70, 16), (6, 33, 29), (8, 130, 8)] {
            let x = rand_vec(&mut rng, m * d);
            let c = rand_vec(&mut rng, k * d);
            let (mut xn_f, mut cn_f) = (Vec::new(), Vec::new());
            fast.row_norms(&x, m, d, &mut xn_f);
            fast.row_norms(&c, k, d, &mut cn_f);
            let (mut xn_s, mut cn_s) = (Vec::new(), Vec::new());
            scalar.row_norms(&x, m, d, &mut xn_s);
            scalar.row_norms(&c, k, d, &mut cn_s);
            let (mut out_f, mut out_s) = (vec![0f32; m * k], vec![0f32; m * k]);
            fast.cost_panel(&x, &xn_f, 0, m, d, &c, &cn_f, k, &mut out_f);
            scalar.cost_block(&x, &xn_s, 0, m, d, &c, &cn_s, k, &mut out_s);
            for (idx, (&f, &s)) in out_f.iter().zip(&out_s).enumerate() {
                // Costs are O(d)-sized sums of O(1) terms; a relative
                // guard of 1e-4 is orders looser than the observed
                // fused-vs-split rounding and still catches indexing or
                // tiling bugs outright.
                let scale = xn_s[idx / k] as f64 + cn_s[idx % k] as f64;
                assert!(
                    (f as f64 - s as f64).abs() <= 1e-4 * (1.0 + scale),
                    "entry {idx}: fast {f} vs scalar {s} (isa={})",
                    fast.isa()
                );
            }
        }
        // The pruning invariant of the fast tier: for points inside the
        // box, the vectorized bound dominates the vectorized distance —
        // exactly, not approximately.
        for d in [1usize, 3, 7, 8, 15, 16, 32, 57] {
            let a = rand_vec(&mut rng, d);
            let b = rand_vec(&mut rng, d);
            let q = rand_vec(&mut rng, d);
            let lo: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| x.min(y)).collect();
            let hi: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| x.max(y)).collect();
            for p in [&a, &b, &lo, &hi] {
                assert!(
                    fast.bbox_far(&q, &lo, &hi) >= fast.sq_dist(&q, p),
                    "d={d} isa={}",
                    fast.isa()
                );
            }
            // ppm-scale agreement with the scalar objective tier.
            let (df, ds) = (fast.sq_dist(&a, &b), sq_dist(&a, &b));
            assert!((df - ds).abs() <= 1e-9 + 1e-5 * ds, "d={d}: {df} vs {ds}");
        }
    }

    #[test]
    fn avx512_kernels_are_ulp_close_to_scalar_or_skip() {
        // Exercises the AVX-512 arm only where it exists: the fast-math
        // table reports `avx512f` only when build.rs compiled the arm
        // (rustc >= 1.89) *and* the host has the ISA — everywhere else
        // this test degrades to a clean skip.
        let fast = Kernels::select(KernelMode::FastMath);
        if fast.isa() != "avx512f" {
            eprintln!("skipping avx512 checks: fast-math selected '{}'", fast.isa());
            return;
        }
        let scalar = Kernels::scalar();
        let mut rng = Pcg32::new(908);
        for len in [1usize, 7, 8, 15, 16, 17, 31, 32, 33, 64, 257, 1000] {
            let a = rand_vec(&mut rng, len);
            let b = rand_vec(&mut rng, len);
            let (vf, vs) = (fast.dot(&a, &b) as f64, scalar.dot(&a, &b) as f64);
            let scale: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
            assert!((vf - vs).abs() <= 1e-5 * (1.0 + scale), "len={len}: {vf} vs {vs}");
            let (df, ds) = (fast.sq_dist(&a, &b), sq_dist(&a, &b));
            assert!((df - ds).abs() <= 1e-9 + 1e-5 * ds, "len={len}: {df} vs {ds}");
        }
    }

    #[test]
    fn env_scalar_forces_the_fallback_on_any_host() {
        // Other tests may race this env var, but the worst outcome is a
        // concurrently-initialized process default landing on `scalar`,
        // which is bit-identical to `auto` — results cannot change.
        std::env::set_var("ABA_KERNELS", "scalar");
        assert_eq!(kernel_mode_env_default(), KernelMode::Scalar);
        assert_eq!(Kernels::select(kernel_mode_env_default()).isa(), "scalar");
        std::env::set_var("ABA_KERNELS", "no-such-mode");
        assert_eq!(kernel_mode_env_default(), KernelMode::Auto);
        // Exported-but-empty (CI matrices) means "no override".
        std::env::set_var("ABA_KERNELS", "");
        assert_eq!(kernel_mode_env_default(), KernelMode::Auto);
        std::env::remove_var("ABA_KERNELS");
        assert_eq!(kernel_mode_env_default(), KernelMode::Auto);
    }

    #[test]
    fn accumulate_decumulate_round_trip() {
        let mut rng = Pcg32::new(904);
        let row = rand_vec(&mut rng, 11);
        let mut acc = vec![0f64; 11];
        let xx = accumulate(&mut acc, &row);
        assert!((xx - sumsq_f64(&row)).abs() < 1e-12 * (1.0 + xx));
        assert_eq!(decumulate(&mut acc, &row), xx);
        assert!(acc.iter().all(|&v| v.abs() < 1e-12));
        let mut means = vec![0f64; 11];
        add_assign_row(&mut means, &row);
        for (m, &v) in means.iter().zip(&row) {
            assert_eq!(*m, v as f64);
        }
    }

    #[test]
    fn centroid_sq_dist_matches_direct_means() {
        let sa = [2.0f64, 4.0, 6.0];
        let sb = [1.0f64, 1.0, 1.0];
        // means: [1, 2, 3] vs [0.5, 0.5, 0.5] -> 0.25 + 2.25 + 6.25
        let got = centroid_sq_dist(&sa, 2.0, &sb, 2.0);
        assert!((got - 8.75).abs() < 1e-12, "{got}");
        // mb = 1.0 treats sb as an already-divided mean, exactly.
        assert_eq!(centroid_sq_dist(&sa, 2.0, &sb, 1.0), {
            let mut s = 0f64;
            for (a, b) in sa.iter().zip(&sb) {
                let diff = a / 2.0 - b;
                s += diff * diff;
            }
            s
        });
    }

    #[test]
    fn sq_dist_variants_agree() {
        let mut rng = Pcg32::new(905);
        let a = rand_vec(&mut rng, 9);
        let b = rand_vec(&mut rng, 9);
        let mu: Vec<f64> = b.iter().map(|&v| v as f64).collect();
        let d32 = sq_dist(&a, &b);
        let d64 = sq_dist_to_f64(&a, &mu);
        // Same values, different widening points: equal up to f32
        // subtraction vs f64 subtraction of f32-representable values —
        // here both are exact per coordinate difference of the widened
        // pair only when the f32 subtraction does not round; allow ULPs.
        assert!((d32 - d64).abs() < 1e-6 * (1.0 + d64), "{d32} vs {d64}");
        assert_eq!(sq_dist(&a, &a), 0.0);
    }
}
