//! Runtime-dispatched SIMD microkernels for the squared-Euclidean hot
//! paths, plus the crate's single accumulation-precision policy.
//!
//! # Two precision tiers, one home
//!
//! Every distance/moment computation in the crate lives here, in one of
//! two documented tiers:
//!
//! * **f32 cost tier** — the per-batch cost matrices and row norms the
//!   assignment solver consumes ([`Kernels::cost_block`],
//!   [`Kernels::row_norms`], [`Kernels::dot`]). Accumulated in f32 over
//!   8 vertical lanes; this is the tier that vectorizes.
//! * **f64 objective tier** — everything that feeds objectives,
//!   orderings, or maintained moments ([`sq_dist`], [`sq_dist_to_f64`],
//!   [`accumulate`] / [`decumulate`], [`add_assign_row`], [`sumsq_f64`],
//!   [`centroid_sq_dist`]). These accumulate in f64 **in index order**
//!   and deliberately stay scalar in every kernel mode: f64 chains are
//!   order-sensitive, and the crate's bit-identity contracts (serial ≡
//!   threaded, view ≡ owned, delta ≡ recompute, save ≡ load) are defined
//!   against this exact order.
//!
//! # Dispatch and the bit-identity contract
//!
//! [`Kernels`] is a table of function pointers selected **once** — at
//! session construction (builder `.kernels(..)`, CLI `--kernels`) or
//! lazily for the process default ([`Kernels::get`], which consults the
//! `ABA_KERNELS` environment variable a single time). The default mode
//! ([`KernelMode::Auto`]) picks the widest ISA whose kernels are
//! **bit-identical** to the scalar reference: the vector `dot` keeps the
//! same 8 vertical f32 accumulator lanes as the scalar kernel (separate
//! multiply and add, never a fused one) and combines them in the same
//! fixed reduction tree, so by IEEE-754 every lane performs the same
//! correctly-rounded operations in the same order and the result cannot
//! differ. The property suite asserts this across the flat,
//! hierarchical, sparse, and online solver paths.
//!
//! | mode | x86_64 | aarch64 | other | numeric contract |
//! |---|---|---|---|---|
//! | `auto` | AVX2 (mul + add) | NEON (mul + add) | scalar | bit-identical to `scalar` |
//! | `scalar` | 8-lane unrolled | 8-lane unrolled | same | the reference |
//! | `fma` | AVX2 + FMA (`vfmadd`) | falls back to auto | scalar | ULP-bounded, not bit-equal |
//!
//! [`KernelMode::Fma`] is opt-in precisely because fused multiply-add
//! contracts the intermediate rounding: it is slightly *more* accurate
//! (and a touch faster) but not bit-equal to the scalar reference, so it
//! is gated by ULP-bound tests and the `kernel` bench section's
//! objective-gap records instead of the bit-identity suite. Requesting a
//! mode the host cannot honor falls back down the same table (the
//! selected ISA is always visible via [`Kernels::isa`], surfaced in
//! `Partition` timings, `BENCH_aba.json`, and serve's `/metrics`).

use crate::error::AbaError;
use std::sync::OnceLock;

/// Kernel-selection knob: builder `.kernels(..)`, CLI `--kernels`, env
/// `ABA_KERNELS`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// Widest available bit-identical vector path (the default).
    Auto,
    /// Force the scalar reference kernels on any host.
    Scalar,
    /// FMA-contracted fast path — ULP-close to, but not bit-equal with,
    /// the scalar reference. Falls back to `Auto` where unavailable.
    Fma,
}

impl KernelMode {
    /// Every mode, in display order — the single source of the accepted
    /// CLI/env values.
    pub const ALL: [KernelMode; 3] = [KernelMode::Auto, KernelMode::Scalar, KernelMode::Fma];

    /// The canonical (CLI/env) spelling.
    pub const fn as_str(self) -> &'static str {
        match self {
            KernelMode::Auto => "auto",
            KernelMode::Scalar => "scalar",
            KernelMode::Fma => "fma",
        }
    }

    /// Accepted spellings joined with `|`, for help and error messages.
    pub fn accepted() -> String {
        Self::ALL
            .iter()
            .map(|m| m.as_str())
            .collect::<Vec<_>>()
            .join("|")
    }
}

impl std::fmt::Display for KernelMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for KernelMode {
    type Err = AbaError;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::ALL
            .iter()
            .copied()
            .find(|m| m.as_str() == s)
            .ok_or_else(|| {
                AbaError::InvalidInput(format!(
                    "unknown kernel mode '{s}' (accepted: {})",
                    KernelMode::accepted()
                ))
            })
    }
}

/// The kernel mode requested by the `ABA_KERNELS` environment variable
/// (unset or unparsable → [`KernelMode::Auto`]). Consulted once by
/// [`Kernels::get`] and once per session build when the builder leaves
/// the knob unset — never on the hot path.
pub fn kernel_mode_env_default() -> KernelMode {
    match std::env::var("ABA_KERNELS") {
        // An exported-but-empty variable (common in CI matrices) means
        // "no override", not a parse error worth warning about.
        Ok(v) if v.trim().is_empty() => KernelMode::Auto,
        Ok(v) => v.parse().unwrap_or_else(|_| {
            log::warn!(
                "ignoring invalid ABA_KERNELS='{v}' (accepted: {})",
                KernelMode::accepted()
            );
            KernelMode::Auto
        }),
        Err(_) => KernelMode::Auto,
    }
}

type DotFn = fn(&[f32], &[f32]) -> f32;
type RowNormsFn = fn(&[f32], usize, &mut Vec<f32>);
type CostBlockFn =
    fn(&[f32], &[f32], usize, usize, usize, &[f32], &[f32], usize, &mut [f32]);

/// A dispatch table of f32-tier kernels, selected once per session (or
/// once per process for [`Kernels::get`]). Copy — holding one is free.
#[derive(Clone, Copy, Debug)]
pub struct Kernels {
    isa: &'static str,
    mode: KernelMode,
    dot: DotFn,
    row_norms: RowNormsFn,
    cost_block: CostBlockFn,
}

static PROCESS_DEFAULT: OnceLock<Kernels> = OnceLock::new();

impl Kernels {
    /// The scalar reference table — the numeric anchor every vector path
    /// is bit-identical to.
    pub fn scalar() -> Self {
        Kernels {
            isa: "scalar",
            mode: KernelMode::Scalar,
            dot: dot_scalar,
            row_norms: row_norms_scalar,
            cost_block: cost_block_scalar,
        }
    }

    /// Select a table for `mode`, probing CPU features at most once per
    /// call. Unavailable requests degrade (`fma` → `auto` → `scalar`)
    /// rather than fail; [`Kernels::isa`] reports what was picked.
    pub fn select(mode: KernelMode) -> Self {
        match mode {
            KernelMode::Scalar => Self::scalar(),
            KernelMode::Auto => vector_table()
                .map(|t| Kernels { mode: KernelMode::Auto, ..t })
                .unwrap_or_else(|| Kernels { mode: KernelMode::Auto, ..Self::scalar() }),
            KernelMode::Fma => fma_table()
                .or_else(vector_table)
                .map(|t| Kernels { mode: KernelMode::Fma, ..t })
                .unwrap_or_else(|| Kernels { mode: KernelMode::Fma, ..Self::scalar() }),
        }
    }

    /// The process-default table: [`kernel_mode_env_default`] resolved
    /// through [`Kernels::select`], memoized on first use. Free-function
    /// consumers (`cost_matrix_native`, serve metrics) read this;
    /// sessions override it per builder.
    pub fn get() -> Kernels {
        *PROCESS_DEFAULT.get_or_init(|| Kernels::select(kernel_mode_env_default()))
    }

    /// The instruction set actually selected: `"scalar"`, `"avx2"`,
    /// `"avx2+fma"`, or `"neon"`.
    pub fn isa(&self) -> &'static str {
        self.isa
    }

    /// The mode this table was requested under (the effective ISA may be
    /// narrower — see [`Kernels::select`]).
    pub fn mode(&self) -> KernelMode {
        self.mode
    }

    /// f32 dot product — 8 vertical accumulator lanes, fixed reduction
    /// order (see the module docs for the bit-identity contract).
    #[inline]
    pub fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        (self.dot)(a, b)
    }

    /// Squared L2 norm of every `d`-row of `x` into `out` (cleared),
    /// via the same dot kernel the cost tier uses — so precomputed and
    /// inline norms are bit-identical.
    pub fn row_norms(&self, x: &[f32], rows: usize, d: usize, out: &mut Vec<f32>) {
        debug_assert_eq!(x.len(), rows * d);
        (self.row_norms)(x, d, out)
    }

    /// Write rows `r0..r1` of the `m x k` cost matrix into `out`
    /// (`(r1 - r0) * k` entries): `||x_i||² + ||c_j||² − 2⟨x_i, c_j⟩`
    /// clamped at 0, with precomputed row norms `xn` (indexed by global
    /// row) and centroid norms `cn`. Tiled over centroid blocks so the
    /// active slice of `c` stays L1-resident while `x` streams; each
    /// entry depends only on its own row/column, so any row split or
    /// tile shape yields bit-identical results.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn cost_block(
        &self,
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        (self.cost_block)(x, xn, r0, r1, d, c, cn, k, out)
    }
}

impl Default for Kernels {
    fn default() -> Self {
        Kernels::get()
    }
}

// ---------------------------------------------------------------------------
// Shared kernel bodies
// ---------------------------------------------------------------------------

/// Centroid-tile width for [`Kernels::cost_block`]: 64 centroids x 64
/// features x 4 bytes = 16 KiB, comfortably L1-resident alongside the x
/// row.
const TILE_COLS: usize = 64;

/// The fixed 8-lane reduction tree every dot kernel (scalar and vector)
/// funnels through — the order half of the bit-identity contract.
#[inline(always)]
fn reduce8(acc: &[f32; 8]) -> f32 {
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// 8-lane unrolled scalar dot product — the reference kernel. The
/// multiple independent accumulators break the f32 dependency chain so
/// LLVM auto-vectorizes even without the explicit paths below (a plain
/// `zip().map().sum()` cannot be reordered and stays scalar) — measured
/// ~3x on the cost-matrix hot path (EXPERIMENTS.md §Perf).
#[inline]
fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 8];
    let chunks = a.len() / 8;
    for t in 0..chunks {
        let (abase, bbase) = (&a[t * 8..t * 8 + 8], &b[t * 8..t * 8 + 8]);
        for l in 0..8 {
            acc[l] += abase[l] * bbase[l];
        }
    }
    let mut dot = reduce8(&acc);
    for t in chunks * 8..a.len() {
        dot += a[t] * b[t];
    }
    dot
}

/// Generic row-norms body, monomorphized per ISA so `dot` inlines.
#[inline(always)]
fn row_norms_impl<F: Fn(&[f32], &[f32]) -> f32>(dot: F, x: &[f32], d: usize, out: &mut Vec<f32>) {
    out.clear();
    out.extend(x.chunks_exact(d).map(|r| dot(r, r)));
}

/// Generic cost-block body, monomorphized per ISA so `dot` inlines into
/// the tiled loop (see [`Kernels::cost_block`] for the semantics).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn cost_block_impl<F: Fn(&[f32], &[f32]) -> f32>(
    dot: F,
    x: &[f32],
    xn: &[f32],
    r0: usize,
    r1: usize,
    d: usize,
    c: &[f32],
    cn: &[f32],
    k: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (r1 - r0) * k);
    let mut jt = 0;
    while jt < k {
        let jhi = (jt + TILE_COLS).min(k);
        for i in r0..r1 {
            let xi = &x[i * d..(i + 1) * d];
            let row = &mut out[(i - r0) * k..(i - r0) * k + k];
            for (j, cj) in c[jt * d..jhi * d].chunks_exact(d).enumerate() {
                let j = jt + j;
                row[j] = (xn[i] + cn[j] - 2.0 * dot(xi, cj)).max(0.0);
            }
        }
        jt = jhi;
    }
}

fn row_norms_scalar(x: &[f32], d: usize, out: &mut Vec<f32>) {
    row_norms_impl(dot_scalar, x, d, out);
}

#[allow(clippy::too_many_arguments)]
fn cost_block_scalar(
    x: &[f32],
    xn: &[f32],
    r0: usize,
    r1: usize,
    d: usize,
    c: &[f32],
    cn: &[f32],
    k: usize,
    out: &mut [f32],
) {
    cost_block_impl(dot_scalar, x, xn, r0, r1, d, c, cn, k, out);
}

// ---------------------------------------------------------------------------
// x86_64: AVX2 (bit-identical) and AVX2+FMA (contracted) paths
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{cost_block_impl, reduce8, row_norms_impl};
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_setzero_ps,
        _mm256_storeu_ps,
    };

    /// AVX2 dot body: per 8-wide chunk each lane performs exactly the
    /// multiply-then-add of the scalar kernel's matching accumulator, and
    /// the vector register is spilled to an array and reduced through the
    /// same [`reduce8`] tree — bit-identical by IEEE-754.
    ///
    /// `#[inline(always)]` with no `#[target_feature]` of its own: the
    /// callers below carry the feature, so after monomorphization the
    /// intrinsics inline into AVX2-enabled code.
    ///
    /// # Safety
    /// Callers must only reach this after `avx2` was detected.
    #[inline(always)]
    unsafe fn dot_avx2_body(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 8;
        let mut acc = _mm256_setzero_ps();
        for (ca, cb) in a.chunks_exact(8).zip(b.chunks_exact(8)) {
            let va = _mm256_loadu_ps(ca.as_ptr());
            let vb = _mm256_loadu_ps(cb.as_ptr());
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut dot = reduce8(&lanes);
        for t in chunks * 8..a.len() {
            dot += a[t] * b[t];
        }
        dot
    }

    /// FMA dot body: same lane layout, but multiply-add is fused
    /// (`vfmadd`), including the scalar tail — ULP-close to the scalar
    /// reference, not bit-equal.
    ///
    /// # Safety
    /// Callers must only reach this after `avx2` and `fma` were detected.
    #[inline(always)]
    unsafe fn dot_fma_body(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 8;
        let mut acc = _mm256_setzero_ps();
        for (ca, cb) in a.chunks_exact(8).zip(b.chunks_exact(8)) {
            let va = _mm256_loadu_ps(ca.as_ptr());
            let vb = _mm256_loadu_ps(cb.as_ptr());
            acc = _mm256_fmadd_ps(va, vb, acc);
        }
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut dot = reduce8(&lanes);
        for t in chunks * 8..a.len() {
            dot = a[t].mul_add(b[t], dot);
        }
        dot
    }

    // Safe `fn`-pointer wrappers. `#[target_feature]` functions must be
    // `unsafe fn` on this toolchain and cannot coerce to plain `fn`
    // pointers, so each wrapper pairs a feature-enabled unsafe inner
    // with a safe outer; the table constructors below only hand these
    // out after `is_x86_feature_detected!` succeeded, which is what
    // makes the inner calls sound.

    #[target_feature(enable = "avx2")]
    unsafe fn dot_avx2_inner(a: &[f32], b: &[f32]) -> f32 {
        dot_avx2_body(a, b)
    }

    pub fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: gated on runtime avx2 detection in `vector_table`.
        unsafe { dot_avx2_inner(a, b) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn row_norms_avx2_inner(x: &[f32], d: usize, out: &mut Vec<f32>) {
        // SAFETY: closure bodies do not inherit the enclosing unsafety;
        // the feature gate that makes this sound is the caller's.
        row_norms_impl(|a, b| unsafe { dot_avx2_body(a, b) }, x, d, out);
    }

    pub fn row_norms_avx2(x: &[f32], d: usize, out: &mut Vec<f32>) {
        // SAFETY: gated on runtime avx2 detection in `vector_table`.
        unsafe { row_norms_avx2_inner(x, d, out) }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    unsafe fn cost_block_avx2_inner(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: as in `row_norms_avx2_inner`.
        cost_block_impl(|a, b| unsafe { dot_avx2_body(a, b) }, x, xn, r0, r1, d, c, cn, k, out);
    }

    #[allow(clippy::too_many_arguments)]
    pub fn cost_block_avx2(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: gated on runtime avx2 detection in `vector_table`.
        unsafe { cost_block_avx2_inner(x, xn, r0, r1, d, c, cn, k, out) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot_fma_inner(a: &[f32], b: &[f32]) -> f32 {
        dot_fma_body(a, b)
    }

    pub fn dot_fma(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: gated on runtime avx2+fma detection in `fma_table`.
        unsafe { dot_fma_inner(a, b) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn row_norms_fma_inner(x: &[f32], d: usize, out: &mut Vec<f32>) {
        // SAFETY: as in `row_norms_avx2_inner`.
        row_norms_impl(|a, b| unsafe { dot_fma_body(a, b) }, x, d, out);
    }

    pub fn row_norms_fma(x: &[f32], d: usize, out: &mut Vec<f32>) {
        // SAFETY: gated on runtime avx2+fma detection in `fma_table`.
        unsafe { row_norms_fma_inner(x, d, out) }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn cost_block_fma_inner(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: as in `row_norms_avx2_inner`.
        cost_block_impl(|a, b| unsafe { dot_fma_body(a, b) }, x, xn, r0, r1, d, c, cn, k, out);
    }

    #[allow(clippy::too_many_arguments)]
    pub fn cost_block_fma(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: gated on runtime avx2+fma detection in `fma_table`.
        unsafe { cost_block_fma_inner(x, xn, r0, r1, d, c, cn, k, out) }
    }
}

#[cfg(target_arch = "x86_64")]
fn vector_table() -> Option<Kernels> {
    if std::arch::is_x86_feature_detected!("avx2") {
        Some(Kernels {
            isa: "avx2",
            mode: KernelMode::Auto,
            dot: x86::dot_avx2,
            row_norms: x86::row_norms_avx2,
            cost_block: x86::cost_block_avx2,
        })
    } else {
        None
    }
}

#[cfg(target_arch = "x86_64")]
fn fma_table() -> Option<Kernels> {
    if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma") {
        Some(Kernels {
            isa: "avx2+fma",
            mode: KernelMode::Fma,
            dot: x86::dot_fma,
            row_norms: x86::row_norms_fma,
            cost_block: x86::cost_block_fma,
        })
    } else {
        None
    }
}

// ---------------------------------------------------------------------------
// aarch64: NEON (bit-identical) path
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{cost_block_impl, reduce8, row_norms_impl};
    use std::arch::aarch64::{vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32};

    /// NEON dot body: two 4-wide registers cover the scalar kernel's 8
    /// accumulator lanes (lanes 0..3 and 4..7), multiply-then-add, same
    /// [`reduce8`] tree — bit-identical by IEEE-754.
    ///
    /// # Safety
    /// Callers must only reach this after `neon` was detected.
    #[inline(always)]
    unsafe fn dot_neon_body(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 8;
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        for (ca, cb) in a.chunks_exact(8).zip(b.chunks_exact(8)) {
            lo = vaddq_f32(lo, vmulq_f32(vld1q_f32(ca.as_ptr()), vld1q_f32(cb.as_ptr())));
            hi = vaddq_f32(
                hi,
                vmulq_f32(vld1q_f32(ca.as_ptr().add(4)), vld1q_f32(cb.as_ptr().add(4))),
            );
        }
        let mut lanes = [0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), hi);
        let mut dot = reduce8(&lanes);
        for t in chunks * 8..a.len() {
            dot += a[t] * b[t];
        }
        dot
    }

    #[target_feature(enable = "neon")]
    unsafe fn dot_neon_inner(a: &[f32], b: &[f32]) -> f32 {
        dot_neon_body(a, b)
    }

    pub fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: gated on runtime neon detection in `vector_table`.
        unsafe { dot_neon_inner(a, b) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn row_norms_neon_inner(x: &[f32], d: usize, out: &mut Vec<f32>) {
        // SAFETY: closure bodies do not inherit the enclosing unsafety;
        // the feature gate that makes this sound is the caller's.
        row_norms_impl(|a, b| unsafe { dot_neon_body(a, b) }, x, d, out);
    }

    pub fn row_norms_neon(x: &[f32], d: usize, out: &mut Vec<f32>) {
        // SAFETY: gated on runtime neon detection in `vector_table`.
        unsafe { row_norms_neon_inner(x, d, out) }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    unsafe fn cost_block_neon_inner(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: as in `row_norms_neon_inner`.
        cost_block_impl(|a, b| unsafe { dot_neon_body(a, b) }, x, xn, r0, r1, d, c, cn, k, out);
    }

    #[allow(clippy::too_many_arguments)]
    pub fn cost_block_neon(
        x: &[f32],
        xn: &[f32],
        r0: usize,
        r1: usize,
        d: usize,
        c: &[f32],
        cn: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        // SAFETY: gated on runtime neon detection in `vector_table`.
        unsafe { cost_block_neon_inner(x, xn, r0, r1, d, c, cn, k, out) }
    }
}

#[cfg(target_arch = "aarch64")]
fn vector_table() -> Option<Kernels> {
    if std::arch::is_aarch64_feature_detected!("neon") {
        Some(Kernels {
            isa: "neon",
            mode: KernelMode::Auto,
            dot: arm::dot_neon,
            row_norms: arm::row_norms_neon,
            cost_block: arm::cost_block_neon,
        })
    } else {
        None
    }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn vector_table() -> Option<Kernels> {
    None
}

#[cfg(not(target_arch = "x86_64"))]
fn fma_table() -> Option<Kernels> {
    None
}

// ---------------------------------------------------------------------------
// f64 objective tier — scalar in every mode, by policy (see module docs)
// ---------------------------------------------------------------------------

/// Squared Euclidean distance between two f32 rows: per coordinate the
/// f32 difference is widened to f64 and squared, accumulated in index
/// order. The objective-tier `dist2` every consumer shares
/// (`Dataset::dist2`, `DataView::dist2`, batch ordering, kNN, pruning
/// bounds — the bound ≥ distance comparisons in [`crate::knn::farthest`]
/// hold exactly because both sides use this accumulation).
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0f64;
    for (&x, &y) in a.iter().zip(b) {
        let diff = (x - y) as f64;
        s += diff * diff;
    }
    s
}

/// Squared Euclidean distance from an f32 row to an f64 centroid (each
/// coordinate widened before subtracting) — the Lloyd/objective variant.
#[inline]
pub fn sq_dist_to_f64(a: &[f32], mu: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), mu.len());
    let mut s = 0f64;
    for (&x, &m) in a.iter().zip(mu) {
        let diff = x as f64 - m;
        s += diff * diff;
    }
    s
}

/// Fold `row` into the f64 running sums `acc` (`acc[j] += row[j]`) and
/// return the row's squared norm `Σ row[j]²`, both accumulated in index
/// order — the moment update of `ClusterDelta::add` and the certificate
/// chunk folds, kept here so the two stay bit-identical by construction.
#[inline]
pub fn accumulate(acc: &mut [f64], row: &[f32]) -> f64 {
    debug_assert_eq!(acc.len(), row.len());
    let mut xx = 0f64;
    for (a, &v) in acc.iter_mut().zip(row) {
        let v = v as f64;
        *a += v;
        xx += v * v;
    }
    xx
}

/// Inverse of [`accumulate`]: fold `row` out of `acc` and return the
/// row's squared norm (`ClusterDelta::remove`).
#[inline]
pub fn decumulate(acc: &mut [f64], row: &[f32]) -> f64 {
    debug_assert_eq!(acc.len(), row.len());
    let mut xx = 0f64;
    for (a, &v) in acc.iter_mut().zip(row) {
        let v = v as f64;
        *a -= v;
        xx += v * v;
    }
    xx
}

/// `acc[j] += row[j]` in f64, index order — the column-sum update behind
/// centroid and column-mean accumulation.
#[inline]
pub fn add_assign_row(acc: &mut [f64], row: &[f32]) {
    debug_assert_eq!(acc.len(), row.len());
    for (a, &v) in acc.iter_mut().zip(row) {
        *a += v as f64;
    }
}

/// Squared L2 norm of an f32 row accumulated in f64, index order.
#[inline]
pub fn sumsq_f64(row: &[f32]) -> f64 {
    row.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// Squared distance between two centroids given as f64 *sums* with
/// member counts: `Σ_j (sa[j]/ma − sb[j]/mb)²`. Pass `mb = 1.0` when `sb`
/// already is a mean (division by 1.0 is exact). Ward merge costs and
/// the online BGSS term share this one accumulation.
#[inline]
pub fn centroid_sq_dist(sa: &[f64], ma: f64, sb: &[f64], mb: f64) -> f64 {
    debug_assert_eq!(sa.len(), sb.len());
    let mut dist2 = 0f64;
    for (&a, &b) in sa.iter().zip(sb) {
        let diff = a / ma - b / mb;
        dist2 += diff * diff;
    }
    dist2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn rand_vec(rng: &mut Pcg32, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    fn dot_ref_f64(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    #[test]
    fn mode_display_round_trips() {
        for m in KernelMode::ALL {
            assert_eq!(m.to_string().parse::<KernelMode>().unwrap(), m);
        }
        assert_eq!(KernelMode::accepted(), "auto|scalar|fma");
        let err = "avx512".parse::<KernelMode>().unwrap_err();
        assert!(err.to_string().contains("auto|scalar|fma"), "{err}");
    }

    #[test]
    fn scalar_table_reports_scalar_everywhere() {
        let k = Kernels::select(KernelMode::Scalar);
        assert_eq!(k.isa(), "scalar");
        assert_eq!(k.mode(), KernelMode::Scalar);
    }

    #[test]
    fn auto_dot_bit_identical_to_scalar() {
        // On a host with AVX2/NEON this is the vector-vs-scalar
        // bit-identity microtest; on a host without, both tables are
        // scalar and it holds trivially.
        let auto = Kernels::select(KernelMode::Auto);
        let scalar = Kernels::scalar();
        let mut rng = Pcg32::new(901);
        for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 100, 128, 257] {
            let a = rand_vec(&mut rng, len);
            let b = rand_vec(&mut rng, len);
            let (va, vs) = (auto.dot(&a, &b), scalar.dot(&a, &b));
            assert_eq!(va.to_bits(), vs.to_bits(), "len={len} isa={}", auto.isa());
            let want = dot_ref_f64(&a, &b);
            assert!((vs as f64 - want).abs() < 1e-3 * (1.0 + want.abs()), "len={len}");
        }
    }

    #[test]
    fn auto_row_norms_and_cost_block_bit_identical_to_scalar() {
        let auto = Kernels::select(KernelMode::Auto);
        let scalar = Kernels::scalar();
        let mut rng = Pcg32::new(902);
        // k > TILE_COLS exercises tiling; ragged d exercises the tail.
        for &(m, k, d) in &[(5usize, 9usize, 4usize), (17, 70, 13), (3, 65, 32), (8, 128, 8)] {
            let x = rand_vec(&mut rng, m * d);
            let c = rand_vec(&mut rng, k * d);
            let (mut xn_a, mut xn_s) = (Vec::new(), Vec::new());
            auto.row_norms(&x, m, d, &mut xn_a);
            scalar.row_norms(&x, m, d, &mut xn_s);
            assert_eq!(xn_a, xn_s, "row_norms m={m} d={d}");
            let (mut cn_a, mut cn_s) = (Vec::new(), Vec::new());
            auto.row_norms(&c, k, d, &mut cn_a);
            scalar.row_norms(&c, k, d, &mut cn_s);
            let (mut out_a, mut out_s) = (vec![0f32; m * k], vec![0f32; m * k]);
            auto.cost_block(&x, &xn_a, 0, m, d, &c, &cn_a, k, &mut out_a);
            scalar.cost_block(&x, &xn_s, 0, m, d, &c, &cn_s, k, &mut out_s);
            assert_eq!(out_a, out_s, "cost_block m={m} k={k} d={d}");
            // And against the direct f64 definition, with tolerance.
            for i in 0..m {
                for j in 0..k {
                    let want = sq_dist(&x[i * d..(i + 1) * d], &c[j * d..(j + 1) * d]);
                    let got = out_s[i * k + j] as f64;
                    assert!((got - want).abs() < 1e-3 * (1.0 + want), "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn fma_mode_is_ulp_close_to_scalar() {
        let fma = Kernels::select(KernelMode::Fma);
        assert_eq!(fma.mode(), KernelMode::Fma);
        let scalar = Kernels::scalar();
        let mut rng = Pcg32::new(903);
        for len in [8usize, 32, 128, 1000] {
            let a = rand_vec(&mut rng, len);
            let b = rand_vec(&mut rng, len);
            let (vf, vs) = (fma.dot(&a, &b) as f64, scalar.dot(&a, &b) as f64);
            let want = dot_ref_f64(&a, &b);
            // Contraction only ever tightens the error bound; both stay
            // within a few f32 ULPs of the f64 reference. The magnitude
            // scale is Σ|a||b|, against which per-step rounding is bound.
            let scale: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
            let tol = 1e-5 * (1.0 + scale);
            assert!((vf - want).abs() <= tol, "len={len}: fma {vf} vs ref {want}");
            assert!((vf - vs).abs() <= tol, "len={len}: fma {vf} vs scalar {vs}");
        }
    }

    #[test]
    fn env_scalar_forces_the_fallback_on_any_host() {
        // Other tests may race this env var, but the worst outcome is a
        // concurrently-initialized process default landing on `scalar`,
        // which is bit-identical to `auto` — results cannot change.
        std::env::set_var("ABA_KERNELS", "scalar");
        assert_eq!(kernel_mode_env_default(), KernelMode::Scalar);
        assert_eq!(Kernels::select(kernel_mode_env_default()).isa(), "scalar");
        std::env::set_var("ABA_KERNELS", "no-such-mode");
        assert_eq!(kernel_mode_env_default(), KernelMode::Auto);
        // Exported-but-empty (CI matrices) means "no override".
        std::env::set_var("ABA_KERNELS", "");
        assert_eq!(kernel_mode_env_default(), KernelMode::Auto);
        std::env::remove_var("ABA_KERNELS");
        assert_eq!(kernel_mode_env_default(), KernelMode::Auto);
    }

    #[test]
    fn accumulate_decumulate_round_trip() {
        let mut rng = Pcg32::new(904);
        let row = rand_vec(&mut rng, 11);
        let mut acc = vec![0f64; 11];
        let xx = accumulate(&mut acc, &row);
        assert!((xx - sumsq_f64(&row)).abs() < 1e-12 * (1.0 + xx));
        assert_eq!(decumulate(&mut acc, &row), xx);
        assert!(acc.iter().all(|&v| v.abs() < 1e-12));
        let mut means = vec![0f64; 11];
        add_assign_row(&mut means, &row);
        for (m, &v) in means.iter().zip(&row) {
            assert_eq!(*m, v as f64);
        }
    }

    #[test]
    fn centroid_sq_dist_matches_direct_means() {
        let sa = [2.0f64, 4.0, 6.0];
        let sb = [1.0f64, 1.0, 1.0];
        // means: [1, 2, 3] vs [0.5, 0.5, 0.5] -> 0.25 + 2.25 + 6.25
        let got = centroid_sq_dist(&sa, 2.0, &sb, 2.0);
        assert!((got - 8.75).abs() < 1e-12, "{got}");
        // mb = 1.0 treats sb as an already-divided mean, exactly.
        assert_eq!(centroid_sq_dist(&sa, 2.0, &sb, 1.0), {
            let mut s = 0f64;
            for (a, b) in sa.iter().zip(&sb) {
                let diff = a / 2.0 - b;
                s += diff * diff;
            }
            s
        });
    }

    #[test]
    fn sq_dist_variants_agree() {
        let mut rng = Pcg32::new(905);
        let a = rand_vec(&mut rng, 9);
        let b = rand_vec(&mut rng, 9);
        let mu: Vec<f64> = b.iter().map(|&v| v as f64).collect();
        let d32 = sq_dist(&a, &b);
        let d64 = sq_dist_to_f64(&a, &mu);
        // Same values, different widening points: equal up to f32
        // subtraction vs f64 subtraction of f32-representable values —
        // here both are exact per coordinate difference of the widened
        // pair only when the f32 subtraction does not round; allow ULPs.
        assert!((d32 - d64).abs() < 1e-6 * (1.0 + d64), "{d32} vs {d64}");
        assert_eq!(sq_dist(&a, &a), 0.0);
    }
}
